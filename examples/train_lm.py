"""End-to-end training scenario: a reduced granite-MoE trains for a few
hundred steps with checkpointing and straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import sys
sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    from repro.launch import train
    sys.argv = ["train", "--arch", "granite-moe-1b-a400m",
                "--steps", str(args.steps), "--reduced",
                "--ckpt", "/tmp/quickstart_ckpt", "--batch", "16",
                "--seq", "128"]
    train.main()


if __name__ == "__main__":
    main()
