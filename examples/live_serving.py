"""Online serving under live traffic: the DESIGN.md §11 runtime.

Single (s, t) requests arrive as an open-loop Poisson stream with a
Zipf-skewed pair mix; the ServingRuntime micro-batches them against
the planner's warmup-compiled pow2 buckets, answers the hot head from
the epoch-tagged result cache, and keeps serving while a background
RefreshDriver absorbs waves of traffic updates through the
incremental delta path.  At the end, a sample of responses is checked
against the host Dijkstra oracle *of the epoch that served each one*
— the consistency contract under concurrent refresh.

    PYTHONPATH=src python examples/live_serving.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core.dist_engine import EpochedEngine  # noqa: E402
from repro.core.graph import road_like  # noqa: E402
from repro.serving import (ServingRuntime,  # noqa: E402
                           run_load_with_refresh,
                           validate_against_epochs, workload_pairs)


def main() -> None:
    t0 = time.perf_counter()
    g = road_like(1600, seed=11)
    engine = EpochedEngine(g)
    runtime = ServingRuntime(engine, max_batch=128, deadline_s=0.002,
                             cache_size=16384)
    runtime.warmup()
    print(f"built road graph n={g.n} m={g.m}, index, and warm serving "
          f"runtime in {time.perf_counter() - t0:.1f}s "
          f"(max_batch={runtime.max_batch}, deadline 2ms)")

    # one blocking request straight away
    d = runtime.query(3, g.n - 5)
    print(f"single query dist(3, {g.n - 5}) = {d}")

    # open-loop Zipf load with two concurrent refresh waves, staged
    # through the prioritized refresh pipeline (DESIGN.md §14): the
    # busiest-served groups re-close first and every intermediate
    # epoch publishes with an explicit staleness descriptor
    pairs = workload_pairs(engine.g, "zipf", 3000, seed=2)
    report, graphs, driver = run_load_with_refresh(
        runtime, pairs, rate_qps=600.0, seed=3, refresh_rounds=2,
        refresh_frac=0.03, refresh_interval_s=0.2, refresh_seed=5,
        refresh_pipelined=True)
    runtime.close()

    stats = report.runtime_stats
    epochs = sorted({r.epoch for r in report.requests})
    print(f"served {report.n_requests} requests at "
          f"{report.achieved_qps:.0f} qps: p50 {report.p50_ms}ms "
          f"p95 {report.p95_ms}ms p99 {report.p99_ms}ms")
    print(f"cache: {stats['cache_hit_rate']:.1%} hit rate, "
          f"{stats['cache_stale']} stale entries rejected; "
          f"{stats['flushes']} flushes "
          f"(full={stats['flush_full']}, "
          f"deadline={stats['flush_deadline']}), occupancy "
          f"{stats['mean_occupancy']:.1%}")
    rec = driver.as_record()
    print(f"epochs served: {epochs} (refresh mean "
          f"{rec['refresh_mean_s']}s across {rec['refresh_items']} "
          f"pipelined work items)")
    print(f"staleness: max serving gap {report.max_serving_gap_ms}ms, "
          f"{report.stale_responses} responses from mid-pipeline "
          f"epochs, max lag {report.max_staleness_batches} batch(es)")
    checked, bad = validate_against_epochs(report.requests, graphs,
                                           sample=48,
                                           evicted=driver.evicted_epochs)
    assert bad == 0, f"{bad} responses broke epoch consistency"
    print(f"validated {checked} responses against their serving "
          "epoch's host oracle: all exact — live-serving demo OK")


if __name__ == "__main__":
    main()
