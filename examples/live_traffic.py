"""Live traffic on a road graph: serve through weight updates.

The end-to-end demo of the incremental-maintenance subsystem
(DESIGN.md §9): an EpochedEngine serves exact batched shortest-distance
queries while waves of localized traffic (jams, then clears) mutate
edge weights.  Each wave is absorbed by the delta path — only the dirty
fragments are re-solved, the SUPER overlay is re-closed from their new
boundary distances, only the dirty pieces are rewritten — and
published as a new
immutable index epoch; queries never see a half-updated index and a
sample is validated against host Dijkstra on the *current* graph every
epoch.

    PYTHONPATH=src python examples/live_traffic.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import dijkstra  # noqa: E402
from repro.core.dist_engine import EpochedEngine  # noqa: E402
from repro.core.graph import road_like, traffic_updates  # noqa: E402


def validate(engine: EpochedEngine, rng, n_queries=256, n_check=24) -> str:
    s = rng.integers(0, engine.g.n, n_queries)
    t = rng.integers(0, engine.g.n, n_queries)
    t0 = time.perf_counter()
    out = engine.query(s, t)
    dt = time.perf_counter() - t0
    bad = 0
    for i in range(n_check):
        want = dijkstra.pair(engine.g, int(s[i]), int(t[i]))
        if not (np.isinf(want) and np.isinf(out[i])) \
                and abs(out[i] - want) > 1e-4 * max(want, 1):
            bad += 1
    assert bad == 0, f"{bad} mismatches vs Dijkstra"
    return (f"{n_queries} queries in {dt * 1e3:.1f}ms "
            f"({dt / n_queries * 1e6:.1f}us/q), {n_check} validated")


def main() -> None:
    rng = np.random.default_rng(7)
    t0 = time.perf_counter()
    g = road_like(1600, seed=11)
    engine = EpochedEngine(g)
    engine.warmup(256)
    print(f"built road graph n={g.n} m={g.m} + index in "
          f"{time.perf_counter() - t0:.1f}s "
          f"(k={engine.plan.k} fragments, S={engine.plan.S} boundary "
          f"nodes, {engine.plan.n_pieces} pieces)")
    print(f"epoch 0: {validate(engine, rng)}")

    for wave in range(3):
        # morning jam: localized slowdowns; evening: the jam clears
        u, v, w = traffic_updates(engine.g, frac=0.03, seed=100 + wave,
                                  jam_frac=1.0 if wave % 2 == 0 else 0.0)
        t0 = time.perf_counter()
        stats = engine.apply_updates(u, v, w)
        dt = time.perf_counter() - t0
        kind = "jam" if wave % 2 == 0 else "clear"
        print(f"epoch {engine.epoch}: absorbed {stats.n_updates} "
              f"{kind} updates in {dt * 1e3:.0f}ms — dirty "
              f"{stats.n_dirty_frags}/{stats.n_frags} fragments, "
              f"{stats.n_dirty_pieces}/{stats.n_pieces} pieces, "
              f"{stats.n_eb_slots} E_B slots, "
              f"decrease_only={stats.decrease_only}")
        print(f"         {validate(engine, rng)}")
    print("live-traffic demo OK")


if __name__ == "__main__":
    main()
