"""Fault-tolerance scenario: training survives a simulated node failure
mid-run — checkpoint, shrink the mesh, restore, continue.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import lm_batches
from repro.launch import steps
from repro.models import transformer
from repro.models.common import Shardings
from repro.optim import adamw_init
from repro.runtime import ElasticTrainer, FailureInjector, StragglerMonitor


def main() -> None:
    cfg = transformer.LMConfig(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, dtype=jnp.float32)
    sh = Shardings(mesh=None)
    data = lm_batches(8, 64, cfg.vocab, seed=0)

    def make_mesh(n):
        return None

    def make_step(mesh):
        fn = steps.lm_train_step(cfg, sh, n_micro=1)
        jit_fn = jax.jit(fn, donate_argnums=(0, 1))

        def step(state, batch):
            params, opt = state
            params, opt, metrics = jit_fn(params, opt, batch)
            return (params, opt)
        return step, None

    def init_state(mesh):
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        return (params, adamw_init(params))

    ck = CheckpointManager("/tmp/elastic_demo", keep=3)
    trainer = ElasticTrainer(ckpt=ck, make_mesh=make_mesh,
                             make_step=make_step, init_state=init_state,
                             checkpoint_every=10)
    injector = FailureInjector(fail_at_step=25)
    monitor = StragglerMonitor()
    out = trainer.run(40, (jnp.asarray(b) for b in data),
                      injector=injector, monitor=monitor)
    print("run summary:", out)
    print("straggler summary:", monitor.summary())
    assert out["restarts"] == 1 and out["final_step"] == 40
    print("elastic failover OK: failed at step 25, resumed from 20, "
          "finished 40")


if __name__ == "__main__":
    main()
