"""Quickstart: build a DISLAND index over a synthetic road network and
answer exact shortest-distance queries three ways.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dijkstra
from repro.core.device_engine import (build_device_index_with_plan,
                                      serve_step)
from repro.core.dist_engine import QueryPlanner
from repro.core.engine import DislandEngine
from repro.core.graph import road_like
from repro.core.paths import PathUnwinder, path_weight
from repro.core.supergraph import build_index


def main() -> None:
    g = road_like(3000, seed=0)
    print(f"graph: {g.n} nodes, {g.m} edges")

    # 1. preprocessing (paper Fig. 7): agents/DRAs -> partition ->
    #    hybrid landmark covers -> SUPER graph
    ix = build_index(g)
    sup = ix.super_graph.graph
    print(f"index: {len(ix.fragments)} fragments, SUPER graph "
          f"{sup.n} nodes ({sup.n / g.n:.1%}) / {sup.m} edges")

    # 2. host engine (paper-faithful bi-level query answering)
    eng = DislandEngine(ix)
    s, t = 17, g.n - 5
    print(f"DISLAND  dist({s},{t}) = {eng.query(s, t):.1f}")
    print(f"Dijkstra dist({s},{t}) = {dijkstra.pair(g, s, t):.1f}")

    # 3. device engine: one jitted program answers a whole batch
    dix, plan = build_device_index_with_plan(ix)
    rng = np.random.default_rng(1)
    qs = jnp.asarray(rng.integers(0, g.n, 512), jnp.int32)
    qt = jnp.asarray(rng.integers(0, g.n, 512), jnp.int32)
    dist = jax.jit(lambda a, b: serve_step(dix, a, b))(qs, qt)
    print(f"batched device engine: {dist.shape[0]} queries, "
          f"mean dist {float(jnp.mean(jnp.where(jnp.isfinite(dist), dist, 0))):.1f}")

    # 4. query planner: bucket the batch by case so each jitted
    #    sub-program does only its own work
    planner = QueryPlanner(dix)
    dist_p = planner(np.asarray(qs), np.asarray(qt))
    assert np.allclose(np.asarray(dist), dist_p, rtol=1e-4, equal_nan=False)
    print(f"planner buckets: {planner.last_counts} (matches serve_step)")

    # 5. exact *paths*: witness-mode serving + host-side unwinding
    #    (DESIGN.md §10) — same index, no extra graph search
    d_w, wit = planner.query_witness(np.asarray(qs[:8]),
                                     np.asarray(qt[:8]))
    unwinder = PathUnwinder(dix, plan)
    path = unwinder.unwind(int(qs[0]), int(qt[0]), d_w[0], wit[0])
    assert path_weight(g, path) == float(d_w[0])
    print(f"path({int(qs[0])},{int(qt[0])}): {len(path) - 1} hops, "
          f"weight {path_weight(g, path):.0f} == served distance")


if __name__ == "__main__":
    main()
