"""Serving scenario: the paper's application as a service — build the
index once, then serve batched query streams with validation.

    PYTHONPATH=src python examples/serve_roadgraph.py
"""
import sys
sys.path.insert(0, "src")


def main() -> None:
    from repro.launch import serve
    sys.argv = ["serve", "--nodes", "6000", "--batches", "8",
                "--batch-size", "2048", "--validate", "64"]
    serve.main()


if __name__ == "__main__":
    main()
