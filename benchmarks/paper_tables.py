"""One benchmark per paper table/figure (deliverable d).

Synthetic road networks stand in for the DIMACS USA graphs (offline
container; DESIGN.md §6); each function validates the paper's
*structural* claim at reduced scale and prints a CSV row per graph.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import dijkstra
from repro.core.agent_wrap import AgentAccelerated, PlainDijkstra
from repro.core.agents import compute_dras
from repro.core.arcflags import ArcFlags
from repro.core.ch import CH
from repro.core.device_engine import build_device_index, serve_step
from repro.core.engine import DislandEngine
from repro.core.graph import Graph, road_like
from repro.core.landmarks import (hybrid_cover, landmark_cover_2approx,
                                  landmark_cover_cost)
from repro.core.partition import partition_bgp
from repro.core.supergraph import build_index
from repro.data.queries import grid_distance_queries

GRAPH_SIZES = (1000, 2500, 6000, 12000)


def _graphs(sizes=GRAPH_SIZES):
    for n in sizes:
        yield f"road{n // 1000}k" if n >= 1000 else f"road{n}", \
            road_like(n, seed=n)


def table1_landmark_overhead(out: List[str]) -> None:
    """Table I: direct landmark covers are impractical."""
    out.append("table1,graph,n,m,|D|,frac_nodes,cover_bytes,"
               "graph_bytes,ratio,time_s")
    for name, g in _graphs((600, 1200, 2500)):
        t0 = time.perf_counter()
        cover, _ = landmark_cover_2approx(g)
        dt = time.perf_counter() - t0
        c = landmark_cover_cost(g, cover)
        out.append(
            f"table1,{name},{g.n},{g.m},{c['n_landmarks']},"
            f"{c['frac_nodes']:.3f},{c['cover_bytes']},"
            f"{c['graph_bytes']},{c['ratio']:.1f},{dt:.2f}")


def table3_agents(out: List[str]) -> None:
    """Table III: agents/DRA counts + compDRAs runtime."""
    out.append("table3,graph,n,agents,agents_frac,represented,"
               "rep_frac,time_s")
    for name, g in _graphs():
        t0 = time.perf_counter()
        dras = compute_dras(g)
        dt = time.perf_counter() - t0
        rep = int(dras.represented_mask().sum())
        out.append(f"table3,{name},{g.n},{dras.n_nontrivial_agents},"
                   f"{dras.n_nontrivial_agents / g.n:.3f},{rep},"
                   f"{rep / g.n:.3f},{dt:.2f}")


def table4_partitions(out: List[str]) -> None:
    """Table IV: BGP fragment/boundary statistics on shrink graphs."""
    out.append("table4,graph,shrink_n,fragments,avg_nodes,"
               "boundary_frac,time_s")
    for name, g in _graphs():
        dras = compute_dras(g)
        shrink, _ = g.subgraph(dras.shrink_nodes())
        gamma = 2 * int(np.sqrt(g.n))
        t0 = time.perf_counter()
        part = partition_bgp(shrink, gamma)
        dt = time.perf_counter() - t0
        b = part.boundary_mask(shrink).sum()
        out.append(f"table4,{name},{shrink.n},{part.n_fragments},"
                   f"{shrink.n / max(part.n_fragments, 1):.1f},"
                   f"{b / max(shrink.n, 1):.3f},{dt:.2f}")


def table5_hybrid_covers(out: List[str]) -> None:
    """Table V: hybrid covers with vs without the cost model."""
    out.append("table5,graph,with_cm_lm,with_cm_edges,"
               "without_cm_lm,without_cm_edges")
    for name, g in _graphs((2500,)):
        ix = build_index(g, use_cost_model=True)
        lm_w = np.mean([f.cover.landmarks.size for f in ix.fragments])
        e_w = np.mean([f.cover.n_enforced_edges for f in ix.fragments])
        ix2 = build_index(g, use_cost_model=False)
        lm_o = np.mean([f.cover.landmarks.size for f in ix2.fragments])
        e_o = np.mean([f.cover.n_enforced_edges for f in ix2.fragments])
        out.append(f"table5,{name},{lm_w:.1f},{e_w:.1f},{lm_o:.1f},"
                   f"{e_o:.1f}")


def table6_super_graphs(out: List[str]) -> None:
    """Table VI: SUPER graph sizes relative to the input."""
    out.append("table6,graph,super_nodes_frac,super_edges_frac")
    for name, g in _graphs():
        ix = build_index(g)
        sup = ix.super_graph.graph
        out.append(f"table6,{name},{sup.n / g.n:.4f},{sup.m / g.m:.4f}")


def exp4_preprocessing(out: List[str]) -> None:
    """Exp-4: preprocessing time + extra space across approaches."""
    out.append("exp4,graph,approach,prep_s,extra_edges_or_bits")
    name, g = next(_graphs((2500,)))
    t0 = time.perf_counter()
    ix = build_index(g)
    disland_t = time.perf_counter() - t0
    out.append(f"exp4,{name},disland,{disland_t:.2f},"
               f"{ix.extra_space_edges()['total']}")
    t0 = time.perf_counter()
    ch = CH(g)
    out.append(f"exp4,{name},ch,{time.perf_counter() - t0:.2f},"
               f"{ch.extra_edges()}")
    t0 = time.perf_counter()
    af = ArcFlags(g, n_regions=12)
    out.append(f"exp4,{name},arcflags,{time.perf_counter() - t0:.2f},"
               f"{af.extra_bits()}")
    t0 = time.perf_counter()
    ac = AgentAccelerated(g, lambda s: CH(s))
    out.append(f"exp4,{name},agent+ch,{time.perf_counter() - t0:.2f},"
               f"{ac.inner.extra_edges()}")


def exp5_query_latency(out: List[str]) -> None:
    """Exp-5 / Figs 9-10: query latency per grid-distance bucket."""
    out.append("exp5,graph,bucket,algo,us_per_query")
    name, g = next(_graphs((6000,)))
    queries = grid_distance_queries(g, n_per_set=40, n_sets=6, seed=1)
    ix = build_index(g)
    eng = DislandEngine(ix)
    dix = build_device_index(ix)
    import jax
    import jax.numpy as jnp
    jit_serve = jax.jit(lambda s, t: serve_step(dix, s, t))
    ch = CH(g)
    af = ArcFlags(g, n_regions=12)
    abd = AgentAccelerated(g, lambda s: PlainDijkstra(s,
                                                      bidirectional=True))
    algos: Dict[str, Callable] = {
        "dijkstra": lambda s, t: dijkstra.pair(g, s, t),
        "bidijkstra": lambda s, t: dijkstra.bidirectional(g, s, t),
        "agent+bidij": abd.query,
        "ch": ch.query,
        "arcflags": af.query,
        "disland": eng.query,
    }
    for bucket, pairs in queries.items():
        for algo, fn in algos.items():
            t0 = time.perf_counter()
            for s, t in pairs:
                fn(int(s), int(t))
            dt = (time.perf_counter() - t0) / len(pairs)
            out.append(f"exp5,{name},Q{bucket},{algo},{dt * 1e6:.1f}")
        # batched device engine: whole bucket in one jitted call
        s = jnp.asarray(pairs[:, 0], jnp.int32)
        t = jnp.asarray(pairs[:, 1], jnp.int32)
        jax.block_until_ready(jit_serve(s, t))     # warm
        t0 = time.perf_counter()
        jax.block_until_ready(jit_serve(s, t))
        dt = (time.perf_counter() - t0) / len(pairs)
        out.append(f"exp5,{name},Q{bucket},disland-batched,"
                   f"{dt * 1e6:.2f}")


def exp7_incremental_refresh(out: List[str]) -> None:
    """Exp-7 (beyond the paper): incremental index refresh vs rebuild.

    Absorbs localized live-traffic batches through the delta path
    (DESIGN.md §9) and compares against a from-scratch device rebuild
    on the same structure — wall time and array-for-array parity.
    """
    from repro.core.device_engine import build_device_index
    from repro.core.dist_engine import EpochedEngine
    from repro.core.graph import traffic_updates
    from repro.core.supergraph import reweight_index

    out.append("exp7,graph,round,update_frac,dirty_frag_frac,"
               "decrease_only,refresh_s,reweight_s,pipeline_s,"
               "ratio_vs_pipeline,match")
    name, g = next(_graphs((2500,)))
    eng = EpochedEngine(g)
    for r in range(3):
        u, v, w = traffic_updates(eng.g, 0.02, seed=40 + r)
        t0 = time.perf_counter()
        stats = eng.apply_updates(u, v, w)
        refresh_s = time.perf_counter() - t0
        # reweight rebuild: exactness reference (same structure)
        t0 = time.perf_counter()
        sdix = build_device_index(reweight_index(eng.ix, eng.g))
        reweight_s = time.perf_counter() - t0
        # full pipeline: the pre-delta-path cost of a weight change
        # (hybrid covers are weight-dependent, DESIGN.md §9)
        t0 = time.perf_counter()
        build_device_index(build_index(eng.g))
        pipeline_s = time.perf_counter() - t0
        match = all(
            np.array_equal(np.asarray(getattr(eng.dix, f)),
                           np.asarray(getattr(sdix, f)))
            for f in ("frag_apsp", "frag_next", "brow", "d_super",
                      "super_next", "piece_flat", "piece_next",
                      "dist_to_agent"))
        out.append(f"exp7,{name},{r},0.02,"
                   f"{stats.dirty_frag_frac:.3f},"
                   f"{int(stats.decrease_only)},"
                   f"{refresh_s:.3f},{reweight_s:.3f},{pipeline_s:.3f},"
                   f"{refresh_s / max(pipeline_s, 1e-9):.3f},"
                   f"{int(match)}")


def exp8_path_reconstruction(out: List[str]) -> None:
    """Exp-8 (beyond the paper): exact path serving via witness
    unwinding (DESIGN.md §10) vs distance-only serving vs host Dijkstra
    with predecessors.

    The witness mode's extra device cost is the argmin carry; the host
    cost is O(path length) table chasing per query — no graph search.
    Every unwound path is validated edge-by-edge and weight-exact.
    """
    from repro.core.dist_engine import EpochedEngine
    from repro.core.paths import path_weight

    out.append("exp8,graph,algo,us_per_query,mean_hops,exact")
    name, g = next(_graphs((2500,)))
    eng = EpochedEngine(g, paths=True)
    rng = np.random.default_rng(8)
    q = 512
    s = rng.integers(0, g.n, q).astype(np.int32)
    t = rng.integers(0, g.n, q).astype(np.int32)
    eng.warmup(q)
    eng.unwinder()                       # snapshot outside the timing
    # distance-only planner serving
    t0 = time.perf_counter()
    eng.query(s, t)
    dist_us = (time.perf_counter() - t0) / q * 1e6
    # witness serving + host unwind
    t0 = time.perf_counter()
    dist, paths = eng.query_path(s, t)
    path_us = (time.perf_counter() - t0) / q * 1e6
    hops = [len(p) - 1 for p in paths if p is not None]
    exact = all(
        (p is None and np.isinf(dist[i]))
        or path_weight(g, p) == float(dist[i])
        == dijkstra.pair(g, int(s[i]), int(t[i]))
        for i, p in list(enumerate(paths))[:64])
    # host baseline: one predecessor Dijkstra per query
    t0 = time.perf_counter()
    for a, b in zip(s[:64], t[:64]):
        dijkstra.pair_with_path(g, int(a), int(b))
    host_us = (time.perf_counter() - t0) / 64 * 1e6
    out.append(f"exp8,{name},serve-dist,{dist_us:.1f},0,1")
    out.append(f"exp8,{name},serve-paths,{path_us:.1f},"
               f"{np.mean(hops):.1f},{int(exact)}")
    out.append(f"exp8,{name},dijkstra-path,{host_us:.1f},"
               f"{np.mean(hops):.1f},1")


def exp9_sustained_load(out: List[str]) -> None:
    """Exp-9 (beyond the paper): the online serving runtime under
    sustained open-loop load (DESIGN.md §11).

    Arrival-rate sweep x result-cache on/off x concurrent-refresh
    on/off over a Zipf-skewed mix: tail latency (p50/p99), achieved
    qps, cache hit rate, and mean batch occupancy per cell, with a
    per-epoch host-oracle check on a response sample (bad == 0 is the
    epoch-consistency claim under load).  Each cell rebuilds the
    device index from the same host index so cells stay comparable
    (refresh cells mutate weights).
    """
    from repro.core.dist_engine import EpochedEngine
    from repro.core.supergraph import build_index as _build_ix
    from repro.serving import (ServingRuntime, run_load_with_refresh,
                               validate_against_epochs,
                               workload_pairs)

    out.append("exp9,graph,rate_qps,cache,refresh,achieved_qps,"
               "p50_ms,p99_ms,hit_rate,mean_occ,epochs,oracle_bad,"
               "max_gap_ms,stale_resp")
    name, g = next(_graphs((2500,)))
    ix = _build_ix(g)
    for rate in (500.0, 2000.0):
        for cache in (True, False):
            for refresh in (True, False):
                eng = EpochedEngine(g, ix=ix)
                rt = ServingRuntime(eng, max_batch=256,
                                    deadline_s=0.002,
                                    cache_size=65536 if cache else 0)
                rt.warmup()
                pairs = workload_pairs(eng.g, "zipf",
                                       max(1, int(rate * 2.5)), seed=9)
                rep, graphs, drv = run_load_with_refresh(
                    rt, pairs, rate_qps=rate, seed=5,
                    refresh_rounds=2 if refresh else 0,
                    refresh_interval_s=0.2, refresh_seed=17,
                    refresh_pipelined=refresh)
                rt.close()
                _n, bad = validate_against_epochs(
                    rep.requests, graphs, sample=32,
                    evicted=drv.evicted_epochs if drv else ())
                st = rep.runtime_stats
                epochs = len({r.epoch for r in rep.requests})
                out.append(
                    f"exp9,{name},{rate:.0f},"
                    f"{int(cache)},{int(refresh)},"
                    f"{rep.achieved_qps:.0f},{rep.p50_ms},"
                    f"{rep.p99_ms},"
                    f"{st.get('cache_hit_rate', 0.0):.3f},"
                    f"{st['mean_occupancy']:.3f},{epochs},{bad},"
                    f"{rep.max_serving_gap_ms},"
                    f"{rep.stale_responses}")


def exp10_scale(out: List[str]) -> None:
    """Exp-10 (beyond the paper): the hierarchy scale sweep
    (DESIGN.md §12).

    Builds each preset end to end — host index, device index with the
    preset's overlay closure (dense at road4000, deep multilevel
    hierarchy at road64k) — then measures planner serve latency at
    batch 1024,
    a refresh round, the overlay memory actually resident (closure +
    witness + row tables) against the dense (S+1)^2 baseline, and a
    sampled host-Dijkstra parity check.  The overlay_bytes column is
    the sub-quadratic-in-S claim, recorded per graph so the scale
    trajectory lives in BENCH_serve.json next to the latency history.

    Graph set via EXP10_GRAPHS (comma-separated preset names); the CI
    artifact run keeps the default, road250k is opt-in (host
    preprocessing dominates at that size).
    """
    import os

    from repro.core.dist_engine import EpochedEngine
    from repro.core.graph import traffic_updates
    from repro.data.roads import road_preset

    names = os.environ.get("EXP10_GRAPHS", "road4000,road64k")
    workers = int(os.environ.get("EXP10_BUILD_WORKERS", "1"))
    out.append("exp10,graph,n,S,levels,nsf,S2,overlay_bytes,"
               "overlay_dense_bytes,build_s,device_s,refresh_s,"
               "us_per_query,oracle_bad")
    out.append("host_build,graph,build_workers,wall_s")
    for name in names.split(","):
        preset = road_preset(name.strip())
        g = preset.make()
        t0 = time.perf_counter()
        ix = build_index(g, build_workers=workers)
        build_s = time.perf_counter() - t0
        # the staged-pipeline wall record the host-build bench gate
        # reads (DESIGN.md §17), emitted here so scale graphs get a
        # host_build history without a second serve-driver build
        out.append(f"host_build,{name},{workers},{build_s:.4f}")
        t0 = time.perf_counter()
        eng = EpochedEngine(g, ix=ix,
                            hierarchy_levels=preset.hierarchy)
        device_s = time.perf_counter() - t0
        plan = eng.plan
        if plan.hierarchy_levels >= 2:
            from repro.core.hierarchy import hier_overlay_stats

            st = hier_overlay_stats(plan.hier, plan.S)
            nsf, s2 = st["nsf"], st["S2"]
            ov_bytes = st["overlay_bytes"]
            dense_bytes = st["overlay_dense_bytes"]
        else:
            nsf, s2 = 0, 0
            dense_bytes = ov_bytes = 2 * (plan.S + 1) ** 2 * 4
        eng.warmup(1024)
        rng = np.random.default_rng(7)
        s = rng.integers(0, g.n, 1024).astype(np.int32)
        t = rng.integers(0, g.n, 1024).astype(np.int32)
        t0 = time.perf_counter()
        got = eng.query(s, t)
        serve_s = time.perf_counter() - t0
        u, v, w = traffic_updates(eng.g, frac=0.01, seed=11)
        t0 = time.perf_counter()
        eng.apply_updates(u, v, w)
        refresh_s = time.perf_counter() - t0
        got2 = eng.query(s, t)
        bad = 0
        for i in range(16):
            want = dijkstra.pair(g, int(s[i]), int(t[i]))
            bad += dijkstra.mismatches_oracle(want, float(got[i]))
            want2 = dijkstra.pair(eng.g, int(s[i]), int(t[i]))
            bad += dijkstra.mismatches_oracle(want2, float(got2[i]))
        out.append(
            f"exp10,{name},{g.n},{plan.S},{plan.hierarchy_levels},"
            f"{nsf},{s2},{ov_bytes},{dense_bytes},{build_s:.1f},"
            f"{device_s:.1f},{refresh_s:.2f},"
            f"{serve_s / 1024 * 1e6:.2f},{bad}")


ALL = [table1_landmark_overhead, table3_agents, table4_partitions,
       table5_hybrid_covers, table6_super_graphs, exp4_preprocessing,
       exp5_query_latency, exp7_incremental_refresh,
       exp8_path_reconstruction, exp9_sustained_load, exp10_scale]
