"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table3,exp5] \
        [--json BENCH_serve.json]

Prints CSV rows (section,graph,...) so downstream tooling can diff
runs; --json additionally appends structured perf records (section,
graph, qps, us_per_query) for the latency sections, so the serve-path
trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time


def _perf_records(rows: list[str]) -> list[dict]:
    """Extract structured perf records from latency/refresh rows."""
    records = []
    for row in rows:
        parts = row.split(",")
        if parts[0] == "exp5" and parts[1] != "graph":
            us = float(parts[4])
            records.append({
                "section": "exp5",
                "graph": parts[1],
                "bucket": parts[2],
                "algo": parts[3],
                "us_per_query": us,
                "qps": round(1e6 / us, 1) if us > 0 else float("inf"),
            })
        elif parts[0] == "exp8" and parts[1] != "graph":
            us = float(parts[3])
            records.append({
                "section": "exp8_paths",
                "graph": parts[1],
                "algo": parts[2],
                "us_per_query": us,
                "mean_hops": float(parts[4]),
                "exact": bool(int(parts[5])),
            })
        elif parts[0] == "exp9" and parts[1] != "graph":
            records.append({
                "section": "exp9_live",
                "graph": parts[1],
                "rate_qps": float(parts[2]),
                "cache": bool(int(parts[3])),
                "refresh": bool(int(parts[4])),
                "achieved_qps": float(parts[5]),
                "p50_ms": float(parts[6]),
                "p99_ms": float(parts[7]),
                "cache_hit_rate": float(parts[8]),
                "mean_occupancy": float(parts[9]),
                "epochs_served": int(parts[10]),
                "oracle_bad": int(parts[11]),
            })
        elif parts[0] == "exp10" and parts[1] != "graph":
            ov = int(parts[7])
            s = int(parts[3])
            records.append({
                "section": "exp10_scale",
                "graph": parts[1],
                "n": int(parts[2]),
                "S": s,
                "hierarchy_levels": int(parts[4]),
                "nsf": int(parts[5]),
                "S2": int(parts[6]),
                "overlay_bytes": ov,
                "overlay_dense_bytes": int(parts[8]),
                # the tentpole claim, made checkable per record: the
                # resident overlay tables are smaller than the dense
                # closure pair measured in the same row
                "sub_quadratic": ov < int(parts[8]),
                "build_s": float(parts[9]),
                "device_s": float(parts[10]),
                "refresh_s": float(parts[11]),
                "us_per_query": float(parts[12]),
                "oracle_bad": int(parts[13]),
            })
        elif parts[0] == "host_build" and parts[1] != "graph":
            records.append({
                "section": "host_build",
                "graph": parts[1],
                "build_workers": int(parts[2]),
                "wall_s": float(parts[3]),
            })
        elif parts[0] == "exp7" and parts[1] != "graph":
            records.append({
                "section": "exp7_refresh",
                "graph": parts[1],
                "round": int(parts[2]),
                "update_frac": float(parts[3]),
                "dirty_frag_frac": float(parts[4]),
                "decrease_only": bool(int(parts[5])),
                "refresh_s": float(parts[6]),
                "scratch_reweight_s": float(parts[7]),
                "scratch_pipeline_s": float(parts[8]),
                "refresh_over_scratch": float(parts[9]),
                "scratch_match": bool(int(parts[10])),
            })
    return records


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import paper_tables

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section prefixes")
    ap.add_argument("--json", default=None,
                    help="append structured perf records to this file")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    out: list[str] = []
    t_all = time.perf_counter()
    for fn in paper_tables.ALL:
        name = fn.__name__
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        fn(out)
        out.append(f"# {name} took {time.perf_counter() - t0:.1f}s")
    out.append(f"# total {time.perf_counter() - t_all:.1f}s")
    print("\n".join(out))
    if args.json:
        from repro.perflog import append_records
        records = _perf_records(out)
        append_records(args.json, records)
        print(f"# {len(records)} perf records appended to {args.json}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
