"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table3,exp5]

Prints CSV rows (section,graph,...) so downstream tooling can diff runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import paper_tables

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section prefixes")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    out: list[str] = []
    t_all = time.perf_counter()
    for fn in paper_tables.ALL:
        name = fn.__name__
        if only and not any(name.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        fn(out)
        out.append(f"# {name} took {time.perf_counter() - t0:.1f}s")
    out.append(f"# total {time.perf_counter() - t_all:.1f}s")
    print("\n".join(out))


if __name__ == "__main__":
    main()
