"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
experiments/dryrun/*.json.  Run after a sweep:

    PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
import glob
import json


def load():
    recs = {}
    for f in sorted(glob.glob("experiments/dryrun/*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def main() -> None:
    recs = load()
    print("## Dry-run matrix (compile status, per-device memory)\n")
    print("| arch | shape | mesh | ok | lower s | compile s | "
          "fit GB (args+temp) | notes |")
    print("|---|---|---|---|---|---|---|---|")
    for (a, s, m), r in sorted(recs.items()):
        if r["ok"]:
            mem = r["memory"]
            fit = (mem["argument_size_in_bytes"]
                   + mem["temp_size_in_bytes"]) / 1e9
            print(f"| {a} | {s} | {m} | OK | {r['lower_s']:.1f} | "
                  f"{r['compile_s']:.1f} | {fit:.2f} | "
                  f"{r.get('notes', '')} |")
        else:
            print(f"| {a} | {s} | {m} | **FAIL** | | | | "
                  f"{r.get('error', '')[:60]} |")
    print()
    print("## Roofline (single-pod, 256 chips; terms in seconds/step)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "model/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    rows = []
    for (a, s, m), r in sorted(recs.items()):
        if m != "single" or not r["ok"]:
            continue
        ro = r["roofline"]
        rows.append((ro["roofline_fraction"], a, s, ro))
    for frac, a, s, ro in sorted(rows, reverse=True):
        print(f"| {a} | {s} | {ro['compute_s']:.4f} | "
              f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
              f"{ro['dominant'].replace('_s', '')} | "
              f"{ro['model_vs_hlo_flops']:.3f} | {frac:.4f} |")
    print()
    print("## Multi-pod deltas (512 chips vs 256; collective term)\n")
    print("| arch | shape | coll_s single | coll_s multipod | "
          "pod-axis overhead |")
    print("|---|---|---|---|---|")
    for (a, s, m), r in sorted(recs.items()):
        if m != "single" or not r["ok"]:
            continue
        r2 = recs.get((a, s, "multipod"))
        if not r2 or not r2["ok"]:
            continue
        c1 = r["roofline"]["collective_s"]
        c2 = r2["roofline"]["collective_s"]
        ovh = (c2 - c1) / c1 if c1 > 0 else float("nan")
        print(f"| {a} | {s} | {c1:.4f} | {c2:.4f} | {ovh:+.1%} |")


if __name__ == "__main__":
    main()
