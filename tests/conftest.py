import os
import sys

# Tests run against the single default CPU device (the 512-device flag is
# dryrun.py-only, per the launch design).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ImportError:  # container without hypothesis: deterministic stub
    from _hypothesis_stub import install

    install()
    from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
