"""Property tests for the multilevel (coarsen / partition / refine)
BGP partitioner and its hierarchy-planner caller, plus the N-level
serving differential (DESIGN.md §13).

The partitioner invariants gate the tentpole's objective: every unit
assigned exactly once, the balance bound respected in *weight* units
(the quotient-graph caller weighs each fragment by its boundary mass),
and the planner's reported level-2 boundary size matching an
independent recount from the slot endpoints.
"""
import numpy as np
import pytest

from repro.core import hierarchy
from repro.core.device_engine import build_device_index_with_plan
from repro.core.graph import Graph, road_like
from repro.core.partition import partition_bgp
from repro.core.supergraph import build_index


def _random_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    u, v = u[keep], v[keep]
    # ensure connectivity-ish: chain backbone
    cu = np.arange(n - 1)
    cv = cu + 1
    u = np.concatenate([u, cu])
    v = np.concatenate([v, cv])
    w = rng.integers(1, 20, u.size).astype(float)
    return Graph.from_edges(n, u, v, w)


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_partition_weighted_invariants(seed):
    """Weighted quotient-graph path: every node assigned exactly once,
    labels compact, and each fragment's node-weight sum respects the
    bound whenever no single unit exceeds it on its own."""
    g = _random_graph(300, 600, seed)
    rng = np.random.default_rng(seed + 1)
    node_w = rng.integers(1, 9, g.n)
    gamma = 64
    res = partition_bgp(g, gamma, seed=seed, node_w=node_w)
    assert res.labels.shape == (g.n,)
    assert (res.labels >= 0).all()
    assert res.labels.max() + 1 == res.n_fragments
    assert np.array_equal(np.unique(res.labels),
                          np.arange(res.n_fragments))
    sizes = np.zeros(res.n_fragments, np.int64)
    np.add.at(sizes, res.labels, node_w)
    assert sizes.max() <= gamma, (sizes.max(), gamma)
    assert sizes.sum() == node_w.sum()      # exactly-once, in weight


def test_partition_default_weights_identical():
    """node_w=None is exactly the all-ones path — the level-1 call
    sites stay byte-identical to the pre-weighted partitioner."""
    g = _random_graph(250, 500, 7)
    a = partition_bgp(g, 48, seed=2)
    b = partition_bgp(g, 48, seed=2, node_w=np.ones(g.n, np.int64))
    np.testing.assert_array_equal(a.labels, b.labels)
    assert a.n_fragments == b.n_fragments


def test_partition_edge_cut_and_boundary_consistent():
    g = _random_graph(200, 420, 5)
    res = partition_bgp(g, 40, seed=1)
    cut = (res.labels[g.edge_u] != res.labels[g.edge_v])
    assert res.edge_cut(g) == int(cut.sum())
    mask = res.boundary_mask(g)
    want = np.zeros(g.n, bool)
    want[g.edge_u[cut]] = True
    want[g.edge_v[cut]] = True
    np.testing.assert_array_equal(mask, want)


def test_planner_boundary_size_matches_recount():
    """Every grouping level of a deep hierarchy: each unit in exactly
    one group, groups within the planner's balance bound, and the
    reported S2 equal to an independent recount of cross-group slot
    endpoints."""
    g = road_like(900, seed=17)
    _dix, plan = build_device_index_with_plan(build_index(g),
                                              hierarchy_levels=3)
    assert plan.hier and len(plan.hier) >= 1
    S = plan.S
    src, dst = plan.sup_src, plan.sup_dst      # level-1 adjacency slots
    for li, h in enumerate(plan.hier):
        assert h.sf_of.shape == (S,)
        assert (h.sf_of >= 0).all() and h.sf_of.max() + 1 == h.nsf
        # members table round-trips: exactly-once assignment
        for sid in range(S):
            assert h.sf_members[h.sf_of[sid], h.pos_in_sf[sid]] == sid
        # reported boundary == independent recount of the endpoints of
        # this level's cross-group slots (slot_sf < 0 marks crossing)
        crossing = h.slot_sf < 0
        np.testing.assert_array_equal(
            h.sf_of[src[crossing]] != h.sf_of[dst[crossing]],
            np.ones(int(crossing.sum()), bool))
        recount = np.unique(np.concatenate([src[crossing],
                                            dst[crossing]]))
        assert h.S2 == recount.size, f"level {li}"
        np.testing.assert_array_equal(h.bnd2_ids, recount)
        # next level groups the level-up ids via the level-up slots
        S, src, dst = h.S2, h.l2_src, h.l2_dst


def test_nlevel_differential_road4000():
    """levels=1 vs 2 vs 3 serve array-equal distances on road4000 —
    the acceptance-criteria differential at the benchmark scale."""
    import jax.numpy as jnp

    from repro.core.device_engine import serve_step

    g = road_like(4000, seed=0)
    ix = build_index(g)
    rng = np.random.default_rng(2)
    s = jnp.asarray(rng.integers(0, g.n, 512), jnp.int32)
    t = jnp.asarray(rng.integers(0, g.n, 512), jnp.int32)
    base = None
    for lv in (1, 2, 3):
        dix, plan = build_device_index_with_plan(ix, hierarchy_levels=lv)
        assert dix.hierarchy_levels == lv
        out = np.asarray(serve_step(dix, s, t))
        if base is None:
            base = out
        else:
            np.testing.assert_array_equal(base, out,
                                          err_msg=f"levels={lv}")


def test_hierarchy_balance_bound():
    """The quotient partitioner's groups respect the boundary-mass
    balance bound the planner hands it (gamma2), in units of per-unit
    boundary counts."""
    g = road_like(900, seed=17)
    _dix, plan = build_device_index_with_plan(build_index(g),
                                              hierarchy_levels=2)
    h = plan.hier[0]
    # per-fragment boundary-node counts are the unit weights
    frag_of_sid = hierarchy._frag_of_sid(plan)
    bcount = np.bincount(frag_of_sid, minlength=plan.k)
    gsum = np.zeros(h.nsf, np.int64)
    np.add.at(gsum, h.sf_of_frag[bcount > 0], bcount[bcount > 0])
    gamma2 = hierarchy._default_gamma2(plan.S)
    assert gsum.max() <= max(gamma2, bcount.max())
