"""Observability layer tests (DESIGN.md §16): metrics registry
thread-safety, streaming-histogram percentile exactness against a
sorted-list reference, span nesting/ordering invariants, trace-export
golden structure from a deterministic scripted serve, the perflog
atomic-append contract under concurrency, and the measured cost of the
disabled tracing path.

The percentile contract under test: ``Histogram.percentile(q)`` must
land within one geometric bucket (``growth`` relative error, 5% by
default) of the exact nearest-rank answer, clamped into the exact
tracked [min, max] — and the phase-scoped ``since()`` window must obey
the same bound using only bucket-count subtraction.
"""
import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.obs import (Counter, Histogram, MetricsExporter,
                       MetricsRegistry, MetricsServer, SlowQueryLog,
                       Tracer, load_chrome_trace, write_chrome_trace,
                       write_snapshot)
from repro.obs import trace as trace_mod
from repro.perflog import append_records, read_records


# ---------------------------------------------------------------------------
# metrics primitives + registry
# ---------------------------------------------------------------------------
def test_registry_get_or_create_shares_instances():
    reg = MetricsRegistry()
    c1 = reg.counter("serve.cache.hits")
    c2 = reg.counter("serve.cache.hits")
    assert c1 is c2
    c1.inc(3)
    assert c2.value == 3
    assert reg.names() == ["serve.cache.hits"]
    assert reg.get("serve.cache.hits") is c1
    assert reg.get("nope") is None


def test_registry_type_conflict_raises():
    """Two call sites silently aliasing one name to different
    primitives is always a bug — it must raise, not return either."""
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.histogram("x")


def test_registry_concurrent_increments_exact():
    """The thread-safety contract: N threads hammering shared
    counters/labels/histograms lose no update — totals are exact."""
    reg = MetricsRegistry()
    n_threads, n_iter = 8, 2000
    barrier = threading.Barrier(n_threads)

    def work(tid):
        barrier.wait()
        c = reg.counter("c")            # get-or-create races too
        lab = reg.labeled("lab")
        h = reg.histogram("h")
        g = reg.gauge("g")
        for i in range(n_iter):
            c.inc()
            lab.inc(tid % 3)
            h.observe(1e-3 * (1 + (i % 7)))
            g.set(i)

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * n_iter
    assert reg.counter("c").value == total
    assert reg.labeled("lab").total == total
    assert sum(reg.labeled("lab").snapshot().values()) == total
    assert reg.histogram("h").count == total
    snap = reg.histogram("h").freeze()
    assert sum(snap.counts.values()) == total


def _exact_nearest_rank(xs, q):
    xs = np.sort(np.asarray(xs, float))
    rank = max(1, int(np.ceil(q / 100.0 * len(xs))))
    return float(xs[rank - 1])


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_percentiles_match_sorted_list(dist):
    """p50/p95/p99 from the streaming histogram vs the exact sorted
    list: within one bucket (5% relative) of the nearest-rank answer,
    and always inside the exact observed [min, max]."""
    rng = np.random.default_rng(hash(dist) % 2**31)
    if dist == "lognormal":
        xs = rng.lognormal(-6.0, 1.0, size=5000)      # ~ms latencies
    elif dist == "uniform":
        xs = rng.uniform(1e-4, 5e-2, size=5000)
    else:
        xs = np.concatenate([rng.normal(2e-3, 2e-4, 2500),
                             rng.normal(4e-2, 3e-3, 2500)]).clip(1e-6)
    h = Histogram("lat")
    for x in xs:
        h.observe(float(x))
    snap = h.freeze()
    assert snap.count == len(xs)
    assert snap.min == pytest.approx(float(xs.min()))
    assert snap.max == pytest.approx(float(xs.max()))
    for q in (1, 25, 50, 90, 95, 99, 99.9, 100):
        got = snap.percentile(q)
        want = _exact_nearest_rank(xs, q)
        assert want / h.growth <= got <= want * h.growth, (q, got, want)
        assert snap.min <= got <= snap.max
    assert snap.mean == pytest.approx(float(xs.mean()), rel=1e-9)


def test_histogram_edge_cases():
    h = Histogram("h")
    assert h.freeze().percentile(99) == 0.0        # empty: defined 0
    h.observe(5e-3)
    s = h.freeze()
    # single observation: every percentile IS that observation (the
    # min==max clamp defeats bucket-midpoint error entirely)
    for q in (0, 50, 100):
        assert s.percentile(q) == pytest.approx(5e-3)
    # outlier beyond the top bucket: mass is clamped, max stays exact
    h2 = Histogram("h2", max_buckets=64)
    h2.observe(1e9)
    assert h2.freeze().max == 1e9
    with pytest.raises(ValueError):
        Histogram("bad", growth=1.0)


def test_histogram_since_window_is_phase_scoped():
    """since(prev) must report ONLY the observations after the freeze
    point — the mechanism run_load uses to scope a shared runtime
    histogram to one load phase."""
    rng = np.random.default_rng(7)
    a = rng.uniform(1e-3, 2e-3, 300)               # phase A: fast
    b = rng.uniform(5e-2, 9e-2, 400)               # phase B: slow
    h = Histogram("lat")
    for x in a:
        h.observe(float(x))
    h0 = h.freeze()
    for x in b:
        h.observe(float(x))
    win = h.since(h0)
    assert win.count == len(b)
    assert win.sum == pytest.approx(float(b.sum()), rel=1e-6)
    for q in (50, 95, 99):
        got = win.percentile(q)
        want = _exact_nearest_rank(b, q)
        # window min/max fall back to bucket bounds, so allow one
        # bucket of slack on each side of the exact-reference bound
        assert want / h.growth**2 <= got <= want * h.growth**2
        assert got > float(a.max())                # phase A invisible
    # empty window
    h1 = h.freeze()
    assert h.since(h1).count == 0
    assert h.since(h1).percentile(99) == 0.0


def test_registry_snapshot_and_prometheus_render():
    reg = MetricsRegistry()
    reg.counter("serve.cache.hits").inc(5)
    reg.gauge("serve.epoch").set(3)
    reg.labeled("serve.batch.flushes").inc("deadline", 2)
    reg.array_counter("serve.frag_traffic", 4).add(
        np.array([0, 2, 0, 7], np.int64))
    reg.histogram("serve.request.latency_s").observe(1e-3)
    snap = reg.snapshot()
    assert snap["serve.cache.hits"] == 5
    assert snap["serve.batch.flushes"] == {"deadline": 2}
    assert snap["serve.frag_traffic"]["total"] == 9
    assert snap["serve.frag_traffic"]["nonzero"] == 2
    assert snap["serve.request.latency_s"]["count"] == 1
    json.dumps(snap)                               # JSON-safe
    prom = reg.prometheus()
    assert "# TYPE serve_cache_hits counter" in prom
    assert "serve_epoch 3" in prom
    assert 'serve_batch_flushes{label="deadline"} 2' in prom
    assert 'serve_request_latency_s{quantile="0.99"}' in prom
    assert "serve_request_latency_s_count 1" in prom


# ---------------------------------------------------------------------------
# tracing spans
# ---------------------------------------------------------------------------
def test_disabled_span_is_shared_noop_singleton():
    """The disabled fast path allocates nothing: every span() call
    returns the same no-op object, events are dropped before building
    anything, and timed() still fills the timings dict."""
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a", k=1), tr.span("b")
    assert s1 is s2                                # shared singleton
    with s1:
        pass
    tr.event("e", 0.0, 1.0, tag=1)
    assert tr.events() == []
    out = {}
    with tr.timed("t", out, "stage"):
        time.sleep(0.002)
    assert out["stage"] >= 0.002                   # timed ALWAYS times
    assert tr.events() == []                       # ... but no event


def test_span_nesting_and_ordering_invariants():
    """Nested spans: children emit before parents (exit order), carry
    their depth, and parent intervals contain child intervals."""
    tr = Tracer(enabled=True)
    with tr.span("outer", stage="build"):
        assert tr.depth == 1
        with tr.span("inner"):
            assert tr.depth == 2
            time.sleep(0.001)
        with tr.span("inner2"):
            pass
    assert tr.depth == 0
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "inner2", "outer"]
    outer = evs[2]
    assert outer["ph"] == "X" and outer["args"]["stage"] == "build"
    assert "depth" not in outer["args"]            # top level
    for child in evs[:2]:
        assert child["args"]["depth"] == 1
        assert child["ts"] >= outer["ts"]
        assert child["ts"] + child["dur"] \
            <= outer["ts"] + outer["dur"] + 1e-3
    assert evs[0]["ts"] + evs[0]["dur"] <= evs[1]["ts"] + 1e-3


def test_span_depth_is_per_thread():
    tr = Tracer(enabled=True)
    seen = {}

    def work(tid):
        with tr.span(f"t{tid}"):
            time.sleep(0.005)
            seen[tid] = tr.depth
    threads = [threading.Thread(target=work, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(d == 1 for d in seen.values())      # no cross-thread
    tids = {e["tid"] for e in tr.events()}
    assert len(tids) == 4                          # separate rows


def test_tracer_buffer_bounded_with_drop_count():
    tr = Tracer(enabled=True, max_events=10)
    for i in range(25):
        tr.event(f"e{i}", 0.0, 1.0)
    evs = tr.events()
    assert len(evs) == 10 and tr.dropped == 15
    assert evs[-1]["name"] == "e24"                # oldest dropped
    assert tr.drain() and tr.events() == []


def test_disabled_path_is_cheap():
    """The overhead argument's foundation: a disabled span() call is
    orders of magnitude under a request's budget.  Bound it loosely
    (2µs/call average over 200k calls — CI machines are noisy; the
    real number is tens of ns)."""
    tr = Tracer(enabled=False)
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot", epoch=1, tier="cache"):
            pass
    per_call = (time.perf_counter() - t0) / n
    assert per_call < 2e-6, f"{per_call * 1e9:.0f}ns per disabled span"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_chrome_trace_roundtrip_and_truncation(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("a", k=1):
        with tr.span("b"):
            pass
    path = str(tmp_path / "trace.json")
    write_chrome_trace(path, tr.events())
    back = load_chrome_trace(path)
    assert back == tr.events()
    # a crash mid-run leaves a trailing-comma, no-] file — the Chrome
    # trace array format tolerates that, and so must the loader
    lines = open(path).read().splitlines()
    (tmp_path / "trunc.json").write_text("\n".join(lines[:-1]))
    assert load_chrome_trace(str(tmp_path / "trunc.json")) \
        == tr.events()[:-1]
    (tmp_path / "empty.json").write_text("[\n")
    assert load_chrome_trace(str(tmp_path / "empty.json")) == []


def test_metrics_snapshot_and_exporter(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    path = str(tmp_path / "metrics.json")
    snap = write_snapshot(path, reg, extra={"run": "test"})
    on_disk = json.loads(open(path).read())
    assert on_disk["metrics"]["c"] == 2 and on_disk["run"] == "test"
    assert snap["metrics"] == on_disk["metrics"]
    prom = open(str(tmp_path / "metrics.prom")).read()
    assert "# TYPE c counter" in prom
    # the periodic exporter writes a final snapshot on stop, so even a
    # run shorter than one interval leaves a complete file
    exp = MetricsExporter(reg, path, interval_s=60.0,
                          extra=lambda: {"slow_queries": []}).start()
    reg.counter("c").inc(1)
    exp.stop()
    assert exp.writes >= 1
    assert json.loads(open(path).read())["metrics"]["c"] == 3


def test_metrics_http_server():
    reg = MetricsRegistry()
    reg.counter("serve.cache.hits").inc(7)
    srv = MetricsServer(reg, port=0).start()
    try:
        base = f"http://127.0.0.1:{srv.port}"
        prom = urllib.request.urlopen(base + "/metrics").read().decode()
        assert "serve_cache_hits 7" in prom
        js = json.loads(urllib.request.urlopen(base + "/").read())
        assert js["metrics"]["serve.cache.hits"] == 7
    finally:
        srv.stop()


def test_slow_query_log_keeps_worst_n():
    log = SlowQueryLog(n=3)
    for i, lat in enumerate([0.01, 0.5, 0.02, 0.3, 0.001, 0.4]):
        log.offer(lat, {"s": i, "t": i + 1, "tier": "planner"})
    recs = log.records()
    assert log.offered == 6 and len(recs) == 3
    assert [r["latency_ms"] for r in recs] == [500.0, 400.0, 300.0]
    assert recs[0]["s"] == 1 and recs[0]["tier"] == "planner"
    json.dumps(recs)


# ---------------------------------------------------------------------------
# scripted serve -> trace export (golden structure)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    from repro.core.dist_engine import EpochedEngine
    from repro.core.graph import road_like

    g = road_like(380, seed=11)
    eng = EpochedEngine(g)
    eng.warmup(64)
    return eng


def test_scripted_serve_trace_export(engine, tmp_path):
    """Deterministic single-thread serve (auto=False) with the default
    tracer enabled: the exported Chrome trace must contain the request
    lifecycle — flush spans sized/bucketed, per-request events tagged
    with tier/epoch/staleness, tier-resolution spans — and load back
    structurally identical."""
    from repro.core.graph import traffic_updates
    from repro.serving import ServingRuntime

    e0 = engine.epoch
    tr = trace_mod.get_tracer()
    tr.clear()
    tr.enable()
    try:
        rt = ServingRuntime(engine, max_batch=64, cache_size=64,
                            auto=False)
        rng = np.random.default_rng(3)
        pairs = rng.integers(0, engine.g.n, (12, 2))
        for a, b in pairs:
            rt.submit(int(a), int(b))
        assert rt.flush() == 12
        # epoch moves; resubmit a prefix (cache goes stale) + fresh
        u, v, w = traffic_updates(engine.g, frac=0.02, seed=5)
        engine.apply_updates(u, v, w)
        for a, b in pairs[:6]:
            rt.submit(int(a), int(b))
        rt.flush()
        rt.close()
        events = tr.drain()
    finally:
        tr.enable(False)
        tr.clear()

    by_name: dict = {}
    for e in events:
        by_name.setdefault(e["name"], []).append(e)
    flushes = by_name.get("serve.flush", [])
    assert len(flushes) == 2
    assert flushes[0]["args"]["size"] == 12
    assert flushes[0]["args"]["bucket"] >= 12      # pow2 pad
    reqs = by_name.get("serve.request", [])
    assert len(reqs) == 18
    for e in reqs:
        assert e["ph"] == "X" and e["dur"] >= 0
        assert e["args"]["tier"] in ("cache", "label", "planner")
        assert e["args"]["epoch"] in (e0, e0 + 1)
        assert e["args"]["staleness"] >= 0
    # epoch tags advance across the refresh
    assert {e["args"]["epoch"] for e in reqs} == {e0, e0 + 1}
    assert by_name.get("serve.cache_lookup")
    assert by_name.get("serve.tier.planner")
    # tier-resolution spans nest inside their flush span
    f0 = flushes[0]
    t0 = by_name["serve.tier.planner"][0]
    assert f0["ts"] <= t0["ts"] + 1e-3
    assert t0["ts"] + t0["dur"] <= f0["ts"] + f0["dur"] + 1e-3

    # golden write -> load roundtrip (chrome://tracing-compatible)
    path = str(tmp_path / "serve_trace.json")
    write_chrome_trace(path, events)
    assert load_chrome_trace(path) == events


def test_runtime_metrics_registry_view(engine):
    """The runtime's registry view of one scripted serve: named
    metrics agree with the legacy stats() dict they replaced."""
    from repro.serving import ServingRuntime

    rt = ServingRuntime(engine, max_batch=64, cache_size=64,
                        auto=False)
    rng = np.random.default_rng(4)
    pairs = rng.integers(0, engine.g.n, (10, 2))
    for a, b in pairs:
        rt.submit(int(a), int(b))
    rt.flush()
    for a, b in pairs:                             # all cache hits
        rt.submit(int(a), int(b))
    rt.flush()
    rt.close()
    st = rt.stats()
    reg = rt.registry
    assert reg.counter("serve.cache.hits").value == st["cache_hits"]
    assert reg.counter("serve.tier.planner.dispatches").value \
        == st["planner_dispatches"]
    hist = rt.latency_histogram()
    assert hist.count == 20                        # every request
    assert hist.summary(scale=1e3)["p99"] > 0
    assert reg.labeled("serve.batch.flushes").get("manual") == 2


def test_tracing_overhead_loose_ab(engine):
    """A-B at test scale: the same scripted serve with tracing +
    exporters enabled must stay within 40% of the disabled wall time
    (min of 3 repeats each — CI machines are noisy; the real budget,
    <2% live qps at road4000, is measured by scripts/obs_overhead.py
    and recorded in BENCH_serve.json)."""
    from repro.serving import ServingRuntime

    rng = np.random.default_rng(9)
    pairs = rng.integers(0, engine.g.n, (64, 2))

    def one_run(traced, tmpdir=None):
        tr = trace_mod.get_tracer()
        if traced:
            tr.clear()
            tr.enable()
        rt = ServingRuntime(engine, max_batch=64, cache_size=0,
                            auto=False)
        t0 = time.perf_counter()
        for a, b in pairs:
            rt.submit(int(a), int(b))
            rt.flush()
        wall = time.perf_counter() - t0
        rt.close()
        if traced:
            tr.enable(False)
            tr.clear()
        return wall

    one_run(False), one_run(True)                  # warm both paths
    off = min(one_run(False) for _ in range(3))
    on = min(one_run(True) for _ in range(3))
    assert on <= off * 1.40, f"tracing overhead {on / off:.2f}x"


# ---------------------------------------------------------------------------
# perflog atomic append
# ---------------------------------------------------------------------------
def test_perflog_concurrent_appends_lose_nothing(tmp_path):
    """N threads x M appends through the flock'd read-modify-write:
    every record lands exactly once and the file is valid JSON at the
    end — the regression test for the lost-update/truncation bug the
    temp-file + lock rewrite fixed."""
    path = str(tmp_path / "bench.json")
    append_records(path, [{"seed": True}])
    n_threads, n_appends = 6, 20
    barrier = threading.Barrier(n_threads)

    def work(tid):
        barrier.wait()
        for i in range(n_appends):
            append_records(path, [{"tid": tid, "i": i}])

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recs = read_records(path)
    assert len(recs) == 1 + n_threads * n_appends
    got = {(r["tid"], r["i"]) for r in recs if "tid" in r}
    assert got == {(t, i) for t in range(n_threads)
                   for i in range(n_appends)}
    json.load(open(path))                          # well-formed


def test_perflog_append_survives_corrupt_history(tmp_path):
    path = str(tmp_path / "bench.json")
    with open(path, "w") as f:
        f.write('[{"half": ')                      # torn write
    append_records(path, [{"ok": 1}])
    assert read_records(path) == [{"ok": 1}]
