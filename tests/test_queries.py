"""Distribution sanity for the serving workload generators
(data/queries.py): the Zipf mix concentrates the configured mass on
its hot head, the geo mix honors its radius, and both produce valid,
reproducible (s, t) pairs."""
import numpy as np
import pytest

from repro.core.graph import road_like
from repro.data.queries import (geo_local_pairs, top_pair_mass,
                                workload_pairs, zipf_pairs)


def _pair_counts(pairs: np.ndarray) -> np.ndarray:
    """Descending query counts per distinct (s, t) pair."""
    key = pairs[:, 0].astype(np.int64) * 10_000_000 + pairs[:, 1]
    _, counts = np.unique(key, return_counts=True)
    return np.sort(counts)[::-1]


def test_zipf_top1pct_mass():
    """The top-1% of pool pairs must carry the analytically configured
    query mass (the skew the result cache exists for) — and far more
    than a uniform mix would give them."""
    g = road_like(900, seed=2)
    pool, a, n = 2048, 1.2, 40_000
    pairs = zipf_pairs(g, n, a=a, pool=pool, seed=3)
    counts = _pair_counts(pairs)
    k = max(1, int(0.01 * pool))
    emp = counts[:k].sum() / n
    want = top_pair_mass(0.01, a=a, pool=pool)
    assert abs(emp - want) < 0.05, (emp, want)
    assert emp > 10 * 0.01          # >=10x the uniform share
    # flatter exponent -> flatter head
    flat = _pair_counts(zipf_pairs(g, n, a=0.6, pool=pool, seed=3))
    assert flat[:k].sum() / n < emp


def test_zipf_pairs_valid_and_reproducible():
    g = road_like(400, seed=1)
    p1 = zipf_pairs(g, 500, seed=9)
    p2 = zipf_pairs(g, 500, seed=9)
    np.testing.assert_array_equal(p1, p2)
    assert p1.shape == (500, 2)
    assert (p1 >= 0).all() and (p1 < g.n).all()
    assert (p1[:, 0] != p1[:, 1]).all()


@pytest.mark.parametrize("radius", [1, 4, 9])
@pytest.mark.parametrize("n", [700, 170])
def test_geo_local_radius_bound(radius, n):
    """Every generated pair sits within the Chebyshev ball on the
    road_like lattice, including around the partial last row."""
    g = road_like(n, seed=4)
    side = int(np.ceil(np.sqrt(g.n)))
    pairs = geo_local_pairs(g, 2500, radius=radius, seed=6)
    assert (pairs >= 0).all() and (pairs < g.n).all()
    assert (pairs[:, 0] != pairs[:, 1]).all()
    cheb = np.maximum(
        np.abs(pairs[:, 0] // side - pairs[:, 1] // side),
        np.abs(pairs[:, 0] % side - pairs[:, 1] % side))
    assert cheb.max() <= radius


def test_geo_local_explicit_coords():
    g = road_like(300, seed=5)
    coords = np.random.default_rng(0).random((g.n, 2)) * 256
    pairs = geo_local_pairs(g, 64, radius=64, coords=coords, seed=7)
    assert (pairs[:, 0] != pairs[:, 1]).all()
    assert (pairs >= 0).all() and (pairs < g.n).all()


def test_workload_dispatcher():
    g = road_like(300, seed=5)
    for mix in ("uniform", "zipf", "geo"):
        p = workload_pairs(g, mix, 128, seed=1)
        assert p.shape == (128, 2)
        assert (p[:, 0] != p[:, 1]).all()
        assert (p >= 0).all() and (p < g.n).all()
    with pytest.raises(ValueError):
        workload_pairs(g, "bogus", 8)
    with pytest.raises(ValueError):
        zipf_pairs(g, 0)
