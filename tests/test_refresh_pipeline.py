"""Pipelined prioritized refresh tests (DESIGN.md §14).

The contract: staging a coalesced update pool through per-group work
items publishes only EXACT epochs.  Each intermediate epoch is the true
index of a well-defined intermediate graph (device answers equal the
host Dijkstra oracle on the engine's graph at that instant), every
staleness descriptor tells the truth about what is still pending, and
the final epoch of a drain is array-equal to a from-scratch rebuild on
the fully-updated graph — staleness bounds recency, never correctness.
"""
import numpy as np
import pytest

from repro.core import dijkstra
from repro.core.device_engine import build_device_index
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like, traffic_updates
from repro.core.refresh_pipeline import (FRESH, RefreshPipeline,
                                         Staleness, UpdateQueue)
from repro.core.supergraph import reweight_index
from repro.launch.serve import REFRESHED_FIELDS
from repro.serving import ServingRuntime


# ---------------------------------------------------------------------------
# queue + descriptor units (no engine)
# ---------------------------------------------------------------------------
def test_update_queue_coalesces_last_write_wins():
    q = UpdateQueue()
    s1 = q.submit([1, 2], [2, 3], [5.0, 6.0])
    s2 = q.submit([2], [1], [9.0])      # same undirected edge, flipped
    assert (s1, s2) == (1, 2)
    assert len(q) == 2                   # coalesced, not 3
    u, v, w, sub = q.take()
    assert sub == 2 and len(q) == 0
    pool = {(int(a), int(b)): float(x) for a, b, x in zip(u, v, w)}
    assert pool == {(1, 2): 9.0, (2, 3): 6.0}
    # drained: the next take is empty but keeps the sequence number
    u, v, w, sub = q.take()
    assert u.size == 0 and v.size == 0 and w.size == 0 and sub == 2


def test_staleness_semantics():
    assert FRESH.complete and FRESH.lag_batches == 0
    s = Staleness(watermark=2, submitted=5, pending_updates=7,
                  pending_groups=(0, 3))
    assert not s.complete and s.lag_batches == 3
    rec = s.as_record()
    assert rec["pending_groups"] == 2 and rec["complete"] is False
    assert rec["lag_batches"] == 3
    assert Staleness(watermark=5, submitted=5).complete


# ---------------------------------------------------------------------------
# planning: priority order (no epochs published — plan only)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    g = road_like(380, seed=21)
    return EpochedEngine(g)


def _coalesced(u, v, w):
    pool = {}
    for a, b, x in zip(u, v, w):
        pool[(min(int(a), int(b)), max(int(a), int(b)))] = float(x)
    keys = np.asarray(list(pool), np.int64).reshape(-1, 2)
    return keys[:, 0], keys[:, 1], np.asarray(list(pool.values()))


def test_plan_orders_by_pending_dirt_without_traffic(engine):
    """Without traffic counters, groups order by coalesced pending-edge
    count (most dirt first) and the tail merges into one item."""
    u, v, w = traffic_updates(engine.g, frac=0.2, seed=5)
    pipe = RefreshPipeline(engine, max_items=4)
    pipe.submit(u, v, w)
    n = pipe.plan()
    assert n == pipe.pending_items() <= 4
    cu, cv, _cw = _coalesced(u, v, w)
    grp = pipe._owner_group(cu, cv)
    groups, counts = np.unique(grp, return_counts=True)
    order = np.lexsort((groups, -counts.astype(float)))
    heads = [it[0] for it in pipe._items]
    # head items are single busiest-first groups; the final item is the
    # merged remainder covering every leftover group exactly once
    for i, gs in enumerate(heads[:-1]):
        assert gs == (int(groups[order[i]]),)
    assert sorted(g for gs in heads for g in gs) \
        == sorted(int(g) for g in groups)
    # every pooled edge landed in exactly one work item
    assert sum(it[1][0].size for it in pipe._items) == cu.size


def test_plan_orders_by_serving_traffic(engine):
    """With traffic counters the busiest-SERVED group re-closes first,
    even when another group has more pending edges."""
    u, v, w = traffic_updates(engine.g, frac=0.2, seed=6)
    cu, cv, _cw = _coalesced(u, v, w)
    probe = RefreshPipeline(engine, max_items=64)
    grp = probe._owner_group(cu, cv)
    groups, counts = np.unique(grp, return_counts=True)
    assert groups.size >= 2, "fixture pool touches a single group"
    cold = int(groups[np.argmin(counts)])    # least dirty group
    # craft traffic concentrated on `cold`'s fragments only
    plan = engine.plan
    frag2grp = np.asarray(plan.hier[0].sf_of_frag[:plan.k]
                          if plan.hier else np.arange(plan.k))
    per_frag = np.where(frag2grp == cold, 1000, 0).astype(np.int64)
    pipe = RefreshPipeline(engine, traffic=lambda: per_frag,
                           max_items=4)
    pipe.submit(u, v, w)
    assert pipe.plan() >= 2
    assert pipe._items[0][0] == (cold,)


def test_plan_is_noop_while_items_pending():
    g = road_like(300, seed=7)
    engine = EpochedEngine(g)
    u, v, w = traffic_updates(g, frac=0.1, seed=3)
    pipe = RefreshPipeline(engine, max_items=3)
    pipe.submit(u, v, w)
    n = pipe.plan()
    assert n >= 2
    # a new batch queues but does NOT reshuffle the in-flight plan
    pipe.submit(u[:1], v[:1], w[:1] + 1)
    assert pipe.plan() == n and len(pipe.queue) == 1
    stats = pipe.drain()
    assert len(stats) == n and pipe.pending_items() == 0
    # the queued-mid-drain batch keeps the published descriptor honest:
    # the drain's last epoch must NOT claim completeness over it
    stale = engine.snapshot()[3]
    assert not stale.complete and stale.lag_batches == 1
    assert stale.pending_updates == 1
    # the next plan picks up the queued batch
    assert pipe.plan() == 1
    assert pipe.step() is not None and pipe.step() is None
    assert pipe.watermark == 2
    assert engine.snapshot()[3].complete


# ---------------------------------------------------------------------------
# execution: staged epochs are exact, descriptors truthful
# ---------------------------------------------------------------------------
def _assert_epoch_exact(engine, rng, k=12):
    pairs = rng.integers(0, engine.g.n, (k, 2))
    got = engine.query(pairs[:, 0], pairs[:, 1])
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(engine.g, int(a), int(b))
        assert not dijkstra.mismatches_oracle(want, got[i]), \
            (engine.epoch, int(a), int(b), got[i], want)


def _assert_final_matches_scratch(engine):
    sdix = build_device_index(reweight_index(engine.ix, engine.g))
    for f in REFRESHED_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(engine.dix, f)),
            np.asarray(getattr(sdix, f)),
            err_msg=f"field {f} diverged from from-scratch rebuild")


def test_staged_epochs_exact_and_final_matches_scratch():
    g = road_like(380, seed=33)
    engine = EpochedEngine(g)
    rng = np.random.default_rng(0)
    u, v, w = traffic_updates(g, frac=0.08, seed=9)
    pipe = RefreshPipeline(engine, max_items=4)
    sub = pipe.submit(u, v, w)
    n_items = pipe.plan()
    assert n_items >= 2, "pool too small to stage"
    e_start = engine.snapshot()[0]
    applied = 0
    prev_pending = None
    while True:
        stats = pipe.step()
        if stats is None:
            break
        applied += 1
        epoch, _dix, _g_now, stale = engine.snapshot()
        assert epoch == e_start + applied    # one epoch per work item
        # descriptor truthfulness at every stage
        assert stale.submitted == sub
        assert len(stale.pending_groups) >= pipe.pending_items() > 0 \
            or stale.complete
        if prev_pending is not None:
            assert stale.pending_updates < prev_pending
        prev_pending = stale.pending_updates
        if pipe.pending_items():
            assert not stale.complete and stale.lag_batches == 1
        else:
            assert stale.complete and stale.watermark == sub
        # the staged epoch is EXACT for the engine's current graph
        _assert_epoch_exact(engine, rng)
    assert applied == n_items
    assert pipe.watermark == sub
    _assert_final_matches_scratch(engine)


def test_step_failure_requeues_item_and_publishes_nothing():
    g = road_like(300, seed=11)
    engine = EpochedEngine(g)
    u, v, w = traffic_updates(g, frac=0.05, seed=3)
    pipe = RefreshPipeline(engine, max_items=3)
    pipe.submit(u, v, w)
    n = pipe.plan()
    e0 = engine.snapshot()[0]

    def boom(u, v, w, *, staleness=None):
        raise RuntimeError("refresh died")

    engine.apply_updates = boom          # shadow the bound method
    with pytest.raises(RuntimeError, match="refresh died"):
        pipe.step()
    del engine.apply_updates
    assert pipe.pending_items() == n     # the item went back in front
    assert engine.snapshot()[0] == e0    # nothing was published
    assert pipe.watermark == 0
    # the retried drain completes and still lands on the exact index
    assert len(pipe.drain()) == n
    _assert_final_matches_scratch(engine)


# ---------------------------------------------------------------------------
# staged-epoch serving contract: scripted mid-pipeline interleaving
# ---------------------------------------------------------------------------
def test_staged_epoch_serving_contract():
    """Serve between pipeline steps (deterministic, auto=False): every
    response's staleness tag must be the descriptor of the epoch it was
    pinned to — mid-pipeline epochs tagged incomplete with lag 1, the
    final epoch complete — every response must equal the host oracle
    for its epoch's graph, and the fully-refreshed index must be
    array-equal to scratch."""
    g = road_like(380, seed=55)
    engine = EpochedEngine(g)
    rt = ServingRuntime(engine, max_batch=32, cache_size=64, auto=False)
    rng = np.random.default_rng(4)
    graphs, stales = {}, {}
    e0, _d, g0, s0 = engine.snapshot()
    graphs[e0], stales[e0] = g0, s0
    assert s0.complete                   # fresh build serves complete
    reqs = []

    def serve_some(k=6):
        batch = [rt.submit(int(a), int(b))
                 for a, b in rng.integers(0, g.n, (k, 2))]
        rt.flush()
        reqs.extend(batch)

    serve_some()
    u, v, w = traffic_updates(g, frac=0.08, seed=13)
    pipe = RefreshPipeline(engine, traffic=rt.frag_traffic, max_items=4)
    pipe.submit(u, v, w)
    assert pipe.plan() >= 2
    while pipe.step() is not None:
        e, _d, ge, se = engine.snapshot()
        graphs[e], stales[e] = ge, se
        serve_some()
    final_e = max(graphs)
    mid = [e for e in graphs if e not in (e0, final_e)]
    assert mid, "pipeline published no intermediate epoch"
    assert stales[final_e].complete
    for e in mid:
        assert not stales[e].complete and stales[e].lag_batches == 1
    for r in reqs:
        assert r.done and r.error is None
        assert r.staleness == stales[r.epoch], \
            (r.epoch, r.staleness, stales[r.epoch])
        want = dijkstra.pair(graphs[r.epoch], r.s, r.t)
        assert not dijkstra.mismatches_oracle(want, r.dist), \
            (r.epoch, r.s, r.t, r.dist, want)
    assert any(not r.staleness.complete for r in reqs), \
        "interleaving never served a mid-pipeline epoch"
    _assert_final_matches_scratch(engine)
