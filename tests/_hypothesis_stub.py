"""Minimal stand-in for ``hypothesis`` when it is not installed.

The test-suite only uses ``@given(st.integers(lo, hi))`` plus
``@settings(max_examples=N)`` and the profile registration API, so a
deterministic seeded sweep is a faithful (if less adversarial)
replacement.  The real package, when present, always wins — conftest
only installs this module into ``sys.modules`` on ImportError.
"""
from __future__ import annotations

import random
import sys
import types

_DEFAULT_MAX_EXAMPLES = 25


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def sample(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


def integers(min_value: int = 0, max_value: int = 1 << 30) -> _IntStrategy:
    return _IntStrategy(min_value, max_value)


class settings:  # noqa: N801 - mirrors hypothesis' API
    _profiles: dict = {}
    _current = {"max_examples": _DEFAULT_MAX_EXAMPLES}

    def __init__(self, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._hyp_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int =
                         _DEFAULT_MAX_EXAMPLES, **kw):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name: str):
        cls._current = dict(cls._profiles.get(
            name, {"max_examples": _DEFAULT_MAX_EXAMPLES}))


def given(*strategies: _IntStrategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_hyp_max_examples",
                        settings._current["max_examples"])
            rng = random.Random(0)
            for _ in range(n):
                drawn = tuple(s.sample(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
