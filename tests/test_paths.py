"""Differential harness for exact path reconstruction (DESIGN.md §10).

The contract under test, per planner case bucket and per index epoch:
``unwind_path`` turns each served (distance, witness) into a node
sequence that

  1. starts at s, ends at t, and every consecutive pair is a real edge
     of the live graph (path_weight raises otherwise),
  2. has summed edge weight EXACTLY equal to the served distance
     (planner witness programs AND monolithic serve_step_w) and to host
     Dijkstra — integer weights make f32/f64 agreement bitwise, so the
     comparisons are ==, not allclose,

for >= 500 random queries per case bucket on road graphs, repeated on
epochs published by the incremental refresh path.  The host engine's
paper-faithful path oracle (DislandEngine.query_path) is held to the
same standard on a subsample.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dijkstra
from repro.core.device_engine import serve_step, serve_step_w
from repro.core.dist_engine import EpochedEngine, QueryPlanner
from repro.core.engine import DislandEngine
from repro.core.graph import road_like, traffic_updates, tree_with_blobs
from repro.core.paths import path_weight

N_PER_BUCKET = 500


def _bucket_pairs(dix, rng, n_per_bucket,
                  buckets=QueryPlanner.CASES):
    """>= n_per_bucket random query pairs for each requested planner
    case (targeted sampling: uniform pairs alone would starve the
    same-DRA / same-fragment buckets on road graphs)."""
    agent_of = np.asarray(dix.agent_of)
    frag_of = np.asarray(dix.frag_of)
    fa = frag_of[agent_of]
    n = agent_of.size
    out = {}
    if "same_dra" in buckets:
        # random pairs inside randomly-drawn multi-member DRAs
        agents, counts = np.unique(agent_of, return_counts=True)
        multi = agents[counts >= 2]
        assert multi.size, "graph has no multi-member DRA"
        pairs = []
        while len(pairs) < n_per_bucket:
            a = int(multi[rng.integers(0, multi.size)])
            members = np.nonzero(agent_of == a)[0]
            s, t = rng.choice(members, 2)
            pairs.append((int(s), int(t)))
        out["same_dra"] = np.asarray(pairs)
    if "same_frag" in buckets:
        # same fragment, different DRAs
        frags = np.unique(fa[fa >= 0])
        pairs = []
        tries = 0
        while len(pairs) < n_per_bucket and tries < 200 * n_per_bucket:
            tries += 1
            f = int(frags[rng.integers(0, frags.size)])
            members = np.nonzero(fa == f)[0]
            s, t = rng.choice(members, 2)
            if agent_of[s] != agent_of[t]:
                pairs.append((int(s), int(t)))
        assert len(pairs) >= n_per_bucket, \
            "could not build same_frag pairs"
        out["same_frag"] = np.asarray(pairs)
    if "cross_frag" in buckets:
        # rejection-sample uniform pairs
        pairs = []
        tries = 0
        while len(pairs) < n_per_bucket and tries < 500 * n_per_bucket:
            tries += 1
            s, t = rng.integers(0, n, 2)
            if agent_of[s] != agent_of[t] and fa[s] != fa[t] \
                    and fa[s] >= 0 and fa[t] >= 0:
                pairs.append((int(s), int(t)))
        assert len(pairs) >= n_per_bucket, \
            "could not build cross_frag pairs"
        out["cross_frag"] = np.asarray(pairs)
    return out


def _assert_paths_exact(engine: EpochedEngine, pairs: np.ndarray,
                        bucket: str) -> None:
    """The acceptance contract for one bucket on the current epoch."""
    g = engine.g
    s, t = pairs[:, 0].astype(np.int32), pairs[:, 1].astype(np.int32)
    dist, wit = engine.planner.query_witness(s, t)
    # witness-mode distances == distance-only serve_step, array-exact
    mono, wit_mono = serve_step_w(engine.dix, jnp.asarray(s),
                                  jnp.asarray(t))
    np.testing.assert_array_equal(
        dist, np.asarray(serve_step(engine.dix, jnp.asarray(s),
                                    jnp.asarray(t))),
        err_msg=f"{bucket}: witness mode perturbed distances")
    uw = engine.unwinder()
    mono_d = np.asarray(mono)
    mono_w = np.asarray(wit_mono)
    for i in range(len(s)):
        want = dijkstra.pair(g, int(s[i]), int(t[i]))
        for d, w in ((dist[i], wit[i]), (mono_d[i], mono_w[i])):
            path = uw.unwind(int(s[i]), int(t[i]), d, int(w))
            if np.isinf(want):
                assert path is None, (bucket, i, path)
                continue
            assert path[0] == s[i] and path[-1] == t[i], (bucket, i)
            # path_weight raises on any hop that is not a real edge
            assert path_weight(g, path) == float(d) == want, \
                (bucket, engine.epoch, int(s[i]), int(t[i]), path)


@pytest.mark.parametrize("seed", [0])
def test_paths_differential_road(seed):
    """>= 500 random queries per case bucket, exact against Dijkstra,
    re-checked on two refresh epochs (the acceptance gate)."""
    g = road_like(900, seed=seed)
    engine = EpochedEngine(g, paths=True)
    rng = np.random.default_rng(seed + 1)
    buckets = _bucket_pairs(engine.dix, rng, N_PER_BUCKET)
    for bucket, pairs in buckets.items():
        _assert_paths_exact(engine, pairs, bucket)
    for r in range(2):
        u, v, w = traffic_updates(engine.g, frac=0.04, seed=seed + 10 + r,
                                  localized=bool(r % 2))
        engine.apply_updates(u, v, w)
        for bucket, pairs in buckets.items():
            _assert_paths_exact(engine, pairs, bucket)
    assert engine.epoch == 2


def test_paths_blob_graph_pieces():
    """Piece-heavy graph: the same-DRA bucket exercises both WIT_PIECE
    (same-piece table) and WIT_VIA_AGENT witnesses, plus piece_next
    refresh through an update epoch."""
    g = tree_with_blobs(25, 6, seed=9)
    engine = EpochedEngine(g, paths=True)
    rng = np.random.default_rng(5)
    pairs = _bucket_pairs(engine.dix, rng, 200,
                          buckets=("same_dra",))["same_dra"]
    _assert_paths_exact(engine, pairs, "same_dra")
    u, v, w = traffic_updates(engine.g, frac=0.06, seed=77,
                              localized=False)
    engine.apply_updates(u, v, w)
    _assert_paths_exact(engine, pairs, "same_dra")


def test_host_engine_path_oracle():
    """DislandEngine.query_path: paper-faithful host oracle — its path
    weight equals its own distance and Dijkstra, on every case."""
    g = road_like(700, seed=3)
    engine = EpochedEngine(g, paths=True)
    host = DislandEngine(engine.ix)
    rng = np.random.default_rng(4)
    buckets = _bucket_pairs(engine.dix, rng, 40)
    for bucket, pairs in buckets.items():
        for s, t in pairs:
            want = dijkstra.pair(g, int(s), int(t))
            dist, path = host.query_path(int(s), int(t))
            if np.isinf(want):
                assert path is None
                continue
            assert path[0] == s and path[-1] == t
            assert path_weight(g, path) == dist == want, (bucket, s, t)


def test_unwind_trivial_and_unreachable():
    g = road_like(400, seed=2)
    engine = EpochedEngine(g, paths=True)
    uw = engine.unwinder()
    assert uw.unwind(5, 5, 0.0, -1) == [5]
    assert uw.unwind(0, 1, float("inf"), -1) is None
    # batched entry points agree
    dist, paths = engine.query_path([7, 7], [7, 123])
    assert paths[0] == [7]
    assert dist[0] == 0.0
    if np.isfinite(dist[1]):
        assert path_weight(g, paths[1]) == float(dist[1])


def test_unwinder_epoch_snapshot():
    """An unwinder snapshot stays valid for its own epoch's witnesses
    even after the engine publishes a new epoch."""
    g = road_like(500, seed=6)
    engine = EpochedEngine(g, paths=True)
    s = np.arange(0, 40, dtype=np.int32)
    t = np.arange(40, 80, dtype=np.int32)
    dist0, wit0 = engine.planner.query_witness(s, t)
    uw0 = engine.unwinder()
    g0 = engine.g
    u, v, w = traffic_updates(engine.g, frac=0.05, seed=8)
    engine.apply_updates(u, v, w)
    assert engine.unwinder() is not uw0      # cache rolled to new epoch
    for i in range(len(s)):
        if not np.isfinite(dist0[i]):
            continue
        p = uw0.unwind(int(s[i]), int(t[i]), dist0[i], int(wit0[i]))
        assert path_weight(g0, p) == float(dist0[i])
