"""REF graphs, vertex-cover landmark covers (Thm 2), hybrid covers,
and the BGP partitioner (paper §III + §V)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import dijkstra
from repro.core.graph import Graph, random_graph, road_like
from repro.core.landmarks import (hybrid_cover, landmark_cover_2approx,
                                  landmark_cover_cost, ref_graph,
                                  vertex_cover_2approx)
from repro.core.partition import partition_bgp


def all_pairs(g: Graph) -> np.ndarray:
    return np.stack([dijkstra.sssp(g, s) for s in range(g.n)])


@pytest.mark.parametrize("seed", range(5))
def test_ref_graph_preserves_distances(seed):
    g = random_graph(25, 60, seed=seed)
    ref = ref_graph(g)
    assert ref.m <= g.m
    np.testing.assert_allclose(all_pairs(ref), all_pairs(g))


def test_vertex_cover_covers_every_edge():
    g = random_graph(40, 90, seed=3)
    vc = vertex_cover_2approx(g)
    inv = np.zeros(g.n, bool)
    inv[vc] = True
    assert (inv[g.edge_u] | inv[g.edge_v]).all()


@pytest.mark.parametrize("seed", range(4))
def test_landmark_cover_property_on_ref_graph(seed):
    """Theorem 2: a vertex cover of an REF graph is a landmark cover —
    for every pair some landmark lies on a shortest path."""
    g = random_graph(18, 30, seed=seed)
    cover, ref = landmark_cover_2approx(g)
    dist = all_pairs(ref)
    lm = set(int(x) for x in cover)
    for s in range(ref.n):
        for t in range(ref.n):
            if s == t or not np.isfinite(dist[s, t]):
                continue
            ok = any(abs(dist[s, x] + dist[x, t] - dist[s, t]) < 1e-9
                     for x in lm)
            assert ok, (s, t)


def test_landmark_cover_cost_accounting():
    g = road_like(900, seed=1)
    cover, _ = landmark_cover_2approx(g)
    cost = landmark_cover_cost(g, cover)
    # paper Table I: landmarks are a large fraction of nodes and the
    # cover dwarfs the graph
    assert 0.2 < cost["frac_nodes"] < 1.0
    assert cost["ratio"] > 10
    assert cost["lower_bound"] == len(cover) // 2


@pytest.mark.parametrize("use_cost_model", [True, False])
def test_hybrid_cover_preserves_boundary_distances(use_cost_model):
    g = road_like(700, seed=2)
    rng = np.random.default_rng(0)
    boundary = rng.choice(g.n, size=12, replace=False)
    cov = hybrid_cover(g, boundary, use_cost_model=use_cost_model)
    # rebuild a graph from enforced edges only; boundary-to-boundary
    # distances must match the original exactly
    eu, ev, ew = [], [], []
    for (u, x, d) in cov.landmark_edges:
        eu.append(int(u)); ev.append(int(x)); ew.append(d)
    for (a, b, d) in cov.direct_edges:
        eu.append(int(a)); ev.append(int(b)); ew.append(d)
    nodes = sorted(set(eu) | set(ev) | set(int(b) for b in boundary))
    remap = {x: i for i, x in enumerate(nodes)}
    sg = Graph.from_edges(len(nodes), [remap[x] for x in eu],
                          [remap[x] for x in ev], ew)
    for i, b1 in enumerate(boundary):
        want = dijkstra.sssp(g, int(b1))
        got = dijkstra.sssp(sg, remap[int(b1)])
        for b2 in boundary[i + 1:]:
            w = want[int(b2)]
            gg = got[remap[int(b2)]]
            if np.isfinite(w):
                assert abs(gg - w) < 1e-6, (b1, b2, gg, w)


def test_hybrid_cover_cost_model_reduces_edges():
    g = road_like(900, seed=5)
    rng = np.random.default_rng(1)
    boundary = rng.choice(g.n, size=14, replace=False)
    with_cm = hybrid_cover(g, boundary, use_cost_model=True)
    without = hybrid_cover(g, boundary, use_cost_model=False)
    # paper Table V: the cost model never increases enforced edges
    assert with_cm.n_enforced_edges <= without.n_enforced_edges


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(3))
def test_partition_respects_gamma_and_covers(seed):
    g = road_like(1500, seed=seed)
    gamma = 2 * int(np.sqrt(g.n))
    part = partition_bgp(g, gamma, seed=seed)
    sizes = np.bincount(part.labels)
    assert sizes.max() <= gamma
    assert sizes.sum() == g.n
    assert part.n_fragments >= g.n // gamma


def test_partition_boundary_vs_edge_cut_bound():
    """Paper §V key observation: |B| <= 2 |E_B|."""
    g = road_like(1200, seed=7)
    part = partition_bgp(g, 2 * int(np.sqrt(g.n)))
    b = part.boundary_mask(g).sum()
    assert b <= 2 * part.edge_cut(g)


@given(st.integers(0, 1000))
def test_partition_random_graphs(seed):
    g = random_graph(30, 60, seed=seed)
    part = partition_bgp(g, 10, seed=0)
    sizes = np.bincount(part.labels, minlength=part.n_fragments)
    assert sizes.max() <= 10
    assert (part.labels >= 0).all()
