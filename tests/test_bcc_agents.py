"""Cut-nodes/BCCs vs brute force + agent/DRA invariants (paper §IV)."""
import heapq

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.agents import compute_dras
from repro.core.bcc import biconnected_components
from repro.core.graph import Graph, random_graph, road_like, tree_with_blobs


def brute_cut_nodes(g: Graph) -> np.ndarray:
    def ncc(skip=None):
        seen = np.zeros(g.n, bool)
        if skip is not None:
            seen[skip] = True
        cnt = 0
        for s in range(g.n):
            if seen[s]:
                continue
            cnt += 1
            stack = [s]
            seen[s] = True
            while stack:
                x = stack.pop()
                a, b = g.indptr[x], g.indptr[x + 1]
                for y in g.indices[a:b]:
                    if not seen[y] and y != skip:
                        seen[y] = True
                        stack.append(int(y))
        return cnt
    base = ncc()
    out = np.zeros(g.n, bool)
    for v in range(g.n):
        if g.indptr[v + 1] > g.indptr[v]:
            out[v] = ncc(v) > base
    return out


def dijkstra_all(g: Graph, s: int) -> np.ndarray:
    dist = np.full(g.n, np.inf)
    dist[s] = 0
    pq = [(0.0, s)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, int(v)))
    return dist


@pytest.mark.parametrize("seed", range(8))
def test_cut_nodes_match_brute_force(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 35))
    g = random_graph(n, int(rng.integers(n - 1, 3 * n)), seed=seed)
    res = biconnected_components(g)
    assert (res.cut == brute_cut_nodes(g)).all()


@pytest.mark.parametrize("seed", range(8))
def test_every_edge_in_exactly_one_bcc(seed):
    g = random_graph(20, 40, seed=seed)
    res = biconnected_components(g)
    cover = 0
    for comp in res.bcc_nodes:
        s = set(comp.tolist())
        cover += sum(1 for u, v in zip(g.edge_u, g.edge_v)
                     if u in s and v in s)
    assert cover == g.m


@given(st.integers(0, 10_000))
def test_bcc_runs_on_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 25))
    g = random_graph(n, int(rng.integers(1, 2 * n)), seed=seed)
    res = biconnected_components(g)
    assert res.n_bcc >= 1
    # cut nodes belong to >= 2 BCCs (defining property)
    membership = np.zeros(g.n)
    for comp in res.bcc_nodes:
        membership[comp] += 1
    assert (membership[res.cut] >= 2).all()


@pytest.mark.parametrize("gname,factory", [
    ("blobs", lambda: tree_with_blobs(8, 4, seed=2)),
    ("road", lambda: road_like(1500, seed=3)),
])
def test_dra_invariants(gname, factory):
    """Props 3-9: pieces sealed by the agent, exact distances, bounded
    size, disjoint DRAs."""
    g = factory()
    dras = compute_dras(g, c=2)
    assert dras.n_nontrivial_agents > 0
    seen = np.zeros(g.n, bool)
    for a in dras.agents:
        d = dijkstra_all(g, a.agent)
        np.testing.assert_allclose(d[a.nodes], a.dist_to_agent)
        assert not seen[a.nodes].any(), "DRAs must be disjoint"
        seen[a.nodes] = True
        for piece in a.pieces:
            assert piece.size <= dras.threshold
            pset = set(piece.tolist())
            assert a.agent in pset
            for x in piece:
                if x == a.agent:
                    continue
                nbrs, _ = g.neighbors(int(x))
                assert all(int(y) in pset for y in nbrs), \
                    "piece leaks around its agent"


def test_shrink_plus_represented_partitions_nodes():
    g = road_like(1200, seed=5)
    dras = compute_dras(g, c=2)
    rep = dras.represented_mask()
    sh = dras.shrink_nodes()
    assert rep.sum() + sh.size == g.n
    assert not rep[sh].any()
