"""Optimizer, checkpoint manager, fault runtime, SSSP, data pipelines."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.core import dijkstra
from repro.core.graph import road_like
from repro.core.sssp import apsp_from_sources, bellman_ford, sources_init
from repro.data import (NeighborSampler, grid_distance_queries,
                        gnn_molecule_batch, lm_batches, recsys_batches)
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         dequantize_int8, quantize_int8)
from repro.runtime import (ElasticTrainer, FailureInjector,
                           StragglerMonitor)
from repro.runtime.fault import SimulatedNodeFailure


# ---- optimizer -------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - 1.0) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, lr=5e-2,
                                      weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.ones(3), atol=1e-2)


def test_adamw_serialize_matches_parallel():
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,))}
    grads = {"a": jnp.ones((2, 3)) * 0.1, "b": -jnp.ones((4,)) * 0.2}
    o1 = adamw_init(params)
    p1, s1, _ = adamw_update(params, grads, o1, lr=1e-2, serialize=False)
    o2 = adamw_init(params)
    p2, s2, _ = adamw_update(params, grads, o2, lr=1e-2, serialize=True)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(a, b)


def test_grad_scale_equals_prescaled():
    params = {"w": jnp.array([1.0, 2.0])}
    grads = {"w": jnp.array([8.0, -4.0])}
    p1, _, m1 = adamw_update(params, grads, adamw_init(params), lr=1e-2,
                             grad_scale=0.25)
    pre = {"w": grads["w"] * 0.25}
    p2, _, m2 = adamw_update(params, pre, adamw_init(params), lr=1e-2)
    np.testing.assert_allclose(p1["w"], p2["w"], rtol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-6)


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) < 1e-6


@given(st.integers(0, 10_000))
@settings(max_examples=20)
def test_int8_quantization_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 10)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    max_err = float(jnp.max(jnp.abs(back - x)))
    assert max_err <= float(scale) * 0.5 + 1e-6


# ---- checkpoint -------------------------------------------------------------
def test_checkpoint_roundtrip_retention_atomicity(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(10.0), "opt": {"m": jnp.ones((3, 3))}}
    for step in [5, 10, 15]:
        ck.save(step, jax.tree_util.tree_map(lambda x: x * step, state))
    assert ck.all_steps() == [10, 15]   # retention
    step, got = ck.restore(state)
    assert step == 15
    np.testing.assert_allclose(got["w"], np.arange(10.0) * 15)
    # stale tmp dirs are GC'd on next save
    os.makedirs(str(tmp_path / "step_000000099.tmp-123"), exist_ok=True)
    ck.save(20, state)
    assert not any(".tmp" in n for n in os.listdir(tmp_path))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    ck.save(1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.ones(3), "b": jnp.ones(2)})


# ---- runtime ---------------------------------------------------------------
def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0)
    for _ in range(20):
        mon.observe(0.1)
    assert mon.observe(1.0) is True
    assert mon.observe(0.1) is False
    assert mon.summary()["stragglers"] == 1


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_step=3)
    inj.check(2)
    with pytest.raises(SimulatedNodeFailure):
        inj.check(3)
    inj.check(3)  # second time: already failed, no raise


def test_elastic_trainer_recovers_from_failure(tmp_path):
    """Full restart path: fail at step 7, restore from step 5, finish."""
    ck = CheckpointManager(str(tmp_path), keep=3)

    def make_mesh(n):
        return None

    def make_step(mesh):
        def step(state, batch):
            return {"x": state["x"] + batch}
        return step, None

    def init_state(mesh):
        return {"x": jnp.zeros(())}

    def batches():
        while True:
            yield jnp.ones(())

    tr = ElasticTrainer(ckpt=ck, make_mesh=make_mesh,
                        make_step=make_step, init_state=init_state,
                        checkpoint_every=5)
    inj = FailureInjector(fail_at_step=7)
    out = tr.run(12, batches(), injector=inj)
    assert out["restarts"] == 1
    assert out["final_step"] == 12
    _, state = ck.restore({"x": jnp.zeros(())})
    assert float(state["x"]) == 12.0


# ---- device SSSP -------------------------------------------------------------
def test_bellman_ford_matches_dijkstra():
    g = road_like(600, seed=11)
    src = jnp.asarray(np.concatenate([g.edge_u, g.edge_v]), jnp.int32)
    dst = jnp.asarray(np.concatenate([g.edge_v, g.edge_u]), jnp.int32)
    w = jnp.asarray(np.concatenate([g.edge_w, g.edge_w]), jnp.float32)
    sources = jnp.asarray([0, 5, 17], jnp.int32)
    got = np.asarray(apsp_from_sources(src, dst, w, sources, n=g.n))
    for i, s in enumerate([0, 5, 17]):
        want = dijkstra.sssp(g, s)
        fin = np.isfinite(want)
        np.testing.assert_allclose(got[i][fin], want[fin], rtol=1e-5)
        assert np.isinf(got[i][~fin]).all()


def test_bellman_ford_padding_edges_are_inert():
    src = jnp.asarray([0, 1, 0], jnp.int32)
    dst = jnp.asarray([1, 2, 0], jnp.int32)
    w = jnp.asarray([1.0, 2.0, np.inf], jnp.float32)
    out = bellman_ford(src, dst, w, sources_init(
        jnp.asarray([0], jnp.int32), 3), n=3)
    np.testing.assert_allclose(np.asarray(out)[0], [0.0, 1.0, 3.0])


# ---- data ---------------------------------------------------------------
def test_neighbor_sampler_produces_valid_subgraph():
    g = road_like(800, seed=13)
    samp = NeighborSampler(g, fanouts=(5, 3), d_feat=8, n_classes=4)
    rng = np.random.default_rng(0)
    batch = samp.sample(rng.integers(0, g.n, 16))
    n = batch["node_feat"].shape[0]
    assert batch["edge_src"].max() < n
    assert batch["edge_dst"].max() < n
    assert batch["loss_mask"].sum() == 16
    assert batch["labels"].shape == (n,)


def test_grid_queries_bucketed():
    g = road_like(2000, seed=14)
    qs = grid_distance_queries(g, n_per_set=20, n_sets=6, seed=0)
    assert set(qs) == set(range(1, 7))
    for i, pairs in qs.items():
        assert pairs.shape[1] == 2


def test_generators_deterministic():
    a = next(lm_batches(2, 8, 100, seed=3))
    b = next(lm_batches(2, 8, 100, seed=3))
    np.testing.assert_array_equal(a, b)
    ra = next(recsys_batches(4, 3, 50, 2, seed=5))
    rb = next(recsys_batches(4, 3, 50, 2, seed=5))
    np.testing.assert_array_equal(ra["sparse_ids"], rb["sparse_ids"])
    m = gnn_molecule_batch(3, 8, 12, 4, seed=7)
    assert m["node_feat"].shape == (24, 4)
