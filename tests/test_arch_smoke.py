"""Per-architecture smoke tests (deliverable f): a REDUCED config of the
same family runs one forward/train step on CPU — output shapes + no NaNs.
The FULL configs are exercised via the dry-run only."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data.pipelines import gnn_molecule_batch
from repro.launch import steps
from repro.models import gnn, recsys, transformer
from repro.models.common import Shardings
from repro.optim import adamw_init

SH = Shardings(mesh=None)


def _reduced_lm(cfg: transformer.LMConfig) -> transformer.LMConfig:
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, dtype=jnp.float32, attn_chunk=16,
        n_experts=4 if cfg.moe else 0, top_k=min(cfg.top_k, 2),
        gather_fsdp_in_body=False, seq_shard_activations=False)


def _reduced_gnn(cfg: gnn.GNNConfig) -> gnn.GNNConfig:
    return dataclasses.replace(cfg, n_layers=2, d_hidden=16, d_feat=8,
                               n_out=2, n_classes=5, sharded=False)


def _reduced_recsys(cfg: recsys.RecsysConfig) -> recsys.RecsysConfig:
    return dataclasses.replace(cfg, n_sparse=6, rows_per_field=100,
                               mlp_dims=(32, 16))


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch_id", list_archs())
def test_arch_smoke_train_step(arch_id):
    spec = get_arch(arch_id)
    key = jax.random.PRNGKey(0)
    if spec.family == "lm":
        cfg = _reduced_lm(spec.model_cfg)
        params = transformer.init_params(cfg, key)
        step = steps.lm_train_step(cfg, SH, n_micro=2)
        tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab)
        p2, o2, metrics = step(params, adamw_init(params), tokens)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(p2)
        # shapes preserved
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            assert a.shape == b.shape
    elif spec.family == "gnn":
        cfg = _reduced_gnn(spec.model_cfg)
        params = gnn.init_params(cfg, key)
        batch = {k: jnp.asarray(v) for k, v in
                 gnn_molecule_batch(4, 10, 16, cfg.d_feat, seed=1).items()}
        batch["labels"] = batch["labels"] % cfg.n_classes
        batch["target"] = batch["target"][:, :1].repeat(cfg.n_out, 1)
        step = steps.gnn_train_step(cfg, SH)
        p2, o2, metrics = step(params, adamw_init(params), batch)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(p2)
    else:
        cfg = _reduced_recsys(spec.model_cfg)
        params = recsys.init_params(cfg, key)
        rng = np.random.default_rng(0)
        batch = {
            "sparse_ids": jnp.asarray(rng.integers(
                0, cfg.rows_per_field,
                (8, cfg.n_sparse, cfg.hots_per_field)).astype(np.int32)),
            "dense": jnp.asarray(rng.normal(
                size=(8, cfg.n_dense)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(0, 2, 8).astype(np.int32)),
        }
        step = steps.recsys_train_step(cfg, SH)
        p2, o2, metrics = step(params, adamw_init(params), batch)
        assert np.isfinite(float(metrics["loss"]))
        assert _finite(p2)


@pytest.mark.parametrize("arch_id", [a for a in list_archs()
                                     if get_arch(a).family == "lm"])
def test_lm_smoke_prefill_decode(arch_id):
    spec = get_arch(arch_id)
    cfg = _reduced_lm(spec.model_cfg)
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab)
    logits, cache = transformer.prefill(cfg, SH, params, toks)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    cache = {"k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 4),
                                       (0, 0), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 4),
                                       (0, 0), (0, 0))),
             "len": cache["len"]}
    logits2, cache = transformer.decode_step(
        cfg, SH, params, cache, toks[:, 0])
    assert logits2.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache["len"]) == 13


def test_all_cells_build_on_tiny_mesh():
    """Every (arch x shape) cell must assemble (structs + shardings) on
    a 1x1 mesh without touching device memory."""
    from repro.compat import make_mesh
    from repro.launch.cells import build_cell
    mesh = make_mesh((1, 1), ("data", "model"))
    for arch_id in list_archs():
        for cell in get_arch(arch_id).shapes:
            b = build_cell(arch_id, cell.name, mesh)
            assert b.model_flops > 0
            leaves = jax.tree_util.tree_leaves(b.args)
            assert all(isinstance(x, jax.ShapeDtypeStruct)
                       for x in leaves)
