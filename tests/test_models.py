"""Model-level numerics: transformer equivalences, GNN oracles,
embedding-bag vs reference semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipelines import gnn_full_batch
from repro.core.graph import road_like
from repro.models import gnn, recsys, transformer
from repro.models.common import (Shardings, cross_entropy_vocab_sharded,
                                 gqa_attention, rms_norm)

SH = Shardings(mesh=None)


def _tiny_lm(moe=False, **kw):
    # capacity_factor 4.0: no token drops, so prefill/decode agree
    # exactly (drops are legitimate MoE behaviour but break equivalence)
    base = dict(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=8,
                moe=moe, n_experts=4 if moe else 0, top_k=2 if moe else 0,
                capacity_factor=4.0)
    base.update(kw)
    return transformer.LMConfig(**base)


def test_chunked_attention_equals_full():
    cfg_c = _tiny_lm(attn_chunk=4)
    cfg_f = _tiny_lm(attn_chunk=64)
    params = transformer.init_params(cfg_c, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    l1 = transformer.forward_loss(cfg_c, SH, params, toks)
    l2 = transformer.forward_loss(cfg_f, SH, params, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("moe", [False, True])
def test_decode_consistent_with_prefill(moe):
    cfg = _tiny_lm(moe=moe)
    params = transformer.init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 10), 0, 64)
    _, cache = transformer.prefill(cfg, SH, params, toks[:, :9])
    cache = {"k": jnp.pad(cache["k"], ((0, 0),) * 2 + ((0, 7),) + ((0, 0),) * 2),
             "v": jnp.pad(cache["v"], ((0, 0),) * 2 + ((0, 7),) + ((0, 0),) * 2),
             "len": cache["len"]}
    dec, _ = transformer.decode_step(cfg, SH, params, cache, toks[:, 9])
    ref, _ = transformer.prefill(cfg, SH, params, toks)
    rel = float(jnp.max(jnp.abs(dec - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 5e-4, rel


def test_gqa_attention_matches_dense_reference():
    """GQA vs explicit per-head softmax attention."""
    rng = np.random.default_rng(0)
    b, tq, tk, h, kv, dh = 2, 5, 5, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, tq, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, tk, kv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, tk, kv, dh)).astype(np.float32))
    got = gqa_attention(q, k, v, causal=True)
    # reference: expand kv heads, loop
    k_e = jnp.repeat(k, h // kv, axis=2)
    v_e = jnp.repeat(v, h // kv, axis=2)
    ref = np.zeros((b, tq, h, dh), np.float32)
    for bi in range(b):
        for hi in range(h):
            s = np.asarray(q)[bi, :, hi] @ np.asarray(k_e)[bi, :, hi].T
            s = s / np.sqrt(dh)
            mask = np.tril(np.ones((tq, tk)))
            s = np.where(mask > 0, s, -np.inf)
            p = np.exp(s - s.max(-1, keepdims=True))
            p = p / p.sum(-1, keepdims=True)
            ref[bi, :, hi] = p @ np.asarray(v_e)[bi, :, hi]
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4, atol=1e-5)


def test_vocab_sharded_ce_matches_dense():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(2, 6, 50)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 50, (2, 6)).astype(np.int32))
    got = cross_entropy_vocab_sharded(logits, labels, SH)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= k the top-1 dispatch drops ~nothing and
    the MoE layer output is a proper convex combination."""
    cfg = _tiny_lm(moe=True, capacity_factor=4.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 32))
    lw = jax.tree_util.tree_map(lambda w: w[0], params["layers"])
    out, aux = transformer._moe_ffn(cfg, SH, lw, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0


def test_rms_norm_invariants():
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16)) * 100
    y = rms_norm(x, jnp.ones(16))
    ms = float(jnp.mean(jnp.asarray(y) ** 2))
    assert abs(ms - 1.0) < 0.05


# ---- GNN ---------------------------------------------------------------
def test_molecule_block_diagonal_equals_per_graph():
    """Disjoint-union batching == running each graph separately."""
    cfg = gnn.GNNConfig(name="g", arch="graphsage", n_layers=2,
                        d_hidden=8, d_feat=4, n_classes=3)
    params = gnn.init_params(cfg, jax.random.PRNGKey(7))
    from repro.data.pipelines import gnn_molecule_batch
    b2 = gnn_molecule_batch(2, 6, 8, 4, seed=9)
    b2 = {k: jnp.asarray(v) for k, v in b2.items()}
    b2["labels"] = b2["labels"] % 3
    full = gnn.forward_loss(cfg, SH, params, b2)
    # split into the two graphs
    losses = []
    for gi in range(2):
        sel = np.asarray(b2["graph_id"]) == gi
        nidx = np.nonzero(sel)[0]
        remap = -np.ones(12, np.int64)
        remap[nidx] = np.arange(6)
        es = np.asarray(b2["edge_src"])
        ed = np.asarray(b2["edge_dst"])
        emask = sel[es]
        sub = dict(
            node_feat=b2["node_feat"][nidx],
            edge_src=jnp.asarray(remap[es[emask]].astype(np.int32)),
            edge_dst=jnp.asarray(remap[ed[emask]].astype(np.int32)),
            labels=b2["labels"][nidx],
            loss_mask=b2["loss_mask"][nidx])
        losses.append(float(gnn.forward_loss(cfg, SH, params, sub)))
    np.testing.assert_allclose(float(full), np.mean(losses), rtol=1e-5)


def test_gat_attention_rows_sum_to_one():
    """Segment softmax: incoming-edge attention normalises per node."""
    g = road_like(200, seed=15)
    batch = gnn_full_batch(g, d_feat=6, n_classes=3, seed=0)
    cfg = gnn.GNNConfig(name="gat", arch="gat", n_layers=1, d_hidden=4,
                        n_heads=2, d_feat=6, n_classes=3)
    params = gnn.init_params(cfg, jax.random.PRNGKey(8))
    lw = params["layers"][0]
    h = jnp.asarray(batch["node_feat"])
    src = jnp.asarray(batch["edge_src"])
    dst = jnp.asarray(batch["edge_dst"])
    z = jnp.einsum("nd,dhf->nhf", h, lw["w"])
    ls = jnp.einsum("nhf,hf->nh", z, lw["a_src"])
    ld = jnp.einsum("nhf,hf->nh", z, lw["a_dst"])
    e = jax.nn.leaky_relu(ls[src] + ld[dst], negative_slope=0.2)
    emax = jax.ops.segment_max(e, dst, num_segments=g.n)
    ee = jnp.exp(e - emax[dst])
    den = jax.ops.segment_sum(ee, dst, num_segments=g.n)
    alpha = ee / jnp.maximum(den[dst], 1e-9)
    sums = np.asarray(jax.ops.segment_sum(alpha, dst, num_segments=g.n))
    deg = np.asarray(jax.ops.segment_sum(jnp.ones_like(alpha[:, 0]),
                                         dst, num_segments=g.n))
    has = deg > 0
    np.testing.assert_allclose(sums[has], 1.0, rtol=1e-5)


# ---- embedding bag ---------------------------------------------------------
@given(st.integers(0, 100_000))
@settings(max_examples=20)
def test_embedding_bag_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    rows, dim = 50, 6
    b, f, h = 3, 2, 4
    table = jnp.asarray(rng.normal(size=(rows, dim)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, rows, (b, f, h)).astype(np.int32))
    got = recsys.embedding_bag(table, ids, combiner="mean")
    want = np.asarray(table)[np.asarray(ids)].mean(axis=2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


@given(st.integers(0, 100_000))
@settings(max_examples=20)
def test_embedding_bag_ragged_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    rows, dim, nnz, bags = 30, 4, 12, 5
    table = jnp.asarray(rng.normal(size=(rows, dim)).astype(np.float32))
    ids = rng.integers(0, rows, nnz).astype(np.int32)
    cuts = np.sort(rng.integers(0, nnz + 1, bags - 1))
    offsets = np.concatenate([[0], cuts]).astype(np.int32)
    got = recsys.embedding_bag_ragged(table, jnp.asarray(ids),
                                      jnp.asarray(offsets), bags,
                                      combiner="sum")
    bounds = np.concatenate([offsets, [nnz]])
    want = np.stack([np.asarray(table)[ids[bounds[i]:bounds[i + 1]]].sum(0)
                     if bounds[i + 1] > bounds[i] else np.zeros(dim)
                     for i in range(bags)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                               atol=1e-6)


def test_retrieval_topk_correct():
    cfg = recsys.RecsysConfig(name="r", n_sparse=3, rows_per_field=40,
                              embed_dim=4, mlp_dims=(16, 8))
    params = recsys.init_params(cfg, jax.random.PRNGKey(9))
    rng = np.random.default_rng(2)
    batch = dict(
        sparse_ids=jnp.asarray(rng.integers(0, 40, (1, 3, 2)).astype(np.int32)),
        dense=jnp.asarray(rng.normal(size=(1, 13)).astype(np.float32)),
        candidates=jnp.asarray(rng.normal(size=(500, 8)).astype(np.float32)))
    vals, idx = recsys.retrieval_scores(cfg, SH, params, batch, top_k=10)
    assert vals.shape == (10,)
    # monotone non-increasing + really the max
    v = np.asarray(vals)
    assert (np.diff(v) <= 1e-6).all()
