"""Differential harness for the two-level overlay hierarchy
(DESIGN.md §12).

The contract:

  1. ``hierarchy_levels=2`` serves distances ARRAY-EQUAL to the dense
     closure — every planner bucket, the monolithic program, and
     one-to-all — and exact against host Dijkstra;
  2. witness serving + host unwinding produce exact edge-valid paths
     whose overlay legs cross hierarchy levels;
  3. incremental refresh == from-scratch rebuild, array-for-array,
     for every per-level table, with rollback on failure;
  4. ``hierarchy_levels=1`` (and "auto" below the threshold) keeps the
     dense index bit-identical to the pre-hierarchy build — the
     road4000 compatibility guarantee.

Graphs here are small (forced levels=2), so both closures are cheap
and the dense one is the oracle.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dijkstra, hierarchy
from repro.core.device_engine import (build_device_index,
                                      build_device_index_with_plan,
                                      index_fields_equal,
                                      overlay_slot_table,
                                      refresh_index,
                                      resolve_hierarchy_levels,
                                      serve_one_to_all, serve_step)
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like, traffic_updates
from repro.core.paths import path_weight
from repro.core.supergraph import build_index, reweight_index
from repro.launch.serve import REFRESHED_FIELDS

HIER_FIELDS = ("sf_closure", "sf_next", "l2row", "d2", "d2_next")


@pytest.fixture(scope="module")
def built():
    g = road_like(700, seed=7)
    ix = build_index(g)
    dense = build_device_index_with_plan(ix, hierarchy_levels=1)
    hier = build_device_index_with_plan(ix, hierarchy_levels=2)
    return g, ix, dense, hier


def test_resolve_levels_knob():
    thr = hierarchy.AUTO_THRESHOLD
    assert resolve_hierarchy_levels(thr, "auto") == 1
    assert resolve_hierarchy_levels(thr + 1, "auto") == 2
    assert resolve_hierarchy_levels(50, 2) == 2
    assert resolve_hierarchy_levels(50, 3) == 3
    assert resolve_hierarchy_levels(50, hierarchy.MAX_LEVELS) \
        == hierarchy.MAX_LEVELS
    assert resolve_hierarchy_levels(0, 2) == 1      # empty overlay
    with pytest.raises(ValueError):
        resolve_hierarchy_levels(50, 0)
    with pytest.raises(ValueError):
        resolve_hierarchy_levels(50, hierarchy.MAX_LEVELS + 1)
    with pytest.raises(ValueError):
        resolve_hierarchy_levels(50, "deep")


def test_auto_small_graph_stays_dense(built):
    """'auto' below the threshold builds the exact dense index —
    bit-identical d_super/super_next, 1-sized hierarchy dummies."""
    g, ix, (dix1, _p1), _ = built
    auto_dix = build_device_index(ix)               # default: auto
    assert auto_dix.hierarchy_levels == 1
    np.testing.assert_array_equal(np.asarray(auto_dix.d_super),
                                  np.asarray(dix1.d_super))
    np.testing.assert_array_equal(np.asarray(auto_dix.super_next),
                                  np.asarray(dix1.super_next))
    assert auto_dix.sf_of == ()            # no grouping levels at all
    assert auto_dix.hierarchy_levels == 1
    assert auto_dix.d2.shape == (1, 1)
    assert auto_dix.res_rows.shape == (1, 1, 1)


def test_hier_structure_invariants(built):
    """Every overlay node lands in exactly one super-fragment, the
    grouping is fragment-aligned (cliques never split), and the
    level-2 boundary is exactly the cross-super-fragment slot
    endpoints."""
    _g, _ix, (_d1, p1), (dix2, p2) = built
    h = p2.hier[0]
    assert dix2.hierarchy_levels == 2
    S = p2.S
    assert h.sf_of.shape == (S,) and (h.sf_of >= 0).all()
    assert h.sf_of.max() + 1 == h.nsf
    # members table round-trips sf_of/pos_in_sf
    for sid in range(S):
        assert h.sf_members[h.sf_of[sid], h.pos_in_sf[sid]] == sid
    # fragment-aligned: a fragment's boundary nodes share one sf
    fi_idx, b_idx = np.nonzero(p2.bvalid)
    sids = p2.bnd_super[fi_idx, b_idx]
    for fi in np.unique(fi_idx):
        assert np.unique(h.sf_of[sids[fi_idx == fi]]).size == 1
    # level-2 boundary = endpoints of sf-crossing slots
    crossing = h.slot_sf < 0
    want_b2 = np.unique(np.concatenate(
        [p2.sup_src[crossing], p2.sup_dst[crossing]]))
    np.testing.assert_array_equal(h.bnd2_ids, want_b2)
    # intra-sf slots carry valid local coords
    intra = ~crossing
    assert (h.slot_p2u[intra] >= 0).all()
    assert (h.sf_of[p2.sup_src[intra]]
            == h.sf_of[p2.sup_dst[intra]]).all()


def test_hier_distances_equal_dense(built):
    """Monolithic + planner-bucketed + one-to-all distances are
    array-equal between the dense and hierarchical closures, and exact
    vs Dijkstra on a sample."""
    g, _ix, (dix1, _p1), (dix2, _p2) = built
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.integers(0, g.n, 256), jnp.int32)
    t = jnp.asarray(rng.integers(0, g.n, 256), jnp.int32)
    o1 = np.asarray(serve_step(dix1, s, t))
    o2 = np.asarray(serve_step(dix2, s, t))
    np.testing.assert_array_equal(o1, o2)
    for i in range(32):
        want = dijkstra.pair(g, int(s[i]), int(t[i]))
        assert not dijkstra.mismatches_oracle(want, float(o2[i]))
    for src in (0, 123, g.n - 1):
        np.testing.assert_array_equal(
            np.asarray(serve_one_to_all(dix1, src)),
            np.asarray(serve_one_to_all(dix2, src)))


def test_hier_pallas_layout_parity(built):
    """The TPU layout (Pallas kernels in interpret mode) of the
    hierarchical combine matches the jnp reference layout exactly."""
    g, _ix, _dense, (dix2, _p2) = built
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.integers(0, g.n, 64), jnp.int32)
    t = jnp.asarray(rng.integers(0, g.n, 64), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(serve_step(dix2, s, t)),
        np.asarray(serve_step(dix2, s, t, force="pallas")))


def test_ov_slot_map_matches_dense_table(built):
    """The sparse OvSlotMap (hierarchical epochs' sub-quadratic slot
    provenance) agrees with the dense overlay_slot_table on every
    adjacency pair, including min-merged parallel slots."""
    _g, _ix, (_d1, p1), _h = built
    dense = overlay_slot_table(p1)
    m = hierarchy.ov_slot_map(p1)
    S = p1.S
    adj = np.nonzero(dense >= 0)
    assert adj[0].size > 0
    for a, b in zip(*adj):
        ds = int(dense[a, b])
        ms = m.lookup(int(a), int(b))
        # both must name a slot of the same weight between (a, b)
        assert p1.sup_w[ms] == p1.sup_w[ds]
    # non-adjacent pair
    empty = np.nonzero(dense < 0)
    if empty[0].size:
        assert m.lookup(int(empty[0][0]), int(empty[1][0])) == -1
    assert m.lookup(S, S) == -1


def _paths_exact(engine, g, rng, n=120):
    s = rng.integers(0, g.n, n).astype(np.int32)
    t = rng.integers(0, g.n, n).astype(np.int32)
    dist, paths = engine.query_path(s, t)
    for i in range(n):
        want = dijkstra.pair(g, int(s[i]), int(t[i]))
        if np.isinf(want):
            assert paths[i] is None
            continue
        w = path_weight(g, paths[i])       # raises on a broken hop
        assert w == float(dist[i]) == want, (int(s[i]), int(t[i]))


def test_hier_paths_exact_across_levels():
    """Witness serving + host unwinding on the hierarchical overlay:
    every sampled path is edge-valid and its weight equals both the
    served distance and Dijkstra — overlay legs resolved through
    sf_next / d2_next / slot provenance across levels."""
    g = road_like(650, seed=21)
    engine = EpochedEngine(g, hierarchy_levels=2, paths=True)
    assert engine.dix.hierarchy_levels == 2
    _paths_exact(engine, g, np.random.default_rng(1))


def test_hier_refresh_differential():
    """Refresh == rebuild array-for-array on the hierarchical index,
    across jam/clear rounds, with exact serving and paths per epoch;
    an update touching no overlay weight carries the per-level tables
    by reference (no spurious re-close)."""
    g = road_like(600, seed=33)
    engine = EpochedEngine(g, hierarchy_levels=2, paths=True)
    rng = np.random.default_rng(4)
    for r in range(3):
        u, v, w = traffic_updates(engine.g, frac=0.05, seed=60 + r,
                                  localized=bool(r % 2))
        engine.apply_updates(u, v, w)
        sdix = build_device_index(reweight_index(engine.ix, engine.g),
                                  hierarchy_levels=2)
        eq = index_fields_equal(engine.dix, sdix, REFRESHED_FIELDS)
        bad = [f for f, ok in eq.items() if not ok]
        assert not bad, f"epoch {engine.epoch}: {bad}"
        _paths_exact(engine, engine.g, rng, n=40)
    # piece-only (or overlay-untouched) update: hier tables must be
    # the SAME arrays (immutability-based double buffering, no FW)
    plan = engine.plan
    fa = plan.frag_of
    inner = np.nonzero((fa[engine.g.edge_u] >= 0)
                       & (fa[engine.g.edge_u] == fa[engine.g.edge_v])
                       & (plan.piece_gid[engine.g.edge_u] < 0)
                       & (plan.piece_gid[engine.g.edge_v] < 0))[0]
    # pick an intra-fragment edge whose fragment has NO overlay slot
    # dependence change: re-assign its CURRENT weight (no-op update)
    e = inner[0]
    before = engine.dix
    engine.apply_updates(engine.g.edge_u[[e]], engine.g.edge_v[[e]],
                         engine.g.edge_w[[e]])
    for f in HIER_FIELDS:
        assert getattr(engine.dix, f) is getattr(before, f), f


def test_hier_refresh_rollback():
    """A failure mid-refresh must restore the hierarchy weight caches
    (sf_adj, l2_w) along with the level-1 ones, so the next refresh
    still composes to the scratch answer."""
    g = road_like(500, seed=9)
    engine = EpochedEngine(g, hierarchy_levels=2)
    plan = engine.plan
    before = [(h.sf_adj.copy(), h.l2_w.copy()) for h in plan.hier]
    u, v, w = traffic_updates(g, frac=0.05, seed=2)
    has_piece = any(plan.piece_gid[a] >= 0 or plan.piece_gid[b] >= 0
                    for a, b in zip(u, v))
    if has_piece:
        with pytest.raises(AttributeError):
            refresh_index(engine.dix, plan, object(), u, v, w)
        for h, (sf_adj_b, l2_w_b) in zip(plan.hier, before):
            np.testing.assert_array_equal(h.sf_adj, sf_adj_b)
            np.testing.assert_array_equal(h.l2_w, l2_w_b)
    engine.apply_updates(u, v, w)
    sdix = build_device_index(reweight_index(engine.ix, engine.g),
                              hierarchy_levels=2)
    eq = index_fields_equal(engine.dix, sdix, REFRESHED_FIELDS)
    assert all(eq.values()), [f for f, ok in eq.items() if not ok]


def test_overlay_bytes_accounting():
    """hier_overlay_stats reports the resident table bytes the exp10
    sub-quadratic claim is judged on."""
    g = road_like(700, seed=7)
    _dix, plan = build_device_index_with_plan(build_index(g),
                                              hierarchy_levels=2)
    stats = hierarchy.hier_overlay_stats(plan.hier, plan.S)
    h = plan.hier[0]
    nsf1 = h.nsf + 1
    want = (2 * nsf1 * h.m2 * h.m2 * 4 + nsf1 * h.m2 * h.mb2 * 4
            + 2 * (h.S2 + 1) ** 2 * 4)
    assert stats["overlay_bytes"] == want
    assert stats["overlay_dense_bytes"] == 2 * (plan.S + 1) ** 2 * 4
    assert stats["hierarchy_levels"] == 2 and stats["S"] == plan.S


def test_refresh_replace_keeps_sidecars():
    """dataclasses.replace drops host sidecars; refresh_index must
    re-attach provenance consistent with the epoch it publishes."""
    g = road_like(550, seed=13)
    engine = EpochedEngine(g, hierarchy_levels=2)
    u, v, w = traffic_updates(g, frac=0.05, seed=8)
    engine.apply_updates(u, v, w)
    assert isinstance(getattr(engine.dix, "host_ov_slot", None),
                      hierarchy.OvSlotMap)
    assert getattr(engine.dix, "host_l2_slot", None) is not None
    # and the dense path still carries its dense table
    eng1 = EpochedEngine(road_like(400, seed=2), hierarchy_levels=1)
    assert isinstance(eng1.dix.host_ov_slot, np.ndarray)
