"""Multi-device semantics, run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process keeps the default single device, per the launch design)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> None:
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
    """ % os.path.join(ROOT, "src")) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", prog],
                         capture_output=True, text=True, timeout=420)
    assert res.returncode == 0, res.stdout + res.stderr


def test_sharded_serve_matches_host_engine():
    _run("""
        from repro.core.graph import road_like
        from repro.core.supergraph import build_index
        from repro.core.device_engine import build_device_index
        from repro.core.dist_engine import serve_sharded
        from repro.core.engine import DislandEngine
        mesh = make_mesh((4, 2), ("data", "model"))
        g = road_like(900, seed=31)
        ix = build_index(g)
        dix = build_device_index(ix)
        eng = DislandEngine(ix)
        rng = np.random.default_rng(0)
        s = jnp.asarray(rng.integers(0, g.n, 32), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, 32), jnp.int32)
        got = np.asarray(serve_sharded(mesh, dix, s, t))
        for i in range(32):
            want = eng.query(int(s[i]), int(t[i]))
            if np.isinf(want):
                assert np.isinf(got[i])
            else:
                assert abs(got[i] - want) < 1e-3
        print("ok")
    """)


def test_compressed_psum_approximates_mean():
    _run("""
        import functools
        from repro.optim import compressed_psum
        mesh = make_mesh((8,), ("d",))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 64)).astype(np.float32))
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=P("d"), out_specs=P("d"))
        def f(v):
            return compressed_psum(v[0], "d")[None]
        got = np.asarray(f(x))
        want = np.asarray(x).mean(0)
        scale = np.abs(x).max() / 127
        assert np.abs(got - want[None]).max() <= scale + 1e-5
        print("ok")
    """)


def test_gnn_sharded_matches_dense():
    """Owner-computes graphcast path == dense path on a localized batch."""
    _run("""
        import dataclasses
        from repro.models import gnn
        from repro.models.common import Shardings
        P_ = 8
        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(3)
        n, d = 64, 8          # 8 nodes per shard
        npp = n // P_
        # edges grouped by dst owner, dst LOCAL, src global
        src_g, dst_l, dst_g = [], [], []
        for shard in range(P_):
            for _ in range(12):
                dst = shard * npp + rng.integers(0, npp)
                src = rng.integers(0, n)
                src_g.append(src); dst_g.append(dst)
                dst_l.append(dst - shard * npp)
        cfg = dataclasses.replace(
            gnn.GNNConfig(name="gc", arch="graphcast", n_layers=2,
                          d_hidden=8, d_feat=d, n_out=2),
            sharded=True)
        params = gnn.init_params(cfg, jax.random.PRNGKey(0))
        base = dict(
            node_feat=jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)),
            edge_feat=jnp.asarray(rng.normal(size=(len(src_g), 4)).astype(np.float32)),
            target=jnp.asarray(rng.normal(size=(n, 2)).astype(np.float32)),
            loss_mask=jnp.ones(n, jnp.float32))
        b_shard = dict(base, edge_src=jnp.asarray(src_g, jnp.int32),
                       edge_dst=jnp.asarray(dst_l, jnp.int32))
        b_dense = dict(base, edge_src=jnp.asarray(src_g, jnp.int32),
                       edge_dst=jnp.asarray(dst_g, jnp.int32))
        sh = Shardings(mesh=mesh)
        got = float(gnn.forward_loss(cfg, sh, params, b_shard))
        cfg_d = dataclasses.replace(cfg, sharded=False)
        want = float(gnn.forward_loss(cfg_d, Shardings(None), params,
                                      b_dense))
        assert abs(got - want) < 1e-4 * max(abs(want), 1), (got, want)
        print("ok", got, want)
    """)


def test_dimenet_sharded_matches_dense_local_triplets():
    _run("""
        import dataclasses
        from repro.models import gnn
        from repro.models.common import Shardings
        P_ = 8
        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(5)
        n, d = 64, 6
        npp = n // P_
        e_per = 8
        src_g, dst_l, dst_g = [], [], []
        for shard in range(P_):
            for _ in range(e_per):
                dst = shard * npp + rng.integers(0, npp)
                src = rng.integers(0, n)
                src_g.append(src); dst_g.append(dst)
                dst_l.append(dst - shard * npp)
        E = len(src_g)
        # partition-local triplets: both edges within the same shard
        t_kj_l, t_ji_l, t_kj_g, t_ji_g, ang = [], [], [], [], []
        for shard in range(P_):
            for _ in range(2 * e_per):
                a_ = rng.integers(0, e_per)
                b_ = rng.integers(0, e_per)
                t_kj_l.append(a_); t_ji_l.append(b_)
                t_kj_g.append(shard * e_per + a_)
                t_ji_g.append(shard * e_per + b_)
                ang.append(rng.uniform(0, np.pi))
        cfg = dataclasses.replace(
            gnn.GNNConfig(name="dn", arch="dimenet", n_layers=2,
                          d_hidden=8, d_feat=d), sharded=True)
        params = gnn.init_params(cfg, jax.random.PRNGKey(1))
        base = dict(
            node_feat=jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)),
            edge_dist=jnp.asarray(rng.uniform(0.5, 3, E).astype(np.float32)),
            tri_angle=jnp.asarray(np.array(ang, np.float32)),
            graph_id=jnp.zeros(n, jnp.int32),
            target_g=jnp.asarray(rng.normal(size=(1,)).astype(np.float32)))
        b_shard = dict(base, edge_src=jnp.asarray(src_g, jnp.int32),
                       edge_dst=jnp.asarray(dst_l, jnp.int32),
                       tri_edge_kj=jnp.asarray(t_kj_l, jnp.int32),
                       tri_edge_ji=jnp.asarray(t_ji_l, jnp.int32))
        b_dense = dict(base, edge_src=jnp.asarray(src_g, jnp.int32),
                       edge_dst=jnp.asarray(dst_g, jnp.int32),
                       tri_edge_kj=jnp.asarray(t_kj_g, jnp.int32),
                       tri_edge_ji=jnp.asarray(t_ji_g, jnp.int32))
        sh = Shardings(mesh=mesh)
        got = float(gnn.forward_loss(cfg, sh, params, b_shard))
        cfg_d = dataclasses.replace(cfg, sharded=False)
        want = float(gnn.forward_loss(cfg_d, Shardings(None), params,
                                      b_dense))
        assert abs(got - want) < 1e-4 * max(abs(want), 1), (got, want)
        print("ok", got, want)
    """)


def test_lm_sharded_loss_matches_single_device():
    """Full train-cell sharding (FSDP+TP+SP) must not change the loss."""
    _run("""
        import dataclasses
        from repro.models import transformer
        from repro.models.common import Shardings
        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = transformer.LMConfig(
            name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
            d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=8,
            gather_fsdp_in_body=True, seq_shard_activations=True)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
        base = float(transformer.forward_loss(cfg, Shardings(None),
                                              params, toks))
        sh = Shardings(mesh=mesh)
        with mesh:
            sharded = float(jax.jit(
                lambda p, t: transformer.forward_loss(cfg, sh, p, t)
            )(params, toks))
        assert abs(base - sharded) < 1e-4 * max(abs(base), 1)
        print("ok", base, sharded)
    """)
