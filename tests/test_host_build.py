"""Staged host build pipeline tests (DESIGN.md §17).

Four contracts pinned here:

* the fragment substrate — ``Graph.subgraph`` / ``extract_fragments`` /
  the shared-CSR views — round-trips ids and weights and is
  deterministic under input permutation (property tests, padding-suite
  style);
* serial parity: ``build_index(build_workers=N)`` is array-equal to the
  serial build on every ``DislandIndex`` table, and the ``DeviceIndex``
  built from each agrees field for field (the differential behind the
  "workers only relocate work" claim);
* the streaming handoff: ``start_build`` exposes a structurally
  complete index before the covers land, and ``finish`` fills the same
  object in place, idempotently;
* the failure contract: a fragment cover that raises surfaces the
  original exception from ``finish`` with the pool reaped and the
  shared block released — no hang, no orphaned shared memory.
"""
import dataclasses
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.device_engine import build_device_index, index_fields_equal
from repro.core.graph import Graph, random_graph, road_like
from repro.core.landmarks import hybrid_cover
from repro.core.supergraph import (_graph_equal, build_index,
                                   index_arrays_equal, start_build)


def _edge_dict(eu, ev, ew):
    return {(int(a), int(b)): float(w) for a, b, w in zip(eu, ev, ew)}


# ---------------------------------------------------------------------------
# fragment substrate properties
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000))
@settings(max_examples=25)
def test_subgraph_round_trip(seed):
    """G[nodes] contains exactly the induced edges, weights intact,
    with old_ids mapping local ids back to the originals."""
    g = random_graph(60, 90, seed=seed)
    rng = np.random.default_rng(seed + 1)
    nodes = np.flatnonzero(rng.random(g.n) < 0.6).astype(np.int32)
    sg, ids = g.subgraph(nodes)
    assert np.array_equal(ids, np.unique(nodes))
    sel = np.zeros(g.n, dtype=bool)
    sel[nodes] = True
    both = sel[g.edge_u] & sel[g.edge_v]
    want = _edge_dict(g.edge_u[both], g.edge_v[both], g.edge_w[both])
    got = _edge_dict(ids[sg.edge_u], ids[sg.edge_v], sg.edge_w)
    assert got == want


@given(st.integers(0, 10_000))
@settings(max_examples=25)
def test_subgraph_deterministic_under_permutation(seed):
    """Shuffling (or duplicating) the node list changes nothing: the
    worker-side re-extraction leans on this canonicalization."""
    g = random_graph(50, 80, seed=seed)
    rng = np.random.default_rng(seed + 2)
    nodes = np.flatnonzero(rng.random(g.n) < 0.5)
    scrambled = rng.permutation(np.concatenate([nodes, nodes]))
    a, ida = g.subgraph(nodes)
    b, idb = g.subgraph(scrambled)
    assert np.array_equal(ida, idb)
    assert _graph_equal(a, b)


@given(st.integers(0, 10_000))
@settings(max_examples=25)
def test_extract_fragments_matches_per_label_subgraph(seed):
    """The batched extraction equals k independent ``subgraph`` calls —
    the equivalence fragment_stage and the cover workers both rest on."""
    g = random_graph(70, 110, seed=seed)
    rng = np.random.default_rng(seed + 3)
    k = int(rng.integers(1, 7))
    labels = rng.integers(0, k, g.n)
    labels[:k] = np.arange(k)        # every fragment non-empty label id
    frags = g.extract_fragments(labels)
    assert len(frags) == k
    for i, (fg, fids) in enumerate(frags):
        want_g, want_ids = g.subgraph(np.flatnonzero(labels == i))
        assert np.array_equal(fids, want_ids)
        assert _graph_equal(fg, want_g)


def test_extract_fragments_rejects_bad_labels():
    g = random_graph(10, 15, seed=0)
    with pytest.raises(ValueError, match="every node"):
        g.extract_fragments(np.zeros(g.n - 1, dtype=np.int64))
    bad = np.zeros(g.n, dtype=np.int64)
    bad[3] = -1
    with pytest.raises(ValueError, match="complete partition"):
        g.extract_fragments(bad)


@given(st.integers(0, 10_000))
@settings(max_examples=10)
def test_shared_csr_round_trip(seed):
    """to_shared/from_shared: zero-copy views equal the source arrays,
    are read-only, and support the worker-side subgraph re-extraction."""
    g = random_graph(40, 60, seed=seed)
    handle = g.to_shared()
    try:
        attached = Graph.from_shared(handle.meta)
        try:
            sg = attached.graph
            assert _graph_equal(g, sg)
            assert not sg.indices.flags.writeable
            with pytest.raises(ValueError):
                sg.edge_w[0] = 99.0
            # a worker's view supports fragment extraction unchanged
            nodes = np.arange(0, g.n, 2, dtype=np.int32)
            a, _ = g.subgraph(nodes)
            b, _ = sg.subgraph(nodes)
            assert _graph_equal(a, b)
        finally:
            attached.close()
    finally:
        handle.close()
        handle.unlink()


# ---------------------------------------------------------------------------
# serial parity differential (the tentpole's acceptance contract)
# ---------------------------------------------------------------------------
def _assert_parity(g, workers):
    serial = build_index(g)
    parallel = build_index(g, build_workers=workers)
    eq = index_arrays_equal(serial, parallel)
    assert all(eq.values()), \
        f"workers={workers} diverges from serial on " \
        f"{[k for k, v in eq.items() if not v]}"
    return serial, parallel


def test_parallel_build_matches_serial_road4000():
    g = road_like(4000, seed=0)
    serial, parallel = _assert_parity(g, workers=2)
    _assert_parity(g, workers=4)
    # the DeviceIndex is a pure function of the host index, but pin the
    # end product too: every device table field-equal between the two
    dser = build_device_index(serial)
    dpar = build_device_index(parallel)
    names = [f.name for f in dataclasses.fields(dser)]
    deq = index_fields_equal(dser, dpar, names)
    assert all(deq.values()), \
        f"device tables diverge on {[k for k, v in deq.items() if not v]}"


@pytest.mark.skipif(os.environ.get("CHECK_SKIP_SCALE") == "1",
                    reason="road64k differential skipped "
                           "(CHECK_SKIP_SCALE=1)")
def test_parallel_build_matches_serial_road64k():
    """Scale leg of the parity differential (full check runs only).
    Host tables only: the device build is a pure function of the host
    index (pinned at road4000 above), and a road64k device FW closure
    is minutes of CPU — the host differential is what workers touch."""
    g = road_like(64_000, seed=0)
    _assert_parity(g, workers=8)


# ---------------------------------------------------------------------------
# streaming handoff
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 2])
def test_streaming_handoff_fills_index_in_place(workers):
    g = road_like(1000, seed=1)
    hb = start_build(g, build_workers=workers)
    six = hb.structural_index()
    # structurally complete: everything the device build reads exists
    assert six.super_graph is None
    assert six.fragments and all(f.cover is None for f in six.fragments)
    assert six.shrink is not None and six.partition is not None
    ix = hb.finish()
    assert ix is six
    assert ix.super_graph is not None
    assert all(f.cover is not None for f in ix.fragments)
    assert "hybrid_covers" in ix.timings
    assert hb.finish() is ix                      # idempotent
    # and the streamed product equals the one-shot build
    eq = index_arrays_equal(ix, build_index(g))
    assert all(eq.values())


# ---------------------------------------------------------------------------
# worker failure contract
# ---------------------------------------------------------------------------
class _InjectedCoverFailure(RuntimeError):
    pass


def _boom_cover(fg, boundary_local, use_cost_model):
    # deterministic: every fragment with a real boundary fails, so the
    # first completed future raises regardless of scheduling order
    if boundary_local.size >= 2:
        raise _InjectedCoverFailure(
            f"injected cover failure ({boundary_local.size} boundary)")
    return hybrid_cover(fg, boundary_local, use_cost_model)


def _shm_names():
    try:
        return {f for f in os.listdir("/dev/shm") if f.startswith("psm_")}
    except FileNotFoundError:            # non-Linux: skip the leak check
        return set()


@pytest.mark.parametrize("workers", [1, 4])
def test_failed_cover_surfaces_original_exception(workers):
    """A raising fragment cover must fail the build promptly with the
    original exception — futures cancelled, pool reaped, shared block
    released — for both the serial and the worker-pool paths."""
    g = road_like(1000, seed=2)
    before = _shm_names()
    with pytest.raises(_InjectedCoverFailure, match="injected"):
        build_index(g, build_workers=workers, cover_fn=_boom_cover)
    assert _shm_names() <= before        # no leaked shared-memory block
