"""DISLAND end-to-end: host engine, device engine, baselines — all
validated against Dijkstra ground truth."""
import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dijkstra
from repro.core.agent_wrap import AgentAccelerated, PlainDijkstra
from repro.core.arcflags import ArcFlags
from repro.core.ch import CH
from repro.core.device_engine import (build_device_index, serve_one_to_all,
                                      serve_step)
from repro.core.dist_engine import QueryPlanner
from repro.core.engine import DislandEngine
from repro.core.graph import road_like, tree_with_blobs
from repro.core.supergraph import build_index


@pytest.fixture(scope="module")
def small_world():
    g = road_like(1600, seed=21)
    ix = build_index(g)
    return g, ix


def _random_pairs(g, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, g.n, size=(n, 2))


def test_disland_engine_exact(small_world):
    g, ix = small_world
    eng = DislandEngine(ix)
    for s, t in _random_pairs(g, 60, seed=1):
        want = dijkstra.pair(g, int(s), int(t))
        got = eng.query(int(s), int(t))
        if np.isinf(want):
            assert np.isinf(got)
        else:
            assert abs(got - want) < 1e-6, (s, t, got, want)


def test_device_engine_matches_host(small_world):
    g, ix = small_world
    dix = build_device_index(ix)
    pairs = _random_pairs(g, 120, seed=2)
    s = jnp.asarray(pairs[:, 0], jnp.int32)
    t = jnp.asarray(pairs[:, 1], jnp.int32)
    got = np.asarray(serve_step(dix, s, t))
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(g, int(a), int(b))
        if np.isinf(want):
            assert np.isinf(got[i])
        else:
            assert abs(got[i] - want) < 1e-3, (a, b, got[i], want)


def test_device_one_to_all(small_world):
    g, ix = small_world
    dix = build_device_index(ix)
    src = 17
    got = np.asarray(serve_one_to_all(dix, src))
    want = dijkstra.sssp(g, src)
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
    assert np.isinf(got[~fin]).all()


def _pairs_covering_all_buckets(g, dix, n_random=60, seed=11):
    """Random pairs plus hand-picked ones so every planner bucket
    (same-DRA / same-fragment / cross-fragment, plus cross_res when
    the epoch carries pre-lifted resident rows) is non-empty."""
    rng = np.random.default_rng(seed)
    pairs = list(map(tuple, rng.integers(0, g.n, size=(n_random, 2))))
    agent_of = np.asarray(dix.agent_of)
    frag_of = np.asarray(dix.frag_of)
    # same-DRA: two distinct nodes sharing an agent
    agents, counts = np.unique(agent_of, return_counts=True)
    a = agents[np.argmax(counts)]
    members = np.nonzero(agent_of == a)[0]
    assert members.size >= 2, "graph has no non-trivial DRA"
    pairs.append((int(members[0]), int(members[-1])))
    # same-fragment, different DRA
    fa = frag_of[agent_of]
    for f in np.unique(fa[fa >= 0]):
        nodes = np.nonzero(fa == f)[0]
        us = agent_of[nodes]
        if np.unique(us).size >= 2:
            i = int(nodes[0])
            j = int(nodes[np.argmax(us != us[0])])
            pairs.append((i, j))
            break
    # cross-fragment
    valid = np.nonzero(fa >= 0)[0]
    f0 = fa[valid[0]]
    other = valid[np.argmax(fa[valid] != f0)]
    pairs.append((int(valid[0]), int(other)))
    # cross_res: both endpoints in resident fragments of different
    # top-level groups (only exists on hierarchical epochs)
    rf = getattr(dix, "host_res_frag", None)
    tg = getattr(dix, "host_topgrp_frag", None)
    if rf is not None and tg is not None:
        hot = (rf[fa[valid]] >= 0)
        hv = valid[hot]
        if hv.size:
            t0 = tg[fa[hv[0]]]
            j = np.argmax(tg[fa[hv]] != t0)
            if tg[fa[hv[j]]] != t0:
                pairs.append((int(hv[0]), int(hv[j])))
    return np.asarray(pairs)


@pytest.mark.parametrize("graph_factory,seed", [
    (lambda: road_like(1400, seed=23), 23),
    (lambda: tree_with_blobs(60, 7, seed=5), 5),
])
def test_planner_matches_host_engine(graph_factory, seed):
    """QueryPlanner (bucketed jitted sub-programs) == DislandEngine,
    with every bucket exercised."""
    g = graph_factory()
    ix = build_index(g)
    dix = build_device_index(ix)
    eng = DislandEngine(ix)
    pairs = _pairs_covering_all_buckets(g, dix, seed=seed)
    planner = QueryPlanner(dix)
    got = planner(pairs[:, 0], pairs[:, 1])
    # cross_res only fills on hierarchical epochs with resident rows;
    # the other buckets must always be exercised
    assert all(n >= 1 for c, n in planner.last_counts.items()
               if c != "cross_res"), planner.last_counts
    if np.asarray(dix.res_rows).shape[0] > 1:
        assert planner.last_counts["cross_res"] >= 1, planner.last_counts
    got_mono = np.asarray(serve_step(dix, jnp.asarray(pairs[:, 0]),
                                     jnp.asarray(pairs[:, 1])))
    for i, (a, b) in enumerate(pairs):
        want = eng.query(int(a), int(b))
        for val in (got[i], got_mono[i]):
            if np.isinf(want):
                assert np.isinf(val)
            else:
                assert abs(val - want) < 1e-3, (a, b, val, want)


def test_serve_step_never_materializes_qxmbxmb(small_world):
    """The combine must stay [q, mb, mb]-free (the whole point of the
    fused path): inspect the jaxpr of both dispatch modes."""
    g, ix = small_world
    dix = build_device_index(ix)
    mb = dix.bpos.shape[1]
    q = 64
    s = jnp.zeros(q, jnp.int32)
    t = jnp.ones(q, jnp.int32)
    for force in (None, "pallas"):
        closed = jax.make_jaxpr(
            lambda a, b: serve_step(dix, a, b, force=force))(s, t)
        text = str(closed)   # nested jaxprs (loop bodies) print inline
        forbidden = f"f32[{q},{mb},{mb}]"
        assert forbidden not in text, \
            f"{forbidden} intermediate found (force={force})"


def test_refresh_programs_never_materialize_qxmbxmb(small_world):
    """The epoch-swappable per-case programs (index passed as an
    argument — what the planner runs across refreshes, DESIGN.md §9)
    must stay [q, mb, mb]-free too: the refactor to dix-as-argument
    must not have reintroduced the gather blowup in either dispatch
    mode, for any planner bucket."""
    import functools

    from repro.core.device_engine import serve_cross, serve_same_dra

    g, ix = small_world
    dix = build_device_index(ix)
    mb = dix.bpos.shape[1]
    q = 64
    s = jnp.zeros(q, jnp.int32)
    t = jnp.ones(q, jnp.int32)
    forbidden = f"f32[{q},{mb},{mb}]"
    for force in (None, "pallas"):
        programs = {
            "same_dra": serve_same_dra,
            "same_frag": functools.partial(serve_cross, with_local=True,
                                           force=force),
            "cross_frag": functools.partial(serve_cross,
                                            with_local=False,
                                            force=force),
        }
        for name, fn in programs.items():
            text = str(jax.make_jaxpr(fn)(dix, s, t))
            assert forbidden not in text, \
                f"{forbidden} found in {name} (force={force})"


def test_super_graph_is_small(small_world):
    g, ix = small_world
    sup = ix.super_graph.graph
    assert sup.n < 0.5 * g.n
    assert sup.m < g.m


def test_extra_space_is_moderate(small_world):
    """Paper: auxiliary structures ~ 1/2 of the input graph edges."""
    g, ix = small_world
    extra = ix.extra_space_edges()
    assert extra["total"] < 2 * g.m


@pytest.mark.parametrize("name,factory", [
    ("ch", lambda g: CH(g)),
    ("arcflags", lambda g: ArcFlags(g, n_regions=8)),
    ("agent_ch", lambda g: AgentAccelerated(g, lambda s: CH(s))),
    ("agent_bidij", lambda g: AgentAccelerated(
        g, lambda s: PlainDijkstra(s, bidirectional=True))),
])
def test_baselines_exact(name, factory):
    g = road_like(900, seed=4)
    algo = factory(g)
    for s, t in _random_pairs(g, 25, seed=3):
        want = dijkstra.pair(g, int(s), int(t))
        got = algo.query(int(s), int(t))
        if np.isinf(want):
            assert np.isinf(got)
        else:
            assert abs(got - want) < 1e-6, (name, s, t, got, want)


def test_blob_graph_same_dra_cases():
    g = tree_with_blobs(10, 5, seed=6)
    ix = build_index(g)
    eng = DislandEngine(ix)
    dix = build_device_index(ix)
    pairs = _random_pairs(g, 80, seed=7)
    s = jnp.asarray(pairs[:, 0], jnp.int32)
    t = jnp.asarray(pairs[:, 1], jnp.int32)
    got = np.asarray(serve_step(dix, s, t))
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(g, int(a), int(b))
        assert abs(eng.query(int(a), int(b)) - want) < 1e-6
        assert abs(got[i] - want) < 1e-3
