"""DISLAND end-to-end: host engine, device engine, baselines — all
validated against Dijkstra ground truth."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import dijkstra
from repro.core.agent_wrap import AgentAccelerated, PlainDijkstra
from repro.core.arcflags import ArcFlags
from repro.core.ch import CH
from repro.core.device_engine import (build_device_index, serve_one_to_all,
                                      serve_step)
from repro.core.engine import DislandEngine
from repro.core.graph import road_like, tree_with_blobs
from repro.core.supergraph import build_index


@pytest.fixture(scope="module")
def small_world():
    g = road_like(1600, seed=21)
    ix = build_index(g)
    return g, ix


def _random_pairs(g, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, g.n, size=(n, 2))


def test_disland_engine_exact(small_world):
    g, ix = small_world
    eng = DislandEngine(ix)
    for s, t in _random_pairs(g, 60, seed=1):
        want = dijkstra.pair(g, int(s), int(t))
        got = eng.query(int(s), int(t))
        if np.isinf(want):
            assert np.isinf(got)
        else:
            assert abs(got - want) < 1e-6, (s, t, got, want)


def test_device_engine_matches_host(small_world):
    g, ix = small_world
    dix = build_device_index(ix)
    pairs = _random_pairs(g, 120, seed=2)
    s = jnp.asarray(pairs[:, 0], jnp.int32)
    t = jnp.asarray(pairs[:, 1], jnp.int32)
    got = np.asarray(serve_step(dix, s, t))
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(g, int(a), int(b))
        if np.isinf(want):
            assert np.isinf(got[i])
        else:
            assert abs(got[i] - want) < 1e-3, (a, b, got[i], want)


def test_device_one_to_all(small_world):
    g, ix = small_world
    dix = build_device_index(ix)
    src = 17
    got = np.asarray(serve_one_to_all(dix, src))
    want = dijkstra.sssp(g, src)
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
    assert np.isinf(got[~fin]).all()


def test_super_graph_is_small(small_world):
    g, ix = small_world
    sup = ix.super_graph.graph
    assert sup.n < 0.5 * g.n
    assert sup.m < g.m


def test_extra_space_is_moderate(small_world):
    """Paper: auxiliary structures ~ 1/2 of the input graph edges."""
    g, ix = small_world
    extra = ix.extra_space_edges()
    assert extra["total"] < 2 * g.m


@pytest.mark.parametrize("name,factory", [
    ("ch", lambda g: CH(g)),
    ("arcflags", lambda g: ArcFlags(g, n_regions=8)),
    ("agent_ch", lambda g: AgentAccelerated(g, lambda s: CH(s))),
    ("agent_bidij", lambda g: AgentAccelerated(
        g, lambda s: PlainDijkstra(s, bidirectional=True))),
])
def test_baselines_exact(name, factory):
    g = road_like(900, seed=4)
    algo = factory(g)
    for s, t in _random_pairs(g, 25, seed=3):
        want = dijkstra.pair(g, int(s), int(t))
        got = algo.query(int(s), int(t))
        if np.isinf(want):
            assert np.isinf(got)
        else:
            assert abs(got - want) < 1e-6, (name, s, t, got, want)


def test_blob_graph_same_dra_cases():
    g = tree_with_blobs(10, 5, seed=6)
    ix = build_index(g)
    eng = DislandEngine(ix)
    dix = build_device_index(ix)
    pairs = _random_pairs(g, 80, seed=7)
    s = jnp.asarray(pairs[:, 0], jnp.int32)
    t = jnp.asarray(pairs[:, 1], jnp.int32)
    got = np.asarray(serve_step(dix, s, t))
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(g, int(a), int(b))
        assert abs(eng.query(int(a), int(b)) - want) < 1e-6
        assert abs(got[i] - want) < 1e-3
