"""Property tests for the consolidated padding rules (core/padding.py).

Three modules used to carry their own spelling of these (device_engine,
dist_engine, the serving scheduler via dist_engine); the properties
below are what build/refresh shape stability, planner warmup coverage,
and the batcher's occupancy bucketing all silently lean on — so they
are pinned once, against the one shared implementation, and the old
import sites are asserted to be aliases of it.
"""
from hypothesis import given, settings, strategies as st

from repro.core import device_engine, dist_engine, padding
from repro.serving import scheduler


def test_import_sites_are_aliases():
    """Every historical spelling resolves to the shared functions."""
    assert device_engine._pad_to is padding.pad_to
    assert device_engine._pow2 is padding.pow2
    assert dist_engine.pad_pow2 is padding.pad_pow2
    assert dist_engine._pad_pow2 is padding.pad_pow2
    # the scheduler buckets occupancy with the planner's exact rule
    assert scheduler.pad_pow2 is padding.pad_pow2


@given(st.integers(0, 100_000))
@settings(max_examples=60)
def test_pad_to_properties(x):
    for mult in (1, 8, 16, 104):
        p = padding.pad_to(x, mult)
        assert p >= x and p >= mult            # floor behavior
        assert p % mult == 0                   # multiple
        assert p - x < mult or x < mult        # tightness
        assert padding.pad_to(p, mult) == p    # idempotent (round-trip)


@given(st.integers(0, 100_000))
@settings(max_examples=60)
def test_pow2_properties(x):
    for floor in (1, 4, 8, 16, 24):
        p = padding.pow2(x, floor)
        assert p >= x and p >= floor           # floor behavior
        # p is floor * 2**k for some k >= 0
        q = p
        while q > floor:
            assert q % 2 == 0
            q //= 2
        assert q == floor
        assert p < 2 * max(x, floor)           # tightness: < 2x input
        assert padding.pow2(p, floor) == p     # idempotent


@given(st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=60)
def test_monotone(a, b):
    """x <= y implies f(x) <= f(y) for every rule (warmup coverage:
    padding a smaller batch can never need a larger compiled shape)."""
    lo, hi = min(a, b), max(a, b)
    assert padding.pad_to(lo) <= padding.pad_to(hi)
    assert padding.pow2(lo, 4) <= padding.pow2(hi, 4)
    assert padding.pad_pow2(lo) <= padding.pad_pow2(hi)


def test_planner_bucket_rule_pinned():
    """The serving stack's floor-16 pow2 rule, by example."""
    assert [padding.pad_pow2(n) for n in (0, 1, 16, 17, 100, 1024)] == \
        [16, 16, 16, 32, 128, 1024]
