"""Unit tests for the perf-gate's history handling (scripts/bench_gate).

The gate's statistical contract: the fresh measurement is compared to
the median of the last N *committed* records of the same config — so
the fresh record must never be able to join its own baseline, and a
malformed committed record must fail loudly instead of silently
shrinking (or unit-mixing) the window.
"""
import importlib.util
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_gate",
    os.path.join(os.path.dirname(__file__), "..", "scripts",
                 "bench_gate.py"))
bench_gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_gate)


MATCH = {"section": "serve", "graph": "road4000", "mode": "planner"}


def _rec(us, **over):
    rec = {"section": "serve", "graph": "road4000", "mode": "planner",
           "us_per_query": us}
    rec.update(over)
    return rec


def test_window_selects_matching_tail():
    recs = ([_rec(9.0 + i) for i in range(8)]
            + [_rec(99.0, mode="fused"),          # different config
               _rec(50.0, section="serve_live",   # different section
                    mode="planner")])
    win = bench_gate.history_window(recs, MATCH, "us_per_query", 5)
    assert win == [12.0, 13.0, 14.0, 15.0, 16.0]


def test_missing_section_fails_loudly():
    recs = [_rec(9.0), {"graph": "road4000", "us_per_query": 9.0}]
    with pytest.raises(SystemExit, match="section"):
        bench_gate.history_window(recs, MATCH, "us_per_query", 5)


def test_matching_record_without_metric_fails_loudly():
    """A record matching every identity key but carrying no numeric
    metric is a half-written entry, not a smaller window."""
    broken = _rec(9.0)
    del broken["us_per_query"]
    with pytest.raises(SystemExit, match="numeric"):
        bench_gate.history_window([_rec(9.0), broken], MATCH,
                                  "us_per_query", 5)
    # bool is not a measurement either (isinstance(True, int) holds)
    with pytest.raises(SystemExit, match="numeric"):
        bench_gate.history_window([_rec(True)], MATCH,
                                  "us_per_query", 5)


def test_missing_graph_fails_loudly():
    """A committed record with a section but no graph key cannot be
    attributed to a scale; it must not silently drop out of (or worse,
    be writable into) any graph's window."""
    broken = _rec(9.0)
    del broken["graph"]
    with pytest.raises(SystemExit, match="graph"):
        bench_gate.history_window([_rec(9.0), broken], MATCH,
                                  "us_per_query", 5)


def test_graph_scales_never_mix():
    """road64k records must be invisible to the road4000 window (and
    vice versa): one 81,000 µs/query record in a 9 µs/query history
    would inflate the median and mask a road4000 regression."""
    recs = ([_rec(9.0 + i) for i in range(4)]
            + [_rec(81021.7, graph="road64k"),
               _rec(81550.0, graph="road64k")])
    win = bench_gate.history_window(recs, MATCH, "us_per_query", 5)
    assert win == [9.0, 10.0, 11.0, 12.0]
    win64 = bench_gate.history_window(
        recs, {**MATCH, "graph": "road64k"}, "us_per_query", 5)
    assert win64 == [81021.7, 81550.0]


def test_live_and_offline_sections_never_mix():
    """serve_live p99 records (ms) must be invisible to the offline
    µs/query window and vice versa — the 'units can't mix' guarantee."""
    recs = [_rec(9.0),
            {"section": "serve_live", "graph": "road4000",
             "mode": "planner", "us_per_query": 9.0, "p99_ms": 30.0}]
    off = bench_gate.history_window(recs, MATCH, "us_per_query", 5)
    assert off == [9.0]
    live = bench_gate.history_window(
        recs, {"section": "serve_live", "graph": "road4000"},
        "p99_ms", 5)
    assert live == [30.0]


def test_fresh_equals_history_rejected(tmp_path):
    """The fresh records file must not alias the committed history —
    else the fresh record joins its own median baseline and the gate
    can never fail."""
    p = tmp_path / "BENCH.json"
    p.write_text("[]")
    with pytest.raises(SystemExit, match="median baseline"):
        bench_gate.ensure_distinct_files(str(p), str(p))
    # a relative-path alias is still the same file
    rel = os.path.relpath(str(p))
    with pytest.raises(SystemExit, match="median baseline"):
        bench_gate.ensure_distinct_files(rel, str(p))
    bench_gate.ensure_distinct_files(str(tmp_path / "fresh.json"),
                                     str(p))    # distinct: fine


def test_fresh_serve_live_requires_tier_fields():
    """A fresh serve_live record missing the per-tier counters
    (DESIGN.md §15) fails loudly; a complete record passes.  Committed
    history is grandfathered — require_tier_fields runs on fresh
    records only, which test_committed_history_is_gate_clean relies
    on."""
    full = {f: 0 for f in bench_gate.TIER_FIELDS}
    bench_gate.require_tier_fields(full)            # no raise
    for f in bench_gate.TIER_FIELDS:
        broken = dict(full)
        del broken[f]
        with pytest.raises(SystemExit, match=f):
            bench_gate.require_tier_fields(broken)


def test_fresh_serve_live_requires_hist_fields():
    """A fresh serve_live record must carry histogram-derived latency
    percentiles (DESIGN.md §16): all of HIST_FIELDS present AND
    latency_source == 'histogram'.  Missing fields or a sampled-path
    fallback fail loudly; committed pre-§16 history is grandfathered
    (require_hist_fields runs on fresh records only)."""
    full = {"p50_ms": 1.0, "p95_ms": 2.0, "p99_ms": 3.0,
            "latency_source": "histogram", "latency_n": 100}
    bench_gate.require_hist_fields(full)            # no raise
    for f in bench_gate.HIST_FIELDS:
        broken = dict(full)
        del broken[f]
        with pytest.raises(SystemExit, match="histogram"):
            bench_gate.require_hist_fields(broken)
    # present-but-degraded: the report fell back to the sampled path
    with pytest.raises(SystemExit, match="sampled"):
        bench_gate.require_hist_fields(
            {**full, "latency_source": "sampled"})


def test_host_build_window_keyed_section_graph():
    """host_build records gate on wall seconds keyed (section, graph):
    serve records (µs/query units) and other graphs' host builds must
    both be invisible to the window."""
    hb = {"section": "host_build", "graph": "road4000", "wall_s": 0.1}
    recs = [_rec(9.0), hb,
            {**hb, "graph": "road64k", "wall_s": 4.3},
            {**hb, "wall_s": 0.12}]
    win = bench_gate.history_window(
        recs, {"section": "host_build", "graph": "road4000"},
        "wall_s", 5)
    assert win == [0.1, 0.12]


def test_host_build_record_without_wall_s_fails_loudly():
    """A matching host_build record with no numeric wall_s is a
    half-written entry — loud failure, not a smaller window."""
    broken = {"section": "host_build", "graph": "road4000",
              "build_workers": 2}
    with pytest.raises(SystemExit, match="numeric"):
        bench_gate.history_window(
            [broken], {"section": "host_build", "graph": "road4000"},
            "wall_s", 5)


def test_committed_history_is_gate_clean():
    """The repo's own BENCH_serve.json must stay loud-failure-free for
    every config the CI gates query."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from repro.perflog import read_records

    recs = read_records(os.path.join(os.path.dirname(__file__), "..",
                                     "BENCH_serve.json"))
    assert recs, "committed history unreadable"
    bench_gate.history_window(
        recs, {"section": "serve", "graph": "road4000",
               "mode": "planner", "backend": "cpu",
               "batch_size": 1024}, "us_per_query", 5)
    bench_gate.history_window(
        recs, {"section": "serve_live", "graph": "road4000"},
        "p99_ms", 5)
    bench_gate.history_window(
        recs, {"section": "host_build", "graph": "road4000"},
        "wall_s", 5)
