"""Online serving runtime tests (DESIGN.md §11).

The contract: every response carries the epoch it was served on, its
distance equals the host Dijkstra oracle **for that epoch's graph**,
and a cache entry written under one epoch is never served under
another (stale entries are detected and dropped, not returned) — no
matter how queries, flushes, and index refreshes interleave.

Interleavings are exercised twice: deterministically on one thread
(scripted submit/update/flush orders, so a CI failure replays
exactly), and as a threaded soak with a background RefreshDriver
racing an open-loop submission stream across >= 3 published epochs.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import dijkstra
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like, traffic_updates
from repro.data.queries import zipf_pairs
from repro.serving import (EpochCache, MicroBatcher, RefreshDriver,
                           ServingRuntime, validate_against_epochs)


# ---------------------------------------------------------------------------
# cache unit tests (no engine)
# ---------------------------------------------------------------------------
def test_mismatches_oracle_contract():
    """The shared oracle comparator: infs agree only with infs, NaN is
    always a mismatch, finites compare with relative tolerance."""
    m = dijkstra.mismatches_oracle
    inf, nan = np.inf, np.nan
    assert not m(inf, inf)
    assert m(inf, 5.0) and m(5.0, inf)           # inf vs finite: wrong
    assert m(5.0, nan) and m(inf, nan) and m(nan, nan)
    assert not m(100.0, 100.0 + 1e-4)
    assert m(100.0, 101.0)
    assert not m(0.0, 0.0)


def test_cache_epoch_tagging():
    c = EpochCache(capacity=8)
    assert c.get(1, 2, epoch=0) is None           # cold miss
    c.put(1, 2, epoch=0, dist=5.0)
    assert c.get(1, 2, epoch=0) == 5.0            # hit
    assert c.get(1, 2, epoch=1) is None           # stale: epoch moved
    st = c.stats()
    assert (st.hits, st.misses, st.stale) == (1, 2, 1)
    assert c.get(1, 2, epoch=1) is None           # evicted, plain miss
    assert c.stats().stale == 1
    c.put(1, 2, epoch=1, dist=7.0)
    assert c.get(1, 2, epoch=1) == 7.0
    assert 0.0 < c.stats().hit_rate < 1.0
    rec = c.stats().as_record()
    assert rec["cache_stale"] == 1 and rec["cache_hits"] == 2


def test_cache_lru_eviction():
    c = EpochCache(capacity=2)
    c.put(0, 1, 0, 1.0)
    c.put(0, 2, 0, 2.0)
    assert c.get(0, 1, 0) == 1.0                  # refresh (0,1)
    c.put(0, 3, 0, 3.0)                           # evicts LRU (0,2)
    assert c.get(0, 2, 0) is None
    assert c.get(0, 1, 0) == 1.0 and c.get(0, 3, 0) == 3.0
    assert c.stats().evictions == 1 and len(c) == 2
    with pytest.raises(ValueError):
        EpochCache(capacity=0)


def test_cache_put_never_clobbers_fresher_entry():
    """Regression (the deterministic two-flush interleaving): flush A
    pins epoch 0, flush B pins epoch 1; B's device serve finishes and
    fills the cache FIRST, then A's slower serve lands its stale fill.
    The write order below IS that interleaving — the stale put must be
    dropped, not clobber the fresher entry (which would turn the next
    hot-pair lookup into a spurious stale-miss, or worse, serve epoch
    0's distance tagged fresh)."""
    c = EpochCache(capacity=8)
    c.put(1, 2, epoch=1, dist=7.0)       # flush B (newer epoch) lands
    c.put(1, 2, epoch=0, dist=5.0)       # flush A (stale) arrives late
    assert c.get(1, 2, epoch=1) == 7.0   # fresher entry survived
    # same-epoch refills and forward progress still write through
    c.put(1, 2, epoch=1, dist=6.5)
    assert c.get(1, 2, epoch=1) == 6.5
    c.put(1, 2, epoch=2, dist=9.0)
    assert c.get(1, 2, epoch=2) == 9.0
    # an empty slot accepts any epoch (no spurious drops on cold fills)
    c.put(3, 4, epoch=0, dist=1.0)
    assert c.get(3, 4, epoch=0) == 1.0


# ---------------------------------------------------------------------------
# micro-batcher unit tests (stub serving, no engine)
# ---------------------------------------------------------------------------
def _stub_serve(batch):
    for r in batch:
        r.dist = float(r.s + r.t)
        r.epoch = 0


def test_batcher_manual_flush():
    mb = MicroBatcher(_stub_serve, max_batch=8, auto=False)
    reqs = [mb.submit(i, i + 1) for i in range(3)]
    assert mb.pending == 3 and not reqs[0].done
    assert mb.flush() == 3
    assert all(r.done and r.dist == r.s + r.t for r in reqs)
    assert reqs[0].latency_s >= 0
    assert mb.flush() == 0                        # empty flush is a no-op
    assert mb.flush_reasons["manual"] == 1
    assert mb.occupancy()["flushes"] == 1


def test_batcher_deadline_flush():
    mb = MicroBatcher(_stub_serve, max_batch=64, deadline_s=0.03,
                      auto=True)
    reqs = [mb.submit(i, i) for i in range(3)]
    for r in reqs:
        assert r.wait(timeout=5.0), "deadline flush never fired"
    assert mb.flush_reasons["deadline"] >= 1
    assert mb.flush_reasons["full"] == 0
    assert mb.flushed_requests == 3
    mb.close()


def test_batcher_full_flush_before_deadline():
    """A full bucket flushes immediately even with a huge deadline."""
    mb = MicroBatcher(_stub_serve, max_batch=16, deadline_s=30.0,
                      auto=True)
    t0 = time.perf_counter()
    reqs = [mb.submit(i, i) for i in range(16)]
    for r in reqs:
        assert r.wait(timeout=5.0), "full-bucket flush never fired"
    assert time.perf_counter() - t0 < 5.0
    assert mb.flush_reasons["full"] == 1
    occ = mb.occupancy()
    assert occ["flushes"] == 1 and occ["occupancy_hist"] == {"16": 1}
    assert occ["mean_occupancy"] == 1.0
    mb.close()


def test_batcher_unresolved_request_raises():
    mb = MicroBatcher(lambda batch: None, max_batch=8, auto=False)
    mb.submit(1, 2)
    with pytest.raises(RuntimeError):
        mb.flush()
    with pytest.raises(ValueError):
        MicroBatcher(_stub_serve, max_batch=0, auto=False)


def test_batcher_flusher_death_fails_requests_and_closes():
    """A serve_batch exception in auto mode must resolve the batch's
    requests with the error, close the batcher, and surface the cause
    on the next submit — never a silent hang."""
    def boom(batch):
        raise ValueError("device exploded")

    mb = MicroBatcher(boom, max_batch=8, deadline_s=0.005, auto=True)
    r = mb.submit(1, 2)
    assert r.wait(timeout=5.0), "failed request never resolved"
    assert isinstance(r.error, ValueError)
    with pytest.raises(RuntimeError, match="flush failed"):
        r.result(timeout=0)
    # the batcher closes itself; any submit that raced the close was
    # failed as a straggler, and later submits raise with the cause
    deadline = time.monotonic() + 5.0
    while True:
        assert time.monotonic() < deadline, "batcher never closed"
        try:
            r2 = mb.submit(3, 4)
        except RuntimeError as exc:
            assert "flusher died" in str(exc)
            break
        assert r2.wait(timeout=5.0) and r2.error is not None
        time.sleep(0.01)
    assert isinstance(mb.error, ValueError)


def test_batcher_manual_flush_error_propagates():
    def boom(batch):
        raise ValueError("boom")

    mb = MicroBatcher(boom, max_batch=8, auto=False)
    r = mb.submit(1, 2)
    with pytest.raises(ValueError):
        mb.flush()
    assert r.done and isinstance(r.error, ValueError)


def test_manual_flush_failure_closes_batcher():
    """Regression (deterministic, auto=False): a failing flush must
    close the batcher in MANUAL mode too.  A request submitted during
    the failing flush (here: reentrantly from the serve callback, the
    single-threaded stand-in for a racing submitter) is resolved with
    the error, and any later submit is rejected with the cause — never
    parked forever on a serve path whose owner already saw the
    exception and walked away."""
    late = []

    def boom(batch):
        late.append(mb.submit(7, 8))      # arrives mid-failing-flush
        raise ValueError("device exploded")

    mb = MicroBatcher(boom, max_batch=8, auto=False)
    r = mb.submit(1, 2)
    with pytest.raises(ValueError):
        mb.flush()
    assert r.done and isinstance(r.error, ValueError)
    # the mid-flush request was swept into the failure, not forgotten
    assert late[0].done and isinstance(late[0].error, ValueError)
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit(3, 4)
    assert isinstance(mb.error, ValueError)
    with pytest.raises(RuntimeError, match="flush failed"):
        late[0].result(timeout=0)


def test_scheduled_time_latency_basis():
    """Regression: latency is measured from the *scheduled* arrival
    when one is given (the open-loop basis of loadgen.run_load), and
    the basis rides on the Request itself — so a response resolved
    from the cache is charged its queueing delay exactly like a device
    miss (no coordinated omission for hot pairs under overload)."""
    mb = MicroBatcher(_stub_serve, max_batch=8, auto=False)
    backlog = 0.25
    t_late = time.perf_counter() - backlog   # scheduled 250ms ago
    r_late = mb.submit(1, 2, t_sched=t_late)
    r_now = mb.submit(3, 4)
    mb.flush()
    assert r_late.t_sched == t_late
    assert r_now.t_sched == r_now.t_submit
    assert r_late.latency_s >= backlog       # queueing delay charged
    assert r_now.latency_s < backlog
    # same flush, same t_done: the only difference IS the basis
    assert abs((r_late.latency_s - r_now.latency_s)
               - (r_now.t_sched - t_late)) < 1e-9


def test_occupancy_buckets_are_planner_shapes():
    """The occupancy histogram reports the padded (pow2, floor-16)
    executable shapes that ran, not raw flush sizes."""
    mb = MicroBatcher(_stub_serve, max_batch=64, auto=False)
    for n in (3, 17, 64):
        for i in range(n):
            mb.submit(i, i)
        mb.flush()
    occ = mb.occupancy()
    assert occ["occupancy_hist"] == {"16": 1, "32": 1, "64": 1}
    assert occ["flushes"] == 3


def test_stats_reads_consistent_under_concurrent_flushes():
    """Regression for the off-lock stats reads: ``occupancy()`` must
    snapshot its counters under the batcher lock, so every report is
    internally consistent (histogram total == flush count == reasons
    total) even while the flusher thread is mutating them mid-flush."""
    mb = MicroBatcher(_stub_serve, max_batch=4, deadline_s=0.0005,
                      auto=True)
    stop = threading.Event()
    torn = []

    def reader():
        while not stop.is_set():
            occ = mb.occupancy()
            hist_total = sum(occ["occupancy_hist"].values())
            reasons = (occ["flush_full"] + occ["flush_deadline"]
                       + occ["flush_manual"])
            if hist_total != occ["flushes"] or reasons != occ["flushes"]:
                torn.append(occ)

    th = threading.Thread(target=reader)
    th.start()
    try:
        reqs = [mb.submit(i, i) for i in range(400)]
        for r in reqs:
            assert r.wait(timeout=10.0)
    finally:
        stop.set()
        th.join()
        mb.close()
    assert not torn, torn[:3]
    assert mb.flushed_requests == 400


def test_batcher_close_drains_pending():
    mb = MicroBatcher(_stub_serve, max_batch=64, deadline_s=30.0,
                      auto=True)
    reqs = [mb.submit(i, i) for i in range(5)]
    mb.close()                                    # drain=True default
    assert all(r.done for r in reqs)
    with pytest.raises(RuntimeError):
        mb.submit(1, 1)


# ---------------------------------------------------------------------------
# runtime + engine: correctness, cache, interleavings
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    g = road_like(380, seed=11)
    eng = EpochedEngine(g)
    eng.warmup(64)
    return eng


def _check_vs_epoch_oracle(req, graphs_by_epoch):
    g = graphs_by_epoch[req.epoch]
    want = dijkstra.pair(g, req.s, req.t)
    assert not dijkstra.mismatches_oracle(want, req.dist), \
        (req.s, req.t, req.epoch, req.dist, want)


def _apply_round(eng, seed):
    u, v, w = traffic_updates(eng.g, frac=0.05, seed=seed)
    eng.apply_updates(u, v, w)
    epoch, _dix, g, _stale = eng.snapshot()
    return epoch, g


def test_runtime_serves_exact_and_caches(engine):
    rt = ServingRuntime(engine, max_batch=64, cache_size=256,
                        auto=False)
    epoch, _dix, g, _stale = engine.snapshot()
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, g.n, (20, 2))
    reqs = [rt.submit(int(a), int(b)) for a, b in pairs]
    assert rt.flush() == 20
    for r in reqs:
        assert r.epoch == epoch and not r.cached
        _check_vs_epoch_oracle(r, {epoch: g})
    # resubmit: all hits, identical values, same epoch tag
    again = [rt.submit(int(a), int(b)) for a, b in pairs]
    rt.flush()
    for r0, r1 in zip(reqs, again):
        assert r1.cached and r1.dist == r0.dist and r1.epoch == epoch
    st = rt.stats()
    assert st["cache_hits"] >= 20 and st["cache_stale"] == 0


def test_runtime_snaps_max_batch_to_planner_bucket(engine):
    rt = ServingRuntime(engine, max_batch=100, auto=False)
    assert rt.max_batch == engine.planner.bucket_sizes(100)[-1] == 128
    assert rt.max_batch >= 100
    with pytest.raises(ValueError):
        ServingRuntime(engine, max_batch=0, auto=False)


def test_cache_disabled(engine):
    rt = ServingRuntime(engine, max_batch=64, cache_size=0, auto=False)
    assert rt.cache is None
    r1 = rt.submit(3, 200)
    rt.flush()
    r2 = rt.submit(3, 200)
    rt.flush()
    assert not r1.cached and not r2.cached and r1.dist == r2.dist
    # the per-tier counters are always present (bench_gate requires
    # them on every serve_live record); with the cache off every
    # request resolves in the label or planner tier
    st = rt.stats()
    assert st["cache_hits"] == 0
    assert st["label_hits"] + st["planner_dispatches"] == 2
    assert r1.tier in ("label", "planner") and r2.tier == r1.tier


def test_cache_hit_latency_uses_scheduled_basis(engine):
    """A response served FROM THE CACHE still measures latency from
    its scheduled arrival — hot Zipf pairs under overload are exactly
    the ones that hit, so an optimistic basis there would skew p50."""
    rt = ServingRuntime(engine, max_batch=64, cache_size=256,
                        auto=False)
    rt.submit(3, 100)
    rt.flush()                                    # miss, fills cache
    backlog = 0.2
    r = rt.submit(3, 100, t_sched=time.perf_counter() - backlog)
    rt.flush()
    assert r.cached
    assert r.latency_s >= backlog                 # backlog charged


def test_planner_pinned_epoch_query(engine):
    """QueryPlanner.query(dix=...) serves an explicit older epoch even
    after set_index published a newer one."""
    e0, dix0, g0, _stale = engine.snapshot()
    rng = np.random.default_rng(1)
    s = rng.integers(0, g0.n, 16)
    t = rng.integers(0, g0.n, 16)
    before = engine.planner.query(s, t)
    _apply_round(engine, seed=77)
    pinned = engine.planner.query(s, t, dix=dix0)
    np.testing.assert_array_equal(pinned, before)
    for i in range(8):
        want = dijkstra.pair(g0, int(s[i]), int(t[i]))
        if np.isinf(want):
            assert np.isinf(pinned[i])
        else:
            assert abs(pinned[i] - want) <= 1e-4 * max(want, 1.0)


def test_stale_cache_entry_detected_never_served(engine):
    """The hot-pair lifecycle across an epoch swap: cached at e, the
    first post-swap lookup must reject (stale counter) and recompute
    against e+1's index, then cache-hit at e+1."""
    rt = ServingRuntime(engine, max_batch=64, cache_size=256,
                        auto=False)
    e0, _dix, g0, _stale = engine.snapshot()
    s, t = 5, g0.n - 7
    r0 = rt.submit(s, t)
    rt.flush()
    r1 = rt.submit(s, t)
    rt.flush()
    assert r1.cached and r1.epoch == e0
    e1, g1 = _apply_round(engine, seed=91)
    assert e1 == e0 + 1
    stale_before = rt.cache.stats().stale
    r2 = rt.submit(s, t)
    rt.flush()
    assert not r2.cached                    # stale entry NOT served
    assert r2.epoch == e1
    assert rt.cache.stats().stale == stale_before + 1
    _check_vs_epoch_oracle(r2, {e1: g1})
    r3 = rt.submit(s, t)
    rt.flush()
    assert r3.cached and r3.epoch == e1 and r3.dist == r2.dist


def test_slow_flush_cannot_clobber_fresh_cache(engine):
    """The serving-level replay of the clobber regression: flush A pins
    epoch e, the epoch bumps and flush B fills the cache at e+1, then
    A's delayed fill (computed against e's pinned index) fires.  The
    e+1 entry must keep hitting — before the epoch guard, the stale
    fill overwrote it and the hot pair bounced off the stale check on
    every subsequent flush."""
    rt = ServingRuntime(engine, max_batch=64, cache_size=256,
                        auto=False)
    e0, dix0, g0, _stale = engine.snapshot()
    s, t = 11, g0.n - 3
    e1, _g1 = _apply_round(engine, seed=55)
    rB = rt.submit(s, t)
    rt.flush()                           # flush B: fills cache at e1
    assert rB.epoch == e1 and not rB.cached
    # flush A's serve was pinned at e0 and resolves only now
    dA = float(engine.planner.query(np.asarray([s], np.int32),
                                    np.asarray([t], np.int32),
                                    dix=dix0)[0])
    rt.cache.put(s, t, e0, dA)           # the late stale fill
    r = rt.submit(s, t)
    rt.flush()
    assert r.cached and r.epoch == e1 and r.dist == rB.dist


@pytest.mark.parametrize("order", [
    ("submit", "flush", "update", "submit", "flush"),
    ("submit", "update", "flush", "submit", "flush"),
    ("submit", "submit", "update", "flush", "update", "submit",
     "flush"),
    ("update", "submit", "flush", "submit", "update", "flush"),
])
def test_deterministic_interleavings(engine, order):
    """Scripted single-thread submit/update/flush interleavings: every
    resolved response must be consistent with the single epoch it is
    tagged with (requests pending across a swap are served wholly on
    the post-swap epoch, never torn)."""
    rt = ServingRuntime(engine, max_batch=64, cache_size=256,
                        auto=False)
    e, _dix, g, _stale = engine.snapshot()
    graphs = {e: g}
    # hash() is per-process salted; derive a stable per-order seed
    rng = np.random.default_rng(
        sum((i + 7) * len(op) for i, op in enumerate(order)))
    reqs = []
    seed = int(rng.integers(0, 10_000))
    for op in order:
        if op == "submit":
            a, b = rng.integers(0, g.n, 2)
            reqs.append(rt.submit(int(a), int(b)))
        elif op == "update":
            e, g = _apply_round(engine, seed=seed)
            graphs[e] = g
            seed += 1
        else:
            rt.flush()
    rt.flush()                                   # resolve stragglers
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.epoch in graphs
        _check_vs_epoch_oracle(r, graphs)


# ---------------------------------------------------------------------------
# threaded soak: concurrent refresh vs open-loop submission
# ---------------------------------------------------------------------------
def test_soak_concurrent_refresh(engine):
    """Background RefreshDriver publishes 3 epochs while a foreground
    stream submits hot zipf pairs through the auto-flushing runtime;
    every response must match the oracle of the epoch that served it
    and the stream must span the refresh rounds (requests keep flowing
    until after the final publish)."""
    rt = ServingRuntime(engine, max_batch=64, deadline_s=0.002,
                        cache_size=4096, auto=True)
    e_start = engine.snapshot()[0]
    drv = RefreshDriver(engine, rounds=3, frac=0.05, interval_s=0.05,
                        seed=23).start()
    pool = zipf_pairs(engine.g, 4000, pool=128, seed=3)
    reqs = []
    i = 0
    t_end = time.monotonic() + 60.0
    while not drv.done and time.monotonic() < t_end:
        a, b = pool[i % len(pool)]
        reqs.append(rt.submit(int(a), int(b)))
        i += 1
        time.sleep(0.001)
    drv.join(timeout=60.0)
    assert drv.done and drv.error is None
    e_end = engine.snapshot()[0]
    assert e_end == e_start + 3
    # a tail served strictly after the final publish
    tail = [rt.submit(int(a), int(b)) for a, b in pool[:24]]
    deadline = time.monotonic() + 60.0
    for r in reqs + tail:
        assert r.wait(max(0.0, deadline - time.monotonic())), \
            "runtime stalled under concurrent refresh"
        assert r.error is None, f"flush failed mid-soak: {r.error!r}"
    rt.close()
    assert all(r.epoch == e_end for r in tail)
    graphs, evicted = drv.graph_snapshots()
    epochs_seen = {r.epoch for r in reqs + tail}
    assert epochs_seen <= set(graphs) | evicted
    checked, bad = validate_against_epochs(
        reqs + tail, graphs, sample=80, seed=1, evicted=evicted)
    assert checked >= 24 and bad == 0
    st = rt.stats()
    assert st["flushes"] > 0 and st["cache_hits"] > 0
    # sanity on the record shapes the load harness publishes
    assert set(drv.as_record()) == {
        "refresh_rounds", "refresh_pipelined", "refresh_items",
        "refresh_mean_s", "refresh_max_s"}
    rec = drv.as_record()
    assert rec["refresh_rounds"] == 3 and not rec["refresh_pipelined"]


def test_refresh_driver_retention_cap(engine):
    """Regression for the unbounded graphs_by_epoch leak: retention
    keeps only the last ``retain_epochs`` snapshots, records the ids it
    evicted, and the validation oracle skips (never miscounts) them."""
    e_start = engine.snapshot()[0]
    drv = RefreshDriver(engine, rounds=5, frac=0.01, seed=7,
                        retain_epochs=3).start()
    drv.join(timeout=300.0)
    graphs, evicted = drv.graph_snapshots()
    assert len(graphs) == 3
    # initial snapshot + 5 rounds = 6 recorded; 3 survive the cap
    assert sorted(graphs) == [e_start + 3, e_start + 4, e_start + 5]
    assert max(evicted) < min(graphs)        # oldest evicted first
    assert {e_start, e_start + 1, e_start + 2} <= evicted

    class _Resp:
        def __init__(self, e):
            self.epoch, self.s, self.t, self.dist = e, 0, 1, 0.0

    reqs = [_Resp(e_start)] + [_Resp(-999)]
    checked, bad = validate_against_epochs(reqs, graphs, sample=16,
                                           seed=0, evicted=evicted)
    # the evicted epoch is skipped; the never-published one counts bad
    assert (checked, bad) == (1, 1)


def test_resident_bucket_warm_across_epoch_swap():
    """The resident fast-path program (cross_res) is warmed with the
    other planner buckets, and an epoch swap keeps every executable
    warm: a flush containing hot cross-fragment queries right after
    ``apply_updates`` must trigger zero fresh XLA compiles."""
    g = road_like(2500, seed=3)
    engine = EpochedEngine(g, hierarchy_levels=3, warm_refresh=True)
    dix = engine.dix
    assert np.asarray(dix.res_rows).shape[0] > 1, \
        "fixture graph produced no resident rows"
    rt = ServingRuntime(engine, max_batch=64, cache_size=0, auto=False)
    rt.warmup()
    sizes = {case: fn._cache_size()
             for case, fn in engine.planner._fns.items()}
    assert sizes["cross_res"] > 0, "warmup skipped the resident program"
    # swap the epoch, then serve a batch that exercises every bucket
    u, v = g.edge_u[:6], g.edge_v[:6]
    engine.apply_updates(u, v, g.edge_w[:6] + 1.0)
    rf = engine.dix.host_res_frag
    tg = engine.dix.host_topgrp_frag
    agent_of = np.asarray(engine.dix.agent_of)
    frag_of = np.asarray(engine.dix.frag_of)
    fa = frag_of[agent_of]
    hot = np.nonzero((fa >= 0) & (rf[np.maximum(fa, 0)] >= 0))[0]
    t0 = tg[fa[hot[0]]]
    far = hot[tg[fa[hot]] != t0]
    reqs = [rt.submit(int(hot[0]), int(far[i % far.size]))
            for i in range(40)]
    rt.flush()
    assert engine.planner.last_counts["cross_res"] > 0
    for r in reqs:
        assert r.wait(10.0) and r.error is None
    after = {case: fn._cache_size()
             for case, fn in engine.planner._fns.items()}
    assert after == sizes, f"epoch swap recompiled: {sizes} -> {after}"
    rt.close()
