"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles, in
interpret mode (force='pallas'), plus semiring property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dijkstra
from repro.core.graph import random_graph
from repro.kernels import ops, ref


def _rand(shape, rng, inf_frac=0.2, dtype=np.float32):
    x = rng.random(shape).astype(np.float32) * 10
    x[rng.random(shape) < inf_frac] = np.inf
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (5, 7, 3), (64, 200, 64),
                                   (130, 128, 257), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_matches_ref(m, k, n, dtype):
    rng = np.random.default_rng(m * 1000 + k + n)
    a = _rand((m, k), rng, dtype=dtype)
    b = _rand((k, n), rng, dtype=dtype)
    got = ops.minplus(a, b, bm=8, bn=128, bk=8, force="pallas")
    want = ref.minplus_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("m,k,n", [(5, 7, 3), (64, 100, 33)])
def test_minplus_accum_matches_ref(m, k, n):
    rng = np.random.default_rng(0)
    a = _rand((m, k), rng)
    b = _rand((k, n), rng)
    c = _rand((m, n), rng, inf_frac=0.5)
    got = ops.minplus_accum(c, a, b, bm=8, bn=128, bk=8, force="pallas")
    np.testing.assert_allclose(got, ref.minplus_accum_ref(c, a, b),
                               rtol=1e-6)


@pytest.mark.parametrize("q,k1,k2", [(1, 1, 1), (5, 7, 3), (37, 130, 201),
                                     (128, 128, 128), (100, 300, 129)])
@pytest.mark.parametrize("force", ["ref", "pallas"])
def test_minplus_twoside_matches_naive(q, k1, k2, force):
    """Fused two-sided contraction vs the direct [q,k1,k2] cube, on
    shapes that are deliberately NOT tile multiples."""
    rng = np.random.default_rng(q * 1000 + k1 + k2)
    rows = _rand((q, k1), rng)
    d = _rand((k1, k2), rng)
    rowt = _rand((q, k2), rng)
    naive = np.min(np.asarray(rows)[:, :, None] + np.asarray(d)[None]
                   + np.asarray(rowt)[:, None, :], axis=(1, 2))
    got = ops.minplus_twoside(rows, d, rowt, bq=8, bk1=16, bk2=128,
                              force=force)
    np.testing.assert_allclose(np.asarray(got), naive, rtol=1e-5)


@pytest.mark.parametrize("q,k1,k2", [(9, 70, 53), (130, 257, 139),
                                     (1, 333, 7)])
def test_minplus_twoside_default_tiles_odd_shapes(q, k1, k2):
    """Padding correctness at the DEFAULT tile sizes (bq=bk1=bk2=128):
    mb/S shapes that are not multiples of any tile dimension — the
    shapes the serve/refresh path actually produces, since mb is padded
    to 8 (not 128) and S+1 is arbitrary.  The +inf padding is the
    semiring's absorbing element, so fillers must never win a min."""
    rng = np.random.default_rng(q * 7919 + k1 * 31 + k2)
    rows = _rand((q, k1), rng)
    d = _rand((k1, k2), rng)
    rowt = _rand((q, k2), rng)
    naive = np.min(np.asarray(rows)[:, :, None] + np.asarray(d)[None]
                   + np.asarray(rowt)[:, None, :], axis=(1, 2))
    for force in ("ref", "pallas"):
        got = ops.minplus_twoside(rows, d, rowt, force=force)
        np.testing.assert_allclose(np.asarray(got), naive, rtol=1e-5)
        assert not np.isnan(np.asarray(got)).any()


def test_minplus_twoside_all_inf():
    """Disconnected case: every path +inf stays +inf (no NaN from
    inf-inf arithmetic in the padding)."""
    rows = jnp.full((4, 10), jnp.inf)
    d = jnp.full((10, 6), jnp.inf)
    rowt = jnp.full((4, 6), jnp.inf)
    for force in ("ref", "pallas"):
        got = np.asarray(ops.minplus_twoside(rows, d, rowt, bq=8, bk1=16,
                                             bk2=128, force=force))
        assert np.isinf(got).all() and not np.isnan(got).any()


@pytest.mark.parametrize("force", ["ref", "pallas"])
def test_fw_all_inf_padding_blocks(force):
    """The refresh path's pow2 padding (`_fw_bucket(pad_pow2=True)`)
    feeds ALL-+inf dummy blocks through the witness FW kernels.  Audit
    pin: the FW recurrence only adds (inf+inf = inf, never inf-inf),
    so a padding block must come out diag-0 / off-diag-inf / nxt -1
    with no NaN anywhere, and must not perturb its batch neighbours
    (FW is row-independent across the batch)."""
    rng = np.random.default_rng(99)
    real = np.asarray(_rand((1, 16, 16), rng, inf_frac=0.3))[0]
    batch = np.stack([np.full((16, 16), np.inf, np.float32), real])
    dist, nxt = map(np.asarray,
                    ops.fw_batch_next(jnp.asarray(batch), force=force))
    assert not np.isnan(dist).any()
    pad_d, pad_n = dist[0], nxt[0]
    off = ~np.eye(16, dtype=bool)
    assert (pad_d[off] == np.inf).all() and (np.diag(pad_d) == 0).all()
    assert (pad_n == -1).all()
    solo_d, solo_n = map(np.asarray,
                         ops.fw_batch_next(jnp.asarray(real[None]),
                                           force=force))
    np.testing.assert_array_equal(dist[1], solo_d[0])
    np.testing.assert_array_equal(nxt[1], solo_n[0])
    # distance-only kernel agrees bit for bit, NaN-free too
    d2 = np.asarray(ops.fw_batch(jnp.asarray(batch), force=force))
    np.testing.assert_array_equal(dist, d2)


def test_fw_bucket_all_inf_guard():
    """_fw_bucket's loud NaN guard + end-to-end all-INF padding: a
    pow2-padded piece batch (2 real pieces -> 8 with +inf dummies)
    yields exact blocks and trips no guard."""
    from repro.core.device_engine import _fw_bucket

    rng = np.random.default_rng(5)
    adjs = [np.asarray(_rand((8, 8), rng, inf_frac=0.5)) for _ in range(2)]
    blocks, nexts = _fw_bucket(adjs, pad_pow2=True)
    want, _ = map(np.asarray, ops.fw_batch_next(jnp.asarray(np.stack(adjs))))
    np.testing.assert_array_equal(blocks, want)
    assert not np.isnan(blocks).any()


def test_minplus_twoside_argmin_all_inf():
    """All-disconnected witness contraction: +inf out, -1 witnesses,
    no NaN — the padding regime serve_cross_w hits when a query batch
    is pure filler."""
    rows = jnp.full((4, 10), jnp.inf)
    d = jnp.full((10, 6), jnp.inf)
    rowt = jnp.full((4, 6), jnp.inf)
    for force in ("ref", "pallas"):
        out, wx, wy = map(np.asarray, ops.minplus_twoside_argmin(
            rows, d, rowt, force=force))
        assert np.isinf(out).all() and not np.isnan(out).any()
        assert (wx == -1).all() and (wy == -1).all()


@pytest.mark.parametrize("q,k1,k2", [(5, 7, 3), (37, 130, 201),
                                     (64, 128, 128)])
@pytest.mark.parametrize("force", ["ref", "pallas"])
def test_minplus_twoside_argmin_witness(q, k1, k2, force):
    """Witness mode: identical minima to the distance-only kernel, and
    every finite minimum's (wx, wy) pair actually achieves it."""
    rng = np.random.default_rng(q * 131 + k1 + k2)
    rows = _rand((q, k1), rng, inf_frac=0.4)
    d = _rand((k1, k2), rng, inf_frac=0.4)
    rowt = _rand((q, k2), rng, inf_frac=0.4)
    want = np.asarray(ops.minplus_twoside(rows, d, rowt, force=force))
    out, wx, wy = ops.minplus_twoside_argmin(rows, d, rowt, force=force)
    out, wx, wy = map(np.asarray, (out, wx, wy))
    np.testing.assert_array_equal(out, want)
    rows_n, d_n, rowt_n = map(np.asarray, (rows, d, rowt))
    for i in range(q):
        if np.isinf(out[i]):
            assert wx[i] == -1 and wy[i] == -1
        else:
            assert 0 <= wx[i] < k1 and 0 <= wy[i] < k2
            assert (rows_n[i, wx[i]] + d_n[wx[i], wy[i]]
                    + rowt_n[i, wy[i]]) == out[i]


@pytest.mark.parametrize("b,n", [(2, 8), (3, 24), (2, 64)])
@pytest.mark.parametrize("force", ["ref", "pallas"])
def test_fw_batch_next_witness(b, n, force):
    """Witness FW: bit-identical distances to fw_batch, and walking the
    successor matrix reproduces every finite distance exactly."""
    rng = np.random.default_rng(b * 100 + n)
    # integer weights so the walk's left-to-right f32 accumulation is
    # exact regardless of FW's summation order (the repo's graphs use
    # integer weights for the same reason)
    d = rng.integers(1, 60, (b, n, n)).astype(np.float32)
    d[rng.random((b, n, n)) < 0.6] = np.inf
    d = np.minimum(d, np.transpose(d, (0, 2, 1)))    # symmetric, like adj
    want = np.asarray(ops.fw_batch(jnp.asarray(d), force=force))
    dist, nxt = ops.fw_batch_next(jnp.asarray(d), force=force)
    dist, nxt = np.asarray(dist), np.asarray(nxt)
    np.testing.assert_array_equal(dist, want)
    adj = d.copy()
    for i in range(n):
        adj[:, i, i] = 0.0
    for bi in range(b):
        for i in range(n):
            for j in range(n):
                if i == j:
                    assert nxt[bi, i, j] == -1
                    continue
                if np.isinf(dist[bi, i, j]):
                    assert nxt[bi, i, j] == -1
                    continue
                u, acc, hops = i, 0.0, 0
                while u != j:
                    h = int(nxt[bi, u, j])
                    assert h >= 0, (bi, i, j, u)
                    acc += adj[bi, u, h]
                    u = h
                    hops += 1
                    assert hops <= n
                assert acc == dist[bi, i, j], (bi, i, j)


def test_fw_next_single_matches_batch():
    rng = np.random.default_rng(7)
    d = np.asarray(_rand((24, 24), rng, inf_frac=0.5))
    for force in ("ref", "pallas"):
        dist_b, nxt_b = ops.fw_batch_next(jnp.asarray(d[None]),
                                          force=force)
        dist, nxt = ops.fw_next(jnp.asarray(d), force=force)
        np.testing.assert_array_equal(np.asarray(dist),
                                      np.asarray(dist_b)[0])
        np.testing.assert_array_equal(np.asarray(nxt),
                                      np.asarray(nxt_b)[0])


@pytest.mark.parametrize("b,n", [(1, 8), (3, 16), (2, 64)])
def test_fw_batch_matches_ref(b, n):
    rng = np.random.default_rng(b * 100 + n)
    d = _rand((b, n, n), rng, inf_frac=0.5)
    d = jnp.minimum(d, jnp.transpose(d, (0, 2, 1)))
    got = ops.fw_batch(d, force="pallas")
    np.testing.assert_allclose(got, ref.fw_batch_ref(d), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(10, 8), (100, 32), (64, 64)])
def test_fw_blocked_matches_ref(n, block):
    rng = np.random.default_rng(n)
    d = _rand((n, n), rng, inf_frac=0.6)
    d = jnp.minimum(d, d.T)
    got = ops.fw_apsp(d, block=block, force="pallas")
    np.testing.assert_allclose(got, ref.fw_ref(d), rtol=1e-6)


def test_fw_integer_weights_exact():
    """Integer weights -> bitwise-exact FW distances in f32: the
    invariant the refresh differential harness (incremental == scratch,
    array-equal) rests on."""
    rng = np.random.default_rng(77)
    d = _rand((60, 60), rng, inf_frac=0.5)
    d = jnp.minimum(d, d.T)
    di = jnp.where(jnp.isfinite(d), jnp.round(d * 8), jnp.inf)
    a = np.asarray(ops.fw_apsp(di))
    b = np.asarray(ref.fw_ref(di))
    np.testing.assert_array_equal(a, b)
    assert (np.asarray(a)[np.isfinite(a)] % 1 == 0).all()


def test_fw_matches_dijkstra():
    """APSP kernel vs heap Dijkstra on a real graph."""
    g = random_graph(40, 80, seed=9)
    adj = np.full((g.n, g.n), np.inf, np.float32)
    adj[g.edge_u, g.edge_v] = g.edge_w
    adj[g.edge_v, g.edge_u] = g.edge_w
    got = np.asarray(ops.fw_apsp(jnp.asarray(adj), block=16,
                                 force="pallas"))
    for s in range(0, g.n, 7):
        want = dijkstra.sssp(g, s)
        fin = np.isfinite(want)
        np.testing.assert_allclose(got[s][fin], want[fin], rtol=1e-5)


# ---- property tests --------------------------------------------------------
@given(st.integers(0, 10_000))
@settings(max_examples=15)
def test_fw_idempotent(seed):
    """APSP is a fixpoint: fw(fw(D)) == fw(D)."""
    rng = np.random.default_rng(seed)
    d = _rand((1, 12, 12), rng, inf_frac=0.4)
    d = jnp.minimum(d, jnp.transpose(d, (0, 2, 1)))
    once = ops.fw_batch(d, force="ref")
    twice = ops.fw_batch(once, force="ref")
    np.testing.assert_allclose(once, twice, rtol=1e-6)


@given(st.integers(0, 10_000))
@settings(max_examples=15)
def test_minplus_associative(seed):
    """(A (x) B) (x) C == A (x) (B (x) C) — semiring associativity."""
    rng = np.random.default_rng(seed)
    a = _rand((6, 5), rng)
    b = _rand((5, 7), rng)
    c = _rand((7, 4), rng)
    left = ref.minplus_ref(ref.minplus_ref(a, b), c)
    right = ref.minplus_ref(a, ref.minplus_ref(b, c))
    np.testing.assert_allclose(left, right, rtol=1e-5)


@given(st.integers(0, 10_000))
@settings(max_examples=15)
def test_minplus_triangle_inequality(seed):
    """D (x) D <= D for any APSP matrix D (metric closure)."""
    rng = np.random.default_rng(seed)
    d = _rand((1, 10, 10), rng, inf_frac=0.3)
    d = jnp.minimum(d, jnp.transpose(d, (0, 2, 1)))
    apsp = ops.fw_batch(d, force="ref")[0]
    sq = ref.minplus_ref(apsp, apsp)
    assert bool(jnp.all(sq >= apsp - 1e-4))
