"""Differential harness for incremental index maintenance (DESIGN.md §9).

The contract under test: after any sequence of edge-weight update
batches, the incrementally-refreshed DeviceIndex is

  1. array-equal, field for field, to a from-scratch device build on
     the updated graph with the same structure (refresh == rebuild),
  2. exact against host Dijkstra through the planner AND the monolithic
     serve path on every epoch,

on randomized ``road_like`` graphs, randomized update batches (jams +
clears, localized + uniform), and randomized query batches.  Update
weights are integers, so f32 distance arithmetic is exact and the
comparisons can demand bitwise equality rather than tolerances.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dijkstra
from repro.core.device_engine import (build_device_index, classify_updates,
                                      refresh_index, serve_step)
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like, traffic_updates, tree_with_blobs
from repro.core.supergraph import reweight_index
# the refreshed-field list lives with the serve driver so the parity
# assertions here can never drift from what serving publishes
from repro.launch.serve import REFRESHED_FIELDS


def _assert_scratch_equal(engine: EpochedEngine) -> None:
    """Incremental rebuild == from-scratch rebuild, array-equal."""
    sdix = build_device_index(reweight_index(engine.ix, engine.g))
    for f in REFRESHED_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(engine.dix, f)),
            np.asarray(getattr(sdix, f)),
            err_msg=f"epoch {engine.epoch}: field {f} diverged from "
                    "from-scratch rebuild")


def _assert_serves_exact(engine: EpochedEngine, pairs) -> None:
    """Planner + monolithic serve vs host Dijkstra on the live graph."""
    got = engine.query(pairs[:, 0], pairs[:, 1])
    mono = np.asarray(serve_step(engine.dix,
                                 jnp.asarray(pairs[:, 0], jnp.int32),
                                 jnp.asarray(pairs[:, 1], jnp.int32)))
    for i, (a, b) in enumerate(pairs):
        want = dijkstra.pair(engine.g, int(a), int(b))
        for val in (got[i], mono[i]):
            if np.isinf(want):
                assert np.isinf(val), (a, b, val)
            else:
                assert abs(val - want) < 1e-3, \
                    (engine.epoch, a, b, val, want)


@given(st.integers(0, 10_000))
@settings(max_examples=4)
def test_refresh_differential(seed):
    """Randomized graphs x randomized update sequences x randomized
    queries: every epoch must match both Dijkstra and a from-scratch
    rebuild (array-equal)."""
    rng = np.random.default_rng(seed)
    g = road_like(int(rng.integers(250, 500)), seed=seed)
    engine = EpochedEngine(g)
    pairs = rng.integers(0, g.n, size=(30, 2))
    _assert_serves_exact(engine, pairs)          # epoch 0 sanity
    for r in range(2):
        u, v, w = traffic_updates(
            engine.g, frac=float(rng.uniform(0.01, 0.08)),
            seed=seed + r, localized=bool(r % 2),
            jam_frac=float(rng.uniform(0.0, 1.0)))
        engine.apply_updates(u, v, w)
        _assert_scratch_equal(engine)
        pairs = rng.integers(0, g.n, size=(30, 2))
        _assert_serves_exact(engine, pairs)


def test_refresh_blob_graph_pieces():
    """Piece-heavy graph: updates land mostly inside DRAs, exercising
    the piece rewrite + dist-to-agent re-derivation path."""
    g = tree_with_blobs(25, 6, seed=9)
    engine = EpochedEngine(g)
    rng = np.random.default_rng(3)
    for r in range(3):
        u, v, w = traffic_updates(engine.g, frac=0.06, seed=50 + r,
                                  localized=False)
        stats = engine.apply_updates(u, v, w)
        assert stats.n_inert == 0    # every edge maps onto a structure
        _assert_scratch_equal(engine)
        pairs = rng.integers(0, g.n, size=(25, 2))
        _assert_serves_exact(engine, pairs)
    assert engine.epoch == 3


def test_decrease_and_increase_batches_agree():
    """Jam-clear (decrease-only) and jam (increase) batches both land
    on the same overlay fixpoint as a from-scratch solve, and the stats
    classify the batch direction correctly."""
    g = road_like(420, seed=13)
    engine = EpochedEngine(g)
    idx = np.arange(0, g.m, 7)
    u, v = g.edge_u[idx], g.edge_v[idx]
    stats = engine.apply_updates(u, v, np.maximum(1, g.edge_w[idx] // 3))
    assert stats.decrease_only
    _assert_scratch_equal(engine)
    # now jam the same edges -> increase path
    stats = engine.apply_updates(u, v, engine.g.edge_w[
        engine.g.edge_ids(u, v)] * 5)
    assert not stats.decrease_only and stats.total_increase > 0
    _assert_scratch_equal(engine)


def test_hier_decrease_fast_path_matches_scratch():
    """The top-closure decrease-only fast path (bounded (min,+)
    relaxation seeded from the changed slot rows) must be taken for
    small jam-clear batches on a hierarchical engine — and its d2 AND
    d2_next must stay array-equal to the full FW re-close a scratch
    rebuild runs.  An increase batch must never take it."""
    g = road_like(420, seed=41)
    engine = EpochedEngine(g, hierarchy_levels=2)

    def assert_scratch_equal_hier():
        # the from-scratch oracle must force the same overlay depth:
        # "auto" would re-dense at this size and change table shapes
        sdix = build_device_index(reweight_index(engine.ix, engine.g),
                                  hierarchy_levels=2)
        for f in REFRESHED_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(engine.dix, f)),
                np.asarray(getattr(sdix, f)),
                err_msg=f"epoch {engine.epoch}: field {f} diverged "
                        "from from-scratch rebuild")

    closures = []
    for r in range(4):
        u, v, w = traffic_updates(engine.g, frac=0.01, seed=60 + r,
                                  localized=True, jam_frac=0.0)
        stats = engine.apply_updates(u, v, w)
        assert stats.decrease_only
        closures.append(stats.top_closure)
        assert_scratch_equal_hier()
    assert "decrease" in closures, closures
    assert "dense" not in closures       # hier engines never re-dense
    assert stats.as_record()["top_closure"] == closures[-1]
    # jam the whole region back up -> increases are never fast-pathed
    u, v, w = traffic_updates(engine.g, frac=0.05, seed=60,
                              localized=True, jam_frac=1.0)
    stats = engine.apply_updates(u, v, w)
    assert not stats.decrease_only
    assert stats.top_closure in ("full_fw", "carry")
    assert_scratch_equal_hier()


def test_piece_only_increase_not_decrease_only():
    """Batch direction is judged against the edges' previous weights,
    not just overlay deltas: a jam entirely inside DRA pieces (no
    overlay slot touched) must not be classified decrease_only."""
    g = tree_with_blobs(15, 6, seed=4)
    engine = EpochedEngine(g)
    gid_e = np.maximum(engine.plan.piece_gid[g.edge_u],
                       engine.plan.piece_gid[g.edge_v])
    idx = np.nonzero(gid_e >= 0)[0][:5]
    assert idx.size
    stats = engine.apply_updates(g.edge_u[idx], g.edge_v[idx],
                                 g.edge_w[idx] * 3)
    assert not stats.decrease_only and stats.total_increase > 0
    _assert_scratch_equal(engine)


def test_failed_refresh_rolls_back_plan_caches():
    """An exception mid-refresh must leave the plan's weight caches
    describing the still-published epoch, so the next refresh composes
    correctly (refresh == rebuild even after a failure)."""
    g = road_like(400, seed=19)
    engine = EpochedEngine(g)
    frag_adj_before = engine.plan.frag_adj.copy()
    sup_w_before = engine.plan.sup_w.copy()
    u, v, w = traffic_updates(g, frac=0.05, seed=2)
    bad_g = object()       # piece stage will blow up on .subgraph
    has_piece = any(
        engine.plan.piece_gid[a] >= 0 or engine.plan.piece_gid[b] >= 0
        for a, b in zip(u, v))
    if has_piece:
        with pytest.raises(AttributeError):
            refresh_index(engine.dix, engine.plan, bad_g, u, v, w)
        np.testing.assert_array_equal(engine.plan.frag_adj,
                                      frag_adj_before)
        np.testing.assert_array_equal(engine.plan.sup_w, sup_w_before)
    # and a real refresh afterwards still matches scratch
    engine.apply_updates(u, v, w)
    _assert_scratch_equal(engine)


def test_classify_updates_targets():
    """Every update lands on its structural owner: same-fragment edges
    dirty exactly one fragment, cross-fragment edges exactly one E_B
    slot, DRA-internal edges exactly one piece."""
    g = road_like(500, seed=17)
    engine = EpochedEngine(g)
    plan = engine.plan
    fa = plan.frag_of
    # same-fragment shrink edge
    m_frag = (fa[g.edge_u] >= 0) & (fa[g.edge_u] == fa[g.edge_v])
    # cross-fragment shrink edge
    m_eb = (fa[g.edge_u] >= 0) & (fa[g.edge_v] >= 0) \
        & (fa[g.edge_u] != fa[g.edge_v])
    # piece edge
    m_piece = (plan.piece_gid[g.edge_u] >= 0) \
        | (plan.piece_gid[g.edge_v] >= 0)
    for mask, kind in ((m_frag, "frag"), (m_eb, "eb"),
                       (m_piece, "piece")):
        assert mask.any(), f"graph has no {kind} edge to test"
        e = np.nonzero(mask)[0][0]
        upd = classify_updates(plan, [g.edge_u[e]], [g.edge_v[e]],
                               [g.edge_w[e] + 1])
        assert upd.n_inert == 0
        assert upd.dirty_frags.size == (1 if kind == "frag" else 0)
        assert upd.eb_slots.size == (1 if kind == "eb" else 0)
        assert upd.dirty_gids.size == (1 if kind == "piece" else 0)


def test_unknown_edge_rejected():
    g = road_like(300, seed=1)
    with pytest.raises(ValueError):
        g.with_edge_weights([0], [0], [5.0])
    # a non-edge pair
    a, b = int(g.edge_u[0]), int(g.edge_v[-1])
    if g.edge_ids([a], [b])[0] < 0:
        with pytest.raises(ValueError):
            g.with_edge_weights([a], [b], [5.0])
    with pytest.raises(ValueError):
        g.with_edge_weights(g.edge_u[:1], g.edge_v[:1], [-1.0])


def test_with_edge_weights_preserves_layout():
    """CSR and edge-list views stay aligned after an update."""
    g = road_like(300, seed=2)
    idx = np.arange(0, g.m, 5)
    w_new = g.edge_w[idx] + 7
    g2 = g.with_edge_weights(g.edge_u[idx], g.edge_v[idx], w_new)
    assert g2.n == g.n and g2.m == g.m
    np.testing.assert_array_equal(g2.edge_u, g.edge_u)
    np.testing.assert_array_equal(g2.edge_v, g.edge_v)
    np.testing.assert_array_equal(g2.indices, g.indices)
    np.testing.assert_array_equal(g2.edge_w[idx], w_new)
    keep = np.ones(g.m, bool)
    keep[idx] = False
    np.testing.assert_array_equal(g2.edge_w[keep], g.edge_w[keep])
    # CSR weights agree with the edge list everywhere
    for u in range(0, g.n, 17):
        nbrs, ws = g2.neighbors(u)
        for v, w in zip(nbrs, ws):
            e = g2.edge_ids([u], [v])[0]
            assert g2.edge_w[e] == w


def test_refresh_stats_shape():
    """Refresh touches only what the update batch dirties."""
    g = road_like(600, seed=23)
    engine = EpochedEngine(g)
    u, v, w = traffic_updates(g, frac=0.01, seed=5, localized=True)
    dix_before = engine.dix
    stats = engine.apply_updates(u, v, w)
    assert stats.n_updates == len(u)
    assert 0 < stats.n_dirty_frags <= stats.n_frags
    assert stats.dirty_frag_frac <= 1.0
    assert stats.timings["total"] > 0
    # as_record carries the full per-stage breakdown (DESIGN.md §16):
    # every stage refresh_index timed is in the dict, totals excluded
    rec = stats.as_record()
    assert {"classify", "frag_fw", "super_fw", "hub", "pieces"} \
        <= set(rec["stage_timings"])
    assert "total" not in rec["stage_timings"]
    assert all(v >= 0 for v in rec["stage_timings"].values())
    assert sum(rec["stage_timings"].values()) \
        <= stats.timings["total"] + 1e-3
    # untouched fields are shared by reference across epochs (immutable
    # double-buffering, not copies)
    for f in ("agent_of", "frag_of", "pos_in_frag", "piece_gid",
              "pos_in_piece", "bpos", "bvalid", "bnd_super"):
        assert getattr(engine.dix, f) is getattr(dix_before, f)


def test_refresh_index_composes_without_engine():
    """refresh_index is usable standalone (no EpochedEngine): feed it
    the plan + updated graph and the result matches a fresh build."""
    from repro.core.device_engine import build_device_index_with_plan
    from repro.core.supergraph import build_index

    g = road_like(350, seed=31)
    ix = build_index(g)
    dix, plan = build_device_index_with_plan(ix)
    u, v, w = traffic_updates(g, frac=0.05, seed=8)
    g2 = g.with_edge_weights(u, v, w)
    dix2, _stats = refresh_index(dix, plan, g2, u, v, w)
    sdix = build_device_index(reweight_index(ix, g2))
    for f in REFRESHED_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(dix2, f)),
                                      np.asarray(getattr(sdix, f)))
