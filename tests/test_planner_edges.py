"""QueryPlanner edge cases: degenerate batches, empty buckets, and
pow2-padding filler hygiene (DESIGN.md §5).

The planner pads each case bucket to a power of two with (0, 0)
self-query filler; none of that filler may ever leak into returned
distances, for any batch composition.
"""
import numpy as np
import pytest

from repro.core import dijkstra
from repro.core.device_engine import build_device_index
from repro.core.dist_engine import QueryPlanner, _pad_pow2
from repro.core.graph import road_like
from repro.core.supergraph import build_index


@pytest.fixture(scope="module")
def world():
    g = road_like(1000, seed=41)
    ix = build_index(g)
    dix = build_device_index(ix)
    return g, dix, QueryPlanner(dix)


@pytest.fixture(scope="module")
def world_res():
    """A hierarchical index with resident pre-lifted rows, so the
    cross_res bucket is actually reachable (the dense ``world`` index
    has no residency and its cross_res bucket is provably empty)."""
    g = road_like(2500, seed=3)
    ix = build_index(g)
    dix = build_device_index(ix, hierarchy_levels=3)
    rf = getattr(dix, "host_res_frag", None)
    if rf is None or np.asarray(dix.res_rows).shape[0] <= 1:
        pytest.skip("no resident rows at this size")
    rf = np.asarray(rf)
    tg = np.asarray(dix.host_topgrp_frag)
    hot = np.nonzero(rf >= 0)[0]
    if hot.size < 2 or np.unique(tg[hot]).size < 2:
        pytest.skip("no resident pair across top groups at this size")
    return g, dix, QueryPlanner(dix)


def _want(g, pairs):
    return np.array([dijkstra.pair(g, int(a), int(b)) for a, b in pairs])


def _check(g, planner, pairs):
    pairs = np.asarray(pairs)
    got = planner(pairs[:, 0], pairs[:, 1])
    want = _want(g, pairs)
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-5)
    assert np.isinf(got[~fin]).all()
    return got


def _pairs_of_case(g, dix, case, n):
    """n query pairs all belonging to one planner case."""
    agent_of = np.asarray(dix.agent_of)
    frag_of = np.asarray(dix.frag_of)
    fa = frag_of[agent_of]
    out = []
    if case == "same_dra":
        agents, counts = np.unique(agent_of, return_counts=True)
        a = agents[np.argmax(counts)]
        members = np.nonzero(agent_of == a)[0]
        assert members.size >= 2
        for i in range(n):
            out.append((int(members[i % members.size]),
                        int(members[(i + 1) % members.size])))
    elif case == "same_frag":
        for f in np.unique(fa[fa >= 0]):
            nodes = np.nonzero(fa == f)[0]
            us = agent_of[nodes]
            if np.unique(us).size >= 2:
                j = int(np.argmax(us != us[0]))
                for i in range(n):
                    out.append((int(nodes[0]), int(nodes[j])))
                break
    elif case == "cross_res":
        rf = np.asarray(dix.host_res_frag)
        tg = np.asarray(dix.host_topgrp_frag)
        hot = np.nonzero(rf >= 0)[0]
        f0 = int(hot[0])
        f1 = int(hot[np.argmax(tg[hot] != tg[f0])])
        assert tg[f1] != tg[f0], "no resident pair across top groups"
        a = int(np.nonzero(fa == f0)[0][0])
        b = int(np.nonzero(fa == f1)[0][0])
        for i in range(n):
            out.append((a, b))
    else:  # cross_frag
        valid = np.nonzero(fa >= 0)[0]
        f0 = fa[valid[0]]
        other = valid[np.argmax(fa[valid] != f0)]
        rf = getattr(dix, "host_res_frag", None)
        if rf is not None:
            # on a resident index, make sure the pair is NOT hot (it
            # would dispatch as cross_res, not cross_frag)
            rf = np.asarray(rf)
            cold = np.nonzero(rf[fa[valid]] < 0)[0]
            if cold.size:
                other = valid[cold[0]]
        for i in range(n):
            out.append((int(valid[0]), int(other)))
    assert len(out) == n, f"could not build {case} pairs"
    return np.asarray(out)


@pytest.mark.parametrize("case", QueryPlanner.CASES)
def test_batch_of_one(request, case):
    g, dix, planner = request.getfixturevalue(
        "world_res" if case == "cross_res" else "world")
    pairs = _pairs_of_case(g, dix, case, 1)
    _check(g, planner, pairs)
    counts = dict(planner.last_counts)
    assert counts[case] == 1
    assert sum(counts.values()) == 1


@pytest.mark.parametrize("case", QueryPlanner.CASES)
def test_single_case_batches(request, case):
    """A batch entirely of one case: the other sub-programs must
    not be dispatched at all (empty-bucket skip)."""
    g, dix, planner = request.getfixturevalue(
        "world_res" if case == "cross_res" else "world")
    pairs = _pairs_of_case(g, dix, case, 13)   # odd size -> pow2 pad
    _check(g, planner, pairs)
    for c, n in planner.last_counts.items():
        assert n == (13 if c == case else 0)


def test_empty_batch(world):
    g, dix, planner = world
    got = planner(np.empty(0, np.int32), np.empty(0, np.int32))
    assert got.shape == (0,)
    assert all(n == 0 for n in planner.last_counts.values())


@pytest.mark.parametrize("size", [1, 2, 3, 5, 17, 100])
def test_pow2_filler_never_leaks(world, size):
    """Non-pow2 batch sizes force filler slots; outputs must equal
    per-pair host Dijkstra regardless — including the degenerate query
    (0, 0) appearing *legitimately* inside the batch."""
    g, dix, planner = world
    rng = np.random.default_rng(size)
    pairs = rng.integers(0, g.n, size=(size, 2))
    pairs[0] = (0, 0)          # a real query identical to the filler
    got = _check(g, planner, pairs)
    assert got[0] == 0.0
    # padded sizes are pow2 internally but output length is exact
    assert got.shape == (size,)
    assert _pad_pow2(size) >= size


def test_self_queries_everywhere(world):
    g, dix, planner = world
    s = np.arange(0, g.n, 97, dtype=np.int32)
    got = planner(s, s)
    np.testing.assert_array_equal(got, np.zeros(s.size, np.float32))


def test_epoch_swap_reuses_compiled_programs(world):
    """set_index on a same-shaped index must not recompile any
    sub-program (epoch swaps are pointer flips, DESIGN.md §9)."""
    g, dix, planner = world
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, g.n, size=(32, 2))
    planner(pairs[:, 0], pairs[:, 1])            # compile at this size
    compiles_before = {c: fn._cache_size() for c, fn in
                       planner._fns.items()}
    planner.set_index(dix)                       # same epoch re-publish
    planner(pairs[:, 0], pairs[:, 1])
    compiles_after = {c: fn._cache_size() for c, fn in
                      planner._fns.items()}
    assert compiles_before == compiles_after
