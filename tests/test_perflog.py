"""repro.perflog coverage (append/read/latest round-trip, corruption
tolerance) and the serve driver's refresh-record shape — both were
previously exercised only by the smoke scripts, so a regression could
silently break the cross-PR perf trajectory the bench gate reads."""
import argparse
import json
import os

import numpy as np
import pytest

from repro import perflog
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like
from repro.launch.serve import REFRESHED_FIELDS, _update_loop


def test_roundtrip_and_latest(tmp_path):
    p = str(tmp_path / "bench.json")
    assert perflog.read_records(p) == []
    assert perflog.latest(p) is None
    perflog.append_records(p, [{"section": "serve", "graph": "g1",
                                "us_per_query": 10.0}])
    perflog.append_records(p, [{"section": "serve", "graph": "g2",
                                "us_per_query": 20.0},
                               {"section": "refresh", "graph": "g1",
                                "refresh_s": 0.5}])
    recs = perflog.read_records(p)
    assert len(recs) == 3
    assert recs[0]["graph"] == "g1"
    # latest() filters exactly and scans from the end
    assert perflog.latest(p, section="serve")["graph"] == "g2"
    assert perflog.latest(p, section="serve",
                          graph="g1")["us_per_query"] == 10.0
    assert perflog.latest(p, section="nope") is None
    # appends preserve prior records verbatim
    with open(p) as f:
        assert json.load(f) == recs


@pytest.mark.parametrize("content", [
    "{not json at all",                       # corrupt
    '{"a": 1}',                               # valid JSON, not a list
    "",                                       # empty file
])
def test_corrupt_file_degrades_to_empty(tmp_path, content):
    p = str(tmp_path / "bench.json")
    with open(p, "w") as f:
        f.write(content)
    assert perflog.read_records(p) == []
    assert perflog.latest(p, section="serve") is None
    # appending to a corrupt file starts a fresh history, not a crash
    perflog.append_records(p, [{"section": "serve"}])
    assert perflog.read_records(p) == [{"section": "serve"}]


def test_update_loop_record_shape():
    """serve.py's live-traffic loop: one record per update batch, with
    the schema the bench tooling and BENCH_serve.json history rely on —
    and array-exact parity between refresh and scratch rebuild
    (scratch_match covers every witness table via REFRESHED_FIELDS)."""
    g = road_like(300, seed=21)
    engine = EpochedEngine(g)
    args = argparse.Namespace(nodes=300, seed=21, batch_size=32,
                              validate=8, update_batches=1,
                              update_frac=0.03)
    records = _update_loop(engine, args, build_s=0.1)
    assert len(records) == 1
    rec = records[0]
    want_keys = {
        "section", "graph", "backend", "epoch", "update_frac",
        "refresh_s", "scratch_pipeline_s", "scratch_reweight_s",
        "refresh_over_scratch", "refresh_over_reweight",
        "initial_build_s", "post_refresh_mismatches", "scratch_match",
        "serve_batch_ms", "n_updates", "dirty_frags",
        "dirty_frag_frac", "dirty_pieces", "decrease_only",
        "stage_timings",
    }
    assert want_keys <= set(rec)
    # the per-stage refresh breakdown rides on every record
    # (DESIGN.md §16) — full dict, not just the total
    assert {"classify", "frag_fw", "super_fw", "hub", "pieces"} \
        <= set(rec["stage_timings"])
    assert rec["section"] == "refresh"
    assert rec["graph"] == "road300"
    assert rec["epoch"] == 1
    assert rec["post_refresh_mismatches"] == 0
    assert rec["scratch_match"] is True
    assert rec["refresh_s"] > 0
    assert json.dumps(rec)                   # JSON-serializable
    # the parity fields include the PR-3 witness tables
    assert {"frag_next", "super_next", "piece_next"} <= set(
        REFRESHED_FIELDS)
    assert np.isfinite(rec["refresh_over_scratch"])
