"""Hub-label hot tier tests (DESIGN.md §15).

The contract, in decreasing order of subtlety:

* **Exactness** — for every pair the gate (``QueryPlanner.hub_mask``)
  admits, the O(W) label merge returns the *identical* float the full
  planner contraction returns, which in turn equals the host float64
  Dijkstra oracle.  All three compare with ``==``: edge weights are
  integers, every distance sum is < 2**24 and hence exactly
  representable in f32, so re-associating the (min,+) sums — which the
  label composition does — cannot perturb a single bit.
* **Refresh ≡ rebuild** — after any scripted update sequence the
  incrementally refreshed hub tables are array-equal to a from-scratch
  build over the updated graph with the same pinned hub set.
* **Stale labels are never served** — a response produced after an
  epoch swap is computed against the *new* epoch's labels (the serving
  flush pins one snapshot; labels ride the DeviceIndex, so there is no
  separate label-invalidation protocol to get wrong), mirroring the
  EpochCache stale-entry lifecycle test.
* **Kernel parity** — the Pallas label-merge kernel (interpret mode on
  CPU) is bit-identical to the jnp reference, +inf padding included.
"""
import numpy as np
import pytest

from repro.core import dijkstra
from repro.core.device_engine import (build_device_index,
                                      index_fields_equal)
from repro.core.dist_engine import EpochedEngine
from repro.core.graph import road_like, traffic_updates
from repro.core.supergraph import reweight_index
from repro.kernels import ops
from repro.serving import ServingRuntime

HUB_FIELDS = ("hub_rows", "hub_of_agent")


def _hub_engine(n=520, seed=5, hl=2, n_hubs=96):
    g = road_like(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    hubs = rng.choice(g.n, min(n_hubs, g.n), replace=False)
    eng = EpochedEngine(g, hierarchy_levels=hl, hub_nodes=hubs)
    return eng, hubs


def _gated_pairs(eng, n_cand=600, seed=2):
    """(s, t, mask) over random candidates; callers assert mask.any()
    so a fixture change that silently kills the gate fails loudly."""
    rng = np.random.default_rng(seed)
    s = rng.integers(0, eng.g.n, n_cand).astype(np.int32)
    t = rng.integers(0, eng.g.n, n_cand).astype(np.int32)
    return s, t, eng.planner.hub_mask(s, t)


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------
def test_label_merge_kernel_matches_ref():
    rng = np.random.default_rng(0)
    labs = rng.integers(1, 2**20, (37, 300)).astype(np.float32)
    labt = rng.integers(1, 2**20, (37, 300)).astype(np.float32)
    # sprinkle +inf (unreachable hubs) including one all-inf row
    labs[rng.random(labs.shape) < 0.1] = np.inf
    labt[rng.random(labt.shape) < 0.1] = np.inf
    labs[5] = np.inf
    want = np.min(labs + labt, axis=1)
    ref = np.asarray(ops.label_merge(labs, labt, force="ref"))
    pal = np.asarray(ops.label_merge(labs, labt, force="pallas"))
    np.testing.assert_array_equal(ref, want)
    np.testing.assert_array_equal(pal, want)   # padding rows/lanes inert
    assert np.isinf(pal[5])


# ---------------------------------------------------------------------------
# exactness: label merge == planner == host Dijkstra, with ==
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hl,n", [(1, 420), (2, 520)])
def test_label_merge_matches_planner_and_dijkstra(hl, n):
    eng, hubs = _hub_engine(n=n, hl=hl)
    s, t, mask = _gated_pairs(eng)
    assert mask.any(), "gate admitted nothing — fixture too small"
    got = eng.planner.query_hub(s[mask], t[mask])
    ref = eng.planner.query(s[mask], t[mask])
    np.testing.assert_array_equal(got, ref)
    for i in np.nonzero(mask)[0][:24]:
        want = dijkstra.pair(eng.g, int(s[i]), int(t[i]))
        j = int(mask[:i].sum())
        assert float(got[j]) == want or \
            (np.isinf(want) and np.isinf(got[j])), \
            (int(s[i]), int(t[i]), float(got[j]), want)


def test_hub_mask_rejects_unlabeled_and_trivial_pairs():
    eng, hubs = _hub_engine()
    # labels cover AGENTS: a node not in the pinned set is still
    # servable when it routes through a labeled agent, so "unlabeled"
    # means its agent carries no label row
    hub_agent = eng.dix.host_hub_agent
    agent_of = np.asarray(eng.dix.agent_of)
    unlabeled = np.nonzero(hub_agent[agent_of] < 0)[0][:16] \
        .astype(np.int32)
    assert unlabeled.size == 16
    labeled = np.asarray(hubs[:16], np.int32)
    # one unlabeled endpoint -> never gated
    assert not eng.planner.hub_mask(unlabeled, labeled).any()
    assert not eng.planner.hub_mask(labeled, unlabeled).any()
    # s == t -> never gated (the planner's same-node case is free)
    assert not eng.planner.hub_mask(labeled, labeled).any()


# ---------------------------------------------------------------------------
# refresh ≡ rebuild across scripted updates
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hl", [1, 2])
def test_hub_refresh_equals_rebuild(hl):
    eng, _hubs = _hub_engine(n=460, seed=7, hl=hl)
    for r in range(3):
        u, v, w = traffic_updates(eng.g, 0.05, seed=31 + r)
        eng.apply_updates(u, v, w)
        sdix = build_device_index(
            reweight_index(eng.ix, eng.g),
            hierarchy_levels=eng.plan.hierarchy_levels,
            hub_nodes=eng.plan.hub_nodes)
        parity = index_fields_equal(eng.dix, sdix, HUB_FIELDS)
        assert all(parity.values()), (r, parity)
        # gated queries stay exact on the refreshed epoch
        s, t, mask = _gated_pairs(eng, seed=50 + r)
        if mask.any():
            got = eng.planner.query_hub(s[mask], t[mask])
            np.testing.assert_array_equal(
                got, eng.planner.query(s[mask], t[mask]))


def test_hub_carry_when_updates_miss_hub_fragments():
    """An update touching no hub fragment and no overlay entry must
    carry the label tables bit-identically (the refresh skip path) —
    and they must still equal the scratch rebuild."""
    eng, _hubs = _hub_engine(n=460, seed=9, hl=2)
    before = np.asarray(eng.dix.hub_rows).copy()
    # a pure no-op "update": republish identical weights on one edge
    u = eng.g.edge_u[:1]
    v = eng.g.edge_v[:1]
    w = eng.g.edge_w[:1]
    eng.apply_updates(u, v, w)
    np.testing.assert_array_equal(np.asarray(eng.dix.hub_rows), before)
    sdix = build_device_index(
        reweight_index(eng.ix, eng.g),
        hierarchy_levels=eng.plan.hierarchy_levels,
        hub_nodes=eng.plan.hub_nodes)
    assert all(index_fields_equal(eng.dix, sdix, HUB_FIELDS).values())


# ---------------------------------------------------------------------------
# serving lifecycle: tier attribution, stale labels never served
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def hub_engine():
    eng, _hubs = _hub_engine(n=520, seed=5, hl=2)
    eng.warmup(64)
    return eng


def test_runtime_label_tier_attribution(hub_engine):
    rt = ServingRuntime(hub_engine, max_batch=64, cache_size=256,
                        auto=False)
    s, t, mask = _gated_pairs(hub_engine)
    gi = np.nonzero(mask)[0]
    assert gi.size, "gate admitted nothing"
    pi = np.nonzero(~mask)[0]
    r_lab = rt.submit(int(s[gi[0]]), int(t[gi[0]]))
    r_pln = rt.submit(int(s[pi[0]]), int(t[pi[0]]))
    rt.flush()
    assert r_lab.tier == "label" and not r_lab.cached
    assert r_pln.tier == "planner" and not r_pln.cached
    # the hot pair now hits the cache, attributed to the cache tier
    r_hit = rt.submit(int(s[gi[0]]), int(t[gi[0]]))
    rt.flush()
    assert r_hit.tier == "cache" and r_hit.cached
    assert r_hit.dist == r_lab.dist
    st = rt.stats()
    assert st["label_hits"] == 1 and st["planner_dispatches"] == 1
    assert st["cache_hits"] == 1
    assert st["label_us_per_query"] > 0
    assert st["planner_us_per_query"] > 0


def test_stale_labels_never_served():
    """The label-tier replay of the EpochCache stale-entry lifecycle:
    a gated hot pair is served from the labels of epoch e, the epoch
    swaps underneath, and the next flush must serve it from e+1's
    labels — matching e+1's host oracle exactly, even when the update
    changed that pair's distance."""
    eng, _hubs = _hub_engine(n=520, seed=5, hl=2)
    rt = ServingRuntime(eng, max_batch=64, cache_size=0, auto=False)
    s, t, mask = _gated_pairs(eng)
    gi = np.nonzero(mask)[0]
    assert gi.size >= 4
    pairs = [(int(s[i]), int(t[i])) for i in gi[:4]]
    e0 = eng.snapshot()[0]
    r0 = [rt.submit(a, b) for a, b in pairs]
    rt.flush()
    for r, (a, b) in zip(r0, pairs):
        assert r.tier == "label" and r.epoch == e0
        assert r.dist == dijkstra.pair(eng.g, a, b)
    u, v, w = traffic_updates(eng.g, 0.08, seed=71)
    eng.apply_updates(u, v, w)
    e1, _dix, g1, _stale = eng.snapshot()
    assert e1 == e0 + 1
    r1 = [rt.submit(a, b) for a, b in pairs]
    rt.flush()
    changed = 0
    for r, old, (a, b) in zip(r1, r0, pairs):
        assert r.epoch == e1
        # still label-served (the gate depends on topology, not
        # weights) and exact against the NEW epoch's oracle
        assert r.tier == "label"
        assert r.dist == dijkstra.pair(g1, a, b)
        changed += r.dist != old.dist
    # the scripted 8% perturbation moves at least one hot distance, so
    # this test would catch labels frozen at e0 (not just re-tagged)
    assert changed > 0
