#!/usr/bin/env python
"""CI perf-regression gate for the serve path.

Runs the serve smoke with ``--json`` into a fresh records file, then
compares the fresh µs/query against the *median of the last N committed*
``BENCH_serve.json`` records for the same config (section/graph/mode/
backend/batch_size).  Fails (exit 1) when the fresh number exceeds
``--factor`` x that median — 2.5x by default, deliberately loose because
shared CI runners are noisy; the gate exists to catch order-of-magnitude
mistakes (an accidental [q, mb, mb] materialization, a recompile in the
serving loop), not 10% drift.  The median-of-history baseline makes one
slow committed record unable to poison the gate in either direction.

``--live`` gates the *online* serving runtime instead: a short open-loop
``serve.py --live`` run with concurrent refresh, compared on p99 latency
against committed ``section: "serve_live"`` records of the same config
(graph/backend/mix/rate/cache/refresh — a separate section key, so the
offline-serve and live-serve histories never mix).  Same 2.5x median
rule; the run also re-asserts the per-epoch oracle check, so the gate
doubles as a consistency smoke.

``--refresh`` gates the concurrent-refresh path (``section:
"serve_refresh"``, emitted by every ``--live`` run that refreshes):
BOTH the refresh wall time (``refresh_max_s``) and the longest
foreground serving gap (``max_serving_gap_ms``) must stay within
``--factor`` x their committed medians — the second metric is the
stop-the-world detector, failing long before wall time moves if a
change re-serializes refresh against the serving flushes.

``--host-build`` gates the staged host preprocessing pipeline
(``section: "host_build"``, emitted by every planner-mode serve run)
on wall seconds, keyed (section, graph) — same 2.5x median rule.  It
catches a host build stage quietly regressing to a Python-loop
implementation long before any serve-path number moves.

Every fresh ``serve_live`` record must additionally carry the per-tier
serving fields (``cache_hits`` / ``label_hits`` /
``planner_dispatches`` plus the per-tier latencies, DESIGN.md §15); a
record missing them fails loudly — committed history predating the hot
tier is grandfathered, fresh runs are not.  The same rule covers the
histogram-latency fields (DESIGN.md §16): a fresh ``serve_live`` record
must report p50/p95/p99 derived from the runtime's streaming latency
histogram (``latency_source == "histogram"``, with ``latency_n``
observations), so the gated p99 is the same bounded-memory number a
production metrics scraper would read.

    python scripts/bench_gate.py                         # CI invocation
    python scripts/bench_gate.py --live                  # live-serve p99 gate
    python scripts/bench_gate.py --refresh               # refresh + gap gate
    python scripts/bench_gate.py --inject-slowdown 10    # self-test: the
        fresh measurement is multiplied by 10x, which MUST fail the gate

The fresh records file (``--fresh``) is uploaded as a workflow artifact
by CI so the cross-run trajectory is inspectable without committing
noisy runner numbers to the repo history.

With no matching history (new graph/mode/backend config) the gate warns
and passes: a config's first record cannot regress against itself.
"""
from __future__ import annotations

import argparse
import os
import statistics
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def ensure_distinct_files(fresh: str, history: str) -> None:
    """The fresh run's records file and the committed history must be
    different files: if they alias, the fresh record would land in the
    history *before* the median is taken and be included in its own
    baseline — a gate that can never fail.  Checked up front, loudly.
    """
    if os.path.realpath(fresh) == os.path.realpath(history):
        raise SystemExit(
            f"bench_gate: --fresh and --history resolve to the same "
            f"file ({os.path.realpath(fresh)}); the fresh record would "
            "be included in its own median baseline")


def history_window(records: list, match: dict, metric: str,
                   last: int) -> list:
    """The metric values of the last ``last`` committed records
    matching ``match`` — with malformed records failing LOUDLY.

    Three malformation classes would otherwise silently shrink (or
    worse, mix) the window: a record with no ``section`` field cannot
    be classified into the offline-serve vs serve_live histories at
    all (their metrics have different units — µs/query vs ms p99 — so
    a misclassified record poisons the median); a record with no
    ``graph`` field cannot be keyed to a graph scale, and the
    (section, graph) pair IS the history key — a road64k µs/query
    landing in the road4000 window would inflate the median ~400x and
    mask any road4000 regression; and a record that matches every
    identity key but lacks a numeric ``metric`` is a half-written
    entry that used to just vanish from the window.
    """
    window = []
    for i, rec in enumerate(records):
        if not isinstance(rec, dict) or "section" not in rec:
            raise SystemExit(
                f"bench_gate: malformed history record #{i}: no "
                f"'section' field (cannot classify offline vs live, "
                f"units would mix): {rec!r}")
        if "graph" not in rec:
            raise SystemExit(
                f"bench_gate: malformed history record #{i}: no "
                f"'graph' field (road4000 and road64k histories would "
                f"mix — scales differ by orders of magnitude): {rec!r}")
        if not all(rec.get(k) == v for k, v in match.items()):
            continue
        val = rec.get(metric)
        if isinstance(val, bool) or not isinstance(val, (int, float)):
            raise SystemExit(
                f"bench_gate: history record #{i} matches "
                f"{match} but has no numeric {metric!r}: {val!r}")
        window.append(val)
    return window[-last:]


# per-tier serving fields (DESIGN.md §15) every FRESH serve_live
# record must carry; committed pre-hot-tier history is grandfathered —
# the check runs on fresh records only, so old records stay readable
# while a runtime that stops attributing responses per tier fails here
TIER_FIELDS = ("cache_hits", "label_hits", "planner_dispatches",
               "label_us_per_query", "planner_us_per_query",
               "label_hit_rate", "hub_budget")


def require_tier_fields(rec: dict) -> None:
    missing = [f for f in TIER_FIELDS if f not in rec]
    if missing:
        raise SystemExit(
            f"bench_gate: fresh serve_live record is missing per-tier "
            f"fields {missing} — the serving runtime no longer "
            "attributes responses to cache/label/planner tiers")


# histogram-provenance fields (DESIGN.md §16) every FRESH serve_live
# record must carry: the gated p99_ms comes from the runtime's streaming
# latency histogram, and latency_source/latency_n say so explicitly.
# Same grandfathering rule as TIER_FIELDS — committed pre-§16 history
# stays readable, a fresh run that stops reporting histogram-derived
# percentiles (or silently falls back to the sampled path) fails here.
HIST_FIELDS = ("p50_ms", "p95_ms", "p99_ms", "latency_source",
               "latency_n")


def require_hist_fields(rec: dict) -> None:
    missing = [f for f in HIST_FIELDS if f not in rec]
    if missing:
        raise SystemExit(
            f"bench_gate: fresh serve_live record is missing "
            f"histogram-latency fields {missing} — the load report no "
            "longer carries streaming-histogram percentiles "
            "(DESIGN.md §16)")
    if rec.get("latency_source") != "histogram":
        raise SystemExit(
            f"bench_gate: fresh serve_live record has latency_source="
            f"{rec.get('latency_source')!r}, not 'histogram' — the "
            "runtime's streaming latency histogram missed requests and "
            "the report fell back to the sampled path")


def _run_serve_cmd(args, extra: list, record_filter: dict) -> dict:
    """Run the serve driver as a subprocess with ``extra`` flags and
    return the fresh record matching ``record_filter`` (or die)."""
    from repro.perflog import latest

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--nodes", str(args.nodes),
           "--validate", str(args.validate),
           "--json", args.fresh] + extra
    print("bench_gate: running", " ".join(cmd), flush=True)
    subprocess.run(cmd, check=True, cwd=REPO, env=env)
    rec = latest(args.fresh, graph=f"road{args.nodes}",
                 **record_filter)
    if rec is None:
        raise SystemExit(
            f"bench_gate: serve run produced no "
            f"{record_filter.get('section')} record")
    return rec


def run_serve(args) -> dict:
    """Run the serve smoke as a subprocess, return its fresh record."""
    return _run_serve_cmd(
        args,
        ["--batches", str(args.batches),
         "--batch-size", str(args.batch_size), "--mode", args.mode],
        {"section": "serve", "mode": args.mode,
         "batch_size": args.batch_size})


def run_live(args) -> dict:
    """Run the live-serving smoke as a subprocess, return its fresh
    ``serve_live`` record (which must carry the per-tier fields)."""
    rec = _run_serve_cmd(
        args,
        ["--live", "--rate", str(args.rate),
         "--live-seconds", str(args.live_seconds), "--mix", args.mix,
         "--live-update-batches", str(args.live_update_batches)],
        {"section": "serve_live", "mix": args.mix,
         "rate_qps": args.rate})
    require_tier_fields(rec)
    require_hist_fields(rec)
    return rec


def run_refresh(args) -> dict:
    """Run the live smoke WITH concurrent refresh and return its fresh
    ``serve_refresh`` record (the per-run refresh/staleness summary the
    driver emits alongside ``serve_live``)."""
    from repro.perflog import latest

    rec = _run_serve_cmd(
        args,
        ["--live", "--rate", str(args.rate),
         "--live-seconds", str(args.live_seconds), "--mix", args.mix,
         "--live-update-batches",
         str(max(1, args.live_update_batches))],
        {"section": "serve_refresh", "mix": args.mix,
         "rate_qps": args.rate})
    # the same run emitted a serve_live record — hold it to the same
    # per-tier field contract even when only the refresh path is gated
    live_rec = latest(args.fresh, graph=f"road{args.nodes}",
                      section="serve_live")
    if live_rec is not None:
        require_tier_fields(live_rec)
        require_hist_fields(live_rec)
    return rec


def run_host_build(args) -> dict:
    """Run a minimal serve smoke and return its fresh ``host_build``
    record — the staged host preprocessing pipeline's wall seconds
    (DESIGN.md §17), emitted by every planner-mode serve run."""
    return _run_serve_cmd(
        args,
        ["--batches", "1", "--batch-size", "256",
         "--build-workers", str(args.build_workers)],
        {"section": "host_build",
         "build_workers": args.build_workers})


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default=os.path.join(
        REPO, "BENCH_serve.json"),
        help="committed perf-record history to gate against")
    ap.add_argument("--fresh", default=os.path.join(
        REPO, "bench_gate_fresh.json"),
        help="where the fresh run's records land (CI artifact)")
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=16)
    ap.add_argument("--mode", default="planner")
    ap.add_argument("--last", type=int, default=5,
                    help="history records to take the median over")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR",
                                                 "2.5")),
                    help="fail when fresh > factor * median(history); "
                         "overridable via BENCH_GATE_FACTOR (the "
                         "committed baseline is machine-relative — if "
                         "a CI runner class is uniformly slower than "
                         "the recording machine, widen the factor or "
                         "commit a runner-measured record rather than "
                         "deleting the gate)")
    ap.add_argument("--inject-slowdown", type=float, default=1.0,
                    help="multiply the fresh measurement (gate "
                         "self-test hook; >= factor must fail)")
    live = ap.add_argument_group("live-serve gate (--live)")
    live.add_argument("--live", action="store_true",
                      help="gate the online serving runtime's p99 "
                           "latency (section serve_live) instead of "
                           "the offline us/query")
    live.add_argument("--rate", type=float, default=500.0,
                      help="offered qps for the live smoke")
    live.add_argument("--live-seconds", type=float, default=3.0)
    live.add_argument("--mix", default="zipf")
    live.add_argument("--live-update-batches", type=int, default=1,
                      help="concurrent refresh rounds during the "
                           "live smoke")
    hb = ap.add_argument_group("host-build gate (--host-build)")
    hb.add_argument("--host-build", action="store_true",
                    help="gate the staged host preprocessing pipeline "
                         "(section host_build) on wall seconds, keyed "
                         "(section, graph) — same median rule; catches "
                         "a host stage regressing to a Python loop "
                         "long before the serve numbers move")
    hb.add_argument("--build-workers", type=int, default=2,
                    help="cover workers for the host-build smoke")
    live.add_argument("--refresh", action="store_true",
                      help="gate the concurrent-refresh path (section "
                           "serve_refresh) instead: refresh wall time "
                           "(refresh_max_s) AND the longest foreground "
                           "serving gap (max_serving_gap_ms) both gate "
                           "against their committed medians")
    args = ap.parse_args()

    from repro.perflog import read_records

    ensure_distinct_files(args.fresh, args.history)
    if args.host_build:
        fresh = run_host_build(args)
        checks = [("wall_s", "s host build")]
        # keyed (section, graph) only: the serial-parity contract makes
        # the worker count a non-identity knob — every worker setting
        # must stay within the factor of the committed wall time
        match = {"section": "host_build", "graph": f"road{args.nodes}"}
        desc = f"road{args.nodes}/host_build"
    elif args.refresh:
        fresh = run_refresh(args)
        # two metrics gate together: the refresh must not get slower
        # AND the foreground must keep serving while it runs (a
        # regression to stop-the-world shows up as a huge serving gap
        # long before refresh wall time moves)
        checks = [("refresh_max_s", "s refresh"),
                  ("max_serving_gap_ms", "ms gap")]
        match = {"section": "serve_refresh",
                 "graph": f"road{args.nodes}",
                 "backend": fresh.get("backend"), "mix": args.mix,
                 "rate_qps": args.rate,
                 "pipelined": fresh.get("pipelined")}
        desc = (f"road{args.nodes}/refresh/{args.mix}"
                f"@{args.rate:.0f}qps/"
                f"pipelined={fresh.get('pipelined')}/"
                f"{fresh.get('backend')}")
    elif args.live:
        fresh = run_live(args)
        checks = [("p99_ms", "ms p99")]
        # separate section + config key: live histories never mix with
        # offline serve records or with differently-shaped live runs
        match = {"section": "serve_live", "graph": f"road{args.nodes}",
                 "backend": fresh.get("backend"), "mix": args.mix,
                 "rate_qps": args.rate, "cache": fresh.get("cache"),
                 "refresh": fresh.get("refresh")}
        desc = (f"road{args.nodes}/live/{args.mix}@{args.rate:.0f}qps/"
                f"cache={fresh.get('cache')}/"
                f"refresh={fresh.get('refresh')}/"
                f"{fresh.get('backend')}")
    else:
        fresh = run_serve(args)
        checks = [("us_per_query", "us/query")]
        match = {"section": "serve", "graph": f"road{args.nodes}",
                 "mode": args.mode, "backend": fresh.get("backend"),
                 "batch_size": args.batch_size}
        desc = (f"road{args.nodes}/{args.mode}/{fresh.get('backend')}/"
                f"b{args.batch_size}")

    history = read_records(args.history)
    failed = 0
    for metric, unit in checks:
        fresh_val = fresh[metric] * args.inject_slowdown
        if args.inject_slowdown != 1.0:
            print(f"bench_gate: INJECTED {args.inject_slowdown}x "
                  f"slowdown ({fresh[metric]} -> {fresh_val:.3f}{unit})")
        window = history_window(history, match, metric, args.last)
        if not window:
            print(f"bench_gate: PASS [{metric}] (no committed history "
                  f"for {desc} in {args.history}; nothing to regress "
                  f"against)")
            continue
        baseline = statistics.median(window)
        limit = args.factor * baseline
        print(f"bench_gate: [{metric}] fresh {fresh_val:.3f}{unit} vs "
              f"median of last {len(window)} committed records "
              f"{baseline:.3f}{unit} (limit {limit:.3f} = "
              f"{args.factor}x)")
        if fresh_val > limit:
            print(f"bench_gate: FAIL — [{metric}] {fresh_val:.3f}{unit} "
                  f"is {fresh_val / baseline:.2f}x the committed "
                  f"median (allowed {args.factor}x)")
            failed = 1
        else:
            print(f"bench_gate: PASS [{metric}]")
    return failed


if __name__ == "__main__":
    sys.exit(main())
