#!/usr/bin/env python
"""CI perf-regression gate for the serve path.

Runs the serve smoke with ``--json`` into a fresh records file, then
compares the fresh µs/query against the *median of the last N committed*
``BENCH_serve.json`` records for the same config (section/graph/mode/
backend/batch_size).  Fails (exit 1) when the fresh number exceeds
``--factor`` x that median — 2.5x by default, deliberately loose because
shared CI runners are noisy; the gate exists to catch order-of-magnitude
mistakes (an accidental [q, mb, mb] materialization, a recompile in the
serving loop), not 10% drift.  The median-of-history baseline makes one
slow committed record unable to poison the gate in either direction.

    python scripts/bench_gate.py                         # CI invocation
    python scripts/bench_gate.py --inject-slowdown 10    # self-test: the
        fresh measurement is multiplied by 10x, which MUST fail the gate

The fresh records file (``--fresh``) is uploaded as a workflow artifact
by CI so the cross-run trajectory is inspectable without committing
noisy runner numbers to the repo history.

With no matching history (new graph/mode/backend config) the gate warns
and passes: a config's first record cannot regress against itself.
"""
from __future__ import annotations

import argparse
import os
import statistics
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def run_serve(args) -> dict:
    """Run the serve smoke as a subprocess, return its fresh record."""
    from repro.perflog import latest

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.launch.serve",
           "--nodes", str(args.nodes), "--batches", str(args.batches),
           "--batch-size", str(args.batch_size), "--mode", args.mode,
           "--validate", str(args.validate), "--json", args.fresh]
    print("bench_gate: running", " ".join(cmd), flush=True)
    subprocess.run(cmd, check=True, cwd=REPO, env=env)
    rec = latest(args.fresh, section="serve", graph=f"road{args.nodes}",
                 mode=args.mode, batch_size=args.batch_size)
    if rec is None:
        raise SystemExit("bench_gate: serve run produced no record")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", default=os.path.join(
        REPO, "BENCH_serve.json"),
        help="committed perf-record history to gate against")
    ap.add_argument("--fresh", default=os.path.join(
        REPO, "bench_gate_fresh.json"),
        help="where the fresh run's records land (CI artifact)")
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=16)
    ap.add_argument("--mode", default="planner")
    ap.add_argument("--last", type=int, default=5,
                    help="history records to take the median over")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get("BENCH_GATE_FACTOR",
                                                 "2.5")),
                    help="fail when fresh > factor * median(history); "
                         "overridable via BENCH_GATE_FACTOR (the "
                         "committed baseline is machine-relative — if "
                         "a CI runner class is uniformly slower than "
                         "the recording machine, widen the factor or "
                         "commit a runner-measured record rather than "
                         "deleting the gate)")
    ap.add_argument("--inject-slowdown", type=float, default=1.0,
                    help="multiply the fresh measurement (gate "
                         "self-test hook; >= factor must fail)")
    args = ap.parse_args()

    from repro.perflog import read_records

    fresh = run_serve(args)
    fresh_us = fresh["us_per_query"] * args.inject_slowdown
    if args.inject_slowdown != 1.0:
        print(f"bench_gate: INJECTED {args.inject_slowdown}x slowdown "
              f"({fresh['us_per_query']} -> {fresh_us:.3f}us/query)")

    hist = [r for r in read_records(args.history)
            if r.get("section") == "serve"
            and r.get("graph") == f"road{args.nodes}"
            and r.get("mode") == args.mode
            and r.get("backend") == fresh.get("backend")
            and r.get("batch_size") == args.batch_size
            and isinstance(r.get("us_per_query"), (int, float))]
    if not hist:
        print(f"bench_gate: PASS (no committed history for "
              f"road{args.nodes}/{args.mode}/{fresh.get('backend')}/"
              f"b{args.batch_size} in {args.history}; nothing to "
              "regress against)")
        return 0
    window = [r["us_per_query"] for r in hist[-args.last:]]
    baseline = statistics.median(window)
    limit = args.factor * baseline
    print(f"bench_gate: fresh {fresh_us:.3f}us/query vs median of last "
          f"{len(window)} committed records {baseline:.3f}us/query "
          f"(limit {limit:.3f} = {args.factor}x)")
    if fresh_us > limit:
        print(f"bench_gate: FAIL — {fresh_us:.3f}us/query is "
              f"{fresh_us / baseline:.2f}x the committed median "
              f"(allowed {args.factor}x)")
        return 1
    print("bench_gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
