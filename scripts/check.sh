#!/usr/bin/env bash
# Tier-1 gate + smokes, the one command a PR must keep green (and the
# single CI entry point, .github/workflows/ci.yml):
#   bash scripts/check.sh [--fast]
# --fast skips the pytest suite (smokes only).
#
# Every stage runs with its exit code captured explicitly; a failing
# stage marks the whole run failed but later stages still execute, and
# the script's own exit code aggregates them — `set -e` alone is not
# relied on for the smoke invocations (a non-final failing stage must
# not be maskable by a later passing one, and CI needs the non-zero
# code propagated).
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

fail=0
failed_stages=()

run_stage() {
    local name="$1"
    shift
    echo "== ${name} =="
    if "$@"; then
        echo "-- ${name}: OK"
    else
        local rc=$?
        echo "-- ${name}: FAILED (exit ${rc})"
        fail=1
        failed_stages+=("${name}")
    fi
}

# Lint first (cheapest signal).  ruff is a CI dependency, not a
# container one — skip gracefully where it isn't installed.
if command -v ruff >/dev/null 2>&1; then
    run_stage "ruff lint" ruff check .
else
    echo "== ruff lint =="
    echo "-- ruff lint: SKIPPED (ruff not installed)"
fi

if [[ "${1:-}" != "--fast" ]]; then
    # no -x: CI should report ALL failures, not stop at the first;
    # --durations surfaces the slowest tests so suite growth stays
    # accountable
    run_stage "tier-1 tests" python -m pytest -q --durations=10
fi

run_stage "serve smoke (2k nodes, CPU, validated)" \
    python -m repro.launch.serve --nodes 2000 --batches 2 \
    --batch-size 256 --validate 64 --json ""

run_stage "live-traffic refresh smoke" \
    python -m repro.launch.serve --nodes 2000 --batches 1 \
    --batch-size 256 --validate 32 --update-batches 1 \
    --update-frac 0.02 --json ""

# Staged host build (DESIGN.md §17): the worker-parallel cover build
# must be array-equal to the serial build on every index table —
# --check-build-parity rebuilds serially in-run and diffs, failing
# loudly on the first diverging field.
run_stage "host-build parity smoke (road4000, 2 workers)" \
    python -m repro.launch.serve --nodes 4000 --batches 1 \
    --batch-size 256 --validate 16 --build-workers 2 \
    --check-build-parity --json ""

# --metrics-out/--trace-out exercise the observability exporters
# (DESIGN.md §16) end to end on every check run; CI uploads the
# resulting snapshot + Chrome trace as workflow artifacts (ci.yml)
run_stage "live serving smoke (open-loop + concurrent refresh)" \
    python -m repro.launch.serve --nodes 2000 --live --rate 400 \
    --live-seconds 2 --mix zipf --live-update-batches 1 \
    --validate 24 --json "" \
    --metrics-out obs_metrics.json --trace-out obs_trace.json

# Scale smoke (DESIGN.md §12/§13): road64k must build the deep
# overlay — --expect-hierarchy 3 fails the run if the build
# silently falls back to two levels (or the dense closure sneaks
# back in) — with a multilevel partition whose level-2 boundary is
# at most 0.5*S (--max-s2-ratio, the partitioner-quality gate;
# measured ~0.45, the floor set by road_like's highway shortcuts),
# and serve with sampled Dijkstra parity.  The long pole of a full check
# run (minutes of device FW), so CHECK_SKIP_SCALE=1 skips it for
# quick local iteration; CI runs it as a dedicated once-per-matrix
# step (ci.yml) rather than on every leg.
if [[ "${CHECK_SKIP_SCALE:-}" != "1" ]]; then
    run_stage "scale smoke (road64k, hierarchical overlay, validated)" \
        python -m repro.launch.serve --graph road64k --batches 1 \
        --batch-size 256 --validate 8 --update-batches 0 \
        --expect-hierarchy 3 --max-s2-ratio 0.5 --json ""
    # Live serving under concurrent refresh at scale (DESIGN.md §14):
    # the foreground must keep completing responses while a 2% update
    # batch re-closes through the pipeline — --max-serving-gap fails
    # the run on the longest response-completion gap, which is exactly
    # where a stop-the-world re-close shows up (the road64k refresh
    # wall is ~4 min; a blocked foreground gaps that long, while the
    # pipelined path measures ~8s worst-case flush-under-contention,
    # so 15s separates the two regimes with CI-machine margin).
    # 1024-cap flushes and 60 qps keep serving under capacity at this
    # scale (flushes are seconds each while refresh hogs the cores).
    # Responses carry staleness tags; sampled epochs oracle-validated.
    # --hub-budget pins hub labels for the Zipf pool's head (the hot
    # tier, DESIGN.md §15); --hot-tier fails the run unless the label
    # merge served at least 10% of cache misses — the floor is set by
    # the gate's cross-TOP-group requirement on a 2048-pair pool, so a
    # selection or gating regression drops straight through it.
    run_stage "scale live smoke (road64k, pipelined refresh, gap-gated)" \
        python -m repro.launch.serve --graph road64k --live \
        --rate 60 --live-seconds 8 --mix zipf --live-batch 1024 \
        --live-update-batches 1 --update-frac 0.02 \
        --live-update-every 2 --live-pipelined \
        --hub-budget 2048 --hot-tier 0.10 \
        --max-serving-gap 15 --validate 8 --json ""
else
    echo "== scale smoke (road64k) =="
    echo "-- scale smoke: SKIPPED (CHECK_SKIP_SCALE=1)"
fi

run_stage "quickstart" python examples/quickstart.py

if [[ ${fail} -ne 0 ]]; then
    echo "CHECKS FAILED: ${failed_stages[*]}"
    exit 1
fi
echo "ALL CHECKS PASSED"
