#!/usr/bin/env bash
# Tier-1 gate + serve smoke, the one command a PR must keep green:
#   bash scripts/check.sh [--fast]
# --fast skips the pytest suite (smokes only).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--fast" ]]; then
    echo "== tier-1 tests =="
    python -m pytest -x -q
fi

echo "== serve smoke (2k nodes, CPU, validated) =="
python -m repro.launch.serve --nodes 2000 --batches 2 --batch-size 256 \
    --validate 64 --json ""

echo "== quickstart =="
python examples/quickstart.py

echo "ALL CHECKS PASSED"
