#!/usr/bin/env python
"""Measure the observability overhead budget (DESIGN.md §16): live
serving qps with the full observability stack ON (tracing spans +
periodic metrics exporter + Prometheus HTTP endpoint) vs OFF
(registry counters only — those are always on), on the same engine.

The two arms run interleaved repeats of the same open-loop zipf load
(same pair pool, same seeds) against fresh runtimes; each arm scores
its best achieved qps (min-of-noise via max-of-repeats) and the
overhead fraction is ``1 - qps_on / qps_off``.  The run appends a
``section: "obs_overhead"`` record to the perf history and exits
non-zero when the overhead exceeds ``--budget`` (2% by default) — the
acceptance gate that keeps "observability is near-free" a measured
claim instead of a doc sentence.

    python scripts/obs_overhead.py                    # road4000, 2% budget
    python scripts/obs_overhead.py --nodes 1000 --seconds 2 --repeats 2
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def run_phase(engine, pairs, args, traced: bool, rep: int) -> float:
    """One load phase against a fresh runtime; returns achieved qps."""
    from repro.obs import MetricsExporter, MetricsServer, trace
    from repro.serving import ServingRuntime, run_load

    tr = trace.get_tracer()
    handles = []
    rt = ServingRuntime(engine, max_batch=args.live_batch,
                        cache_size=args.cache_size)
    rt.warmup()
    if traced:
        tr.clear()
        tr.enable()
        out = os.path.join(tempfile.gettempdir(),
                           f"obs_overhead_{os.getpid()}.json")
        handles.append(MetricsExporter(rt.registry, out,
                                       interval_s=0.5).start())
        handles.append(MetricsServer(rt.registry, port=0).start())
    try:
        report = run_load(rt, pairs, rate_qps=args.rate,
                          seed=args.seed + rep)
    finally:
        rt.close()
        for h in handles:
            h.stop()
        if traced:
            tr.enable(False)
            tr.clear()
    arm = "on " if traced else "off"
    print(f"  rep {rep} obs={arm}: {report.achieved_qps:8.1f} qps "
          f"achieved (p99 {report.p99_ms}ms, "
          f"{report.latency_source})", flush=True)
    return report.achieved_qps


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="offered qps (kept above capacity so achieved "
                         "qps measures throughput, not the clock)")
    ap.add_argument("--seconds", type=float, default=3.0)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--live-batch", type=int, default=256)
    ap.add_argument("--cache-size", type=int, default=65536)
    ap.add_argument("--mix", default="zipf")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget", type=float, default=0.02,
                    help="max tolerated overhead fraction (fail above)")
    ap.add_argument("--json", default=os.path.join(REPO,
                                                   "BENCH_serve.json"),
                    help="perf history to append the record to "
                         "('' skips)")
    args = ap.parse_args()

    import jax

    from repro.core.dist_engine import EpochedEngine
    from repro.core.graph import road_like
    from repro.data.queries import workload_pairs

    print(f"building road{args.nodes} engine "
          f"(backend {jax.default_backend()})...", flush=True)
    g = road_like(args.nodes, seed=args.seed)
    engine = EpochedEngine(g)
    engine.warmup(args.live_batch)
    n = max(1, int(round(args.rate * args.seconds)))
    pairs = workload_pairs(g, args.mix, n, seed=args.seed + 4)
    print(f"A-B: {n} {args.mix} requests at {args.rate:.0f} qps "
          f"offered, {args.repeats} interleaved repeats per arm")

    qps_off, qps_on = [], []
    for rep in range(args.repeats):
        qps_off.append(run_phase(engine, pairs, args, False, rep))
        qps_on.append(run_phase(engine, pairs, args, True, rep))
    best_off, best_on = max(qps_off), max(qps_on)
    overhead = 1.0 - best_on / best_off
    print(f"obs_overhead: road{args.nodes} qps off={best_off:.1f} "
          f"on={best_on:.1f} overhead={overhead * 100:.2f}% "
          f"(budget {args.budget * 100:.1f}%)")

    if args.json:
        from repro.perflog import append_records
        append_records(args.json, [{
            "section": "obs_overhead",
            "graph": f"road{args.nodes}",
            "backend": jax.default_backend(),
            "mix": args.mix,
            "rate_qps": args.rate,
            "n_requests": n,
            "repeats": args.repeats,
            "qps_off": round(best_off, 1),
            "qps_on": round(best_on, 1),
            "overhead_frac": round(overhead, 4),
            "budget_frac": args.budget,
        }])
        print(f"obs_overhead: recorded in {args.json}")

    if overhead > args.budget:
        print(f"obs_overhead: FAIL — {overhead * 100:.2f}% exceeds "
              f"the {args.budget * 100:.1f}% budget")
        return 1
    print("obs_overhead: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
