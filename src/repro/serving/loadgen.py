"""Open-loop load harness for the serving runtime (DESIGN.md §11).

Open-loop means arrivals follow their own clock (Poisson process at
``rate_qps``), *not* the server's: when the runtime falls behind, the
generator keeps submitting and queueing delay lands in the measured
latency — the standard way to see tail behavior that closed-loop
(wait-for-response) drivers structurally hide.

Query mixes come from ``data/queries.py`` (``workload_pairs``):
``uniform`` endpoints, ``zipf`` hot-pair skew (exercises the result
cache), ``geo`` spatially-local pairs (exercises same-fragment planner
buckets).  The run report carries p50/p95/p99 latency, offered vs
achieved qps, cache hit rate, and the batch-occupancy histogram,
shaped for ``repro.perflog``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .runtime import ServingRuntime
from .scheduler import Request


@dataclass
class LoadReport:
    """One load phase's results; ``as_record()`` is perflog-shaped."""
    n_requests: int
    offered_qps: float
    achieved_qps: float
    wall_s: float
    p50_ms: float
    p95_ms: float
    p99_ms: float
    mean_ms: float
    max_ms: float
    # serving-gap / staleness observability (DESIGN.md §14): the
    # longest completion-time gap between consecutive responses (a
    # stop-the-world refresh shows up here as one huge gap), how many
    # responses were served from a mid-pipeline epoch, and the worst
    # batch lag any response carried
    max_serving_gap_ms: float = 0.0
    stale_responses: int = 0
    max_staleness_batches: int = 0
    # provenance of the reported percentiles (DESIGN.md §16): "histogram"
    # means p50/p95/p99 come from the runtime's streaming latency
    # histogram scoped to this phase (n = latency_n observations);
    # "sampled" is the pre-§16 sorted-request-list fallback
    latency_source: str = "histogram"
    latency_n: int = 0
    runtime_stats: dict = field(default_factory=dict)
    requests: list = field(default_factory=list, repr=False)

    def as_record(self) -> dict:
        return {
            "n_requests": self.n_requests,
            "offered_qps": round(self.offered_qps, 1),
            "achieved_qps": round(self.achieved_qps, 1),
            "p50_ms": self.p50_ms,
            "p95_ms": self.p95_ms,
            "p99_ms": self.p99_ms,
            "mean_ms": self.mean_ms,
            "max_ms": self.max_ms,
            "latency_source": self.latency_source,
            "latency_n": self.latency_n,
            "max_serving_gap_ms": self.max_serving_gap_ms,
            "stale_responses": self.stale_responses,
            "max_staleness_batches": self.max_staleness_batches,
            **self.runtime_stats,
        }


def _percentiles(lat_ms: np.ndarray, hist_window=None) -> dict:
    """Latency fields for the report.  p50/p95/p99 come from the
    runtime's streaming histogram scoped to this phase (``hist_window``,
    a ``HistogramSnapshot``) when it saw every request — the same
    bounded-memory numbers a production scraper reads, within one 5%
    bucket of exact.  mean/max stay exact from the request list, and
    the list is also the fallback when no histogram is available."""
    if hist_window is not None and hist_window.count == len(lat_ms) \
            and hist_window.count > 0:
        return {
            "p50_ms": round(hist_window.percentile(50) * 1e3, 3),
            "p95_ms": round(hist_window.percentile(95) * 1e3, 3),
            "p99_ms": round(hist_window.percentile(99) * 1e3, 3),
            "mean_ms": round(float(lat_ms.mean()), 3),
            "max_ms": round(float(lat_ms.max()), 3),
            "latency_source": "histogram",
            "latency_n": hist_window.count,
        }
    return {
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p95_ms": round(float(np.percentile(lat_ms, 95)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_ms": round(float(lat_ms.mean()), 3),
        "max_ms": round(float(lat_ms.max()), 3),
        "latency_source": "sampled",
        "latency_n": int(len(lat_ms)),
    }


def run_load(runtime: ServingRuntime, pairs: np.ndarray, *,
             rate_qps: float, seed: int = 0,
             wait_timeout_s: float = 60.0) -> LoadReport:
    """Drive ``pairs`` ([n, 2]) through the runtime as an open-loop
    Poisson stream at ``rate_qps``; blocks until every response lands.

    Arrival times are pre-drawn (exponential inter-arrivals); a
    generator running behind schedule submits immediately rather than
    shedding, so the offered load is honored and overload shows up as
    queueing latency, not as a silently lower rate.
    """
    rng = np.random.default_rng(seed)
    n = len(pairs)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=n))
    reqs: list[Request] = []
    # scope the runtime's streaming latency histogram to this phase:
    # freeze before the first submit, diff after the last response
    hist = getattr(runtime, "latency_histogram", lambda: None)()
    h0 = hist.freeze() if hist is not None else None
    t0 = time.perf_counter()
    for i in range(n):
        delay = arrivals[i] - (time.perf_counter() - t0)
        if delay > 0:
            time.sleep(delay)
        # the scheduled arrival rides on the request itself, so every
        # consumer of its latency — cache hit or device miss — shares
        # the open-loop basis (scheduler.Request.latency_s)
        reqs.append(runtime.submit(int(pairs[i, 0]), int(pairs[i, 1]),
                                   t_sched=t0 + arrivals[i]))
    deadline = time.perf_counter() + wait_timeout_s
    for req in reqs:
        if not req.wait(max(0.0, deadline - time.perf_counter())):
            raise TimeoutError(
                f"load run: ({req.s},{req.t}) unserved after "
                f"{wait_timeout_s}s (runtime stalled?)")
        if req.error is not None:
            raise RuntimeError(
                f"load run: flush failed for ({req.s},{req.t})"
            ) from req.error
    wall = time.perf_counter() - t0
    # latency from the *scheduled* arrival, not the actual submit —
    # otherwise a generator starved by the server (GIL, overload)
    # under-reports exactly the queueing delay an open-loop client
    # would see (coordinated omission).  The basis lives on each
    # Request (t_sched), so cache-hit responses are measured the same
    # way as misses here AND everywhere else latency_s is read.
    lat_ms = np.array([r.latency_s for r in reqs]) * 1e3
    # serving gap: the longest stretch of the run in which NO response
    # completed (measured from run start through the last completion).
    # A refresh that blocks the flusher appears here directly — the
    # "foreground never pauses" acceptance gates on this number.
    done = np.sort(np.array([r.t_done for r in reqs]))
    gaps = np.diff(np.concatenate([[t0], done]))
    stale = [r.staleness for r in reqs if r.staleness is not None]
    return LoadReport(
        n_requests=n, offered_qps=rate_qps,
        achieved_qps=n / wall, wall_s=wall,
        max_serving_gap_ms=round(float(gaps.max()) * 1e3, 3)
        if gaps.size else 0.0,
        stale_responses=sum(1 for s in stale if not s.complete),
        max_staleness_batches=max(
            (s.lag_batches for s in stale), default=0),
        runtime_stats=runtime.stats(), requests=reqs,
        **_percentiles(lat_ms,
                       hist.since(h0) if hist is not None else None))


def run_load_with_refresh(runtime: ServingRuntime, pairs: np.ndarray,
                          *, rate_qps: float, seed: int = 0,
                          refresh_rounds: int = 0,
                          refresh_frac: float = 0.02,
                          refresh_interval_s: float = 0.0,
                          refresh_seed: int = 0,
                          refresh_pipelined: bool = False,
                          wait_timeout_s: float = 60.0,
                          join_timeout_s: float = 120.0):
    """``run_load`` with an optional concurrent RefreshDriver — the one
    spelling of the load-phase teardown shared by ``serve.py --live``,
    benchmarks exp9, and the example.

    ``refresh_pipelined`` stages each round through the prioritized
    refresh pipeline (intermediate epochs, traffic-weighted by this
    runtime's ``frag_traffic`` counters) instead of one monolithic
    apply_updates per round.

    Returns ``(report, graphs_by_epoch, driver)``; ``driver`` is None
    when ``refresh_rounds == 0``, and ``graphs_by_epoch`` maps every
    retained epoch to its validation-oracle graph (pass
    ``driver.evicted_epochs`` to ``validate_against_epochs`` so
    capped-out snapshots are skipped, not miscounted).
    """
    from .runtime import RefreshDriver

    driver = None
    if refresh_rounds:
        driver = RefreshDriver(runtime.engine, rounds=refresh_rounds,
                               frac=refresh_frac,
                               interval_s=refresh_interval_s,
                               seed=refresh_seed,
                               pipelined=refresh_pipelined,
                               traffic=runtime.frag_traffic).start()
    report = run_load(runtime, pairs, rate_qps=rate_qps, seed=seed,
                      wait_timeout_s=wait_timeout_s)
    if driver is not None:
        driver.join(timeout=join_timeout_s)
        graphs, _evicted = driver.graph_snapshots()
    else:
        epoch, _dix, g, _stale = runtime.engine.snapshot()
        graphs = {epoch: g}
    return report, graphs, driver


def validate_against_epochs(requests, graphs_by_epoch, *,
                            sample: int = 64, seed: int = 0,
                            evicted=()) -> tuple[int, int]:
    """Differential check: a sampled response must equal the host
    Dijkstra oracle on the graph of the epoch that served it.

    Returns ``(n_checked, n_bad)``; a response tagged with an epoch
    missing from ``graphs_by_epoch`` counts as bad (it was served
    against an index no one published) UNLESS the epoch is in
    ``evicted`` — the RefreshDriver's retention cap dropped its oracle
    graph, so the response is skipped rather than miscounted.
    """
    from ..core import dijkstra

    rng = np.random.default_rng(seed)
    reqs = list(requests)
    idx = rng.permutation(len(reqs))[:sample]
    checked = 0
    bad = 0
    for i in idx:
        req = reqs[i]
        g = graphs_by_epoch.get(req.epoch)
        if g is None:
            if req.epoch in evicted:
                continue
            checked += 1
            bad += 1
            continue
        checked += 1
        want = dijkstra.pair(g, req.s, req.t)
        bad += dijkstra.mismatches_oracle(want, req.dist)
    return checked, bad
