"""Online serving runtime (DESIGN.md §11): deadline-aware
micro-batching, an epoch-consistent result cache, the hub-label hot
tier (DESIGN.md §15), concurrent index refresh, and an open-loop load
harness over the EpochedEngine.

Owned invariant — the tier order EpochCache -> label merge -> planner
changes only COST, never answers: every response is exact for the one
epoch its flush pinned, whichever tier resolved it, and carries that
tier on the Request for per-tier accounting.

Workload mixes come straight from ``repro.data.queries``
(``workload_pairs``, re-exported here for the load-harness callers)."""
from ..core.refresh_pipeline import (RefreshPipeline, Staleness,
                                     UpdateQueue)
from ..data.queries import workload_pairs
from .cache import CacheStats, EpochCache
from .loadgen import (LoadReport, run_load, run_load_with_refresh,
                      validate_against_epochs)
from .runtime import RefreshDriver, ServingRuntime
from .scheduler import MicroBatcher, Request

__all__ = [
    "CacheStats", "EpochCache", "LoadReport", "MicroBatcher",
    "RefreshDriver", "RefreshPipeline", "Request", "ServingRuntime",
    "Staleness", "UpdateQueue", "run_load", "run_load_with_refresh",
    "validate_against_epochs", "workload_pairs",
]
