"""Epoch-tagged LRU result cache for the online serving path.

Skewed road-graph traffic makes the same hot ``(s, t)`` pairs recur
constantly (the Zipf mixes in ``data/queries.py`` model this); an exact
distance is a single float, so caching it skips the device entirely for
the hot head of the distribution.

Correctness under live updates is the whole design: every entry is
tagged with the index **epoch** its value was computed on, and a lookup
pinned to epoch ``e`` only ever returns an entry tagged ``e``.  An
``apply_updates`` therefore needs no cache flush and no lock hand-off
with readers — the epoch bump itself invalidates every older entry,
which is counted (``stale``) and evicted lazily on first touch.  A
stale value can be *detected*, never *served* (the differential test in
``tests/test_serving.py`` asserts exactly this).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

from ..obs.metrics import MetricsRegistry


@dataclass
class CacheStats:
    """Counter snapshot; ``stale`` counts lookups that found an entry
    from an older epoch (rejected + evicted, never served)."""
    hits: int = 0
    misses: int = 0
    stale: int = 0
    evictions: int = 0
    size: int = 0
    capacity: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_record(self) -> dict:
        return {
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "cache_stale": self.stale,
            "cache_evictions": self.evictions,
            "cache_hit_rate": round(self.hit_rate, 4),
        }


class EpochCache:
    """Thread-safe LRU over ``(s, t)`` keyed by index epoch.

    ``get``/``put`` take the epoch explicitly (the serving runtime pins
    one per micro-batch flush from ``EpochedEngine.snapshot``), so the
    cache itself never races the epoch swap: an entry written for epoch
    e is simply unreachable from a flush pinned at e+1.
    """

    def __init__(self, capacity: int = 65536,
                 registry: MetricsRegistry | None = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive: {capacity}")
        self.capacity = capacity
        self._od: OrderedDict[tuple[int, int], tuple[int, float]] = \
            OrderedDict()
        self._lock = threading.Lock()
        # hit/miss/stale/evict tallies are registry counters (DESIGN.md
        # §16) so exporters see them live; mutated only under
        # self._lock, so stats() snapshots stay mutually consistent
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._hits = self.registry.counter("serve.cache.hits")
        self._misses = self.registry.counter("serve.cache.misses")
        self._stale = self.registry.counter("serve.cache.stale")
        self._evictions = self.registry.counter("serve.cache.evictions")

    def get(self, s: int, t: int, epoch: int) -> float | None:
        """Value for ``(s, t)`` computed on ``epoch``, else None.  An
        entry from any other epoch counts as stale and is evicted."""
        key = (s, t)
        with self._lock:
            ent = self._od.get(key)
            if ent is None:
                self._misses.inc()
                return None
            if ent[0] != epoch:
                self._stale.inc()
                self._misses.inc()
                del self._od[key]
                return None
            self._hits.inc()
            self._od.move_to_end(key)
            return ent[1]

    def put(self, s: int, t: int, epoch: int, dist: float) -> None:
        """Store only when the slot is empty or the incoming epoch is
        >= the stored one: a slow flush still pinned at epoch e must
        never clobber an (s, t) value already cached at e+1 — that
        would force a spurious stale-evict + device recompute on the
        next hot-pair lookup (and the fresher value is the one a
        current reader can actually use)."""
        key = (s, t)
        with self._lock:
            ent = self._od.get(key)
            if ent is not None and ent[0] > epoch:
                return
            self._od[key] = (epoch, dist)
            self._od.move_to_end(key)
            if len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self._evictions.inc()

    def __len__(self) -> int:
        # snapshot under the lock: len(dict) mid-rehash from a
        # concurrent put is a torn read
        with self._lock:
            return len(self._od)

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=int(self._hits.value),
                              misses=int(self._misses.value),
                              stale=int(self._stale.value),
                              evictions=int(self._evictions.value),
                              size=len(self._od),
                              capacity=self.capacity)
