"""Online serving runtime: scheduler + cache over an EpochedEngine.

``ServingRuntime`` is the single-request front door to the batched
serving stack (DESIGN.md §11).  A request flows

    submit(s, t) -> MicroBatcher buffer -> flush
        -> pin (epoch, dix, graph) via EpochedEngine.snapshot()
        -> EpochCache lookups keyed by that epoch
        -> hub-label merge (QueryPlanner.query_hub) for the misses
           whose endpoints are both labeled in the pinned epoch
        -> one QueryPlanner.query(..., dix=pinned) for the rest
        -> cache fill + resolve, every response tagged with the epoch

The middle tier is the DESIGN.md §15 hot tier: when the build pinned
hub labels for a traffic-heavy node set, any miss whose (s, t) pair
``hub_mask`` admits is answered by an O(W) label merge instead of the
full planner contraction — exact by construction, so tiers differ in
cost only, never in answers.  Per-tier counters and wall-clock splits
(``stats()``) make the label-vs-planner latency claim measurable.

The epoch pin is the consistency argument in one line: everything a
flush does — cache reads, device serve, cache writes, the tag on each
response — binds to one atomically-read published epoch, so no
response can mix epoch e's cache with epoch e+1's index no matter how
``apply_updates`` interleaves with the flush.  (The deterministic
interleaving tests and the threaded soak in ``tests/test_serving.py``
check this against per-epoch host oracles.)

``RefreshDriver`` is the concurrent-refresh half of the tentpole: a
background thread absorbing synthetic traffic batches through the
existing staged delta path while the foreground keeps serving; it
keeps the per-epoch graph snapshots the differential validation needs.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from ..core.dist_engine import EpochedEngine
from ..core.graph import traffic_updates
from ..core.refresh_pipeline import RefreshPipeline
from ..obs import trace
from ..obs.export import SlowQueryLog
from ..obs.metrics import MetricsRegistry
from .cache import EpochCache
from .scheduler import MicroBatcher, Request


class ServingRuntime:
    """Deadline-batched, epoch-cached serving over an EpochedEngine.

    ``cache_size=0`` disables the cache (every request hits the
    device); ``auto=False`` disables the flusher thread so tests can
    drive ``flush()`` deterministically.  ``max_batch`` is snapped up
    to a planner bucket size so every flush runs a warmup-compiled
    executable — call ``engine.warmup(max_batch)`` (or let
    ``warmup()`` here do it) before timing anything.
    """

    def __init__(self, engine: EpochedEngine, *, max_batch: int = 256,
                 deadline_s: float = 0.002, cache_size: int = 65536,
                 auto: bool = True,
                 registry: MetricsRegistry | None = None,
                 slow_log_n: int = 16):
        if max_batch <= 0:
            # bucket_sizes would silently floor this to 16; reject it
            # instead (cache_size=0 is the disable idiom, not this)
            raise ValueError(f"max_batch must be positive: {max_batch}")
        self.engine = engine
        self.max_batch = engine.planner.bucket_sizes(max_batch)[-1]
        # one registry per runtime (DESIGN.md §16): the cache, batcher,
        # tier ladder, and traffic counters all record into it, and the
        # exporters (--metrics-out/--metrics-port) read it live
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.slow_log = SlowQueryLog(slow_log_n)
        self.cache = EpochCache(cache_size, registry=self.registry) \
            if cache_size else None
        # per-fragment serving counters (both endpoints, represented
        # nodes routed through their agent): the traffic weights the
        # refresh pipeline prioritizes dirty groups by
        self._traffic = self.registry.array_counter(
            "serve.frag_traffic", engine.plan.k)
        # per-tier accounting (DESIGN.md §15): every cache miss is
        # resolved by exactly one of the label tier (hub merge) or the
        # planner; the wall-clock split makes the label-vs-planner
        # latency comparison a measured serve_live field, not a claim
        self._m_label_hits = self.registry.counter(
            "serve.tier.label.hits")
        self._m_planner_hits = self.registry.counter(
            "serve.tier.planner.dispatches")
        self._m_label_s = self.registry.counter(
            "serve.tier.label.seconds")
        self._m_planner_s = self.registry.counter(
            "serve.tier.planner.seconds")
        self._m_epoch = self.registry.gauge("serve.epoch")
        self.batcher = MicroBatcher(self._serve_batch,
                                    max_batch=self.max_batch,
                                    deadline_s=deadline_s, auto=auto,
                                    registry=self.registry,
                                    slow_log=self.slow_log)

    def warmup(self) -> None:
        """Compile every planner sub-program at every bucket size a
        flush can produce — including the resident fast-path program
        (cross_res) when the epoch carries pre-lifted rows.  Because an
        epoch swap preserves every table's shape (refresh re-derives
        the resident rows at the same budget), the executables warmed
        here keep serving across swaps: the first live flush after
        ``apply_updates`` never pays an XLA compile in its p99."""
        self.engine.warmup(self.max_batch)

    def submit(self, s: int, t: int,
               t_sched: float | None = None) -> Request:
        """Enqueue one query; returns its in-flight Request.
        ``t_sched``: the open-loop scheduled arrival time — latency is
        measured from it (see scheduler.Request)."""
        return self.batcher.submit(s, t, t_sched)

    def query(self, s: int, t: int,
              timeout: float | None = 30.0) -> float:
        """Blocking single-query convenience: submit + wait, raising
        on timeout or a failed flush."""
        return self.submit(s, t).result(timeout)

    def frag_traffic(self) -> np.ndarray:
        """Snapshot of the per-fragment serving counters (a copy)."""
        return self._traffic.snapshot()

    def latency_histogram(self):
        """The request-latency histogram (seconds; observed per
        resolved request on the open-loop ``t_sched`` basis) — the
        load harness derives its reported percentiles from this."""
        return self.registry.histogram("serve.request.latency_s")

    def _count_traffic(self, batch) -> None:
        plan = self.engine.plan
        nodes = np.fromiter(
            (x for r in batch for x in (r.s, r.t)), np.int64,
            2 * len(batch))
        frag = plan.frag_of[nodes]
        frag = np.where(frag >= 0, frag,
                        plan.frag_of[plan.agent_of[nodes]])
        counts = np.bincount(frag[frag >= 0], minlength=plan.k)
        self._traffic.add(counts)

    # -- the flush body (runs on the flusher thread in auto mode) ------
    def _serve_batch(self, batch) -> None:
        epoch, dix, _g, stale = self.engine.snapshot()
        self._m_epoch.set(epoch)
        self._count_traffic(batch)
        misses = []
        with trace.span("serve.cache_lookup", epoch=epoch,
                        size=len(batch)):
            for req in batch:
                hit = None if self.cache is None else \
                    self.cache.get(req.s, req.t, epoch)
                if hit is not None:
                    req.dist = hit
                    req.epoch = epoch
                    req.staleness = stale
                    req.cached = True
                    req.tier = "cache"
                else:
                    misses.append(req)
        if misses:
            planner = self.engine.planner
            s = np.fromiter((r.s for r in misses), np.int32,
                            len(misses))
            t = np.fromiter((r.t for r in misses), np.int32,
                            len(misses))
            # label tier: pairs whose endpoints are both hub-labeled
            # in the pinned epoch bypass the planner entirely — the
            # merge is exact (§15), so this changes cost, not answers
            hub = planner.hub_mask(s, t, dix=dix)
            out = np.empty(len(misses), np.float64)
            label_n = planner_n = 0
            label_s = planner_s = 0.0
            lag = stale.lag_batches if stale is not None else 0
            if hub.any():
                t0 = time.perf_counter()
                out[hub] = planner.query_hub(s[hub], t[hub], dix=dix)
                t1 = time.perf_counter()
                label_s = t1 - t0
                label_n = int(hub.sum())
                trace.event("serve.tier.label", t0, t1, n=label_n,
                            epoch=epoch, staleness=lag)
            rest = ~hub
            if rest.any():
                t0 = time.perf_counter()
                out[rest] = planner.query(s[rest], t[rest], dix=dix)
                t1 = time.perf_counter()
                planner_s = t1 - t0
                planner_n = int(rest.sum())
                trace.event("serve.tier.planner", t0, t1, n=planner_n,
                            epoch=epoch, staleness=lag)
            for req, d, h in zip(misses, out, hub):
                req.dist = float(d)
                req.epoch = epoch
                req.staleness = stale
                req.tier = "label" if h else "planner"
                if self.cache is not None:
                    self.cache.put(req.s, req.t, epoch, req.dist)
            if label_n:
                self._m_label_hits.inc(label_n)
                self._m_label_s.inc(label_s)
            if planner_n:
                self._m_planner_hits.inc(planner_n)
                self._m_planner_s.inc(planner_s)

    def flush(self) -> int:
        return self.batcher.flush()

    def close(self) -> None:
        self.batcher.close()

    def stats(self) -> dict:
        """Occupancy + per-tier counters.  ``cache_hits`` is always
        present (0 when the cache is disabled — the cache stats record
        overrides it otherwise); ``label_us_per_query`` vs
        ``planner_us_per_query`` is the measured hot-tier speedup."""
        out = self.batcher.occupancy()
        label_hits = int(self._m_label_hits.value)
        planner_hits = int(self._m_planner_hits.value)
        out["cache_hits"] = 0
        out["label_hits"] = label_hits
        out["planner_dispatches"] = planner_hits
        out["label_us_per_query"] = round(
            1e6 * self._m_label_s.value / label_hits, 3) \
            if label_hits else 0.0
        out["planner_us_per_query"] = round(
            1e6 * self._m_planner_s.value / planner_hits, 3) \
            if planner_hits else 0.0
        if self.cache is not None:
            out.update(self.cache.stats().as_record())
        return out


class RefreshDriver:
    """Background index refresher: ``rounds`` traffic batches through
    ``EpochedEngine.apply_updates`` while the foreground serves.

    Retains ``graphs_by_epoch`` — the exact host graph published with
    each epoch — so responses tagged epoch e can be validated against
    the Dijkstra oracle *for e* even after later epochs land, and
    records per-round refresh wall time.  Retention is capped at the
    last ``retain_epochs`` epochs (a road64k host graph is tens of MB;
    a long schedule retaining every epoch is an unbounded leak); the
    ids evicted past the cap are tracked so the validation oracle can
    tell "evicted" from "never published".  All snapshot access is
    synchronized (``graph_snapshots``) — the foreground may sample
    mid-run.  ``pipelined=True`` routes each round through the staged
    ``core.refresh_pipeline.RefreshPipeline`` (one epoch per work item,
    ``traffic``-prioritized) instead of one monolithic apply_updates.
    ``interval_s`` spaces the rounds out (0 = back-to-back).  Start
    with ``start()``; ``join()`` waits for completion.
    """

    def __init__(self, engine: EpochedEngine, *, rounds: int = 3,
                 frac: float = 0.02, interval_s: float = 0.0,
                 seed: int = 0, retain_epochs: int = 64,
                 pipelined: bool = False, traffic=None,
                 max_items: int = 8):
        self.engine = engine
        self.rounds = rounds
        self.frac = frac
        self.interval_s = interval_s
        self.seed = seed
        self.retain_epochs = max(2, int(retain_epochs))
        self.pipeline = RefreshPipeline(
            engine, traffic=traffic, max_items=max_items) \
            if pipelined else None
        self._glock = threading.Lock()
        e0, _dix, g0, _stale = engine.snapshot()
        self.graphs_by_epoch = {e0: g0}
        self.evicted_epochs: set[int] = set()
        self.refresh_s: list[float] = []
        self.items_per_round: list[int] = []
        self.error: BaseException | None = None
        self._thread = threading.Thread(target=self._run,
                                        name="refresh-driver",
                                        daemon=True)

    def start(self) -> "RefreshDriver":
        self._thread.start()
        return self

    def join(self, timeout: float | None = None) -> None:
        """Wait for the driver; raises TimeoutError if it is still
        running when ``timeout`` expires (callers must not proceed as
        if the refresh schedule completed) and re-raises any exception
        the refresh thread died with."""
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"RefreshDriver still running after {timeout}s "
                f"({len(self.refresh_s)}/{self.rounds} rounds done)")
        if self.error is not None:
            raise self.error

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()

    def _record_epoch(self) -> None:
        epoch, _dix, g, _stale = self.engine.snapshot()
        with self._glock:
            self.graphs_by_epoch[epoch] = g
            while len(self.graphs_by_epoch) > self.retain_epochs:
                old = min(self.graphs_by_epoch)
                del self.graphs_by_epoch[old]
                self.evicted_epochs.add(old)

    def graph_snapshots(self) -> tuple[dict, set]:
        """Synchronized copy of (graphs_by_epoch, evicted_epochs) —
        safe to call from the foreground mid-run."""
        with self._glock:
            return dict(self.graphs_by_epoch), set(self.evicted_epochs)

    def _run(self) -> None:
        try:
            for r in range(self.rounds):
                u, v, w = traffic_updates(self.engine.g, self.frac,
                                          seed=self.seed + 101 + r)
                t0 = time.perf_counter()
                span = trace.span("refresh.round", round=r,
                                  pipelined=self.pipeline is not None)
                with span:
                    self._one_round(u, v, w)
                self.refresh_s.append(time.perf_counter() - t0)
                if self.interval_s:
                    time.sleep(self.interval_s)
        except BaseException as exc:   # surfaced by join()
            self.error = exc

    def _one_round(self, u, v, w) -> None:
        if self.pipeline is not None:
            # staged: one epoch per work item, busiest groups
            # first — the foreground serves between items
            self.pipeline.submit(u, v, w)
            self.pipeline.plan()
            items = 0
            while self.pipeline.step() is not None:
                items += 1
                self._record_epoch()
            self.items_per_round.append(items)
        else:
            self.engine.apply_updates(u, v, w)
            self._record_epoch()
            self.items_per_round.append(1)

    def as_record(self) -> dict:
        return {
            "refresh_rounds": len(self.refresh_s),
            "refresh_pipelined": self.pipeline is not None,
            "refresh_items": int(sum(self.items_per_round)),
            "refresh_mean_s": round(float(np.mean(self.refresh_s)), 4)
            if self.refresh_s else 0.0,
            "refresh_max_s": round(max(self.refresh_s), 4)
            if self.refresh_s else 0.0,
        }
