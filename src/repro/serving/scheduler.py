"""Deadline-aware micro-batching scheduler (DESIGN.md §11).

Single ``(s, t)`` requests arrive one at a time (live traffic); the
device serves fixed pow2 batch shapes (``QueryPlanner.bucket_sizes``).
The ``MicroBatcher`` bridges the two: requests accumulate in a pending
buffer and the whole buffer flushes as one planner batch when either

  * the buffer reaches ``max_batch`` (a warmup-compiled bucket size —
    throughput bound, "full" flush), or
  * ``deadline_s`` has elapsed since the *oldest* pending request
    arrived (tail-latency bound, "deadline" flush).

So a request waits at most one deadline before its batch launches, and
under load the batch fills long before the deadline — latency degrades
into throughput exactly at the arrival rate where batching starts
paying.  Flush sizes are recorded per flush (occupancy histogram) so
the load harness can report how full the buckets actually ran.

Two drive modes: ``auto=True`` spawns a daemon flusher thread (the
production arrangement, used by the load harness and the threaded soak
test); ``auto=False`` leaves flushing to explicit ``flush()`` calls so
tests can interleave submits, flushes, and index refreshes
deterministically on one thread.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Sequence

from ..core.dist_engine import pad_pow2
from ..obs import trace
from ..obs.metrics import MetricsRegistry


class Request:
    """One in-flight query; resolved in place by the serving flush.
    ``error`` is set instead of ``dist`` when the flush failed —
    ``result()`` is the raising accessor.

    ``t_sched`` is the request's *scheduled* arrival time (open-loop
    clock); it defaults to the submit instant but an open-loop driver
    running behind schedule passes the time the request was supposed
    to arrive, so ``latency_s`` charges the queueing delay instead of
    hiding it (coordinated omission).  The basis is a property of the
    request, not of the serve path that resolved it — a cache hit and
    a device miss measure from the same clock.

    ``tier`` records which serving tier resolved the request —
    "cache", "label" (hub-label merge, DESIGN.md §15) or "planner" —
    so responses stay attributable per tier; ``cached`` is the
    backwards-compatible boolean view of the first.
    """

    __slots__ = ("s", "t", "t_submit", "t_sched", "t_done", "dist",
                 "epoch", "staleness", "cached", "tier", "error",
                 "_done")

    def __init__(self, s: int, t: int, t_sched: float | None = None):
        self.s = int(s)
        self.t = int(t)
        self.t_submit = time.perf_counter()
        self.t_sched = self.t_submit if t_sched is None else t_sched
        self.t_done: float | None = None
        self.dist: float | None = None
        self.epoch: int | None = None
        # the pinned epoch's recency tag (core.refresh_pipeline
        # .Staleness), set by the serving flush alongside ``epoch``
        self.staleness = None
        self.cached = False
        self.tier: str | None = None
        self.error: BaseException | None = None
        self._done = threading.Event()

    def wait(self, timeout: float | None = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: float | None = None) -> float:
        """Distance, or raise: TimeoutError if unserved, the flush's
        exception if its batch failed."""
        if not self.wait(timeout):
            raise TimeoutError(f"query ({self.s},{self.t}) not served "
                               f"within {timeout}s")
        if self.error is not None:
            raise RuntimeError(
                f"serving flush failed for ({self.s},{self.t})"
            ) from self.error
        return self.dist

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def latency_s(self) -> float:
        """Completion latency from the scheduled arrival (== submit
        when no schedule was given) — the open-loop basis shared by
        cache hits and misses alike."""
        if self.t_done is None:
            raise RuntimeError("request not resolved yet")
        return self.t_done - self.t_sched


class MicroBatcher:
    """Accumulate requests; flush by deadline or full bucket.

    ``serve_batch`` is called with the list of pending requests and
    must set ``dist``/``epoch``/``cached`` on each; the batcher stamps
    completion times and wakes waiters.  Flush metadata accumulates
    incrementally (bucket histogram + counters, O(1) per flush — a
    long-lived runtime flushes hundreds of times a second) and is
    reported by ``occupancy()`` / ``flush_reasons``.
    """

    def __init__(self, serve_batch: Callable[[Sequence[Request]], None],
                 *, max_batch: int = 256, deadline_s: float = 0.002,
                 auto: bool = True, registry: MetricsRegistry | None = None,
                 slow_log=None):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive: {max_batch}")
        self._serve_batch = serve_batch
        self.max_batch = max_batch
        self.deadline_s = deadline_s
        self._pending: list[Request] = []
        self._cond = threading.Condition()
        self._closed = False
        self.error: BaseException | None = None
        # per-flush accounting lives in registry metrics (DESIGN.md
        # §16), O(1) space: pow2-bucket labeled counter of flush sizes
        # plus flush-reason counters and the request-latency histogram.
        # All flush counters mutate only in _take (under self._cond),
        # so occupancy() snapshots them under the same lock and never
        # reports torn mid-flush state.
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._m_occ = self.registry.labeled("serve.batch.occupancy")
        self._m_reasons = self.registry.labeled("serve.batch.flushes")
        self._m_requests = self.registry.counter(
            "serve.batch.flushed_requests")
        self._m_latency = self.registry.histogram(
            "serve.request.latency_s")
        self._slow_log = slow_log
        self._thread: threading.Thread | None = None
        if auto:
            self._thread = threading.Thread(target=self._run,
                                            name="microbatcher",
                                            daemon=True)
            self._thread.start()

    # -- submission ----------------------------------------------------
    def submit(self, s: int, t: int,
               t_sched: float | None = None) -> Request:
        req = Request(s, t, t_sched)
        with self._cond:
            if self._closed:
                raise RuntimeError(
                    "MicroBatcher is closed"
                    + (f" (flusher died: {self.error!r})"
                       if self.error else ""))
            self._pending.append(req)
            # wake the flusher: either this is the first request (its
            # deadline clock starts now) or the bucket just filled
            self._cond.notify_all()
        return req

    @property
    def pending(self) -> int:
        with self._cond:
            return len(self._pending)

    # -- flushing ------------------------------------------------------
    def _take(self, reason: str) -> list[Request]:
        """Caller must hold the lock.  Detach at most ``max_batch``
        pending requests (oldest first) and account the flush."""
        batch = self._pending[:self.max_batch]
        self._pending = self._pending[self.max_batch:]
        if batch:
            self._m_occ.inc(pad_pow2(len(batch)))
            self._m_requests.inc(len(batch))
            self._m_reasons.inc(reason)
        return batch

    # Backwards-compatible counter views (the pre-§16 attribute API),
    # all reading the registry metrics _take maintains.
    @property
    def n_flushes(self) -> int:
        return int(self._m_reasons.total)

    @property
    def flushed_requests(self) -> int:
        return int(self._m_requests.value)

    @property
    def flush_reasons(self) -> dict:
        return {"full": 0, "deadline": 0, "manual": 0,
                **self._m_reasons.snapshot()}

    def _fail(self, batch: list[Request], exc: BaseException) -> None:
        """Resolve ``batch`` (and anything still pending) with ``exc``
        so no waiter hangs on a dead flush path."""
        with self._cond:
            batch = batch + self._pending
            self._pending = []
        now = time.perf_counter()
        for req in batch:
            if not req.done:
                req.error = exc
                req.t_done = now
                req._done.set()

    def _resolve(self, batch: list[Request]) -> None:
        """Serve and complete one flush.  A failure closes the batcher
        FIRST (under the lock), then resolves every affected request
        with the exception, then re-raises for the caller.

        The close-before-fail order is what makes the failure path
        race-free in BOTH drive modes: a request submitted during the
        failing flush either landed in the pending buffer before the
        close — and is swept into ``_fail`` below — or its submit
        raises with the cause.  Closing only from the auto thread (the
        old arrangement) left manual-mode (``auto=False``) callers a
        window where a request submitted while ``flush()`` was raising
        stayed queued forever on a serve path whose owner had already
        seen the exception and walked away.
        """
        if not batch:
            return
        t_flush = time.perf_counter()
        try:
            with trace.span("serve.flush", size=len(batch),
                            bucket=pad_pow2(len(batch))):
                self._serve_batch(batch)
            for req in batch:
                if req.dist is None or req.epoch is None:
                    raise RuntimeError(
                        f"serve_batch left ({req.s},{req.t}) "
                        "unresolved")
        except BaseException as exc:
            self.error = exc
            with self._cond:
                self._closed = True
                self._cond.notify_all()
            self._fail(batch, exc)
            raise
        now = time.perf_counter()
        for req in batch:
            req.t_done = now
            req._done.set()
        self._observe(batch, t_flush, now)

    def _observe(self, batch: list[Request], t_flush: float,
                 now: float) -> None:
        """Post-resolution accounting: latency histogram, slow-query
        log, and (tracing on) one lifecycle event per request covering
        scheduled-arrival -> respond, tagged with the tier/epoch/
        staleness the flush stamped."""
        tr = trace.get_tracer()
        emit = tr.enabled
        for req in batch:
            lat = now - req.t_sched
            self._m_latency.observe(lat)
            lag = req.staleness.lag_batches \
                if req.staleness is not None else 0
            if self._slow_log is not None:
                self._slow_log.offer(lat, {
                    "s": req.s, "t": req.t, "tier": req.tier,
                    "epoch": req.epoch, "staleness_batches": lag,
                    "batch_wait_ms": round(
                        (t_flush - req.t_submit) * 1e3, 3),
                    "flush_ms": round((now - t_flush) * 1e3, 3),
                    "batch_size": len(batch),
                })
            if emit:
                tr.event("serve.request", req.t_sched, now,
                         tier=req.tier, epoch=req.epoch,
                         staleness=lag, bucket=pad_pow2(len(batch)),
                         wait_ms=round(
                             (t_flush - req.t_submit) * 1e3, 3))

    def flush(self) -> int:
        """Synchronously flush one batch of whatever is pending (the
        deterministic-test drive mode); returns its size."""
        with self._cond:
            batch = self._take("manual")
        self._resolve(batch)
        return len(batch)

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._closed:
                    self._cond.wait()
                if self._closed and not self._pending:
                    return
                # deadline runs from the oldest pending arrival, so a
                # request never waits more than deadline_s to launch
                first = self._pending[0].t_submit
                while len(self._pending) < self.max_batch:
                    remaining = self.deadline_s \
                        - (time.perf_counter() - first)
                    if remaining <= 0 or self._closed:
                        break
                    self._cond.wait(timeout=remaining)
                reason = ("full" if len(self._pending) >= self.max_batch
                          else "deadline")
                batch = self._take(reason)
            try:
                self._resolve(batch)
            except BaseException:
                # _resolve already closed the batcher (so submits now
                # raise, carrying self.error) and failed the batch plus
                # every straggler — nothing ever hangs; just stop
                return

    def close(self, *, drain: bool = True) -> None:
        """Stop the flusher; by default drain pending requests first.
        Raises if the flusher will not stop (e.g. stuck in a cold
        compile) rather than draining concurrently with it — two
        threads must never drive serve_batch at once."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=60.0)
            if self._thread.is_alive():
                raise RuntimeError(
                    "MicroBatcher flusher did not stop within 60s; "
                    "refusing to drain concurrently with it")
            self._thread = None
        if drain:
            while self.flush():
                pass

    # -- introspection -------------------------------------------------
    def occupancy(self) -> dict:
        """Flush-size histogram + mean occupancy vs ``max_batch``.

        Bucketed by the planner's pow2 padding rule (floor 16) applied
        to the *whole* flush — an upper bound on executable shape,
        since the planner additionally splits each flush into per-case
        buckets that may each pad smaller.  The registry metrics are
        mutated only in ``_take`` under ``self._cond``, so snapshotting
        them here under the same lock can never report torn mid-flush
        state (e.g. a bumped flush count next to a not-yet-bumped
        histogram) — the concurrency test asserts exactly this."""
        with self._cond:
            hist = self._m_occ.snapshot()
            reasons = self.flush_reasons
            flushed = int(self._m_requests.value)
        n_flushes = sum(reasons.values())
        mean = (flushed / n_flushes / self.max_batch) if n_flushes \
            else 0.0
        return {
            "flushes": n_flushes,
            "mean_occupancy": round(mean, 4),
            "occupancy_hist": hist,
            **{f"flush_{k}": v for k, v in reasons.items()},
        }
