"""Agents and Deterministic Routing Areas (paper §IV; host-side
preprocessing stage 1, DESIGN.md §7).

Owned invariant: every non-agent node belongs to exactly one DRA and
reaches the rest of G only through that DRA's agent — the case split
every engine (host and device) keys its query routing on.

An *agent* u represents a set of nodes A_u (|A_u| <= c*floor(sqrt(n)))
whose only connection to the rest of G is through u.  The union A_u^+ of
all sets represented by u is its DRA: a maximal connected subgraph that
touches the rest of G only at u (Props 3-9).

compDRAs (Fig. 6) runs in linear time:
  1. cut-nodes + BCCs (Hopcroft-Tarjan),
  2. BC-SKETCH bipartite tree (cut-nodes x BCCs, Prop 12),
  3. leaf-inward peeling of the sketch tree, merging BCC regions whose
     combined size stays under the threshold; surviving cut-nodes whose
     leaf regions fit the bound become maximal agents.

Deviation from the paper's pseudo-code, recorded per DESIGN.md: line 3 of
extractDRAs picks "a cut-node with leaf neighbours"; for the claimed
invariant "at most one non-leaf neighbour" to hold we peel with the
standard tree worklist (only cut-nodes with <= 1 non-leaf neighbour are
eligible), which is the unique order-independent reading.  We also keep a
cut-node whose neighbours are ALL leaves as an agent instead of collapsing
its whole component into an orphan region, preserving DRA coverage for
small components.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List

import numpy as np

from .bcc import biconnected_components
from .graph import Graph


@dataclasses.dataclass
class AgentInfo:
    agent: int                     # graph node id of the maximal agent
    pieces: List[np.ndarray]       # each A_u^i (node ids, includes agent)
    nodes: np.ndarray              # A_u^+ \ {agent}: represented nodes
    dist_to_agent: np.ndarray      # dist(agent, v) for v in ``nodes``
    piece_of: np.ndarray           # piece index aligned with ``nodes``


@dataclasses.dataclass
class DRAResult:
    agents: List[AgentInfo]
    agent_of: np.ndarray           # int[n]; representing agent or self
    dist_to_agent: np.ndarray      # float[n]; 0 for agents/trivial nodes
    piece_of: np.ndarray           # int[n]; piece idx within DRA, -1 else
    threshold: int

    @property
    def n_nontrivial_agents(self) -> int:
        return len(self.agents)

    def represented_mask(self) -> np.ndarray:
        mask = np.zeros(self.agent_of.size, dtype=bool)
        for a in self.agents:
            mask[a.nodes] = True
        return mask

    def shrink_nodes(self) -> np.ndarray:
        """Nodes surviving into the shrink graph G[A] (agents + trivial)."""
        return np.nonzero(~self.represented_mask())[0].astype(np.int32)


def _sssp_within(g: Graph, source: int, allowed: np.ndarray) -> Dict[int, float]:
    """Dijkstra from ``source`` restricted to ``allowed`` node set."""
    ok = np.zeros(g.n, dtype=bool)
    ok[allowed] = True
    dist = {int(source): 0.0}
    pq = [(0.0, int(source))]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist.get(u, np.inf):
            continue
        s, e = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[s:e], g.weights[s:e]):
            v = int(v)
            if not ok[v]:
                continue
            nd = d + float(w)
            if nd < dist.get(v, np.inf):
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


def compute_dras(g: Graph, c: int = 2) -> DRAResult:
    """Algorithm compDRAs (paper Fig. 6)."""
    n = g.n
    threshold = c * int(np.floor(np.sqrt(n)))
    bcc = biconnected_components(g)

    # ---- BC-SKETCH tree ------------------------------------------------
    # sketch node ids: cut-node c_v -> ('c', v); BCC regions get dict ids.
    cut_ids = np.nonzero(bcc.cut)[0]
    is_cut = bcc.cut
    # region state (BCC sketch nodes, merged over time).  Contents are
    # kept as lazy lists of member arrays with an exact size counter:
    # regions adjacent to one cut node pairwise intersect in exactly
    # that node (the sketch is a tree), so merged sizes follow by
    # arithmetic and the arrays are unioned only once, for the leaf
    # regions that survive into agent pieces — the peeling loop never
    # pays an O(region) set union.
    region_parts: Dict[int, List[np.ndarray]] = {}  # rid -> member arrays
    region_size: Dict[int, int] = {}       # rid -> exact |contents|
    region_adj: Dict[int, set] = {}        # rid -> adjacent cut graph-node ids
    cut_adj: Dict[int, set] = {}           # cut graph-node id -> rids
    next_rid = 0
    for comp in bcc.bcc_nodes:
        rid = next_rid
        next_rid += 1
        region_parts[rid] = [comp.astype(np.int32)]
        region_size[rid] = int(comp.size)
        borders = {int(v) for v in comp[is_cut[comp]]}
        region_adj[rid] = borders
        for v in borders:
            cut_adj.setdefault(v, set()).add(rid)
    for v in cut_ids:
        cut_adj.setdefault(int(v), set())

    def non_leaf_regions(v: int) -> List[int]:
        return [r for r in cut_adj[v] if len(region_adj[r]) > 1]

    # ---- leaf-inward peeling (extractDRAs lines 1-9) --------------------
    work = [v for v in cut_adj if len(non_leaf_regions(v)) <= 1]
    in_work = set(work)
    alive_cut = set(cut_adj.keys())
    while work:
        v = work.pop()
        in_work.discard(v)
        if v not in alive_cut:
            continue
        X = list(cut_adj[v])
        if not X:
            continue
        nonleaf = [r for r in X if len(region_adj[r]) > 1]
        if len(nonleaf) > 1:
            continue  # not eligible (yet); re-added when neighbours merge
        if len(nonleaf) == 0:
            # all-leaf cut node: keep v as a surviving agent candidate
            continue
        alpha = sum(region_size[r] for r in X) - len(X) + 1
        if alpha > threshold:
            continue  # v survives as a potential maximal agent
        # merge X and v into a new region replacing the non-leaf one
        y0 = nonleaf[0]
        merged: List[np.ndarray] = []
        for r in X:
            merged.extend(region_parts[r])
        merged.append(np.array([v], dtype=np.int32))
        new_borders = (region_adj[y0] - {v})
        rid = next_rid
        next_rid += 1
        region_parts[rid] = merged
        region_size[rid] = alpha
        region_adj[rid] = set(new_borders)
        for r in X:
            for w in region_adj[r]:
                cut_adj[w].discard(r)
            del region_parts[r], region_adj[r], region_size[r]
        for w in new_borders:
            cut_adj[w].add(rid)
        alive_cut.discard(v)
        del cut_adj[v]
        # neighbours of the new region may have become eligible
        for w in new_borders:
            if w not in in_work:
                work.append(w)
                in_work.add(w)

    # ---- identify agents + DRAs (extractDRAs lines 10-15) ---------------
    agents: List[AgentInfo] = []
    agent_of = np.arange(n, dtype=np.int32)
    dist_to_agent = np.zeros(n, dtype=np.float64)
    piece_of = -np.ones(n, dtype=np.int32)
    for v in sorted(alive_cut):
        leaf_pieces = [r for r in sorted(cut_adj[v])
                       if len(region_adj[r]) == 1
                       and region_size[r] <= threshold]
        # piece must contain more than just {v, one other}?  No: any size
        # >= 2 region represents >= 1 non-agent node.
        pieces = []
        rep_parts: List[np.ndarray] = []
        pp_parts: List[np.ndarray] = []
        for r in leaf_pieces:
            # the one union a surviving leaf region ever pays
            nodes = np.unique(np.concatenate(region_parts[r])).astype(
                np.int32)
            if nodes.size <= 1:
                continue
            pieces.append(nodes)
            rep_r = nodes[nodes != v]
            rep_parts.append(rep_r)
            pp_parts.append(np.full(rep_r.size, len(pieces) - 1,
                                    dtype=np.int32))
        if not rep_parts:
            continue
        rep = np.concatenate(rep_parts)
        ppiece = np.concatenate(pp_parts)
        allp = np.unique(np.concatenate(pieces))
        dmap = _sssp_within(g, v, allp)
        d = np.array([dmap.get(int(x), np.inf) for x in rep])
        agents.append(AgentInfo(agent=int(v), pieces=pieces, nodes=rep,
                                dist_to_agent=d, piece_of=ppiece))
        agent_of[rep] = v
        dist_to_agent[rep] = d
        piece_of[rep] = ppiece
    return DRAResult(agents=agents, agent_of=agent_of,
                     dist_to_agent=dist_to_agent, piece_of=piece_of,
                     threshold=threshold)


def shrink_graph(g: Graph, dras: DRAResult) -> tuple[Graph, np.ndarray]:
    """Shrink graph G[A] (preprocessing step 3): remove represented nodes.

    Returns (graph, old_ids) with old_ids[new_id] = original node id.
    """
    return g.subgraph(dras.shrink_nodes())
