"""Batched multi-source shortest paths on device (DESIGN.md §2).

Dijkstra's heap has no TPU analogue, so the device engine relaxes edges
in dense sweeps: batched Bellman-Ford over an edge list, one
``segment_min`` per sweep, iterated under ``lax.while_loop`` until a
fixpoint.  S sources relax simultaneously — the batch dimension is what
makes this TPU-shaped (S*E element-wise work per sweep on the VPU).

All functions take *directed* edge arrays; undirected graphs pass each
edge twice.  +inf marks unreachable; padding edges can use src=dst=0,
w=+inf (they never relax anything).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.inf


@functools.partial(jax.jit, static_argnames=("n", "max_iters"))
def bellman_ford(src: jax.Array, dst: jax.Array, w: jax.Array,
                 init_dist: jax.Array, *, n: int,
                 max_iters: int | None = None) -> jax.Array:
    """Batched BF: init_dist [S, n] -> fixpoint distances [S, n].

    One sweep: dist[s, v] <- min(dist[s, v],
                                 min_{(u,v,w) in E} dist[s, u] + w).
    The S x E candidate matrix is flattened so a single segment_min over
    offset ids (v + s*n) covers the whole batch.
    """
    s_dim = init_dist.shape[0]
    if max_iters is None:
        max_iters = n  # worst-case path length
    offsets = (jnp.arange(s_dim, dtype=jnp.int32) * n)[:, None]
    flat_ids = (dst[None, :] + offsets).reshape(-1)

    def sweep(dist):
        cand = (dist[:, src] + w[None, :]).reshape(-1)
        relaxed = jax.ops.segment_min(cand, flat_ids,
                                      num_segments=s_dim * n)
        return jnp.minimum(dist, relaxed.reshape(s_dim, n))

    def cond(carry):
        _, changed, it = carry
        return changed & (it < max_iters)

    def body(carry):
        dist, _, it = carry
        nd = sweep(dist)
        return nd, jnp.any(nd < dist), it + 1

    out, _, _ = jax.lax.while_loop(cond, body,
                                   (init_dist, jnp.bool_(True),
                                    jnp.int32(0)))
    return out


def sources_init(sources: jax.Array, n: int) -> jax.Array:
    """[S, n] init matrix: 0 at each source, +inf elsewhere."""
    s_dim = sources.shape[0]
    init = jnp.full((s_dim, n), INF, dtype=jnp.float32)
    return init.at[jnp.arange(s_dim), sources].set(0.0)


@functools.partial(jax.jit, static_argnames=("n",))
def apsp_from_sources(src: jax.Array, dst: jax.Array, w: jax.Array,
                      sources: jax.Array, *, n: int) -> jax.Array:
    """Distances from each of ``sources`` to every node: [S, n]."""
    return bellman_ford(src, dst, w, sources_init(sources, n), n=n)


# ---------------------------------------------------------------------------
# A measured negative result worth keeping (DESIGN.md §9): warm-starting
# the SUPER overlay refresh through this BF — init = the old d_super,
# valid whenever no weight increased, since min-relaxation only lowers
# values — was implemented and benchmarked for the incremental-refresh
# path, and LOST to simply re-closing the dense overlay with the
# blocked FW kernel.  Two independent reasons, both structural:
#   * the segment_min sweep above is scatter-bound on CPU-XLA (~750ms
#     per sweep at S=625/13k edges, x ~28 sweeps from scratch), and a
#     warm init still needs several sweeps;
#   * a *dense* warm sweep min(d, d (x) M) costs S^3 — i.e. one sweep
#     already costs as much as the entire FW closure (~60ms at S=625),
#     so warm-starting can never come out ahead on a clique-dense
#     overlay.
# The edge-list BF below remains the right tool for large sparse
# inputs (it is what the sharded offline build uses); the overlay
# refresh lives in device_engine.super_stage.
# ---------------------------------------------------------------------------
