"""The one home for every padding rule in the index/serving stack.

Three spellings used to live in three modules (``_pad_to`` / ``_pow2``
in device_engine, ``pad_pow2`` in dist_engine, re-imported by the
serving scheduler); they are consolidated here so the shape contracts
the planner warms up, the batcher buckets by, and the build/refresh
stages pad to can never drift apart.

Contracts (property-tested in tests/test_padding.py):

  * ``pad_to(x, mult)``   — smallest multiple of ``mult`` that is
    >= max(x, mult); used for fragment/boundary axis padding (device
    tiles want multiples of 8, not powers of two).
  * ``pow2(x, floor)``    — smallest power-of-two-multiple-of-``floor``
    >= max(x, floor) (``floor`` itself need not be a power of two);
    used for batch-count padding so jitted programs compile for
    O(log n) distinct shapes.
  * ``pad_pow2(n, floor)`` — alias of ``pow2`` with the query-planner
    default floor of 16: the padded bucket sizes every serve
    sub-program is warmup-compiled at.

All three are monotone non-decreasing, idempotent (f(f(x)) == f(x)),
and never smaller than their input — the properties batching and
warmup correctness lean on.
"""
from __future__ import annotations


def pad_to(x: int, mult: int = 8) -> int:
    """Round ``x`` up to a multiple of ``mult`` (never below ``mult``)."""
    return max(mult, -(-x // mult) * mult)


def pow2(x: int, floor: int = 1) -> int:
    """Round ``x`` up to ``floor * 2**k`` (never below ``floor``)."""
    m = floor
    while m < x:
        m *= 2
    return m


def pad_pow2(n: int, floor: int = 16) -> int:
    """The query planner's padded bucket size for a batch of ``n``."""
    return pow2(n, floor)
