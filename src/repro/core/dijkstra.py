"""Dijkstra variants: baseline query algorithms (paper §VI-C, [20]).

Host-side reference implementations used (a) as the paper's baselines
for Exp-4/Exp-5 and (b) as correctness oracles for the JAX device engine.

Role: the ground truth every differential test compares against
(DESIGN.md §2).  Owned invariants: distances are computed in float64
(exact for the stack's integer weights), and ``mismatches_oracle`` is
the single comparator all validation paths share — infs match only
infs, NaN never matches, finites compare with relative tolerance.
"""
from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from .graph import Graph


def sssp(g: Graph, s: int, targets: Optional[np.ndarray] = None
         ) -> np.ndarray:
    """Single-source shortest distances; early exit once targets settle."""
    dist = np.full(g.n, np.inf)
    dist[s] = 0.0
    remaining = None if targets is None else set(int(t) for t in targets)
    pq = [(0.0, int(s))]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        if remaining is not None:
            remaining.discard(u)
            if not remaining:
                break
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            nd = d + float(w)
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, int(v)))
    return dist


def pair_with_path(g: Graph, s: int, t: int
                   ) -> tuple[float, Optional[list]]:
    """s->t distance and one shortest path as a node list (None when
    unreachable), with target early exit.  The predecessor tree is the
    host path oracle the witness-unwinding device path (paths.py) is
    differentially tested against."""
    if s == t:
        return 0.0, [int(s)]
    dist = np.full(g.n, np.inf)
    pred = np.full(g.n, -1, dtype=np.int64)
    dist[s] = 0.0
    pq = [(0.0, int(s))]
    found = False
    while pq:
        d, u = heapq.heappop(pq)
        if u == t:
            found = True
            break
        if d > dist[u]:
            continue
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            nd = d + float(w)
            if nd < dist[v]:
                dist[v] = nd
                pred[v] = u
                heapq.heappush(pq, (nd, int(v)))
    if not found:
        return np.inf, None
    path = [int(t)]
    while path[-1] != s:
        path.append(int(pred[path[-1]]))
    return float(dist[t]), path[::-1]


def mismatches_oracle(want: float, got: float, *,
                      rel_tol: float = 1e-4) -> bool:
    """The one spelling of "served distance disagrees with the host
    oracle": infinities must agree exactly (a finite answer for an
    unreachable pair is as wrong as the reverse), finite values within
    ``rel_tol`` relative tolerance.  Shared by the serve drivers, the
    live-serving validators, and the tests so the correctness contract
    cannot drift between spellings."""
    if not (np.isfinite(want) and np.isfinite(got)):
        # both +inf (unreachable) is the only non-finite agreement;
        # NaN anywhere is always a mismatch
        return not (np.isinf(want) and np.isinf(got))
    return abs(got - want) > rel_tol * max(want, 1.0)


def pair(g: Graph, s: int, t: int) -> float:
    """s->t distance with target early exit (unidirectional Dijkstra)."""
    if s == t:
        return 0.0
    dist = np.full(g.n, np.inf)
    dist[s] = 0.0
    pq = [(0.0, int(s))]
    while pq:
        d, u = heapq.heappop(pq)
        if u == t:
            return d
        if d > dist[u]:
            continue
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            nd = d + float(w)
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, int(v)))
    return np.inf


def bidirectional(g: Graph, s: int, t: int) -> float:
    """Bidirectional Dijkstra [20]: meet-in-the-middle with the standard
    top(fwd)+top(bwd) >= mu stopping criterion."""
    if s == t:
        return 0.0
    INF = np.inf
    dist_f = {int(s): 0.0}
    dist_b = {int(t): 0.0}
    done_f: set = set()
    done_b: set = set()
    pq_f = [(0.0, int(s))]
    pq_b = [(0.0, int(t))]
    mu = INF

    def expand(pq, dist, done, other_dist):
        nonlocal mu
        d, u = heapq.heappop(pq)
        if u in done:
            return
        done.add(u)
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            v = int(v)
            nd = d + float(w)
            if nd < dist.get(v, INF):
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
            if v in other_dist:
                mu = min(mu, nd + other_dist[v])

    while pq_f and pq_b:
        if pq_f[0][0] + pq_b[0][0] >= mu:
            break
        if pq_f[0][0] <= pq_b[0][0]:
            expand(pq_f, dist_f, done_f, dist_b)
        else:
            expand(pq_b, dist_b, done_b, dist_f)
    return mu
