"""Host-side exact path reconstruction over the witness tables
(DESIGN.md §10).

The device index answers *distances* with (min,+) algebra; since PR 3
every tropical reduction also records its argmin:

  * ``frag_next``  — first hop of each intra-fragment shortest path,
  * ``piece_next`` — the same for each DRA piece (flat layout shared
    with ``piece_flat``),
  * ``super_next`` — first hop through the SUPER overlay closure,
  * the serve-path combine returns the winning boundary pair (b1, b2)
    packed into an int32 witness (``serve_step_w`` and friends).

``PathUnwinder`` walks those tables back to a concrete node sequence.
Every super-overlay hop is overlay-*adjacent* by the successor-matrix
invariant, so it resolves to either an E_B slot (a real graph edge
between two boundary nodes) or a fragment boundary-clique slot, which
recursively unwinds through that fragment's ``frag_next``.  No graph
search runs anywhere — unwinding is pure table chasing, O(path length).

Exactness: each table's successor entries are argmins of the exact
distance recurrences, so the unwound edge sequence sums to exactly the
served distance (integer weights make f32/f64 agreement bitwise; the
differential harness in tests/test_paths.py enforces equality against
both ``serve_step`` and host Dijkstra).

Epoch discipline: an unwinder snapshots the arrays it needs at
construction, so it stays internally consistent even while the engine
publishes new epochs; pair it with witnesses served by the *same*
epoch's index (EpochedEngine.query_path does this for you).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from . import hierarchy
from .device_engine import (WIT_LOCAL, WIT_NONE, WIT_PIECE, BuildPlan,
                            DeviceIndex, _overlay_size,
                            overlay_slot_table)


class PathUnwinder:
    """Walk witness tables from one epoch's (DeviceIndex, BuildPlan).

    Everything read from ``plan`` here is *structure* (piece registry,
    fragment/boundary lookups, SUPER slot topology), which weight
    updates never mutate — so a snapshot stays valid across refreshes.
    The one weight-dependent host table, the overlay slot provenance,
    travels WITH the index epoch (``dix.host_ov_slot``, written by the
    build/refresh stages); the plan-derived fallback below is for
    standalone indices that never saw a refresh.

    Hierarchical epochs (DESIGN.md §12/§13) have no dense
    ``super_next``; the overlay walk x -> y is instead *derived* here
    from the per-level snapshots (each level's group closures + the
    top closure): the winning route is recomputed host-side over the
    small per-pair candidate sets — O(mb2^2) numpy per level, exact
    because every table entry is the same f32 the device served — and
    then expanded level by level (``_route`` recursing down the
    ladder) until every hop is overlay-adjacent, at which point the
    ordinary slot expansion below takes over.
    """

    def __init__(self, dix: DeviceIndex, plan: BuildPlan):
        self.plan = plan
        self.s1 = _overlay_size(dix)                 # S + 1
        # device tables, snapshotted to host numpy
        self.agent_of = np.asarray(dix.agent_of)
        self.piece_gid = np.asarray(dix.piece_gid)
        self.pos_in_piece = np.asarray(dix.pos_in_piece)
        self.frag_next = np.asarray(dix.frag_next)
        self.piece_next = np.asarray(dix.piece_next)
        self.super_next = np.asarray(dix.super_next)
        self.hier = plan.hier if len(dix.sf_of) else None
        if self.hier is not None:
            # per-grouping-level snapshots (lists indexed by lvl - 1)
            self.sf_closure = [np.asarray(a) for a in dix.sf_closure]
            self.sf_next = [np.asarray(a) for a in dix.sf_next]
            self.l2row_t = [np.asarray(a) for a in dix.l2row]
            self.d2 = np.asarray(dix.d2)
            self.d2_next = np.asarray(dix.d2_next)
            l2s = getattr(dix, "host_l2_slot", None)
            self.l2_slot = (list(l2s) if l2s is not None
                            else [hierarchy.l2_slot_map(h)
                                  for h in self.hier])
        # position -> original id, per fragment (inverse of the plan's
        # frag_of/pos_in_frag lookups)
        k, maxf = plan.k, plan.maxf
        self.frag_nodes = np.full((k, maxf), -1, np.int64)
        hot = np.nonzero(plan.frag_of >= 0)[0]
        self.frag_nodes[plan.frag_of[hot], plan.pos_in_frag[hot]] = hot
        # super id -> (home fragment, position, original id)
        S = plan.S
        self.super_frag = np.full(S, -1, np.int64)
        self.super_pos = np.zeros(S, np.int64)
        fi_idx, b_idx = np.nonzero(plan.bvalid)
        sid = plan.bnd_super[fi_idx, b_idx]
        self.super_frag[sid] = fi_idx
        self.super_pos[sid] = plan.bpos[fi_idx, b_idx]
        self.super_node = np.where(
            self.super_frag >= 0,
            self.frag_nodes[self.super_frag, self.super_pos], -1)
        # winning slot per overlay adjacency pair, paired with this
        # dix's overlay-closure epoch (see class docstring); dense
        # epochs carry the [S, S] table, hierarchical epochs the
        # sparse OvSlotMap (sub-quadratic host memory)
        ov = getattr(dix, "host_ov_slot", None)
        if ov is None:
            ov = (hierarchy.ov_slot_map(plan) if self.hier is not None
                  else overlay_slot_table(plan))
        self.ov_slot = ov

    def _slot_of(self, a: int, b: int) -> int:
        """Winning level-1 slot for overlay adjacency (a, b), -1 if
        none — dense-table or sparse-map lookup, whichever this epoch
        carries."""
        if isinstance(self.ov_slot, hierarchy.SlotMap):
            return self.ov_slot.lookup(a, b)
        return int(self.ov_slot[a, b])

    # ---- table walks ---------------------------------------------------
    def _frag_walk(self, fi: int, pa: int, pb: int) -> List[int]:
        """Original-id node sequence of the fragment-internal shortest
        path from position pa to pb (inclusive ends)."""
        nxt = self.frag_next[fi]
        seq = [pa]
        u = pa
        while u != pb:
            u = int(nxt[u, pb])
            if u < 0 or len(seq) > nxt.shape[0]:
                raise RuntimeError(
                    f"inconsistent frag_next walk (frag {fi}, "
                    f"{pa}->{pb})")
            seq.append(u)
        return [int(self.frag_nodes[fi, p]) for p in seq]

    def _piece_walk(self, gid: int, pa: int, pb: int) -> List[int]:
        plan = self.plan
        cap = int(plan.piece_cap[gid])
        base = int(plan.piece_base[gid])
        nxt = self.piece_next[base:base + cap * cap].reshape(cap, cap)
        members = plan.piece_members[gid]
        seq = [pa]
        u = pa
        while u != pb:
            u = int(nxt[u, pb])
            if u < 0 or len(seq) > cap:
                raise RuntimeError(
                    f"inconsistent piece_next walk (piece {gid}, "
                    f"{pa}->{pb})")
            seq.append(u)
        return [int(members[p]) for p in seq]

    def _leg_to_agent(self, s: int) -> List[int]:
        """s -> its agent, inside s's piece ([s] when s IS an agent or a
        trivial node)."""
        gid = int(self.piece_gid[s])
        if gid < 0:
            return [int(s)]
        return self._piece_walk(gid, int(self.pos_in_piece[s]),
                                int(self.plan.piece_agent_pos[gid]))

    def _super_walk(self, x: int, y: int) -> List[int]:
        """Overlay-adjacent super-id sequence x -> y: a super_next
        chase on dense epochs, the derived hierarchical route on
        hierarchical epochs."""
        if self.hier is not None:
            return self._route(1, x, y)
        seq = [x]
        u = x
        while u != y:
            u = int(self.super_next[u, y])
            if u < 0 or len(seq) > self.s1:
                raise RuntimeError(
                    f"inconsistent super_next walk ({x}->{y})")
            seq.append(u)
        return seq

    # ---- hierarchical overlay walks (DESIGN.md §12/§13) ----------------
    # id/level vocabulary: "level-1 ids" are super (overlay) ids;
    # grouping level lvl (hier[lvl - 1]) groups level-lvl ids and its
    # group boundaries form the level-(lvl + 1) id space; the top
    # (lvl == len(hier) + 1) ids index the d2 closure.

    def _sf_walk(self, lvl: int, sf: int, pa: int, pb: int) -> List[int]:
        """Level-``lvl`` id sequence of the within-group shortest path
        from group-local position pa to pb (inclusive ends); every hop
        is level-``lvl``-adjacent by the successor-matrix invariant,
        one level up from _frag_walk."""
        h = self.hier[lvl - 1]
        nxt = self.sf_next[lvl - 1][sf]
        seq = [pa]
        u = pa
        while u != pb:
            u = int(nxt[u, pb])
            if u < 0 or len(seq) > nxt.shape[0]:
                raise RuntimeError(
                    f"inconsistent sf_next walk (lvl {lvl}, sf {sf}, "
                    f"{pa}->{pb})")
            seq.append(u)
        return [int(h.sf_members[sf, p]) for p in seq]

    def _l2_walk(self, c: int, d: int) -> List[int]:
        """Top-level-adjacent id sequence c -> d from d2_next."""
        seq = [c]
        u = c
        while u != d:
            u = int(self.d2_next[u, d])
            if u < 0 or len(seq) > self.d2_next.shape[0]:
                raise RuntimeError(
                    f"inconsistent d2_next walk ({c}->{d})")
            seq.append(u)
        return seq

    def _dist_block(self, lvl: int, xs, ys) -> np.ndarray:
        """[len(xs), len(ys)] exact distances between level-``lvl``
        ids from the epoch snapshots: the d2 closure at the top, else
        min(same-group closure, lift through the group boundary one
        level up) — the same recurrence the device combine evaluates.
        Integer edge weights keep every f32 sum exact, so an argmin
        over this block always reproduces a servable route."""
        xs = np.asarray(xs, np.int64)
        ys = np.asarray(ys, np.int64)
        if lvl == len(self.hier) + 1:
            return self.d2[np.ix_(xs, ys)]
        inf = np.float32(np.inf)
        if xs.size == 0 or ys.size == 0:
            return np.full((xs.size, ys.size), inf, np.float32)
        h = self.hier[lvl - 1]
        sfx, px = h.sf_of[xs], h.pos_in_sf[xs]
        sfy, py = h.sf_of[ys], h.pos_in_sf[ys]
        cls = self.sf_closure[lvl - 1]
        same = sfx[:, None] == sfy[None, :]
        out = np.where(same,
                       cls[sfx[:, None], px[:, None], py[None, :]], inf)
        if h.bnd2_valid.shape[1] == 0:
            return out
        row = self.l2row_t[lvl - 1]
        RX = np.where(h.bnd2_valid[sfx], row[sfx, px], inf)
        RY = np.where(h.bnd2_valid[sfy], row[sfy, py], inf)
        IX = np.where(h.bnd2_valid[sfx], h.bnd2_sid[sfx], 0)
        IY = np.where(h.bnd2_valid[sfy], h.bnd2_sid[sfy], 0)
        U, inv = np.unique(np.concatenate([IX.ravel(), IY.ravel()]),
                           return_inverse=True)
        mix = inv[:IX.size].reshape(IX.shape)
        miy = inv[IX.size:].reshape(IY.shape)
        B = self._dist_block(lvl + 1, U, U)
        # tropical RX*B then gather-min against each y's boundary rows
        x2 = np.min(RX[:, :, None] + B[mix], axis=1)       # [nx, |U|]
        vb = np.min(x2[:, miy] + RY[None, :, :], axis=2)   # [nx, ny]
        return np.minimum(out, vb)

    def _expand_hop(self, lvl: int, a: int, b: int) -> List[int]:
        """One level-``lvl`` adjacency hop -> level-(lvl-1) ids AFTER
        a's node (cross slot: the far endpoint of the underlying
        level-(lvl-1) adjacency; clique slot: the within-group walk
        one level down)."""
        h = self.hier[lvl - 2]
        slot = self.l2_slot[lvl - 2].lookup(a, b)
        if slot < 0:
            raise RuntimeError(
                f"no level-{lvl} slot for hop {a}->{b}")
        ov = int(h.l2_ov_slot[slot])
        if ov >= 0:               # cross slot: one hop one level down
            if lvl == 2:
                su = int(self.plan.sup_src[ov])
                sv = int(self.plan.sup_dst[ov])
            else:
                hh = self.hier[lvl - 3]
                su, sv = int(hh.l2_src[ov]), int(hh.l2_dst[ov])
            return [sv] if int(h.sid2_of[su]) == a else [su]
        sf = int(h.l2_sf[slot])
        if int(h.l2_src[slot]) == a:
            pa, pb = int(h.l2_pu[slot]), int(h.l2_pv[slot])
        else:
            pa, pb = int(h.l2_pv[slot]), int(h.l2_pu[slot])
        return self._sf_walk(lvl - 1, sf, pa, pb)[1:]

    def _route(self, lvl: int, x: int, y: int) -> List[int]:
        """Level-``lvl``-adjacent id sequence x -> y through the
        hierarchy: re-derive the winning route (same-group closure vs
        lift through the group boundary one level up) from the epoch
        snapshots, then expand the upper leg hop by hop.  At the top
        it is a plain d2_next chase."""
        if lvl == len(self.hier) + 1:
            return self._l2_walk(x, y)
        h = self.hier[lvl - 1]
        sfx, sfy = int(h.sf_of[x]), int(h.sf_of[y])
        px, py = int(h.pos_in_sf[x]), int(h.pos_in_sf[y])
        va = (self.sf_closure[lvl - 1][sfx, px, py] if sfx == sfy
              else np.float32(np.inf))
        vx = np.nonzero(h.bnd2_valid[sfx])[0]
        vy = np.nonzero(h.bnd2_valid[sfy])[0]
        vb = np.float32(np.inf)
        if vx.size and vy.size:
            a_row = self.l2row_t[lvl - 1][sfx, px, vx]
            b_row = self.l2row_t[lvl - 1][sfy, py, vy]
            d_blk = self._dist_block(lvl + 1, h.bnd2_sid[sfx, vx],
                                     h.bnd2_sid[sfy, vy])
            tot = a_row[:, None] + d_blk + b_row[None, :]
            ai, bi = np.unravel_index(int(np.argmin(tot)), tot.shape)
            vb = tot[ai, bi]
        if not (np.isfinite(va) or np.isfinite(vb)):
            raise RuntimeError(
                f"unreachable level-{lvl} route {x}->{y}")
        if va <= vb:
            return self._sf_walk(lvl, sfx, px, py)
        a_slot, b_slot = int(vx[ai]), int(vy[bi])
        seq = self._sf_walk(lvl, sfx, px, int(h.bnd2_pos[sfx, a_slot]))
        up = self._route(lvl + 1, int(h.bnd2_sid[sfx, a_slot]),
                         int(h.bnd2_sid[sfy, b_slot]))
        for u2, v2 in zip(up, up[1:]):
            seq += self._expand_hop(lvl + 1, u2, v2)
        seq += self._sf_walk(lvl, sfy, int(h.bnd2_pos[sfy, b_slot]),
                             py)[1:]
        return seq

    def _expand_super_hop(self, a: int, b: int) -> List[int]:
        """One overlay adjacency hop -> original node ids AFTER a's
        node (E_B slot: the neighbour; clique slot: the intra-fragment
        path)."""
        plan = self.plan
        slot = self._slot_of(a, b)
        if slot < 0:
            raise RuntimeError(f"no overlay slot for super hop {a}->{b}")
        fi = int(plan.sup_fi[slot])
        if fi < 0:                      # E_B: a real boundary-boundary edge
            return [int(self.super_node[b])]
        if a == int(plan.sup_src[slot]):
            pa, pb = int(plan.sup_pu[slot]), int(plan.sup_pv[slot])
        else:
            pa, pb = int(plan.sup_pv[slot]), int(plan.sup_pu[slot])
        return self._frag_walk(fi, pa, pb)[1:]

    # ---- public API ----------------------------------------------------
    def unwind(self, s: int, t: int, dist: float,
               wit: int) -> Optional[List[int]]:
        """(s, t, served distance, served witness) -> node sequence of
        an exact shortest path, or None when t is unreachable."""
        s, t, wit = int(s), int(t), int(wit)
        if s == t:
            return [s]
        if not np.isfinite(dist) or wit == WIT_NONE:
            return None
        us, ut = int(self.agent_of[s]), int(self.agent_of[t])
        if us == ut:                                   # case 1
            if wit == WIT_PIECE:
                gid = int(self.piece_gid[s])
                return self._piece_walk(gid, int(self.pos_in_piece[s]),
                                        int(self.pos_in_piece[t]))
            leg_s = self._leg_to_agent(s)              # WIT_VIA_AGENT
            leg_t = self._leg_to_agent(t)
            return leg_s + leg_t[::-1][1:]
        # case 2: s -> u_s -> (middle) -> u_t -> t
        plan = self.plan
        fs, ft = int(plan.frag_of[us]), int(plan.frag_of[ut])
        ps, pt = int(plan.pos_in_frag[us]), int(plan.pos_in_frag[ut])
        path = self._leg_to_agent(s)
        if wit == WIT_LOCAL:
            path += self._frag_walk(fs, ps, pt)[1:]
        else:                                          # packed (x, y)
            x, y = wit // self.s1, wit % self.s1
            path += self._frag_walk(fs, ps, int(self.super_pos[x]))[1:]
            sup = self._super_walk(x, y)
            for a, b in zip(sup, sup[1:]):
                path += self._expand_super_hop(a, b)
            path += self._frag_walk(ft, int(self.super_pos[y]), pt)[1:]
        leg_t = self._leg_to_agent(t)
        return path + leg_t[::-1][1:]

    def unwind_many(self, s, t, dist, wit) -> List[Optional[List[int]]]:
        return [self.unwind(a, b, d, w)
                for a, b, d, w in zip(np.asarray(s), np.asarray(t),
                                      np.asarray(dist), np.asarray(wit))]


def unwind_path(dix: DeviceIndex, plan: BuildPlan, s: int, t: int,
                dist: float, wit: int) -> Optional[List[int]]:
    """One-shot convenience around PathUnwinder (build the unwinder
    once and reuse it when serving many queries)."""
    return PathUnwinder(dix, plan).unwind(s, t, dist, wit)


def path_weight(g, path: Sequence[int]) -> float:
    """Sum of edge weights along ``path``, validating every consecutive
    pair is a real edge of ``g``.  Raises ValueError on a broken hop —
    the differential tests lean on this to reject 'plausible' paths."""
    path = list(path)
    if len(path) <= 1:
        return 0.0
    u = np.asarray(path[:-1])
    v = np.asarray(path[1:])
    eid = g.edge_ids(u, v)
    if (eid < 0).any():
        bad = int(np.nonzero(eid < 0)[0][0])
        raise ValueError(
            f"path hop ({path[bad]}, {path[bad + 1]}) is not an edge")
    return float(g.edge_w[eid].sum())
