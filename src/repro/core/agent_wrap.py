"""Agent + X wrappers (paper Exp-4/Exp-5: Agent+Dijkstra/CH/ArcFlags).

Agents/DRAs are a light-weight front: X is built on the *shrink graph*
(2/3 of the input on road graphs), and a query (s, t) becomes
dist(s,u_s) + X(u_s, u_t) + dist(u_t,t), with same-DRA queries answered
from the agent tables alone (paper §VI-B case 1).

Role: baseline combinators for the auxiliary-workload experiments
(DESIGN.md §8).  Invariant: wrapping never changes answers — every
wrapped oracle stays exact vs host Dijkstra, because the agent
decomposition is the paper's exact case split, not a heuristic.
"""
from __future__ import annotations

import numpy as np

from . import dijkstra
from .agents import DRAResult, compute_dras
from .graph import Graph


class AgentAccelerated:
    """Wraps a shrink-graph query oracle with the agent/DRA front-end."""

    def __init__(self, g: Graph, inner_factory, c: int = 2,
                 dras: DRAResult | None = None):
        self.g = g
        self.dras = dras if dras is not None else compute_dras(g, c=c)
        nodes = self.dras.shrink_nodes()
        self.shrink, self.shrink_ids = g.subgraph(nodes)
        self.to_shrink = -np.ones(g.n, dtype=np.int64)
        self.to_shrink[self.shrink_ids] = np.arange(self.shrink_ids.size)
        self.inner = inner_factory(self.shrink)

    def _same_dra(self, s: int, t: int, u: int) -> float:
        d = self.dras
        if s == u:
            return float(d.dist_to_agent[t])
        if t == u:
            return float(d.dist_to_agent[s])
        if d.piece_of[s] == d.piece_of[t]:
            for a in d.agents:
                if a.agent == u:
                    piece = a.pieces[int(d.piece_of[s])]
                    sub, ids = self.g.subgraph(piece)
                    remap = {int(x): k for k, x in enumerate(ids)}
                    return float(dijkstra.pair(sub, remap[s], remap[t]))
        return float(d.dist_to_agent[s] + d.dist_to_agent[t])

    def query(self, s: int, t: int) -> float:
        if s == t:
            return 0.0
        us = int(self.dras.agent_of[s])
        ut = int(self.dras.agent_of[t])
        if us == ut:
            return self._same_dra(s, t, us)
        mid = self.inner.query(int(self.to_shrink[us]),
                               int(self.to_shrink[ut]))
        return (float(self.dras.dist_to_agent[s]) + mid
                + float(self.dras.dist_to_agent[t]))


class PlainDijkstra:
    """Adapter so plain/bidirectional Dijkstra fit the oracle protocol."""

    def __init__(self, g: Graph, bidirectional: bool = False):
        self.g = g
        self.bi = bidirectional

    def query(self, s: int, t: int) -> float:
        if self.bi:
            return dijkstra.bidirectional(self.g, s, t)
        return dijkstra.pair(self.g, s, t)
