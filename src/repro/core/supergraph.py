"""SUPER graphs (paper §V-A) and the full DISLAND preprocessing pipeline.

Preprocessing (paper §VI-A, Fig. 7):
  1. compDRAs -> maximal agents + DRAs (agents.py)
  2. per-DRA agent->node distances (stored in DRAResult)
  3. shrink graph G[A]
  4. BGP partition of the shrink graph into fragments of ~ c*floor(sqrt n)
  5. per-fragment hybrid landmark cover over the boundary nodes
  6. SUPER graph assembly: boundary nodes + landmarks; cross-fragment
     original edges + per-fragment enforced edges (weights = local
     shortest distances Upsilon).

Everything here is host-side numpy (one-shot, linear-ish); the *products*
are padded tensors the device engine consumes (device_engine.py).

Role: the one build pipeline behind every index (DESIGN.md §7).  Owned
invariants: the SUPER graph preserves all cross-fragment boundary
distances of the input graph, and ``reweight_index`` reproduces
``build_index`` on a reweighted graph with the *same structure* —
which is what makes refresh ≡ rebuild comparisons meaningful at all
(DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..obs import trace
from .agents import DRAResult, compute_dras
from .graph import Graph
from .landmarks import HybridCover, hybrid_cover
from .partition import PartitionResult, partition_bgp


@dataclasses.dataclass
class Fragment:
    nodes: np.ndarray        # original node ids in this fragment
    graph: Graph             # induced subgraph (local ids)
    boundary_local: np.ndarray
    cover: HybridCover       # local ids


@dataclasses.dataclass
class SuperGraph:
    graph: Graph             # SUPER graph over compact ids
    node_ids: np.ndarray     # compact id -> original node id
    id_of: dict              # original node id -> compact id


@dataclasses.dataclass
class DislandIndex:
    """All auxiliary structures DISLAND query answering needs."""
    g: Graph
    dras: DRAResult
    shrink: Graph
    shrink_ids: np.ndarray       # shrink-local -> original id
    shrink_id_of: np.ndarray     # original -> shrink-local (-1 if removed)
    partition: PartitionResult   # over shrink-local ids
    fragments: List[Fragment]    # nodes/graph in original/local id spaces
    super_graph: SuperGraph
    frag_of: np.ndarray          # original id -> fragment id (-1 if in DRA)
    timings: dict

    # -- extra-space accounting (paper §VI "Extra space analysis") -------
    def extra_space_edges(self) -> dict:
        agent_edges = sum(a.nodes.size for a in self.dras.agents)
        enforced = sum(f.cover.n_enforced_edges for f in self.fragments)
        cross = int(self.super_graph.graph.m)
        return {
            "agent_dra_edges": agent_edges,
            "super_graph_edges": cross,
            "enforced_edges": enforced,
            "total": agent_edges + cross,
        }


def build_index(g: Graph, c: int = 2, use_cost_model: bool = True,
                seed: int = 0) -> DislandIndex:
    """Run the full preprocessing module (paper Fig. 7)."""
    # stage wall-times flow through the one span API (DESIGN.md §16):
    # the same measurement fills the index's ``timings`` dict and, when
    # tracing is on, the build trace
    timings = {}
    with trace.timed("build.compDRAs", timings, "compDRAs", n=g.n):
        dras = compute_dras(g, c=c)

    with trace.timed("build.shrink_graph", timings, "shrink_graph"):
        shrink_nodes = dras.shrink_nodes()
        shrink, shrink_ids = g.subgraph(shrink_nodes)
        shrink_id_of = -np.ones(g.n, dtype=np.int64)
        shrink_id_of[shrink_ids] = np.arange(shrink_ids.size)

    with trace.timed("build.partition", timings, "partition"):
        gamma = max(4, c * int(np.floor(np.sqrt(g.n))))
        part = partition_bgp(shrink, gamma, seed=seed)

    t0 = time.perf_counter()
    boundary = part.boundary_mask(shrink)
    fragments: List[Fragment] = []
    frag_of = -np.ones(g.n, dtype=np.int64)
    for i in range(part.n_fragments):
        loc = part.fragment_nodes(i)            # shrink-local ids
        orig = shrink_ids[loc]                  # original ids
        frag_of[orig] = i
        fg, fids = shrink.subgraph(loc)         # fids: frag-local -> shrink
        # boundary nodes in frag-local ids
        bmask = boundary[fids]
        bl = np.nonzero(bmask)[0].astype(np.int32)
        cover = hybrid_cover(fg, bl, use_cost_model=use_cost_model)
        fragments.append(Fragment(nodes=shrink_ids[fids], graph=fg,
                                  boundary_local=bl, cover=cover))
    timings["hybrid_covers"] = time.perf_counter() - t0
    trace.event("build.hybrid_covers", t0,
                t0 + timings["hybrid_covers"],
                k=part.n_fragments)

    with trace.timed("build.super_graph", timings, "super_graph"):
        sg = _assemble_super(g, shrink, shrink_ids, part, fragments)

    return DislandIndex(g=g, dras=dras, shrink=shrink,
                        shrink_ids=shrink_ids, shrink_id_of=shrink_id_of,
                        partition=part, fragments=fragments, super_graph=sg,
                        frag_of=frag_of, timings=timings)


def reweight_index(ix: DislandIndex, g_new: Graph) -> DislandIndex:
    """Same index *structure*, new edge weights (DESIGN.md §9).

    Weight updates never change cut nodes, BCCs, DRAs, fragments, or
    the SUPER node universe — all are purely topological — so a live
    traffic batch only invalidates the weight-dependent products.  This
    rebuilds exactly those on the host: per-DRA agent distances, the
    shrink/fragment subgraph weights.  Covers and the SUPER graph are
    carried over structurally; their cached enforced-edge *distances*
    are stale, which the device build never reads (it regathers Upsilon
    weights from the fragment APSP, device_engine.super_weights) — use
    ``build_index(g_new)`` if a fully-consistent host engine is needed.

    ``build_device_index(reweight_index(ix, g_new))`` is therefore the
    from-scratch reference the incremental ``refresh_index`` path is
    differentially tested against, array-for-array.
    """
    from .agents import _sssp_within

    if g_new.n != ix.g.n or g_new.m != ix.g.m:
        raise ValueError("reweight_index requires identical topology")
    dist_to_agent = ix.dras.dist_to_agent.copy()
    agents = []
    for a in ix.dras.agents:
        allp = np.unique(np.concatenate(a.pieces))
        dmap = _sssp_within(g_new, a.agent, allp)
        d = np.array([dmap.get(int(x), np.inf) for x in a.nodes])
        agents.append(dataclasses.replace(a, dist_to_agent=d))
        dist_to_agent[a.nodes] = d
    dras = dataclasses.replace(ix.dras, agents=agents,
                               dist_to_agent=dist_to_agent)

    shrink, shrink_ids = g_new.subgraph(ix.shrink_ids)
    fragments = []
    for i, f in enumerate(ix.fragments):
        loc = ix.partition.fragment_nodes(i)
        fg, _fids = shrink.subgraph(loc)
        fragments.append(dataclasses.replace(f, graph=fg))

    return dataclasses.replace(
        ix, g=g_new, dras=dras, shrink=shrink, fragments=fragments,
        timings=dict(ix.timings, reweighted=True))


def _assemble_super(g: Graph, shrink: Graph, shrink_ids: np.ndarray,
                    part: PartitionResult,
                    fragments: List[Fragment]) -> SuperGraph:
    """SUPER graph: boundary nodes + landmarks, E_B + enforced edges."""
    eu, ev, ew = [], [], []
    members: set = set()
    # E_B: original (shrink) edges with both endpoints boundary
    boundary = part.boundary_mask(shrink)
    bmask_u = boundary[shrink.edge_u]
    bmask_v = boundary[shrink.edge_v]
    both = bmask_u & bmask_v
    for u, v, w in zip(shrink.edge_u[both], shrink.edge_v[both],
                       shrink.edge_w[both]):
        ou, ov = int(shrink_ids[u]), int(shrink_ids[v])
        eu.append(ou)
        ev.append(ov)
        ew.append(float(w))
        members.add(ou)
        members.add(ov)
    # enforced edges per fragment (local ids -> original ids)
    for f in fragments:
        fmap = f.nodes
        for b in f.boundary_local:
            members.add(int(fmap[b]))
        for (u, x, d) in f.cover.landmark_edges:
            ou, ox = int(fmap[int(u)]), int(fmap[int(x)])
            if ou == ox:
                continue
            eu.append(ou)
            ev.append(ox)
            ew.append(float(d))
            members.add(ou)
            members.add(ox)
        for (a, b, d) in f.cover.direct_edges:
            oa, ob = int(fmap[int(a)]), int(fmap[int(b)])
            if oa == ob:
                continue
            eu.append(oa)
            ev.append(ob)
            ew.append(float(d))
            members.add(oa)
            members.add(ob)
    node_ids = np.array(sorted(members), dtype=np.int64)
    id_of = {int(v): i for i, v in enumerate(node_ids)}
    if eu:
        lu = np.array([id_of[x] for x in eu], dtype=np.int32)
        lv = np.array([id_of[x] for x in ev], dtype=np.int32)
        sg = Graph.from_edges(node_ids.size, lu, lv, np.array(ew))
    else:
        sg = Graph.from_edges(max(node_ids.size, 0), [], [], [])
    return SuperGraph(graph=sg, node_ids=node_ids, id_of=id_of)
