"""SUPER graphs (paper §V-A) and the full DISLAND preprocessing pipeline.

Preprocessing (paper §VI-A, Fig. 7):
  1. compDRAs -> maximal agents + DRAs (agents.py)
  2. per-DRA agent->node distances (stored in DRAResult)
  3. shrink graph G[A]
  4. BGP partition of the shrink graph into fragments of ~ c*floor(sqrt n)
  5. per-fragment hybrid landmark cover over the boundary nodes
  6. SUPER graph assembly: boundary nodes + landmarks; cross-fragment
     original edges + per-fragment enforced edges (weights = local
     shortest distances Upsilon).

Everything here is host-side numpy (one-shot, linear-ish); the *products*
are padded tensors the device engine consumes (device_engine.py).

Since the staged-pipeline refactor (DESIGN.md §17) the build is explicit
stage functions over a ``HostBuildPlan`` — the host mirror of the
device-side ``BuildPlan`` idiom — with a ``build_workers`` knob:
per-fragment covers run process-parallel over a shared read-only CSR,
and ``start_build``/``HostBuild.finish`` expose the structural index
*before* the covers land so the device build can overlap them (the
device stages never read covers; only ``_assemble_super`` does).

Role: the one build pipeline behind every index (DESIGN.md §7).  Owned
invariants: the SUPER graph preserves all cross-fragment boundary
distances of the input graph; ``build_index(build_workers=N)`` is
array-equal to the serial build for every index table (the
serial-parity contract — workers only relocate deterministic
per-fragment work, they never reorder or re-randomize it); and
``reweight_index`` reproduces ``build_index`` on a reweighted graph
with the *same structure* — which is what makes refresh ≡ rebuild
comparisons meaningful at all (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
from concurrent.futures import ProcessPoolExecutor, as_completed
from typing import Callable, List, Optional

import numpy as np

from ..obs import trace
from .agents import DRAResult, compute_dras
from .graph import Graph, SharedGraph
from .landmarks import HybridCover, hybrid_cover
from .partition import PartitionResult, partition_bgp

#: fork inherits the parent's read-only pages and needs no module
#: re-import per worker; spawn is the fallback off Linux.  Cover
#: workers touch numpy only — never JAX — so forking a process that
#: has initialized XLA is safe here.
_MP_START = "fork" if "fork" in mp.get_all_start_methods() else "spawn"


@dataclasses.dataclass
class Fragment:
    nodes: np.ndarray        # original node ids in this fragment
    graph: Graph             # induced subgraph (local ids)
    boundary_local: np.ndarray
    cover: Optional[HybridCover]   # local ids; None until cover_stage


@dataclasses.dataclass
class SuperGraph:
    graph: Graph             # SUPER graph over compact ids
    node_ids: np.ndarray     # compact id -> original node id
    id_of: dict              # original node id -> compact id


@dataclasses.dataclass
class DislandIndex:
    """All auxiliary structures DISLAND query answering needs."""
    g: Graph
    dras: DRAResult
    shrink: Graph
    shrink_ids: np.ndarray       # shrink-local -> original id
    shrink_id_of: np.ndarray     # original -> shrink-local (-1 if removed)
    partition: PartitionResult   # over shrink-local ids
    fragments: List[Fragment]    # nodes/graph in original/local id spaces
    super_graph: SuperGraph
    frag_of: np.ndarray          # original id -> fragment id (-1 if in DRA)
    timings: dict

    # -- extra-space accounting (paper §VI "Extra space analysis") -------
    def extra_space_edges(self) -> dict:
        agent_edges = sum(a.nodes.size for a in self.dras.agents)
        enforced = sum(f.cover.n_enforced_edges for f in self.fragments)
        cross = int(self.super_graph.graph.m)
        return {
            "agent_dra_edges": agent_edges,
            "super_graph_edges": cross,
            "enforced_edges": enforced,
            "total": agent_edges + cross,
        }


# ---------------------------------------------------------------------------
# staged host build pipeline (DESIGN.md §17)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HostBuildPlan:
    """Host-side staged build state, mirroring the device ``BuildPlan``.

    Each ``*_stage`` function below consumes the fields earlier stages
    filled and writes its own — the dependency order is the field
    order.  All stage wall-times flow through the one span API
    (DESIGN.md §16): the same measurement fills ``timings`` and, when
    tracing is on, the build trace.
    """
    g: Graph
    c: int = 2
    use_cost_model: bool = True
    seed: int = 0
    build_workers: int = 1
    cover_fn: Optional[Callable] = None   # hybrid_cover override (tests)
    timings: dict = dataclasses.field(default_factory=dict)
    # stage products
    dras: Optional[DRAResult] = None
    shrink: Optional[Graph] = None
    shrink_ids: Optional[np.ndarray] = None
    shrink_id_of: Optional[np.ndarray] = None
    partition: Optional[PartitionResult] = None
    boundary: Optional[np.ndarray] = None          # shrink-local mask
    fragments: Optional[List[Fragment]] = None
    frag_of: Optional[np.ndarray] = None
    super_graph: Optional[SuperGraph] = None


def agents_stage(plan: HostBuildPlan) -> None:
    """compDRAs: maximal agents + DRAs (paper Fig. 6)."""
    with trace.timed("build.compDRAs", plan.timings, "compDRAs",
                     n=plan.g.n):
        plan.dras = compute_dras(plan.g, c=plan.c)


def shrink_stage(plan: HostBuildPlan) -> None:
    """Shrink graph G[A]: drop DRA-represented nodes."""
    with trace.timed("build.shrink_graph", plan.timings, "shrink_graph"):
        shrink_nodes = plan.dras.shrink_nodes()
        plan.shrink, plan.shrink_ids = plan.g.subgraph(shrink_nodes)
        plan.shrink_id_of = -np.ones(plan.g.n, dtype=np.int64)
        plan.shrink_id_of[plan.shrink_ids] = np.arange(
            plan.shrink_ids.size)


def partition_stage(plan: HostBuildPlan) -> None:
    """BGP partition of the shrink graph (gamma ~ c*floor(sqrt n))."""
    with trace.timed("build.partition", plan.timings, "partition"):
        gamma = max(4, plan.c * int(np.floor(np.sqrt(plan.g.n))))
        plan.partition = partition_bgp(plan.shrink, gamma, seed=plan.seed)


def fragment_stage(plan: HostBuildPlan) -> None:
    """Batched fragment extraction; covers stay None until cover_stage.

    After this stage the index is *structurally* complete — everything
    the device build reads exists — which is the streaming handoff
    point: ``HostBuild.structural_index`` hands the device stages their
    input while the covers are still computing.
    """
    with trace.timed("build.fragments", plan.timings, "fragments",
                     k=plan.partition.n_fragments):
        plan.boundary = plan.partition.boundary_mask(plan.shrink)
        frag_of = -np.ones(plan.g.n, dtype=np.int64)
        fragments: List[Fragment] = []
        for i, (fg, fids) in enumerate(
                plan.shrink.extract_fragments(plan.partition.labels)):
            orig = plan.shrink_ids[fids]
            frag_of[orig] = i
            bl = np.nonzero(plan.boundary[fids])[0].astype(np.int32)
            fragments.append(Fragment(nodes=orig, graph=fg,
                                      boundary_local=bl, cover=None))
        plan.fragments = fragments
        plan.frag_of = frag_of


def super_stage(plan: HostBuildPlan) -> None:
    """SUPER graph assembly from the (now complete) covers."""
    with trace.timed("build.super_graph", plan.timings, "super_graph"):
        plan.super_graph = _assemble_super(
            plan.g, plan.shrink, plan.shrink_ids, plan.partition,
            plan.fragments)


# -- worker-side cover computation ------------------------------------------
# Workers attach the shared shrink CSR once (initializer), then each
# task ships only a fragment id and returns only the cover arrays.  The
# worker re-derives its fragment subgraph from the shared CSR — bit-
# identical to the parent's extract_fragments product because
# from_edges canonicalizes — so nothing graph-sized is ever pickled.
_WORKER_STATE: dict = {}


def _cover_worker_init(meta: dict, labels: np.ndarray,
                       boundary: np.ndarray, use_cost_model: bool,
                       cover_fn: Optional[Callable]) -> None:
    shared = Graph.from_shared(meta)
    _WORKER_STATE.update(
        shared=shared, shrink=shared.graph, labels=labels,
        boundary=boundary, use_cost_model=use_cost_model,
        cover_fn=cover_fn or hybrid_cover)


def _cover_worker_task(frag_id: int):
    st = _WORKER_STATE
    loc = np.nonzero(st["labels"] == frag_id)[0].astype(np.int32)
    fg, fids = st["shrink"].subgraph(loc)
    bl = np.nonzero(st["boundary"][fids])[0].astype(np.int32)
    cov = st["cover_fn"](fg, bl, st["use_cost_model"])
    return frag_id, cov.landmarks, cov.landmark_edges, cov.direct_edges


class HostBuild:
    """An in-flight host build: structural stages done, covers pending.

    ``start_build`` runs agents/shrink/partition/fragment stages
    synchronously and (for ``build_workers > 1``) submits every
    fragment cover to a process pool over the shared shrink CSR.
    ``structural_index`` is then immediately available for the device
    build — its stages never read covers — and ``finish`` joins the
    covers, assembles the SUPER graph, and returns the completed index
    (the same object ``structural_index`` returned, covers filled in
    place).

    Failure contract: if any fragment cover raises, ``finish`` cancels
    all outstanding futures, shuts the pool down, releases the shared
    block, and re-raises the original exception — no orphaned workers,
    no hang.
    """

    def __init__(self, plan: HostBuildPlan, ix: DislandIndex,
                 pool: Optional[ProcessPoolExecutor] = None,
                 futures: Optional[dict] = None,
                 shared: Optional[SharedGraph] = None):
        self.plan = plan
        self._ix = ix
        self._pool = pool
        self._futures = futures
        self._shared = shared
        self._done = False

    def structural_index(self) -> DislandIndex:
        """The index with every device-build input present (covers and
        super_graph still pending — call ``finish`` before using the
        host-side SUPER graph or serializing the index)."""
        return self._ix

    def finish(self) -> DislandIndex:
        """Join covers, assemble the SUPER graph, return the index."""
        if self._done:
            return self._ix
        plan = self.plan
        with trace.timed("build.hybrid_covers", plan.timings,
                         "hybrid_covers", k=len(plan.fragments),
                         workers=plan.build_workers):
            if self._pool is None:
                fn = plan.cover_fn or hybrid_cover
                for f in plan.fragments:
                    f.cover = fn(f.graph, f.boundary_local,
                                 plan.use_cost_model)
            else:
                self._collect_covers()
        super_stage(plan)
        self._ix.super_graph = plan.super_graph
        self._done = True
        return self._ix

    def _collect_covers(self) -> None:
        try:
            for fut in as_completed(self._futures):
                fid, lms, ledges, dedges = fut.result()
                self.plan.fragments[fid].cover = HybridCover(
                    landmarks=lms, landmark_edges=ledges,
                    direct_edges=dedges)
        except BaseException:
            # surface the *original* failure: cancel everything still
            # queued, reap the pool, then re-raise (no hang, no orphans)
            for f in self._futures:
                f.cancel()
            self._pool.shutdown(wait=True, cancel_futures=True)
            raise
        else:
            self._pool.shutdown(wait=True)
        finally:
            self._pool = None
            self._futures = None
            self._shared.close()
            self._shared.unlink()
            self._shared = None


def start_build(g: Graph, c: int = 2, use_cost_model: bool = True,
                seed: int = 0, build_workers: int = 1,
                cover_fn: Optional[Callable] = None) -> HostBuild:
    """Run the structural stages now; kick covers off in the background.

    The returned ``HostBuild`` is the streaming handoff: feed
    ``structural_index()`` to the device build immediately, then call
    ``finish()`` (which blocks on the covers) before the index is used
    host-side.  ``build_workers <= 1`` keeps everything in-process —
    covers then run inside ``finish()``, still after the device build
    had a chance to start.
    """
    plan = HostBuildPlan(g=g, c=c, use_cost_model=use_cost_model,
                         seed=seed, build_workers=build_workers,
                         cover_fn=cover_fn)
    agents_stage(plan)
    shrink_stage(plan)
    partition_stage(plan)
    fragment_stage(plan)
    ix = DislandIndex(
        g=g, dras=plan.dras, shrink=plan.shrink,
        shrink_ids=plan.shrink_ids, shrink_id_of=plan.shrink_id_of,
        partition=plan.partition, fragments=plan.fragments,
        super_graph=None, frag_of=plan.frag_of, timings=plan.timings)
    nfrag = plan.partition.n_fragments
    if build_workers > 1 and nfrag > 1:
        shared = plan.shrink.to_shared()
        try:
            pool = ProcessPoolExecutor(
                max_workers=min(build_workers, nfrag),
                mp_context=mp.get_context(_MP_START),
                initializer=_cover_worker_init,
                initargs=(shared.meta, plan.partition.labels,
                          plan.boundary, use_cost_model, cover_fn))
            futures = {pool.submit(_cover_worker_task, i): i
                       for i in range(nfrag)}
        except BaseException:
            shared.close()
            shared.unlink()
            raise
        return HostBuild(plan, ix, pool=pool, futures=futures,
                         shared=shared)
    return HostBuild(plan, ix)


def build_index(g: Graph, c: int = 2, use_cost_model: bool = True,
                seed: int = 0, build_workers: int = 1,
                cover_fn: Optional[Callable] = None) -> DislandIndex:
    """Run the full preprocessing module (paper Fig. 7)."""
    return start_build(g, c=c, use_cost_model=use_cost_model, seed=seed,
                       build_workers=build_workers,
                       cover_fn=cover_fn).finish()


def _graph_equal(a: Graph, b: Graph) -> bool:
    return (a.n == b.n and a.m == b.m
            and np.array_equal(a.indptr, b.indptr)
            and np.array_equal(a.indices, b.indices)
            and np.array_equal(a.weights, b.weights)
            and np.array_equal(a.edge_u, b.edge_u)
            and np.array_equal(a.edge_v, b.edge_v)
            and np.array_equal(a.edge_w, b.edge_w))


def index_arrays_equal(a: DislandIndex, b: DislandIndex) -> dict:
    """Field-wise array equality of two host indices.

    The serial-parity differential check (DESIGN.md §17):
    ``build_index(build_workers=N)`` must agree with the serial build
    on every table.  Returns ``{field: bool}``; callers assert
    ``all(...values())`` so a failure names the diverging field.
    """
    out = {}
    da, db = a.dras, b.dras
    out["dras.arrays"] = (
        np.array_equal(da.agent_of, db.agent_of)
        and np.array_equal(da.dist_to_agent, db.dist_to_agent)
        and np.array_equal(da.piece_of, db.piece_of)
        and da.threshold == db.threshold)
    out["dras.agents"] = (
        len(da.agents) == len(db.agents)
        and all(x.agent == y.agent
                and len(x.pieces) == len(y.pieces)
                and all(np.array_equal(p, q)
                        for p, q in zip(x.pieces, y.pieces))
                and np.array_equal(x.nodes, y.nodes)
                and np.array_equal(x.dist_to_agent, y.dist_to_agent)
                and np.array_equal(x.piece_of, y.piece_of)
                for x, y in zip(da.agents, db.agents)))
    out["shrink"] = (_graph_equal(a.shrink, b.shrink)
                     and np.array_equal(a.shrink_ids, b.shrink_ids)
                     and np.array_equal(a.shrink_id_of, b.shrink_id_of))
    out["partition"] = (
        a.partition.n_fragments == b.partition.n_fragments
        and np.array_equal(a.partition.labels, b.partition.labels))
    out["frag_of"] = np.array_equal(a.frag_of, b.frag_of)
    frag_ok = cov_ok = len(a.fragments) == len(b.fragments)
    for fa, fb in zip(a.fragments, b.fragments):
        frag_ok = (frag_ok and np.array_equal(fa.nodes, fb.nodes)
                   and _graph_equal(fa.graph, fb.graph)
                   and np.array_equal(fa.boundary_local,
                                      fb.boundary_local))
        if (fa.cover is None) != (fb.cover is None):
            cov_ok = False
        elif fa.cover is not None:
            ca, cb = fa.cover, fb.cover
            cov_ok = (cov_ok
                      and np.array_equal(ca.landmarks, cb.landmarks)
                      and np.array_equal(ca.landmark_edges,
                                         cb.landmark_edges)
                      and np.array_equal(ca.direct_edges,
                                         cb.direct_edges))
    out["fragments"] = frag_ok
    out["covers"] = cov_ok
    sa, sb = a.super_graph, b.super_graph
    if sa is None or sb is None:
        out["super_graph"] = sa is None and sb is None
    else:
        out["super_graph"] = (
            _graph_equal(sa.graph, sb.graph)
            and np.array_equal(sa.node_ids, sb.node_ids)
            and sa.id_of == sb.id_of)
    return out


def reweight_index(ix: DislandIndex, g_new: Graph) -> DislandIndex:
    """Same index *structure*, new edge weights (DESIGN.md §9).

    Weight updates never change cut nodes, BCCs, DRAs, fragments, or
    the SUPER node universe — all are purely topological — so a live
    traffic batch only invalidates the weight-dependent products.  This
    rebuilds exactly those on the host: per-DRA agent distances, the
    shrink/fragment subgraph weights.  Covers and the SUPER graph are
    carried over structurally; their cached enforced-edge *distances*
    are stale, which the device build never reads (it regathers Upsilon
    weights from the fragment APSP, device_engine.super_weights) — use
    ``build_index(g_new)`` if a fully-consistent host engine is needed.

    ``build_device_index(reweight_index(ix, g_new))`` is therefore the
    from-scratch reference the incremental ``refresh_index`` path is
    differentially tested against, array-for-array.
    """
    from .agents import _sssp_within

    if g_new.n != ix.g.n or g_new.m != ix.g.m:
        raise ValueError("reweight_index requires identical topology")
    dist_to_agent = ix.dras.dist_to_agent.copy()
    agents = []
    for a in ix.dras.agents:
        allp = np.unique(np.concatenate(a.pieces))
        dmap = _sssp_within(g_new, a.agent, allp)
        d = np.array([dmap.get(int(x), np.inf) for x in a.nodes])
        agents.append(dataclasses.replace(a, dist_to_agent=d))
        dist_to_agent[a.nodes] = d
    dras = dataclasses.replace(ix.dras, agents=agents,
                               dist_to_agent=dist_to_agent)

    shrink, shrink_ids = g_new.subgraph(ix.shrink_ids)
    fragments = []
    for i, f in enumerate(ix.fragments):
        loc = ix.partition.fragment_nodes(i)
        fg, _fids = shrink.subgraph(loc)
        fragments.append(dataclasses.replace(f, graph=fg))

    return dataclasses.replace(
        ix, g=g_new, dras=dras, shrink=shrink, fragments=fragments,
        timings=dict(ix.timings, reweighted=True))


def _assemble_super(g: Graph, shrink: Graph, shrink_ids: np.ndarray,
                    part: PartitionResult,
                    fragments: List[Fragment]) -> SuperGraph:
    """SUPER graph: boundary nodes + landmarks, E_B + enforced edges.

    One vectorized pass: per-source edge arrays (E_B, per-fragment
    landmark + direct edges, all mapped to original ids) concatenate
    into a single edge list; the member universe is their endpoints
    plus every boundary node; local ids fall out of one searchsorted.
    """
    eu_parts: List[np.ndarray] = []
    ev_parts: List[np.ndarray] = []
    ew_parts: List[np.ndarray] = []
    member_parts: List[np.ndarray] = []
    # E_B: original (shrink) edges with both endpoints boundary
    boundary = part.boundary_mask(shrink)
    both = boundary[shrink.edge_u] & boundary[shrink.edge_v]
    eu_parts.append(shrink_ids[shrink.edge_u[both]].astype(np.int64))
    ev_parts.append(shrink_ids[shrink.edge_v[both]].astype(np.int64))
    ew_parts.append(shrink.edge_w[both].astype(np.float64))
    # enforced edges per fragment (local ids -> original ids)
    for f in fragments:
        fmap = f.nodes
        member_parts.append(np.asarray(fmap[f.boundary_local],
                                       dtype=np.int64))
        for rows in (f.cover.landmark_edges, f.cover.direct_edges):
            if not len(rows):
                continue
            ou = fmap[rows[:, 0].astype(np.int64)].astype(np.int64)
            ov = fmap[rows[:, 1].astype(np.int64)].astype(np.int64)
            keep = ou != ov
            eu_parts.append(ou[keep])
            ev_parts.append(ov[keep])
            ew_parts.append(rows[keep, 2].astype(np.float64))
    eu = np.concatenate(eu_parts)
    ev = np.concatenate(ev_parts)
    ew = np.concatenate(ew_parts)
    node_ids = np.unique(np.concatenate(member_parts + [eu, ev]))
    id_of = {int(v): i for i, v in enumerate(node_ids)}
    if eu.size:
        lu = np.searchsorted(node_ids, eu).astype(np.int32)
        lv = np.searchsorted(node_ids, ev).astype(np.int32)
        sg = Graph.from_edges(node_ids.size, lu, lv, ew)
    else:
        sg = Graph.from_edges(max(node_ids.size, 0), [], [], [])
    return SuperGraph(graph=sg, node_ids=node_ids, id_of=id_of)
