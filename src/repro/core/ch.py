"""Contraction Hierarchies (Geisberger et al. [13]) — baseline + CH
integration for DISLAND (paper §VI-C).

Build: contract nodes in ascending 'importance' order (lazy-updated
priority = edge difference + contracted-neighbour count), adding witness-
checked shortcuts.  Query: bidirectional upward Dijkstra; only edges to
higher-ranked endpoints are relaxed (order-rising paths; the meeting node
is the unique order-turning apex).

Role: comparison baseline for the auxiliary workloads (DESIGN.md §8).
Invariant: every shortcut is witness-checked at insertion, so the
contracted graph preserves all pairwise distances exactly and the
bidirectional query equals plain Dijkstra on the original graph.
"""
from __future__ import annotations

import heapq
from typing import Dict, List

import numpy as np

from .graph import Graph


class CH:
    def __init__(self, g: Graph, hop_limit: int = 16,
                 witness_settle_limit: int = 64):
        self.g = g
        self.n = g.n
        self.hop_limit = hop_limit
        self.witness_settle_limit = witness_settle_limit
        self.order = np.zeros(g.n, dtype=np.int64)   # rank per node
        self.n_shortcuts = 0
        self._build()

    # ------------------------------------------------------------------
    def _witness_dist(self, adj, s: int, t: int, skip: int,
                      bound: float) -> float:
        """Bounded local Dijkstra ignoring ``skip``; settles few nodes."""
        dist = {s: 0.0}
        pq = [(0.0, s)]
        settled = 0
        while pq and settled < self.witness_settle_limit:
            d, u = heapq.heappop(pq)
            if d > dist.get(u, np.inf):
                continue
            if u == t:
                return d
            if d > bound:
                break
            settled += 1
            for v, w in adj[u].items():
                if v == skip:
                    continue
                nd = d + w
                if nd <= bound and nd < dist.get(v, np.inf):
                    dist[v] = nd
                    heapq.heappush(pq, (nd, v))
        return dist.get(t, np.inf)

    def _shortcuts_needed(self, adj, v: int) -> List[tuple]:
        """Shortcuts required to preserve distances when contracting v."""
        nbrs = list(adj[v].items())
        out = []
        for i in range(len(nbrs)):
            u, wu = nbrs[i]
            for j in range(i + 1, len(nbrs)):
                w, ww = nbrs[j]
                through = wu + ww
                if self._witness_dist(adj, u, w, v, through) > through:
                    out.append((u, w, through))
        return out

    def _build(self) -> None:
        g = self.g
        # live adjacency (remaining graph) as dict-of-dict
        adj: List[Dict[int, float]] = [dict() for _ in range(self.n)]
        for u, v, w in zip(g.edge_u, g.edge_v, g.edge_w):
            u, v, w = int(u), int(v), float(w)
            if v not in adj[u] or w < adj[u][v]:
                adj[u][v] = w
                adj[v][u] = w
        # search graph accumulates original edges + shortcuts
        search: List[Dict[int, float]] = [dict(a) for a in adj]
        deleted_nbrs = np.zeros(self.n, dtype=np.int64)

        def priority(v: int) -> float:
            sc = self._shortcuts_needed(adj, v)
            return len(sc) - len(adj[v]) + 0.5 * deleted_nbrs[v]

        pq = [(priority(v), v) for v in range(self.n)]
        heapq.heapify(pq)
        rank = 0
        contracted = np.zeros(self.n, dtype=bool)
        while pq:
            p, v = heapq.heappop(pq)
            if contracted[v]:
                continue
            # lazy re-evaluation: re-insert if priority became stale
            np_ = priority(v)
            if pq and np_ > pq[0][0]:
                heapq.heappush(pq, (np_, v))
                continue
            # contract v
            for (a, b, w) in self._shortcuts_needed(adj, v):
                if b not in adj[a] or w < adj[a][b]:
                    adj[a][b] = w
                    adj[b][a] = w
                if b not in search[a] or w < search[a][b]:
                    search[a][b] = w
                    search[b][a] = w
                    self.n_shortcuts += 1
            for u in adj[v]:
                del adj[u][v]
                deleted_nbrs[u] += 1
            adj[v].clear()
            contracted[v] = True
            self.order[v] = rank
            rank += 1
        # upward CSR: edges to higher-ranked endpoints only
        eu, ev, ew = [], [], []
        for u in range(self.n):
            for v, w in search[u].items():
                if self.order[v] > self.order[u]:
                    eu.append(u)
                    ev.append(v)
                    ew.append(w)
        self.up_head = np.array(ev, dtype=np.int32)
        self.up_w = np.array(ew, dtype=np.float64)
        ptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(ptr, np.array(eu, dtype=np.int64) + 1, 1)
        self.up_ptr = np.cumsum(ptr)
        order_idx = np.argsort(np.array(eu, dtype=np.int64), kind="stable")
        self.up_head = self.up_head[order_idx]
        self.up_w = self.up_w[order_idx]

    # ------------------------------------------------------------------
    def _upward_search(self, s: int) -> Dict[int, float]:
        dist = {int(s): 0.0}
        pq = [(0.0, int(s))]
        settled: Dict[int, float] = {}
        while pq:
            d, u = heapq.heappop(pq)
            if d > dist.get(u, np.inf):
                continue
            settled[u] = d
            a, b = self.up_ptr[u], self.up_ptr[u + 1]
            for v, w in zip(self.up_head[a:b], self.up_w[a:b]):
                v = int(v)
                nd = d + float(w)
                if nd < dist.get(v, np.inf):
                    dist[v] = nd
                    heapq.heappush(pq, (nd, v))
        return settled

    def query(self, s: int, t: int) -> float:
        if s == t:
            return 0.0
        df = self._upward_search(s)
        db = self._upward_search(t)
        mu = np.inf
        small, big = (df, db) if len(df) < len(db) else (db, df)
        for v, d in small.items():
            if v in big:
                mu = min(mu, d + big[v])
        return mu

    def settled_per_query(self, s: int, t: int) -> int:
        return len(self._upward_search(s)) + len(self._upward_search(t))

    def extra_edges(self) -> int:
        return self.n_shortcuts
