"""Two-level SUPER overlay hierarchy (DESIGN.md §12).

The dense overlay closure (`device_engine.super_stage`) is O(S^2)
memory and O(S^3) work in the boundary count S — fine at road4000
(S ~ 600), a wall at road64k (S ~ 7000+).  Hierarchical Cut Labelling
(arXiv:2311.11063) and Pruned Landmark Labeling (arXiv:1304.4661) both
reach large road networks the same way: keep every per-level closure
small.  This module applies that recursively to our own overlay:

  1. group the level-1 *fragments* into super-fragments (greedy BFS
     over the fragment quotient graph, budgeted by overlay-node count
     — topology only, so the grouping is weight-invariant and survives
     every refresh, exactly like the level-1 partition);
  2. close each super-fragment's induced overlay subgraph with the
     existing batched witness FW kernel (`ops.fw_batch_next`) at one
     pow2-padded tile shape [nsf, m2, m2];
  3. close only the level-2 boundary set (overlay nodes incident to a
     super-fragment-crossing slot) densely: a level-2 overlay graph of
     cross slots + per-super-fragment boundary cliques whose weights
     are *gathered from the super-fragment closures* — the same
     derived-weight discipline as the level-1 Upsilon weights
     (`device_engine.super_weights`), so scratch build and incremental
     refresh obtain every level-2 weight by the same gather.

Exactness mirrors the level-1 argument one level up: any overlay path
between x and y either stays inside x's super-fragment (covered by its
closure) or crosses the level-2 boundary, where it decomposes into
within-super-fragment segments (>= the clique weights) and cross slots
(= the cross edges); the dense level-2 closure is therefore the exact
overlay metric on the boundary set, and

  OD(x, y) = min( sf_closure[sf, x, y]           if sf(x) == sf(y),
                  min_{a, b} l2row[x, a] + D2[a, b] + l2row[y, b] ).

Memory drops from (S+1)^2 to nsf*m2^2 + nsf*m2*mb2 + (S2+1)^2 —
sub-quadratic in S for the sqrt-ish budget chosen below (measured and
recorded by benchmarks exp10).

Everything here is host-side numpy structure plus thin device stages;
`device_engine` owns the DeviceIndex fields, the serve-path combine,
and the refresh orchestration.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import padding

INF = np.float32(np.inf)

#: S above which build_device_index's ``hierarchy_levels="auto"``
#: switches from the dense closure to the two-level hierarchy.  Road
#: graphs near the threshold are fine either way; road4000 (S ~ 600)
#: stays dense (bit-identical to the pre-hierarchy index), road64k
#: (S ~ 7000) must not be closed densely.
AUTO_THRESHOLD = 1024


@dataclasses.dataclass
class HierPlan:
    """Host-side level-2 structure, carried on BuildPlan as ``.hier``.

    Like the rest of the plan, everything except the weight caches
    (``sf_adj``, ``l2_w``) is weight-invariant structure; a refresh
    mutates only those caches and regathers everything else.
    """

    nsf: int                 # super-fragment count
    m2: int                  # pow2-padded max overlay nodes per sf
    mb2: int                 # padded max level-2 boundary slots per sf
    S2: int                  # level-2 boundary node count
    sf_of_frag: np.ndarray   # int32 [k] fragment -> super-fragment
    sf_of: np.ndarray        # int32 [S] overlay node -> super-fragment
    pos_in_sf: np.ndarray    # int32 [S] position inside its sf
    sf_members: np.ndarray   # int64 [nsf, m2] sf slot -> overlay id (-1)
    # intra-sf slot addressing (level-1 overlay slots)
    slot_sf: np.ndarray      # int32 [Es] owning sf (-1: crosses sfs)
    slot_p2u: np.ndarray     # int32 [Es] sf-local endpoints (-1: cross)
    slot_p2v: np.ndarray
    sf_adj: np.ndarray       # f32 [nsf, m2, m2] weight cache
    # level-2 boundary registry
    bnd2_ids: np.ndarray     # int64 [S2] overlay ids, sorted
    sid2_of: np.ndarray      # int64 [S] overlay id -> level-2 id (-1)
    bnd2_pos: np.ndarray     # int32 [nsf, mb2] sf-local positions
    bnd2_valid: np.ndarray   # bool [nsf, mb2]
    bnd2_sid: np.ndarray     # int32 [nsf, mb2] level-2 id (S2 sentinel)
    # level-2 slots (fixed structure, derived weights)
    l2_src: np.ndarray       # int32 [E2] level-2 ids
    l2_dst: np.ndarray
    l2_w: np.ndarray         # f32 [E2] weight cache
    l2_sf: np.ndarray        # int32 [E2] owning sf for cliques (-1: cross)
    l2_pu: np.ndarray        # int32 [E2] sf-local gather coords (cliques)
    l2_pv: np.ndarray
    l2_ov_slot: np.ndarray   # int64 [E2] level-1 slot id (cross; -1 else)

    def overlay_bytes(self) -> int:
        """Device bytes of the hierarchical overlay tables (closure +
        witness + rows + level-2 closure), the quantity exp10 reports
        against the dense (S+1)^2 baseline."""
        nsf1 = self.nsf + 1
        return (2 * nsf1 * self.m2 * self.m2 * 4      # sf_closure + next
                + nsf1 * self.m2 * self.mb2 * 4       # l2row
                + 2 * (self.S2 + 1) ** 2 * 4)         # d2 + d2_next


# ---------------------------------------------------------------------------
# structure assembly (weight-invariant)
# ---------------------------------------------------------------------------
def _frag_of_sid(plan) -> np.ndarray:
    """Home fragment of every overlay node (each boundary node belongs
    to exactly one fragment of the level-1 partition)."""
    out = -np.ones(plan.S, dtype=np.int64)
    fi_idx, b_idx = np.nonzero(plan.bvalid)
    out[plan.bnd_super[fi_idx, b_idx]] = fi_idx
    return out


def _group_fragments(plan, frag_of_sid: np.ndarray,
                     gamma2: int) -> np.ndarray:
    """Group fragments into super-fragments: greedy BFS seeding over
    the fragment quotient graph, budgeted by total overlay-node
    (boundary) count <= gamma2 per group, then FM-style refinement
    that moves fragments toward the neighbouring group holding most of
    their E_B adjacency.

    The refinement objective IS the quantity that makes the hierarchy
    pay: every E_B slot whose endpoints land in different groups makes
    both endpoints level-2 boundary nodes, and the level-2 closure is
    dense O(S2^2)/O(S2^3) — so minimizing cross-group slots minimizes
    S2 directly (a road graph's boundary set shrinks like the group
    perimeter, ~1/sqrt(fragments per group)).

    Deterministic and purely topological (quotient edges = which
    fragments share a cross E_B slot, weights = how many): a weight
    update can never move a fragment between super-fragments, which is
    what keeps the level-2 structure refresh-stable — the same
    invariance the level-1 partition provides one level down.
    """
    k = plan.k
    bcount = plan.bvalid.sum(axis=1).astype(np.int64)
    # fragment quotient multigraph from cross-fragment (E_B) slots:
    # nbrs[f][g] = number of E_B slots between fragments f and g
    cross = plan.sup_fi < 0
    fu = frag_of_sid[plan.sup_src[cross]]
    fv = frag_of_sid[plan.sup_dst[cross]]
    nbrs: List[dict] = [{} for _ in range(k)]
    for a, b in zip(fu, fv):
        a, b = int(a), int(b)
        nbrs[a][b] = nbrs[a].get(b, 0) + 1
        nbrs[b][a] = nbrs[b].get(a, 0) + 1
    labels = -np.ones(k, dtype=np.int64)
    sf = 0
    for seed in range(k):
        if labels[seed] >= 0:
            continue
        size = 0
        queue = [seed]
        qi = 0
        while qi < len(queue):
            f = queue[qi]
            qi += 1
            if labels[f] >= 0:
                continue
            if size and size + bcount[f] > gamma2:
                continue
            labels[f] = sf
            size += int(bcount[f])
            # grow toward the heaviest-adjacency neighbours first:
            # compactness now is less rework for the refiner below
            queue.extend(sorted((x for x in nbrs[f] if labels[x] < 0),
                                key=lambda x: (-nbrs[f][x], x)))
        sf += 1
    # FM-style refinement: move a fragment to the neighbouring group
    # with the best cross-slot gain, under the budget
    sizes = np.zeros(sf, dtype=np.int64)
    np.add.at(sizes, labels, bcount)
    for _ in range(8):
        moved = 0
        for f in range(k):
            lf = int(labels[f])
            gains: dict = {}
            for g, w in nbrs[f].items():
                gains[int(labels[g])] = gains.get(int(labels[g]), 0) + w
            internal = gains.get(lf, 0)
            best_l, best_gain = lf, 0
            for lg in sorted(gains):
                if lg == lf or sizes[lg] + bcount[f] > gamma2:
                    continue
                gain = gains[lg] - internal
                if gain > best_gain:
                    best_l, best_gain = lg, gain
            if best_l != lf:
                sizes[lf] -= bcount[f]
                sizes[best_l] += bcount[f]
                labels[f] = best_l
                moved += 1
        if moved == 0:
            break
    # compact away groups the refiner emptied
    uniq, inv = np.unique(labels, return_inverse=True)
    return inv.astype(np.int64)


def plan_hierarchy(plan, *, gamma2: Optional[int] = None) -> HierPlan:
    """Assemble the level-2 structure for ``plan`` (no device work).

    ``gamma2`` bounds overlay nodes per super-fragment.  The default
    balances the two per-level closures: the level-2 boundary shrinks
    like the group perimeter (S2 ~ S/sqrt(f) for f fragments per
    group), so groups must be LARGE enough that the dense S2 closure
    stays small, while the batched per-group FW (nsf * m2^3) stays
    tractable — ~S^(2/3) is where those costs meet.  The budget is
    then snapped to ~94% of the pow2 tile size it implies, so the
    padded [nsf, m2, m2] batch runs nearly full instead of wasting up
    to half its closure memory on padding.
    """
    S = plan.S
    if gamma2 is None:
        m2_target = padding.pow2(
            max(48, int(round(2.0 * max(S, 1) ** (2.0 / 3.0)))), floor=8)
        gamma2 = max(48, int(0.94 * m2_target))
    frag_sid = _frag_of_sid(plan)
    sf_of_frag = _group_fragments(plan, frag_sid, gamma2)
    nsf = int(sf_of_frag.max()) + 1 if sf_of_frag.size else 0
    sf_of = sf_of_frag[frag_sid].astype(np.int32)

    # members (overlay-id order within each sf) + positions
    pos_in_sf = np.zeros(S, dtype=np.int32)
    sf_sizes = np.bincount(sf_of, minlength=nsf)
    m2 = padding.pow2(int(sf_sizes.max()) if nsf else 1, floor=8)
    sf_members = np.full((nsf, m2), -1, dtype=np.int64)
    for s in range(nsf):
        ids = np.nonzero(sf_of == s)[0]
        sf_members[s, :ids.size] = ids
        pos_in_sf[ids] = np.arange(ids.size, dtype=np.int32)

    # slot addressing: intra-sf slots scatter into sf_adj, the rest
    # cross super-fragments and become level-2 edges
    su, sv = plan.sup_src, plan.sup_dst
    sfu, sfv = sf_of[su], sf_of[sv]
    intra = sfu == sfv
    slot_sf = np.where(intra, sfu, -1).astype(np.int32)
    slot_p2u = np.where(intra, pos_in_sf[su], -1).astype(np.int32)
    slot_p2v = np.where(intra, pos_in_sf[sv], -1).astype(np.int32)
    sf_adj = np.full((nsf, m2, m2), INF, dtype=np.float32)

    # level-2 boundary: overlay nodes incident to a cross-sf slot
    is_b2 = np.zeros(S, dtype=bool)
    is_b2[su[~intra]] = True
    is_b2[sv[~intra]] = True
    bnd2_ids = np.nonzero(is_b2)[0].astype(np.int64)
    S2 = bnd2_ids.size
    sid2_of = -np.ones(S, dtype=np.int64)
    sid2_of[bnd2_ids] = np.arange(S2)
    b2_per_sf = [bnd2_ids[sf_of[bnd2_ids] == s] for s in range(nsf)]
    mb2 = padding.pad_to(max((b.size for b in b2_per_sf), default=1))
    bnd2_pos = np.zeros((nsf, mb2), dtype=np.int32)
    bnd2_valid = np.zeros((nsf, mb2), dtype=bool)
    bnd2_sid = np.full((nsf, mb2), S2, dtype=np.int32)
    for s, ids in enumerate(b2_per_sf):
        nb = ids.size
        bnd2_pos[s, :nb] = pos_in_sf[ids]
        bnd2_valid[s, :nb] = True
        bnd2_sid[s, :nb] = sid2_of[ids]

    # level-2 slot list: cross slots keep their level-1 provenance,
    # per-sf boundary cliques get derived weights (hier_weights)
    l2_src = [sid2_of[su[~intra]].astype(np.int32)]
    l2_dst = [sid2_of[sv[~intra]].astype(np.int32)]
    n_cross = int((~intra).sum())
    l2_sf = [np.full(n_cross, -1, np.int32)]
    l2_pu = [np.full(n_cross, -1, np.int32)]
    l2_pv = [np.full(n_cross, -1, np.int32)]
    l2_ov = [np.nonzero(~intra)[0].astype(np.int64)]
    for s, ids in enumerate(b2_per_sf):
        if ids.size < 2:
            continue
        ii, jj = np.triu_indices(ids.size, k=1)
        l2_src.append(sid2_of[ids[ii]].astype(np.int32))
        l2_dst.append(sid2_of[ids[jj]].astype(np.int32))
        l2_sf.append(np.full(ii.size, s, np.int32))
        l2_pu.append(pos_in_sf[ids[ii]].astype(np.int32))
        l2_pv.append(pos_in_sf[ids[jj]].astype(np.int32))
        l2_ov.append(np.full(ii.size, -1, np.int64))

    def cat(parts, dtype):
        return (np.concatenate(parts).astype(dtype) if parts
                else np.empty(0, dtype))

    l2_src = cat(l2_src, np.int32)
    return HierPlan(
        nsf=nsf, m2=m2, mb2=mb2, S2=S2,
        sf_of_frag=sf_of_frag.astype(np.int32), sf_of=sf_of,
        pos_in_sf=pos_in_sf, sf_members=sf_members,
        slot_sf=slot_sf, slot_p2u=slot_p2u, slot_p2v=slot_p2v,
        sf_adj=sf_adj,
        bnd2_ids=bnd2_ids, sid2_of=sid2_of, bnd2_pos=bnd2_pos,
        bnd2_valid=bnd2_valid, bnd2_sid=bnd2_sid,
        l2_src=l2_src, l2_dst=cat(l2_dst, np.int32),
        l2_w=np.full(l2_src.size, INF, np.float32),
        l2_sf=cat(l2_sf, np.int32),
        l2_pu=cat(l2_pu, np.int32), l2_pv=cat(l2_pv, np.int32),
        l2_ov_slot=cat(l2_ov, np.int64),
    )


# ---------------------------------------------------------------------------
# weight caches (derived; the refresh path re-runs these on dirt)
# ---------------------------------------------------------------------------
def sf_adj_fill(hier: HierPlan, plan, sfs: Optional[np.ndarray] = None
                ) -> None:
    """(Re)build the intra-super-fragment adjacency blocks from the
    current level-1 slot weights (``plan.sup_w``), min-merging parallel
    slots.  ``sfs=None``: every block; otherwise only the listed ones
    (their blocks are reset first, so a slot that stopped being the
    min is forgotten)."""
    intra = hier.slot_sf >= 0
    if sfs is None:
        hier.sf_adj[:] = INF
        sel = intra
    else:
        hier.sf_adj[sfs] = INF
        sel = intra & np.isin(hier.slot_sf, sfs)
    s = hier.slot_sf[sel]
    pu = hier.slot_p2u[sel]
    pv = hier.slot_p2v[sel]
    w = plan.sup_w[sel].astype(np.float32)
    np.minimum.at(hier.sf_adj, (s, pu, pv), w)
    np.minimum.at(hier.sf_adj, (s, pv, pu), w)


def hier_weights(hier: HierPlan, plan, blocks: np.ndarray,
                 sfs: Optional[np.ndarray] = None) -> None:
    """Fill the level-2 slot weights: clique slots gather from the
    super-fragment closure ``blocks`` (never stored authoritatively —
    the same derived-state rule as ``device_engine.super_weights``),
    cross slots copy their level-1 slot's current weight.

    ``sfs=None``: blocks is the full [nsf, m2, m2] closure, every slot
    is rewritten.  Otherwise blocks holds only the listed sfs' rows and
    only their clique slots are rewritten (cross slots are always
    rewritten — they are O(cross) cheap and depend only on sup_w).
    """
    if sfs is None:
        mask = hier.l2_sf >= 0
        local = hier.l2_sf[mask]
    else:
        mask = np.isin(hier.l2_sf, sfs)
        sf_to_row = -np.ones(hier.nsf, dtype=np.int64)
        sf_to_row[sfs] = np.arange(len(sfs))
        local = sf_to_row[hier.l2_sf[mask]]
    hier.l2_w[mask] = blocks[local, hier.l2_pu[mask], hier.l2_pv[mask]]
    cross = hier.l2_ov_slot >= 0
    hier.l2_w[cross] = plan.sup_w[hier.l2_ov_slot[cross]]


# ---------------------------------------------------------------------------
# device stages (mirror frag_stage / super_stage)
# ---------------------------------------------------------------------------
def _pad_sentinel(dist: jax.Array, nxt: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Append the all-INF / all--1 sentinel block (index nsf) so padded
    gathers through ``sf_of`` need no masking."""
    d_s = jnp.full((1,) + dist.shape[1:], INF, dist.dtype)
    n_s = jnp.full((1,) + nxt.shape[1:], -1, nxt.dtype)
    return (jnp.concatenate([dist, d_s]), jnp.concatenate([nxt, n_s]))


def l2row_from(closure: jax.Array, bnd2_pos: np.ndarray,
               bnd2_valid: np.ndarray) -> jax.Array:
    """Per-member level-2 boundary rows, the hierarchy analog of the
    fragment ``brow`` table: l2row[sf, p, b] = closure distance from
    the member at position p to the sf's b-th level-2 boundary slot."""
    rows = jnp.take_along_axis(closure,
                               jnp.asarray(bnd2_pos)[:, None, :], axis=2)
    return jnp.where(jnp.asarray(bnd2_valid)[:, None, :], rows, INF)


def sf_stage(hier: HierPlan, *, force=None) -> tuple[jax.Array,
                                                     jax.Array,
                                                     jax.Array]:
    """Stage 2a: batched witness FW over every super-fragment's induced
    overlay subgraph at the one pow2 tile shape [nsf, m2, m2] ->
    (sf_closure, sf_next, l2row), sentinel block appended."""
    closure, nxt = ops.fw_batch_next(jnp.asarray(hier.sf_adj),
                                     force=force)
    rows = l2row_from(closure, hier.bnd2_pos, hier.bnd2_valid)
    closure, nxt = _pad_sentinel(closure, nxt)
    r_s = jnp.full((1,) + rows.shape[1:], INF, rows.dtype)
    return closure, nxt, jnp.concatenate([rows, r_s])


def l2_overlay(hier: HierPlan) -> jax.Array:
    """Dense [S2, S2] level-2 adjacency from the slot list (parallel
    slots min-merged, diag 0) — the level-2 twin of super_overlay."""
    S2 = hier.S2
    m = np.full((S2, S2), INF, np.float32)
    np.minimum.at(m, (hier.l2_src, hier.l2_dst), hier.l2_w)
    np.minimum.at(m, (hier.l2_dst, hier.l2_src), hier.l2_w)
    np.fill_diagonal(m, 0.0)
    return jnp.asarray(m)


def l2_stage(hier: HierPlan, *, force=None) -> tuple[jax.Array,
                                                     jax.Array]:
    """Stage 2b: dense witness FW closure of the level-2 boundary set
    -> (d2, d2_next) with the +inf sentinel row/col appended."""
    S2 = hier.S2
    d2 = jnp.full((S2 + 1, S2 + 1), INF, jnp.float32)
    d2_next = jnp.full((S2 + 1, S2 + 1), -1, jnp.int32)
    if S2 == 0 or hier.l2_src.size == 0:
        return d2, d2_next
    d_s, n_s = ops.fw_next(l2_overlay(hier), force=force)
    return (d2.at[:S2, :S2].set(d_s), d2_next.at[:S2, :S2].set(n_s))


# ---------------------------------------------------------------------------
# slot provenance for path unwinding (per-epoch host sidecars)
# ---------------------------------------------------------------------------
class SlotMap:
    """Sparse winning-slot lookup for an overlay slot list.

    A dense [n, n] slot table is exactly the quadratic host object the
    hierarchy exists to avoid, so hierarchical epochs carry this
    sorted-key map instead: O(slots) memory, O(log slots) lookup.
    Parallel slots resolve to the lightest (the same rule as the
    overlay adjacency min-merge and the dense ``overlay_slot_table``).
    """

    def __init__(self, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray, stride: int):
        a = np.concatenate([src, dst]).astype(np.int64)
        b = np.concatenate([dst, src]).astype(np.int64)
        ww = np.concatenate([w, w])
        slot = np.concatenate(
            [np.arange(src.size, dtype=np.int64)] * 2)
        key = a * stride + b
        order = np.lexsort((ww, key))
        key, slot = key[order], slot[order]
        first = np.ones(key.size, dtype=bool)
        first[1:] = key[1:] != key[:-1]
        self.stride = stride
        self.keys = key[first]
        self.slots = slot[first]

    def lookup(self, a: int, b: int) -> int:
        """Winning slot id for the adjacency (a, b), -1 if the pair is
        not adjacent."""
        key = a * self.stride + b
        i = int(np.searchsorted(self.keys, key))
        if i < self.keys.size and self.keys[i] == key:
            return int(self.slots[i])
        return -1


def ov_slot_map(plan) -> SlotMap:
    """Level-1 slot provenance (the sparse overlay_slot_table)."""
    return SlotMap(plan.sup_src, plan.sup_dst, plan.sup_w, plan.S + 1)


def l2_slot_map(hier: HierPlan) -> SlotMap:
    """Level-2 slot provenance (cross + clique slots, min-merged)."""
    return SlotMap(hier.l2_src, hier.l2_dst, hier.l2_w, hier.S2 + 1)


#: historical alias — hierarchical epochs' host_ov_slot sidecars are
#: SlotMap instances (the unwinder dispatches on this type)
OvSlotMap = SlotMap


def hier_overlay_stats(hier: HierPlan, S: int) -> dict:
    """Shape/memory summary for perf records and the serve driver."""
    dense = 2 * (S + 1) * (S + 1) * 4            # d_super + super_next
    return {
        "hierarchy_levels": 2,
        "S": S,
        "nsf": hier.nsf,
        "m2": hier.m2,
        "S2": hier.S2,
        "overlay_bytes": hier.overlay_bytes(),
        "overlay_dense_bytes": dense,
    }
