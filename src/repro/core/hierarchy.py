"""N-level SUPER overlay hierarchy (DESIGN.md §12–13).

The dense overlay closure (`device_engine.super_stage`) is O(S^2)
memory and O(S^3) work in the boundary count S — fine at road4000
(S ~ 600), a wall at road64k (S ~ 7000+).  Hierarchical Cut Labelling
(arXiv:2311.11063) and Pruned Landmark Labeling (arXiv:1304.4661) both
reach large road networks the same way: keep every per-level closure
small.  This module applies that recursively to our own overlay.

One *grouping level* takes an overlay (node set of size S, a slot list
with min-merged weights) and

  1. groups its *units* (fragments at level 1, groups-of-the-previous-
     level above that) into super-fragments via a multilevel scheme on
     the unit quotient graph — coarsen by heavy-edge matching,
     partition the coarse graph (``partition_bgp`` with per-unit
     boundary-mass node weights), uncoarsen with FM refinement — then
     runs a final FM pass whose gain is the EXACT change in the
     next-level boundary size (the count of overlay nodes incident to
     a cross-group slot).  That boundary size is the quantity that
     makes the hierarchy pay: the next level is built on exactly those
     nodes.  Purely topological, so the grouping is weight-invariant
     and survives every refresh;
  2. closes each group's induced overlay subgraph with the existing
     batched witness FW kernel (`ops.fw_batch_next`) at one
     pow2-padded tile shape [nsf, m2, m2];
  3. emits the next overlay: the boundary nodes (incident to a
     cross-group slot) with cross slots + per-group boundary cliques
     whose weights are *gathered from the group closures* — the same
     derived-weight discipline as the level-1 Upsilon weights
     (`device_engine.super_weights`), so scratch build and incremental
     refresh obtain every weight by the same gather.

``plan_hierarchy`` stacks grouping levels until the remaining boundary
set is small enough to close densely (the top closure ``d2``), or to
the explicitly requested depth.  ``hierarchy_levels = 1 + len(levels)``:
one grouping level is the two-level hierarchy of DESIGN.md §12,
unchanged in meaning.

Exactness is the level-1 argument applied per level: any overlay path
between x and y either stays inside x's group (covered by its closure)
or crosses the next boundary, where it decomposes into within-group
segments (>= the clique weights) and cross slots (= the cross edges);
by induction the top dense closure is the exact overlay metric on the
top boundary set.

Everything here is host-side numpy structure plus thin device stages;
`device_engine` owns the DeviceIndex fields, the serve-path combine,
and the refresh orchestration.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..obs import trace
from . import padding
from .partition import partition_bgp
from .graph import Graph

INF = np.float32(np.inf)

#: Boundary size above which ``hierarchy_levels="auto"`` adds another
#: grouping level instead of closing densely.  Road graphs near the
#: threshold are fine either way; road4000 (S ~ 600) stays dense
#: (bit-identical to the pre-hierarchy index), road64k (S ~ 7000)
#: gets as many levels as it takes to bring the top under this.
AUTO_THRESHOLD = 1024

#: Hard cap on hierarchy depth ("auto" and explicit): each level's
#: boundary shrinks geometrically, so depth beyond this is a planner
#: bug, not a bigger graph.
MAX_LEVELS = 5


@dataclasses.dataclass
class HierPlan:
    """Host-side structure of ONE grouping level, carried on BuildPlan
    as an element of ``.hier`` (a list, bottom level first).

    Field names keep their two-level spelling — "sf" is this level's
    group, "l2"/"2" is this level's *next* overlay — but every array is
    per-level: at level 1 the units are fragments and the overlay nodes
    are the level-1 boundary set; at level l+1 the units are level-l
    groups and the nodes are level-l boundary slots.  Like the rest of
    the plan, everything except the weight caches (``sf_adj``,
    ``l2_w``) is weight-invariant structure; a refresh mutates only
    those caches and regathers everything else.
    """

    nsf: int                 # group count at this level
    m2: int                  # pow2-padded max overlay nodes per group
    mb2: int                 # padded max next-level boundary slots/group
    S2: int                  # next-level boundary node count
    sf_of_frag: np.ndarray   # int32 [k] unit -> group
    sf_of: np.ndarray        # int32 [S] overlay node -> group
    pos_in_sf: np.ndarray    # int32 [S] position inside its group
    sf_members: np.ndarray   # int64 [nsf, m2] slot -> overlay id (-1)
    # intra-group slot addressing (this level's overlay slots)
    slot_sf: np.ndarray      # int32 [Es] owning group (-1: crosses)
    slot_p2u: np.ndarray     # int32 [Es] group-local endpoints (-1)
    slot_p2v: np.ndarray
    sf_adj: np.ndarray       # f32 [nsf, m2, m2] weight cache
    # next-level boundary registry
    bnd2_ids: np.ndarray     # int64 [S2] overlay ids, sorted
    sid2_of: np.ndarray      # int64 [S] overlay id -> next-level id (-1)
    bnd2_pos: np.ndarray     # int32 [nsf, mb2] group-local positions
    bnd2_valid: np.ndarray   # bool [nsf, mb2]
    bnd2_sid: np.ndarray     # int32 [nsf, mb2] next id (S2 sentinel)
    # next-level slots (fixed structure, derived weights)
    l2_src: np.ndarray       # int32 [E2] next-level ids
    l2_dst: np.ndarray
    l2_w: np.ndarray         # f32 [E2] weight cache
    l2_sf: np.ndarray        # int32 [E2] owning group (cliques; -1 cross)
    l2_pu: np.ndarray        # int32 [E2] group-local gather coords
    l2_pv: np.ndarray
    l2_ov_slot: np.ndarray   # int64 [E2] slot id in THIS level's slot
    #                          list (cross slots; -1 for cliques)

    def overlay_bytes(self) -> int:
        """Device bytes of this level's tables (closure + witness +
        rows); the top dense closure is accounted by
        ``hier_overlay_stats``."""
        nsf1 = self.nsf + 1
        return (2 * nsf1 * self.m2 * self.m2 * 4      # sf_closure + next
                + nsf1 * self.m2 * self.mb2 * 4)      # l2row


# ---------------------------------------------------------------------------
# structure assembly (weight-invariant)
# ---------------------------------------------------------------------------
def _frag_of_sid(plan) -> np.ndarray:
    """Home fragment of every overlay node (each boundary node belongs
    to exactly one fragment of the level-1 partition)."""
    out = -np.ones(plan.S, dtype=np.int64)
    fi_idx, b_idx = np.nonzero(plan.bvalid)
    out[plan.bnd_super[fi_idx, b_idx]] = fi_idx
    return out


def _refine_boundary(labels: np.ndarray, unit_of: np.ndarray,
                     na: np.ndarray, nb: np.ndarray,
                     bcount: np.ndarray, gamma2: int,
                     passes: int = 8) -> np.ndarray:
    """Exact next-boundary FM over unit moves.

    The multilevel partitioner below optimizes the cross-slot edge cut
    (a good proxy: every cross-group slot makes both endpoints boundary
    nodes).  This final pass optimizes the real objective: for each
    candidate move of unit ``f`` to an adjacent group, the gain is the
    exact change in the number of overlay nodes incident to a
    cross-group slot, evaluated over the only nodes a move of ``f``
    can affect (f's own cross-adjacent nodes and their cross
    neighbours).  Greedy positive-gain moves under the gamma2 budget,
    until a pass moves nothing.

    ``na, nb``: node endpoints of the cross-UNIT slots (intra-unit
    slots can never cross groups — units move atomically).
    """
    labels = labels.copy()
    k = labels.size
    if k == 0 or na.size == 0:
        return labels
    nfrag = int(labels.max()) + 1
    sizes = np.zeros(nfrag, dtype=np.int64)
    np.add.at(sizes, labels, bcount)
    # node -> units reachable via one cross slot; unit -> affected nodes
    adj: dict[int, list] = {}
    touch: List[set] = [set() for _ in range(k)]
    for a, b in zip(na.tolist(), nb.tolist()):
        ua, ub = int(unit_of[a]), int(unit_of[b])
        adj.setdefault(a, []).append(ub)
        adj.setdefault(b, []).append(ua)
        touch[ua].update((a, b))
        touch[ub].update((a, b))

    def n_boundary(nodes) -> int:
        c = 0
        for x in nodes:
            lx = labels[unit_of[x]]
            for u in adj[x]:
                if labels[u] != lx:
                    c += 1
                    break
        return c

    for _ in range(passes):
        moved = 0
        for f in range(k):
            nodes = touch[f]
            if not nodes:
                continue
            lf = int(labels[f])
            cand = sorted({int(labels[unit_of[x]]) for x in nodes})
            base = n_boundary(nodes)
            best_l, best_gain = lf, 0
            for lg in cand:
                if lg == lf or sizes[lg] + bcount[f] > gamma2:
                    continue
                labels[f] = lg
                gain = base - n_boundary(nodes)
                labels[f] = lf
                if gain > best_gain:
                    best_l, best_gain = lg, gain
            if best_l != lf:
                sizes[lf] -= bcount[f]
                sizes[best_l] += bcount[f]
                labels[f] = best_l
                moved += 1
        if moved == 0:
            break
    return labels


def _group_units(S: int, unit_of: np.ndarray, k: int,
                 src: np.ndarray, dst: np.ndarray,
                 gamma2: int, seed: int = 0) -> np.ndarray:
    """Group this level's units into super-fragments, minimizing the
    next-level boundary size.

    The unit quotient graph (nodes = units, node weight = overlay-node
    count, edge weight = cross-unit slot multiplicity) goes through
    the SAME multilevel partitioner as the level-1 node partition —
    heavy-edge-matching coarsening, Prim-style initial growth, FM
    uncoarsening (``partition_bgp`` with per-unit node weights and
    ``cut_weights=True``: here one quotient edge stands for its slot
    multiplicity, so the weighted cut IS the boundary proxy) — and
    then ``_refine_boundary`` trades the edge-cut proxy for the exact
    objective.  Deterministic and purely topological, so a weight
    update can never move a unit between groups: the same refresh
    stability the level-1 partition provides one level down.
    """
    if k == 0:
        return np.empty(0, dtype=np.int64)
    bcount = np.bincount(unit_of, minlength=k).astype(np.int64)
    cross = unit_of[src] != unit_of[dst]
    na, nb = src[cross].astype(np.int64), dst[cross].astype(np.int64)
    fu, fv = unit_of[na], unit_of[nb]
    lo = np.minimum(fu, fv).astype(np.int64)
    hi = np.maximum(fu, fv).astype(np.int64)
    if lo.size:
        key = lo * k + hi
        uniq, cnt = np.unique(key, return_counts=True)
        qlo, qhi = uniq // k, uniq % k
        qg = Graph.from_edges(k, qlo, qhi, cnt.astype(np.float64))
    else:
        qg = Graph.from_edges(k, [], [], [])
    part = partition_bgp(qg, gamma2, seed=seed, node_w=bcount,
                         cut_weights=True)
    labels = _refine_boundary(part.labels, unit_of, na, nb, bcount,
                              gamma2)
    uniq, inv = np.unique(labels, return_inverse=True)
    return inv.astype(np.int64)


def _default_gamma2(S: int) -> int:
    """Per-group overlay-node budget.  Balances the per-level closures:
    the next boundary shrinks like the group perimeter (S2 ~ S/sqrt(f)
    for f units per group), so groups must be LARGE enough that the
    next level stays small, while the batched per-group FW (nsf * m2^3)
    stays tractable — ~S^(2/3) is where those costs meet.  The budget
    is snapped to ~94% of the pow2 tile size it implies, so the padded
    [nsf, m2, m2] batch runs nearly full instead of wasting up to half
    its closure memory on padding."""
    m2_target = padding.pow2(
        max(48, int(round(2.0 * max(S, 1) ** (2.0 / 3.0)))), floor=8)
    return max(48, int(0.94 * m2_target))


def plan_one_level(S: int, unit_of: np.ndarray, k: int,
                   src: np.ndarray, dst: np.ndarray,
                   gamma2: int, seed: int = 0) -> HierPlan:
    """Assemble one grouping level over an overlay of ``S`` nodes with
    slot list ``(src, dst)`` and unit assignment ``unit_of`` (no device
    work)."""
    sf_of_frag = _group_units(S, unit_of, k, src, dst, gamma2,
                              seed=seed)
    nsf = int(sf_of_frag.max()) + 1 if sf_of_frag.size else 0
    sf_of = sf_of_frag[unit_of].astype(np.int32)

    # members (overlay-id order within each group) + positions
    pos_in_sf = np.zeros(S, dtype=np.int32)
    sf_sizes = np.bincount(sf_of, minlength=nsf)
    m2 = padding.pow2(int(sf_sizes.max()) if nsf else 1, floor=8)
    sf_members = np.full((nsf, m2), -1, dtype=np.int64)
    for s in range(nsf):
        ids = np.nonzero(sf_of == s)[0]
        sf_members[s, :ids.size] = ids
        pos_in_sf[ids] = np.arange(ids.size, dtype=np.int32)

    # slot addressing: intra-group slots scatter into sf_adj, the rest
    # cross groups and become next-level edges
    su, sv = src, dst
    sfu, sfv = sf_of[su], sf_of[sv]
    intra = sfu == sfv
    slot_sf = np.where(intra, sfu, -1).astype(np.int32)
    slot_p2u = np.where(intra, pos_in_sf[su], -1).astype(np.int32)
    slot_p2v = np.where(intra, pos_in_sf[sv], -1).astype(np.int32)
    sf_adj = np.full((nsf, m2, m2), INF, dtype=np.float32)

    # next-level boundary: overlay nodes incident to a cross-group slot
    is_b2 = np.zeros(S, dtype=bool)
    is_b2[su[~intra]] = True
    is_b2[sv[~intra]] = True
    bnd2_ids = np.nonzero(is_b2)[0].astype(np.int64)
    S2 = bnd2_ids.size
    sid2_of = -np.ones(S, dtype=np.int64)
    sid2_of[bnd2_ids] = np.arange(S2)
    b2_per_sf = [bnd2_ids[sf_of[bnd2_ids] == s] for s in range(nsf)]
    mb2 = padding.pad_to(max((b.size for b in b2_per_sf), default=1))
    bnd2_pos = np.zeros((nsf, mb2), dtype=np.int32)
    bnd2_valid = np.zeros((nsf, mb2), dtype=bool)
    bnd2_sid = np.full((nsf, mb2), S2, dtype=np.int32)
    for s, ids in enumerate(b2_per_sf):
        nb = ids.size
        bnd2_pos[s, :nb] = pos_in_sf[ids]
        bnd2_valid[s, :nb] = True
        bnd2_sid[s, :nb] = sid2_of[ids]

    # next-level slot list: cross slots keep their provenance into
    # THIS level's slot list, per-group boundary cliques get derived
    # weights (hier_weights)
    l2_src = [sid2_of[su[~intra]].astype(np.int32)]
    l2_dst = [sid2_of[sv[~intra]].astype(np.int32)]
    n_cross = int((~intra).sum())
    l2_sf = [np.full(n_cross, -1, np.int32)]
    l2_pu = [np.full(n_cross, -1, np.int32)]
    l2_pv = [np.full(n_cross, -1, np.int32)]
    l2_ov = [np.nonzero(~intra)[0].astype(np.int64)]
    for s, ids in enumerate(b2_per_sf):
        if ids.size < 2:
            continue
        ii, jj = np.triu_indices(ids.size, k=1)
        l2_src.append(sid2_of[ids[ii]].astype(np.int32))
        l2_dst.append(sid2_of[ids[jj]].astype(np.int32))
        l2_sf.append(np.full(ii.size, s, np.int32))
        l2_pu.append(pos_in_sf[ids[ii]].astype(np.int32))
        l2_pv.append(pos_in_sf[ids[jj]].astype(np.int32))
        l2_ov.append(np.full(ii.size, -1, np.int64))

    def cat(parts, dtype):
        return (np.concatenate(parts).astype(dtype) if parts
                else np.empty(0, dtype))

    l2_src = cat(l2_src, np.int32)
    return HierPlan(
        nsf=nsf, m2=m2, mb2=mb2, S2=S2,
        sf_of_frag=sf_of_frag.astype(np.int32), sf_of=sf_of,
        pos_in_sf=pos_in_sf, sf_members=sf_members,
        slot_sf=slot_sf, slot_p2u=slot_p2u, slot_p2v=slot_p2v,
        sf_adj=sf_adj,
        bnd2_ids=bnd2_ids, sid2_of=sid2_of, bnd2_pos=bnd2_pos,
        bnd2_valid=bnd2_valid, bnd2_sid=bnd2_sid,
        l2_src=l2_src, l2_dst=cat(l2_dst, np.int32),
        l2_w=np.full(l2_src.size, INF, np.float32),
        l2_sf=cat(l2_sf, np.int32),
        l2_pu=cat(l2_pu, np.int32), l2_pv=cat(l2_pv, np.int32),
        l2_ov_slot=cat(l2_ov, np.int64),
    )


def plan_hierarchy(plan, *, levels="auto",
                   gamma2: Optional[int] = None) -> List[HierPlan]:
    """Stack grouping levels over ``plan``'s overlay (no device work).

    ``levels="auto"`` keeps adding grouping levels while the remaining
    boundary exceeds AUTO_THRESHOLD (so the top dense closure stays
    small), up to MAX_LEVELS total; an integer asks for exactly that
    many total hierarchy levels (``len(result) = levels - 1``), ending
    early only when a level's boundary empties or collapses to one
    group — the returned depth is the authoritative one.  ``gamma2``
    overrides the first level's group budget (tests); deeper levels
    use the size-derived default, floored so a group averages >= ~2.2
    units: deeper units are whole previous-level groups, so without
    that floor most units exceed the budget, land solo, and the
    boundary stops shrinking.  Under "auto" a level is dropped (and
    the stack stops below it) when it fails to shrink the boundary by
    >= 5% — highway-dense graphs hit a floor set by long-range edges
    — or when its group closures (nsf * m2^2) would cost more memory
    than just closing the remaining boundary densely; stacking such
    levels only adds closure memory and lift hops.  An explicit
    integer depth is honored as requested (differential tests rely on
    exact depths).
    """
    out: List[HierPlan] = []
    S = plan.S
    unit_of = _frag_of_sid(plan)
    k = plan.k
    src, dst = plan.sup_src, plan.sup_dst
    while True:
        if gamma2 is not None and not out:
            g2 = gamma2
        else:
            g2 = _default_gamma2(S)
            if out:
                g2 = max(g2, int(np.ceil(2.2 * S / max(k, 1))))
        h = plan_one_level(S, unit_of, k, src, dst, g2,
                           seed=len(out))
        out.append(h)
        if h.S2 == 0 or h.nsf <= 1:
            break
        if levels == "auto":
            if len(out) > 1 and (
                    h.S2 > 0.95 * S
                    or h.nsf * h.m2 ** 2 >= (S + 1) ** 2):
                # no progress, or the level's group closures cost more
                # memory than just closing this boundary densely:
                # stop below it
                out.pop()
                break
            if h.S2 <= AUTO_THRESHOLD or len(out) >= MAX_LEVELS - 1:
                break
        elif len(out) >= int(levels) - 1:
            break
        S = h.S2
        unit_of = h.sf_of[h.bnd2_ids].astype(np.int64)
        k = h.nsf
        src, dst = h.l2_src.astype(np.int64), h.l2_dst.astype(np.int64)
    return out


# ---------------------------------------------------------------------------
# weight caches (derived; the refresh path re-runs these on dirt)
# ---------------------------------------------------------------------------
def sf_adj_fill(hier: HierPlan, w: np.ndarray,
                sfs: Optional[np.ndarray] = None) -> None:
    """(Re)build the intra-group adjacency blocks from this level's
    current slot weights ``w`` (``plan.sup_w`` at level 1, the previous
    level's ``l2_w`` above), min-merging parallel slots.  ``sfs=None``:
    every block; otherwise only the listed ones (their blocks are reset
    first, so a slot that stopped being the min is forgotten)."""
    intra = hier.slot_sf >= 0
    if sfs is None:
        hier.sf_adj[:] = INF
        sel = intra
    else:
        hier.sf_adj[sfs] = INF
        sel = intra & np.isin(hier.slot_sf, sfs)
    s = hier.slot_sf[sel]
    pu = hier.slot_p2u[sel]
    pv = hier.slot_p2v[sel]
    ws = np.asarray(w)[sel].astype(np.float32)
    np.minimum.at(hier.sf_adj, (s, pu, pv), ws)
    np.minimum.at(hier.sf_adj, (s, pv, pu), ws)


def hier_weights(hier: HierPlan, blocks: np.ndarray, src_w: np.ndarray,
                 sfs: Optional[np.ndarray] = None) -> None:
    """Fill this level's next-overlay slot weights: clique slots gather
    from the group closure ``blocks`` (never stored authoritatively —
    the same derived-state rule as ``device_engine.super_weights``),
    cross slots copy their source slot's current weight from ``src_w``
    (this level's slot weight vector).

    ``sfs=None``: blocks is the full [nsf, m2, m2] closure, every slot
    is rewritten.  Otherwise blocks holds only the listed groups' rows
    and only their clique slots are rewritten (cross slots are always
    rewritten — they are O(cross) cheap and depend only on src_w).
    """
    if sfs is None:
        mask = hier.l2_sf >= 0
        local = hier.l2_sf[mask]
    else:
        mask = np.isin(hier.l2_sf, sfs)
        sf_to_row = -np.ones(hier.nsf, dtype=np.int64)
        sf_to_row[sfs] = np.arange(len(sfs))
        local = sf_to_row[hier.l2_sf[mask]]
    hier.l2_w[mask] = blocks[local, hier.l2_pu[mask], hier.l2_pv[mask]]
    cross = hier.l2_ov_slot >= 0
    hier.l2_w[cross] = np.asarray(src_w)[hier.l2_ov_slot[cross]]


# ---------------------------------------------------------------------------
# device stages (mirror frag_stage / super_stage)
# ---------------------------------------------------------------------------
def _pad_sentinel(dist: jax.Array, nxt: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Append the all-INF / all--1 sentinel block (index nsf) so padded
    gathers through ``sf_of`` need no masking."""
    d_s = jnp.full((1,) + dist.shape[1:], INF, dist.dtype)
    n_s = jnp.full((1,) + nxt.shape[1:], -1, nxt.dtype)
    return (jnp.concatenate([dist, d_s]), jnp.concatenate([nxt, n_s]))


def l2row_from(closure: jax.Array, bnd2_pos: np.ndarray,
               bnd2_valid: np.ndarray) -> jax.Array:
    """Per-member next-boundary rows, the hierarchy analog of the
    fragment ``brow`` table: l2row[sf, p, b] = closure distance from
    the member at position p to the group's b-th next-boundary slot."""
    rows = jnp.take_along_axis(closure,
                               jnp.asarray(bnd2_pos)[:, None, :], axis=2)
    return jnp.where(jnp.asarray(bnd2_valid)[:, None, :], rows, INF)


def sf_stage(hier: HierPlan, *, force=None) -> tuple[jax.Array,
                                                     jax.Array,
                                                     jax.Array]:
    """Per-level stage: batched witness FW over every group's induced
    overlay subgraph at the one pow2 tile shape [nsf, m2, m2] ->
    (sf_closure, sf_next, l2row), sentinel block appended."""
    with trace.span("hierarchy.sf_stage", nsf=int(hier.nsf),
                    m2=int(hier.m2)):
        closure, nxt = ops.fw_batch_next(jnp.asarray(hier.sf_adj),
                                         force=force)
        rows = l2row_from(closure, hier.bnd2_pos, hier.bnd2_valid)
        closure, nxt = _pad_sentinel(closure, nxt)
        r_s = jnp.full((1,) + rows.shape[1:], INF, rows.dtype)
        return closure, nxt, jnp.concatenate([rows, r_s])


def l2_overlay(hier: HierPlan) -> jax.Array:
    """Dense [S2, S2] next-level adjacency from the slot list (parallel
    slots min-merged, diag 0) — the per-level twin of super_overlay."""
    S2 = hier.S2
    m = np.full((S2, S2), INF, np.float32)
    np.minimum.at(m, (hier.l2_src, hier.l2_dst), hier.l2_w)
    np.minimum.at(m, (hier.l2_dst, hier.l2_src), hier.l2_w)
    np.fill_diagonal(m, 0.0)
    return jnp.asarray(m)


def first_hops(adj: np.ndarray, dist: np.ndarray,
               rows: Optional[np.ndarray] = None,
               cols: Optional[np.ndarray] = None) -> np.ndarray:
    """Canonical first-hop witnesses from (adjacency, exact closure).

    next[i, j] = the smallest k != i with adj[i, k] finite and
    adj[i, k] + dist[k, j] == dist[i, j]; -1 on the diagonal and for
    unreachable pairs.  A pure function of the two tables — independent
    of which kernel (or incremental relaxation) produced ``dist`` — so
    the scratch build and every refresh path derive bit-identical
    witness tables, extending the refresh == rebuild contract to
    ``d2_next``.  Positive edge weights make the chase strictly
    decrease dist[., j], so it always terminates.  ``rows``/``cols``
    restrict the output block (the decrease fast path re-derives only
    the rows/columns whose inputs changed).
    """
    n = dist.shape[0]
    rows = np.arange(n, dtype=np.int64) if rows is None else rows
    cols = np.arange(n, dtype=np.int64) if cols is None else cols
    a = adj.astype(np.float32, copy=True)
    np.fill_diagonal(a, INF)                     # k == i never witnesses
    dc = dist[:, cols]                           # [n, m] candidate tails
    out = np.full((rows.size, cols.size), -1, np.int32)
    # chunk rows so the [c, n, m] candidate cube stays ~64 MiB
    chunk = max(1, (1 << 24) // max(1, n * cols.size))
    for i0 in range(0, rows.size, chunk):
        ri = rows[i0:i0 + chunk]
        ar = a[ri]                               # [c, n]
        tgt = dist[np.ix_(ri, cols)]             # [c, m]
        ok = (np.isfinite(ar)[:, :, None]
              & (ar[:, :, None] + dc[None, :, :] == tgt[:, None, :]))
        hop = np.argmax(ok, axis=1).astype(np.int32)
        out[i0:i0 + chunk] = np.where(
            ok.any(axis=1) & np.isfinite(tgt), hop, -1)
    return out


def l2_stage(hier: HierPlan, *, force=None) -> tuple[jax.Array,
                                                     jax.Array]:
    """Top stage: dense FW closure of the LAST level's boundary set ->
    (d2, d2_next) with the +inf sentinel row/col appended.  Witnesses
    come from ``first_hops`` on the closed distances rather than the
    FW kernel's pivot-order-dependent tie-breaks, so the decrease-only
    refresh fast path (``l2_decrease_stage``) can reproduce them
    array-equal without re-running the full closure."""
    S2 = hier.S2
    with trace.span("hierarchy.l2_stage", S2=int(S2)):
        d2 = jnp.full((S2 + 1, S2 + 1), INF, jnp.float32)
        d2_next = jnp.full((S2 + 1, S2 + 1), -1, jnp.int32)
        if S2 == 0 or hier.l2_src.size == 0:
            return d2, d2_next
        adj = np.asarray(l2_overlay(hier))
        d_s = np.asarray(ops.fw_apsp(jnp.asarray(adj), force=force))
        n_s = first_hops(adj, d_s)
        return (d2.at[:S2, :S2].set(d_s),
                d2_next.at[:S2, :S2].set(jnp.asarray(n_s)))


#: decrease fast path bail-out: above this fraction of S2 touched, the
#: r x r seed closure + [S2, r, S2] relaxation stops beating full FW
DECREASE_MAX_FRAC = 8


def l2_decrease_stage(hier: HierPlan, d2_old: jax.Array,
                      d2_next_old: jax.Array,
                      changed_slots: np.ndarray
                      ) -> Optional[tuple[jax.Array, jax.Array]]:
    """Decrease-only incremental top closure (DESIGN.md §14).

    Precondition (checked by the caller): every slot in
    ``changed_slots`` carries a weight <= its previous one and no other
    slot changed.  Then with U = the changed slots' endpoints and
    M* = the closed [r, r] block of min(old closure on U, new changed
    weights), the exact new closure is

        D_new = min(D_old, D_old[:, U] (x) M* (x) D_old[U, :])

    — candidates never undershoot (every old path survives a decrease
    with weight >= its new true distance), and any strictly shorter new
    path splits at its first/last changed-edge endpoints, both in U, so
    the three-factor contraction reaches it.  Witnesses re-derive via
    ``first_hops`` only on the rows/columns whose adjacency row or
    closure column changed; everything else carries over — for (i, j)
    with both outside that set, adj[i, :], dist[:, j] and dist[i, j]
    are all unchanged, so the canonical witness is too.

    Returns the sentinel-padded (d2, d2_next) pair, or None when the
    touched endpoint set is too large for the fast path to pay
    (caller falls back to the full ``l2_stage``).
    """
    S2 = hier.S2
    u_ids = np.unique(np.concatenate(
        [hier.l2_src[changed_slots], hier.l2_dst[changed_slots]]
    )).astype(np.int64)
    r = int(u_ids.size)
    if r == 0 or r > max(16, S2 // DECREASE_MAX_FRAC):
        return None
    with trace.span("hierarchy.l2_decrease_stage",
                    S2=int(S2), r=r):
        d_old = np.asarray(d2_old)[:S2, :S2]
        nxt_old = np.asarray(d2_next_old)[:S2, :S2]
        # seed block: old closure restricted to U, min-merged with the NEW
        # changed-slot weights, then closed by a tiny r x r FW
        m = d_old[np.ix_(u_ids, u_ids)].copy()
        pos = np.full(S2, -1, np.int64)
        pos[u_ids] = np.arange(r)
        pa = pos[hier.l2_src[changed_slots]]
        pb = pos[hier.l2_dst[changed_slots]]
        wc = hier.l2_w[changed_slots].astype(np.float32)
        np.minimum.at(m, (pa, pb), wc)
        np.minimum.at(m, (pb, pa), wc)
        np.fill_diagonal(m, 0.0)
        for k in range(r):
            np.minimum(m, m[:, k, None] + m[None, k, :], out=m)
        # two-sided relaxation, chunked so [c, r, S2] stays ~64 MiB
        left = d_old[:, u_ids]                        # [S2, r]
        right = d_old[u_ids, :]                       # [r, S2]
        lm = np.min(left[:, :, None] + m[None, :, :], axis=1)  # [S2, r]
        d_new = d_old.copy()
        chunk = max(1, (1 << 24) // max(1, r * S2))
        for i0 in range(0, S2, chunk):
            cand = np.min(lm[i0:i0 + chunk, :, None] + right[None, :, :],
                          axis=1)
            np.minimum(d_new[i0:i0 + chunk], cand,
                       out=d_new[i0:i0 + chunk])
        # canonical witnesses on the changed rows/columns only (D stays
        # symmetric, so changed rows == changed columns)
        touched = np.union1d(
            u_ids, np.nonzero((d_new != d_old).any(axis=1))[0])
        adj = np.asarray(l2_overlay(hier))
        nxt_new = nxt_old.copy()
        nxt_new[touched, :] = first_hops(adj, d_new, rows=touched)
        rest = np.setdiff1d(np.arange(S2, dtype=np.int64), touched)
        if rest.size and touched.size:
            nxt_new[np.ix_(rest, touched)] = first_hops(
                adj, d_new, rows=rest, cols=touched)
        d2 = jnp.full((S2 + 1, S2 + 1), INF, jnp.float32)
        d2_next = jnp.full((S2 + 1, S2 + 1), -1, jnp.int32)
        return (d2.at[:S2, :S2].set(jnp.asarray(d_new)),
                d2_next.at[:S2, :S2].set(jnp.asarray(nxt_new)))


# ---------------------------------------------------------------------------
# slot provenance for path unwinding (per-epoch host sidecars)
# ---------------------------------------------------------------------------
class SlotMap:
    """Sparse winning-slot lookup for an overlay slot list.

    A dense [n, n] slot table is exactly the quadratic host object the
    hierarchy exists to avoid, so hierarchical epochs carry this
    sorted-key map instead: O(slots) memory, O(log slots) lookup.
    Parallel slots resolve to the lightest (the same rule as the
    overlay adjacency min-merge and the dense ``overlay_slot_table``).
    """

    def __init__(self, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray, stride: int):
        a = np.concatenate([src, dst]).astype(np.int64)
        b = np.concatenate([dst, src]).astype(np.int64)
        ww = np.concatenate([w, w])
        slot = np.concatenate(
            [np.arange(src.size, dtype=np.int64)] * 2)
        key = a * stride + b
        order = np.lexsort((ww, key))
        key, slot = key[order], slot[order]
        first = np.ones(key.size, dtype=bool)
        first[1:] = key[1:] != key[:-1]
        self.stride = stride
        self.keys = key[first]
        self.slots = slot[first]

    def lookup(self, a: int, b: int) -> int:
        """Winning slot id for the adjacency (a, b), -1 if the pair is
        not adjacent."""
        key = a * self.stride + b
        i = int(np.searchsorted(self.keys, key))
        if i < self.keys.size and self.keys[i] == key:
            return int(self.slots[i])
        return -1


def ov_slot_map(plan) -> SlotMap:
    """Level-1 slot provenance (the sparse overlay_slot_table)."""
    return SlotMap(plan.sup_src, plan.sup_dst, plan.sup_w, plan.S + 1)


def l2_slot_map(hier: HierPlan) -> SlotMap:
    """One level's next-overlay slot provenance (cross + clique slots,
    min-merged)."""
    return SlotMap(hier.l2_src, hier.l2_dst, hier.l2_w, hier.S2 + 1)


#: historical alias — hierarchical epochs' host_ov_slot sidecars are
#: SlotMap instances (the unwinder dispatches on this type)
OvSlotMap = SlotMap


def hier_overlay_stats(levels: List[HierPlan], S: int) -> dict:
    """Shape/memory summary for perf records and the serve driver.
    ``nsf``/``m2``/``S2`` keep their historical (first-level) meaning
    so exp10 records stay comparable; ``S_top``/``levels_S2`` carry the
    full ladder."""
    h0, htop = levels[0], levels[-1]
    dense = 2 * (S + 1) * (S + 1) * 4            # d_super + super_next
    total = (sum(h.overlay_bytes() for h in levels)
             + 2 * (htop.S2 + 1) ** 2 * 4)       # d2 + d2_next
    return {
        "hierarchy_levels": 1 + len(levels),
        "S": S,
        "nsf": h0.nsf,
        "m2": h0.m2,
        "S2": h0.S2,
        "S_top": htop.S2,
        "levels_S2": [h.S2 for h in levels],
        "overlay_bytes": total,
        "overlay_dense_bytes": dense,
    }
