"""Distance landmarks revisited (paper §III) + hybrid covers (§III-B, §V).

Three pieces:
  1. REF graphs: drop redundant edges (removal does not change the
     endpoint distance).
  2. Theorem 2: on an REF graph a landmark cover IS a vertex cover, so
     the classical maximal-matching 2-approximation applies (Fig. 1).
     Used for the Table I overhead estimation.
  3. Hybrid landmark covers with the per-node cost model
     space_L(x)=|N_x| <= space_N(x)=|P_x| (paper Example 1), built for
     the *boundary nodes of a fragment* (§V-A) — the production path.

Role: preprocessing stage for the per-fragment enforced edges
(DESIGN.md §7).  Owned invariant: a cover's enforced edges preserve
every boundary-to-boundary shortest distance through the fragment, so
the SUPER graph built on them is distance-exact.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple

import numpy as np

from .graph import Graph


# --------------------------------------------------------------------------
# REF graphs + 2-approx landmark covers (paper §III-A)
# --------------------------------------------------------------------------
def _alt_dist_bounded(g: Graph, u: int, v: int, skip_w: float,
                      skip_v: int) -> float:
    """Shortest u->v distance ignoring one (u,v) edge, early-exit when the
    frontier exceeds ``skip_w`` (the paper's redundancy test)."""
    dist = {u: 0.0}
    pq = [(0.0, u)]
    while pq:
        d, x = heapq.heappop(pq)
        if d > dist.get(x, np.inf):
            continue
        if d > skip_w:
            return np.inf  # every remaining node is farther than w(u,v)
        if x == v:
            return d
        s, e = g.indptr[x], g.indptr[x + 1]
        for y, w in zip(g.indices[s:e], g.weights[s:e]):
            y = int(y)
            if x == u and y == skip_v:
                continue  # skip the candidate edge itself
            nd = d + float(w)
            if nd <= skip_w and nd < dist.get(y, np.inf):
                dist[y] = nd
                heapq.heappush(pq, (nd, y))
    return np.inf


def redundant_edge_mask(g: Graph) -> np.ndarray:
    """bool[m]: True where edge (u,v) is redundant (alt path <= w)."""
    out = np.zeros(g.m, dtype=bool)
    for i in range(g.m):
        u, v, w = int(g.edge_u[i]), int(g.edge_v[i]), float(g.edge_w[i])
        out[i] = _alt_dist_bounded(g, u, v, w, v) <= w
    return out


def ref_graph(g: Graph) -> Graph:
    """One REF graph of G: drop redundant edges greedily.

    Removing one redundant edge can make another edge non-redundant
    (two routes that certify each other), so we re-test each edge against
    the *current* graph, sweeping heaviest-first so long shortcuts go
    before they can shield each other.  Mutable dict-of-dict adjacency
    keeps each test a bounded Dijkstra on the live graph.
    """
    adj: List[Dict[int, float]] = [dict() for _ in range(g.n)]
    for u, v, w in zip(g.edge_u, g.edge_v, g.edge_w):
        adj[int(u)][int(v)] = float(w)
        adj[int(v)][int(u)] = float(w)

    def alt_dist(u: int, v: int, bound: float) -> float:
        dist = {u: 0.0}
        pq = [(0.0, u)]
        while pq:
            d, x = heapq.heappop(pq)
            if d > dist.get(x, np.inf):
                continue
            if d > bound:
                return np.inf
            if x == v:
                return d
            for y, w in adj[x].items():
                if x == u and y == v:
                    continue
                nd = d + w
                if nd <= bound and nd < dist.get(y, np.inf):
                    dist[y] = nd
                    heapq.heappush(pq, (nd, y))
        return np.inf

    order = np.argsort(-g.edge_w)
    alive = np.ones(g.m, dtype=bool)
    for i in order:
        u, v, w = int(g.edge_u[i]), int(g.edge_v[i]), float(g.edge_w[i])
        if alt_dist(u, v, w) <= w:
            alive[i] = False
            del adj[u][v], adj[v][u]
    return Graph.from_edges(g.n, g.edge_u[alive], g.edge_v[alive],
                            g.edge_w[alive])


def vertex_cover_2approx(g: Graph, rng_seed: int = 0) -> np.ndarray:
    """Maximal-matching 2-approx vertex cover [31]; returns node ids."""
    rng = np.random.default_rng(rng_seed)
    order = rng.permutation(g.m)
    used = np.zeros(g.n, dtype=bool)
    for i in order:
        u, v = int(g.edge_u[i]), int(g.edge_v[i])
        if not used[u] and not used[v]:
            used[u] = True
            used[v] = True
    return np.nonzero(used)[0].astype(np.int32)


def landmark_cover_2approx(g: Graph) -> Tuple[np.ndarray, Graph]:
    """Fig. 1: REF reduction + vertex cover => landmark cover of G.

    Returns (landmarks, ref_graph). |D|/2 and |D| bound the optimum.
    """
    ref = ref_graph(g)
    return vertex_cover_2approx(ref), ref


def landmark_cover_cost(g: Graph, cover: np.ndarray) -> dict:
    """Paper Table I accounting: 4-byte entries, |D|*(|V|-1) distances."""
    d = int(cover.size)
    return {
        "n_landmarks": d,
        "frac_nodes": d / max(g.n, 1),
        "cover_bytes": 4 * d * (g.n - 1),
        "graph_bytes": g.size_bytes(),
        "ratio": (4 * d * (g.n - 1)) / max(g.size_bytes(), 1),
        "lower_bound": d // 2,
    }


# --------------------------------------------------------------------------
# Hybrid landmark covers for fragment boundary nodes (paper §III-B + §V-A)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class HybridCover:
    """Hybrid landmark cover D~ = (D, E_D^-) of a fragment's boundary set.

    ``landmark_edges``: (u, x, dist) rows, u in N_x — the |N_x| cost.
    ``direct_edges``:   (b1, b2, dist) rows for uncovered pairs E_D^-.
    All node ids are *fragment-local*; ``dist`` is the fragment-local
    shortest distance (the Upsilon weight of §V-A).
    """
    landmarks: np.ndarray          # local node ids
    landmark_edges: np.ndarray     # [e,3] float64 (u, x, dist)
    direct_edges: np.ndarray       # [e,3] float64 (b1, b2, dist)

    @property
    def n_enforced_edges(self) -> int:
        return len(self.landmark_edges) + len(self.direct_edges)


def _dijkstra_with_parent(g: Graph, s: int):
    dist = np.full(g.n, np.inf)
    parent = -np.ones(g.n, dtype=np.int64)
    dist[s] = 0.0
    pq = [(0.0, s)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        a, b = g.indptr[u], g.indptr[u + 1]
        for v, w in zip(g.indices[a:b], g.weights[a:b]):
            v = int(v)
            nd = d + float(w)
            if nd < dist[v]:
                dist[v] = nd
                parent[v] = u
                heapq.heappush(pq, (nd, v))
    return dist, parent


def multi_source_sssp(g: Graph, sources: np.ndarray):
    """Exact distances + a shortest-path-tree parent for every source at
    once: vectorized Bellman-Ford to fixpoint over the CSR.

    Returns ``(dist [ns, n], parent [ns, n])``.  Integer weights keep
    every path sum exactly representable, so the fixpoint distances
    equal Dijkstra's bit for bit.  The parent rule is deterministic and
    order-free: ``parent[i, v]`` is the neighbour u minimising
    ``(dist[i, u] + w(u, v), u)`` lexicographically (-1 at sources and
    unreachable nodes), so every process — serial or worker — derives
    the identical tree from the same graph (the serial-parity
    contract, DESIGN.md §17).
    """
    sources = np.asarray(sources, dtype=np.int64)
    ns, n = sources.size, g.n
    # node-major layout [n, ns]: every relaxation step is then a
    # contiguous row gather/scatter instead of a strided column one
    distT = np.full((n, ns), np.inf)
    parentT = -np.ones((n, ns), dtype=np.int64)
    if ns:
        distT[sources, np.arange(ns)] = 0.0
    if ns == 0 or g.indices.size == 0:
        return (np.ascontiguousarray(distT.T),
                np.ascontiguousarray(parentT.T))
    # padded incoming adjacency [n, D] (undirected: a node's CSR row IS
    # its incoming-tail list), rows sorted by neighbour id so argmax of
    # the tie mask lands on the smallest tail.  The relaxation becomes
    # one contiguous axis-reduce per iteration — no reduceat, no
    # variable-length groups.
    deg = np.diff(g.indptr)
    total = int(g.indices.size)
    rows = np.repeat(np.arange(n, dtype=np.int64), deg)
    order = np.lexsort((g.indices, rows))
    nbr = g.indices[order].astype(np.int64)
    nbw = g.weights[order]
    D = int(deg.max())
    cols = np.arange(total, dtype=np.int64) - g.indptr[rows]
    pad_src = np.zeros((n, D), dtype=np.int64)
    pad_w = np.full((n, D), np.inf)
    pad_src[rows, cols] = nbr
    pad_w[rows, cols] = nbw
    pad_w3 = pad_w[:, :, None]
    while True:
        cand = distT[pad_src] + pad_w3        # [n, D, ns]
        best = cand.min(axis=1)               # [n, ns]
        upd = np.minimum(distT, best)
        if not (upd < distT).any():
            break
        distT = upd
    # one more candidate pass at the fixpoint extracts the parents
    cand = distT[pad_src] + pad_w3
    best = cand.min(axis=1)
    # a node gets a parent only where the best incoming relaxation
    # equals its final distance: true for every reachable non-source
    # (inf == inf would otherwise hand parents to unreachable nodes)
    ok = np.isfinite(best) & (best == distT)
    col = (cand == best[:, None, :]).argmax(axis=1)
    parentT = np.where(ok, pad_src[np.arange(n)[:, None], col], -1)
    return np.ascontiguousarray(distT.T), np.ascontiguousarray(parentT.T)


def hybrid_cover(frag: Graph, boundary: np.ndarray,
                 use_cost_model: bool = True) -> HybridCover:
    """Build a hybrid landmark cover for ``boundary`` nodes of a fragment.

    One vectorized multi-source SSSP over all boundary nodes gives (a)
    the local boundary-to-boundary distances and (b) one canonical
    shortest path per pair (the deterministic lexicographic parent
    tree, ``multi_source_sssp``), whose *internal* nodes are the
    landmark candidates (Example 1 semantics).

    Greedy selection under the cost model: repeatedly pick the node x
    maximising |P_x| among those with |N_x| <= |P_x| over the still-
    uncovered pairs (disjointness condition (b) of §III-B is maintained
    because covered pairs are removed).  ``use_cost_model=False``
    reproduces the paper's Table V ablation: any node on >= 1 path is
    eligible (classical landmark-cover greedy).
    """
    boundary = np.unique(np.asarray(boundary, dtype=np.int64)).astype(
        np.int32)
    nb = boundary.size
    if nb <= 1:
        return HybridCover(landmarks=np.empty(0, np.int32),
                           landmark_edges=np.empty((0, 3)),
                           direct_edges=np.empty((0, 3)))
    dist, parent = multi_source_sssp(frag, boundary)
    dist_bb = dist[:, boundary]
    # walk every pair's canonical parent chain t -> b simultaneously:
    # each step is one [n_active] gather, arrays compacting as chains
    # terminate.  Pairs are encoded as i*nb + j (i < j).
    iu, ju = np.triu_indices(nb, k=1)
    finite = np.isfinite(dist_bb[iu, ju])
    iu, ju = iu[finite], ju[finite]
    pairid = iu.astype(np.int64) * nb + ju
    bsrc = boundary[iu].astype(np.int64)
    cur = parent[iu, boundary[ju]]
    xs_parts: List[np.ndarray] = []
    ps_parts: List[np.ndarray] = []
    walking = (cur >= 0) & (cur != bsrc)
    while walking.any():
        iu, cur = iu[walking], cur[walking]
        bsrc, pairid = bsrc[walking], pairid[walking]
        xs_parts.append(cur)
        ps_parts.append(pairid)
        cur = parent[iu, cur]
        walking = (cur >= 0) & (cur != bsrc)
    # node -> set of pair ids whose canonical path passes through it
    through: Dict[int, set] = {}
    if xs_parts:
        xs = np.concatenate(xs_parts)
        ps = np.concatenate(ps_parts)
        order = np.argsort(xs, kind="stable")
        xs, ps = xs[order], ps[order]
        ux, ustarts = np.unique(xs, return_index=True)
        bounds = np.append(ustarts, xs.size).tolist()
        pslist = ps.tolist()
        through = {int(x): set(pslist[s:e]) for x, s, e in
                   zip(ux.tolist(), bounds[:-1], bounds[1:])}

    covered: set = set()
    landmarks: List[int] = []
    lm_edges: List[Tuple[int, int, float]] = []
    # greedy max |P_x| with cost-model gate, via a lazy max-heap: live
    # pair counts only ever shrink, so a popped entry whose count is
    # still current is the global argmax (CELF-style lazy greedy).
    # Ties break toward the smaller node id — value-determined, so
    # every process selects the identical landmark sequence.
    heap = [(-len(pairs), x) for x, pairs in through.items()]
    heapq.heapify(heap)
    while heap:
        negc, x = heapq.heappop(heap)
        pairs = through.get(x)
        if pairs is None:
            continue
        live = pairs - covered
        if not live:
            del through[x]
            continue
        if len(live) != -negc:
            through[x] = live
            heapq.heappush(heap, (-len(live), x))
            continue
        nx = {p // nb for p in live} | {p % nb for p in live}
        del through[x]
        if use_cost_model and len(nx) > len(live):
            # space_L > space_N: cheaper to materialise pairs directly;
            # drop x from candidacy (its surviving pairs go to E_D^-)
            continue
        landmarks.append(int(x))
        # enforced edges (u, x) for u in N_x with local shortest
        # distance: dist(b_u, x) is a row gather from the multi-source
        # run (undirected symmetry), not another SSSP.  sorted(nx) so
        # edge order is value-determined, identical in every process.
        for bi in sorted(nx):
            lm_edges.append((int(boundary[bi]), int(x),
                             float(dist[bi, x])))
        covered |= live

    # E_D^-: finite, still-uncovered pairs become direct edges
    iu, ju = np.triu_indices(nb, k=1)
    dvals = dist_bb[iu, ju]
    keep = np.isfinite(dvals)
    if covered:
        cov = np.fromiter(covered, dtype=np.int64, count=len(covered))
        keep &= ~np.isin(iu.astype(np.int64) * nb + ju, cov)
    direct = np.column_stack([boundary[iu[keep]], boundary[ju[keep]],
                              dvals[keep]]).astype(np.float64)
    return HybridCover(
        landmarks=np.array(landmarks, dtype=np.int32),
        landmark_edges=np.array(lm_edges, dtype=np.float64).reshape(-1, 3),
        direct_edges=direct.reshape(-1, 3))
