"""DISLAND bi-level query answering (paper §VI-B) — host reference.

Given the preprocessed DislandIndex:
  case 1  s, t in the same DRA: answered from agent tables (constant
          time across pieces, local Dijkstra within one piece);
  case 2  different DRAs/trivial: dist(s,t) = dist(s,u_s)
          + dist_shrink(u_s,u_t) + dist(u_t,t) where the middle term is a
          Dijkstra on G[V_s] u G[V_t] u SUPER (observation of [4]).

This is the paper-faithful engine; device_engine.py is the TPU-batched
reformulation validated against it (DESIGN.md §1-§2).  Owned
invariant: answers equal host Dijkstra on the input graph exactly —
this module is the readable middle step of that proof chain, not a
performance path.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from . import dijkstra
from .graph import Graph
from .supergraph import DislandIndex


class DislandEngine:
    def __init__(self, index: DislandIndex):
        self.ix = index
        self._union_cache: Dict[Tuple[int, int], tuple] = {}
        self._agent_by_id = {int(a.agent): a for a in index.dras.agents}

    # ---- case 1 helpers -------------------------------------------------
    def _same_dra(self, s: int, t: int, u: int) -> float:
        ix = self.ix
        if s == u:
            return float(ix.dras.dist_to_agent[t])
        if t == u:
            return float(ix.dras.dist_to_agent[s])
        if ix.dras.piece_of[s] == ix.dras.piece_of[t]:
            # same A_u^i: local Dijkstra on the piece
            a = self._agent_by_id.get(u)
            if a is None:
                raise AssertionError("agent table inconsistent")
            piece = a.pieces[int(ix.dras.piece_of[s])]
            sub, ids = ix.g.subgraph(piece)
            remap = {int(x): k for k, x in enumerate(ids)}
            return float(dijkstra.pair(sub, remap[s], remap[t]))
        return float(ix.dras.dist_to_agent[s] + ix.dras.dist_to_agent[t])

    # ---- case 2: union graph --------------------------------------------
    def _union_graph(self, fs: int, ft: int):
        key = (min(fs, ft), max(fs, ft))
        hit = self._union_cache.get(key)
        if hit is not None:
            return hit
        ix = self.ix
        eu, ev, ew = [], [], []

        def add_fragment(fi: int):
            f = ix.fragments[fi]
            fmap = f.nodes
            for u, v, w in zip(f.graph.edge_u, f.graph.edge_v,
                               f.graph.edge_w):
                eu.append(int(fmap[u]))
                ev.append(int(fmap[v]))
                ew.append(float(w))

        add_fragment(fs)
        if ft != fs:
            add_fragment(ft)
        sgraph = ix.super_graph
        for u, v, w in zip(sgraph.graph.edge_u, sgraph.graph.edge_v,
                           sgraph.graph.edge_w):
            eu.append(int(sgraph.node_ids[u]))
            ev.append(int(sgraph.node_ids[v]))
            ew.append(float(w))
        nodes = sorted(set(eu) | set(ev))
        remap = {x: i for i, x in enumerate(nodes)}
        g = Graph.from_edges(len(nodes),
                             [remap[x] for x in eu],
                             [remap[x] for x in ev], ew)
        out = (g, remap)
        if len(self._union_cache) < 256:
            self._union_cache[key] = out
        return out

    # ---- public API -------------------------------------------------------
    def query(self, s: int, t: int) -> float:
        if s == t:
            return 0.0
        ix = self.ix
        us = int(ix.dras.agent_of[s])
        ut = int(ix.dras.agent_of[t])
        if us == ut:
            return self._same_dra(s, t, us)
        d_s = float(ix.dras.dist_to_agent[s])
        d_t = float(ix.dras.dist_to_agent[t])
        fs = int(ix.frag_of[us])
        ft = int(ix.frag_of[ut])
        if fs < 0 or ft < 0:
            # agent node in no fragment: isolated shrink component
            return float("inf") if fs != ft else d_s + d_t
        g, remap = self._union_graph(fs, ft)
        if us not in remap or ut not in remap:
            return float("inf")
        mid = dijkstra.pair(g, remap[us], remap[ut])
        return d_s + mid + d_t

    def query_many(self, pairs) -> np.ndarray:
        return np.array([self.query(int(s), int(t)) for s, t in pairs])

    # ---- path oracle (host reference for the device witness path) -----
    def _piece_path(self, s: int, t: int) -> list:
        """Shortest s -> t path inside the DRA piece containing both
        (paths between piece members and their agent never leave the
        piece, Props 3-9)."""
        if s == t:
            return [int(s)]
        ix = self.ix
        ref = s if ix.dras.piece_of[s] >= 0 else t
        a = self._agent_by_id[int(ix.dras.agent_of[ref])]
        piece = a.pieces[int(ix.dras.piece_of[ref])]
        sub, ids = ix.g.subgraph(piece)
        remap = {int(x): k for k, x in enumerate(ids)}
        _d, p = dijkstra.pair_with_path(sub, remap[s], remap[t])
        assert p is not None, (s, t)
        return [int(ids[x]) for x in p]

    def query_path(self, s: int, t: int) -> tuple:
        """(distance, node sequence) — the bi-level decomposition with
        every leg resolved by a predecessor-tracking Dijkstra on its own
        subgraph: piece paths never leave their piece, and the middle
        u_s -> u_t leg never leaves the shrink graph (a path entering a
        DRA must exit through the same agent, so with positive weights
        it never pays to).  This is the host oracle the device witness
        unwinding is differentially tested against.
        """
        if s == t:
            return 0.0, [int(s)]
        ix = self.ix
        us = int(ix.dras.agent_of[s])
        ut = int(ix.dras.agent_of[t])
        if us == ut:
            if ix.dras.piece_of[s] >= 0 and \
                    ix.dras.piece_of[s] == ix.dras.piece_of[t]:
                path = self._piece_path(s, t)
            else:
                leg_s = self._piece_path(s, us) if s != us else [s]
                leg_t = self._piece_path(ut, t) if t != ut else [t]
                path = leg_s + leg_t[1:]
        else:
            sid_s = int(ix.shrink_id_of[us])
            sid_t = int(ix.shrink_id_of[ut])
            if sid_s < 0 or sid_t < 0:
                return float("inf"), None
            _d, mid = dijkstra.pair_with_path(ix.shrink, sid_s, sid_t)
            if mid is None:
                return float("inf"), None
            leg_s = self._piece_path(s, us) if s != us else [s]
            leg_t = self._piece_path(ut, t) if t != ut else [t]
            path = leg_s + [int(ix.shrink_ids[x]) for x in mid][1:] \
                + leg_t[1:]
        w = 0.0
        for a, b in zip(path, path[1:]):
            e = ix.g.edge_ids([a], [b])[0]
            assert e >= 0, (a, b)
            w += float(ix.g.edge_w[e])
        return w, path
