"""Distributed DISLAND serving + offline build (shard_map).

Serving layout (production posture, DESIGN.md §5): the index tensors are
*replicated* — on 16 GB chips the index is ~1/2 the input graph, so every
device holds it and the query batch is sharded across the whole mesh
(pure DP; zero query-time collectives; linear scaling with chips).

Offline build is the heavy part (batched FW over fragments, batched BF
over SUPER sources): both are sharded over their batch dimension with a
shard_map, which is where the multi-pod mesh earns its keep.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from . import sssp
from .device_engine import DeviceIndex, serve_step


def serve_sharded(mesh: Mesh, dix: DeviceIndex, s: jax.Array,
                  t: jax.Array, *,
                  batch_axes: Sequence[str] | None = None) -> jax.Array:
    """Batched queries sharded over ``batch_axes`` (default: all axes)."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(), P(axes), P(axes)), out_specs=P(axes))
    def _local(dix_, s_, t_):
        return serve_step(dix_, s_, t_)

    return _local(dix, s, t)


def serve_jit(mesh: Mesh, dix_like, *,
              batch_axes: Sequence[str] | None = None):
    """jit'd sharded serve step with explicit in/out shardings, suitable
    for AOT lowering (dry-run).  ``dix_like`` is any DeviceIndex pytree
    (arrays or ShapeDtypeStructs) used to build the replicated specs."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)
    rep = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P(axes))
    dix_shardings = jax.tree_util.tree_map(lambda _: rep, dix_like)

    def step(dix: DeviceIndex, s: jax.Array, t: jax.Array) -> jax.Array:
        return serve_step(dix, s, t)

    return jax.jit(step, in_shardings=(dix_shardings, shard, shard),
                   out_shardings=shard)


def fw_fragments_sharded(mesh: Mesh, frag_adj: jax.Array,
                         axis: str = "data") -> jax.Array:
    """Offline per-fragment APSP with the fragment batch sharded."""

    @functools.partial(jax.shard_map, mesh=mesh,
                       in_specs=P(axis), out_specs=P(axis))
    def _local(adj):
        return ops.fw_batch(adj)

    return _local(frag_adj)


def super_apsp_sharded(mesh: Mesh, src: jax.Array, dst: jax.Array,
                       w: jax.Array, n_super: int,
                       axis: str = "data") -> jax.Array:
    """Offline SUPER APSP: BF sources sharded, edge list replicated."""
    srcs = jnp.arange(n_super, dtype=jnp.int32)

    @functools.partial(jax.shard_map, mesh=mesh,
                       in_specs=(P(), P(), P(), P(axis)),
                       out_specs=P(axis))
    def _local(src_, dst_, w_, sources_):
        return sssp.apsp_from_sources(src_, dst_, w_, sources_, n=n_super)

    return _local(src, dst, w, srcs)
