"""Distributed DISLAND serving + offline build (shard_map) + planner.

Serving layout (production posture, DESIGN.md §5): the index tensors are
*replicated* — on 16 GB chips the index is ~1/2 the input graph, so every
device holds it and the query batch is sharded across the whole mesh
(pure DP; zero query-time collectives; linear scaling with chips).

The QueryPlanner is the host-side front end: it buckets each incoming
batch by case (same-DRA / same-fragment / cross-fragment) and runs one
specialized jitted program per bucket, so same-DRA queries never pay
for the SUPER combine and cross-fragment queries never touch the piece
tables (DESIGN.md §5).

Offline build is the heavy part (batched FW over fragments, batched BF
over SUPER sources): both are sharded over their batch dimension with a
shard_map, which is where the multi-pod mesh earns its keep.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops
from . import sssp
from .device_engine import (DeviceIndex, serve_cross, serve_same_dra,
                            serve_step)


# ---------------------------------------------------------------------------
# query planner
# ---------------------------------------------------------------------------
def _pad_pow2(n: int, floor: int = 16) -> int:
    m = floor
    while m < n:
        m *= 2
    return m


class QueryPlanner:
    """Bucket a query batch by case and dispatch per-case programs.

    Bucket sizes are padded to powers of two (self-queries as filler)
    so each sub-program compiles for O(log batch) distinct shapes.
    """

    CASES = ("same_dra", "same_frag", "cross_frag")

    def __init__(self, dix: DeviceIndex, *, force=None):
        self.dix = dix
        self._agent_of = np.asarray(dix.agent_of)
        self._frag_of = np.asarray(dix.frag_of)
        self._fns = {
            "same_dra": jax.jit(lambda s, t: serve_same_dra(dix, s, t)),
            "same_frag": jax.jit(lambda s, t: serve_cross(
                dix, s, t, with_local=True, force=force)),
            "cross_frag": jax.jit(lambda s, t: serve_cross(
                dix, s, t, with_local=False, force=force)),
        }
        self.last_counts: dict = {}

    def warmup(self, batch_size: int) -> None:
        """Compile every sub-program at every padded bucket size that a
        batch of ``batch_size`` can produce, so no XLA compile lands in
        the serving (timed) path."""
        m = _pad_pow2(1)
        sizes = []
        while m <= _pad_pow2(batch_size):
            sizes.append(m)
            m *= 2
        z = np.zeros(max(sizes), np.int32)
        for fn in self._fns.values():
            for size in sizes:
                jax.block_until_ready(fn(jnp.asarray(z[:size]),
                                         jnp.asarray(z[:size])))

    def plan(self, s: np.ndarray, t: np.ndarray) -> dict:
        """-> {case: index array} partition of the batch."""
        us, ut = self._agent_of[s], self._agent_of[t]
        fs, ft = self._frag_of[us], self._frag_of[ut]
        case1 = us == ut
        case2 = ~case1 & (fs == ft)
        return {
            "same_dra": np.nonzero(case1)[0],
            "same_frag": np.nonzero(case2)[0],
            "cross_frag": np.nonzero(~case1 & ~case2)[0],
        }

    def __call__(self, s, t) -> np.ndarray:
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        out = np.full(s.shape, np.inf, np.float32)
        plan = self.plan(s, t)
        self.last_counts = {c: int(ix.size) for c, ix in plan.items()}
        for case, idx in plan.items():
            if idx.size == 0:
                continue
            m = _pad_pow2(idx.size)
            sp = np.zeros(m, np.int32)
            tp = np.zeros(m, np.int32)
            sp[:idx.size] = s[idx]
            tp[:idx.size] = t[idx]
            res = self._fns[case](jnp.asarray(sp), jnp.asarray(tp))
            out[idx] = np.asarray(res)[:idx.size]
        return out


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------
def serve_sharded(mesh: Mesh, dix: DeviceIndex, s: jax.Array,
                  t: jax.Array, *,
                  batch_axes: Sequence[str] | None = None) -> jax.Array:
    """Batched queries sharded over ``batch_axes`` (default: all axes)."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axes), P(axes)), out_specs=P(axes))
    def _local(dix_, s_, t_):
        return serve_step(dix_, s_, t_)

    return _local(dix, s, t)


def serve_jit(mesh: Mesh, dix_like, *,
              batch_axes: Sequence[str] | None = None):
    """jit'd sharded serve step with explicit in/out shardings, suitable
    for AOT lowering (dry-run).  ``dix_like`` is any DeviceIndex pytree
    (arrays or ShapeDtypeStructs) used to build the replicated specs."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)
    rep = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P(axes))
    dix_shardings = jax.tree_util.tree_map(lambda _: rep, dix_like)

    def step(dix: DeviceIndex, s: jax.Array, t: jax.Array) -> jax.Array:
        return serve_step(dix, s, t)

    return jax.jit(step, in_shardings=(dix_shardings, shard, shard),
                   out_shardings=shard)


# ---------------------------------------------------------------------------
# sharded offline build
# ---------------------------------------------------------------------------
def fw_fragments_sharded(mesh: Mesh, frag_adj: jax.Array,
                         axis: str = "data") -> jax.Array:
    """Offline per-fragment APSP with the fragment batch sharded."""

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=P(axis), out_specs=P(axis))
    def _local(adj):
        return ops.fw_batch(adj)

    return _local(frag_adj)


def super_apsp_sharded(mesh: Mesh, src: jax.Array, dst: jax.Array,
                       w: jax.Array, n_super: int,
                       axis: str = "data") -> jax.Array:
    """Offline SUPER APSP: BF sources sharded, edge list replicated."""
    srcs = jnp.arange(n_super, dtype=jnp.int32)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(), P(), P(axis)),
                       out_specs=P(axis))
    def _local(src_, dst_, w_, sources_):
        return sssp.apsp_from_sources(src_, dst_, w_, sources_, n=n_super)

    return _local(src, dst, w, srcs)
