"""Distributed DISLAND serving + offline build (shard_map) + planner.

Serving layout (production posture, DESIGN.md §5): the index tensors are
*replicated* — on 16 GB chips the index is ~1/2 the input graph, so every
device holds it and the query batch is sharded across the whole mesh
(pure DP; zero query-time collectives; linear scaling with chips).

The QueryPlanner is the host-side front end: it buckets each incoming
batch by case (same-DRA / same-fragment / cross-fragment) and runs one
specialized jitted program per bucket, so same-DRA queries never pay
for the SUPER combine and cross-fragment queries never touch the piece
tables (DESIGN.md §5).  It also fronts the hub-label hot tier
(DESIGN.md §15): ``hub_mask`` gates pairs both of whose endpoints
carry labels in the pinned epoch, ``query_hub`` answers them with one
O(W) label merge — NOT a planner case; the serving runtime dispatches
it, and ``query`` stays the untouched differential reference the
merge must equal bit-for-bit.

Owned invariants: ``plan()``'s buckets cover every query exactly once;
``set_index`` publishes the epoch's host maps atomically (one tuple
swap); warmup compiles every executable any flush can request, so an
epoch swap never pays XLA compile in its tail latency (DESIGN.md §9).

Offline build is the heavy part (batched FW over fragments, batched BF
over SUPER sources): both are sharded over their batch dimension with a
shard_map, which is where the multi-pod mesh earns its keep.
"""
from __future__ import annotations

import functools
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops
from . import padding, refresh_pipeline, sssp
from .device_engine import (DeviceIndex, RefreshStats,
                            build_device_index_with_plan, refresh_index,
                            serve_cross, serve_cross_res, serve_cross_w,
                            serve_hub, serve_same_dra, serve_same_dra_w,
                            serve_step, warmup_refresh)
from .paths import PathUnwinder
from .supergraph import DislandIndex, start_build


# ---------------------------------------------------------------------------
# query planner
# ---------------------------------------------------------------------------
# canonical padding rules live in core/padding.py; the planner's
# bucket rule and the serving scheduler's occupancy histogram share
# this exact spelling (scheduler imports pad_pow2 from here)
_pad_pow2 = padding.pad_pow2
pad_pow2 = padding.pad_pow2


class QueryPlanner:
    """Bucket a query batch by case and dispatch per-case programs.

    Bucket sizes are padded to powers of two (self-queries as filler)
    so each sub-program compiles for O(log batch) distinct shapes.

    The index is passed to each jitted sub-program as an *argument*,
    not closed over: an epoch swap (``set_index``) is then just a
    pointer replacement — the new epoch's tensors have identical
    shapes/dtypes, so every cached executable is reused and no XLA
    compile lands anywhere near the serving path (DESIGN.md §9).
    """

    CASES = ("same_dra", "same_frag", "cross_frag", "cross_res")

    def __init__(self, dix: DeviceIndex, *, force=None,
                 paths: bool = False):
        self._fns = {
            "same_dra": jax.jit(serve_same_dra),
            "same_frag": jax.jit(functools.partial(
                serve_cross, with_local=True, force=force)),
            "cross_frag": jax.jit(functools.partial(
                serve_cross, with_local=False, force=force)),
            # resident fast path: both endpoints in pre-lifted hot
            # super-fragments of *different* top-level groups, so the
            # whole query is one fused twoside against the top closure
            "cross_res": jax.jit(functools.partial(
                serve_cross_res, force=force)),
        }
        # hub-label hot tier (DESIGN.md §15): NOT a planner case — the
        # serving runtime gates pairs with hub_mask and dispatches them
        # through query_hub, above/instead of the planner.  Jitting is
        # free until called, so the program exists on every index and
        # warmup() only compiles it when the epoch carries real labels.
        self._hub_fn = jax.jit(functools.partial(serve_hub, force=force))
        # witness-returning (return_witness mode) sub-programs; jit
        # wrappers are free until called, so these always exist and
        # ``paths`` only decides whether warmup() compiles them.
        # cross_res deliberately maps to the full-lift witness program:
        # the resident rows re-associate f32 min-plus, so an argmin over
        # them may disagree with the unwinder's exact re-find — witness
        # queries keep the exact pipeline (distances are equal anyway)
        self._wfns = {
            "same_dra": jax.jit(serve_same_dra_w),
            "same_frag": jax.jit(functools.partial(
                serve_cross_w, with_local=True, force=force)),
            "cross_frag": jax.jit(functools.partial(
                serve_cross_w, with_local=False, force=force)),
            "cross_res": jax.jit(functools.partial(
                serve_cross_w, with_local=False, force=force)),
        }
        self.paths = paths
        self.last_counts: dict = {}
        self.set_index(dix)

    def set_index(self, dix: DeviceIndex) -> None:
        """Publish a new index epoch.  In-flight batches keep the old
        arrays alive (immutable); subsequent calls plan and serve
        against the new epoch with zero recompilation."""
        self.dix = dix
        # partition maps cached as one tuple keyed by index identity,
        # so an explicitly pinned dispatch (query(dix=...)) can always
        # bucket with ITS epoch's maps even if this publish lands
        # mid-flush (weight-only refreshes share these arrays across
        # epochs, but the epoch-pin contract must not depend on that)
        self._maps = (dix, np.asarray(dix.agent_of),
                      np.asarray(dix.frag_of),
                      getattr(dix, "host_res_frag", None),
                      getattr(dix, "host_topgrp_frag", None),
                      getattr(dix, "host_hub_agent", None))

    @staticmethod
    def bucket_sizes(batch_size: int) -> list[int]:
        """The padded (pow2) bucket sizes a batch of ``batch_size`` can
        produce — exactly the shapes ``warmup`` compiles.  Introspection
        hook for the serving runtime: a micro-batcher that caps its
        flushes at ``bucket_sizes(b)[-1]`` never triggers a fresh XLA
        compile, and the occupancy histogram buckets by these sizes."""
        m = _pad_pow2(1)
        sizes = []
        while m <= _pad_pow2(batch_size):
            sizes.append(m)
            m *= 2
        return sizes

    def warmup(self, batch_size: int) -> None:
        """Compile every sub-program at every padded bucket size that a
        batch of ``batch_size`` can produce, so no XLA compile lands in
        the serving (timed) path."""
        sizes = self.bucket_sizes(batch_size)
        z = np.zeros(max(sizes), np.int32)
        # the resident program only exists on indices that carry real
        # pre-lifted rows (shape[0] > 1; the cold dummy is (1, 1, 1)) —
        # its bucket is provably empty otherwise, so skip the compile
        has_res = np.asarray(self.dix.res_rows).shape[0] > 1
        fns = [fn for case, fn in self._fns.items()
               if has_res or case != "cross_res"]
        if self.paths:
            fns += [fn for case, fn in self._wfns.items()
                    if has_res or case != "cross_res"]
        # same guard for the hub tier: the label program only exists on
        # epochs carrying real rows (the cold dummy is (1, 1))
        if np.asarray(self.dix.hub_rows).shape[0] > 1:
            fns = fns + [self._hub_fn]
        for fn in fns:
            for size in sizes:
                jax.block_until_ready(fn(self.dix, jnp.asarray(z[:size]),
                                         jnp.asarray(z[:size])))

    def plan(self, s: np.ndarray, t: np.ndarray,
             dix: DeviceIndex | None = None) -> dict:
        """-> {case: index array} partition of the batch, bucketed by
        ``dix``'s own membership maps (default: current epoch)."""
        cached = self._maps          # single atomic read of the tuple
        if dix is None or cached[0] is dix:
            agent_of, frag_of = cached[1], cached[2]
            res_frag, topgrp = cached[3], cached[4]
        else:
            # pinned to an epoch that is no longer current: derive the
            # maps from that index (cold path — only reachable when a
            # publish lands between the pin and this dispatch)
            agent_of = np.asarray(dix.agent_of)
            frag_of = np.asarray(dix.frag_of)
            res_frag = getattr(dix, "host_res_frag", None)
            topgrp = getattr(dix, "host_topgrp_frag", None)
        us, ut = agent_of[s], agent_of[t]
        fs, ft = frag_of[us], frag_of[ut]
        case1 = us == ut
        case2 = ~case1 & (fs == ft)
        case3 = ~case1 & ~case2
        if res_frag is not None and topgrp is not None:
            # hot split of cross_frag: both fragments pre-lifted AND in
            # different top-level groups (the exactness gate for the
            # resident rows: nested grouping means different top groups
            # imply different groups at every level, so no same-group
            # leg can shortcut the route and the pre-composed lift
            # covers the confined prefix completely)
            valid = (fs >= 0) & (ft >= 0)
            hot = case3 & valid & (res_frag[np.where(valid, fs, 0)] >= 0) \
                & (res_frag[np.where(valid, ft, 0)] >= 0) \
                & (topgrp[np.where(valid, fs, 0)]
                   != topgrp[np.where(valid, ft, 0)])
            case3 = case3 & ~hot
        else:
            hot = np.zeros(s.shape, bool)
        return {
            "same_dra": np.nonzero(case1)[0],
            "same_frag": np.nonzero(case2)[0],
            "cross_frag": np.nonzero(case3)[0],
            "cross_res": np.nonzero(hot)[0],
        }

    def hub_mask(self, s: np.ndarray, t: np.ndarray,
                 dix: DeviceIndex | None = None) -> np.ndarray:
        """Host-side gate for the hub-label hot tier (DESIGN.md §15):
        True where both endpoints' agents are labeled AND the exactness
        gate holds — different fragments, and on hierarchical epochs
        different TOP groups (only then must every route touch the top
        boundary the labels enumerate).  Everything else falls through
        to the planner.  Reads the same atomically-published map tuple
        as plan(), so a pinned dispatch gates with ITS epoch's labels."""
        cached = self._maps
        if dix is None or cached[0] is dix:
            dix_, agent_of, frag_of = cached[0], cached[1], cached[2]
            topgrp, hub_agent = cached[4], cached[5]
        else:
            dix_ = dix
            agent_of = np.asarray(dix.agent_of)
            frag_of = np.asarray(dix.frag_of)
            topgrp = getattr(dix, "host_topgrp_frag", None)
            hub_agent = getattr(dix, "host_hub_agent", None)
        s = np.asarray(s, np.int64)
        t = np.asarray(t, np.int64)
        if hub_agent is None:
            return np.zeros(s.shape, bool)
        us, ut = agent_of[s], agent_of[t]
        fs, ft = frag_of[us], frag_of[ut]
        ok = ((s != t) & (fs >= 0) & (ft >= 0) & (fs != ft)
              & (hub_agent[us] >= 0) & (hub_agent[ut] >= 0))
        if len(dix_.sf_of) > 0:
            # hierarchical: same-top-group routes may never touch the
            # top boundary — the labels are silent about them
            if topgrp is None:
                return np.zeros(s.shape, bool)
            ok &= (topgrp[np.where(ok, fs, 0)]
                   != topgrp[np.where(ok, ft, 0)])
        return ok

    def query_hub(self, s, t, *, dix: DeviceIndex | None = None
                  ) -> np.ndarray:
        """Vectorized hub-label merge for hub_mask-gated pairs — one
        pow2-padded program (label gathers + O(W) merge), bypassing the
        planner's case split entirely.  A mis-gated pair gathers the
        all-INF sentinel row and returns +inf, never a wrong finite
        distance.  Bit-equal to query() on gated pairs (the §15
        differential harness pins this)."""
        dix = self.dix if dix is None else dix
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        if s.size == 0:
            return np.zeros(s.shape, np.float32)
        m = _pad_pow2(s.size)
        sp = np.zeros(m, np.int32)
        tp = np.zeros(m, np.int32)
        sp[:s.size] = s
        tp[:t.size] = t
        res = self._hub_fn(dix, jnp.asarray(sp), jnp.asarray(tp))
        return np.asarray(res)[:s.size]

    def _dispatch(self, fns, s, t, outs, dix=None) -> None:
        """Shared bucket/pad/dispatch loop: partition (s, t), pad each
        bucket to a power of two, run its sub-program from ``fns`` and
        scatter every output array into the matching array of ``outs``.

        ``dix`` pins the epoch; defaulting to the planner's current
        pointer, read ONCE so a concurrent set_index between bucket
        dispatches cannot split one batch across two epochs.
        """
        dix = self.dix if dix is None else dix
        plan = self.plan(s, t, dix)
        self.last_counts = {c: int(ix.size) for c, ix in plan.items()}
        for case, idx in plan.items():
            if idx.size == 0:
                continue
            m = _pad_pow2(idx.size)
            sp = np.zeros(m, np.int32)
            tp = np.zeros(m, np.int32)
            sp[:idx.size] = s[idx]
            tp[:idx.size] = t[idx]
            res = fns[case](dix, jnp.asarray(sp), jnp.asarray(tp))
            if len(outs) == 1:
                res = (res,)
            for out, r in zip(outs, res):
                out[idx] = np.asarray(r)[:idx.size]

    def __call__(self, s, t) -> np.ndarray:
        return self.query(s, t)

    def query(self, s, t, *, dix=None) -> np.ndarray:
        """Planner-bucketed batched distances.  Pass ``dix`` to serve
        against an explicit epoch instead of the planner's current
        pointer — the serving runtime pins one epoch per micro-batch
        flush so a concurrent ``set_index`` cannot tear a flush across
        epochs (its cache entries are keyed to the same pin)."""
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        out = np.full(s.shape, np.inf, np.float32)
        self._dispatch(self._fns, s, t, (out,), dix=dix)
        return out

    def query_witness(self, s, t, *, dix=None
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Planner-bucketed return_witness serving -> (dist, wit).

        Same bucketing/padding as __call__, dispatching the witness
        sub-programs; wit follows the per-case encoding documented in
        device_engine (WIT_* / packed SUPER pair).  Self queries get
        distance 0 and WIT_NONE (nothing to unwind — the unwinder
        special-cases s == t first).  Pass ``dix`` to serve against an
        explicit epoch (EpochedEngine.query_path pairs it with the
        matching unwinder snapshot).
        """
        s = np.asarray(s, np.int32)
        t = np.asarray(t, np.int32)
        out = np.full(s.shape, np.inf, np.float32)
        wit = np.full(s.shape, -1, np.int32)
        self._dispatch(self._wfns, s, t, (out, wit), dix=dix)
        same = s == t
        out[same] = 0.0
        wit[same] = -1
        return out, wit


# ---------------------------------------------------------------------------
# epoch-swapped serving over a live-updating index
# ---------------------------------------------------------------------------
class EpochedEngine:
    """Serve batched queries while absorbing live edge-weight updates.

    Double-buffered epochs (DESIGN.md §9): queries always run against
    the current *immutable* DeviceIndex; ``apply_updates`` runs the
    incremental rebuild (device_engine.refresh_index) off to the side
    and then publishes the result as epoch e+1 with a single planner
    pointer swap.  Batches already in flight finish on epoch e — the
    old arrays stay alive exactly as long as something references them.

    Because the planner's jitted sub-programs take the index as an
    argument, an epoch swap compiles nothing; refresh cost is the only
    pause-free background work, and serving never blocks on it.
    """

    def __init__(self, g, *, c: int = 2, seed: int = 0, force=None,
                 ix: DislandIndex | None = None,
                 warm_refresh: bool = True, paths: bool = False,
                 hierarchy_levels: int | str = "auto",
                 resident_mb: float | str = "auto",
                 hub_nodes=None, build_workers: int = 1):
        self.g = g
        # streaming handoff (DESIGN.md §17): the device build needs only
        # the structural index (it never reads covers — make_build_plan
        # regathers all overlay weights from frag_apsp), so it runs
        # while the worker pool is still computing covers; finish()
        # joins them before the engine is returned to the caller.
        host_build = None
        if ix is None:
            host_build = start_build(g, c=c, seed=seed,
                                     build_workers=build_workers)
            ix = host_build.structural_index()
        self.ix = ix
        self.dix, self.plan = build_device_index_with_plan(
            self.ix, force=force, hierarchy_levels=hierarchy_levels,
            resident_mb=resident_mb, hub_nodes=hub_nodes)
        if host_build is not None:
            host_build.finish()
        self.planner = QueryPlanner(self.dix, force=force, paths=paths)
        self.epoch = 0
        # one-tuple publish (epoch, dix, graph, staleness): snapshot()
        # readers get a mutually consistent quadruple with a single
        # reference read, never a torn mix of old epoch number and new
        # index (or of an epoch and another epoch's staleness tag)
        self._published = (0, self.dix, self.g, refresh_pipeline.FRESH)
        self.force = force
        self.last_stats: RefreshStats | None = None
        # (dix, PathUnwinder) pair, replaced atomically (unwinder())
        self._unwinder: tuple | None = None
        self._lock = threading.Lock()
        if warm_refresh:
            # compile the refresh FW programs now, not mid-update
            warmup_refresh(self.plan, force=force)
            self._warm_refresh_path()

    def _warm_refresh_path(self) -> None:
        """Trace/compile the full delta path with a no-op update batch
        (existing edges re-assigned their current weights): exercises
        classification, the padded FW scatter/gather programs, and the
        piece rewrite, all without changing any distance — so the first
        real apply_updates runs entirely on warm programs."""
        plan = self.plan
        g = self.g
        fa = plan.frag_of
        picks: list = []
        # one edge in each of up to 8 distinct fragments (covers the
        # pow2-padded scatter shapes 4 and 8) ...
        m_frag = (fa[g.edge_u] >= 0) & (fa[g.edge_u] == fa[g.edge_v])
        e_frag = np.nonzero(m_frag)[0]
        if e_frag.size:
            _, first = np.unique(fa[g.edge_u[e_frag]], return_index=True)
            picks += list(e_frag[first[:8]])
        # ... and one edge in a piece of each bucket size in use
        gid_e = np.where(plan.piece_gid[g.edge_u] >= 0,
                         plan.piece_gid[g.edge_u],
                         plan.piece_gid[g.edge_v])
        e_piece = np.nonzero(gid_e >= 0)[0]
        if e_piece.size:
            _, first = np.unique(plan.piece_cap[gid_e[e_piece]],
                                 return_index=True)
            picks += list(e_piece[first])
        if not picks:
            return
        idx = np.asarray(sorted(set(picks)))
        refresh_index(self.dix, plan, g, g.edge_u[idx], g.edge_v[idx],
                      g.edge_w[idx], force=self.force)

    def query(self, s, t) -> np.ndarray:
        """Planner-bucketed batched queries on the current epoch."""
        return self.planner(s, t)

    def snapshot(self) -> tuple:
        """Atomic ``(epoch, dix, graph, staleness)`` read of the
        published state.

        The quadruple is replaced as one tuple by ``apply_updates``, so
        a reader can pin an epoch for a whole micro-batch flush — serve
        against ``dix``, key cache entries by ``epoch``, validate
        against ``graph``, tag responses with ``staleness`` — without
        holding any lock and without ever observing epoch e's number
        next to epoch e+1's arrays or another epoch's staleness tag.
        """
        return self._published

    def unwinder(self, dix: DeviceIndex | None = None) -> PathUnwinder:
        """A PathUnwinder paired with ``dix`` (default: the currently
        published epoch).  Cached by index identity, so repeated
        query_path calls within one epoch reuse the snapshot and a
        concurrent epoch publish can never mismatch witnesses with
        tables — the unwinder is keyed to the exact index object its
        witnesses were served from."""
        dix = self.dix if dix is None else dix
        cached = self._unwinder          # single atomic read: (dix, uw)
        if cached is not None and cached[0] is dix:
            return cached[1]
        uw = PathUnwinder(dix, self.plan)
        # publish as one tuple and return the locally built instance,
        # never the cache slot: a concurrent epoch publish may
        # overwrite the slot with another epoch's unwinder in between
        self._unwinder = (dix, uw)
        return uw

    def query_path(self, s, t) -> tuple[np.ndarray, list]:
        """Batched exact shortest *paths*.

        Returns (dist [q] f32, paths): paths[i] is the node sequence
        s_i -> t_i whose edge weights sum to exactly dist[i], or None
        when t_i is unreachable.  Distances come from the witness
        sub-programs (device); unwinding is host-side table chasing
        (DESIGN.md §10).  The epoch is pinned once: witnesses and
        unwinder both bind to the same index snapshot, so an
        apply_updates landing mid-call cannot tear them apart.
        """
        dix = self.planner.dix
        dist, wit = self.planner.query_witness(s, t, dix=dix)
        uw = self.unwinder(dix)
        return dist, uw.unwind_many(s, t, dist, wit)

    def warmup(self, batch_size: int) -> None:
        self.planner.warmup(batch_size)

    def apply_updates(self, u, v, w, *,
                      staleness: "refresh_pipeline.Staleness | None"
                      = None) -> RefreshStats:
        """Absorb a weight-update batch and publish the next epoch.

        Serving continues on the old epoch until the final swap; the
        lock only serializes concurrent updaters, never readers.
        ``staleness`` is the recency descriptor a staged caller
        (core.refresh_pipeline.RefreshPipeline) attaches to the
        published epoch; a direct (monolithic) call publishes a
        complete tag — the epoch reflects everything it was handed.
        """
        with self._lock:
            w_old = self.g.edge_w[self.g.edge_ids(u, v)]
            g_new = self.g.with_edge_weights(u, v, w)
            new_dix, stats = refresh_index(self.dix, self.plan, g_new,
                                           u, v, w, w_old=w_old,
                                           force=self.force)
            # an epoch publishes fully materialized: readers must never
            # stall on a lazily-executing refresh
            jax.block_until_ready(new_dix)
            # publish: readers see (epoch, dix) flip atomically per ref
            self.g = g_new
            self.dix = new_dix
            self.planner.set_index(new_dix)
            self.epoch += 1
            if staleness is None:
                prev = self._published[3]
                sub = max(prev.submitted, prev.watermark) + 1
                staleness = refresh_pipeline.Staleness(
                    watermark=sub, submitted=sub)
            self._published = (self.epoch, new_dix, g_new, staleness)
            self.last_stats = stats
            return stats


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------
def serve_sharded(mesh: Mesh, dix: DeviceIndex, s: jax.Array,
                  t: jax.Array, *,
                  batch_axes: Sequence[str] | None = None) -> jax.Array:
    """Batched queries sharded over ``batch_axes`` (default: all axes)."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(axes), P(axes)), out_specs=P(axes))
    def _local(dix_, s_, t_):
        return serve_step(dix_, s_, t_)

    return _local(dix, s, t)


def serve_jit(mesh: Mesh, dix_like, *,
              batch_axes: Sequence[str] | None = None):
    """jit'd sharded serve step with explicit in/out shardings, suitable
    for AOT lowering (dry-run).  ``dix_like`` is any DeviceIndex pytree
    (arrays or ShapeDtypeStructs) used to build the replicated specs."""
    axes = tuple(batch_axes) if batch_axes else tuple(mesh.axis_names)
    rep = NamedSharding(mesh, P())
    shard = NamedSharding(mesh, P(axes))
    dix_shardings = jax.tree_util.tree_map(lambda _: rep, dix_like)

    def step(dix: DeviceIndex, s: jax.Array, t: jax.Array) -> jax.Array:
        return serve_step(dix, s, t)

    return jax.jit(step, in_shardings=(dix_shardings, shard, shard),
                   out_shardings=shard)


# ---------------------------------------------------------------------------
# sharded offline build
# ---------------------------------------------------------------------------
def fw_fragments_sharded(mesh: Mesh, frag_adj: jax.Array,
                         axis: str = "data") -> jax.Array:
    """Offline per-fragment APSP with the fragment batch sharded."""

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=P(axis), out_specs=P(axis))
    def _local(adj):
        return ops.fw_batch(adj)

    return _local(frag_adj)


def super_apsp_sharded(mesh: Mesh, src: jax.Array, dst: jax.Array,
                       w: jax.Array, n_super: int,
                       axis: str = "data") -> jax.Array:
    """Offline SUPER APSP: BF sources sharded, edge list replicated."""
    srcs = jnp.arange(n_super, dtype=jnp.int32)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P(), P(), P(axis)),
                       out_specs=P(axis))
    def _local(src_, dst_, w_, sources_):
        return sssp.apsp_from_sources(src_, dst_, w_, sources_, n=n_super)

    return _local(src, dst, w, srcs)
