"""Pipelined prioritized refresh (DESIGN.md §14).

``refresh_index`` is exact but monolithic: one apply_updates call holds
the engine's refresh lock for the whole re-close, so a big batch leaves
the published epoch increasingly stale with no bound or visibility.
This module stages that work instead:

  UpdateQueue      update-coalescing queue (one slot per undirected
                   edge, last write wins) with batch sequence numbers.
  RefreshPipeline  partitions the pooled updates into per-group work
                   items, orders them by serving traffic, and applies
                   each through the engine's ordinary apply_updates —
                   publishing an intermediate epoch after every item.
  Staleness        the descriptor attached to each published epoch:
                   which batches it fully reflects (watermark), which
                   groups are still pending.

Exactness: each work item advances the engine's graph by exactly its
own edges, so every staged epoch is the true index of a well-defined
intermediate graph — staleness bounds *recency*, never correctness —
and the final epoch of a drain equals the monolithic refresh, which is
array-equal to a from-scratch rebuild (tests/test_refresh.py).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..obs import trace


@dataclasses.dataclass(frozen=True)
class Staleness:
    """Recency descriptor of one published epoch.

    ``watermark``: every update batch with sequence <= this is fully
    reflected.  ``submitted``: the newest batch sequence the queue had
    accepted when this epoch's drain was planned (edges from batches in
    (watermark, submitted] may be partially applied).
    ``pending_updates`` / ``pending_groups``: coalesced edges and
    level-1 groups still queued behind this epoch.
    """

    watermark: int = 0
    submitted: int = 0
    pending_updates: int = 0
    pending_groups: Tuple[int, ...] = ()

    @property
    def complete(self) -> bool:
        return (self.pending_updates == 0 and not self.pending_groups
                and self.watermark >= self.submitted)

    @property
    def lag_batches(self) -> int:
        return max(0, self.submitted - self.watermark)

    def as_record(self) -> dict:
        return {
            "watermark": self.watermark,
            "submitted": self.submitted,
            "lag_batches": self.lag_batches,
            "pending_updates": self.pending_updates,
            "pending_groups": len(self.pending_groups),
            "complete": self.complete,
        }


#: the descriptor a freshly built (never refreshed) engine publishes
FRESH = Staleness()


class UpdateQueue:
    """Update-coalescing queue.

    One slot per undirected edge; a later submit of the same edge
    overwrites the earlier weight (only the newest weight can matter —
    the pipeline serves exact distances per epoch, not history).
    ``submit`` returns the batch sequence number for staleness
    accounting; ``take`` atomically drains the pool.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._pending: dict = {}
        self.submitted = 0

    def submit(self, u, v, w) -> int:
        u = np.asarray(u, np.int64)
        v = np.asarray(v, np.int64)
        w = np.asarray(w, np.float64)
        lo = np.minimum(u, v)
        hi = np.maximum(u, v)
        with self._lock:
            for a, b, x in zip(lo, hi, w):
                self._pending[(int(a), int(b))] = float(x)
            self.submitted += 1
            return self.submitted

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def take(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """-> (u, v, w, submitted): the pooled edges and the newest
        batch sequence they cover, atomically."""
        with self._lock:
            items = self._pending
            self._pending = {}
            sub = self.submitted
        if not items:
            e = np.empty(0, np.int64)
            return e, e.copy(), np.empty(0, np.float64), sub
        keys = np.asarray(list(items.keys()), np.int64).reshape(-1, 2)
        w = np.asarray(list(items.values()), np.float64)
        return keys[:, 0], keys[:, 1], w, sub


class RefreshPipeline:
    """Traffic-prioritized staged refresh over an EpochedEngine.

    ``traffic``: optional zero-arg callable returning per-fragment
    serving counts (ServingRuntime.frag_traffic); the busiest groups
    re-close first so hot queries see fresh weights earliest.  Without
    it, groups order by their pending-edge count (most dirt first).
    ``max_items``: cap on work items per drain — the lowest-priority
    tail merges into one item so epoch churn stays bounded.

    ``plan`` stages the queue into work items; ``step`` applies one
    item (one intermediate epoch); ``drain`` runs plan + steps to
    completion.  Serving never waits on the whole pool: between steps
    the engine publishes a consistent epoch tagged with how far behind
    it is.
    """

    def __init__(self, engine, *,
                 traffic: Optional[Callable[[], np.ndarray]] = None,
                 max_items: int = 8) -> None:
        self.engine = engine
        self.queue = UpdateQueue()
        self.traffic = traffic
        self.max_items = max(1, int(max_items))
        self.watermark = 0
        self._lock = threading.Lock()
        self._items: List[tuple] = []
        self._submitted_at_plan = 0

    # ---- update intake --------------------------------------------------
    def submit(self, u, v, w) -> int:
        """Queue a weight-update batch; returns its sequence number."""
        return self.queue.submit(u, v, w)

    # ---- work-item planning ---------------------------------------------
    def _owner_group(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Level-1 group owning each edge's re-close work: piece edges
        route to the piece agent's fragment, same-fragment edges to
        that fragment, cross-fragment (E_B) edges to the
        higher-numbered endpoint fragment; fragments then map through
        ``sf_of_frag`` when the plan is hierarchical (each fragment IS
        the group on dense plans)."""
        plan = self.engine.plan
        gu, gv = plan.piece_gid[u], plan.piece_gid[v]
        gid = np.where(gu >= 0, gu, gv)
        agent_frag = plan.frag_of[
            plan.piece_agent[np.clip(gid, 0, None)]]
        frag = np.where(gid >= 0, agent_frag,
                        np.maximum(plan.frag_of[u], plan.frag_of[v]))
        frag = np.clip(frag, 0, None).astype(np.int64)
        if plan.hier:
            return plan.hier[0].sf_of_frag[frag].astype(np.int64)
        return frag

    def plan(self) -> int:
        """Stage the queued pool into prioritized work items; no-op if
        items from a previous plan are still pending.  Returns the
        number of pending items."""
        with self._lock:
            if self._items:
                return len(self._items)
            u, v, w, sub = self.queue.take()
            self._submitted_at_plan = sub
            if u.size == 0:
                return 0
            grp = self._owner_group(u, v)
            groups = np.unique(grp)
            weight = np.zeros(groups.size, np.float64)
            if self.traffic is not None:
                per_frag = np.asarray(self.traffic(), np.float64)
                plan = self.engine.plan
                frag2grp = (plan.hier[0].sf_of_frag[:plan.k]
                            if plan.hier else np.arange(plan.k))
                for gi, gval in enumerate(groups):
                    weight[gi] = per_frag[
                        np.asarray(frag2grp) == gval].sum()
            else:
                for gi, gval in enumerate(groups):
                    weight[gi] = float((grp == gval).sum())
            # busiest first; group id breaks ties deterministically
            order = np.lexsort((groups, -weight))
            ordered = groups[order]
            head = ordered[:self.max_items - 1]
            tail = ordered[self.max_items - 1:]
            chunks = [np.asarray([g]) for g in head]
            if tail.size:
                chunks.append(tail)
            for gs in chunks:
                sel = np.isin(grp, gs)
                self._items.append(
                    (tuple(int(g) for g in gs),
                     (u[sel], v[sel], w[sel])))
            return len(self._items)

    # ---- execution ------------------------------------------------------
    def step(self):
        """Apply ONE planned work item and publish its epoch (tagged
        with what is still pending).  Returns the RefreshStats of the
        applied item, or None when nothing is planned."""
        with self._lock:
            if not self._items:
                return None
            item = self._items.pop(0)
            _groups, (u, v, w) = item
            rest = self._items
            # count BOTH the planned remainder and anything submitted
            # to the queue since this plan — a batch arriving mid-drain
            # must keep the published descriptor incomplete
            pending_updates = sum(it[1][0].size for it in rest) \
                + len(self.queue)
            pending_groups = tuple(
                g for it in rest for g in it[0])
            last = not rest
            sub = self._submitted_at_plan
            desc = Staleness(
                watermark=sub if last else self.watermark,
                submitted=max(sub, self.queue.submitted),
                pending_updates=int(pending_updates),
                pending_groups=pending_groups)
        try:
            with trace.span("refresh.item", groups=len(_groups),
                            n_updates=int(u.size),
                            pending=int(pending_updates)):
                stats = self.engine.apply_updates(u, v, w,
                                                  staleness=desc)
        except BaseException:
            # the engine rolled its caches back and published nothing:
            # put the item back so the pool is never silently dropped
            with self._lock:
                self._items.insert(0, item)
            raise
        if last:
            with self._lock:
                self.watermark = sub
        return stats

    def drain(self) -> list:
        """Plan the queued pool and apply every work item in priority
        order; returns the per-item RefreshStats list."""
        stats = []
        self.plan()
        while True:
            st = self.step()
            if st is None:
                break
            stats.append(st)
        return stats

    def pending_items(self) -> int:
        with self._lock:
            return len(self._items)
