"""Device (TPU) DISLAND engine: fixed-shape batched query answering.

Hardware adaptation of the paper's per-query Dijkstra (DESIGN.md §2):
every query path becomes gathers + (min,+) algebra over padded tensors.

Offline (build_device_index, device-resident products):
  * per-fragment dense APSP        [k, maxf, maxf]   (Pallas blocked FW)
  * boundary-row table             [k, maxf, mb]     (node -> boundary)
  * SUPER boundary x boundary APSP [S+1, S+1]        (dense FW closure)
  * per-piece APSP, flattened      [sum_b P_b*mp_b^2] (+ per-node
    base/stride so one gather answers any same-piece query)
  * per-node lookup vectors        agent/fragment/piece ids + positions

Online (serve_step — one jitted program per query batch):
  dist(s,t) = same-DRA answer                                (case 1)
            | d(s,u_s) + min(local, combine) + d(u_t,t)      (case 2)
  combine = min_{b1,b2} row_s[b1] + D_super[b1,b2] + row_t[b2],
computed without ever materializing a [q, mb, mb] block: on TPU the
boundary rows are scattered into SUPER coordinates and contracted by
the fused minplus_twoside Pallas kernel (D_super tiles stay resident
in VMEM); on CPU an x-chunked gather keeps the peak intermediate at
[q, 8, mb] (DESIGN.md §4).

Also owned here: the hub-label hot tier's build (``hub_stage``) and
serve (``serve_hub``) halves — 2-hop labels over the closed hierarchy
for a pinned traffic-head node set, derived by batched (min,+)
products from the same tables above, no new graph searches
(DESIGN.md §15) — and ``refresh_index``, the staged delta path that
re-derives every table (labels included) array-equal to a scratch
rebuild on each epoch (DESIGN.md §9).

Everything is exact (validated against the host engine): integer
weights make every f32 (min,+) sum exactly representable, so "exact"
means bit-for-bit, regardless of association order.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..obs import trace
from . import hierarchy, padding
from .supergraph import DislandIndex

INF = np.float32(np.inf)
PIECE_BUCKETS = (8, 32, 128, 512, 2048)


def _dummy(shape, fill, dtype):
    return lambda: jnp.full(shape, fill, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    # per-node lookups [n]
    agent_of: jax.Array          # int32
    dist_to_agent: jax.Array     # f32
    frag_of: jax.Array           # int32 (fragment of each *shrink* node)
    pos_in_frag: jax.Array       # int32
    piece_gid: jax.Array         # int32 global piece id (-1 if none)
    pos_in_piece: jax.Array      # int32
    piece_base: jax.Array        # int32 offset of piece block in flat
    piece_stride: jax.Array      # int32 row stride (= padded piece size)
    # fragments
    frag_apsp: jax.Array         # f32 [k, maxf, maxf]
    frag_next: jax.Array         # int32 [k, maxf, maxf] FW first hop (-1)
    brow: jax.Array              # f32 [k, maxf, mb] node->boundary rows
    bpos: jax.Array              # int32 [k, mb] boundary position in frag
    bvalid: jax.Array            # bool [k, mb]
    bnd_super: jax.Array         # int32 [k, mb] super id (S = sentinel)
    # super graph (dense overlay; authoritative at hierarchy_levels=1)
    d_super: jax.Array           # f32 [S+1, S+1] (+inf sentinel row/col)
    super_next: jax.Array        # int32 [S+1, S+1] overlay first hop (-1)
    # pieces: every bucketed APSP tensor, flattened end to end
    piece_flat: jax.Array        # f32 [sum_b P_b * mp_b * mp_b]
    piece_next: jax.Array        # int32, same layout as piece_flat (-1)
    # hierarchical overlay (hierarchy_levels=N, DESIGN.md §12-13).  One
    # tuple entry per grouping level, bottom first; the dense pair
    # above shrinks to a [1, 1] dummy and these per-level tables take
    # over, with d2/d2_next holding the TOP (last level's boundary)
    # closure.  At levels=1 the tuples are empty.  Serve/unwind code
    # dispatches on len(sf_of) — a static trace-time fact (tuple
    # lengths live in the pytree treedef), so no flags thread through
    # jit.
    sf_of: tuple = ()        # int32 [S_l+1] each (group count = sentinel)
    pos_in_sf: tuple = ()    # int32 [S_l+1]
    sf_members: tuple = ()   # int32 [ng+1, m2] (S_l = pad)
    sf_closure: tuple = ()   # f32 [ng+1, m2, m2]
    sf_next: tuple = ()      # int32 [ng+1, m2, m2]
    l2row: tuple = ()        # f32 [ng+1, m2, mb2]
    bnd2_sid: tuple = ()     # int32 [ng+1, mb2] (S_{l+1} = pad)
    d2: jax.Array = dataclasses.field(             # f32 [S_top+1, S_top+1]
        default_factory=_dummy((1, 1), INF, jnp.float32))
    d2_next: jax.Array = dataclasses.field(        # int32 [S_top+1, S_top+1]
        default_factory=_dummy((1, 1), -1, jnp.int32))
    # epoch-resident pre-lifted rows (DESIGN.md §13): for each hot
    # level-1 super-fragment, its members' exact confined distances to
    # every TOP boundary node — so a hot cross-top-group query is one
    # fused minplus_twoside against d2 with no per-level lifting.
    # res_rows row [R] is the all-INF sentinel; res_of_frag maps every
    # fragment to its group's resident row (R when not resident).
    res_rows: jax.Array = dataclasses.field(       # f32 [R+1, m2, S_top+1]
        default_factory=_dummy((1, 1, 1), INF, jnp.float32))
    res_of_frag: jax.Array = dataclasses.field(    # int32 [k]
        default_factory=_dummy((1,), 0, jnp.int32))
    # fragment -> TOP-level group (device twin of the planner sidecar
    # host_topgrp_frag): the CPU serve path uses it to contract only
    # against each endpoint's own top-group boundary columns
    topgrp_of_frag: jax.Array = dataclasses.field(  # int32 [k]
        default_factory=_dummy((1,), 0, jnp.int32))
    # 2-hop hub labels for the hot serving tier (DESIGN.md §15): row
    # hub_of_agent[a] of hub_rows is agent a's label — its exact
    # overlay distance to every TOP closure coordinate (dense epochs:
    # every SUPER node).  The last row is the all-INF sentinel and
    # unlabeled agents map to it, so a mis-gated merge degrades to
    # +inf, never a wrong finite distance.  Derived by hub_stage from
    # (brow, per-level tables, d2); refresh re-derives it whenever any
    # of those inputs move, keeping refresh == rebuild array-equal.
    hub_rows: jax.Array = dataclasses.field(        # f32 [H+1, W]
        default_factory=_dummy((1, 1), INF, jnp.float32))
    hub_of_agent: jax.Array = dataclasses.field(    # int32 [n]
        default_factory=_dummy((1,), 0, jnp.int32))

    @property
    def hierarchy_levels(self) -> int:
        return 1 + len(self.sf_of)

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        children = tuple(getattr(self, f.name) for f in fields)
        return children, tuple(f.name for f in fields)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(**dict(zip(names, children)))


# ---------------------------------------------------------------------------
# offline build, staged (DESIGN.md §2, §9)
#
# The build is decomposed into per-stage functions over a host-side
# BuildPlan so the incremental refresh path (refresh_index) can re-run
# exactly the stage subset a weight-update batch dirties, while a
# from-scratch build composes every stage.  Both paths run the same
# per-item tensor programs, which is what makes "incremental rebuild ==
# from-scratch rebuild" hold array-for-array (tests/test_refresh.py).
# ---------------------------------------------------------------------------
# canonical padding rules live in padding.py (shared with the planner
# and the serving scheduler); the old private names stay as aliases
_pad_to = padding.pad_to
_pow2 = padding.pow2


@dataclasses.dataclass
class BuildPlan:
    """Host-side skeleton of the device index.

    Everything the refresh path needs that serve-time tensors do not
    carry: the mutable weight caches (``frag_adj``, ``sup_w``), the
    fixed SUPER edge-list *structure* with per-slot provenance, and the
    piece registry.  Structure (DRAs, fragments, SUPER topology) is
    weight-invariant, so a weight-update batch mutates only the caches
    and the plan survives arbitrarily many epochs.
    """

    n: int
    k: int
    maxf: int
    mb: int
    S: int
    # per-node host lookups (update classification)
    agent_of: np.ndarray
    frag_of: np.ndarray          # original id -> fragment (-1: represented)
    pos_in_frag: np.ndarray
    piece_gid: np.ndarray
    pos_in_piece: np.ndarray
    # fragments
    frag_adj: np.ndarray         # f32 [k, maxf, maxf] current weights
    bpos: np.ndarray
    bvalid: np.ndarray
    bnd_super: np.ndarray
    # SUPER edge slots (undirected, compact ids; structure is fixed)
    sup_src: np.ndarray          # int32 [Es]
    sup_dst: np.ndarray          # int32 [Es]
    sup_w: np.ndarray            # f32 [Es] current weights
    sup_fi: np.ndarray           # int32 [Es] owning fragment (-1: E_B)
    sup_pu: np.ndarray           # int32 [Es] frag-local gather row
    sup_pv: np.ndarray           # int32 [Es] frag-local gather col
    eb_key: np.ndarray           # int64 sorted lo*n+hi keys of E_B slots
    eb_slot: np.ndarray          # int64 slot per key
    # piece registry (gid order)
    piece_members: List[np.ndarray]   # sorted original ids, incl. agent
    piece_agent: np.ndarray           # int32 [P]
    piece_agent_pos: np.ndarray       # int32 [P]
    piece_cap: np.ndarray             # int32 [P] padded size
    piece_base: np.ndarray            # int64 [P] offset into piece_flat
    # overlay hierarchy (DESIGN.md §12-13): 1 = dense d_super closure,
    # N >= 2 = per-group closures at N-1 grouping levels (``hier``, one
    # HierPlan per level, bottom first) + dense TOP boundary closure
    hierarchy_levels: int = 1
    hier: "List[hierarchy.HierPlan] | None" = None
    # resident pre-lift budget in MiB (0 disables; DESIGN.md §13)
    resident_mb: float = 0.0
    # hub-label hot tier (DESIGN.md §15): the pinned node set whose
    # agents get 2-hop labels (None/empty disables).  Selection is a
    # *build input*, not derived state — refresh re-labels exactly this
    # set, which is what keeps refresh == rebuild array-equal; a new
    # traffic-driven selection is a new plan, not a refresh.
    hub_nodes: "np.ndarray | None" = None
    # per-stage wall times of the build that produced this plan
    # (DESIGN.md §16; filled by build_device_index_with_plan through
    # the trace.timed span API, same measurement the trace events see)
    build_timings: "dict | None" = None

    @property
    def n_pieces(self) -> int:
        return len(self.piece_members)


def make_build_plan(ix: DislandIndex) -> BuildPlan:
    """Stage 0: host-side structure assembly (no device work).

    The device SUPER overlay is rebuilt here from first principles
    rather than taken from ``ix.super_graph.graph``: its node universe
    is exactly the boundary nodes (all bnd_super can ever reference),
    E_B slots are the cross-fragment shrink edges, and each fragment
    contributes its full boundary-to-boundary clique whose weights are
    *gathered from frag_apsp* (super_weights) — never stored
    authoritatively.  The host index keeps the paper's hybrid landmark
    covers (§V-A) for its space story; the device overlay cannot,
    because a cover's pair structure encodes which node lies on a
    shortest path — a weight-dependent fact that a live update batch
    silently invalidates (DESIGN.md §9).  The clique structure is
    weight-invariant, so scratch build and incremental refresh obtain
    every overlay weight by the same gather.
    """
    g = ix.g
    n = g.n
    k = len(ix.fragments)

    # ---- fragments + boundary universe ---------------------------------
    maxf = _pad_to(max((f.graph.n for f in ix.fragments), default=1))
    mb = _pad_to(max((f.boundary_local.size for f in ix.fragments),
                     default=1))
    frag_adj = np.full((k, maxf, maxf), INF, dtype=np.float32)
    frag_of = -np.ones(n, dtype=np.int32)
    pos_in_frag = np.zeros(n, dtype=np.int32)
    bpos = np.zeros((k, mb), dtype=np.int32)
    bvalid = np.zeros((k, mb), dtype=bool)
    bnd_ids = np.unique(np.concatenate(
        [f.nodes[f.boundary_local] for f in ix.fragments]
        or [np.empty(0, np.int64)]))
    S = bnd_ids.size
    bnd_super = np.full((k, mb), S, dtype=np.int32)
    super_id_of = -np.ones(n, dtype=np.int64)
    super_id_of[bnd_ids] = np.arange(S)
    for fi, f in enumerate(ix.fragments):
        fg = f.graph
        frag_of[f.nodes] = fi
        pos_in_frag[f.nodes] = np.arange(f.nodes.size)
        frag_adj[fi, fg.edge_u, fg.edge_v] = fg.edge_w.astype(np.float32)
        frag_adj[fi, fg.edge_v, fg.edge_u] = fg.edge_w.astype(np.float32)
        nb = f.boundary_local.size
        bpos[fi, :nb] = f.boundary_local
        bvalid[fi, :nb] = True
        bnd_super[fi, :nb] = super_id_of[f.nodes[f.boundary_local]]

    # ---- SUPER edge slots (vectorized; slot order is E_B in shrink
    # edge order, then per-fragment cliques row-major — the exact
    # layout the per-slot Python loops this replaces produced) --------
    shrink = ix.shrink
    lab = ix.partition.labels
    # E_B: cross-fragment shrink edges (both endpoints boundary by
    # construction); same-fragment boundary-boundary edges are subsumed
    # by that fragment's clique, so every edge has ONE owning slot kind
    cross = lab[shrink.edge_u] != lab[shrink.edge_v]
    ou = ix.shrink_ids[shrink.edge_u[cross]].astype(np.int64)
    ov = ix.shrink_ids[shrink.edge_v[cross]].astype(np.int64)
    ek = np.minimum(ou, ov) * n + np.maximum(ou, ov)
    es = np.arange(ou.size, dtype=np.int64)
    src_parts = [super_id_of[ou].astype(np.int32)]
    dst_parts = [super_id_of[ov].astype(np.int32)]
    w_parts = [shrink.edge_w[cross].astype(np.float32)]
    fi_parts = [np.full(ou.size, -1, dtype=np.int32)]
    pu_parts = [np.full(ou.size, -1, dtype=np.int32)]
    pv_parts = [np.full(ou.size, -1, dtype=np.int32)]
    # per-fragment boundary cliques (paper §V-A Upsilon weights, derived)
    for fi, f in enumerate(ix.fragments):
        bl = f.boundary_local
        ids = super_id_of[f.nodes[bl]]
        ii, jj = np.triu_indices(bl.size, k=1)
        src_parts.append(ids[ii].astype(np.int32))
        dst_parts.append(ids[jj].astype(np.int32))
        w_parts.append(np.full(ii.size, INF, dtype=np.float32))
        fi_parts.append(np.full(ii.size, fi, dtype=np.int32))
        pu_parts.append(bl[ii].astype(np.int32))
        pv_parts.append(bl[jj].astype(np.int32))
    sup_src = np.concatenate(src_parts)
    sup_dst = np.concatenate(dst_parts)
    sup_w = np.concatenate(w_parts)
    sup_fi = np.concatenate(fi_parts)
    sup_pu = np.concatenate(pu_parts)
    sup_pv = np.concatenate(pv_parts)
    order = np.argsort(ek)

    # ---- piece registry + per-node lookups ------------------------------
    piece_gid = -np.ones(n, dtype=np.int32)
    pos_in_piece = np.zeros(n, dtype=np.int32)
    piece_members: List[np.ndarray] = []
    piece_agent: List[int] = []
    piece_agent_pos: List[int] = []
    piece_cap: List[int] = []
    for a in ix.dras.agents:
        for piece in a.pieces:
            cap = next(c for c in PIECE_BUCKETS if piece.size <= c)
            ids = np.unique(np.asarray(piece, dtype=np.int32))
            gid = len(piece_members)
            piece_members.append(ids)
            piece_agent.append(int(a.agent))
            piece_agent_pos.append(int(np.searchsorted(ids, a.agent)))
            piece_cap.append(cap)
            # the agent belongs to many pieces: leave its lookup at -1 so
            # case-1 logic falls through to the exact ds+dt formula
            inner = ids != a.agent
            piece_gid[ids[inner]] = gid
            pos_in_piece[ids[inner]] = np.nonzero(inner)[0]
    # flat layout: bucket-major (all cap-8 blocks, then cap-32, ...),
    # bucket-local order = gid order — matches piece_stage's FW batching
    cap_arr = np.asarray(piece_cap, dtype=np.int64)
    piece_base = np.zeros(len(piece_members), dtype=np.int64)
    off = 0
    for cap in PIECE_BUCKETS:
        for gid in np.nonzero(cap_arr == cap)[0]:
            piece_base[gid] = off
            off += cap * cap

    return BuildPlan(
        n=n, k=k, maxf=maxf, mb=mb, S=S,
        agent_of=ix.dras.agent_of.astype(np.int32),
        frag_of=frag_of, pos_in_frag=pos_in_frag,
        piece_gid=piece_gid, pos_in_piece=pos_in_piece,
        frag_adj=frag_adj, bpos=bpos, bvalid=bvalid, bnd_super=bnd_super,
        sup_src=np.asarray(sup_src, dtype=np.int32),
        sup_dst=np.asarray(sup_dst, dtype=np.int32),
        sup_w=np.asarray(sup_w, dtype=np.float32),
        sup_fi=np.asarray(sup_fi, dtype=np.int32),
        sup_pu=np.asarray(sup_pu, dtype=np.int32),
        sup_pv=np.asarray(sup_pv, dtype=np.int32),
        eb_key=ek[order], eb_slot=es[order],
        piece_members=piece_members,
        piece_agent=np.asarray(piece_agent, dtype=np.int32),
        piece_agent_pos=np.asarray(piece_agent_pos, dtype=np.int32),
        piece_cap=cap_arr.astype(np.int32),
        piece_base=piece_base,
    )


def _brow_from(frag_apsp: jax.Array, bpos: np.ndarray,
               bvalid: np.ndarray) -> jax.Array:
    """Boundary-row table: brow[f, p, b] = dist(node at position p,
    boundary slot b) — serve gathers one row per query endpoint instead
    of a take_along_axis over [q, maxf]."""
    brow = jnp.take_along_axis(frag_apsp,
                               jnp.asarray(bpos)[:, None, :], axis=2)
    return jnp.where(jnp.asarray(bvalid)[:, None, :], brow, INF)


def frag_stage(plan: BuildPlan, *, force=None) -> tuple[jax.Array,
                                                        jax.Array,
                                                        jax.Array]:
    """Stage 1: batched witness FW over every fragment ->
    (apsp, brow, next).  The witness kernel's distance output is
    bit-identical to the distance-only kernel (same recurrence, same
    pivot order), so the path table rides along for free."""
    frag_apsp, frag_next = ops.fw_batch_next(jnp.asarray(plan.frag_adj),
                                             force=force)
    return (frag_apsp, _brow_from(frag_apsp, plan.bpos, plan.bvalid),
            frag_next)


def super_weights(plan: BuildPlan, blocks: np.ndarray,
                  frags: np.ndarray | None = None) -> None:
    """Fill the enforced SUPER slot weights by gathering from fragment
    APSP ``blocks`` (DESIGN.md §9: the Upsilon weights are *derived*
    state, never stored authoritatively).

    ``frags=None``: blocks is the full [k, maxf, maxf] table, fill every
    enforced slot.  Otherwise blocks holds only the listed fragments'
    rows, and only their slots are rewritten.
    """
    if frags is None:
        mask = plan.sup_fi >= 0
        local = plan.sup_fi[mask]
    else:
        mask = np.isin(plan.sup_fi, frags)
        fi_to_row = -np.ones(plan.k, dtype=np.int64)
        fi_to_row[frags] = np.arange(len(frags))
        local = fi_to_row[plan.sup_fi[mask]]
    plan.sup_w[mask] = blocks[local, plan.sup_pu[mask], plan.sup_pv[mask]]


def super_overlay(plan: BuildPlan) -> jax.Array:
    """Dense [S, S] overlay adjacency from the slot list (parallel
    slots min-merged, diag 0)."""
    S = plan.S
    m = np.full((S, S), INF, np.float32)
    np.minimum.at(m, (plan.sup_src, plan.sup_dst), plan.sup_w)
    np.minimum.at(m, (plan.sup_dst, plan.sup_src), plan.sup_w)
    np.fill_diagonal(m, 0.0)
    return jnp.asarray(m)


def overlay_slot_table(plan: BuildPlan) -> np.ndarray:
    """Winning slot id per overlay adjacency pair [S, S] (-1: none).

    Writes slots in descending weight order so the last (= lightest)
    write wins, matching super_overlay's min-merge of parallel slots.
    Computed whenever the overlay is (re)closed and carried on the
    published DeviceIndex as the host-side ``host_ov_slot`` sidecar, so
    path unwinding always reads slot provenance consistent with the
    d_super/super_next epoch it serves — never the live-mutating
    ``plan.sup_w`` (DESIGN.md §10).
    """
    ov = np.full((plan.S, plan.S), -1, np.int32)
    if plan.sup_w.size:
        order = np.argsort(plan.sup_w, kind="stable")[::-1]
        src, dst = plan.sup_src[order], plan.sup_dst[order]
        ov[src, dst] = order
        ov[dst, src] = order
    return ov


def super_stage(plan: BuildPlan, *, force=None) -> tuple[jax.Array,
                                                         jax.Array]:
    """Stage 2: SUPER APSP — dense witness FW closure of the boundary
    overlay -> (d_super, super_next).

    The overlay is small and clique-dense, which is exactly the regime
    where dense (min,+) algebra crushes edge-list relaxation: the FW
    closure solves S=625 in ~60ms where the segment_min Bellman-Ford
    needed a diameter's worth of ~750ms sweeps (~20s) — measured on
    road4000, bit-identical results.  The same closure serves scratch
    builds and incremental refreshes: a warm-started BF was tried for
    the refresh path and measured out (negative-result note in sssp.py;
    the edge-list BF remains the tool for the large sparse sharded
    build, dist_engine.super_apsp_sharded).  Since PR 3 the closure
    carries the first-hop witness matrix (DESIGN.md §10): super_next
    chains through overlay-*adjacent* super nodes, and each adjacency
    hop is resolved back to a concrete slot by PathUnwinder via the
    epoch's overlay_slot_table sidecar.
    """
    S = plan.S
    d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)
    super_next = jnp.full((S + 1, S + 1), -1, jnp.int32)
    if S == 0 or plan.sup_src.size == 0:
        return d_super, super_next
    d_s, n_s = ops.fw_next(super_overlay(plan), force=force)
    return (d_super.at[:S, :S].set(d_s),
            super_next.at[:S, :S].set(n_s))


def _piece_adj(g, members: np.ndarray, cap: int) -> np.ndarray:
    sub, _ids = g.subgraph(members)
    adj = np.full((cap, cap), INF, dtype=np.float32)
    adj[sub.edge_u, sub.edge_v] = sub.edge_w.astype(np.float32)
    adj[sub.edge_v, sub.edge_u] = sub.edge_w.astype(np.float32)
    return adj


def _fw_bucket(adjs: List[np.ndarray], *, force=None,
               pad_pow2: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Batched witness FW over equally-padded piece matrices ->
    (dist blocks, next blocks).  ``pad_pow2`` (refresh path) rounds the
    batch up with +inf dummies, floored at 8, so the jitted FW program
    compiles for O(log P) distinct batch shapes — and a typical
    localized update batch always hits the already-warm 8-shape
    (EpochedEngine pre-compiles it)."""
    cap = adjs[0].shape[0]
    batch = np.stack(adjs)
    if pad_pow2 and _pow2(len(adjs), floor=8) != len(adjs):
        full = np.full((_pow2(len(adjs), floor=8), cap, cap), INF,
                       np.float32)
        full[:len(adjs)] = batch
        batch = full
    out, nxt = ops.fw_batch_next(jnp.asarray(batch), force=force)
    out = np.asarray(out)[:len(adjs)]
    # Padding blocks are all-+inf: the FW recurrence only ever ADDS
    # (inf+inf = inf, no inf-inf), so no NaN can arise — audited and
    # pinned by the all-INF kernel tests in tests/test_kernels.py.
    # Guard it anyway: mismatches_oracle treats NaN as always-wrong,
    # so a kernel regression here must fail the build loudly, not
    # surface as serving mismatches three layers up.
    if np.isnan(out).any():
        raise FloatingPointError(
            "piece FW produced NaN (inf-padding arithmetic regressed?)")
    return (out, np.asarray(nxt)[:len(adjs)])


def piece_stage(plan: BuildPlan, g, *, force=None) -> tuple[np.ndarray,
                                                            np.ndarray]:
    """Stage 3: per-piece APSP, size-bucketed batched FW, flattened
    end-to-end into the single piece_flat gather table (DESIGN.md §3),
    plus the identically-laid-out first-hop witness table piece_next."""
    total = int(sum(int(c) * int(c) for c in plan.piece_cap))
    flat = np.full(max(total, 1), INF, dtype=np.float32)
    nflat = np.full(max(total, 1), -1, dtype=np.int32)
    for cap in PIECE_BUCKETS:
        gids = np.nonzero(plan.piece_cap == cap)[0]
        if gids.size == 0:
            continue
        adjs = [_piece_adj(g, plan.piece_members[gid], cap)
                for gid in gids]
        blocks, nexts = _fw_bucket(adjs, force=force)
        for gid, block, nxt in zip(gids, blocks, nexts):
            base = plan.piece_base[gid]
            flat[base:base + cap * cap] = block.reshape(-1)
            nflat[base:base + cap * cap] = nxt.reshape(-1)
    return flat, nflat


def hier_super_stage(plan: BuildPlan, *, force=None) -> dict:
    """Stage 2, hierarchical (DESIGN.md §12-13): close the overlay as
    an N-level partition hierarchy instead of one dense FW.

    Per grouping level, bottom first: fill the level's group adjacency
    from its source overlay's current slot weights (level 1 gathers
    ``plan.sup_w``; level l > 1 the previous level's derived ``l2_w``),
    run the existing batched witness FW once at the pow2 tile shape
    [nsf, m2, m2] (``hierarchy.sf_stage``), then gather the NEXT
    overlay's clique weights from those closures (derived state,
    exactly like the level-1 Upsilon weights).  Only the top boundary
    set closes densely (``hierarchy.l2_stage`` -> d2).  Returns the
    DeviceIndex field dict (per-level tuples) plus the host-side
    provenance sidecars (one SlotMap per level).
    """
    levels = plan.hier
    per: dict = {name: [] for name in (
        "sf_of", "pos_in_sf", "sf_members", "sf_closure", "sf_next",
        "l2row", "bnd2_sid")}
    l2_slots = []
    w = plan.sup_w
    for h in levels:
        hierarchy.sf_adj_fill(h, w)
        sf_closure, sf_next, l2row = hierarchy.sf_stage(h, force=force)
        hierarchy.hier_weights(h, np.asarray(sf_closure)[:h.nsf], w)
        Sl = h.sf_of.shape[0]                    # this overlay's size
        sf_of = np.concatenate([h.sf_of, [h.nsf]]).astype(np.int32)
        pos_in_sf = np.concatenate([h.pos_in_sf, [0]]).astype(np.int32)
        members = np.where(h.sf_members < 0, Sl,
                           h.sf_members).astype(np.int32)
        members = np.concatenate(
            [members, np.full((1, h.m2), Sl, np.int32)])
        bnd2_sid = np.concatenate(
            [h.bnd2_sid, np.full((1, h.mb2), h.S2, np.int32)])
        per["sf_of"].append(jnp.asarray(sf_of))
        per["pos_in_sf"].append(jnp.asarray(pos_in_sf))
        per["sf_members"].append(jnp.asarray(members))
        per["sf_closure"].append(sf_closure)
        per["sf_next"].append(sf_next)
        per["l2row"].append(l2row)
        per["bnd2_sid"].append(jnp.asarray(bnd2_sid))
        l2_slots.append(hierarchy.l2_slot_map(h))
        w = h.l2_w
    d2, d2_next = hierarchy.l2_stage(levels[-1], force=force)
    fields = {name: tuple(v) for name, v in per.items()}
    fields["d2"] = d2
    fields["d2_next"] = d2_next
    return {
        "fields": fields,
        "ov_slot": hierarchy.ov_slot_map(plan),
        "l2_slot": l2_slots,
    }


def _compose_minplus(U: jax.Array, M: jax.Array,
                     chunk: int = 32) -> jax.Array:
    """out[i, j] = min_b U[i, b] + M[b, j], chunked over b so the peak
    intermediate stays [m2, chunk, mb'] (build-time helper for the
    resident pre-lift; runs once per hot group per epoch)."""
    out = jnp.full((U.shape[0], M.shape[1]), INF, U.dtype)
    for i in range(0, U.shape[1], chunk):
        out = jnp.minimum(out, jnp.min(
            U[:, i:i + chunk, None] + M[None, i:i + chunk, :], axis=1))
    return out


def resident_stage(plan: BuildPlan, fields: dict) -> dict | None:
    """Stage 2b: epoch-resident pre-lifted rows (DESIGN.md §13).

    For each hot level-1 group g (top traffic mass, capped by
    ``plan.resident_mb``), compose the per-level lift chain once:

      U_g[p, c] = min over (a_1, ..., a_{L-1}) of
                  l2row[0][g, p, a_1] + l2row[1][g_2, pos(a_1), a_2]
                  + ... (+ sentinel-masked at every step)

    scattered to dense top coordinates — the exact confined distance
    from every member position p to every TOP boundary node c.  A hot
    cross-top-group query then runs ONE fused minplus_twoside against
    d2 instead of L per-level lifts; exact because a route between
    different top groups must touch the top boundary, and its prefix
    up to the first top contact stays hierarchically confined (no
    same-group legs apply: different top groups imply different groups
    at every level, since groups nest).

    Deterministic in (structure, per-level tables), so a refresh that
    re-runs it lands array-equal with a from-scratch build.  Returns
    the DeviceIndex field dict plus the planner's host sidecars, or
    None when disabled/degenerate.
    """
    levels = plan.hier
    if not levels or plan.resident_mb <= 0:
        return None
    h0 = levels[0]
    stp1 = int(fields["d2"].shape[0])
    if h0.nsf == 0 or stp1 <= 1:
        return None
    # traffic-mass proxy: original graph nodes per level-1 group (the
    # serve mix samples nodes uniformly-by-traffic, so member count is
    # the stationary hot-group weight)
    frag_nodes = np.bincount(plan.frag_of[plan.frag_of >= 0].astype(
        np.int64), minlength=plan.k)
    mass = np.zeros(h0.nsf, dtype=np.int64)
    np.add.at(mass, h0.sf_of_frag.astype(np.int64), frag_nodes)
    per_sf = h0.m2 * stp1 * 4
    cap = int(plan.resident_mb * (1 << 20)) // max(per_sf, 1)
    if cap <= 0:
        return None
    hot = np.sort(np.argsort(-mass, kind="stable")[:min(cap, h0.nsf)])
    l2rows, sids, poss = (fields["l2row"], fields["bnd2_sid"],
                          fields["pos_in_sf"])
    L = len(l2rows)
    rows_out = []
    for g in hot.tolist():
        U = l2rows[0][g]                         # [m2, mb2_1]
        ids = np.asarray(sids[0][g])             # next-overlay ids
        gg = g
        for li in range(1, L):
            sent = levels[li - 1].S2             # ids' sentinel value
            gg = int(levels[li].sf_of_frag[gg])  # groups nest upward
            p = np.asarray(poss[li])[ids]
            M = l2rows[li][gg][jnp.asarray(p)]   # [mb, mb']
            M = jnp.where(jnp.asarray(ids != sent)[:, None], M, INF)
            U = _compose_minplus(U, M)
            ids = np.asarray(sids[li][gg])
        dense = jnp.full((U.shape[0], stp1), INF, U.dtype)
        rows_out.append(dense.at[:, jnp.asarray(ids)].min(U))
    R = len(rows_out)
    res_rows = jnp.stack(
        rows_out + [jnp.full((h0.m2, stp1), INF, jnp.float32)])
    rmap = np.full(h0.nsf, R, np.int32)
    rmap[hot] = np.arange(R, dtype=np.int32)
    res_of_frag = rmap[h0.sf_of_frag.astype(np.int64)]
    top = h0.sf_of_frag.astype(np.int64)
    for li in range(1, L):
        top = levels[li].sf_of_frag.astype(np.int64)[top]
    return {
        "fields": {"res_rows": res_rows,
                   "res_of_frag": jnp.asarray(res_of_frag),
                   "topgrp_of_frag": jnp.asarray(top.astype(np.int32))},
        # planner sidecars: fragment -> resident row (-1: cold) and
        # fragment -> TOP group (the exactness gate)
        "res_frag": np.where(res_of_frag < R, res_of_frag,
                             -1).astype(np.int32),
        "topgrp_frag": top.astype(np.int32),
    }


def hub_stage(plan: BuildPlan, fields: dict) -> dict | None:
    """Stage 2c: 2-hop hub labels for the hot serving tier (§15).

    For every agent of a node in ``plan.hub_nodes`` (fragment-batched),
    compose its label row — the exact overlay distance from the agent
    to every TOP closure coordinate:

      lab[a, y] = min_{j, x} brow[f, p_a, j] + chain_f[j, x] + d2[x, y]

    where ``chain_f`` is the same per-level confined lift ladder the
    resident rows pre-compose (resident_stage), restricted to fragment
    f's boundary slots, and the trailing d2 contraction closes the row
    over the whole top boundary.  Dense epochs skip the ladder:
    lab[a] = brow row (min,+) d_super.  No Dijkstras anywhere — every
    leg is a batched (min,+) product over tables the build already
    carries.

    Exactness (the §15 merge argument): for endpoints in different TOP
    groups (dense: different fragments) the route must touch the top
    boundary; lab is then a pointwise-exact distance-to-hub vector, so
    min_y lab_s[y] + lab_t[y] equals the planner's two-sided combine —
    lower-bounded by the triangle inequality of the closed overlay
    metric, met at the route's first top contact (d2's diagonal is 0).
    Same-top-group pairs must fall through to the planner: their routes
    may never touch the hubs.

    Deterministic in (hub_nodes, brow, per-level tables, d2), so a
    refresh that re-runs it lands array-equal with a from-scratch
    build.  Returns the DeviceIndex field dict plus the planner's host
    sidecars, or None when disabled/degenerate.
    """
    nodes = plan.hub_nodes
    if nodes is None or len(nodes) == 0:
        return None
    nodes = np.asarray(nodes, np.int64)
    agents = np.unique(plan.agent_of[nodes].astype(np.int64))
    agents = agents[plan.frag_of[agents] >= 0]
    if agents.size == 0:
        return None
    brow = fields["brow"]
    levels = plan.hier
    frag_a = plan.frag_of[agents]
    pos_a = plan.pos_in_frag[agents]
    # fragment-batched construction; (fragment, agent) order is the
    # label row order, stable across build and refresh
    order = np.lexsort((agents, frag_a))
    agents, frag_a, pos_a = agents[order], frag_a[order], pos_a[order]
    H = int(agents.size)
    rows_out = []
    topgrp_frag = None
    if levels:
        h0 = levels[0]
        l2rows, sids = fields["l2row"], fields["bnd2_sid"]
        poss, d2 = fields["pos_in_sf"], fields["d2"]
        width = int(d2.shape[0])
        L = len(l2rows)
        p0 = np.asarray(poss[0])
        chains: dict[int, tuple] = {}

        def group_chain(g: int) -> tuple:
            """(U, ids): group g's confined member rows composed up the
            ladder (same loop as resident_stage, kept compact — the
            trailing d2 gather makes the dense scatter unnecessary)."""
            got = chains.get(g)
            if got is not None:
                return got
            U = l2rows[0][g]
            ids = np.asarray(sids[0][g])
            gg = g
            for li in range(1, L):
                sent = levels[li - 1].S2
                gg = int(levels[li].sf_of_frag[gg])
                p = np.asarray(poss[li])[ids]
                M = l2rows[li][gg][jnp.asarray(p)]
                M = jnp.where(jnp.asarray(ids != sent)[:, None], M, INF)
                U = _compose_minplus(U, M)
                ids = np.asarray(sids[li][gg])
            chains[g] = (U, ids)
            return chains[g]

        for f in np.unique(frag_a).tolist():
            sel = frag_a == f
            U, ids = group_chain(int(h0.sf_of_frag[f]))
            Z = U[jnp.asarray(p0[plan.bnd_super[f]])]    # [mb, mb_top]
            Z = jnp.where(jnp.asarray(plan.bvalid[f])[:, None], Z, INF)
            conf = _compose_minplus(
                brow[f][jnp.asarray(pos_a[sel])], Z)
            # sentinel ids land on d2's +inf row: absorbing, no mask
            rows_out.append(_compose_minplus(conf, d2[jnp.asarray(ids)]))
        top = h0.sf_of_frag.astype(np.int64)
        for li in range(1, L):
            top = levels[li].sf_of_frag.astype(np.int64)[top]
        topgrp_frag = top.astype(np.int32)
    else:
        d_super = fields["d_super"]
        width = int(d_super.shape[0])
        for f in np.unique(frag_a).tolist():
            sel = frag_a == f
            M = d_super[jnp.asarray(plan.bnd_super[f])]  # [mb, S+1]
            M = jnp.where(jnp.asarray(plan.bvalid[f])[:, None], M, INF)
            rows_out.append(_compose_minplus(
                brow[f][jnp.asarray(pos_a[sel])], M))
    hub_rows = jnp.concatenate(
        rows_out + [jnp.full((1, width), INF, jnp.float32)])
    hmap = np.full(plan.n, H, np.int32)          # sentinel row for all
    hmap[agents] = np.arange(H, dtype=np.int32)
    hub_agent = np.full(plan.n, -1, np.int32)    # planner gate sidecar
    hub_agent[agents] = np.arange(H, dtype=np.int32)
    return {
        "fields": {"hub_rows": hub_rows,
                   "hub_of_agent": jnp.asarray(hmap)},
        "hub_agent": hub_agent,
        # fragment -> TOP group, the hierarchical exactness gate — hub
        # serving must not depend on the resident stage having run
        "topgrp_frag": topgrp_frag,
    }


def hub_base_fields(plan: BuildPlan, src, brow) -> dict:
    """The hub_stage input dict from an index/field source: ``src``
    maps a field name to its current array (a dict from the build or
    refresh in flight, falling back to ``dix`` attributes), ``brow``
    is always the freshest fragment boundary rows."""
    base = {"brow": brow}
    if plan.hierarchy_levels >= 2:
        base.update({name: src(name) for name in
                     ("l2row", "bnd2_sid", "pos_in_sf", "d2")})
    else:
        base["d_super"] = src("d_super")
    return base


def resolve_hierarchy_levels(S: int, hierarchy_levels) -> int:
    """Normalize the ``hierarchy_levels`` build knob: "auto" switches
    off the dense overlay once S crosses hierarchy.AUTO_THRESHOLD (the
    planner then deepens on its own until the top closure fits);
    explicit 1..MAX_LEVELS is honored (degrading to 1 on an empty
    overlay; the built depth plan_hierarchy returns is authoritative
    when levels collapse early)."""
    if hierarchy_levels == "auto":
        hierarchy_levels = 2 if S > hierarchy.AUTO_THRESHOLD else 1
    try:
        lv = int(hierarchy_levels)
    except (TypeError, ValueError):
        raise ValueError(
            f"hierarchy_levels must be an int or 'auto': "
            f"{hierarchy_levels!r}")
    if not 1 <= lv <= hierarchy.MAX_LEVELS:
        raise ValueError(
            f"hierarchy_levels must be in 1..{hierarchy.MAX_LEVELS} "
            f"or 'auto': {hierarchy_levels!r}")
    if lv > 1 and S == 0:
        return 1
    return lv


def _node_piece_addressing(plan: BuildPlan) -> tuple[np.ndarray,
                                                     np.ndarray]:
    """Per-node (piece_base, piece_stride) vectors from the registry."""
    base = np.zeros(plan.n, dtype=np.int32)
    stride = np.zeros(plan.n, dtype=np.int32)
    hot = plan.piece_gid >= 0
    gid = plan.piece_gid[hot]
    base[hot] = plan.piece_base[gid]
    stride[hot] = plan.piece_cap[gid]
    return base, stride


#: default resident pre-lift budget (MiB) when ``resident_mb="auto"``
#: on a hierarchical index — sized so every road64k-scale group fits
RESIDENT_MB_AUTO = 64.0


def build_device_index_with_plan(
        ix: DislandIndex, *, force=None,
        hierarchy_levels: int | str = "auto",
        resident_mb: float | str = "auto",
        hub_nodes=None
        ) -> tuple[DeviceIndex, BuildPlan]:
    """Full from-scratch build: compose every stage, keep the plan
    around so refresh_index can run incrementally afterwards.

    ``hierarchy_levels`` picks the overlay closure: 1 = the dense
    [S+1, S+1] FW (unchanged, bit-identical to the pre-hierarchy
    index), N >= 2 = the N-level partition hierarchy (DESIGN.md
    §12-13), "auto" = hierarchical once S crosses
    ``hierarchy.AUTO_THRESHOLD``, deepening until the top closure fits
    under it.  ``resident_mb`` budgets the epoch-resident pre-lifted
    row cache on hierarchical indices ("auto" = RESIDENT_MB_AUTO; 0
    disables).  ``hub_nodes`` pins the hub-label hot-tier node set
    (DESIGN.md §15; None/empty disables the tier).
    """
    bt: dict = {}
    with trace.timed("build.plan", bt, "plan"):
        plan = make_build_plan(ix)
        if hub_nodes is not None and len(hub_nodes):
            plan.hub_nodes = np.asarray(hub_nodes, np.int64)
        lv = resolve_hierarchy_levels(plan.S, hierarchy_levels)
        if lv >= 2:
            plan.hier = hierarchy.plan_hierarchy(
                plan,
                levels="auto" if hierarchy_levels == "auto" else lv)
            # the planner may stop early on degenerate levels (or
            # deepen, under "auto"): the built depth is authoritative
            plan.hierarchy_levels = 1 + len(plan.hier)
            plan.resident_mb = (RESIDENT_MB_AUTO
                                if resident_mb == "auto"
                                else float(resident_mb))
        else:
            plan.hierarchy_levels = 1
    plan.build_timings = bt
    with trace.timed("build.frag_stage", bt, "frag_stage",
                     k=plan.k):
        frag_apsp, brow, frag_next = frag_stage(plan, force=force)
        super_weights(plan, np.asarray(frag_apsp))
    if plan.hierarchy_levels >= 2:
        with trace.timed("build.hier_super_stage", bt, "super_stage",
                         levels=plan.hierarchy_levels):
            hres = hier_super_stage(plan, force=force)
            hier_fields = dict(hres["fields"])
        with trace.timed("build.resident_stage", bt,
                         "resident_stage"):
            rres = resident_stage(plan, hier_fields)
            if rres is not None:
                hier_fields.update(rres["fields"])
        d_super = jnp.full((1, 1), INF, jnp.float32)
        super_next = jnp.full((1, 1), -1, jnp.int32)
    else:
        hres = None
        rres = None
        hier_fields = {}
        with trace.timed("build.super_stage", bt, "super_stage",
                         S=plan.S):
            d_super, super_next = super_stage(plan, force=force)
    with trace.timed("build.hub_stage", bt, "hub_stage"):
        hub = hub_stage(plan, hub_base_fields(
            plan, lambda name: hier_fields.get(name, d_super), brow))
    with trace.timed("build.piece_stage", bt, "piece_stage",
                     pieces=plan.n_pieces):
        piece_flat, piece_next = piece_stage(plan, ix.g, force=force)
    base, stride = _node_piece_addressing(plan)
    dix = DeviceIndex(
        **hier_fields,
        **({} if hub is None else hub["fields"]),
        agent_of=jnp.asarray(plan.agent_of),
        dist_to_agent=jnp.asarray(
            ix.dras.dist_to_agent.astype(np.float32)),
        frag_of=jnp.asarray(plan.frag_of),
        pos_in_frag=jnp.asarray(plan.pos_in_frag),
        piece_gid=jnp.asarray(plan.piece_gid),
        pos_in_piece=jnp.asarray(plan.pos_in_piece),
        piece_base=jnp.asarray(base),
        piece_stride=jnp.asarray(stride),
        frag_apsp=frag_apsp,
        frag_next=frag_next,
        brow=brow,
        bpos=jnp.asarray(plan.bpos),
        bvalid=jnp.asarray(plan.bvalid),
        bnd_super=jnp.asarray(plan.bnd_super),
        d_super=d_super,
        super_next=super_next,
        piece_flat=jnp.asarray(piece_flat),
        piece_next=jnp.asarray(piece_next),
    )
    # host-side sidecars (not pytree fields): slot provenance for the
    # overlay closure this index was built with.  Dense epochs carry
    # the [S, S] overlay_slot_table; hierarchical epochs carry the
    # sparse OvSlotMap (the dense table is exactly the quadratic host
    # object the hierarchy avoids) plus the small level-2 slot table.
    if hres is not None:
        dix.host_ov_slot = hres["ov_slot"]
        dix.host_l2_slot = hres["l2_slot"]
        if rres is not None:
            dix.host_res_frag = rres["res_frag"]
            dix.host_topgrp_frag = rres["topgrp_frag"]
    else:
        dix.host_ov_slot = overlay_slot_table(plan)
    if hub is not None:
        dix.host_hub_agent = hub["hub_agent"]
        if (hub["topgrp_frag"] is not None
                and getattr(dix, "host_topgrp_frag", None) is None):
            # hierarchical epoch without resident rows: the hub gate
            # still needs the fragment -> TOP group map
            dix.host_topgrp_frag = hub["topgrp_frag"]
    return dix, plan


def build_device_index(ix: DislandIndex, *, force=None,
                       hierarchy_levels: int | str = "auto",
                       resident_mb: float | str = "auto",
                       hub_nodes=None) -> DeviceIndex:
    """Assemble padded tensors on host, run device APSP preprocessing."""
    return build_device_index_with_plan(
        ix, force=force, hierarchy_levels=hierarchy_levels,
        resident_mb=resident_mb, hub_nodes=hub_nodes)[0]


def index_fields_equal(a: DeviceIndex, b: DeviceIndex,
                       names) -> dict:
    """Per-field array equality between two indices, tuple-field aware
    (per-level fields compare leaf-by-leaf).  Shared by the refresh
    differential harnesses in serve.py and the tests."""
    out = {}
    for name in names:
        la = jax.tree_util.tree_leaves(getattr(a, name))
        lb = jax.tree_util.tree_leaves(getattr(b, name))
        out[name] = (len(la) == len(lb) and all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(la, lb)))
    return out


def warmup_refresh(plan: BuildPlan, *, force=None) -> None:
    """Pre-compile the refresh-path FW programs (the small pow2
    fragment-batch shapes + one [8, cap, cap] batch per piece bucket in
    use), so no XLA compile lands inside a live apply_updates.  The
    overlay FW program is already warm from the build.  Mirrors
    QueryPlanner.warmup for the serve path (DESIGN.md §9)."""
    shapes = [(min(p, plan.k), plan.maxf, plan.maxf) for p in (4, 8)]
    shapes += [(8, int(cap), int(cap))
               for cap in np.unique(plan.piece_cap)]
    if plan.hier:
        # dirty group batches refresh at these pow2 shapes, per level
        for h in plan.hier:
            shapes += [(min(p, h.nsf), h.m2, h.m2) for p in (4, 8)]
    for shp in set(shapes):
        jax.block_until_ready(
            ops.fw_batch_next(jnp.full(shp, INF, jnp.float32),
                              force=force))


# ---------------------------------------------------------------------------
# incremental refresh (DESIGN.md §9; paper §IV/§V locality)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class UpdateClass:
    """A weight-update batch classified against the index structure.

    The paper's decomposition localizes every weight change: an edge is
    (i) inside one DRA piece, (ii) inside one fragment, and/or (iii) an
    E_B SUPER slot — nothing else.  Same-fragment boundary-boundary
    edges hit (ii) and (iii) simultaneously.
    """

    dirty_frags: np.ndarray      # fragment ids
    frag_fi: np.ndarray          # per same-fragment update
    frag_pu: np.ndarray
    frag_pv: np.ndarray
    frag_w: np.ndarray
    eb_slots: np.ndarray         # per E_B update
    eb_w: np.ndarray
    dirty_gids: np.ndarray       # piece ids
    n_inert: int                 # edges touching no served structure


def classify_updates(plan: BuildPlan, u, v, w) -> UpdateClass:
    """Map (u, v, new_w) updates onto dirty fragments / slots / pieces."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    gid_u = plan.piece_gid[u]
    gid_v = plan.piece_gid[v]
    piece_m = (gid_u >= 0) | (gid_v >= 0)
    gid = np.where(gid_u >= 0, gid_u, gid_v)
    # structural invariant (paper Props 3-9): a represented node's only
    # neighbours are its piece co-members and its agent
    other_gid = np.where(gid_u >= 0, gid_v, gid_u)
    other = np.where(gid_u >= 0, v, u)
    safe_gid = np.where(piece_m, gid, 0)
    ok = (~piece_m | (other_gid == gid)
          | (other == plan.piece_agent[safe_gid]))
    if not ok.all():
        bad = np.nonzero(~ok)[0][0]
        raise ValueError(
            f"edge ({int(u[bad])}, {int(v[bad])}) crosses piece "
            "boundaries; index structure does not admit it")
    # same-fragment updates (frag_adj entries)
    fu = plan.frag_of[u]
    fv = plan.frag_of[v]
    frag_m = ~piece_m & (fu >= 0) & (fu == fv)
    # E_B slots (covers cross-fragment edges AND same-fragment edges
    # whose endpoints are both boundary)
    key = np.minimum(u, v) * plan.n + np.maximum(u, v)
    if plan.eb_key.size:
        pos = np.clip(np.searchsorted(plan.eb_key, key), 0,
                      plan.eb_key.size - 1)
        eb_m = ~piece_m & (plan.eb_key[pos] == key)
        slots = plan.eb_slot[pos]
    else:
        eb_m = np.zeros(u.size, dtype=bool)
        slots = np.zeros(u.size, dtype=np.int64)
    inert = int((~piece_m & ~frag_m & ~eb_m).sum())
    return UpdateClass(
        dirty_frags=np.unique(fu[frag_m]).astype(np.int64),
        frag_fi=fu[frag_m],
        frag_pu=plan.pos_in_frag[u[frag_m]],
        frag_pv=plan.pos_in_frag[v[frag_m]],
        frag_w=w[frag_m],
        eb_slots=slots[eb_m],
        eb_w=w[eb_m],
        dirty_gids=np.unique(gid[piece_m]).astype(np.int64),
        n_inert=inert,
    )


@dataclasses.dataclass
class RefreshStats:
    """What one refresh_index call touched, for perflog records."""

    n_updates: int
    n_dirty_frags: int
    n_frags: int
    n_dirty_pieces: int
    n_pieces: int
    n_eb_slots: int
    n_inert: int
    total_increase: float
    decrease_only: bool          # no weight rose (jam-clear batch)
    timings: dict
    # how the top closure was produced: "carry" (no overlay delta),
    # "decrease" (bounded relaxation fast path), "full_fw", "dense"
    top_closure: str = "carry"

    @property
    def dirty_frag_frac(self) -> float:
        return self.n_dirty_frags / max(self.n_frags, 1)

    def as_record(self) -> dict:
        return {
            "n_updates": self.n_updates,
            "dirty_frags": f"{self.n_dirty_frags}/{self.n_frags}",
            "dirty_frag_frac": round(self.dirty_frag_frac, 4),
            "dirty_pieces": f"{self.n_dirty_pieces}/{self.n_pieces}",
            "decrease_only": self.decrease_only,
            "top_closure": self.top_closure,
            "refresh_s": round(self.timings.get("total", 0.0), 4),
            # full per-stage split (classify/frag_fw/super_fw/hub/
            # pieces), so a refresh regression in the record history is
            # attributable to a stage, not just a bigger total
            "stage_timings": {
                k: round(v, 4)
                for k, v in sorted(self.timings.items())
                if k != "total"},
        }


def refresh_frag_stage(plan: BuildPlan, frag_apsp: jax.Array,
                       brow: jax.Array, frag_next: jax.Array,
                       upd: UpdateClass, *,
                       force=None) -> tuple[jax.Array, jax.Array,
                                            jax.Array, np.ndarray]:
    """Re-run witness FW on the dirty fragment subset only.

    The dirty batch is padded to a power of two with +inf dummies so
    refreshes compile O(log k) FW programs total; FW is row-independent
    across the batch, so the dirty rows come out bit-identical to a
    full-batch from-scratch run — distances and first-hop witnesses
    alike, which is what keeps epochs path-consistent (DESIGN.md §10).
    """
    plan.frag_adj[upd.frag_fi, upd.frag_pu, upd.frag_pv] = upd.frag_w
    plan.frag_adj[upd.frag_fi, upd.frag_pv, upd.frag_pu] = upd.frag_w
    dirty = upd.dirty_frags
    if dirty.size == 0:
        return frag_apsp, brow, frag_next, np.empty(
            (0, plan.maxf, plan.maxf), np.float32)
    # every array op below runs at the padded size: repeating the first
    # dirty fragment is idempotent (same rows scattered twice), and the
    # fixed shapes keep refreshes on pre-compiled programs
    # (warmup_refresh) instead of compiling one per dirty count
    d = int(dirty.size)
    p = min(_pow2(d, floor=4), plan.k)
    pad = np.concatenate([dirty, np.full(p - d, dirty[0], np.int64)]) \
        if p > d else dirty
    jpad = jnp.asarray(pad)
    jblocks, jnexts = ops.fw_batch_next(jnp.asarray(plan.frag_adj[pad]),
                                        force=force)
    frag_apsp = frag_apsp.at[jpad].set(jblocks)
    frag_next = frag_next.at[jpad].set(jnexts)
    br = _brow_from(jblocks, plan.bpos[pad], plan.bvalid[pad])
    return (frag_apsp, brow.at[jpad].set(br), frag_next,
            np.asarray(jblocks[:d]))


def refresh_hier_stage(plan: BuildPlan, dix: DeviceIndex,
                       changed_slots: np.ndarray, undo: dict, *,
                       force=None) -> dict:
    """Hierarchical twin of the dense overlay re-close (DESIGN.md
    §12-13): cascade the dirty-slot delta up the level ladder.

    At each level, a changed source slot dirties either one group's
    adjacency block (both endpoints inside it — re-close those groups'
    FW tiles, pow2-padded with repeats, bit-identical to a
    from-scratch hier_super_stage) or a cross slot (a direct
    next-level weight copy) — nothing else, the same block-diagonal
    structure the fragment refresh exploits one level down.  The
    *observed* next-level weight delta (l2_w before vs after) is what
    propagates: the cascade stops at the first level whose boundary
    weights came out unchanged, and every deeper table plus the top
    closure carries over by reference — exactly the
    no-overlay-change carry rule, applied per level.  ``undo`` is
    filled with per-level rollback snapshots of the weight caches
    BEFORE any mutation, so a failure later in the refresh can
    restore them.
    """
    levels = plan.hier
    closures = list(dix.sf_closure)
    nexts = list(dix.sf_next)
    rows_t = list(dix.l2row)
    l2_slots = list(dix.host_l2_slot)
    undo["levels"] = []
    cur = changed_slots
    w_src = plan.sup_w
    d2, d2_next = dix.d2, dix.d2_next
    dirty_top = False
    top_closure = "carry"
    lw_old = np.empty(0, np.float32)
    for li, h in enumerate(levels):
        sl = h.slot_sf[cur]
        sfs = np.unique(sl[sl >= 0]).astype(np.int64)
        lw_old = h.l2_w.copy()
        undo["levels"].append({"hier": h, "sfs": sfs,
                               "sf_adj": h.sf_adj[sfs].copy(),
                               "l2_w": lw_old})
        if sfs.size:
            hierarchy.sf_adj_fill(h, w_src, sfs=sfs)
            d = int(sfs.size)
            p = min(_pow2(d, floor=4), h.nsf)
            pad = np.concatenate([sfs, np.full(p - d, sfs[0],
                                               np.int64)]) \
                if p > d else sfs
            jpad = jnp.asarray(pad)
            blocks, nx = ops.fw_batch_next(jnp.asarray(h.sf_adj[pad]),
                                           force=force)
            closures[li] = closures[li].at[jpad].set(blocks)
            nexts[li] = nexts[li].at[jpad].set(nx)
            r = hierarchy.l2row_from(blocks, h.bnd2_pos[pad],
                                     h.bnd2_valid[pad])
            rows_t[li] = rows_t[li].at[jpad].set(r)
            hierarchy.hier_weights(h, np.asarray(blocks[:d]), w_src,
                                   sfs=sfs)
        else:
            # only cross-group slots changed at this level: no FW,
            # just the O(cross) next-level weight copy
            hierarchy.hier_weights(
                h, np.empty((0, h.m2, h.m2), np.float32), w_src,
                sfs=sfs)
        l2_slots[li] = hierarchy.l2_slot_map(h)
        nxt_changed = np.nonzero(h.l2_w != lw_old)[0].astype(np.int64)
        if nxt_changed.size == 0:
            # the next overlay's weights are untouched: closures AND
            # witnesses above this level are still exact, carry them
            break
        cur = nxt_changed
        w_src = h.l2_w
    else:
        dirty_top = True
    if dirty_top:
        # decrease-only fast path: when every changed top slot weight
        # went DOWN, a bounded (min,+) relaxation seeded from the old
        # closure is exact (hierarchy.l2_decrease_stage); any increase
        # — or a too-large touched set — falls back to the full FW
        h = levels[-1]
        fast = None
        if cur.size and bool(np.all(h.l2_w[cur] <= lw_old[cur])):
            fast = hierarchy.l2_decrease_stage(h, d2, d2_next, cur)
        if fast is not None:
            d2, d2_next = fast
            top_closure = "decrease"
        else:
            d2, d2_next = hierarchy.l2_stage(h, force=force)
            top_closure = "full_fw"
    return {
        "fields": {"sf_closure": tuple(closures),
                   "sf_next": tuple(nexts), "l2row": tuple(rows_t),
                   "d2": d2, "d2_next": d2_next},
        "ov_slot": hierarchy.ov_slot_map(plan),
        "l2_slot": l2_slots,
        "top_closure": top_closure,
    }


def refresh_piece_stage(plan: BuildPlan, g_new, dirty_gids: np.ndarray,
                        piece_flat: np.ndarray, piece_next: np.ndarray,
                        dist_to_agent: np.ndarray, *,
                        force=None) -> None:
    """Recompute only the dirty pieces, writing their APSP + witness
    blocks in place into the flat tables and re-deriving dist-to-agent
    for their members from the agent's APSP row (paths from a
    represented node to its agent never leave the piece, Props 3-9)."""
    for cap in PIECE_BUCKETS:
        gids = [g for g in dirty_gids if plan.piece_cap[g] == cap]
        if not gids:
            continue
        adjs = [_piece_adj(g_new, plan.piece_members[gid], cap)
                for gid in gids]
        blocks, nexts = _fw_bucket(adjs, force=force, pad_pow2=True)
        for gid, block, nxt in zip(gids, blocks, nexts):
            base = plan.piece_base[gid]
            piece_flat[base:base + cap * cap] = block.reshape(-1)
            piece_next[base:base + cap * cap] = nxt.reshape(-1)
            members = plan.piece_members[gid]
            inner = members != plan.piece_agent[gid]
            dist_to_agent[members[inner]] = block[
                plan.piece_agent_pos[gid], np.nonzero(inner)[0]]


def refresh_index(dix: DeviceIndex, plan: BuildPlan, g_new, u, v, w, *,
                  w_old=None,
                  force=None) -> tuple[DeviceIndex, RefreshStats]:
    """Incremental index maintenance (DESIGN.md §9; the live-traffic
    path that replaces the full offline pipeline of paper Fig. 7).

    Locality is inherited from the paper's decomposition: a DRA touches
    the rest of G only at its agent (§IV, Props 3-9), so a DRA-internal
    edge dirties exactly one piece; fragments meet only at boundary
    nodes (§V-A), so an intra-fragment edge dirties one fragment's APSP
    plus its boundary-clique Upsilon weights; a cross-fragment edge is
    one E_B overlay slot (§V-A).  Nothing else exists — the same fact
    that makes the query algorithm (§VI-B) two-level makes the update
    problem block-diagonal.

    Given a batch of edge-weight updates (u, v, new_w) against the
    graph the plan currently reflects, re-runs exactly the dirtied
    build stages:

      a. batched FW on the dirty fragments only (refresh_frag_stage),
      b. SUPER slot weights regathered from the new fragment APSP +
         direct E_B writes, then the overlay re-closed by the dense FW
         kernel — skipped entirely when no overlay weight actually
         changed (super_stage; a warm-started BF alternative was
         measured out, see sssp.py),
      c. dirty piece APSP blocks rewritten in place into piece_flat,
         with member dist-to-agent re-derived from the agent row,
      d. a brand-new immutable DeviceIndex assembled from the results —
         the caller publishes it as the next epoch while queries keep
         draining on the old one (dist_engine.EpochedEngine).

    ``g_new`` must be the post-update graph (Graph.with_edge_weights);
    the plan's weight caches are mutated to match, so consecutive
    refreshes compose — and an exception anywhere mid-refresh rolls the
    caches back, so a failed refresh leaves plan and published index
    consistent.  ``w_old`` (the updated edges' previous weights, which
    EpochedEngine passes) is what classifies the batch direction in the
    stats; without it, piece-internal changes are invisible to the
    overlay-delta fallback.  Exactness: every stage recomputes from
    true weights (never patches distances), so the result is
    array-equal to a from-scratch build on g_new — the property the
    differential harness in tests/test_refresh.py enforces per epoch.
    """
    # stage timings flow through the one span API (DESIGN.md §16):
    # trace.timed always fills ``timings`` (the RefreshStats contract)
    # and additionally emits a trace span when the tracer is enabled
    timings: dict = {}
    t_all = time.perf_counter()

    with trace.timed("refresh.classify", timings, "classify",
                     n_updates=len(u)):
        upd = classify_updates(plan, u, v, w)

    frag_w_before = plan.frag_adj[upd.frag_fi, upd.frag_pu,
                                  upd.frag_pv].copy()
    sup_w_before = plan.sup_w.copy()
    hier_undo: dict = {}
    try:
        with trace.timed("refresh.frag_fw", timings, "frag_fw",
                         dirty=int(upd.dirty_frags.size)):
            frag_apsp, brow, frag_next, blocks = refresh_frag_stage(
                plan, dix.frag_apsp, dix.brow, dix.frag_next, upd,
                force=force)

        # ---- SUPER: regather dirty slot weights, re-close overlay ---
        with trace.timed("refresh.super_fw", timings, "super_fw"):
            touched = np.isin(plan.sup_fi, upd.dirty_frags)
            touched_slots = np.concatenate(
                [np.nonzero(touched)[0],
                 upd.eb_slots]).astype(np.int64)
            slot_w_old = sup_w_before[touched_slots]
            if upd.dirty_frags.size:
                super_weights(plan, blocks, frags=upd.dirty_frags)
            plan.sup_w[upd.eb_slots] = upd.eb_w
            slot_w_new = plan.sup_w[touched_slots]
            changed = slot_w_old != slot_w_new
            hier_fields: dict = {}
            l2_slot = getattr(dix, "host_l2_slot", None)
            res_frag = getattr(dix, "host_res_frag", None)
            topgrp_frag = getattr(dix, "host_topgrp_frag", None)
            top_closure = "carry"
            if changed.any():
                if plan.hierarchy_levels >= 2:
                    hres = refresh_hier_stage(plan, dix,
                                              touched_slots[changed],
                                              hier_undo, force=force)
                    hier_fields = dict(hres["fields"])
                    ov_slot = hres["ov_slot"]
                    l2_slot = hres["l2_slot"]
                    top_closure = hres["top_closure"]
                    d_super, super_next = dix.d_super, dix.super_next
                    # re-lift the resident rows against the refreshed
                    # per-level tables (same deterministic stage as
                    # the build, so refresh == rebuild stays
                    # array-equal)
                    rbase = {name: hier_fields.get(name,
                                                   getattr(dix, name))
                             for name in ("l2row", "bnd2_sid",
                                          "pos_in_sf", "d2")}
                    rres = resident_stage(plan, rbase)
                    if rres is not None:
                        hier_fields.update(rres["fields"])
                        res_frag = rres["res_frag"]
                        topgrp_frag = rres["topgrp_frag"]
                else:
                    d_super, super_next = super_stage(plan,
                                                      force=force)
                    ov_slot = overlay_slot_table(plan)
                    top_closure = "dense"
            else:
                # no overlay weight changed: closure AND witnesses are
                # still exact, so the path tables carry over too
                # (hier_fields stays empty — per-level tables and the
                # resident rows carry too)
                d_super, super_next = dix.d_super, dix.super_next
                ov_slot = getattr(dix, "host_ov_slot", None)

        # ---- hub labels (DESIGN.md §15) -----------------------------
        # a label folds a brow leg with the overlay closure, so it is
        # stale iff the closure moved (changed.any()) OR any labeled
        # fragment's boundary rows did (dirty_frags); otherwise every
        # input is unchanged and carrying the rows is bit-identical to
        # recomputing them — the refresh == rebuild invariant the
        # differential harness in tests/test_hublabels.py enforces
        with trace.timed("refresh.hub", timings, "hub"):
            hub_fields: dict = {}
            hub_agent = getattr(dix, "host_hub_agent", None)
            hub_topgrp = None
            if plan.hub_nodes is not None and len(plan.hub_nodes):
                hub_frags = np.unique(plan.frag_of[
                    plan.agent_of[plan.hub_nodes].astype(np.int64)])
                if changed.any() or np.intersect1d(
                        upd.dirty_frags, hub_frags).size:
                    hub = hub_stage(plan, hub_base_fields(
                        plan,
                        lambda name: hier_fields.get(
                            name, getattr(dix, name))
                        if name != "d_super" else d_super, brow))
                    if hub is not None:
                        hub_fields = hub["fields"]
                        hub_agent = hub["hub_agent"]
                        hub_topgrp = hub["topgrp_frag"]

        # ---- pieces + dist-to-agent ---------------------------------
        with trace.timed("refresh.pieces", timings, "pieces",
                         dirty=int(upd.dirty_gids.size)):
            if upd.dirty_gids.size:
                piece_flat = np.asarray(dix.piece_flat).copy()
                piece_next = np.asarray(dix.piece_next).copy()
                dist_to_agent = np.asarray(dix.dist_to_agent).copy()
                refresh_piece_stage(plan, g_new, upd.dirty_gids,
                                    piece_flat, piece_next,
                                    dist_to_agent, force=force)
                piece_flat_j = jnp.asarray(piece_flat)
                piece_next_j = jnp.asarray(piece_next)
                dist_j = jnp.asarray(dist_to_agent)
            else:
                piece_flat_j = dix.piece_flat
                piece_next_j = dix.piece_next
                dist_j = dix.dist_to_agent
    except BaseException:
        # roll the weight caches back: the caller never published a new
        # epoch, so the plan must keep describing the old one
        plan.frag_adj[upd.frag_fi, upd.frag_pu,
                      upd.frag_pv] = frag_w_before
        plan.frag_adj[upd.frag_fi, upd.frag_pv,
                      upd.frag_pu] = frag_w_before
        plan.sup_w[:] = sup_w_before
        for lv in hier_undo.get("levels", []):
            lv["hier"].sf_adj[lv["sfs"]] = lv["sf_adj"]
            lv["hier"].l2_w[:] = lv["l2_w"]
        raise

    # batch direction: against the edges' previous weights when the
    # caller provides them; the overlay delta alone cannot see
    # piece-internal changes
    if w_old is not None:
        delta = np.asarray(w, np.float64) - np.asarray(w_old, np.float64)
        total_increase = float(np.maximum(0.0, delta).sum())
    else:
        fin = np.isfinite(slot_w_old) & np.isfinite(slot_w_new)
        total_increase = float(np.maximum(
            0.0, slot_w_new[fin] - slot_w_old[fin]).sum())

    timings["total"] = time.perf_counter() - t_all
    trace.event("refresh.apply", t_all, t_all + timings["total"],
                n_updates=len(u), top_closure=top_closure,
                dirty_frags=int(upd.dirty_frags.size))
    new_dix = dataclasses.replace(
        dix, frag_apsp=frag_apsp, frag_next=frag_next, brow=brow,
        d_super=d_super, super_next=super_next,
        piece_flat=piece_flat_j, piece_next=piece_next_j,
        dist_to_agent=dist_j, **hier_fields, **hub_fields)
    if ov_slot is not None:
        new_dix.host_ov_slot = ov_slot
    if l2_slot is not None:
        new_dix.host_l2_slot = l2_slot
    if res_frag is not None:
        new_dix.host_res_frag = res_frag
        new_dix.host_topgrp_frag = topgrp_frag
    if hub_agent is not None:
        new_dix.host_hub_agent = hub_agent
        if getattr(new_dix, "host_topgrp_frag", None) is None:
            # hierarchical epoch without resident rows: the hub gate's
            # TOP-group map must survive the epoch swap (replace()
            # never copies host sidecars)
            if hub_topgrp is None:
                hub_topgrp = getattr(dix, "host_topgrp_frag", None)
            if hub_topgrp is not None:
                new_dix.host_topgrp_frag = hub_topgrp
    stats = RefreshStats(
        n_updates=int(np.asarray(u).size),
        n_dirty_frags=int(upd.dirty_frags.size), n_frags=plan.k,
        n_dirty_pieces=int(upd.dirty_gids.size),
        n_pieces=plan.n_pieces,
        n_eb_slots=int(upd.eb_slots.size), n_inert=upd.n_inert,
        total_increase=total_increase,
        decrease_only=total_increase == 0.0, timings=timings,
        top_closure=top_closure)
    return new_dix, stats


# ---------------------------------------------------------------------------
# serving.  Witness conventions (DESIGN.md §10): the *_w variants return
# (dist, wit) with wit int32 per query:
#   same-DRA bucket:  WIT_PIECE (same-piece table won) or WIT_VIA_AGENT
#   cross buckets:    x * (S+1) + y — the winning SUPER boundary pair —
#                     or WIT_LOCAL (intra-fragment path won)
#   any bucket:       WIT_NONE when the distance is +inf
# The host-side PathUnwinder (paths.py) turns (s, t, wit) into a node
# sequence by walking frag_next / piece_next / super_next.
# ---------------------------------------------------------------------------
WIT_NONE = -1       # unreachable; nothing to unwind
WIT_LOCAL = -2      # case 2, intra-fragment path beat the SUPER combine
WIT_VIA_AGENT = 0   # case 1, s -> agent -> t
WIT_PIECE = 1       # case 1, same-piece direct path


def _same_dra_dist(dix: DeviceIndex, s, t, ds, dt):
    """Case 1: same agent.  Same piece -> one flat gather; else via
    agent.  The flat layout replaces the per-bucket Python loop with a
    single padded gather over piece_flat."""
    gid_s = dix.piece_gid[s]
    same_piece = (gid_s >= 0) & (gid_s == dix.piece_gid[t])
    d_via_agent = ds + dt
    idx = (dix.piece_base[s]
           + dix.pos_in_piece[s] * dix.piece_stride[s]
           + dix.pos_in_piece[t])
    d_piece = dix.piece_flat[jnp.where(same_piece, idx, 0)]
    return jnp.where(same_piece, jnp.minimum(d_piece, d_via_agent),
                     d_via_agent)


def _overlay_size(dix: DeviceIndex) -> int:
    """S + 1: the witness packing stride and the sentinel super id + 1.
    Hierarchical indices carry it as the bottom sf_of's length (their
    d_super is a [1, 1] dummy); dense indices as d_super's side."""
    return (dix.sf_of[0].shape[0] if len(dix.sf_of)
            else dix.d_super.shape[0])


def _hier_leg(dix: DeviceIndex, li: int, row_s, grp_s, pos_s,
              row_t, grp_t, pos_t):
    """Same-group leg at grouping level ``li``: min over slot pairs
    (i, j) in the SAME level-li group of
    row_s[i] + sf_closure[li][g, pos_i, pos_j] + row_t[j], chunked
    over the s-axis so the gathered block stays [q, 8, width]."""
    q, mbs = row_s.shape
    mbt = row_t.shape[1]
    c = min(8, mbs)                    # widths are padded to mult of 8

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        g_c = jax.lax.dynamic_slice_in_dim(grp_s, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos_s, i * c, c, axis=1)
        blk = dix.sf_closure[li][g_c[:, :, None], p_c[:, :, None],
                                 pos_t[:, None, :]]      # [q, c, mbt]
        same = g_c[:, :, None] == grp_t[:, None, :]
        cand = jnp.min(jnp.where(same, r_c[:, :, None] + blk, INF),
                       axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, mbs // c, body,
                            jnp.full((q, mbt), INF, row_s.dtype))
    return jnp.min(tmp + row_t, axis=1)


def _lift_compact(dix: DeviceIndex, li: int, row, grp, pos):
    """Lift a compact boundary row one level: out[q, j] = min_b
    row[q, b] + l2row[li][grp_b, pos_b, j].  All valid slots of one
    side share one group per level (groups nest), so the output stays
    COMPACT — its next-level ids are that group's bnd2_sid row, read
    by the caller — instead of scattering to a dense [q, S_{l+1}+1]
    row at every level.  Chunked so the gathered block stays
    [q, 8, mb']."""
    q, mb = row.shape
    c = min(8, mb)
    mbn = dix.l2row[li].shape[2]

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        g_c = jax.lax.dynamic_slice_in_dim(grp, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos, i * c, c, axis=1)
        l2_c = dix.l2row[li][g_c, p_c]           # [q, c, mb']
        return jnp.minimum(acc,
                           jnp.min(r_c[:, :, None] + l2_c, axis=1))

    return jax.lax.fori_loop(0, mb // c, body,
                             jnp.full((q, mbn), INF, row.dtype))


def _lift_src_of(dix: DeviceIndex, li: int, row, ids, grp, pos, wc):
    """Witness recovery for one lift: the level-li id whose lifted
    contribution achieved the next-level row at target id ``wc`` (same
    chunked schedule as _lift_compact, carrying a running argmin;
    exact f32 re-comparison)."""
    q, mb = row.shape
    c = min(8, mb)

    def body(i, carry):
        best, besti = carry
        r_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        g_c = jax.lax.dynamic_slice_in_dim(grp, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos, i * c, c, axis=1)
        l2_c = dix.l2row[li][g_c, p_c]
        sid_c = dix.bnd2_sid[li][g_c]
        m = sid_c == wc[:, None, None]
        contrib = jnp.min(jnp.where(m, r_c[:, :, None] + l2_c, INF),
                          axis=2)                # [q, c]
        cmin = jnp.min(contrib, axis=1)
        loc = jnp.argmin(contrib, axis=1).astype(jnp.int32)
        better = cmin < best
        return (jnp.where(better, cmin, best),
                jnp.where(better, i * c + loc, besti))

    _best, besti = jax.lax.fori_loop(
        0, mb // c, body,
        (jnp.full((q,), INF, row.dtype), jnp.zeros((q,), jnp.int32)))
    return jnp.take_along_axis(ids, besti[:, None], axis=1)[:, 0]


def _scatter_top(dix: DeviceIndex, row, ids):
    """Scatter a compact top-level row into dense d2 coordinates."""
    q = row.shape[0]
    stp1 = dix.d2.shape[0]
    qi = jnp.arange(q, dtype=jnp.int32)[:, None]
    return jnp.full((q, stp1), INF, row.dtype).at[qi, ids].min(row)


def _top_mid_gather(dix: DeviceIndex, row_s, ids_s, row_t, ids_t):
    """Contract compact top rows against d2 WITHOUT scattering:

      mid = min_{x,y} row_s[x] + d2[ids_s[x], ids_t[y]] + row_t[y]

    The scattered row is +inf outside its own top-group boundary
    columns, so gathering d2 at just [ids_s x ids_t] is bit-identical
    to scatter + full minplus_twoside while touching mb_s*mb_t of the
    (S_top+1)^2 closure (~8x less on road64k).  Sentinel slots carry
    id S_top, which indexes d2's +inf row/col — no masking needed.
    Same chunked-gather idiom as the dense CPU witness path, but with
    the largest chunk that divides the (pad_to-8) width — bigger
    gather blocks amortize XLA's per-slice overhead (~25% on the
    road64k top width of 552)."""
    q, mb = row_s.shape
    c = next(cc for cc in (24, 16, 8, mb) if mb % cc == 0)

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(ids_s, i * c, c, axis=1)
        blk = dix.d2[b_c[:, :, None], ids_t[:, None, :]]  # [q, c, mb_t]
        return jnp.minimum(acc,
                           jnp.min(r_c[:, :, None] + blk, axis=1))

    tmp = jax.lax.fori_loop(
        0, mb // c, body,
        jnp.full((q, row_t.shape[1]), INF, row_s.dtype))
    return jnp.min(tmp + row_t, axis=1)


def _combine_mid_h(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                   force=None):
    """Hierarchical combine (hierarchy_levels=N, DESIGN.md §12-13):

      mid = min_{x,y} row_s[x] + OD(x, y) + row_t[y]

    where OD decomposes per level: either both sides sit in the same
    level-l group (its resident closure answers exactly — the va
    legs), or the route crosses every level's boundary and the TOP
    closure answers against both rows lifted level by level (the vb
    leg).  On an accelerator the vb leg scatters both rows dense and
    runs the SAME fused minplus_twoside kernel as the dense path; on
    CPU it stays compact and gathers only each side's own top-group
    boundary columns of d2 (_top_mid_gather — bit-identical, the
    scattered row is +inf everywhere else).  The lift state stays
    compact ([q, width] + ids) until the top; one grouping level
    reproduces the two-level combine bit-for-bit (min re-association
    is exact in f32).
    """
    L = len(dix.sf_of)
    q = row_s.shape[0]
    ids_s, ids_t = bs, bt
    va = jnp.full((q,), INF, row_s.dtype)
    for li in range(L):
        grp_s, pos_s = dix.sf_of[li][ids_s], dix.pos_in_sf[li][ids_s]
        grp_t, pos_t = dix.sf_of[li][ids_t], dix.pos_in_sf[li][ids_t]
        va = jnp.minimum(va, _hier_leg(dix, li, row_s, grp_s, pos_s,
                                       row_t, grp_t, pos_t))
        new_s = _lift_compact(dix, li, row_s, grp_s, pos_s)
        new_t = _lift_compact(dix, li, row_t, grp_t, pos_t)
        # slot 0 is valid-first by construction, so its group IS the
        # side's group (sentinel-only rows land on the sentinel group,
        # whose bnd2_sid row is all-sentinel and whose rows are +inf)
        ids_s = dix.bnd2_sid[li][grp_s[:, 0]]
        ids_t = dix.bnd2_sid[li][grp_t[:, 0]]
        row_s, row_t = new_s, new_t
    if ops.use_pallas(force):
        vb = ops.minplus_twoside(_scatter_top(dix, row_s, ids_s),
                                 dix.d2,
                                 _scatter_top(dix, row_t, ids_t),
                                 force=force)
    else:
        vb = _top_mid_gather(dix, row_s, ids_s, row_t, ids_t)
    return jnp.minimum(va, vb)


def _hier_leg_w(dix: DeviceIndex, li: int, row_s, ids_s, grp_s, pos_s,
                row_t, ids_t, grp_t, pos_t):
    """_hier_leg carrying its argmin -> (va, xa, ya) with the winning
    pair expressed as level-li overlay ids."""
    q, mbs = row_s.shape
    mbt = row_t.shape[1]
    c = min(8, mbs)

    def body(i, carry):
        acc, accb = carry
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        g_c = jax.lax.dynamic_slice_in_dim(grp_s, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos_s, i * c, c, axis=1)
        blk = dix.sf_closure[li][g_c[:, :, None], p_c[:, :, None],
                                 pos_t[:, None, :]]
        same = g_c[:, :, None] == grp_t[:, None, :]
        cube = jnp.where(same, r_c[:, :, None] + blk, INF)
        cand = jnp.min(cube, axis=1)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(
            hit, jax.lax.broadcasted_iota(jnp.int32, cube.shape, 1),
            jnp.int32(mbs)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * c + loc, accb))

    acc0 = jnp.full((q, mbt), INF, row_s.dtype)
    accb0 = jnp.full((q, mbt), -1, jnp.int32)
    acc, accb = jax.lax.fori_loop(0, mbs // c, body, (acc0, accb0))
    tmp = acc + row_t
    va = jnp.min(tmp, axis=1)
    hit = tmp == va[:, None]
    pos_tw = jnp.min(jnp.where(
        hit, jnp.arange(mbt, dtype=jnp.int32)[None, :], jnp.int32(mbt)),
        axis=1)
    pos_tc = jnp.clip(pos_tw, 0, mbt - 1)
    pos_sw = jnp.take_along_axis(accb, pos_tc[:, None], axis=1)[:, 0]
    xa = jnp.take_along_axis(
        ids_s, jnp.clip(pos_sw, 0, mbs - 1)[:, None], axis=1)[:, 0]
    ya = jnp.take_along_axis(ids_t, pos_tc[:, None], axis=1)[:, 0]
    return va, xa, ya


def _combine_mid_h_w(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                     force=None):
    """Witness variant of _combine_mid_h -> (mid, wx, wy): the winning
    level-1 SUPER pair under the hierarchical overlay metric.  Each
    same-group leg carries its argmin; the top leg gets the winning
    boundary pair (c, d) from the fused argmin kernel and resolves it
    back DOWN the ladder: at each level the winning id either comes
    from that level's same-group leg (if it won) or is un-lifted one
    level by re-finding the row entry whose lift achieved the
    next-level row (an O(q * width) masked argmin — exact because the
    lift is a min of f32 sums re-comparable bit-for-bit).
    """
    L = len(dix.sf_of)
    q = row_s.shape[0]
    ids_s, ids_t = bs, bt
    states = []
    vas, legx, legy = [], [], []
    for li in range(L):
        grp_s, pos_s = dix.sf_of[li][ids_s], dix.pos_in_sf[li][ids_s]
        grp_t, pos_t = dix.sf_of[li][ids_t], dix.pos_in_sf[li][ids_t]
        states.append((row_s, ids_s, grp_s, pos_s,
                       row_t, ids_t, grp_t, pos_t))
        va, xa, ya = _hier_leg_w(dix, li, row_s, ids_s, grp_s, pos_s,
                                 row_t, ids_t, grp_t, pos_t)
        vas.append(va)
        legx.append(xa)
        legy.append(ya)
        row_s = _lift_compact(dix, li, row_s, grp_s, pos_s)
        row_t = _lift_compact(dix, li, row_t, grp_t, pos_t)
        ids_s = dix.bnd2_sid[li][grp_s[:, 0]]
        ids_t = dix.bnd2_sid[li][grp_t[:, 0]]
    vb, wc, wd = ops.minplus_twoside_argmin(
        _scatter_top(dix, row_s, ids_s), dix.d2,
        _scatter_top(dix, row_t, ids_t), force=force)
    mid = vb
    for va in vas:
        mid = jnp.minimum(mid, va)
    # winner selection, lowest level first (same tie preference as the
    # two-level code: a same-group leg beats the lifted leg)
    taken = jnp.zeros((q,), bool)
    wins = []
    for va in vas:
        w = (va == mid) & ~taken
        taken = taken | w
        wins.append(w)
    cur_x, cur_y = wc, wd
    for li in range(L - 1, -1, -1):
        (r_s, i_s, g_s, p_s, r_t, i_t, g_t, p_t) = states[li]
        dx = _lift_src_of(dix, li, r_s, i_s, g_s, p_s, cur_x)
        dy = _lift_src_of(dix, li, r_t, i_t, g_t, p_t, cur_y)
        cur_x = jnp.where(wins[li], legx[li], dx)
        cur_y = jnp.where(wins[li], legy[li], dy)
    fin = jnp.isfinite(mid)
    wx = jnp.where(fin, cur_x, -1)
    wy = jnp.where(fin, cur_y, -1)
    return mid, wx, wy


def _combine_mid(dix: DeviceIndex, row_s, bs, row_t, bt, *, force=None):
    """combine = min_{b1,b2} row_s[b1] + D_super[bs[b1], bt[b2]]
    + row_t[b2] without a [q, mb, mb] intermediate.

    Hierarchical indices (non-empty sf_of tuple — a static trace-time
    treedef fact) route to _combine_mid_h.  Dense indices:
    TPU: scatter-min the boundary rows into SUPER coordinates (one
    O(q*mb) scatter each) and run the fused two-sided tropical kernel
    against the resident D_super.  CPU/ref: chunk the b1 axis so the
    gathered block never exceeds [q, 8, mb].
    """
    if len(dix.sf_of):
        return _combine_mid_h(dix, row_s, bs, row_t, bt, force=force)
    if ops.use_pallas(force):
        s1 = dix.d_super.shape[0]
        q = row_s.shape[0]
        qi = jnp.arange(q, dtype=jnp.int32)[:, None]
        rs = jnp.full((q, s1), INF, row_s.dtype).at[qi, bs].min(row_s)
        rt = jnp.full((q, s1), INF, row_t.dtype).at[qi, bt].min(row_t)
        return ops.minplus_twoside(rs, dix.d_super, rt, force=force)
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(bs, i * c, c, axis=1)
        blk = dix.d_super[b_c[:, :, None], bt[:, None, :]]  # [q, c, mb]
        cand = jnp.min(r_c[:, :, None] + blk, axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, mb // c, body,
                            jnp.full((q, mb), INF, row_s.dtype))
    return jnp.min(tmp + row_t, axis=1)


def _combine_mid_w(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                   force=None):
    """Witness variant of _combine_mid -> (mid, wx, wy) where (wx, wy)
    is the winning SUPER boundary pair in super ids (-1 when mid is
    +inf).  Same two layouts as the distance path: fused argmin kernel
    against the scattered rows on TPU, b1-chunked gather on CPU;
    hierarchical indices route to _combine_mid_h_w."""
    if len(dix.sf_of):
        return _combine_mid_h_w(dix, row_s, bs, row_t, bt, force=force)
    if ops.use_pallas(force):
        s1 = dix.d_super.shape[0]
        q = row_s.shape[0]
        qi = jnp.arange(q, dtype=jnp.int32)[:, None]
        rs = jnp.full((q, s1), INF, row_s.dtype).at[qi, bs].min(row_s)
        rt = jnp.full((q, s1), INF, row_t.dtype).at[qi, bt].min(row_t)
        return ops.minplus_twoside_argmin(rs, dix.d_super, rt,
                                          force=force)
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, carry):
        acc, accb = carry
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(bs, i * c, c, axis=1)
        blk = dix.d_super[b_c[:, :, None], bt[:, None, :]]  # [q, c, mb]
        cube = r_c[:, :, None] + blk
        cand = jnp.min(cube, axis=1)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(
            hit, jax.lax.broadcasted_iota(jnp.int32, cube.shape, 1),
            jnp.int32(mb)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * c + loc, accb))

    acc0 = jnp.full((q, mb), INF, row_s.dtype)
    accb0 = jnp.full((q, mb), -1, jnp.int32)
    acc, accb = jax.lax.fori_loop(0, mb // c, body, (acc0, accb0))
    tmp = acc + row_t                    # [q, mb]
    mid = jnp.min(tmp, axis=1)
    hit = tmp == mid[:, None]
    pos_t = jnp.min(jnp.where(
        hit, jnp.arange(mb, dtype=jnp.int32)[None, :], jnp.int32(mb)),
        axis=1)
    pos_t_c = jnp.clip(pos_t, 0, mb - 1)
    pos_s = jnp.take_along_axis(accb, pos_t_c[:, None], axis=1)[:, 0]
    fin = jnp.isfinite(mid)
    wx = jnp.where(fin, jnp.take_along_axis(
        bs, jnp.clip(pos_s, 0, mb - 1)[:, None], axis=1)[:, 0], -1)
    wy = jnp.where(fin, jnp.take_along_axis(
        bt, pos_t_c[:, None], axis=1)[:, 0], -1)
    return mid, wx, wy


def serve_same_dra(dix: DeviceIndex, s: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Planner bucket 1: both endpoints in the same DRA."""
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    out = _same_dra_dist(dix, s, t, ds, dt)
    return jnp.where(s == t, 0.0, out)


def serve_same_dra_w(dix: DeviceIndex, s: jax.Array, t: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """serve_same_dra in return_witness mode -> (dist, wit) with wit in
    {WIT_PIECE, WIT_VIA_AGENT, WIT_NONE}."""
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    gid_s = dix.piece_gid[s]
    same_piece = (gid_s >= 0) & (gid_s == dix.piece_gid[t])
    d_via_agent = ds + dt
    idx = (dix.piece_base[s]
           + dix.pos_in_piece[s] * dix.piece_stride[s]
           + dix.pos_in_piece[t])
    d_piece = dix.piece_flat[jnp.where(same_piece, idx, 0)]
    out = jnp.where(same_piece, jnp.minimum(d_piece, d_via_agent),
                    d_via_agent)
    wit = jnp.where(same_piece & (d_piece <= d_via_agent),
                    WIT_PIECE, WIT_VIA_AGENT)
    out = jnp.where(s == t, 0.0, out)
    wit = jnp.where(jnp.isfinite(out), wit, WIT_NONE)
    return out, wit.astype(jnp.int32)


def serve_cross(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                with_local: bool, force=None) -> jax.Array:
    """Planner buckets 2/3: endpoints in different DRAs.  with_local
    folds in the intra-fragment distance (same-fragment bucket only,
    so the cross-fragment program skips that gather entirely)."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    mid = _combine_mid(dix, row_s, dix.bnd_super[fs], row_t,
                       dix.bnd_super[ft], force=force)
    if with_local:
        mid = jnp.minimum(mid, jnp.where(fs == ft,
                                         dix.frag_apsp[fs, ps, pt], INF))
    d = ds + mid + dt
    return jnp.where((fs >= 0) & (ft >= 0), d, INF)


def serve_cross_w(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                  with_local: bool, force=None
                  ) -> tuple[jax.Array, jax.Array]:
    """serve_cross in return_witness mode -> (dist, wit): wit is the
    packed winning SUPER pair x * (S+1) + y, WIT_LOCAL when the
    intra-fragment path won (same-fragment bucket only), WIT_NONE when
    unreachable."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    mid, wx, wy = _combine_mid_w(dix, row_s, dix.bnd_super[fs], row_t,
                                 dix.bnd_super[ft], force=force)
    s1 = _overlay_size(dix)
    wit = wx * s1 + wy
    if with_local:
        local = jnp.where(fs == ft, dix.frag_apsp[fs, ps, pt], INF)
        wit = jnp.where(local <= mid, WIT_LOCAL, wit)
        mid = jnp.minimum(mid, local)
    d = ds + mid + dt
    d = jnp.where((fs >= 0) & (ft >= 0), d, INF)
    wit = jnp.where(jnp.isfinite(d), wit, WIT_NONE)
    return d, wit.astype(jnp.int32)


def _lift_res(dix: DeviceIndex, row, pos, ridx, cols=None):
    """Resident lift: rs[q, c] = min_b row[q, b] +
    res_rows[ridx, pos_b, c] — the whole per-level lift ladder
    collapsed into one chunked gather against the pre-composed rows.

    With ``cols`` (int32 [q, w]) the output is restricted to those
    d2 columns per query instead of the full S_top+1 width — the CPU
    path passes each endpoint's own top-group boundary ids, cutting
    the gather traffic to match _top_mid_gather's contraction."""
    q, mb = row.shape
    c = min(8, mb)
    stp1 = dix.res_rows.shape[2]

    def body_full(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos, i * c, c, axis=1)
        blk = dix.res_rows[ridx[:, None], p_c]   # [q, c, S_top+1]
        return jnp.minimum(acc,
                           jnp.min(r_c[:, :, None] + blk, axis=1))

    def body_cols(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(pos, i * c, c, axis=1)
        blk = dix.res_rows[ridx[:, None, None], p_c[:, :, None],
                           cols[:, None, :]]     # [q, c, w]
        return jnp.minimum(acc,
                           jnp.min(r_c[:, :, None] + blk, axis=1))

    width = stp1 if cols is None else cols.shape[1]
    body = body_full if cols is None else body_cols
    return jax.lax.fori_loop(0, mb // c, body,
                             jnp.full((q, width), INF, row.dtype))


def serve_cross_res(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                    force=None) -> jax.Array:
    """Planner bucket 4 (DESIGN.md §13): the resident fast path for hot
    cross-top-group queries.  Both endpoints' fragments must be in
    RESIDENT level-1 groups and in DIFFERENT top-level groups (the
    planner guarantees both) — then the route must touch the top
    boundary, every confined prefix is pre-composed in res_rows, and
    the whole combine is one contraction against d2: a fused
    minplus_twoside on an accelerator, or a gather restricted to each
    endpoint's own top-group boundary columns on CPU (a route's first
    top-boundary contact lies in its endpoint's own top group — the
    confined prefix up to it is exactly what res_rows pre-compose —
    so the restriction is exact).  The same value as the full lift up
    to f32 re-association (the resident rows pre-add the per-level
    legs); exact in the reals, validated against the oracle like
    every other bucket."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    bs, bt = dix.bnd_super[fs], dix.bnd_super[ft]
    pos_s = dix.pos_in_sf[0][bs]
    pos_t = dix.pos_in_sf[0][bt]
    if ops.use_pallas(force):
        rs = _lift_res(dix, row_s, pos_s, dix.res_of_frag[fs])
        rt = _lift_res(dix, row_t, pos_t, dix.res_of_frag[ft])
        mid = ops.minplus_twoside(rs, dix.d2, rt, force=force)
    else:
        ids_s = dix.bnd2_sid[-1][dix.topgrp_of_frag[fs]]
        ids_t = dix.bnd2_sid[-1][dix.topgrp_of_frag[ft]]
        rs = _lift_res(dix, row_s, pos_s, dix.res_of_frag[fs],
                       cols=ids_s)
        rt = _lift_res(dix, row_t, pos_t, dix.res_of_frag[ft],
                       cols=ids_t)
        mid = _top_mid_gather(dix, rs, ids_s, rt, ids_t)
    d = ds + mid + dt
    return jnp.where((fs >= 0) & (ft >= 0), d, INF)


def serve_hub(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
              force=None) -> jax.Array:
    """Hot-tier hub-label serve (DESIGN.md §15): both endpoints'
    agents must be labeled and in different TOP groups (dense epochs:
    different fragments) — the planner's hub_mask guarantees both —
    then the whole query is two label-row gathers and one O(W)
    (min,+) merge; no per-level lifting, no d2 contraction, no planner
    dispatch.  A mis-gated pair gathers the all-INF sentinel row and
    returns +inf rather than a wrong distance.  Bit-equal to the
    planner cross path: every sum is an integer-valued f32 (graph
    weights are integers), so the merge's re-association is exact."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ls = dix.hub_rows[dix.hub_of_agent[us]]      # [q, W]
    lt = dix.hub_rows[dix.hub_of_agent[ut]]
    mid = ops.label_merge(ls, lt, force=force)
    d = ds + mid + dt
    return jnp.where((fs >= 0) & (ft >= 0), d, INF)


def serve_step(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
               force=None) -> jax.Array:
    """Batched exact distance queries: s, t int32 [q] -> f32 [q].

    The monolithic program (every case in one jit); the query planner
    in dist_engine.py runs the per-case programs instead.
    """
    us, ut = dix.agent_of[s], dix.agent_of[t]
    d_cross = serve_cross(dix, s, t, with_local=True, force=force)
    d_same = serve_same_dra(dix, s, t)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(s == t, 0.0, out)


def serve_step_w(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                 force=None) -> tuple[jax.Array, jax.Array]:
    """serve_step in return_witness mode -> (dist, wit).

    The witness namespace is per-case (same-DRA flags vs packed SUPER
    pairs); the host unwinder re-derives the case from agent_of, so no
    case bits are spent in the witness itself.
    """
    us, ut = dix.agent_of[s], dix.agent_of[t]
    d_cross, w_cross = serve_cross_w(dix, s, t, with_local=True,
                                     force=force)
    d_same, w_same = serve_same_dra_w(dix, s, t)
    same = us == ut
    out = jnp.where(same, d_same, d_cross)
    wit = jnp.where(same, w_same, w_cross)
    return jnp.where(s == t, 0.0, out), wit


def _overlay_row_h(dix: DeviceIndex, rs: jax.Array, *,
                   force=None) -> jax.Array:
    """Exact overlay distances from a scattered source row rs [S+1] to
    EVERY overlay node, through the hierarchy: ascend the ladder
    (within-group (min,+) against the resident closures + boundary
    lift per level), one small vector (x) matrix product against the
    top closure, then descend (lift back through each level's rows,
    min-merged with that level's within-group leg)."""
    L = len(dix.sf_of)
    r = rs
    withins = []
    for li in range(L):
        members = dix.sf_members[li]             # [ng+1, m2] (S_l pad)
        rm = r[members]                          # [ng+1, m2]
        withins.append(jnp.min(rm[:, :, None] + dix.sf_closure[li],
                               axis=1))
        lift = jnp.min(rm[:, :, None] + dix.l2row[li], axis=1)
        np1 = (dix.sf_of[li + 1].shape[0] if li + 1 < L
               else dix.d2.shape[0])
        r = jnp.full((np1,), INF, rs.dtype).at[
            dix.bnd2_sid[li]].min(lift)
    z = ops.minplus(r[None, :], dix.d2, force=force)[0]  # [S_top+1]
    for li in range(L - 1, -1, -1):
        back = z[dix.bnd2_sid[li]]               # [ng+1, mb2]
        via = jnp.min(dix.l2row[li] + back[:, None, :], axis=2)
        out = jnp.minimum(withins[li], via)      # [ng+1, m2]
        sz = dix.sf_of[li].shape[0]
        z = jnp.full((sz,), INF, rs.dtype).at[
            dix.sf_members[li]].min(out)
    return z


def serve_one_to_all(dix: DeviceIndex, s: int | jax.Array, *,
                     force=None) -> jax.Array:
    """Exact distances from one source to EVERY node: [n].

    The bulk/retrieval pattern: scatter the source boundary row into
    SUPER coordinates, one vector-matrix (min,+) product against the
    SUPER matrix (Pallas kernel on TPU), then a per-node gather
    combine.  Used by the retrieval-style benchmarks.
    """
    s = jnp.asarray(s, jnp.int32).reshape(())
    n = dix.agent_of.shape[0]
    us = dix.agent_of[s]
    ds = dix.dist_to_agent[s]
    fs = dix.frag_of[us]
    ps = dix.pos_in_frag[us]
    row_s = dix.brow[fs, ps]                             # [mb]
    bs = dix.bnd_super[fs]                               # [mb]
    s1 = _overlay_size(dix)
    rs = jnp.full((s1,), INF, row_s.dtype).at[bs].min(row_s)
    # u_s -> every super node (vector (x) matrix min-plus; the
    # hierarchical overlay runs it per level)
    if len(dix.sf_of):
        x = _overlay_row_h(dix, rs, force=force)                # [S+1]
    else:
        x = ops.minplus(rs[None, :], dix.d_super, force=force)[0]
    # per-target combine (sentinel slots hit the +inf row of d_super)
    tt = jnp.arange(n, dtype=jnp.int32)
    ut = dix.agent_of[tt]
    dt = dix.dist_to_agent[tt]
    ft = dix.frag_of[ut]
    ptv = dix.pos_in_frag[ut]
    row_t = dix.brow[ft, ptv]                            # [n, mb]
    mid = jnp.min(x[dix.bnd_super[ft]] + row_t, axis=1)  # [n]
    local = jnp.where(ft == fs, dix.frag_apsp[ft, ps, ptv], INF)
    d_cross = ds + jnp.minimum(mid, local) + dt
    d_cross = jnp.where((fs >= 0) & (ft >= 0), d_cross, INF)
    d_same = _same_dra_dist(dix, jnp.broadcast_to(s, tt.shape), tt,
                            jnp.broadcast_to(ds, dt.shape), dt)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(tt == s, 0.0, out)
