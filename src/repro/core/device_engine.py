"""Device (TPU) DISLAND engine: fixed-shape batched query answering.

Hardware adaptation of the paper's per-query Dijkstra (DESIGN.md §2):
every query path becomes gathers + (min,+) algebra over padded tensors.

Offline (build_device_index, device-resident products):
  * per-fragment dense APSP        [k, maxf, maxf]   (Pallas blocked FW)
  * boundary-row table             [k, maxf, mb]     (node -> boundary)
  * SUPER boundary x boundary APSP [S+1, S+1]        (batched BF / FW)
  * per-piece APSP, flattened      [sum_b P_b*mp_b^2] (+ per-node
    base/stride so one gather answers any same-piece query)
  * per-node lookup vectors        agent/fragment/piece ids + positions

Online (serve_step — one jitted program per query batch):
  dist(s,t) = same-DRA answer                                (case 1)
            | d(s,u_s) + min(local, combine) + d(u_t,t)      (case 2)
  combine = min_{b1,b2} row_s[b1] + D_super[b1,b2] + row_t[b2],
computed without ever materializing a [q, mb, mb] block: on TPU the
boundary rows are scattered into SUPER coordinates and contracted by
the fused minplus_twoside Pallas kernel (D_super tiles stay resident
in VMEM); on CPU an x-chunked gather keeps the peak intermediate at
[q, 8, mb] (DESIGN.md §4).

Everything is exact (validated against the host engine).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import sssp
from .supergraph import DislandIndex

INF = np.float32(np.inf)
PIECE_BUCKETS = (8, 32, 128, 512, 2048)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    # per-node lookups [n]
    agent_of: jax.Array          # int32
    dist_to_agent: jax.Array     # f32
    frag_of: jax.Array           # int32 (fragment of each *shrink* node)
    pos_in_frag: jax.Array       # int32
    piece_gid: jax.Array         # int32 global piece id (-1 if none)
    pos_in_piece: jax.Array      # int32
    piece_base: jax.Array        # int32 offset of piece block in flat
    piece_stride: jax.Array      # int32 row stride (= padded piece size)
    # fragments
    frag_apsp: jax.Array         # f32 [k, maxf, maxf]
    brow: jax.Array              # f32 [k, maxf, mb] node->boundary rows
    bpos: jax.Array              # int32 [k, mb] boundary position in frag
    bvalid: jax.Array            # bool [k, mb]
    bnd_super: jax.Array         # int32 [k, mb] super id (S = sentinel)
    # super graph
    d_super: jax.Array           # f32 [S+1, S+1] (+inf sentinel row/col)
    # pieces: every bucketed APSP tensor, flattened end to end
    piece_flat: jax.Array        # f32 [sum_b P_b * mp_b * mp_b]

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        children = tuple(getattr(self, f.name) for f in fields)
        return children, tuple(f.name for f in fields)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(**dict(zip(names, children)))


# ---------------------------------------------------------------------------
def _pad_to(x: int, mult: int = 8) -> int:
    return max(mult, -(-x // mult) * mult)


def build_device_index(ix: DislandIndex, *, force=None) -> DeviceIndex:
    """Assemble padded tensors on host, run device APSP preprocessing."""
    g = ix.g
    n = g.n
    k = len(ix.fragments)

    agent_of = ix.dras.agent_of.astype(np.int32)
    dist_to_agent = ix.dras.dist_to_agent.astype(np.float32)

    # ---- fragments ------------------------------------------------------
    maxf = _pad_to(max((f.graph.n for f in ix.fragments), default=1))
    mb = _pad_to(max((f.boundary_local.size for f in ix.fragments),
                     default=1))
    frag_adj = np.full((k, maxf, maxf), INF, dtype=np.float32)
    frag_of = -np.ones(n, dtype=np.int32)
    pos_in_frag = np.zeros(n, dtype=np.int32)
    bpos = np.zeros((k, mb), dtype=np.int32)
    bvalid = np.zeros((k, mb), dtype=bool)
    S = ix.super_graph.node_ids.size
    bnd_super = np.full((k, mb), S, dtype=np.int32)
    super_id_of = -np.ones(n, dtype=np.int64)
    super_id_of[ix.super_graph.node_ids] = np.arange(S)
    for fi, f in enumerate(ix.fragments):
        fg = f.graph
        frag_of[f.nodes] = fi
        pos_in_frag[f.nodes] = np.arange(f.nodes.size)
        frag_adj[fi, fg.edge_u, fg.edge_v] = fg.edge_w.astype(np.float32)
        frag_adj[fi, fg.edge_v, fg.edge_u] = fg.edge_w.astype(np.float32)
        nb = f.boundary_local.size
        bpos[fi, :nb] = f.boundary_local
        bvalid[fi, :nb] = True
        bnd_super[fi, :nb] = super_id_of[f.nodes[f.boundary_local]]
    frag_apsp = ops.fw_batch(jnp.asarray(frag_adj), force=force)
    # boundary-row table: brow[f, p, b] = dist(node at position p,
    # boundary slot b) — serve_step gathers one row per query endpoint
    # instead of a take_along_axis over [q, maxf]
    brow = jnp.take_along_axis(frag_apsp,
                               jnp.asarray(bpos)[:, None, :], axis=2)
    brow = jnp.where(jnp.asarray(bvalid)[:, None, :], brow, INF)

    # ---- SUPER graph APSP (batched BF over the sparse edge list) --------
    sg = ix.super_graph.graph
    if S > 0 and sg.m > 0:
        src = np.concatenate([sg.edge_u, sg.edge_v]).astype(np.int32)
        dst = np.concatenate([sg.edge_v, sg.edge_u]).astype(np.int32)
        w = np.concatenate([sg.edge_w, sg.edge_w]).astype(np.float32)
        d_s = sssp.apsp_from_sources(jnp.asarray(src), jnp.asarray(dst),
                                     jnp.asarray(w),
                                     jnp.arange(S, dtype=jnp.int32), n=S)
        d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)
        d_super = d_super.at[:S, :S].set(d_s)
    else:
        d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)

    # ---- pieces: size-bucketed batched FW, then one flat table ----------
    piece_gid = -np.ones(n, dtype=np.int32)
    pos_in_piece = np.zeros(n, dtype=np.int32)
    piece_bucket = np.zeros(n, dtype=np.int32)
    piece_bidx = np.zeros(n, dtype=np.int32)
    bucket_adjs: List[List[np.ndarray]] = [[] for _ in PIECE_BUCKETS]
    next_gid = 0
    for a in ix.dras.agents:
        for piece in a.pieces:
            sz = piece.size
            b = next(i for i, cap in enumerate(PIECE_BUCKETS) if sz <= cap)
            cap = PIECE_BUCKETS[b]
            sub, ids = g.subgraph(piece)
            adj = np.full((cap, cap), INF, dtype=np.float32)
            adj[sub.edge_u, sub.edge_v] = sub.edge_w.astype(np.float32)
            adj[sub.edge_v, sub.edge_u] = sub.edge_w.astype(np.float32)
            pi = len(bucket_adjs[b])
            bucket_adjs[b].append(adj)
            # the agent belongs to many pieces: leave its lookup at -1 so
            # case-1 logic falls through to the exact ds+dt formula
            inner = ids != a.agent
            piece_gid[ids[inner]] = next_gid
            piece_bucket[ids[inner]] = b
            piece_bidx[ids[inner]] = pi
            pos_in_piece[ids[inner]] = np.nonzero(inner)[0]
            next_gid += 1
    flat_parts: List[np.ndarray] = []
    bucket_off = np.zeros(len(PIECE_BUCKETS), dtype=np.int64)
    off = 0
    for b, adjs in enumerate(bucket_adjs):
        bucket_off[b] = off
        if adjs:
            apsp = np.asarray(ops.fw_batch(jnp.asarray(np.stack(adjs)),
                                           force=force))
            flat_parts.append(apsp.reshape(-1))
            off += apsp.size
    piece_flat = (np.concatenate(flat_parts) if flat_parts
                  else np.full(1, INF, np.float32))
    caps = np.asarray(PIECE_BUCKETS, dtype=np.int64)
    piece_base = (bucket_off[piece_bucket]
                  + piece_bidx.astype(np.int64)
                  * caps[piece_bucket] ** 2).astype(np.int32)
    piece_stride = caps[piece_bucket].astype(np.int32)

    return DeviceIndex(
        agent_of=jnp.asarray(agent_of),
        dist_to_agent=jnp.asarray(dist_to_agent),
        frag_of=jnp.asarray(frag_of),
        pos_in_frag=jnp.asarray(pos_in_frag),
        piece_gid=jnp.asarray(piece_gid),
        pos_in_piece=jnp.asarray(pos_in_piece),
        piece_base=jnp.asarray(piece_base),
        piece_stride=jnp.asarray(piece_stride),
        frag_apsp=frag_apsp,
        brow=brow,
        bpos=jnp.asarray(bpos),
        bvalid=jnp.asarray(bvalid),
        bnd_super=jnp.asarray(bnd_super),
        d_super=d_super,
        piece_flat=jnp.asarray(piece_flat),
    )


# ---------------------------------------------------------------------------
def _same_dra_dist(dix: DeviceIndex, s, t, ds, dt):
    """Case 1: same agent.  Same piece -> one flat gather; else via
    agent.  The flat layout replaces the per-bucket Python loop with a
    single padded gather over piece_flat."""
    gid_s = dix.piece_gid[s]
    same_piece = (gid_s >= 0) & (gid_s == dix.piece_gid[t])
    d_via_agent = ds + dt
    idx = (dix.piece_base[s]
           + dix.pos_in_piece[s] * dix.piece_stride[s]
           + dix.pos_in_piece[t])
    d_piece = dix.piece_flat[jnp.where(same_piece, idx, 0)]
    return jnp.where(same_piece, jnp.minimum(d_piece, d_via_agent),
                     d_via_agent)


def _combine_mid(dix: DeviceIndex, row_s, bs, row_t, bt, *, force=None):
    """combine = min_{b1,b2} row_s[b1] + D_super[bs[b1], bt[b2]]
    + row_t[b2] without a [q, mb, mb] intermediate.

    TPU: scatter-min the boundary rows into SUPER coordinates (one
    O(q*mb) scatter each) and run the fused two-sided tropical kernel
    against the resident D_super.  CPU/ref: chunk the b1 axis so the
    gathered block never exceeds [q, 8, mb].
    """
    if ops.use_pallas(force):
        s1 = dix.d_super.shape[0]
        q = row_s.shape[0]
        qi = jnp.arange(q, dtype=jnp.int32)[:, None]
        rs = jnp.full((q, s1), INF, row_s.dtype).at[qi, bs].min(row_s)
        rt = jnp.full((q, s1), INF, row_t.dtype).at[qi, bt].min(row_t)
        return ops.minplus_twoside(rs, dix.d_super, rt, force=force)
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(bs, i * c, c, axis=1)
        blk = dix.d_super[b_c[:, :, None], bt[:, None, :]]  # [q, c, mb]
        cand = jnp.min(r_c[:, :, None] + blk, axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, mb // c, body,
                            jnp.full((q, mb), INF, row_s.dtype))
    return jnp.min(tmp + row_t, axis=1)


def serve_same_dra(dix: DeviceIndex, s: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Planner bucket 1: both endpoints in the same DRA."""
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    out = _same_dra_dist(dix, s, t, ds, dt)
    return jnp.where(s == t, 0.0, out)


def serve_cross(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                with_local: bool, force=None) -> jax.Array:
    """Planner buckets 2/3: endpoints in different DRAs.  with_local
    folds in the intra-fragment distance (same-fragment bucket only,
    so the cross-fragment program skips that gather entirely)."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    mid = _combine_mid(dix, row_s, dix.bnd_super[fs], row_t,
                       dix.bnd_super[ft], force=force)
    if with_local:
        mid = jnp.minimum(mid, jnp.where(fs == ft,
                                         dix.frag_apsp[fs, ps, pt], INF))
    d = ds + mid + dt
    return jnp.where((fs >= 0) & (ft >= 0), d, INF)


def serve_step(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
               force=None) -> jax.Array:
    """Batched exact distance queries: s, t int32 [q] -> f32 [q].

    The monolithic program (every case in one jit); the query planner
    in dist_engine.py runs the per-case programs instead.
    """
    us, ut = dix.agent_of[s], dix.agent_of[t]
    d_cross = serve_cross(dix, s, t, with_local=True, force=force)
    d_same = serve_same_dra(dix, s, t)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(s == t, 0.0, out)


def serve_one_to_all(dix: DeviceIndex, s: int | jax.Array, *,
                     force=None) -> jax.Array:
    """Exact distances from one source to EVERY node: [n].

    The bulk/retrieval pattern: scatter the source boundary row into
    SUPER coordinates, one vector-matrix (min,+) product against the
    SUPER matrix (Pallas kernel on TPU), then a per-node gather
    combine.  Used by the retrieval-style benchmarks.
    """
    s = jnp.asarray(s, jnp.int32).reshape(())
    n = dix.agent_of.shape[0]
    us = dix.agent_of[s]
    ds = dix.dist_to_agent[s]
    fs = dix.frag_of[us]
    ps = dix.pos_in_frag[us]
    row_s = dix.brow[fs, ps]                             # [mb]
    bs = dix.bnd_super[fs]                               # [mb]
    s1 = dix.d_super.shape[0]
    rs = jnp.full((s1,), INF, row_s.dtype).at[bs].min(row_s)
    # u_s -> every super node (vector (x) matrix min-plus)
    x = ops.minplus(rs[None, :], dix.d_super, force=force)[0]   # [S+1]
    # per-target combine (sentinel slots hit the +inf row of d_super)
    tt = jnp.arange(n, dtype=jnp.int32)
    ut = dix.agent_of[tt]
    dt = dix.dist_to_agent[tt]
    ft = dix.frag_of[ut]
    ptv = dix.pos_in_frag[ut]
    row_t = dix.brow[ft, ptv]                            # [n, mb]
    mid = jnp.min(x[dix.bnd_super[ft]] + row_t, axis=1)  # [n]
    local = jnp.where(ft == fs, dix.frag_apsp[ft, ps, ptv], INF)
    d_cross = ds + jnp.minimum(mid, local) + dt
    d_cross = jnp.where((fs >= 0) & (ft >= 0), d_cross, INF)
    d_same = _same_dra_dist(dix, jnp.broadcast_to(s, tt.shape), tt,
                            jnp.broadcast_to(ds, dt.shape), dt)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(tt == s, 0.0, out)
