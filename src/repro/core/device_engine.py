"""Device (TPU) DISLAND engine: fixed-shape batched query answering.

Hardware adaptation of the paper's per-query Dijkstra (DESIGN.md §2):
every query path becomes gathers + (min,+) algebra over padded tensors.

Offline (build_device_index, device-resident products):
  * per-fragment dense APSP        [k, maxf, maxf]   (Pallas blocked FW)
  * boundary-row table             [k, maxf, mb]     (node -> boundary)
  * SUPER boundary x boundary APSP [S+1, S+1]        (dense FW closure)
  * per-piece APSP, flattened      [sum_b P_b*mp_b^2] (+ per-node
    base/stride so one gather answers any same-piece query)
  * per-node lookup vectors        agent/fragment/piece ids + positions

Online (serve_step — one jitted program per query batch):
  dist(s,t) = same-DRA answer                                (case 1)
            | d(s,u_s) + min(local, combine) + d(u_t,t)      (case 2)
  combine = min_{b1,b2} row_s[b1] + D_super[b1,b2] + row_t[b2],
computed without ever materializing a [q, mb, mb] block: on TPU the
boundary rows are scattered into SUPER coordinates and contracted by
the fused minplus_twoside Pallas kernel (D_super tiles stay resident
in VMEM); on CPU an x-chunked gather keeps the peak intermediate at
[q, 8, mb] (DESIGN.md §4).

Everything is exact (validated against the host engine).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import hierarchy, padding
from .supergraph import DislandIndex

INF = np.float32(np.inf)
PIECE_BUCKETS = (8, 32, 128, 512, 2048)


def _dummy(shape, fill, dtype):
    return lambda: jnp.full(shape, fill, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    # per-node lookups [n]
    agent_of: jax.Array          # int32
    dist_to_agent: jax.Array     # f32
    frag_of: jax.Array           # int32 (fragment of each *shrink* node)
    pos_in_frag: jax.Array       # int32
    piece_gid: jax.Array         # int32 global piece id (-1 if none)
    pos_in_piece: jax.Array      # int32
    piece_base: jax.Array        # int32 offset of piece block in flat
    piece_stride: jax.Array      # int32 row stride (= padded piece size)
    # fragments
    frag_apsp: jax.Array         # f32 [k, maxf, maxf]
    frag_next: jax.Array         # int32 [k, maxf, maxf] FW first hop (-1)
    brow: jax.Array              # f32 [k, maxf, mb] node->boundary rows
    bpos: jax.Array              # int32 [k, mb] boundary position in frag
    bvalid: jax.Array            # bool [k, mb]
    bnd_super: jax.Array         # int32 [k, mb] super id (S = sentinel)
    # super graph (dense overlay; authoritative at hierarchy_levels=1)
    d_super: jax.Array           # f32 [S+1, S+1] (+inf sentinel row/col)
    super_next: jax.Array        # int32 [S+1, S+1] overlay first hop (-1)
    # pieces: every bucketed APSP tensor, flattened end to end
    piece_flat: jax.Array        # f32 [sum_b P_b * mp_b * mp_b]
    piece_next: jax.Array        # int32, same layout as piece_flat (-1)
    # hierarchical overlay (hierarchy_levels=2, DESIGN.md §12).  The
    # dense pair above shrinks to a [1, 1] dummy and these per-level
    # tables take over; at levels=1 THESE are the 1-sized dummies.
    # Serve/unwind code dispatches on sf_of.shape[0] > 1 — a static
    # trace-time fact, so no flags thread through jit.
    sf_of: jax.Array = dataclasses.field(          # int32 [S+1] (nsf = sentinel)
        default_factory=_dummy((1,), 0, jnp.int32))
    pos_in_sf: jax.Array = dataclasses.field(      # int32 [S+1]
        default_factory=_dummy((1,), 0, jnp.int32))
    sf_members: jax.Array = dataclasses.field(     # int32 [nsf+1, m2] (S = pad)
        default_factory=_dummy((1, 1), 0, jnp.int32))
    sf_closure: jax.Array = dataclasses.field(     # f32 [nsf+1, m2, m2]
        default_factory=_dummy((1, 1, 1), INF, jnp.float32))
    sf_next: jax.Array = dataclasses.field(        # int32 [nsf+1, m2, m2]
        default_factory=_dummy((1, 1, 1), -1, jnp.int32))
    l2row: jax.Array = dataclasses.field(          # f32 [nsf+1, m2, mb2]
        default_factory=_dummy((1, 1, 1), INF, jnp.float32))
    bnd2_sid: jax.Array = dataclasses.field(       # int32 [nsf+1, mb2] (S2 = pad)
        default_factory=_dummy((1, 1), 0, jnp.int32))
    d2: jax.Array = dataclasses.field(             # f32 [S2+1, S2+1]
        default_factory=_dummy((1, 1), INF, jnp.float32))
    d2_next: jax.Array = dataclasses.field(        # int32 [S2+1, S2+1]
        default_factory=_dummy((1, 1), -1, jnp.int32))

    @property
    def hierarchy_levels(self) -> int:
        return 2 if self.sf_of.shape[0] > 1 else 1

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        children = tuple(getattr(self, f.name) for f in fields)
        return children, tuple(f.name for f in fields)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(**dict(zip(names, children)))


# ---------------------------------------------------------------------------
# offline build, staged (DESIGN.md §2, §9)
#
# The build is decomposed into per-stage functions over a host-side
# BuildPlan so the incremental refresh path (refresh_index) can re-run
# exactly the stage subset a weight-update batch dirties, while a
# from-scratch build composes every stage.  Both paths run the same
# per-item tensor programs, which is what makes "incremental rebuild ==
# from-scratch rebuild" hold array-for-array (tests/test_refresh.py).
# ---------------------------------------------------------------------------
# canonical padding rules live in padding.py (shared with the planner
# and the serving scheduler); the old private names stay as aliases
_pad_to = padding.pad_to
_pow2 = padding.pow2


@dataclasses.dataclass
class BuildPlan:
    """Host-side skeleton of the device index.

    Everything the refresh path needs that serve-time tensors do not
    carry: the mutable weight caches (``frag_adj``, ``sup_w``), the
    fixed SUPER edge-list *structure* with per-slot provenance, and the
    piece registry.  Structure (DRAs, fragments, SUPER topology) is
    weight-invariant, so a weight-update batch mutates only the caches
    and the plan survives arbitrarily many epochs.
    """

    n: int
    k: int
    maxf: int
    mb: int
    S: int
    # per-node host lookups (update classification)
    agent_of: np.ndarray
    frag_of: np.ndarray          # original id -> fragment (-1: represented)
    pos_in_frag: np.ndarray
    piece_gid: np.ndarray
    pos_in_piece: np.ndarray
    # fragments
    frag_adj: np.ndarray         # f32 [k, maxf, maxf] current weights
    bpos: np.ndarray
    bvalid: np.ndarray
    bnd_super: np.ndarray
    # SUPER edge slots (undirected, compact ids; structure is fixed)
    sup_src: np.ndarray          # int32 [Es]
    sup_dst: np.ndarray          # int32 [Es]
    sup_w: np.ndarray            # f32 [Es] current weights
    sup_fi: np.ndarray           # int32 [Es] owning fragment (-1: E_B)
    sup_pu: np.ndarray           # int32 [Es] frag-local gather row
    sup_pv: np.ndarray           # int32 [Es] frag-local gather col
    eb_key: np.ndarray           # int64 sorted lo*n+hi keys of E_B slots
    eb_slot: np.ndarray          # int64 slot per key
    # piece registry (gid order)
    piece_members: List[np.ndarray]   # sorted original ids, incl. agent
    piece_agent: np.ndarray           # int32 [P]
    piece_agent_pos: np.ndarray       # int32 [P]
    piece_cap: np.ndarray             # int32 [P] padded size
    piece_base: np.ndarray            # int64 [P] offset into piece_flat
    # overlay hierarchy (DESIGN.md §12): 1 = dense d_super closure,
    # 2 = per-super-fragment closures + dense level-2 boundary closure
    hierarchy_levels: int = 1
    hier: "hierarchy.HierPlan | None" = None

    @property
    def n_pieces(self) -> int:
        return len(self.piece_members)


def make_build_plan(ix: DislandIndex) -> BuildPlan:
    """Stage 0: host-side structure assembly (no device work).

    The device SUPER overlay is rebuilt here from first principles
    rather than taken from ``ix.super_graph.graph``: its node universe
    is exactly the boundary nodes (all bnd_super can ever reference),
    E_B slots are the cross-fragment shrink edges, and each fragment
    contributes its full boundary-to-boundary clique whose weights are
    *gathered from frag_apsp* (super_weights) — never stored
    authoritatively.  The host index keeps the paper's hybrid landmark
    covers (§V-A) for its space story; the device overlay cannot,
    because a cover's pair structure encodes which node lies on a
    shortest path — a weight-dependent fact that a live update batch
    silently invalidates (DESIGN.md §9).  The clique structure is
    weight-invariant, so scratch build and incremental refresh obtain
    every overlay weight by the same gather.
    """
    g = ix.g
    n = g.n
    k = len(ix.fragments)

    # ---- fragments + boundary universe ---------------------------------
    maxf = _pad_to(max((f.graph.n for f in ix.fragments), default=1))
    mb = _pad_to(max((f.boundary_local.size for f in ix.fragments),
                     default=1))
    frag_adj = np.full((k, maxf, maxf), INF, dtype=np.float32)
    frag_of = -np.ones(n, dtype=np.int32)
    pos_in_frag = np.zeros(n, dtype=np.int32)
    bpos = np.zeros((k, mb), dtype=np.int32)
    bvalid = np.zeros((k, mb), dtype=bool)
    bnd_ids = np.unique(np.concatenate(
        [f.nodes[f.boundary_local] for f in ix.fragments]
        or [np.empty(0, np.int64)]))
    S = bnd_ids.size
    bnd_super = np.full((k, mb), S, dtype=np.int32)
    super_id_of = -np.ones(n, dtype=np.int64)
    super_id_of[bnd_ids] = np.arange(S)
    for fi, f in enumerate(ix.fragments):
        fg = f.graph
        frag_of[f.nodes] = fi
        pos_in_frag[f.nodes] = np.arange(f.nodes.size)
        frag_adj[fi, fg.edge_u, fg.edge_v] = fg.edge_w.astype(np.float32)
        frag_adj[fi, fg.edge_v, fg.edge_u] = fg.edge_w.astype(np.float32)
        nb = f.boundary_local.size
        bpos[fi, :nb] = f.boundary_local
        bvalid[fi, :nb] = True
        bnd_super[fi, :nb] = super_id_of[f.nodes[f.boundary_local]]

    # ---- SUPER edge slots ----------------------------------------------
    shrink = ix.shrink
    lab = ix.partition.labels
    sup_src: List[int] = []
    sup_dst: List[int] = []
    sup_w: List[float] = []
    sup_fi: List[int] = []
    sup_pu: List[int] = []
    sup_pv: List[int] = []
    eb_keys: List[int] = []
    eb_slots: List[int] = []
    # E_B: cross-fragment shrink edges (both endpoints boundary by
    # construction); same-fragment boundary-boundary edges are subsumed
    # by that fragment's clique, so every edge has ONE owning slot kind
    cross = lab[shrink.edge_u] != lab[shrink.edge_v]
    for u, v, w in zip(shrink.edge_u[cross], shrink.edge_v[cross],
                       shrink.edge_w[cross]):
        ou, ov = int(ix.shrink_ids[u]), int(ix.shrink_ids[v])
        eb_keys.append(min(ou, ov) * n + max(ou, ov))
        eb_slots.append(len(sup_src))
        sup_src.append(int(super_id_of[ou]))
        sup_dst.append(int(super_id_of[ov]))
        sup_w.append(float(w))
        sup_fi.append(-1)
        sup_pu.append(-1)
        sup_pv.append(-1)
    # per-fragment boundary cliques (paper §V-A Upsilon weights, derived)
    for fi, f in enumerate(ix.fragments):
        bl = f.boundary_local
        ids = super_id_of[f.nodes[bl]]
        for i in range(bl.size):
            for j in range(i + 1, bl.size):
                sup_src.append(int(ids[i]))
                sup_dst.append(int(ids[j]))
                sup_w.append(float("inf"))   # filled by super_weights
                sup_fi.append(fi)
                sup_pu.append(int(bl[i]))
                sup_pv.append(int(bl[j]))
    ek = np.asarray(eb_keys, dtype=np.int64)
    es = np.asarray(eb_slots, dtype=np.int64)
    order = np.argsort(ek)

    # ---- piece registry + per-node lookups ------------------------------
    piece_gid = -np.ones(n, dtype=np.int32)
    pos_in_piece = np.zeros(n, dtype=np.int32)
    piece_members: List[np.ndarray] = []
    piece_agent: List[int] = []
    piece_agent_pos: List[int] = []
    piece_cap: List[int] = []
    for a in ix.dras.agents:
        for piece in a.pieces:
            cap = next(c for c in PIECE_BUCKETS if piece.size <= c)
            ids = np.asarray(sorted(set(int(x) for x in piece)),
                             dtype=np.int32)
            gid = len(piece_members)
            piece_members.append(ids)
            piece_agent.append(int(a.agent))
            piece_agent_pos.append(int(np.searchsorted(ids, a.agent)))
            piece_cap.append(cap)
            # the agent belongs to many pieces: leave its lookup at -1 so
            # case-1 logic falls through to the exact ds+dt formula
            inner = ids != a.agent
            piece_gid[ids[inner]] = gid
            pos_in_piece[ids[inner]] = np.nonzero(inner)[0]
    # flat layout: bucket-major (all cap-8 blocks, then cap-32, ...),
    # bucket-local order = gid order — matches piece_stage's FW batching
    cap_arr = np.asarray(piece_cap, dtype=np.int64)
    piece_base = np.zeros(len(piece_members), dtype=np.int64)
    off = 0
    for cap in PIECE_BUCKETS:
        for gid in np.nonzero(cap_arr == cap)[0]:
            piece_base[gid] = off
            off += cap * cap

    return BuildPlan(
        n=n, k=k, maxf=maxf, mb=mb, S=S,
        agent_of=ix.dras.agent_of.astype(np.int32),
        frag_of=frag_of, pos_in_frag=pos_in_frag,
        piece_gid=piece_gid, pos_in_piece=pos_in_piece,
        frag_adj=frag_adj, bpos=bpos, bvalid=bvalid, bnd_super=bnd_super,
        sup_src=np.asarray(sup_src, dtype=np.int32),
        sup_dst=np.asarray(sup_dst, dtype=np.int32),
        sup_w=np.asarray(sup_w, dtype=np.float32),
        sup_fi=np.asarray(sup_fi, dtype=np.int32),
        sup_pu=np.asarray(sup_pu, dtype=np.int32),
        sup_pv=np.asarray(sup_pv, dtype=np.int32),
        eb_key=ek[order], eb_slot=es[order],
        piece_members=piece_members,
        piece_agent=np.asarray(piece_agent, dtype=np.int32),
        piece_agent_pos=np.asarray(piece_agent_pos, dtype=np.int32),
        piece_cap=cap_arr.astype(np.int32),
        piece_base=piece_base,
    )


def _brow_from(frag_apsp: jax.Array, bpos: np.ndarray,
               bvalid: np.ndarray) -> jax.Array:
    """Boundary-row table: brow[f, p, b] = dist(node at position p,
    boundary slot b) — serve gathers one row per query endpoint instead
    of a take_along_axis over [q, maxf]."""
    brow = jnp.take_along_axis(frag_apsp,
                               jnp.asarray(bpos)[:, None, :], axis=2)
    return jnp.where(jnp.asarray(bvalid)[:, None, :], brow, INF)


def frag_stage(plan: BuildPlan, *, force=None) -> tuple[jax.Array,
                                                        jax.Array,
                                                        jax.Array]:
    """Stage 1: batched witness FW over every fragment ->
    (apsp, brow, next).  The witness kernel's distance output is
    bit-identical to the distance-only kernel (same recurrence, same
    pivot order), so the path table rides along for free."""
    frag_apsp, frag_next = ops.fw_batch_next(jnp.asarray(plan.frag_adj),
                                             force=force)
    return (frag_apsp, _brow_from(frag_apsp, plan.bpos, plan.bvalid),
            frag_next)


def super_weights(plan: BuildPlan, blocks: np.ndarray,
                  frags: np.ndarray | None = None) -> None:
    """Fill the enforced SUPER slot weights by gathering from fragment
    APSP ``blocks`` (DESIGN.md §9: the Upsilon weights are *derived*
    state, never stored authoritatively).

    ``frags=None``: blocks is the full [k, maxf, maxf] table, fill every
    enforced slot.  Otherwise blocks holds only the listed fragments'
    rows, and only their slots are rewritten.
    """
    if frags is None:
        mask = plan.sup_fi >= 0
        local = plan.sup_fi[mask]
    else:
        mask = np.isin(plan.sup_fi, frags)
        fi_to_row = -np.ones(plan.k, dtype=np.int64)
        fi_to_row[frags] = np.arange(len(frags))
        local = fi_to_row[plan.sup_fi[mask]]
    plan.sup_w[mask] = blocks[local, plan.sup_pu[mask], plan.sup_pv[mask]]


def super_overlay(plan: BuildPlan) -> jax.Array:
    """Dense [S, S] overlay adjacency from the slot list (parallel
    slots min-merged, diag 0)."""
    S = plan.S
    m = np.full((S, S), INF, np.float32)
    np.minimum.at(m, (plan.sup_src, plan.sup_dst), plan.sup_w)
    np.minimum.at(m, (plan.sup_dst, plan.sup_src), plan.sup_w)
    np.fill_diagonal(m, 0.0)
    return jnp.asarray(m)


def overlay_slot_table(plan: BuildPlan) -> np.ndarray:
    """Winning slot id per overlay adjacency pair [S, S] (-1: none).

    Writes slots in descending weight order so the last (= lightest)
    write wins, matching super_overlay's min-merge of parallel slots.
    Computed whenever the overlay is (re)closed and carried on the
    published DeviceIndex as the host-side ``host_ov_slot`` sidecar, so
    path unwinding always reads slot provenance consistent with the
    d_super/super_next epoch it serves — never the live-mutating
    ``plan.sup_w`` (DESIGN.md §10).
    """
    ov = np.full((plan.S, plan.S), -1, np.int32)
    if plan.sup_w.size:
        order = np.argsort(plan.sup_w, kind="stable")[::-1]
        src, dst = plan.sup_src[order], plan.sup_dst[order]
        ov[src, dst] = order
        ov[dst, src] = order
    return ov


def super_stage(plan: BuildPlan, *, force=None) -> tuple[jax.Array,
                                                         jax.Array]:
    """Stage 2: SUPER APSP — dense witness FW closure of the boundary
    overlay -> (d_super, super_next).

    The overlay is small and clique-dense, which is exactly the regime
    where dense (min,+) algebra crushes edge-list relaxation: the FW
    closure solves S=625 in ~60ms where the segment_min Bellman-Ford
    needed a diameter's worth of ~750ms sweeps (~20s) — measured on
    road4000, bit-identical results.  The same closure serves scratch
    builds and incremental refreshes: a warm-started BF was tried for
    the refresh path and measured out (negative-result note in sssp.py;
    the edge-list BF remains the tool for the large sparse sharded
    build, dist_engine.super_apsp_sharded).  Since PR 3 the closure
    carries the first-hop witness matrix (DESIGN.md §10): super_next
    chains through overlay-*adjacent* super nodes, and each adjacency
    hop is resolved back to a concrete slot by PathUnwinder via the
    epoch's overlay_slot_table sidecar.
    """
    S = plan.S
    d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)
    super_next = jnp.full((S + 1, S + 1), -1, jnp.int32)
    if S == 0 or plan.sup_src.size == 0:
        return d_super, super_next
    d_s, n_s = ops.fw_next(super_overlay(plan), force=force)
    return (d_super.at[:S, :S].set(d_s),
            super_next.at[:S, :S].set(n_s))


def _piece_adj(g, members: np.ndarray, cap: int) -> np.ndarray:
    sub, _ids = g.subgraph(members)
    adj = np.full((cap, cap), INF, dtype=np.float32)
    adj[sub.edge_u, sub.edge_v] = sub.edge_w.astype(np.float32)
    adj[sub.edge_v, sub.edge_u] = sub.edge_w.astype(np.float32)
    return adj


def _fw_bucket(adjs: List[np.ndarray], *, force=None,
               pad_pow2: bool = False) -> tuple[np.ndarray, np.ndarray]:
    """Batched witness FW over equally-padded piece matrices ->
    (dist blocks, next blocks).  ``pad_pow2`` (refresh path) rounds the
    batch up with +inf dummies, floored at 8, so the jitted FW program
    compiles for O(log P) distinct batch shapes — and a typical
    localized update batch always hits the already-warm 8-shape
    (EpochedEngine pre-compiles it)."""
    cap = adjs[0].shape[0]
    batch = np.stack(adjs)
    if pad_pow2 and _pow2(len(adjs), floor=8) != len(adjs):
        full = np.full((_pow2(len(adjs), floor=8), cap, cap), INF,
                       np.float32)
        full[:len(adjs)] = batch
        batch = full
    out, nxt = ops.fw_batch_next(jnp.asarray(batch), force=force)
    out = np.asarray(out)[:len(adjs)]
    # Padding blocks are all-+inf: the FW recurrence only ever ADDS
    # (inf+inf = inf, no inf-inf), so no NaN can arise — audited and
    # pinned by the all-INF kernel tests in tests/test_kernels.py.
    # Guard it anyway: mismatches_oracle treats NaN as always-wrong,
    # so a kernel regression here must fail the build loudly, not
    # surface as serving mismatches three layers up.
    if np.isnan(out).any():
        raise FloatingPointError(
            "piece FW produced NaN (inf-padding arithmetic regressed?)")
    return (out, np.asarray(nxt)[:len(adjs)])


def piece_stage(plan: BuildPlan, g, *, force=None) -> tuple[np.ndarray,
                                                            np.ndarray]:
    """Stage 3: per-piece APSP, size-bucketed batched FW, flattened
    end-to-end into the single piece_flat gather table (DESIGN.md §3),
    plus the identically-laid-out first-hop witness table piece_next."""
    total = int(sum(int(c) * int(c) for c in plan.piece_cap))
    flat = np.full(max(total, 1), INF, dtype=np.float32)
    nflat = np.full(max(total, 1), -1, dtype=np.int32)
    for cap in PIECE_BUCKETS:
        gids = np.nonzero(plan.piece_cap == cap)[0]
        if gids.size == 0:
            continue
        adjs = [_piece_adj(g, plan.piece_members[gid], cap)
                for gid in gids]
        blocks, nexts = _fw_bucket(adjs, force=force)
        for gid, block, nxt in zip(gids, blocks, nexts):
            base = plan.piece_base[gid]
            flat[base:base + cap * cap] = block.reshape(-1)
            nflat[base:base + cap * cap] = nxt.reshape(-1)
    return flat, nflat


def hier_super_stage(plan: BuildPlan, *, force=None) -> dict:
    """Stage 2, hierarchical (DESIGN.md §12): close the overlay as a
    two-level partition hierarchy instead of one dense FW.

    Runs the existing batched witness FW once per super-fragment batch
    at the pow2 tile shape [nsf, m2, m2] (``hierarchy.sf_stage``),
    gathers the level-2 clique weights from those closures (derived
    state, exactly like the level-1 Upsilon weights), and closes only
    the small level-2 boundary set densely (``hierarchy.l2_stage``).
    Returns the DeviceIndex field dict for the per-level tables plus
    the host-side provenance sidecars.
    """
    hier = plan.hier
    hierarchy.sf_adj_fill(hier, plan)
    sf_closure, sf_next, l2row = hierarchy.sf_stage(hier, force=force)
    hierarchy.hier_weights(hier, plan,
                           np.asarray(sf_closure)[:hier.nsf])
    d2, d2_next = hierarchy.l2_stage(hier, force=force)
    S = plan.S
    sf_of = np.concatenate([hier.sf_of,
                            [hier.nsf]]).astype(np.int32)       # [S+1]
    pos_in_sf = np.concatenate([hier.pos_in_sf, [0]]).astype(np.int32)
    members = np.where(hier.sf_members < 0, S,
                       hier.sf_members).astype(np.int32)
    members = np.concatenate(
        [members, np.full((1, hier.m2), S, np.int32)])          # [nsf+1]
    bnd2_sid = np.concatenate(
        [hier.bnd2_sid, np.full((1, hier.mb2), hier.S2, np.int32)])
    return {
        "fields": {
            "sf_of": jnp.asarray(sf_of),
            "pos_in_sf": jnp.asarray(pos_in_sf),
            "sf_members": jnp.asarray(members),
            "sf_closure": sf_closure,
            "sf_next": sf_next,
            "l2row": l2row,
            "bnd2_sid": jnp.asarray(bnd2_sid),
            "d2": d2,
            "d2_next": d2_next,
        },
        "ov_slot": hierarchy.ov_slot_map(plan),
        "l2_slot": hierarchy.l2_slot_map(hier),
    }


def resolve_hierarchy_levels(S: int, hierarchy_levels) -> int:
    """Normalize the ``hierarchy_levels`` build knob: "auto" switches
    to the two-level overlay once S crosses hierarchy.AUTO_THRESHOLD;
    explicit 1/2 is honored (2 degrades to 1 on an empty overlay)."""
    if hierarchy_levels == "auto":
        hierarchy_levels = 2 if S > hierarchy.AUTO_THRESHOLD else 1
    if hierarchy_levels not in (1, 2):
        raise ValueError(
            f"hierarchy_levels must be 1, 2 or 'auto': {hierarchy_levels}")
    if hierarchy_levels == 2 and S == 0:
        return 1
    return int(hierarchy_levels)


def _node_piece_addressing(plan: BuildPlan) -> tuple[np.ndarray,
                                                     np.ndarray]:
    """Per-node (piece_base, piece_stride) vectors from the registry."""
    base = np.zeros(plan.n, dtype=np.int32)
    stride = np.zeros(plan.n, dtype=np.int32)
    hot = plan.piece_gid >= 0
    gid = plan.piece_gid[hot]
    base[hot] = plan.piece_base[gid]
    stride[hot] = plan.piece_cap[gid]
    return base, stride


def build_device_index_with_plan(
        ix: DislandIndex, *, force=None,
        hierarchy_levels: int | str = "auto"
        ) -> tuple[DeviceIndex, BuildPlan]:
    """Full from-scratch build: compose every stage, keep the plan
    around so refresh_index can run incrementally afterwards.

    ``hierarchy_levels`` picks the overlay closure: 1 = the dense
    [S+1, S+1] FW (unchanged, bit-identical to the pre-hierarchy
    index), 2 = the two-level partition hierarchy (DESIGN.md §12),
    "auto" = 2 once S crosses ``hierarchy.AUTO_THRESHOLD``.
    """
    plan = make_build_plan(ix)
    plan.hierarchy_levels = resolve_hierarchy_levels(plan.S,
                                                     hierarchy_levels)
    if plan.hierarchy_levels == 2:
        plan.hier = hierarchy.plan_hierarchy(plan)
    frag_apsp, brow, frag_next = frag_stage(plan, force=force)
    super_weights(plan, np.asarray(frag_apsp))
    if plan.hierarchy_levels == 2:
        hres = hier_super_stage(plan, force=force)
        hier_fields = hres["fields"]
        d_super = jnp.full((1, 1), INF, jnp.float32)
        super_next = jnp.full((1, 1), -1, jnp.int32)
    else:
        hres = None
        hier_fields = {}
        d_super, super_next = super_stage(plan, force=force)
    piece_flat, piece_next = piece_stage(plan, ix.g, force=force)
    base, stride = _node_piece_addressing(plan)
    dix = DeviceIndex(
        **hier_fields,
        agent_of=jnp.asarray(plan.agent_of),
        dist_to_agent=jnp.asarray(
            ix.dras.dist_to_agent.astype(np.float32)),
        frag_of=jnp.asarray(plan.frag_of),
        pos_in_frag=jnp.asarray(plan.pos_in_frag),
        piece_gid=jnp.asarray(plan.piece_gid),
        pos_in_piece=jnp.asarray(plan.pos_in_piece),
        piece_base=jnp.asarray(base),
        piece_stride=jnp.asarray(stride),
        frag_apsp=frag_apsp,
        frag_next=frag_next,
        brow=brow,
        bpos=jnp.asarray(plan.bpos),
        bvalid=jnp.asarray(plan.bvalid),
        bnd_super=jnp.asarray(plan.bnd_super),
        d_super=d_super,
        super_next=super_next,
        piece_flat=jnp.asarray(piece_flat),
        piece_next=jnp.asarray(piece_next),
    )
    # host-side sidecars (not pytree fields): slot provenance for the
    # overlay closure this index was built with.  Dense epochs carry
    # the [S, S] overlay_slot_table; hierarchical epochs carry the
    # sparse OvSlotMap (the dense table is exactly the quadratic host
    # object the hierarchy avoids) plus the small level-2 slot table.
    if hres is not None:
        dix.host_ov_slot = hres["ov_slot"]
        dix.host_l2_slot = hres["l2_slot"]
    else:
        dix.host_ov_slot = overlay_slot_table(plan)
    return dix, plan


def build_device_index(ix: DislandIndex, *, force=None,
                       hierarchy_levels: int | str = "auto"
                       ) -> DeviceIndex:
    """Assemble padded tensors on host, run device APSP preprocessing."""
    return build_device_index_with_plan(
        ix, force=force, hierarchy_levels=hierarchy_levels)[0]


def warmup_refresh(plan: BuildPlan, *, force=None) -> None:
    """Pre-compile the refresh-path FW programs (the small pow2
    fragment-batch shapes + one [8, cap, cap] batch per piece bucket in
    use), so no XLA compile lands inside a live apply_updates.  The
    overlay FW program is already warm from the build.  Mirrors
    QueryPlanner.warmup for the serve path (DESIGN.md §9)."""
    shapes = [(min(p, plan.k), plan.maxf, plan.maxf) for p in (4, 8)]
    shapes += [(8, int(cap), int(cap))
               for cap in np.unique(plan.piece_cap)]
    if plan.hier is not None:
        # dirty super-fragment batches refresh at these pow2 shapes
        shapes += [(min(p, plan.hier.nsf), plan.hier.m2, plan.hier.m2)
                   for p in (4, 8)]
    for shp in set(shapes):
        jax.block_until_ready(
            ops.fw_batch_next(jnp.full(shp, INF, jnp.float32),
                              force=force))


# ---------------------------------------------------------------------------
# incremental refresh (DESIGN.md §9; paper §IV/§V locality)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class UpdateClass:
    """A weight-update batch classified against the index structure.

    The paper's decomposition localizes every weight change: an edge is
    (i) inside one DRA piece, (ii) inside one fragment, and/or (iii) an
    E_B SUPER slot — nothing else.  Same-fragment boundary-boundary
    edges hit (ii) and (iii) simultaneously.
    """

    dirty_frags: np.ndarray      # fragment ids
    frag_fi: np.ndarray          # per same-fragment update
    frag_pu: np.ndarray
    frag_pv: np.ndarray
    frag_w: np.ndarray
    eb_slots: np.ndarray         # per E_B update
    eb_w: np.ndarray
    dirty_gids: np.ndarray       # piece ids
    n_inert: int                 # edges touching no served structure


def classify_updates(plan: BuildPlan, u, v, w) -> UpdateClass:
    """Map (u, v, new_w) updates onto dirty fragments / slots / pieces."""
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    gid_u = plan.piece_gid[u]
    gid_v = plan.piece_gid[v]
    piece_m = (gid_u >= 0) | (gid_v >= 0)
    gid = np.where(gid_u >= 0, gid_u, gid_v)
    # structural invariant (paper Props 3-9): a represented node's only
    # neighbours are its piece co-members and its agent
    other_gid = np.where(gid_u >= 0, gid_v, gid_u)
    other = np.where(gid_u >= 0, v, u)
    safe_gid = np.where(piece_m, gid, 0)
    ok = (~piece_m | (other_gid == gid)
          | (other == plan.piece_agent[safe_gid]))
    if not ok.all():
        bad = np.nonzero(~ok)[0][0]
        raise ValueError(
            f"edge ({int(u[bad])}, {int(v[bad])}) crosses piece "
            "boundaries; index structure does not admit it")
    # same-fragment updates (frag_adj entries)
    fu = plan.frag_of[u]
    fv = plan.frag_of[v]
    frag_m = ~piece_m & (fu >= 0) & (fu == fv)
    # E_B slots (covers cross-fragment edges AND same-fragment edges
    # whose endpoints are both boundary)
    key = np.minimum(u, v) * plan.n + np.maximum(u, v)
    if plan.eb_key.size:
        pos = np.clip(np.searchsorted(plan.eb_key, key), 0,
                      plan.eb_key.size - 1)
        eb_m = ~piece_m & (plan.eb_key[pos] == key)
        slots = plan.eb_slot[pos]
    else:
        eb_m = np.zeros(u.size, dtype=bool)
        slots = np.zeros(u.size, dtype=np.int64)
    inert = int((~piece_m & ~frag_m & ~eb_m).sum())
    return UpdateClass(
        dirty_frags=np.unique(fu[frag_m]).astype(np.int64),
        frag_fi=fu[frag_m],
        frag_pu=plan.pos_in_frag[u[frag_m]],
        frag_pv=plan.pos_in_frag[v[frag_m]],
        frag_w=w[frag_m],
        eb_slots=slots[eb_m],
        eb_w=w[eb_m],
        dirty_gids=np.unique(gid[piece_m]).astype(np.int64),
        n_inert=inert,
    )


@dataclasses.dataclass
class RefreshStats:
    """What one refresh_index call touched, for perflog records."""

    n_updates: int
    n_dirty_frags: int
    n_frags: int
    n_dirty_pieces: int
    n_pieces: int
    n_eb_slots: int
    n_inert: int
    total_increase: float
    decrease_only: bool          # no weight rose (jam-clear batch)
    timings: dict

    @property
    def dirty_frag_frac(self) -> float:
        return self.n_dirty_frags / max(self.n_frags, 1)

    def as_record(self) -> dict:
        return {
            "n_updates": self.n_updates,
            "dirty_frags": f"{self.n_dirty_frags}/{self.n_frags}",
            "dirty_frag_frac": round(self.dirty_frag_frac, 4),
            "dirty_pieces": f"{self.n_dirty_pieces}/{self.n_pieces}",
            "decrease_only": self.decrease_only,
            "refresh_s": round(self.timings.get("total", 0.0), 4),
        }


def refresh_frag_stage(plan: BuildPlan, frag_apsp: jax.Array,
                       brow: jax.Array, frag_next: jax.Array,
                       upd: UpdateClass, *,
                       force=None) -> tuple[jax.Array, jax.Array,
                                            jax.Array, np.ndarray]:
    """Re-run witness FW on the dirty fragment subset only.

    The dirty batch is padded to a power of two with +inf dummies so
    refreshes compile O(log k) FW programs total; FW is row-independent
    across the batch, so the dirty rows come out bit-identical to a
    full-batch from-scratch run — distances and first-hop witnesses
    alike, which is what keeps epochs path-consistent (DESIGN.md §10).
    """
    plan.frag_adj[upd.frag_fi, upd.frag_pu, upd.frag_pv] = upd.frag_w
    plan.frag_adj[upd.frag_fi, upd.frag_pv, upd.frag_pu] = upd.frag_w
    dirty = upd.dirty_frags
    if dirty.size == 0:
        return frag_apsp, brow, frag_next, np.empty(
            (0, plan.maxf, plan.maxf), np.float32)
    # every array op below runs at the padded size: repeating the first
    # dirty fragment is idempotent (same rows scattered twice), and the
    # fixed shapes keep refreshes on pre-compiled programs
    # (warmup_refresh) instead of compiling one per dirty count
    d = int(dirty.size)
    p = min(_pow2(d, floor=4), plan.k)
    pad = np.concatenate([dirty, np.full(p - d, dirty[0], np.int64)]) \
        if p > d else dirty
    jpad = jnp.asarray(pad)
    jblocks, jnexts = ops.fw_batch_next(jnp.asarray(plan.frag_adj[pad]),
                                        force=force)
    frag_apsp = frag_apsp.at[jpad].set(jblocks)
    frag_next = frag_next.at[jpad].set(jnexts)
    br = _brow_from(jblocks, plan.bpos[pad], plan.bvalid[pad])
    return (frag_apsp, brow.at[jpad].set(br), frag_next,
            np.asarray(jblocks[:d]))


def refresh_hier_stage(plan: BuildPlan, dix: DeviceIndex,
                       changed_slots: np.ndarray, undo: dict, *,
                       force=None) -> dict:
    """Hierarchical twin of the dense overlay re-close (DESIGN.md §12):
    re-run the super-fragment FW on the dirty super-fragments only.

    A changed level-1 slot dirties either one super-fragment's
    adjacency block (both endpoints inside it) or a level-2 cross edge
    (endpoints in different super-fragments) — nothing else, the same
    block-diagonal structure the fragment refresh exploits one level
    down.  The dirty batch pads to a power of two with repeats (same
    idempotent-scatter trick as refresh_frag_stage), so the refreshed
    rows are bit-identical to a from-scratch hier_super_stage; the
    small dense level-2 closure is then re-run whole.  ``undo`` is
    filled with rollback snapshots of the weight caches BEFORE any
    mutation, so a failure later in the refresh can restore them.
    """
    hier = plan.hier
    sl = hier.slot_sf[changed_slots]
    sfs = np.unique(sl[sl >= 0]).astype(np.int64)
    undo["sfs"] = sfs
    undo["sf_adj"] = hier.sf_adj[sfs].copy()
    undo["l2_w"] = hier.l2_w.copy()
    sf_closure, sf_next, l2row = dix.sf_closure, dix.sf_next, dix.l2row
    if sfs.size:
        hierarchy.sf_adj_fill(hier, plan, sfs=sfs)
        d = int(sfs.size)
        p = min(_pow2(d, floor=4), hier.nsf)
        pad = np.concatenate([sfs, np.full(p - d, sfs[0], np.int64)]) \
            if p > d else sfs
        jpad = jnp.asarray(pad)
        blocks, nexts = ops.fw_batch_next(jnp.asarray(hier.sf_adj[pad]),
                                          force=force)
        sf_closure = sf_closure.at[jpad].set(blocks)
        sf_next = sf_next.at[jpad].set(nexts)
        rows = hierarchy.l2row_from(blocks, hier.bnd2_pos[pad],
                                    hier.bnd2_valid[pad])
        l2row = l2row.at[jpad].set(rows)
        hierarchy.hier_weights(hier, plan, np.asarray(blocks[:d]),
                               sfs=sfs)
    else:
        # only cross-super-fragment slots changed: no FW, just the
        # O(cross) level-2 weight rewrite inside hier_weights
        hierarchy.hier_weights(
            hier, plan, np.empty((0, hier.m2, hier.m2), np.float32),
            sfs=sfs)
    d2, d2_next = hierarchy.l2_stage(hier, force=force)
    return {
        "fields": {"sf_closure": sf_closure, "sf_next": sf_next,
                   "l2row": l2row, "d2": d2, "d2_next": d2_next},
        "ov_slot": hierarchy.ov_slot_map(plan),
        "l2_slot": hierarchy.l2_slot_map(hier),
    }


def refresh_piece_stage(plan: BuildPlan, g_new, dirty_gids: np.ndarray,
                        piece_flat: np.ndarray, piece_next: np.ndarray,
                        dist_to_agent: np.ndarray, *,
                        force=None) -> None:
    """Recompute only the dirty pieces, writing their APSP + witness
    blocks in place into the flat tables and re-deriving dist-to-agent
    for their members from the agent's APSP row (paths from a
    represented node to its agent never leave the piece, Props 3-9)."""
    for cap in PIECE_BUCKETS:
        gids = [g for g in dirty_gids if plan.piece_cap[g] == cap]
        if not gids:
            continue
        adjs = [_piece_adj(g_new, plan.piece_members[gid], cap)
                for gid in gids]
        blocks, nexts = _fw_bucket(adjs, force=force, pad_pow2=True)
        for gid, block, nxt in zip(gids, blocks, nexts):
            base = plan.piece_base[gid]
            piece_flat[base:base + cap * cap] = block.reshape(-1)
            piece_next[base:base + cap * cap] = nxt.reshape(-1)
            members = plan.piece_members[gid]
            inner = members != plan.piece_agent[gid]
            dist_to_agent[members[inner]] = block[
                plan.piece_agent_pos[gid], np.nonzero(inner)[0]]


def refresh_index(dix: DeviceIndex, plan: BuildPlan, g_new, u, v, w, *,
                  w_old=None,
                  force=None) -> tuple[DeviceIndex, RefreshStats]:
    """Incremental index maintenance (DESIGN.md §9; the live-traffic
    path that replaces the full offline pipeline of paper Fig. 7).

    Locality is inherited from the paper's decomposition: a DRA touches
    the rest of G only at its agent (§IV, Props 3-9), so a DRA-internal
    edge dirties exactly one piece; fragments meet only at boundary
    nodes (§V-A), so an intra-fragment edge dirties one fragment's APSP
    plus its boundary-clique Upsilon weights; a cross-fragment edge is
    one E_B overlay slot (§V-A).  Nothing else exists — the same fact
    that makes the query algorithm (§VI-B) two-level makes the update
    problem block-diagonal.

    Given a batch of edge-weight updates (u, v, new_w) against the
    graph the plan currently reflects, re-runs exactly the dirtied
    build stages:

      a. batched FW on the dirty fragments only (refresh_frag_stage),
      b. SUPER slot weights regathered from the new fragment APSP +
         direct E_B writes, then the overlay re-closed by the dense FW
         kernel — skipped entirely when no overlay weight actually
         changed (super_stage; a warm-started BF alternative was
         measured out, see sssp.py),
      c. dirty piece APSP blocks rewritten in place into piece_flat,
         with member dist-to-agent re-derived from the agent row,
      d. a brand-new immutable DeviceIndex assembled from the results —
         the caller publishes it as the next epoch while queries keep
         draining on the old one (dist_engine.EpochedEngine).

    ``g_new`` must be the post-update graph (Graph.with_edge_weights);
    the plan's weight caches are mutated to match, so consecutive
    refreshes compose — and an exception anywhere mid-refresh rolls the
    caches back, so a failed refresh leaves plan and published index
    consistent.  ``w_old`` (the updated edges' previous weights, which
    EpochedEngine passes) is what classifies the batch direction in the
    stats; without it, piece-internal changes are invisible to the
    overlay-delta fallback.  Exactness: every stage recomputes from
    true weights (never patches distances), so the result is
    array-equal to a from-scratch build on g_new — the property the
    differential harness in tests/test_refresh.py enforces per epoch.
    """
    timings: dict = {}
    t_all = time.perf_counter()

    t0 = time.perf_counter()
    upd = classify_updates(plan, u, v, w)
    timings["classify"] = time.perf_counter() - t0

    frag_w_before = plan.frag_adj[upd.frag_fi, upd.frag_pu,
                                  upd.frag_pv].copy()
    sup_w_before = plan.sup_w.copy()
    hier_undo: dict = {}
    try:
        t0 = time.perf_counter()
        frag_apsp, brow, frag_next, blocks = refresh_frag_stage(
            plan, dix.frag_apsp, dix.brow, dix.frag_next, upd,
            force=force)
        timings["frag_fw"] = time.perf_counter() - t0

        # ---- SUPER: regather dirty slot weights, re-close overlay ---
        t0 = time.perf_counter()
        touched = np.isin(plan.sup_fi, upd.dirty_frags)
        touched_slots = np.concatenate([np.nonzero(touched)[0],
                                        upd.eb_slots]).astype(np.int64)
        slot_w_old = sup_w_before[touched_slots]
        if upd.dirty_frags.size:
            super_weights(plan, blocks, frags=upd.dirty_frags)
        plan.sup_w[upd.eb_slots] = upd.eb_w
        slot_w_new = plan.sup_w[touched_slots]
        changed = slot_w_old != slot_w_new
        hier_fields: dict = {}
        l2_slot = getattr(dix, "host_l2_slot", None)
        if changed.any():
            if plan.hierarchy_levels == 2:
                hres = refresh_hier_stage(plan, dix,
                                          touched_slots[changed],
                                          hier_undo, force=force)
                hier_fields = hres["fields"]
                ov_slot = hres["ov_slot"]
                l2_slot = hres["l2_slot"]
                d_super, super_next = dix.d_super, dix.super_next
            else:
                d_super, super_next = super_stage(plan, force=force)
                ov_slot = overlay_slot_table(plan)
        else:
            # no overlay weight changed: closure AND witnesses are
            # still exact, so the path tables carry over too
            # (hier_fields stays empty — per-level tables carry too)
            d_super, super_next = dix.d_super, dix.super_next
            ov_slot = getattr(dix, "host_ov_slot", None)
        timings["super_fw"] = time.perf_counter() - t0

        # ---- pieces + dist-to-agent ---------------------------------
        t0 = time.perf_counter()
        if upd.dirty_gids.size:
            piece_flat = np.asarray(dix.piece_flat).copy()
            piece_next = np.asarray(dix.piece_next).copy()
            dist_to_agent = np.asarray(dix.dist_to_agent).copy()
            refresh_piece_stage(plan, g_new, upd.dirty_gids, piece_flat,
                                piece_next, dist_to_agent, force=force)
            piece_flat_j = jnp.asarray(piece_flat)
            piece_next_j = jnp.asarray(piece_next)
            dist_j = jnp.asarray(dist_to_agent)
        else:
            piece_flat_j = dix.piece_flat
            piece_next_j = dix.piece_next
            dist_j = dix.dist_to_agent
        timings["pieces"] = time.perf_counter() - t0
    except BaseException:
        # roll the weight caches back: the caller never published a new
        # epoch, so the plan must keep describing the old one
        plan.frag_adj[upd.frag_fi, upd.frag_pu,
                      upd.frag_pv] = frag_w_before
        plan.frag_adj[upd.frag_fi, upd.frag_pv,
                      upd.frag_pu] = frag_w_before
        plan.sup_w[:] = sup_w_before
        if hier_undo:
            plan.hier.sf_adj[hier_undo["sfs"]] = hier_undo["sf_adj"]
            plan.hier.l2_w[:] = hier_undo["l2_w"]
        raise

    # batch direction: against the edges' previous weights when the
    # caller provides them; the overlay delta alone cannot see
    # piece-internal changes
    if w_old is not None:
        delta = np.asarray(w, np.float64) - np.asarray(w_old, np.float64)
        total_increase = float(np.maximum(0.0, delta).sum())
    else:
        fin = np.isfinite(slot_w_old) & np.isfinite(slot_w_new)
        total_increase = float(np.maximum(
            0.0, slot_w_new[fin] - slot_w_old[fin]).sum())

    timings["total"] = time.perf_counter() - t_all
    new_dix = dataclasses.replace(
        dix, frag_apsp=frag_apsp, frag_next=frag_next, brow=brow,
        d_super=d_super, super_next=super_next,
        piece_flat=piece_flat_j, piece_next=piece_next_j,
        dist_to_agent=dist_j, **hier_fields)
    if ov_slot is not None:
        new_dix.host_ov_slot = ov_slot
    if l2_slot is not None:
        new_dix.host_l2_slot = l2_slot
    stats = RefreshStats(
        n_updates=int(np.asarray(u).size),
        n_dirty_frags=int(upd.dirty_frags.size), n_frags=plan.k,
        n_dirty_pieces=int(upd.dirty_gids.size),
        n_pieces=plan.n_pieces,
        n_eb_slots=int(upd.eb_slots.size), n_inert=upd.n_inert,
        total_increase=total_increase,
        decrease_only=total_increase == 0.0, timings=timings)
    return new_dix, stats


# ---------------------------------------------------------------------------
# serving.  Witness conventions (DESIGN.md §10): the *_w variants return
# (dist, wit) with wit int32 per query:
#   same-DRA bucket:  WIT_PIECE (same-piece table won) or WIT_VIA_AGENT
#   cross buckets:    x * (S+1) + y — the winning SUPER boundary pair —
#                     or WIT_LOCAL (intra-fragment path won)
#   any bucket:       WIT_NONE when the distance is +inf
# The host-side PathUnwinder (paths.py) turns (s, t, wit) into a node
# sequence by walking frag_next / piece_next / super_next.
# ---------------------------------------------------------------------------
WIT_NONE = -1       # unreachable; nothing to unwind
WIT_LOCAL = -2      # case 2, intra-fragment path beat the SUPER combine
WIT_VIA_AGENT = 0   # case 1, s -> agent -> t
WIT_PIECE = 1       # case 1, same-piece direct path


def _same_dra_dist(dix: DeviceIndex, s, t, ds, dt):
    """Case 1: same agent.  Same piece -> one flat gather; else via
    agent.  The flat layout replaces the per-bucket Python loop with a
    single padded gather over piece_flat."""
    gid_s = dix.piece_gid[s]
    same_piece = (gid_s >= 0) & (gid_s == dix.piece_gid[t])
    d_via_agent = ds + dt
    idx = (dix.piece_base[s]
           + dix.pos_in_piece[s] * dix.piece_stride[s]
           + dix.pos_in_piece[t])
    d_piece = dix.piece_flat[jnp.where(same_piece, idx, 0)]
    return jnp.where(same_piece, jnp.minimum(d_piece, d_via_agent),
                     d_via_agent)


def _overlay_size(dix: DeviceIndex) -> int:
    """S + 1: the witness packing stride and the sentinel super id + 1.
    Hierarchical indices carry it as sf_of's length (their d_super is a
    [1, 1] dummy); dense indices as d_super's side."""
    return (dix.sf_of.shape[0] if dix.sf_of.shape[0] > 1
            else dix.d_super.shape[0])


def _lift_l2(dix: DeviceIndex, row, sf, p2):
    """Lift a fragment-boundary row to the level-2 boundary set:
    r2[q, c] = min over slots (i, j) with bnd2_sid == c of
    row[q, i] + l2row[sf_i, p2_i, j] — the hierarchical analog of the
    dense path's scatter into SUPER coordinates.  Chunked over the
    boundary axis so the gathered block stays [q, 8, mb2] (mb2 can be
    hundreds at road64k scale; the full [q, mb, mb2] cube would be
    hundreds of MB per batch)."""
    q, mb = row.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8
    s2p1 = dix.d2.shape[0]
    qi = jnp.arange(q, dtype=jnp.int32)[:, None, None]

    def body(i, r2):
        row_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        sf_c = jax.lax.dynamic_slice_in_dim(sf, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(p2, i * c, c, axis=1)
        l2_c = dix.l2row[sf_c, p_c]              # [q, c, mb2]
        sid_c = dix.bnd2_sid[sf_c]
        return r2.at[qi, sid_c].min(row_c[:, :, None] + l2_c)

    return jax.lax.fori_loop(0, mb // c, body,
                             jnp.full((q, s2p1), INF, row.dtype))


def _l2_src_of(dix: DeviceIndex, row, b, sf, p2, wc):
    """Witness recovery for the level-2 leg: the level-1 super id whose
    lifted contribution achieved r2[q, wc[q]] (same chunked schedule
    as _lift_l2, carrying a running argmin; exact f32 re-comparison)."""
    q, mb = row.shape
    c = min(8, mb)

    def body(i, carry):
        best, besti = carry
        row_c = jax.lax.dynamic_slice_in_dim(row, i * c, c, axis=1)
        sf_c = jax.lax.dynamic_slice_in_dim(sf, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(p2, i * c, c, axis=1)
        l2_c = dix.l2row[sf_c, p_c]
        sid_c = dix.bnd2_sid[sf_c]
        m = sid_c == wc[:, None, None]
        contrib = jnp.min(jnp.where(m, row_c[:, :, None] + l2_c, INF),
                          axis=2)                # [q, c]
        cmin = jnp.min(contrib, axis=1)
        loc = jnp.argmin(contrib, axis=1).astype(jnp.int32)
        better = cmin < best
        return (jnp.where(better, cmin, best),
                jnp.where(better, i * c + loc, besti))

    _best, besti = jax.lax.fori_loop(
        0, mb // c, body,
        (jnp.full((q,), INF, row.dtype), jnp.zeros((q,), jnp.int32)))
    return jnp.take_along_axis(b, besti[:, None], axis=1)[:, 0]


def _combine_mid_h(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                   force=None):
    """Hierarchical combine (hierarchy_levels=2, DESIGN.md §12):

      mid = min_{x,y} row_s[x] + OD(x, y) + row_t[y],
      OD(x, y) = min( sf_closure[sf, x, y]  if sf(x) == sf(y),
                      min_{a,b} l2row[x,a] + D2[a,b] + l2row[y,b] )

    computed as (a) a b1-chunked same-super-fragment gather (peak
    intermediate [q, 8, mb], same schedule as the dense CPU path) plus
    (b) a level-2 lift of both rows contracted by the SAME fused
    minplus_twoside kernel the dense path uses — just against the
    small [S2+1, S2+1] closure instead of [S+1, S+1].
    """
    sfs, p2s = dix.sf_of[bs], dix.pos_in_sf[bs]
    sft, p2t = dix.sf_of[bt], dix.pos_in_sf[bt]
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        sf_c = jax.lax.dynamic_slice_in_dim(sfs, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(p2s, i * c, c, axis=1)
        blk = dix.sf_closure[sf_c[:, :, None], p_c[:, :, None],
                             p2t[:, None, :]]            # [q, c, mb]
        same = sf_c[:, :, None] == sft[:, None, :]
        cand = jnp.min(jnp.where(same, r_c[:, :, None] + blk, INF),
                       axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, mb // c, body,
                            jnp.full((q, mb), INF, row_s.dtype))
    va = jnp.min(tmp + row_t, axis=1)
    rs2 = _lift_l2(dix, row_s, sfs, p2s)
    rt2 = _lift_l2(dix, row_t, sft, p2t)
    vb = ops.minplus_twoside(rs2, dix.d2, rt2, force=force)
    return jnp.minimum(va, vb)


def _combine_mid_h_w(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                     force=None):
    """Witness variant of _combine_mid_h -> (mid, wx, wy): the winning
    level-1 SUPER pair under the hierarchical overlay metric.  The
    same-super-fragment leg carries its argmin like the dense CPU
    schedule; the level-2 leg gets the winning boundary pair (c, d)
    from the fused argmin kernel and resolves it back to level-1 ids
    by re-finding, per side, the row entry whose lift achieved
    rs2[c] / rt2[d] (an O(q * mb) masked argmin — exact because the
    lift is a min of f32 sums re-comparable bit-for-bit).
    """
    sfs, p2s = dix.sf_of[bs], dix.pos_in_sf[bs]
    sft, p2t = dix.sf_of[bt], dix.pos_in_sf[bt]
    q, mb = row_s.shape
    c = min(8, mb)

    def body(i, carry):
        acc, accb = carry
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        sf_c = jax.lax.dynamic_slice_in_dim(sfs, i * c, c, axis=1)
        p_c = jax.lax.dynamic_slice_in_dim(p2s, i * c, c, axis=1)
        blk = dix.sf_closure[sf_c[:, :, None], p_c[:, :, None],
                             p2t[:, None, :]]
        same = sf_c[:, :, None] == sft[:, None, :]
        cube = jnp.where(same, r_c[:, :, None] + blk, INF)
        cand = jnp.min(cube, axis=1)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(
            hit, jax.lax.broadcasted_iota(jnp.int32, cube.shape, 1),
            jnp.int32(mb)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * c + loc, accb))

    acc0 = jnp.full((q, mb), INF, row_s.dtype)
    accb0 = jnp.full((q, mb), -1, jnp.int32)
    acc, accb = jax.lax.fori_loop(0, mb // c, body, (acc0, accb0))
    tmp = acc + row_t
    va = jnp.min(tmp, axis=1)
    hit = tmp == va[:, None]
    pos_t = jnp.min(jnp.where(
        hit, jnp.arange(mb, dtype=jnp.int32)[None, :], jnp.int32(mb)),
        axis=1)
    pos_t_c = jnp.clip(pos_t, 0, mb - 1)
    pos_s = jnp.take_along_axis(accb, pos_t_c[:, None], axis=1)[:, 0]
    xa = jnp.take_along_axis(
        bs, jnp.clip(pos_s, 0, mb - 1)[:, None], axis=1)[:, 0]
    ya = jnp.take_along_axis(bt, pos_t_c[:, None], axis=1)[:, 0]

    rs2 = _lift_l2(dix, row_s, sfs, p2s)
    rt2 = _lift_l2(dix, row_t, sft, p2t)
    vb, wc, wd = ops.minplus_twoside_argmin(rs2, dix.d2, rt2,
                                            force=force)
    xb = _l2_src_of(dix, row_s, bs, sfs, p2s, wc)
    yb = _l2_src_of(dix, row_t, bt, sft, p2t, wd)

    use_a = va <= vb
    mid = jnp.minimum(va, vb)
    fin = jnp.isfinite(mid)
    wx = jnp.where(fin, jnp.where(use_a, xa, xb), -1)
    wy = jnp.where(fin, jnp.where(use_a, ya, yb), -1)
    return mid, wx, wy


def _combine_mid(dix: DeviceIndex, row_s, bs, row_t, bt, *, force=None):
    """combine = min_{b1,b2} row_s[b1] + D_super[bs[b1], bt[b2]]
    + row_t[b2] without a [q, mb, mb] intermediate.

    Hierarchical indices (sf_of longer than the [1] dummy — a static
    trace-time shape fact) route to _combine_mid_h.  Dense indices:
    TPU: scatter-min the boundary rows into SUPER coordinates (one
    O(q*mb) scatter each) and run the fused two-sided tropical kernel
    against the resident D_super.  CPU/ref: chunk the b1 axis so the
    gathered block never exceeds [q, 8, mb].
    """
    if dix.sf_of.shape[0] > 1:
        return _combine_mid_h(dix, row_s, bs, row_t, bt, force=force)
    if ops.use_pallas(force):
        s1 = dix.d_super.shape[0]
        q = row_s.shape[0]
        qi = jnp.arange(q, dtype=jnp.int32)[:, None]
        rs = jnp.full((q, s1), INF, row_s.dtype).at[qi, bs].min(row_s)
        rt = jnp.full((q, s1), INF, row_t.dtype).at[qi, bt].min(row_t)
        return ops.minplus_twoside(rs, dix.d_super, rt, force=force)
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(bs, i * c, c, axis=1)
        blk = dix.d_super[b_c[:, :, None], bt[:, None, :]]  # [q, c, mb]
        cand = jnp.min(r_c[:, :, None] + blk, axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, mb // c, body,
                            jnp.full((q, mb), INF, row_s.dtype))
    return jnp.min(tmp + row_t, axis=1)


def _combine_mid_w(dix: DeviceIndex, row_s, bs, row_t, bt, *,
                   force=None):
    """Witness variant of _combine_mid -> (mid, wx, wy) where (wx, wy)
    is the winning SUPER boundary pair in super ids (-1 when mid is
    +inf).  Same two layouts as the distance path: fused argmin kernel
    against the scattered rows on TPU, b1-chunked gather on CPU;
    hierarchical indices route to _combine_mid_h_w."""
    if dix.sf_of.shape[0] > 1:
        return _combine_mid_h_w(dix, row_s, bs, row_t, bt, force=force)
    if ops.use_pallas(force):
        s1 = dix.d_super.shape[0]
        q = row_s.shape[0]
        qi = jnp.arange(q, dtype=jnp.int32)[:, None]
        rs = jnp.full((q, s1), INF, row_s.dtype).at[qi, bs].min(row_s)
        rt = jnp.full((q, s1), INF, row_t.dtype).at[qi, bt].min(row_t)
        return ops.minplus_twoside_argmin(rs, dix.d_super, rt,
                                          force=force)
    q, mb = row_s.shape
    c = min(8, mb)                       # mb is padded to a multiple of 8

    def body(i, carry):
        acc, accb = carry
        r_c = jax.lax.dynamic_slice_in_dim(row_s, i * c, c, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(bs, i * c, c, axis=1)
        blk = dix.d_super[b_c[:, :, None], bt[:, None, :]]  # [q, c, mb]
        cube = r_c[:, :, None] + blk
        cand = jnp.min(cube, axis=1)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(
            hit, jax.lax.broadcasted_iota(jnp.int32, cube.shape, 1),
            jnp.int32(mb)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * c + loc, accb))

    acc0 = jnp.full((q, mb), INF, row_s.dtype)
    accb0 = jnp.full((q, mb), -1, jnp.int32)
    acc, accb = jax.lax.fori_loop(0, mb // c, body, (acc0, accb0))
    tmp = acc + row_t                    # [q, mb]
    mid = jnp.min(tmp, axis=1)
    hit = tmp == mid[:, None]
    pos_t = jnp.min(jnp.where(
        hit, jnp.arange(mb, dtype=jnp.int32)[None, :], jnp.int32(mb)),
        axis=1)
    pos_t_c = jnp.clip(pos_t, 0, mb - 1)
    pos_s = jnp.take_along_axis(accb, pos_t_c[:, None], axis=1)[:, 0]
    fin = jnp.isfinite(mid)
    wx = jnp.where(fin, jnp.take_along_axis(
        bs, jnp.clip(pos_s, 0, mb - 1)[:, None], axis=1)[:, 0], -1)
    wy = jnp.where(fin, jnp.take_along_axis(
        bt, pos_t_c[:, None], axis=1)[:, 0], -1)
    return mid, wx, wy


def serve_same_dra(dix: DeviceIndex, s: jax.Array,
                   t: jax.Array) -> jax.Array:
    """Planner bucket 1: both endpoints in the same DRA."""
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    out = _same_dra_dist(dix, s, t, ds, dt)
    return jnp.where(s == t, 0.0, out)


def serve_same_dra_w(dix: DeviceIndex, s: jax.Array, t: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """serve_same_dra in return_witness mode -> (dist, wit) with wit in
    {WIT_PIECE, WIT_VIA_AGENT, WIT_NONE}."""
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    gid_s = dix.piece_gid[s]
    same_piece = (gid_s >= 0) & (gid_s == dix.piece_gid[t])
    d_via_agent = ds + dt
    idx = (dix.piece_base[s]
           + dix.pos_in_piece[s] * dix.piece_stride[s]
           + dix.pos_in_piece[t])
    d_piece = dix.piece_flat[jnp.where(same_piece, idx, 0)]
    out = jnp.where(same_piece, jnp.minimum(d_piece, d_via_agent),
                    d_via_agent)
    wit = jnp.where(same_piece & (d_piece <= d_via_agent),
                    WIT_PIECE, WIT_VIA_AGENT)
    out = jnp.where(s == t, 0.0, out)
    wit = jnp.where(jnp.isfinite(out), wit, WIT_NONE)
    return out, wit.astype(jnp.int32)


def serve_cross(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                with_local: bool, force=None) -> jax.Array:
    """Planner buckets 2/3: endpoints in different DRAs.  with_local
    folds in the intra-fragment distance (same-fragment bucket only,
    so the cross-fragment program skips that gather entirely)."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    mid = _combine_mid(dix, row_s, dix.bnd_super[fs], row_t,
                       dix.bnd_super[ft], force=force)
    if with_local:
        mid = jnp.minimum(mid, jnp.where(fs == ft,
                                         dix.frag_apsp[fs, ps, pt], INF))
    d = ds + mid + dt
    return jnp.where((fs >= 0) & (ft >= 0), d, INF)


def serve_cross_w(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                  with_local: bool, force=None
                  ) -> tuple[jax.Array, jax.Array]:
    """serve_cross in return_witness mode -> (dist, wit): wit is the
    packed winning SUPER pair x * (S+1) + y, WIT_LOCAL when the
    intra-fragment path won (same-fragment bucket only), WIT_NONE when
    unreachable."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s = dix.brow[fs, ps]                     # [q, mb]
    row_t = dix.brow[ft, pt]
    mid, wx, wy = _combine_mid_w(dix, row_s, dix.bnd_super[fs], row_t,
                                 dix.bnd_super[ft], force=force)
    s1 = _overlay_size(dix)
    wit = wx * s1 + wy
    if with_local:
        local = jnp.where(fs == ft, dix.frag_apsp[fs, ps, pt], INF)
        wit = jnp.where(local <= mid, WIT_LOCAL, wit)
        mid = jnp.minimum(mid, local)
    d = ds + mid + dt
    d = jnp.where((fs >= 0) & (ft >= 0), d, INF)
    wit = jnp.where(jnp.isfinite(d), wit, WIT_NONE)
    return d, wit.astype(jnp.int32)


def serve_step(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
               force=None) -> jax.Array:
    """Batched exact distance queries: s, t int32 [q] -> f32 [q].

    The monolithic program (every case in one jit); the query planner
    in dist_engine.py runs the per-case programs instead.
    """
    us, ut = dix.agent_of[s], dix.agent_of[t]
    d_cross = serve_cross(dix, s, t, with_local=True, force=force)
    d_same = serve_same_dra(dix, s, t)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(s == t, 0.0, out)


def serve_step_w(dix: DeviceIndex, s: jax.Array, t: jax.Array, *,
                 force=None) -> tuple[jax.Array, jax.Array]:
    """serve_step in return_witness mode -> (dist, wit).

    The witness namespace is per-case (same-DRA flags vs packed SUPER
    pairs); the host unwinder re-derives the case from agent_of, so no
    case bits are spent in the witness itself.
    """
    us, ut = dix.agent_of[s], dix.agent_of[t]
    d_cross, w_cross = serve_cross_w(dix, s, t, with_local=True,
                                     force=force)
    d_same, w_same = serve_same_dra_w(dix, s, t)
    same = us == ut
    out = jnp.where(same, d_same, d_cross)
    wit = jnp.where(same, w_same, w_cross)
    return jnp.where(s == t, 0.0, out), wit


def _overlay_row_h(dix: DeviceIndex, rs: jax.Array, *,
                   force=None) -> jax.Array:
    """Exact overlay distances from a scattered source row rs [S+1] to
    EVERY overlay node, through the hierarchy: per-super-fragment
    (min,+) against the resident closures for the within-sf leg, one
    small vector (x) matrix product against D2 for the cross leg."""
    members = dix.sf_members                     # [nsf+1, m2] (S = pad)
    r = rs[members]                              # [nsf+1, m2]
    within = jnp.min(r[:, :, None] + dix.sf_closure, axis=1)
    lift = jnp.min(r[:, :, None] + dix.l2row, axis=1)   # [nsf+1, mb2]
    s2p1 = dix.d2.shape[0]
    rs2 = jnp.full((s2p1,), INF, rs.dtype).at[dix.bnd2_sid].min(lift)
    z2 = ops.minplus(rs2[None, :], dix.d2, force=force)[0]  # [S2+1]
    back = z2[dix.bnd2_sid]                      # [nsf+1, mb2]
    via = jnp.min(dix.l2row + back[:, None, :], axis=2)
    out = jnp.minimum(within, via)               # [nsf+1, m2]
    return jnp.full(rs.shape, INF, rs.dtype).at[members].min(out)


def serve_one_to_all(dix: DeviceIndex, s: int | jax.Array, *,
                     force=None) -> jax.Array:
    """Exact distances from one source to EVERY node: [n].

    The bulk/retrieval pattern: scatter the source boundary row into
    SUPER coordinates, one vector-matrix (min,+) product against the
    SUPER matrix (Pallas kernel on TPU), then a per-node gather
    combine.  Used by the retrieval-style benchmarks.
    """
    s = jnp.asarray(s, jnp.int32).reshape(())
    n = dix.agent_of.shape[0]
    us = dix.agent_of[s]
    ds = dix.dist_to_agent[s]
    fs = dix.frag_of[us]
    ps = dix.pos_in_frag[us]
    row_s = dix.brow[fs, ps]                             # [mb]
    bs = dix.bnd_super[fs]                               # [mb]
    s1 = _overlay_size(dix)
    rs = jnp.full((s1,), INF, row_s.dtype).at[bs].min(row_s)
    # u_s -> every super node (vector (x) matrix min-plus; the
    # hierarchical overlay runs it per level)
    if dix.sf_of.shape[0] > 1:
        x = _overlay_row_h(dix, rs, force=force)                # [S+1]
    else:
        x = ops.minplus(rs[None, :], dix.d_super, force=force)[0]
    # per-target combine (sentinel slots hit the +inf row of d_super)
    tt = jnp.arange(n, dtype=jnp.int32)
    ut = dix.agent_of[tt]
    dt = dix.dist_to_agent[tt]
    ft = dix.frag_of[ut]
    ptv = dix.pos_in_frag[ut]
    row_t = dix.brow[ft, ptv]                            # [n, mb]
    mid = jnp.min(x[dix.bnd_super[ft]] + row_t, axis=1)  # [n]
    local = jnp.where(ft == fs, dix.frag_apsp[ft, ps, ptv], INF)
    d_cross = ds + jnp.minimum(mid, local) + dt
    d_cross = jnp.where((fs >= 0) & (ft >= 0), d_cross, INF)
    d_same = _same_dra_dist(dix, jnp.broadcast_to(s, tt.shape), tt,
                            jnp.broadcast_to(ds, dt.shape), dt)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(tt == s, 0.0, out)
