"""Device (TPU) DISLAND engine: fixed-shape batched query answering.

Hardware adaptation of the paper's per-query Dijkstra (DESIGN.md §2):
every query path becomes gathers + (min,+) algebra over padded tensors.

Offline (build_device_index, device-resident products):
  * per-fragment dense APSP        [k, maxf, maxf]   (Pallas blocked FW)
  * SUPER boundary x boundary APSP [S+1, S+1]        (batched BF / FW)
  * per-piece APSP, size-bucketed  [P_b, mp_b, mp_b] (Pallas batched FW)
  * per-node lookup vectors        agent/fragment/piece ids + positions

Online (serve_step — one jitted program per query batch):
  dist(s,t) = same-DRA answer                                (case 1)
            | d(s,u_s) + min(local, min-plus combine) + d(u_t,t)  (case 2)
  combine = min_{b1,b2} row_s[b1] + D_super[b1,b2] + row_t[b2].

Everything is exact (validated against the host engine).
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import sssp
from .supergraph import DislandIndex

INF = np.float32(np.inf)
PIECE_BUCKETS = (8, 32, 128, 512, 2048)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceIndex:
    # per-node lookups [n]
    agent_of: jax.Array          # int32
    dist_to_agent: jax.Array     # f32
    frag_of: jax.Array           # int32 (fragment of each *shrink* node)
    pos_in_frag: jax.Array       # int32
    piece_bucket: jax.Array      # int32 (-1 for non-represented)
    piece_idx: jax.Array         # int32 index within bucket
    pos_in_piece: jax.Array      # int32
    # fragments
    frag_apsp: jax.Array         # f32 [k, maxf, maxf]
    bpos: jax.Array              # int32 [k, mb] boundary position in frag
    bvalid: jax.Array            # bool [k, mb]
    bnd_super: jax.Array         # int32 [k, mb] super id (S = sentinel)
    # super graph
    d_super: jax.Array           # f32 [S+1, S+1] (+inf sentinel row/col)
    # pieces (one APSP tensor per size bucket)
    piece_apsp: List[jax.Array]  # f32 [P_b, mp_b, mp_b]

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        children = tuple(getattr(self, f.name) for f in fields)
        return children, tuple(f.name for f in fields)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(**dict(zip(names, children)))


# ---------------------------------------------------------------------------
def _pad_to(x: int, mult: int = 8) -> int:
    return max(mult, -(-x // mult) * mult)


def build_device_index(ix: DislandIndex, *, force=None) -> DeviceIndex:
    """Assemble padded tensors on host, run device APSP preprocessing."""
    g = ix.g
    n = g.n
    k = len(ix.fragments)

    agent_of = ix.dras.agent_of.astype(np.int32)
    dist_to_agent = ix.dras.dist_to_agent.astype(np.float32)

    # ---- fragments ------------------------------------------------------
    maxf = _pad_to(max((f.graph.n for f in ix.fragments), default=1))
    mb = _pad_to(max((f.boundary_local.size for f in ix.fragments),
                     default=1))
    frag_adj = np.full((k, maxf, maxf), INF, dtype=np.float32)
    frag_of = -np.ones(n, dtype=np.int32)
    pos_in_frag = np.zeros(n, dtype=np.int32)
    bpos = np.zeros((k, mb), dtype=np.int32)
    bvalid = np.zeros((k, mb), dtype=bool)
    S = ix.super_graph.node_ids.size
    bnd_super = np.full((k, mb), S, dtype=np.int32)
    super_id_of = -np.ones(n, dtype=np.int64)
    super_id_of[ix.super_graph.node_ids] = np.arange(S)
    for fi, f in enumerate(ix.fragments):
        fg = f.graph
        frag_of[f.nodes] = fi
        pos_in_frag[f.nodes] = np.arange(f.nodes.size)
        frag_adj[fi, fg.edge_u, fg.edge_v] = fg.edge_w.astype(np.float32)
        frag_adj[fi, fg.edge_v, fg.edge_u] = fg.edge_w.astype(np.float32)
        nb = f.boundary_local.size
        bpos[fi, :nb] = f.boundary_local
        bvalid[fi, :nb] = True
        bnd_super[fi, :nb] = super_id_of[f.nodes[f.boundary_local]]
    frag_apsp = ops.fw_batch(jnp.asarray(frag_adj), force=force)

    # ---- SUPER graph APSP (batched BF over the sparse edge list) --------
    sg = ix.super_graph.graph
    if S > 0 and sg.m > 0:
        src = np.concatenate([sg.edge_u, sg.edge_v]).astype(np.int32)
        dst = np.concatenate([sg.edge_v, sg.edge_u]).astype(np.int32)
        w = np.concatenate([sg.edge_w, sg.edge_w]).astype(np.float32)
        d_s = sssp.apsp_from_sources(jnp.asarray(src), jnp.asarray(dst),
                                     jnp.asarray(w),
                                     jnp.arange(S, dtype=jnp.int32), n=S)
        d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)
        d_super = d_super.at[:S, :S].set(d_s)
    else:
        d_super = jnp.full((S + 1, S + 1), INF, jnp.float32)

    # ---- pieces, bucketed by padded size ---------------------------------
    piece_bucket = -np.ones(n, dtype=np.int32)
    piece_idx = np.zeros(n, dtype=np.int32)
    pos_in_piece = np.zeros(n, dtype=np.int32)
    bucket_adjs: List[List[np.ndarray]] = [[] for _ in PIECE_BUCKETS]
    for a in ix.dras.agents:
        for piece in a.pieces:
            sz = piece.size
            b = next(i for i, cap in enumerate(PIECE_BUCKETS) if sz <= cap)
            cap = PIECE_BUCKETS[b]
            sub, ids = g.subgraph(piece)
            adj = np.full((cap, cap), INF, dtype=np.float32)
            adj[sub.edge_u, sub.edge_v] = sub.edge_w.astype(np.float32)
            adj[sub.edge_v, sub.edge_u] = sub.edge_w.astype(np.float32)
            pi = len(bucket_adjs[b])
            bucket_adjs[b].append(adj)
            # the agent belongs to many pieces: leave its lookup at -1 so
            # case-1 logic falls through to the exact ds+dt formula
            inner = ids != a.agent
            piece_bucket[ids[inner]] = b
            piece_idx[ids[inner]] = pi
            pos_in_piece[ids[inner]] = np.nonzero(inner)[0]
    piece_apsp: List[jax.Array] = []
    for b, adjs in enumerate(bucket_adjs):
        if adjs:
            piece_apsp.append(ops.fw_batch(jnp.asarray(np.stack(adjs)),
                                           force=force))
        else:
            # empty bucket: minimal inf dummy (never hit at query time)
            piece_apsp.append(jnp.full((1, 1, 1), INF, jnp.float32))

    return DeviceIndex(
        agent_of=jnp.asarray(agent_of),
        dist_to_agent=jnp.asarray(dist_to_agent),
        frag_of=jnp.asarray(frag_of),
        pos_in_frag=jnp.asarray(pos_in_frag),
        piece_bucket=jnp.asarray(piece_bucket),
        piece_idx=jnp.asarray(piece_idx),
        pos_in_piece=jnp.asarray(pos_in_piece),
        frag_apsp=frag_apsp,
        bpos=jnp.asarray(bpos),
        bvalid=jnp.asarray(bvalid),
        bnd_super=jnp.asarray(bnd_super),
        d_super=d_super,
        piece_apsp=piece_apsp,
    )


# ---------------------------------------------------------------------------
def _same_dra_dist(dix: DeviceIndex, s, t, ds, dt):
    """Case 1: same agent.  Same piece -> piece APSP; else via agent."""
    pb_s, pb_t = dix.piece_bucket[s], dix.piece_bucket[t]
    same_piece = ((pb_s == pb_t) & (pb_s >= 0)
                  & (dix.piece_idx[s] == dix.piece_idx[t]))
    d_via_agent = ds + dt
    out = d_via_agent
    for b, apsp in enumerate(dix.piece_apsp):
        hit = same_piece & (pb_s == b)
        d_b = apsp[dix.piece_idx[s], dix.pos_in_piece[s],
                   dix.pos_in_piece[t]]
        out = jnp.where(hit, jnp.minimum(d_b, d_via_agent), out)
    return out


def serve_step(dix: DeviceIndex, s: jax.Array, t: jax.Array) -> jax.Array:
    """Batched exact distance queries: s, t int32 [q] -> f32 [q]."""
    us, ut = dix.agent_of[s], dix.agent_of[t]
    ds, dt = dix.dist_to_agent[s], dix.dist_to_agent[t]
    # ---- case 2: cross-DRA --------------------------------------------
    fs, ft = dix.frag_of[us], dix.frag_of[ut]
    ps, pt = dix.pos_in_frag[us], dix.pos_in_frag[ut]
    row_s_full = dix.frag_apsp[fs, ps]          # [q, maxf]
    row_t_full = dix.frag_apsp[ft, pt]
    row_s = jnp.take_along_axis(row_s_full, dix.bpos[fs], axis=1)
    row_t = jnp.take_along_axis(row_t_full, dix.bpos[ft], axis=1)
    row_s = jnp.where(dix.bvalid[fs], row_s, INF)   # [q, mb]
    row_t = jnp.where(dix.bvalid[ft], row_t, INF)
    bs = dix.bnd_super[fs]                      # [q, mb]
    bt = dix.bnd_super[ft]
    blk = dix.d_super[bs[:, :, None], bt[:, None, :]]   # [q, mb, mb]
    tmp = jnp.min(row_s[:, :, None] + blk, axis=1)      # [q, mb]
    mid = jnp.min(tmp + row_t, axis=1)                  # [q]
    local = jnp.where(fs == ft,
                      dix.frag_apsp[fs, ps, pt], INF)
    d_cross = ds + jnp.minimum(mid, local) + dt
    valid_frag = (fs >= 0) & (ft >= 0)
    d_cross = jnp.where(valid_frag, d_cross, INF)
    # ---- case 1: same DRA ----------------------------------------------
    d_same = _same_dra_dist(dix, s, t, ds, dt)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(s == t, 0.0, out)


def serve_one_to_all(dix: DeviceIndex, s: int | jax.Array) -> jax.Array:
    """Exact distances from one source to EVERY node: [n].

    The bulk/retrieval pattern: one vector-matrix (min,+) product against
    the SUPER matrix (Pallas kernel on TPU) then a per-node gather
    combine.  Used by the retrieval-style benchmarks.
    """
    s = jnp.asarray(s, jnp.int32).reshape(())
    n = dix.agent_of.shape[0]
    us = dix.agent_of[s]
    ds = dix.dist_to_agent[s]
    fs = dix.frag_of[us]
    ps = dix.pos_in_frag[us]
    row_s = jnp.take(dix.frag_apsp[fs, ps], dix.bpos[fs])
    row_s = jnp.where(dix.bvalid[fs], row_s, INF)       # [mb]
    bs = dix.bnd_super[fs]                               # [mb]
    d_sub = dix.d_super[bs, :]                           # [mb, S+1]
    # u_s -> every super node (vector (x) matrix min-plus)
    x = ops.minplus(row_s[None, :], d_sub)[0]            # [S+1]
    x = jnp.append(x, INF)                               # sentinel slot
    # per-target combine
    tt = jnp.arange(n, dtype=jnp.int32)
    ut = dix.agent_of[tt]
    dt = dix.dist_to_agent[tt]
    ft = dix.frag_of[ut]
    ptv = dix.pos_in_frag[ut]
    row_t = jnp.take_along_axis(dix.frag_apsp[ft, ptv], dix.bpos[ft],
                                axis=1)
    row_t = jnp.where(dix.bvalid[ft], row_t, INF)        # [n, mb]
    bt = jnp.where(dix.bvalid[ft], dix.bnd_super[ft], x.shape[0] - 1)
    mid = jnp.min(x[bt] + row_t, axis=1)                 # [n]
    local = jnp.where(ft == fs, dix.frag_apsp[ft, ps, ptv], INF)
    d_cross = ds + jnp.minimum(mid, local) + dt
    d_cross = jnp.where((fs >= 0) & (ft >= 0), d_cross, INF)
    d_same = _same_dra_dist(dix, jnp.broadcast_to(s, tt.shape), tt,
                            jnp.broadcast_to(ds, dt.shape), dt)
    out = jnp.where(us == ut, d_same, d_cross)
    return jnp.where(tt == s, 0.0, out)
