"""Cut-nodes and bi-connected components (paper §II-A, §IV-C step 1).

Iterative Hopcroft-Tarjan articulation-point / BCC algorithm [6],[15].
Linear time O(n + m); iterative because road graphs have paths far deeper
than Python's recursion limit.

Outputs the pieces compDRAs needs:
  - ``cut``: bool[n] articulation-point mask
  - ``bcc_nodes``: list[np.ndarray] node sets per BCC (each undirected
    edge lands in exactly one BCC; a BCC is identified by its edge set,
    the node set is the union of the edge endpoints)

Role: the first host preprocessing pass (DESIGN.md §7).  Owned
invariants: the edge partition above, and cut-mask correctness —
removing a flagged node disconnects its component; removing an
unflagged one never does (property-tested in tests/test_bcc_agents).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .graph import Graph


@dataclasses.dataclass
class BCCResult:
    cut: np.ndarray                 # bool [n]
    bcc_nodes: List[np.ndarray]     # per-BCC sorted node ids
    n_bcc: int

    def bcc_sizes(self) -> np.ndarray:
        return np.array([b.size for b in self.bcc_nodes], dtype=np.int64)


def biconnected_components(g: Graph) -> BCCResult:
    """Iterative Tarjan BCC over the CSR adjacency.

    We walk directed CSR slots so each undirected edge {u,v} appears as
    two slots; a slot is a *tree or back edge* the first time its
    undirected pair is traversed, and is skipped on the reverse
    traversal (tracked with a visited-slot mask paired via ``pair``).
    """
    n = g.n
    indptr, indices = g.indptr, g.indices
    nslots = indices.size

    # pair[i] = CSR slot index of the reverse edge of slot i.
    # Build by sorting (min,max,occurrence) keys of both directions.
    src = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dst = indices.astype(np.int64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    order = np.lexsort((dst, lo, hi))  # groups the two slots of each edge
    pair = np.empty(nslots, dtype=np.int64)
    a = order[0::2]
    b = order[1::2]
    pair[a] = b
    pair[b] = a

    disc = -np.ones(n, dtype=np.int64)
    low = np.zeros(n, dtype=np.int64)
    cut = np.zeros(n, dtype=bool)
    slot_used = np.zeros(nslots, dtype=bool)  # traversed as tree/back edge
    timer = 0
    edge_stack: list[int] = []  # CSR slot ids of edges on the BCC stack
    bcc_nodes: List[np.ndarray] = []

    for root in range(n):
        if disc[root] >= 0:
            continue
        if indptr[root] == indptr[root + 1]:
            # isolated node forms its own (node-only) BCC
            disc[root] = timer
            timer += 1
            bcc_nodes.append(np.array([root], dtype=np.int32))
            continue
        disc[root] = low[root] = timer
        timer += 1
        root_children = 0
        # frames: [node, csr_cursor]
        stack = [[root, int(indptr[root])]]
        while stack:
            frame = stack[-1]
            u, cursor = frame
            if cursor < indptr[u + 1]:
                frame[1] = cursor + 1
                if slot_used[cursor] or slot_used[pair[cursor]]:
                    continue  # undirected edge already traversed
                v = int(indices[cursor])
                if disc[v] < 0:
                    slot_used[cursor] = True
                    edge_stack.append(cursor)
                    disc[v] = low[v] = timer
                    timer += 1
                    if u == root:
                        root_children += 1
                    stack.append([v, int(indptr[v])])
                elif disc[v] < disc[u]:
                    # back edge u -> ancestor v
                    slot_used[cursor] = True
                    edge_stack.append(cursor)
                    if disc[v] < low[u]:
                        low[u] = disc[v]
            else:
                stack.pop()
                if stack:
                    p = stack[-1][0]
                    if low[u] < low[p]:
                        low[p] = low[u]
                    if low[u] >= disc[p]:
                        # pop the BCC: everything above and including the
                        # tree edge (p, u) belongs to it
                        comp: set[int] = set()
                        while edge_stack:
                            s = edge_stack.pop()
                            a_, b_ = int(src[s]), int(dst[s])
                            comp.add(a_)
                            comp.add(b_)
                            if a_ == p and b_ == u:
                                break
                        if comp:
                            bcc_nodes.append(
                                np.array(sorted(comp), dtype=np.int32))
                        if p != root:
                            cut[p] = True
        if root_children >= 2:
            cut[root] = True
    return BCCResult(cut=cut, bcc_nodes=bcc_nodes, n_bcc=len(bcc_nodes))
