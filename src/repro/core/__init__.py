"""DISLAND core: preprocessing, index builds, and query engines.

The package splits along the paper's host/device boundary (DESIGN.md
§1): host-side one-shot preprocessing (``bcc``/``agents``/``partition``
/``landmarks``/``supergraph``), host reference engines and baselines
(``engine``/``dijkstra``/``ch``/``arcflags``/``agent_wrap``), and the
device-resident reformulation (``device_engine``/``dist_engine``/
``hierarchy``/``sssp``/``paths``/``refresh_pipeline``) that serves
batched queries as (min,+) algebra over padded tensors.

The one invariant everything here answers to: every device-served
distance equals the host float64 Dijkstra oracle exactly — integer
edge weights keep all f32 sums below 2**24, so "exactly" means ``==``,
not a tolerance (the differential tests enforce it that way).
"""
