"""Weighted undirected graph substrate.

CSR adjacency on the host (numpy) for the one-shot preprocessing passes
(BCC, BC-SKETCH, partitioning) plus a flat edge-list view that device-side
JAX numerics (batched Bellman-Ford, segment relaxation) consume directly.

All graphs are simple, undirected, positive-weighted, as in the paper
(Section II-A). Node ids are dense ints [0, n).

Owned invariant (DESIGN.md §6): every weight this module produces —
the ``road_like`` generator AND ``traffic_updates`` perturbations — is
a positive *integer*, small enough that any shortest-distance sum
stays below 2**24 and is therefore exactly representable in f32.  The
whole stack's bit-for-bit exactness story (serve == refresh == scratch
rebuild == host Dijkstra with ``==``, any (min,+) association order,
DESIGN.md §10/§15) rests on this one property; do not add a
float-weight source here without revisiting it.
"""
from __future__ import annotations

import dataclasses
from multiprocessing import shared_memory
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable undirected weighted graph in CSR form.

    ``indptr/indices/weights`` store each undirected edge twice (both
    directions), the standard adjacency-list representation the paper
    costs its Table I against. ``edge_u/edge_v/edge_w`` keep each
    undirected edge exactly once (u < v) for algorithms that iterate
    edges (vertex cover, partition coarsening, super-graph assembly).
    """

    n: int
    indptr: np.ndarray   # [n+1] int64
    indices: np.ndarray  # [2m] int32 neighbor ids
    weights: np.ndarray  # [2m] float64 edge weights
    edge_u: np.ndarray   # [m] int32, u < v
    edge_v: np.ndarray   # [m] int32
    edge_w: np.ndarray   # [m] float64

    # ---- constructors -------------------------------------------------
    @staticmethod
    def from_edges(n: int, u, v, w) -> "Graph":
        u = np.asarray(u, dtype=np.int32)
        v = np.asarray(v, dtype=np.int32)
        w = np.asarray(w, dtype=np.float64)
        if u.size:
            if (u == v).any():
                raise ValueError("self loops not allowed")
            if (w <= 0).any():
                raise ValueError("weights must be positive")
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        # dedupe parallel edges keeping the lightest
        order = np.lexsort((w, hi, lo))
        lo, hi, w = lo[order], hi[order], w[order]
        if lo.size:
            keep = np.ones(lo.size, dtype=bool)
            keep[1:] = (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])
            lo, hi, w = lo[keep], hi[keep], w[keep]
        m = lo.size
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        ww = np.concatenate([w, w])
        order = np.argsort(src, kind="stable")
        src, dst, ww = src[order], dst[order], ww[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return Graph(n=n, indptr=indptr, indices=dst.astype(np.int32),
                     weights=ww, edge_u=lo.astype(np.int32),
                     edge_v=hi.astype(np.int32), edge_w=w)

    # ---- basic accessors ---------------------------------------------
    @property
    def m(self) -> int:
        return self.edge_u.size

    def neighbors(self, u: int):
        s, e = self.indptr[u], self.indptr[u + 1]
        return self.indices[s:e], self.weights[s:e]

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def size_bytes(self) -> int:
        """Adjacency-list space cost, 4-byte ids/weights (paper Table I)."""
        return 4 * (self.n + 1) + 4 * self.indices.size * 2

    # ---- subgraphs ----------------------------------------------------
    def subgraph(self, nodes: Sequence[int]) -> tuple["Graph", np.ndarray]:
        """Induced subgraph G[nodes]; returns (graph, old_ids[new_id])."""
        nodes = np.unique(np.asarray(nodes, dtype=np.int64)).astype(np.int32)
        remap = -np.ones(self.n, dtype=np.int32)
        remap[nodes] = np.arange(nodes.size, dtype=np.int32)
        mask = (remap[self.edge_u] >= 0) & (remap[self.edge_v] >= 0)
        g = Graph.from_edges(nodes.size, remap[self.edge_u[mask]],
                             remap[self.edge_v[mask]], self.edge_w[mask])
        return g, nodes

    def extract_fragments(self, labels) -> List[Tuple["Graph", np.ndarray]]:
        """Batched ``subgraph`` for a complete partition of the nodes.

        ``labels[v]`` in [0, k) assigns every node to one fragment.
        Returns ``[(graph_i, old_ids_i)]`` for i in [0, k), each equal to
        ``self.subgraph(nonzero(labels == i))`` — one vectorized pass over
        the edge list instead of k O(m) masks, which is what keeps host
        fragment extraction linear when k ~ sqrt(n).  Equality holds
        because ``from_edges`` canonicalizes (lexsort + dedupe), so edge
        grouping order never leaks into the product.
        """
        labels = np.asarray(labels, dtype=np.int64)
        if labels.size != self.n:
            raise ValueError("labels must assign every node")
        k = int(labels.max()) + 1 if labels.size else 0
        if labels.size and labels.min() < 0:
            raise ValueError("labels must be a complete partition (>= 0)")
        # nodes per fragment, ascending within each (stable argsort)
        order = np.argsort(labels, kind="stable")
        counts = np.bincount(labels, minlength=k)
        starts = np.concatenate([[0], np.cumsum(counts)])
        local = np.empty(self.n, dtype=np.int32)
        local[order] = (np.arange(self.n, dtype=np.int64)
                        - starts[labels[order]]).astype(np.int32)
        # internal edges grouped by fragment
        el = labels[self.edge_u]
        internal = el == labels[self.edge_v]
        eu, ev = self.edge_u[internal], self.edge_v[internal]
        ew, el = self.edge_w[internal], el[internal]
        eorder = np.argsort(el, kind="stable")
        eu, ev, ew = eu[eorder], ev[eorder], ew[eorder]
        ecounts = np.bincount(el, minlength=k)
        estarts = np.concatenate([[0], np.cumsum(ecounts)])
        out: List[Tuple[Graph, np.ndarray]] = []
        for i in range(k):
            nodes = order[starts[i]:starts[i + 1]].astype(np.int32)
            es, ee = estarts[i], estarts[i + 1]
            fg = Graph.from_edges(nodes.size, local[eu[es:ee]],
                                  local[ev[es:ee]], ew[es:ee])
            out.append((fg, nodes))
        return out

    # ---- weight updates (live traffic; DESIGN.md §9) ------------------
    def edge_ids(self, u, v) -> np.ndarray:
        """Indices into ``edge_u/edge_v/edge_w`` for each (u, v) pair.

        Orientation-insensitive; returns -1 where no such edge exists.
        Vectorized (sorted-key binary search), so update batches stay
        O(b log m) on the host.
        """
        u = np.asarray(u, dtype=np.int64)
        v = np.asarray(v, dtype=np.int64)
        lo, hi = np.minimum(u, v), np.maximum(u, v)
        key = lo * self.n + hi
        # from_edges lexsorts by (lo, hi) and hi < n, so the edge keys
        # are already strictly ascending — searchsorted directly
        ekey = self.edge_u.astype(np.int64) * self.n + self.edge_v
        if ekey.size == 0:
            return np.full(key.shape, -1, dtype=np.int64)
        idx = np.clip(np.searchsorted(ekey, key), 0, ekey.size - 1)
        return np.where(ekey[idx] == key, idx, -1).astype(np.int64)

    def with_edge_weights(self, u, v, w) -> "Graph":
        """New Graph with the weights of existing edges (u, v) replaced.

        Topology is untouched — this is the live-traffic update primitive
        (DESIGN.md §9): edge orderings, CSR layout, and ids are all
        preserved, so downstream index structures built against this
        graph stay position-stable.  Raises on unknown edges or
        non-positive weights; duplicate updates to one edge keep the
        last value.
        """
        w = np.asarray(w, dtype=np.float64)
        if w.size and (w <= 0).any():
            raise ValueError("weights must be positive")
        idx = self.edge_ids(u, v)
        if (idx < 0).any():
            bad = np.nonzero(idx < 0)[0][:3]
            raise ValueError(
                f"no such edge(s): {[(int(np.asarray(u)[i]), int(np.asarray(v)[i])) for i in bad]}")
        edge_w = self.edge_w.copy()
        edge_w[idx] = w
        # CSR stores each edge twice; rebuild its weight view in place
        # using the same doubling + stable ordering as from_edges
        src = np.concatenate([self.edge_u, self.edge_v])
        ww = np.concatenate([edge_w, edge_w])
        order = np.argsort(src, kind="stable")
        return Graph(n=self.n, indptr=self.indptr, indices=self.indices,
                     weights=ww[order], edge_u=self.edge_u,
                     edge_v=self.edge_v, edge_w=edge_w)

    def connected_components(self) -> np.ndarray:
        """Label array [n] via iterative BFS (host, linear time)."""
        comp = -np.ones(self.n, dtype=np.int32)
        cur = 0
        for seed in range(self.n):
            if comp[seed] >= 0:
                continue
            stack = [seed]
            comp[seed] = cur
            while stack:
                x = stack.pop()
                s, e = self.indptr[x], self.indptr[x + 1]
                for y in self.indices[s:e]:
                    if comp[y] < 0:
                        comp[y] = cur
                        stack.append(int(y))
            cur += 1
        return comp

    def largest_component(self) -> "Graph":
        comp = self.connected_components()
        if comp.size == 0:
            return self
        big = np.bincount(comp).argmax()
        g, _ = self.subgraph(np.nonzero(comp == big)[0])
        return g

    # ---- shared-memory views (parallel host build; DESIGN.md §17) ------
    def to_shared(self) -> "SharedGraph":
        """Export all six CSR/edge arrays into one shared-memory block.

        Worker processes attach with ``Graph.from_shared(handle.meta)``
        and get zero-copy read-only views — nothing but the small
        ``meta`` dict ever crosses the pickle boundary.  The caller owns
        the block: call ``close()`` in every attached process and
        ``unlink()`` exactly once (the creator) when the build is done.
        """
        arrays = [self.indptr, self.indices, self.weights,
                  self.edge_u, self.edge_v, self.edge_w]
        offsets, total = [], 0
        for a in arrays:
            total = (total + 7) & ~7          # 8-byte alignment
            offsets.append(total)
            total += a.nbytes
        shm = shared_memory.SharedMemory(create=True, size=max(total, 1))
        for a, off in zip(arrays, offsets):
            view = np.ndarray(a.shape, dtype=a.dtype, buffer=shm.buf,
                              offset=off)
            view[:] = a
        meta = {
            "name": shm.name,
            "n": int(self.n),
            "shapes": [tuple(a.shape) for a in arrays],
            "dtypes": [str(a.dtype) for a in arrays],
            "offsets": offsets,
        }
        return SharedGraph(shm=shm, meta=meta)

    @staticmethod
    def from_shared(meta: dict) -> "SharedGraph":
        """Attach to a block exported by ``to_shared``; zero-copy views.

        The views are marked read-only: the shared CSR is a broadcast
        input, never a communication channel.  Keep the returned handle
        alive as long as ``handle.graph`` is in use (the buffer dies
        with it), and ``close()`` when done.
        """
        shm = shared_memory.SharedMemory(name=meta["name"])
        views = []
        for shape, dtype, off in zip(meta["shapes"], meta["dtypes"],
                                     meta["offsets"]):
            v = np.ndarray(shape, dtype=np.dtype(dtype), buffer=shm.buf,
                           offset=off)
            v.flags.writeable = False
            views.append(v)
        g = Graph(n=meta["n"], indptr=views[0], indices=views[1],
                  weights=views[2], edge_u=views[3], edge_v=views[4],
                  edge_w=views[5])
        return SharedGraph(shm=shm, meta=dict(meta), graph=g)


@dataclasses.dataclass
class SharedGraph:
    """Handle for a Graph living in a shared-memory block.

    ``meta`` is the picklable attach token (block name + array layout);
    ``graph`` is set on the attach side (``from_shared``).  Lifecycle:
    every process that holds the handle calls ``close()``; the creating
    process additionally calls ``unlink()`` once to free the block.
    """
    shm: shared_memory.SharedMemory
    meta: dict
    graph: "Graph | None" = None

    def close(self) -> None:
        try:
            self.shm.close()
        except BufferError:
            # numpy views still alive in this process; the block is
            # freed by unlink regardless, so this is not a leak
            pass

    def unlink(self) -> None:
        try:
            self.shm.unlink()
        except FileNotFoundError:
            pass


# ---- synthetic road-network generators --------------------------------
def road_like(n_target: int, seed: int = 0, *, highway_frac: float = 0.01,
              delete_frac: float = 0.35) -> Graph:
    """Synthetic road network (DIMACS stand-in; DESIGN.md §6).

    2D lattice with a fraction of edges deleted (dead ends, rivers) plus a
    few long-range 'highway' shortcuts. Produces avg degree ~2.4-3.0 and a
    cut-node-rich periphery, matching USA road-graph structure the paper
    exploits (many small BCCs + one big BCC core).
    """
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n_target))
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    nid = (ii * side + jj).astype(np.int32)
    # horizontal + vertical lattice edges
    us = [nid[:, :-1].ravel(), nid[:-1, :].ravel()]
    vs = [nid[:, 1:].ravel(), nid[1:, :].ravel()]
    u = np.concatenate(us)
    v = np.concatenate(vs)
    keep = rng.random(u.size) > delete_frac
    u, v = u[keep], v[keep]
    w = rng.integers(1, 1000, size=u.size).astype(np.float64)
    # long-range highways between random lattice points
    nh = max(1, int(highway_frac * n))
    hu = rng.integers(0, n, size=nh)
    hv = rng.integers(0, n, size=nh)
    ok = hu != hv
    hu, hv = hu[ok], hv[ok]
    hw = rng.integers(500, 5000, size=hu.size).astype(np.float64)
    g = Graph.from_edges(n, np.concatenate([u, hu]),
                         np.concatenate([v, hv]),
                         np.concatenate([w, hw]))
    return g.largest_component()


def traffic_updates(g: Graph, frac: float = 0.05, seed: int = 0, *,
                    localized: bool = True,
                    jam_frac: float = 0.5) -> tuple[np.ndarray, np.ndarray,
                                                    np.ndarray]:
    """Synthetic live-traffic weight-update batch: (u, v, new_w).

    Picks ``round(frac * m)`` distinct edges and rescales their weights:
    a ``jam_frac`` share jam (x2..x6) and the rest clear (/2../6), with
    integer outputs so f32 index arithmetic stays exact (the
    differential tests in tests/test_refresh.py rely on that).

    ``localized=True`` samples edges from a BFS ball around a random
    center instead of uniformly — traffic is spatially correlated, which
    is what keeps the dirty-fragment set small and the incremental
    refresh path (DESIGN.md §9) cheap.
    """
    rng = np.random.default_rng(seed)
    n_upd = max(1, int(round(frac * g.m)))
    if localized and g.m > n_upd:
        # grow a BFS ball until it touches enough incident edges
        center = int(rng.integers(0, g.n))
        in_ball = np.zeros(g.n, dtype=bool)
        in_ball[center] = True
        frontier = [center]
        picked = np.zeros(g.m, dtype=bool)
        while frontier and picked.sum() < n_upd:
            nxt = []
            for x in frontier:
                s, e = g.indptr[x], g.indptr[x + 1]
                for y in g.indices[s:e]:
                    if not in_ball[y]:
                        in_ball[y] = True
                        nxt.append(int(y))
            picked = in_ball[g.edge_u] & in_ball[g.edge_v]
            frontier = nxt
        cand = np.nonzero(picked)[0]
        if cand.size < n_upd:       # ball swallowed a whole component
            cand = np.arange(g.m)
    else:
        cand = np.arange(g.m)
    idx = rng.choice(cand, size=min(n_upd, cand.size), replace=False)
    jam = rng.random(idx.size) < jam_frac
    factor = np.where(jam, rng.integers(2, 7, idx.size),
                      1.0 / rng.integers(2, 7, idx.size))
    new_w = np.maximum(1, np.round(g.edge_w[idx] * factor)).astype(
        np.float64)
    return g.edge_u[idx].copy(), g.edge_v[idx].copy(), new_w


def random_graph(n: int, m: int, seed: int = 0, max_w: int = 100) -> Graph:
    """Erdos-Renyi-ish random connected-ish graph for property tests."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, size=m)
    v = rng.integers(0, n, size=m)
    ok = u != v
    u, v = u[ok], v[ok]
    w = rng.integers(1, max_w + 1, size=u.size).astype(np.float64)
    # chain to keep it connected
    cu = np.arange(n - 1)
    cv = cu + 1
    cw = rng.integers(1, max_w + 1, size=n - 1).astype(np.float64)
    return Graph.from_edges(n, np.concatenate([u, cu]),
                            np.concatenate([v, cv]),
                            np.concatenate([w, cw]))


def tree_with_blobs(n_blobs: int, blob_size: int, seed: int = 0) -> Graph:
    """Cut-node-heavy graph: blobs (cliques) strung on a path. Every blob
    connector is a cut node -> exercises agents/DRAs densely."""
    rng = np.random.default_rng(seed)
    edges_u, edges_v = [], []
    nid = 0
    prev_anchor = None
    for _ in range(n_blobs):
        base = nid
        nid += blob_size
        for a in range(blob_size):
            for b in range(a + 1, blob_size):
                if rng.random() < 0.6 or b == a + 1:
                    edges_u.append(base + a)
                    edges_v.append(base + b)
        if prev_anchor is not None:
            edges_u.append(prev_anchor)
            edges_v.append(base)
        prev_anchor = base
    w = rng.integers(1, 50, size=len(edges_u)).astype(np.float64)
    return Graph.from_edges(nid, edges_u, edges_v, w)
