"""Arc-Flags (Moehring et al. [22]) — baseline + DISLAND integration.

Partition-based edge labelling: flag[slot, r] = 1 iff the directed edge
(CSR slot) lies on some shortest path into region r.  Built with one
backward shortest-path tree per boundary node per region (the expensive
preprocessing the paper measures in Exp-4); queries run Dijkstra pruned
to edges flagged for the target's region.

Role: comparison baseline for the auxiliary workloads (DESIGN.md §8).
Invariant: flags are conservative (every shortest-path edge into r is
flagged), so the pruned Dijkstra stays exact — only faster.
"""
from __future__ import annotations

import heapq

import numpy as np

from .dijkstra import sssp
from .graph import Graph
from .partition import partition_bgp


class ArcFlags:
    def __init__(self, g: Graph, n_regions: int = 16, seed: int = 0):
        self.g = g
        gamma = max(4, int(np.ceil(g.n / max(n_regions, 1))))
        part = partition_bgp(g, gamma, seed=seed)
        self.region = part.labels
        self.k = part.n_fragments
        nslots = g.indices.size
        self.flags = np.zeros((nslots, self.k), dtype=bool)
        self._slot_src = np.repeat(np.arange(g.n, dtype=np.int64),
                                   np.diff(g.indptr))
        self._build()

    def _build(self) -> None:
        g = self.g
        # intra-region edges: flag both directions for their own region
        src = self._slot_src
        dst = g.indices
        same = self.region[src] == self.region[dst]
        self.flags[same, self.region[src[same]]] = True
        # boundary nodes per region
        cross_u = g.edge_u[self.region[g.edge_u] != self.region[g.edge_v]]
        cross_v = g.edge_v[self.region[g.edge_u] != self.region[g.edge_v]]
        boundary = np.unique(np.concatenate([cross_u, cross_v]))
        for b in boundary:
            r = int(self.region[b])
            dist = sssp(g, int(b))
            # directed edge u->v is on a shortest path toward b iff
            # dist[v] + w == dist[u]
            du = dist[src]
            dv = dist[dst]
            on_sp = np.isfinite(du) & np.isclose(dv + g.weights, du)
            self.flags[on_sp, r] = True

    def query(self, s: int, t: int) -> float:
        if s == t:
            return 0.0
        g = self.g
        rt = int(self.region[t])
        dist = np.full(g.n, np.inf)
        dist[s] = 0.0
        pq = [(0.0, int(s))]
        while pq:
            d, u = heapq.heappop(pq)
            if u == t:
                return d
            if d > dist[u]:
                continue
            a, b = g.indptr[u], g.indptr[u + 1]
            for slot in range(a, b):
                if not self.flags[slot, rt]:
                    continue
                v = int(g.indices[slot])
                nd = d + float(g.weights[slot])
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(pq, (nd, v))
        return np.inf

    def extra_bits(self) -> int:
        return self.flags.size
