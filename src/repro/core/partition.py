"""Bounded graph partitioning (paper §V-B).

BGP: partition V into fragments with |V_i| <= Gamma minimising the number
of boundary nodes.  NP-complete (Prop 13); the paper attacks it through
the classical edge-cut objective (|B| <= 2|E_B|, §V key observation)
with METIS.  METIS is not available offline, so this module implements
the same multilevel scheme in-repo (DESIGN.md §7.2):

  1. coarsening by heavy-edge matching (contract heaviest incident edge;
     node weights accumulate so balance is tracked in original-node
     units),
  2. initial partition by greedy heaviest-connection (Prim-style) region
     growing on the coarsest graph: each region repeatedly absorbs the
     unassigned node with the largest total edge weight into the region,
     grown only to ``_FILL * Gamma`` so refinement has slack to move
     nodes without violating the hard bound,
  3. uncoarsening with boundary Kernighan-Lin/FM refinement: move
     boundary nodes to the neighbouring fragment with the best edge-cut
     gain subject to the size bound.

Objective note: BGP minimizes the *number* of boundary nodes, so the
cut objective counts edges (|B| <= 2|E_B|) — by default every edge
weighs 1 in matching and refinement regardless of the graph's own
weights (road travel times are noise for this objective).  Callers
whose edge weights ARE cut multiplicities — the hierarchy planner's
unit quotient graph, where one edge stands for N parallel cross-unit
slots — pass ``cut_weights=True`` to optimize the weighted cut.

Owned invariants: |V_i| <= Gamma is a HARD bound (refinement may only
improve the cut within it), every node is assigned to exactly one
fragment, and the partition is purely topological — weight refreshes
never re-partition, which is what keeps refresh shapes stable
(DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

import numpy as np

from .graph import Graph

#: initial regions grow only to this fraction of Gamma, leaving FM
#: refinement headroom to move boundary nodes (a partition packed to
#: 100% of the bound admits no moves at all — every region is full)
_FILL = 0.8


@dataclasses.dataclass
class PartitionResult:
    labels: np.ndarray           # int[n] fragment id
    n_fragments: int

    def boundary_mask(self, g: Graph) -> np.ndarray:
        lab = self.labels
        cross = lab[g.edge_u] != lab[g.edge_v]
        mask = np.zeros(g.n, dtype=bool)
        mask[g.edge_u[cross]] = True
        mask[g.edge_v[cross]] = True
        return mask

    def edge_cut(self, g: Graph) -> int:
        return int((self.labels[g.edge_u] != self.labels[g.edge_v]).sum())

    def fragment_nodes(self, i: int) -> np.ndarray:
        return np.nonzero(self.labels == i)[0].astype(np.int32)


# ---------------------------------------------------------------------------
def _heavy_edge_matching(g: Graph, node_w: np.ndarray, max_node_w: int,
                         rng: np.random.Generator):
    """Heavy-edge matching via vectorized propose-accept rounds.

    Every unmatched node proposes its incident live edge of maximal
    global rank (weight, then a random per-edge priority — one strict
    total order shared by all nodes); mutual proposals match.  The
    globally top-ranked live edge is always mutual, so every round
    makes progress and the loop terminates.  Same METIS-HEM contract
    as the sequential visit-order scan this replaces — match heavy
    edges first under the ``max_node_w`` balance bound — but each
    round is O(live edges) numpy instead of a Python adjacency walk.
    """
    n = g.n
    match = -np.ones(n, dtype=np.int64)
    m = g.edge_u.size
    if m == 0:
        match[:] = np.arange(n)
        return match
    # directed edge list with undirected ids for the shared rank
    eprio = rng.permutation(m)
    src = np.concatenate([g.edge_u, g.edge_v]).astype(np.int64)
    dst = np.concatenate([g.edge_v, g.edge_u]).astype(np.int64)
    eid = np.concatenate([np.arange(m), np.arange(m)])
    feasible = (node_w[src] + node_w[dst]) <= max_node_w
    src, dst, eid = src[feasible], dst[feasible], eid[feasible]
    # sort once by (src, weight, priority); per round the last live
    # entry of each src group is that node's proposal
    order = np.lexsort((eprio[eid], g.edge_w[eid], src))
    src, dst = src[order], dst[order]
    while src.size:
        live = (match[src] < 0) & (match[dst] < 0)
        src, dst = src[live], dst[live]
        if not src.size:
            break
        last = np.flatnonzero(np.r_[src[1:] != src[:-1], True])
        proposal = -np.ones(n, dtype=np.int64)
        proposal[src[last]] = dst[last]
        u = src[last]
        mutual = u[proposal[proposal[u]] == u]
        if not mutual.size:
            break
        match[mutual] = proposal[mutual]
    match[match < 0] = np.nonzero(match < 0)[0]
    return match


def _contract(g: Graph, node_w: np.ndarray, match: np.ndarray):
    """Contract matched pairs; sum parallel edge weights (cut weight)."""
    rep = np.minimum(np.arange(g.n), match)
    new_id = -np.ones(g.n, dtype=np.int64)
    uniq = np.unique(rep)
    new_id[uniq] = np.arange(uniq.size)
    cmap = new_id[rep]  # old node -> coarse node
    cw = np.zeros(uniq.size, dtype=np.int64)
    np.add.at(cw, cmap, node_w)
    cu = cmap[g.edge_u]
    cv = cmap[g.edge_v]
    keep = cu != cv
    cu, cv, w = cu[keep], cv[keep], g.edge_w[keep]
    lo, hi = np.minimum(cu, cv), np.maximum(cu, cv)
    # sum weights of parallel edges
    key = lo.astype(np.int64) * uniq.size + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, w = key[order], lo[order], hi[order], w[order]
    if key.size:
        first = np.ones(key.size, dtype=bool)
        first[1:] = key[1:] != key[:-1]
        idx = np.cumsum(first) - 1
        ws = np.zeros(first.sum())
        np.add.at(ws, idx, w)
        lo, hi = lo[first], hi[first]
        w = ws
    cg = Graph.from_edges(uniq.size, lo, hi, w) if lo.size else \
        Graph.from_edges(uniq.size, [], [], [])
    return cg, cw, cmap


def _initial_partition(g: Graph, node_w: np.ndarray, gamma: int,
                       rng: np.random.Generator) -> np.ndarray:
    """Greedy heaviest-connection region growing bounded by gamma.

    Prim-style: the region absorbs the unassigned node with the
    largest accumulated edge weight into the region (a max-heap keyed
    by connection weight, stale entries skipped).  Plain BFS order
    crosses a 1-weight long-range edge as readily as a 20-weight
    interface, which scatters regions all over the graph; growing by
    connection strength keeps them geometrically compact, which is
    what the FM passes need to polish the cut.
    """
    labels = -np.ones(g.n, dtype=np.int64)
    frag = 0
    order = np.argsort(np.diff(g.indptr))  # grow from low-degree periphery
    for seed in order:
        if labels[seed] >= 0:
            continue
        size = 0
        conn = {int(seed): 0.0}
        heap = [(0.0, int(seed))]
        while heap:
            negw, u = heapq.heappop(heap)
            if labels[u] >= 0 or -negw < conn.get(u, 0.0):
                continue               # already taken / stale entry
            if size + node_w[u] > gamma and size > 0:
                continue
            labels[u] = frag
            size += int(node_w[u])
            s, e = g.indptr[u], g.indptr[u + 1]
            for v, w in zip(g.indices[s:e], g.weights[s:e]):
                v = int(v)
                if labels[v] < 0:
                    conn[v] = conn.get(v, 0.0) + float(w)
                    heapq.heappush(heap, (-conn[v], v))
        frag += 1
    return labels


def _refine(g: Graph, node_w: np.ndarray, labels: np.ndarray, gamma: int,
            passes: int = 8) -> np.ndarray:
    """Boundary FM: greedy positive-gain moves under the size bound."""
    labels = labels.copy()
    nfrag = int(labels.max()) + 1 if labels.size else 0
    sizes = np.zeros(nfrag, dtype=np.int64)
    np.add.at(sizes, labels, node_w)
    for _ in range(passes):
        cross = labels[g.edge_u] != labels[g.edge_v]
        bnodes = np.unique(np.concatenate([g.edge_u[cross],
                                           g.edge_v[cross]]))
        moved = 0
        for u in bnodes:
            u = int(u)
            s, e = g.indptr[u], g.indptr[u + 1]
            lu = labels[u]
            # weight of edges toward each neighbouring fragment
            gains: dict[int, float] = {}
            for v, w in zip(g.indices[s:e], g.weights[s:e]):
                gains[int(labels[v])] = gains.get(int(labels[v]), 0.0) + w
            internal = gains.get(int(lu), 0.0)
            best_l, best_gain = lu, 0.0
            for l, wsum in gains.items():
                if l == lu:
                    continue
                if sizes[l] + node_w[u] > gamma:
                    continue
                gain = wsum - internal
                if gain > best_gain:
                    best_l, best_gain = l, gain
            if best_l != lu:
                sizes[lu] -= node_w[u]
                sizes[best_l] += node_w[u]
                labels[u] = best_l
                moved += 1
        if moved == 0:
            break
    return labels


def partition_bgp(g: Graph, gamma: int, seed: int = 0,
                  coarsen_to: int = 512,
                  node_w: np.ndarray | None = None,
                  cut_weights: bool = False) -> PartitionResult:
    """Multilevel BGP partitioner: fragments of <= gamma weight units.

    ``node_w=None`` (the default, and the level-1 call path) weights
    every node 1 so gamma bounds original-node counts exactly as
    before.  A caller partitioning a *quotient* graph — the hierarchy
    planner grouping fragments by overlay-boundary mass — passes its
    own per-node weights and gamma bounds their sum per fragment; the
    coarsening, initial partition, and FM refinement already track
    accumulated node weights, so the scheme is unchanged.

    ``cut_weights=False`` (default) optimizes the *unweighted* edge
    cut — the BGP boundary objective, where road travel times on the
    edges are irrelevant noise; ``cut_weights=True`` keeps the graph's
    edge weights as cut multiplicities (the quotient-graph callers,
    whose one edge stands for N parallel cross-unit slots).
    """
    if g.n == 0:
        return PartitionResult(labels=np.empty(0, np.int64), n_fragments=0)
    if not cut_weights:
        g = Graph.from_edges(g.n, g.edge_u, g.edge_v,
                             np.ones(g.m, dtype=np.float64))
    rng = np.random.default_rng(seed)
    graphs: List[Graph] = [g]
    if node_w is None:
        node_w = np.ones(g.n, dtype=np.int64)
    weights: List[np.ndarray] = [np.asarray(node_w, dtype=np.int64)]
    maps: List[np.ndarray] = []
    # 1. coarsen
    while graphs[-1].n > coarsen_to:
        cur, curw = graphs[-1], weights[-1]
        match = _heavy_edge_matching(cur, curw, max(1, gamma // 2), rng)
        cg, cw, cmap = _contract(cur, curw, match)
        if cg.n >= cur.n:  # no progress (matching saturated)
            break
        graphs.append(cg)
        weights.append(cw)
        maps.append(cmap)
    # 2. initial partition on the coarsest level (grown to _FILL*gamma
    #    so the refinement passes have slack; the bound stays gamma)
    grow = max(1, int(_FILL * gamma))
    labels = _initial_partition(graphs[-1], weights[-1], grow, rng)
    labels = _refine(graphs[-1], weights[-1], labels, gamma)
    # 3. uncoarsen + refine
    for lvl in range(len(maps) - 1, -1, -1):
        labels = labels[maps[lvl]]
        labels = _refine(graphs[lvl], weights[lvl], labels, gamma)
    # compact labels
    uniq, inv = np.unique(labels, return_inverse=True)
    return PartitionResult(labels=inv.astype(np.int64),
                           n_fragments=int(uniq.size))
