"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = min_k A[i,k] + B[k,j] (tropical GEMM)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus_accum_ref(c: jax.Array, a: jax.Array, b: jax.Array
                      ) -> jax.Array:
    return jnp.minimum(c, minplus_ref(a, b))


def fw_ref(d: jax.Array) -> jax.Array:
    """Floyd-Warshall APSP on one [n, n] matrix (diag forced to 0)."""
    n = d.shape[0]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, d)

    def body(k, mat):
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)
        return jnp.minimum(mat, col + row)

    return jax.lax.fori_loop(0, n, body, d)


def fw_batch_ref(d: jax.Array) -> jax.Array:
    return jax.vmap(fw_ref)(d)
