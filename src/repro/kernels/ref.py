"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = min_k A[i,k] + B[k,j] (tropical GEMM)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus_accum_ref(c: jax.Array, a: jax.Array, b: jax.Array
                      ) -> jax.Array:
    return jnp.minimum(c, minplus_ref(a, b))


def label_merge_ref(labs: jax.Array, labt: jax.Array) -> jax.Array:
    """out[q] = min_j labs[q,j] + labt[q,j] (hub-label merge)."""
    return jnp.min(labs + labt, axis=1)


def minplus_twoside_ref(rows: jax.Array, d: jax.Array, rowt: jax.Array,
                        *, chunk: int = 16) -> jax.Array:
    """out[q] = min_{x,y} rows[q,x] + d[x,y] + rowt[q,y].

    x-chunked so the peak intermediate is [q, chunk, k2], never the
    full [q, k1, k2] cube (mirrors the Pallas kernel's contract).
    """
    q, k1 = rows.shape
    k2 = rowt.shape[1]
    k1p = -(-k1 // chunk) * chunk
    rows_p = jnp.full((q, k1p), jnp.inf, rows.dtype).at[:, :k1].set(rows)
    d_p = jnp.full((k1p, k2), jnp.inf, d.dtype).at[:k1].set(d)

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(rows_p, i * chunk, chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d_p, i * chunk, chunk, axis=0)
        cand = jnp.min(r_c[:, :, None] + d_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, k1p // chunk, body,
                            jnp.full((q, k2), jnp.inf, rows.dtype))
    return jnp.min(tmp + rowt, axis=1)


def minplus_twoside_argmin_ref(rows: jax.Array, d: jax.Array,
                               rowt: jax.Array, *, chunk: int = 16
                               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Witness-tracking twoside contraction: (out, wx, wy) with
    out[q] = rows[q, wx[q]] + d[wx[q], wy[q]] + rowt[q, wy[q]] whenever
    out[q] is finite; wx = wy = -1 otherwise.  Same x-chunked schedule
    as minplus_twoside_ref, carrying the winning x per (q, y) cell."""
    q, k1 = rows.shape
    k2 = rowt.shape[1]
    k1p = -(-k1 // chunk) * chunk
    rows_p = jnp.full((q, k1p), jnp.inf, rows.dtype).at[:, :k1].set(rows)
    d_p = jnp.full((k1p, k2), jnp.inf, d.dtype).at[:k1].set(d)

    def body(i, carry):
        acc, accx = carry
        r_c = jax.lax.dynamic_slice_in_dim(rows_p, i * chunk, chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d_p, i * chunk, chunk, axis=0)
        cube = r_c[:, :, None] + d_c[None, :, :]       # [q, chunk, k2]
        cand = jnp.min(cube, axis=1)
        # smallest chunk-local x achieving the min (tie-stable)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(hit,
                                jnp.arange(chunk, dtype=jnp.int32)[None, :,
                                                                   None],
                                jnp.int32(k1p)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * chunk + loc, accx))

    acc0 = jnp.full((q, k2), jnp.inf, rows.dtype)
    accx0 = jnp.full((q, k2), -1, jnp.int32)
    acc, accx = jax.lax.fori_loop(0, k1p // chunk, body, (acc0, accx0))
    tmp = acc + rowt                                   # [q, k2]
    out = jnp.min(tmp, axis=1)
    hit = tmp == out[:, None]
    wy = jnp.min(jnp.where(hit, jnp.arange(k2, dtype=jnp.int32)[None, :],
                           jnp.int32(k2)), axis=1)
    fin = jnp.isfinite(out)
    wy = jnp.where(fin, wy, -1)
    wx = jnp.where(fin,
                   jnp.take_along_axis(accx, jnp.clip(wy, 0)[:, None],
                                       axis=1)[:, 0], -1)
    return out, wx, wy


def fw_ref(d: jax.Array) -> jax.Array:
    """Floyd-Warshall APSP on one [n, n] matrix (diag forced to 0)."""
    n = d.shape[0]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, d)

    def body(k, mat):
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)
        return jnp.minimum(mat, col + row)

    return jax.lax.fori_loop(0, n, body, d)


def fw_batch_ref(d: jax.Array) -> jax.Array:
    return jax.vmap(fw_ref)(d)


def fw_next_init(d: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(diag-zeroed distances, first-hop successor init) for witness FW.

    nxt[i, j] = j where (i, j) is a direct edge, -1 elsewhere (incl. the
    diagonal) — the classic FW path-reconstruction convention: following
    nxt from i lands one adjacency hop closer to j at every step.
    """
    n = d.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    d0 = jnp.where(eye, 0.0, d)
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), d.shape)
    nxt0 = jnp.where(jnp.isfinite(d0) & ~eye, cols, -1)
    return d0, nxt0


def fw_next_ref(d: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Witness-carrying Floyd-Warshall on one [n, n] matrix.

    Returns (dist, nxt); dist is bit-identical to fw_ref (the update is
    the same strict-improvement recurrence in the same pivot order), and
    nxt[i, j] is the first hop of a shortest i -> j path (-1 when
    j is unreachable or i == j).
    """
    n = d.shape[0]
    mat0, nxt0 = fw_next_init(d)

    def body(k, carry):
        mat, nxt = carry
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)
        cand = col + row
        nk = jax.lax.dynamic_slice_in_dim(nxt, k, 1, axis=1)  # nxt[:, k]
        better = cand < mat
        return (jnp.where(better, cand, mat),
                jnp.where(better, jnp.broadcast_to(nk, nxt.shape), nxt))

    return jax.lax.fori_loop(0, n, body, (mat0, nxt0))


def fw_batch_next_ref(d: jax.Array) -> tuple[jax.Array, jax.Array]:
    return jax.vmap(fw_next_ref)(d)


# NOTE (measured): a chunked blocked-panel FW variant of fw_ref was
# tried for the CPU overlay closure and came out ~8x slower at n=625 —
# its [n, chunk, n] broadcast intermediates thrash memory, while the n
# small single-pivot iterations above stay cache-resident and fuse.
# The blocked schedule only pays off inside the Pallas kernel
# (floyd_warshall.py), where tiles are explicitly VMEM-resident.
