"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[i,j] = min_k A[i,k] + B[k,j] (tropical GEMM)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus_accum_ref(c: jax.Array, a: jax.Array, b: jax.Array
                      ) -> jax.Array:
    return jnp.minimum(c, minplus_ref(a, b))


def minplus_twoside_ref(rows: jax.Array, d: jax.Array, rowt: jax.Array,
                        *, chunk: int = 16) -> jax.Array:
    """out[q] = min_{x,y} rows[q,x] + d[x,y] + rowt[q,y].

    x-chunked so the peak intermediate is [q, chunk, k2], never the
    full [q, k1, k2] cube (mirrors the Pallas kernel's contract).
    """
    q, k1 = rows.shape
    k2 = rowt.shape[1]
    k1p = -(-k1 // chunk) * chunk
    rows_p = jnp.full((q, k1p), jnp.inf, rows.dtype).at[:, :k1].set(rows)
    d_p = jnp.full((k1p, k2), jnp.inf, d.dtype).at[:k1].set(d)

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(rows_p, i * chunk, chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d_p, i * chunk, chunk, axis=0)
        cand = jnp.min(r_c[:, :, None] + d_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, k1p // chunk, body,
                            jnp.full((q, k2), jnp.inf, rows.dtype))
    return jnp.min(tmp + rowt, axis=1)


def fw_ref(d: jax.Array) -> jax.Array:
    """Floyd-Warshall APSP on one [n, n] matrix (diag forced to 0)."""
    n = d.shape[0]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, d)

    def body(k, mat):
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)
        return jnp.minimum(mat, col + row)

    return jax.lax.fori_loop(0, n, body, d)


def fw_batch_ref(d: jax.Array) -> jax.Array:
    return jax.vmap(fw_ref)(d)


# NOTE (measured): a chunked blocked-panel FW variant of fw_ref was
# tried for the CPU overlay closure and came out ~8x slower at n=625 —
# its [n, chunk, n] broadcast intermediates thrash memory, while the n
# small single-pivot iterations above stay cache-resident and fuse.
# The blocked schedule only pays off inside the Pallas kernel
# (floyd_warshall.py), where tiles are explicitly VMEM-resident.
