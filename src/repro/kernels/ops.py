"""Public jit'd wrappers around the Pallas kernels.

Dispatch policy: on TPU the Pallas kernels compile natively; on CPU the
default is the pure-jnp reference (XLA-compiled, fast) so host-side
pipelines stay usable, while ``force="pallas"`` runs the kernels in
interpret mode — tests use that to exercise tiling/indexing end-to-end.
"""
from __future__ import annotations

from typing import Literal, Optional

import jax

from . import floyd_warshall as _fw
from . import label_merge as _lm
from . import minplus as _mp
from . import minplus_twoside as _ts
from . import ref as _ref

Force = Optional[Literal["pallas", "ref"]]


def _use_pallas(force: Force) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if force == "ref":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if force == "pallas":
        return True, not on_tpu
    return on_tpu, False


def minplus(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
            bk: int = 128, force: Force = None) -> jax.Array:
    """Tropical GEMM: min_k A[i,k] + B[k,j]."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _mp.minplus_pallas(a, b, bm=bm, bn=bn, bk=bk,
                                  interpret=interp)
    return _ref.minplus_ref(a, b)


def minplus_twoside(rows: jax.Array, d: jax.Array, rowt: jax.Array, *,
                    bq: int = 128, bk1: int = 128, bk2: int = 128,
                    force: Force = None) -> jax.Array:
    """Fused two-sided contraction: out[q] = min_{x,y} rows[q,x]
    + d[x,y] + rowt[q,y] — the serve-path combine, [q,k,k]-free."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _ts.minplus_twoside_pallas(rows, d, rowt, bq=bq, bk1=bk1,
                                          bk2=bk2, interpret=interp)
    return _ref.minplus_twoside_ref(rows, d, rowt)


def label_merge(labs: jax.Array, labt: jax.Array, *, bq: int = 128,
                bj: int = 512, force: Force = None) -> jax.Array:
    """Hub-label merge: out[q] = min_j labs[q,j] + labt[q,j] — the
    hot-tier combine (DESIGN.md §15), O(W) per query."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _lm.label_merge_pallas(labs, labt, bq=bq, bj=bj,
                                      interpret=interp)
    return _ref.label_merge_ref(labs, labt)


def minplus_twoside_argmin(rows: jax.Array, d: jax.Array,
                           rowt: jax.Array, *, bq: int = 128,
                           bk1: int = 128, bk2: int = 128,
                           force: Force = None
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Witness-returning twoside contraction -> (out, wx, wy): the
    winning (x, y) pair alongside each minimum, -1 where out is +inf.
    The path-reconstruction serve mode's combine step (DESIGN.md §10)."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _ts.minplus_twoside_argmin_pallas(
            rows, d, rowt, bq=bq, bk1=bk1, bk2=bk2, interpret=interp)
    return _ref.minplus_twoside_argmin_ref(rows, d, rowt)


def use_pallas(force: Force = None) -> bool:
    """Expose the dispatch decision (engines pick layouts with it)."""
    return _use_pallas(force)[0]


def minplus_accum(c: jax.Array, a: jax.Array, b: jax.Array, *,
                  bm: int = 128, bn: int = 128, bk: int = 128,
                  force: Force = None) -> jax.Array:
    """min(C, A (x) B)."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _mp.minplus_accum_pallas(c, a, b, bm=bm, bn=bn, bk=bk,
                                        interpret=interp)
    return _ref.minplus_accum_ref(c, a, b)


def fw_batch(d: jax.Array, *, force: Force = None) -> jax.Array:
    """Batched dense APSP over [b, n, n] fragment matrices."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _fw.fw_batch_pallas(d, interpret=interp)
    return _ref.fw_batch_ref(d)


def fw_batch_next(d: jax.Array, *, force: Force = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Witness-carrying batched APSP -> (dist, nxt); dist bit-identical
    to fw_batch, nxt[b, i, j] = first hop of a shortest i -> j path in
    batch entry b (-1: unreachable / diagonal)."""
    pallas, interp = _use_pallas(force)
    if pallas:
        return _fw.fw_batch_next_pallas(d, interpret=interp)
    return _ref.fw_batch_next_ref(d)


def fw_next(d: jax.Array, *, force: Force = None
            ) -> tuple[jax.Array, jax.Array]:
    """Witness-carrying APSP for a single [n, n] matrix.

    The Pallas path runs the whole matrix as a batch of one (the SUPER
    overlay is a few hundred nodes, comfortably VMEM-resident; a blocked
    witness closure is not worth its complexity at that size)."""
    pallas, interp = _use_pallas(force)
    if pallas:
        dist, nxt = _fw.fw_batch_next_pallas(d[None], interpret=interp)
        return dist[0], nxt[0]
    return _ref.fw_next_ref(d)


def fw_apsp(d: jax.Array, *, block: int = 128,
            force: Force = None) -> jax.Array:
    """Blocked APSP for a single [n, n] matrix.

    The CPU path stays single-pivot on purpose: a chunked blocked-panel
    jnp schedule was benchmarked 8x SLOWER at n=625 (the [n, chunk, n]
    broadcast intermediates thrash memory, while XLA fuses the n small
    col+row+min iterations cache-resident).
    """
    pallas, interp = _use_pallas(force)
    if pallas:
        return _fw.fw_blocked(d, block=block, interpret=interp)
    return _ref.fw_ref(d)
