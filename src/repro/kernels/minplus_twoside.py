"""Fused two-sided tropical contraction — the DISLAND combine step.

    out[q] = min_{x, y} rows[q, x] + D[x, y] + rowt[q, y]

This is the serve-path case-2 middle term: distances query-source ->
SUPER nodes (rows), SUPER x SUPER APSP (D), SUPER -> query-target
(rowt), contracted over BOTH super indices at once.  The naive
formulation gathers a per-query [mb, mb] block of D (O(q*mb^2) HBM
traffic); here D is streamed tile-by-tile through VMEM exactly once per
query tile and the [q, x, y] intermediate is never materialized.

TPU mapping (VPU work, no MXU form for (min,+)): grid is
(q tiles, y tiles, x tiles) with the two contraction axes innermost and
sequential, so the output tile is min-accumulated across all (x, y)
tile pairs (revisiting pattern).  Each invocation reduces its
[bq, bk1] x [bk1, bk2] x [bq, bk2] triple down to per-lane partial
minima [bq, 128]; the final cross-lane min happens outside the kernel
(a trivial [q, 128] -> [q] reduce).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _twoside_kernel(rows_ref, d_ref, rowt_ref, out_ref, *, k_chunk: int):
    """Min-accumulate one (q, y, x) tile triple into lane partials."""
    yi = pl.program_id(1)
    xi = pl.program_id(2)

    @pl.when((yi == 0) & (xi == 0))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    rows = rows_ref[...]          # [bq, bk1]
    d = d_ref[...]                # [bk1, bk2]
    rowt = rowt_ref[...]          # [bq, bk2]
    bk1 = rows.shape[1]
    bq, bk2 = rowt.shape

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(rows, i * k_chunk, k_chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d, i * k_chunk, k_chunk,
                                           axis=0)
        # [bq, kc, bk2] broadcast add, min over the x chunk
        cand = jnp.min(r_c[:, :, None] + d_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, bk1 // k_chunk, body,
                            jnp.full((bq, bk2), jnp.inf, rows.dtype))
    tmp = tmp + rowt              # [bq, bk2]
    # fold the y tile down to its 128 lanes; cross-lane min is done by
    # the caller so every store here stays (8, 128)-aligned
    part = jnp.min(tmp.reshape(bq, bk2 // _LANES, _LANES), axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], part)


@functools.partial(jax.jit, static_argnames=("bq", "bk1", "bk2",
                                             "k_chunk", "interpret"))
def minplus_twoside_pallas(rows: jax.Array, d: jax.Array,
                           rowt: jax.Array, *, bq: int = 128,
                           bk1: int = 128, bk2: int = 128,
                           k_chunk: int = 8,
                           interpret: bool = False) -> jax.Array:
    """out[q] = min_{x,y} rows[q,x] + d[x,y] + rowt[q,y].

    Shapes: rows [q, k1], d [k1, k2], rowt [q, k2] -> out [q].
    Pads every axis to tile multiples with +inf (absorbing element).
    """
    q, k1 = rows.shape
    k1b, k2 = d.shape
    qb, k2b = rowt.shape
    assert k1 == k1b and k2 == k2b and q == qb, (rows.shape, d.shape,
                                                rowt.shape)
    assert bk2 % _LANES == 0 and bk1 % k_chunk == 0, (bk1, bk2, k_chunk)
    qp = -(-q // bq) * bq
    k1p = -(-k1 // bk1) * bk1
    k2p = -(-k2 // bk2) * bk2
    rows_p = jnp.full((qp, k1p), jnp.inf, rows.dtype).at[:q, :k1].set(rows)
    d_p = jnp.full((k1p, k2p), jnp.inf, d.dtype).at[:k1, :k2].set(d)
    rowt_p = jnp.full((qp, k2p), jnp.inf, rowt.dtype).at[:q, :k2].set(rowt)
    grid = (qp // bq, k2p // bk2, k1p // bk1)
    part = pl.pallas_call(
        functools.partial(_twoside_kernel, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk1), lambda qi, yi, xi: (qi, xi)),
            pl.BlockSpec((bk1, bk2), lambda qi, yi, xi: (xi, yi)),
            pl.BlockSpec((bq, bk2), lambda qi, yi, xi: (qi, yi)),
        ],
        out_specs=pl.BlockSpec((bq, _LANES), lambda qi, yi, xi: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, _LANES), rows.dtype),
        interpret=interpret,
    )(rows_p, d_p, rowt_p)
    return jnp.min(part, axis=1)[:q]


def _twoside_argmin_kernel(rows_ref, d_ref, rowt_ref, out_ref, wit_ref,
                           *, k_chunk: int, k2_stride: int):
    """Witness-carrying variant of _twoside_kernel: alongside the lane
    partial minima, carry the winning (x, y) pair packed as
    x * k2_stride + y (global padded coordinates, int32).  Ties resolve
    to the smallest packed witness, deterministically."""
    yi = pl.program_id(1)
    xi = pl.program_id(2)

    @pl.when((yi == 0) & (xi == 0))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)
        wit_ref[...] = jnp.full_like(wit_ref, -1)

    rows = rows_ref[...]          # [bq, bk1]
    d = d_ref[...]                # [bk1, bk2]
    rowt = rowt_ref[...]          # [bq, bk2]
    bk1 = rows.shape[1]
    bq, bk2 = rowt.shape

    def body(i, carry):
        acc, accx = carry
        r_c = jax.lax.dynamic_slice_in_dim(rows, i * k_chunk, k_chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d, i * k_chunk, k_chunk,
                                           axis=0)
        cube = r_c[:, :, None] + d_c[None, :, :]   # [bq, kc, bk2]
        cand = jnp.min(cube, axis=1)
        hit = cube == cand[:, None, :]
        loc = jnp.min(jnp.where(
            hit,
            jax.lax.broadcasted_iota(jnp.int32, cube.shape, 1),
            jnp.int32(bk1)), axis=1)
        better = cand < acc
        return (jnp.where(better, cand, acc),
                jnp.where(better, i * k_chunk + loc, accx))

    acc0 = jnp.full((bq, bk2), jnp.inf, rows.dtype)
    accx0 = jnp.full((bq, bk2), -1, jnp.int32)
    acc, accx = jax.lax.fori_loop(0, bk1 // k_chunk, body, (acc0, accx0))
    tmp = acc + rowt              # [bq, bk2]
    # pack the global witness per (q, y) cell, then fold y to 128 lanes
    # keeping value/witness aligned (min-of-where instead of argmin so
    # every op stays lane-shaped)
    y_glob = yi * bk2 + jax.lax.broadcasted_iota(jnp.int32, tmp.shape, 1)
    wxy = (xi * bk1 + accx) * k2_stride + y_glob
    g = bk2 // _LANES
    tmp_r = tmp.reshape(bq, g, _LANES)
    wxy_r = wxy.reshape(bq, g, _LANES)
    part = jnp.min(tmp_r, axis=1)                        # [bq, 128]
    hit = tmp_r == part[:, None, :]
    pwit = jnp.min(jnp.where(hit, wxy_r, jnp.iinfo(jnp.int32).max),
                   axis=1)
    cur = out_ref[...]
    cur_wit = wit_ref[...]
    better = part < cur
    out_ref[...] = jnp.where(better, part, cur)
    wit_ref[...] = jnp.where(better, pwit, cur_wit)


@functools.partial(jax.jit, static_argnames=("bq", "bk1", "bk2",
                                             "k_chunk", "interpret"))
def minplus_twoside_argmin_pallas(rows: jax.Array, d: jax.Array,
                                  rowt: jax.Array, *, bq: int = 128,
                                  bk1: int = 128, bk2: int = 128,
                                  k_chunk: int = 8,
                                  interpret: bool = False
                                  ) -> tuple[jax.Array, jax.Array,
                                             jax.Array]:
    """Witness-returning twoside contraction: (out, wx, wy) with
    out[q] = rows[q, wx[q]] + d[wx[q], wy[q]] + rowt[q, wy[q]] for every
    finite out[q]; wx = wy = -1 where out[q] is +inf.  Same tiling and
    revisiting pattern as minplus_twoside_pallas; padded cells are +inf
    so they can never win a witness."""
    q, k1 = rows.shape
    k1b, k2 = d.shape
    qb, k2b = rowt.shape
    assert k1 == k1b and k2 == k2b and q == qb, (rows.shape, d.shape,
                                                rowt.shape)
    assert bk2 % _LANES == 0 and bk1 % k_chunk == 0, (bk1, bk2, k_chunk)
    qp = -(-q // bq) * bq
    k1p = -(-k1 // bk1) * bk1
    k2p = -(-k2 // bk2) * bk2
    rows_p = jnp.full((qp, k1p), jnp.inf, rows.dtype).at[:q, :k1].set(rows)
    d_p = jnp.full((k1p, k2p), jnp.inf, d.dtype).at[:k1, :k2].set(d)
    rowt_p = jnp.full((qp, k2p), jnp.inf, rowt.dtype).at[:q, :k2].set(rowt)
    grid = (qp // bq, k2p // bk2, k1p // bk1)
    part, pwit = pl.pallas_call(
        functools.partial(_twoside_argmin_kernel, k_chunk=k_chunk,
                          k2_stride=k2p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk1), lambda qi, yi, xi: (qi, xi)),
            pl.BlockSpec((bk1, bk2), lambda qi, yi, xi: (xi, yi)),
            pl.BlockSpec((bq, bk2), lambda qi, yi, xi: (qi, yi)),
        ],
        out_specs=[pl.BlockSpec((bq, _LANES), lambda qi, yi, xi: (qi, 0)),
                   pl.BlockSpec((bq, _LANES), lambda qi, yi, xi: (qi, 0))],
        out_shape=[jax.ShapeDtypeStruct((qp, _LANES), rows.dtype),
                   jax.ShapeDtypeStruct((qp, _LANES), jnp.int32)],
        interpret=interpret,
    )(rows_p, d_p, rowt_p)
    out = jnp.min(part, axis=1)
    hit = part == out[:, None]
    wit = jnp.min(jnp.where(hit, pwit, jnp.iinfo(jnp.int32).max), axis=1)
    fin = jnp.isfinite(out)
    wx = jnp.where(fin, wit // k2p, -1)
    wy = jnp.where(fin, wit % k2p, -1)
    return out[:q], wx[:q], wy[:q]
