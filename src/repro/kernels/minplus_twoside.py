"""Fused two-sided tropical contraction — the DISLAND combine step.

    out[q] = min_{x, y} rows[q, x] + D[x, y] + rowt[q, y]

This is the serve-path case-2 middle term: distances query-source ->
SUPER nodes (rows), SUPER x SUPER APSP (D), SUPER -> query-target
(rowt), contracted over BOTH super indices at once.  The naive
formulation gathers a per-query [mb, mb] block of D (O(q*mb^2) HBM
traffic); here D is streamed tile-by-tile through VMEM exactly once per
query tile and the [q, x, y] intermediate is never materialized.

TPU mapping (VPU work, no MXU form for (min,+)): grid is
(q tiles, y tiles, x tiles) with the two contraction axes innermost and
sequential, so the output tile is min-accumulated across all (x, y)
tile pairs (revisiting pattern).  Each invocation reduces its
[bq, bk1] x [bk1, bk2] x [bq, bk2] triple down to per-lane partial
minima [bq, 128]; the final cross-lane min happens outside the kernel
(a trivial [q, 128] -> [q] reduce).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _twoside_kernel(rows_ref, d_ref, rowt_ref, out_ref, *, k_chunk: int):
    """Min-accumulate one (q, y, x) tile triple into lane partials."""
    yi = pl.program_id(1)
    xi = pl.program_id(2)

    @pl.when((yi == 0) & (xi == 0))
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    rows = rows_ref[...]          # [bq, bk1]
    d = d_ref[...]                # [bk1, bk2]
    rowt = rowt_ref[...]          # [bq, bk2]
    bk1 = rows.shape[1]
    bq, bk2 = rowt.shape

    def body(i, acc):
        r_c = jax.lax.dynamic_slice_in_dim(rows, i * k_chunk, k_chunk,
                                           axis=1)
        d_c = jax.lax.dynamic_slice_in_dim(d, i * k_chunk, k_chunk,
                                           axis=0)
        # [bq, kc, bk2] broadcast add, min over the x chunk
        cand = jnp.min(r_c[:, :, None] + d_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    tmp = jax.lax.fori_loop(0, bk1 // k_chunk, body,
                            jnp.full((bq, bk2), jnp.inf, rows.dtype))
    tmp = tmp + rowt              # [bq, bk2]
    # fold the y tile down to its 128 lanes; cross-lane min is done by
    # the caller so every store here stays (8, 128)-aligned
    part = jnp.min(tmp.reshape(bq, bk2 // _LANES, _LANES), axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], part)


@functools.partial(jax.jit, static_argnames=("bq", "bk1", "bk2",
                                             "k_chunk", "interpret"))
def minplus_twoside_pallas(rows: jax.Array, d: jax.Array,
                           rowt: jax.Array, *, bq: int = 128,
                           bk1: int = 128, bk2: int = 128,
                           k_chunk: int = 8,
                           interpret: bool = False) -> jax.Array:
    """out[q] = min_{x,y} rows[q,x] + d[x,y] + rowt[q,y].

    Shapes: rows [q, k1], d [k1, k2], rowt [q, k2] -> out [q].
    Pads every axis to tile multiples with +inf (absorbing element).
    """
    q, k1 = rows.shape
    k1b, k2 = d.shape
    qb, k2b = rowt.shape
    assert k1 == k1b and k2 == k2b and q == qb, (rows.shape, d.shape,
                                                rowt.shape)
    assert bk2 % _LANES == 0 and bk1 % k_chunk == 0, (bk1, bk2, k_chunk)
    qp = -(-q // bq) * bq
    k1p = -(-k1 // bk1) * bk1
    k2p = -(-k2 // bk2) * bk2
    rows_p = jnp.full((qp, k1p), jnp.inf, rows.dtype).at[:q, :k1].set(rows)
    d_p = jnp.full((k1p, k2p), jnp.inf, d.dtype).at[:k1, :k2].set(d)
    rowt_p = jnp.full((qp, k2p), jnp.inf, rowt.dtype).at[:q, :k2].set(rowt)
    grid = (qp // bq, k2p // bk2, k1p // bk1)
    part = pl.pallas_call(
        functools.partial(_twoside_kernel, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bk1), lambda qi, yi, xi: (qi, xi)),
            pl.BlockSpec((bk1, bk2), lambda qi, yi, xi: (xi, yi)),
            pl.BlockSpec((bq, bk2), lambda qi, yi, xi: (qi, yi)),
        ],
        out_specs=pl.BlockSpec((bq, _LANES), lambda qi, yi, xi: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, _LANES), rows.dtype),
        interpret=interpret,
    )(rows_p, d_p, rowt_p)
    return jnp.min(part, axis=1)[:q]
