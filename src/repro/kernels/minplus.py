"""Tropical (min,+) GEMM Pallas kernel — the DISLAND combine step.

C[i, j] = min_k A[i, k] + B[k, j]

This is the query-time workhorse of the device engine: distances
node->boundary (A) combined with boundary->boundary SUPER distances (B)
is exactly a min-plus product (GraphBLAS shortest-distance semiring).

TPU mapping: (min,+) has no MXU form, so this is VPU work; tiles are
(8,128)-lane aligned and sized so A-tile + B-tile + C-tile + the [bm,
kc, bn] broadcast scratch stay well inside the ~16 MB VMEM budget.  The
K grid axis is innermost and sequential on TPU, so the output tile is
min-accumulated across K invocations (revisiting pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INIT = jnp.inf


def _minplus_kernel(a_ref, b_ref, c_ref, *, k_chunk: int):
    """One (bm x bn) output tile; min-accumulate over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = jnp.full_like(c_ref, _NEG_INIT)

    a = a_ref[...]            # [bm, bk]
    b = b_ref[...]            # [bk, bn]
    bk = a.shape[1]

    def body(i, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * k_chunk, k_chunk, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * k_chunk, k_chunk, axis=0)
        # [bm, kc, bn] broadcast add, min over kc
        cand = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    acc = jax.lax.fori_loop(0, bk // k_chunk, body, c_ref[...])
    c_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "k_chunk",
                                             "interpret"))
def minplus_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                   bn: int = 128, bk: int = 128, k_chunk: int = 8,
                   interpret: bool = False) -> jax.Array:
    """Tropical GEMM via Pallas; pads to tile multiples with +inf."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    a_p = jnp.full((mp, kp), jnp.inf, a.dtype).at[:m, :k].set(a)
    b_p = jnp.full((kp, np_), jnp.inf, b.dtype).at[:k, :n].set(b)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _minplus_accum_kernel(c_in_ref, a_ref, b_ref, c_ref, *, k_chunk: int):
    """C = min(C_in, A (x) B) — used by the blocked Floyd-Warshall
    phases 2/3, where the output tile must fold into existing distances."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_ref[...] = c_in_ref[...]

    a = a_ref[...]
    b = b_ref[...]
    bk = a.shape[1]

    def body(i, acc):
        a_c = jax.lax.dynamic_slice_in_dim(a, i * k_chunk, k_chunk, axis=1)
        b_c = jax.lax.dynamic_slice_in_dim(b, i * k_chunk, k_chunk, axis=0)
        cand = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    c_ref[...] = jax.lax.fori_loop(0, bk // k_chunk, body, c_ref[...])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "k_chunk",
                                             "interpret"))
def minplus_accum_pallas(c: jax.Array, a: jax.Array, b: jax.Array, *,
                         bm: int = 128, bn: int = 128, bk: int = 128,
                         k_chunk: int = 8,
                         interpret: bool = False) -> jax.Array:
    """min(C, A (x) B) with +inf padding; shapes C[m,n] A[m,k] B[k,n]."""
    m, k = a.shape
    _, n = b.shape
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    a_p = jnp.full((mp, kp), jnp.inf, a.dtype).at[:m, :k].set(a)
    b_p = jnp.full((kp, np_), jnp.inf, b.dtype).at[:k, :n].set(b)
    c_p = jnp.full((mp, np_), jnp.inf, c.dtype).at[:m, :n].set(c)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_minplus_accum_kernel, k_chunk=k_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), c.dtype),
        interpret=interpret,
    )(c_p, a_p, b_p)
    return out[:m, :n]
