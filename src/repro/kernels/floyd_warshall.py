"""Blocked Floyd-Warshall APSP Pallas kernels.

Three entry points:

  * ``fw_batch_pallas``  — grid over a batch of small dense matrices
    (DISLAND fragments, padded to a common size <= 256); the whole
    [nf, nf] tile lives in VMEM and a fori_loop runs the classic FW
    recurrence with a functional carry.

  * ``fw_batch_next_pallas`` — the same, additionally carrying the
    first-hop successor matrix (int32) for exact path reconstruction
    (DESIGN.md §10); distances come out bit-identical.

  * ``fw_blocked``       — classic 3-phase blocked FW for one larger
    matrix: phase 1 = diagonal-block FW (this kernel), phases 2/3 =
    min-plus accumulate tiles (minplus.minplus_accum_pallas).  Used for
    the SUPER-graph boundary x boundary matrix.

Float32, +inf = unreachable; diagonal forced to 0 on entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .minplus import minplus_accum_pallas
from .ref import fw_next_init


def _fw_block_kernel(d_ref, o_ref):
    """In-VMEM Floyd-Warshall on one [nf, nf] tile (leading batch of 1)."""
    x = d_ref[0]
    n = x.shape[0]

    def body(k, mat):
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)  # [1, n]
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)  # [n, 1]
        return jnp.minimum(mat, col + row)

    o_ref[0] = jax.lax.fori_loop(0, n, body, x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fw_batch_pallas(d: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Batched APSP: d[b, n, n] -> shortest distances per batch entry."""
    b, n, n2 = d.shape
    assert n == n2
    # zero the diagonals (distance to self)
    eye = jnp.eye(n, dtype=bool)
    d = jnp.where(eye[None], 0.0, d)
    return pl.pallas_call(
        _fw_block_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), d.dtype),
        interpret=interpret,
    )(d)


def _fw_next_block_kernel(d_ref, n_ref, do_ref, no_ref):
    """Witness-carrying FW on one [nf, nf] tile: alongside the distance
    recurrence, carry nxt[i, j] = first hop of a shortest i -> j path
    (int32, -1 = unreachable/diagonal).  Same strict-improvement update
    in the same pivot order as _fw_block_kernel, so the distance output
    is bit-identical — path tables can ride along any build without
    perturbing the distances the rest of the index is tested against."""
    x = d_ref[0]
    nx0 = n_ref[0]
    n = x.shape[0]

    def body(k, carry):
        mat, nxt = carry
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)  # [1, n]
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)  # [n, 1]
        cand = col + row
        nk = jax.lax.dynamic_slice_in_dim(nxt, k, 1, axis=1)   # nxt[:, k]
        better = cand < mat
        return (jnp.where(better, cand, mat),
                jnp.where(better, jnp.broadcast_to(nk, nxt.shape), nxt))

    mat, nxt = jax.lax.fori_loop(0, n, body, (x, nx0))
    do_ref[0] = mat
    no_ref[0] = nxt


@functools.partial(jax.jit, static_argnames=("interpret",))
def fw_batch_next_pallas(d: jax.Array, *, interpret: bool = False
                         ) -> tuple[jax.Array, jax.Array]:
    """Batched witness APSP: d[b, n, n] -> (dist, nxt) per batch entry."""
    b, n, n2 = d.shape
    assert n == n2
    d0, nxt0 = fw_next_init(d)
    return pl.pallas_call(
        _fw_next_block_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, n, n), d.dtype),
                   jax.ShapeDtypeStruct((b, n, n), jnp.int32)],
        interpret=interpret,
    )(d0, nxt0)


def _fw_diag(d_kk: jax.Array, interpret: bool) -> jax.Array:
    return fw_batch_pallas(d_kk[None], interpret=interpret)[0]


def fw_blocked(d: jax.Array, *, block: int = 128,
               interpret: bool = False) -> jax.Array:
    """3-phase blocked Floyd-Warshall for one [n, n] matrix.

    Pads to a block multiple with +inf.  Per k-block:
      phase 1: FW on the diagonal block D[kk]
      phase 2: D[k, *] = min(D[k, *], D[kk] (x) D[k, *]);
               D[*, k] = min(D[*, k], D[*, k] (x) D[kk])
      phase 3: D = min(D, D[*, k] (x) D[k, *])
    """
    n = d.shape[0]
    np_ = -(-n // block) * block
    pad = jnp.full((np_, np_), jnp.inf, d.dtype)
    pad = pad.at[:n, :n].set(d)
    eye = jnp.eye(np_, dtype=bool)
    pad = jnp.where(eye, 0.0, pad)
    nb = np_ // block
    for kb in range(nb):
        s = kb * block
        dkk = _fw_diag(jax.lax.dynamic_slice(pad, (s, s), (block, block)),
                       interpret)
        pad = jax.lax.dynamic_update_slice(pad, dkk, (s, s))
        row = jax.lax.dynamic_slice(pad, (s, 0), (block, np_))
        row = minplus_accum_pallas(row, dkk, row, bm=block, bn=block,
                                   bk=block, interpret=interpret)
        pad = jax.lax.dynamic_update_slice(pad, row, (s, 0))
        col = jax.lax.dynamic_slice(pad, (0, s), (np_, block))
        col = minplus_accum_pallas(col, col, dkk, bm=block, bn=block,
                                   bk=block, interpret=interpret)
        pad = jax.lax.dynamic_update_slice(pad, col, (0, s))
        pad = minplus_accum_pallas(pad, col, row, bm=block, bn=block,
                                   bk=block, interpret=interpret)
    return pad[:n, :n]
