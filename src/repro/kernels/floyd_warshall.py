"""Blocked Floyd-Warshall APSP Pallas kernels.

Two entry points:

  * ``fw_batch_pallas``  — grid over a batch of small dense matrices
    (DISLAND fragments, padded to a common size <= 256); the whole
    [nf, nf] tile lives in VMEM and a fori_loop runs the classic FW
    recurrence with a functional carry.

  * ``fw_blocked``       — classic 3-phase blocked FW for one larger
    matrix: phase 1 = diagonal-block FW (this kernel), phases 2/3 =
    min-plus accumulate tiles (minplus.minplus_accum_pallas).  Used for
    the SUPER-graph boundary x boundary matrix.

Float32, +inf = unreachable; diagonal forced to 0 on entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .minplus import minplus_accum_pallas


def _fw_block_kernel(d_ref, o_ref):
    """In-VMEM Floyd-Warshall on one [nf, nf] tile (leading batch of 1)."""
    x = d_ref[0]
    n = x.shape[0]

    def body(k, mat):
        row = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=0)  # [1, n]
        col = jax.lax.dynamic_slice_in_dim(mat, k, 1, axis=1)  # [n, 1]
        return jnp.minimum(mat, col + row)

    o_ref[0] = jax.lax.fori_loop(0, n, body, x)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fw_batch_pallas(d: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Batched APSP: d[b, n, n] -> shortest distances per batch entry."""
    b, n, n2 = d.shape
    assert n == n2
    # zero the diagonals (distance to self)
    eye = jnp.eye(n, dtype=bool)
    d = jnp.where(eye[None], 0.0, d)
    return pl.pallas_call(
        _fw_block_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), d.dtype),
        interpret=interpret,
    )(d)


def _fw_diag(d_kk: jax.Array, interpret: bool) -> jax.Array:
    return fw_batch_pallas(d_kk[None], interpret=interpret)[0]


def fw_blocked(d: jax.Array, *, block: int = 128,
               interpret: bool = False) -> jax.Array:
    """3-phase blocked Floyd-Warshall for one [n, n] matrix.

    Pads to a block multiple with +inf.  Per k-block:
      phase 1: FW on the diagonal block D[kk]
      phase 2: D[k, *] = min(D[k, *], D[kk] (x) D[k, *]);
               D[*, k] = min(D[*, k], D[*, k] (x) D[kk])
      phase 3: D = min(D, D[*, k] (x) D[k, *])
    """
    n = d.shape[0]
    np_ = -(-n // block) * block
    pad = jnp.full((np_, np_), jnp.inf, d.dtype)
    pad = pad.at[:n, :n].set(d)
    eye = jnp.eye(np_, dtype=bool)
    pad = jnp.where(eye, 0.0, pad)
    nb = np_ // block
    for kb in range(nb):
        s = kb * block
        dkk = _fw_diag(jax.lax.dynamic_slice(pad, (s, s), (block, block)),
                       interpret)
        pad = jax.lax.dynamic_update_slice(pad, dkk, (s, s))
        row = jax.lax.dynamic_slice(pad, (s, 0), (block, np_))
        row = minplus_accum_pallas(row, dkk, row, bm=block, bn=block,
                                   bk=block, interpret=interpret)
        pad = jax.lax.dynamic_update_slice(pad, row, (s, 0))
        col = jax.lax.dynamic_slice(pad, (0, s), (np_, block))
        col = minplus_accum_pallas(col, col, dkk, bm=block, bn=block,
                                   bk=block, interpret=interpret)
        pad = jax.lax.dynamic_update_slice(pad, col, (0, s))
        pad = minplus_accum_pallas(pad, col, row, bm=block, bn=block,
                                   bk=block, interpret=interpret)
    return pad[:n, :n]
