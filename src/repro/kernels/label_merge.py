"""Hub-label merge — the hot-tier combine step (DESIGN.md §15).

    out[q] = min_j labs[q, j] + labt[q, j]

Each labeled endpoint carries a dense label row over the TOP closure
coordinates (device_engine.hub_stage); answering a gated pair is one
elementwise tropical product of the two gathered rows followed by a row
min — O(W) per query instead of the planner cross path's O(W^2)
two-sided contraction.

TPU mapping (VPU work, same conventions as minplus_twoside): grid is
(q tiles, j tiles) with the contraction axis innermost and sequential,
so the output tile is min-accumulated across all j tiles (revisiting
pattern).  Each invocation folds its [bq, bj] add down to per-lane
partial minima [bq, 128]; the final cross-lane min happens outside the
kernel.  Padding is +inf (absorbing element), so padded queries and
padded label columns can never win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _merge_kernel(labs_ref, labt_ref, out_ref):
    """Min-accumulate one (q, j) tile pair into lane partials."""
    ji = pl.program_id(1)

    @pl.when(ji == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    tmp = labs_ref[...] + labt_ref[...]      # [bq, bj]
    bq, bj = tmp.shape
    # fold the j tile down to its 128 lanes; cross-lane min is done by
    # the caller so every store here stays (8, 128)-aligned
    part = jnp.min(tmp.reshape(bq, bj // _LANES, _LANES), axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], part)


@functools.partial(jax.jit, static_argnames=("bq", "bj", "interpret"))
def label_merge_pallas(labs: jax.Array, labt: jax.Array, *,
                       bq: int = 128, bj: int = 512,
                       interpret: bool = False) -> jax.Array:
    """out[q] = min_j labs[q, j] + labt[q, j].

    Shapes: labs [q, W], labt [q, W] -> out [q].  Pads both axes to
    tile multiples with +inf (absorbing element).
    """
    q, w = labs.shape
    qb, wb = labt.shape
    assert q == qb and w == wb, (labs.shape, labt.shape)
    assert bj % _LANES == 0, bj
    qp = -(-q // bq) * bq
    wp = -(-w // bj) * bj
    labs_p = jnp.full((qp, wp), jnp.inf,
                      labs.dtype).at[:q, :w].set(labs)
    labt_p = jnp.full((qp, wp), jnp.inf,
                      labt.dtype).at[:q, :w].set(labt)
    grid = (qp // bq, wp // bj)
    part = pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bj), lambda qi, ji: (qi, ji)),
            pl.BlockSpec((bq, bj), lambda qi, ji: (qi, ji)),
        ],
        out_specs=pl.BlockSpec((bq, _LANES), lambda qi, ji: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((qp, _LANES), labs.dtype),
        interpret=interpret,
    )(labs_p, labt_p)
    return jnp.min(part, axis=1)[:q]
