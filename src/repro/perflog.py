"""Append-only JSON perf records (BENCH_serve.json and friends).

One list-of-dicts file per metric family; every serving/benchmark run
appends, so the cross-PR trajectory stays in one place.  A corrupt or
missing file degrades to an empty history instead of failing the run.

Appends are crash-safe and concurrency-safe: the new history is
written to a temp file in the same directory and swapped in with
``os.replace`` (readers always see a complete JSON — a crash mid-write
can no longer truncate the committed history to ``[]``), and the whole
read-modify-write is serialized through an ``fcntl`` lock on a sidecar
``<path>.lock`` file, so concurrent appenders (live serve loop +
refresh loop, or two processes) compose instead of losing records.
"""
from __future__ import annotations

import contextlib
import json
import os
import tempfile
from typing import List

try:
    import fcntl
except ImportError:          # non-POSIX: atomic replace still holds
    fcntl = None


def read_records(path: str) -> List[dict]:
    """Full history at ``path`` ([] on missing/corrupt, same policy as
    append_records)."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            out = json.load(f)
        return out if isinstance(out, list) else []
    except (json.JSONDecodeError, OSError):
        return []


def latest(path: str, **filters) -> dict | None:
    """Most recent record whose fields match ``filters`` exactly, e.g.
    ``latest("BENCH_serve.json", section="refresh", graph="road4000")``.
    Serving/benchmark drivers use it to print the cross-PR delta next
    to a fresh measurement."""
    for rec in reversed(read_records(path)):
        if all(rec.get(k) == v for k, v in filters.items()):
            return rec
    return None


@contextlib.contextmanager
def _append_lock(path: str):
    """Exclusive advisory lock serializing read-modify-write cycles.
    ``flock`` locks the open file description, so two opens of the
    sidecar — same process or different ones — exclude each other."""
    if fcntl is None:
        yield
        return
    with open(path + ".lock", "a") as lk:
        fcntl.flock(lk, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lk, fcntl.LOCK_UN)


def append_records(path: str, records: List[dict]) -> None:
    """Append ``records`` to the history at ``path`` atomically: the
    merged list lands via temp-file + ``os.replace`` under the append
    lock, so neither a crash mid-write nor a concurrent appender can
    corrupt or drop committed history."""
    with _append_lock(path):
        existing = read_records(path)
        d = os.path.dirname(os.path.abspath(path)) or "."
        fd, tmp = tempfile.mkstemp(
            prefix="." + os.path.basename(path) + ".", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(existing + records, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
