"""Append-only JSON perf records (BENCH_serve.json and friends).

One list-of-dicts file per metric family; every serving/benchmark run
appends, so the cross-PR trajectory stays in one place.  A corrupt or
missing file degrades to an empty history instead of failing the run.
"""
from __future__ import annotations

import json
import os
from typing import List


def read_records(path: str) -> List[dict]:
    """Full history at ``path`` ([] on missing/corrupt, same policy as
    append_records)."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            out = json.load(f)
        return out if isinstance(out, list) else []
    except (json.JSONDecodeError, OSError):
        return []


def latest(path: str, **filters) -> dict | None:
    """Most recent record whose fields match ``filters`` exactly, e.g.
    ``latest("BENCH_serve.json", section="refresh", graph="road4000")``.
    Serving/benchmark drivers use it to print the cross-PR delta next
    to a fresh measurement."""
    for rec in reversed(read_records(path)):
        if all(rec.get(k) == v for k, v in filters.items()):
            return rec
    return None


def append_records(path: str, records: List[dict]) -> None:
    existing = read_records(path)
    with open(path, "w") as f:
        json.dump(existing + records, f, indent=1)
