"""Append-only JSON perf records (BENCH_serve.json and friends).

One list-of-dicts file per metric family; every serving/benchmark run
appends, so the cross-PR trajectory stays in one place.  A corrupt or
missing file degrades to an empty history instead of failing the run.
"""
from __future__ import annotations

import json
import os
from typing import List


def append_records(path: str, records: List[dict]) -> None:
    existing: list = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = []
    with open(path, "w") as f:
        json.dump(existing + records, f, indent=1)
