"""jax version-portability shims.

The container pins jax 0.4.x, where ``shard_map`` still lives under
``jax.experimental`` and ``Mesh`` has no ``axis_types``.  Newer jax
moves both into the public namespace; these helpers pick whichever is
available so the rest of the codebase stays version-agnostic.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.6
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types wherever the API supports it."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)
