"""Fault-tolerant checkpointing: atomic commits, retention, restore.

Layout per step:
    <dir>/step_000123.tmp-<pid>/   (write in progress)
        shard_000.npz              (flattened leaves, chunked)
        manifest.json              (treedef, leaf shapes/dtypes, step)
    <dir>/step_000123/             (atomic rename = commit)

Crash safety: a partially written checkpoint never carries the committed
name, so restore() only ever sees complete checkpoints; stale .tmp dirs
are garbage-collected on the next save.  Restore can re-shard onto a
*different* mesh (elastic restart): arrays are loaded on host then
device_put with the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 chunk_leaves: int = 64):
        self.dir = directory
        self.keep = keep
        self.chunk = chunk_leaves
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> str:
        self._gc_tmp()
        leaves, treedef = jax.tree_util.tree_flatten(state)
        tmp = self._step_dir(step) + f".tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        host = [np.asarray(x) for x in leaves]
        for ci in range(0, len(host), self.chunk):
            chunk = host[ci:ci + self.chunk]
            np.savez(os.path.join(tmp, f"shard_{ci // self.chunk:03d}.npz"),
                     **{f"leaf_{ci + j}": a for j, a in enumerate(chunk)})
        manifest = {
            "step": step,
            "n_leaves": len(host),
            "treedef": str(treedef),
            "dtypes": [str(a.dtype) for a in host],
            "shapes": [list(a.shape) for a in host],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._retain()
        return final

    def restore(self, like: Any, step: Optional[int] = None, *,
                shardings: Any = None) -> tuple[int, Any]:
        """-> (step, state).

        ``like``: a pytree with the target structure (e.g. from
        jax.eval_shape on the init function) — the manifest stores leaf
        metadata but the tree structure comes from the caller, which is
        what makes restore work across code versions and custom nodes.
        ``shardings``: optional pytree of NamedSharding for elastic
        re-mesh restore (arrays land host-side then device_put)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        treedef = jax.tree_util.tree_structure(like)
        if treedef.num_leaves != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, target "
                f"structure has {treedef.num_leaves}")
        leaves: list[Any] = [None] * manifest["n_leaves"]
        for name in sorted(os.listdir(d)):
            if not name.startswith("shard_"):
                continue
            with np.load(os.path.join(d, name)) as z:
                for key in z.files:
                    leaves[int(key.split("_")[1])] = z[key]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return step, state

    # ------------------------------------------------------------------
    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
