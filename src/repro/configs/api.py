"""Architecture registry: one ArchSpec per assigned architecture.

Every spec carries the exact published dimensions plus per-arch launch
knobs (microbatching granularity, attention chunking) that the cell
builder (launch/cells.py) consumes.  Shapes are the assignment's own
shape sets; sharded leading dims are padded to multiples of 512 so both
the 256-chip and 512-chip meshes divide them (JAX requires divisible
shardings; padding is recorded per cell).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

PAD_TO = 512  # lcm of both production mesh sizes


def pad_up(x: int, mult: int = PAD_TO) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                  # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                # lm | gnn | recsys
    model_cfg: Any
    shapes: Tuple[ShapeCell, ...]
    # launch knobs
    seqs_per_micro: int = 4    # LM grad-accum granularity (per device)
    opt_state_dtype: str = "float32"  # "bfloat16" halves AdamW moments
    serialize_opt_update: bool = False  # chain leaf updates (mem peak)
    grad_accum_dtype: str = "float32"  # bf16 halves the accum tree (104B)
    notes: str = ""

    def shape(self, name: str) -> ShapeCell:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}; "
                       f"have {[s.name for s in self.shapes]}")


# ---- canonical shape sets --------------------------------------------------
def lm_shapes() -> Tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_4k", "train",
                  {"seq_len": 4096, "global_batch": 256}),
        ShapeCell("prefill_32k", "prefill",
                  {"seq_len": 32768, "global_batch": 32}),
        ShapeCell("decode_32k", "decode",
                  {"seq_len": 32768, "global_batch": 128}),
        ShapeCell("long_500k", "decode",
                  {"seq_len": 524288, "global_batch": 1, "shard_seq": 1}),
    )


def gnn_shapes() -> Tuple[ShapeCell, ...]:
    # edge counts are directed (x2 undirected); all padded to 512
    return (
        ShapeCell("full_graph_sm", "train",
                  {"n_nodes": pad_up(2708), "n_edges": pad_up(2 * 10556),
                   "d_feat": 1433, "n_graphs": 1}),
        ShapeCell("minibatch_lg", "train",
                  {"n_nodes": pad_up(1024 * (1 + 15 + 150)),
                   "n_edges": pad_up(1024 * 15 + 1024 * 150),
                   "d_feat": 602, "n_graphs": 1}),
        ShapeCell("ogb_products", "train",
                  {"n_nodes": pad_up(2_449_029),
                   "n_edges": pad_up(2 * 61_859_140),
                   "d_feat": 100, "n_graphs": 1}),
        ShapeCell("molecule", "train",
                  {"n_nodes": pad_up(128 * 30), "n_edges": pad_up(2 * 64 * 128),
                   "d_feat": 32, "n_graphs": 128}),
    )


def recsys_shapes() -> Tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_batch", "train", {"batch": 65536}),
        ShapeCell("serve_p99", "serve", {"batch": 512}),
        ShapeCell("serve_bulk", "serve", {"batch": 262144}),
        ShapeCell("retrieval_cand", "retrieval",
                  {"batch": 1, "n_candidates": pad_up(1_000_000)}),
    )
