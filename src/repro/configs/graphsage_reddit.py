"""graphsage-reddit [gnn]: 2 layers d128 mean aggregator, fanout 25-10
[arXiv:1706.02216]."""
from ..models.gnn import GNNConfig
from .api import ArchSpec, gnn_shapes

SPEC = ArchSpec(
    arch_id="graphsage-reddit", family="gnn",
    model_cfg=GNNConfig(name="graphsage-reddit", arch="graphsage",
                        n_layers=2, d_hidden=128, d_feat=602,
                        n_classes=41, aggregator="mean"),
    shapes=gnn_shapes())
