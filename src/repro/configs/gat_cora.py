"""gat-cora [gnn]: 2 layers, 8 heads x d8, attention aggregator
[arXiv:1710.10903]."""
from ..models.gnn import GNNConfig
from .api import ArchSpec, gnn_shapes

SPEC = ArchSpec(
    arch_id="gat-cora", family="gnn",
    model_cfg=GNNConfig(name="gat-cora", arch="gat", n_layers=2,
                        d_hidden=8, n_heads=8, d_feat=1433, n_classes=7,
                        aggregator="attn"),
    shapes=gnn_shapes())
