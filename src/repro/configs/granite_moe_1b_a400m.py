"""granite-moe-1b-a400m [moe]: 24L d1024 16H (GQA kv=8) ff512/expert
vocab 49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m]."""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .api import ArchSpec, lm_shapes

SPEC = ArchSpec(
    arch_id="granite-moe-1b-a400m", family="lm",
    model_cfg=LMConfig(name="granite-moe-1b-a400m", n_layers=24,
                       d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
                       vocab=49155, moe=True, n_experts=32, top_k=8,
                       rope_theta=10_000.0, dtype=jnp.bfloat16,
                       attn_chunk=1024),
    shapes=lm_shapes(), seqs_per_micro=2,
    notes="32 experts / 16 ranks = 2 experts per rank; vocab 49155 is "
          "padded to 49408 (multiple of 256) for the TP vocab shard.")
