"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

from . import (command_r_plus_104b, dimenet, gat_cora, granite_8b,
               granite_moe_1b_a400m, graphcast, graphsage_reddit,
               llama4_scout_17b_a16e, phi4_mini_3_8b, wide_deep)
from .api import ArchSpec, ShapeCell

_ALL = [granite_8b.SPEC, command_r_plus_104b.SPEC, phi4_mini_3_8b.SPEC,
        llama4_scout_17b_a16e.SPEC, granite_moe_1b_a400m.SPEC,
        graphcast.SPEC, dimenet.SPEC, graphsage_reddit.SPEC,
        gat_cora.SPEC, wide_deep.SPEC]

REGISTRY = {s.arch_id: s for s in _ALL}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id}; have {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def list_archs():
    return sorted(REGISTRY)
