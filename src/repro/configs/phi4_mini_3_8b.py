"""phi4-mini-3.8b [dense]: 32L d3072 24H (GQA kv=8) ff8192 vocab 200064.
RoPE SwiGLU GQA [arXiv:2412.08905]."""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .api import ArchSpec, lm_shapes

SPEC = ArchSpec(
    arch_id="phi4-mini-3.8b", family="lm",
    model_cfg=LMConfig(name="phi4-mini-3.8b", n_layers=32, d_model=3072,
                       n_heads=24, n_kv_heads=8, d_ff=8192, vocab=200064,
                       rope_theta=10_000.0, dtype=jnp.bfloat16,
                       attn_chunk=128),
    shapes=lm_shapes(), seqs_per_micro=4,
    notes="24 heads %% 16 != 0 -> attention replicated over model axis "
          "(FFN/vocab still TP); smaller attn_chunk bounds score tiles.")
