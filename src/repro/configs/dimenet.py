"""dimenet [gnn]: 6 interaction blocks, d128, 8 bilinear, 7 spherical x
6 radial bases; triplet directional message passing [arXiv:2003.03123].
Triplet count is capped at 2 x n_edges (GemNet-style angular sampling) —
recorded in DESIGN.md §Arch-applicability."""
from ..models.gnn import GNNConfig
from .api import ArchSpec, gnn_shapes

SPEC = ArchSpec(
    arch_id="dimenet", family="gnn",
    model_cfg=GNNConfig(name="dimenet", arch="dimenet", n_layers=6,
                        d_hidden=128, d_feat=32, n_bilinear=8,
                        n_spherical=7, n_radial=6, n_out=1),
    shapes=gnn_shapes())
