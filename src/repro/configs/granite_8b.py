"""granite-8b [dense]: 36L d4096 32H (GQA kv=8) ff14336 vocab 49152.
Llama-arch code model [arXiv:2405.04324]."""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .api import ArchSpec, lm_shapes

SPEC = ArchSpec(
    arch_id="granite-8b", family="lm",
    model_cfg=LMConfig(name="granite-8b", n_layers=36, d_model=4096,
                       n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49152,
                       rope_theta=10_000_000.0, dtype=jnp.bfloat16,
                       attn_chunk=1024, zero_stage=1,
                       remat_policy="save_tp_outputs"),
    shapes=lm_shapes(), seqs_per_micro=1,
    notes="heads 32 %% 16 == 0 -> TP on heads. ZeRO-1: bf16 params "
          "(1 GB/dev at tp=16) replicate over data, opt state sharded "
          "— kills the per-layer FSDP all-gathers (EXPERIMENTS §Perf "
          "P1); save_tp_outputs remat keeps the per-layer all-reduced "
          "tensors so the recompute pass skips their collectives (P1b).")
