"""llama4-scout-17b-a16e [moe]: 48L d5120 40H (GQA kv=8) ff8192
vocab 202048, MoE 16 experts top-1 [hf:meta-llama/Llama-4-Scout-17B-16E]."""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .api import ArchSpec, lm_shapes

SPEC = ArchSpec(
    arch_id="llama4-scout-17b-a16e", family="lm",
    model_cfg=LMConfig(name="llama4-scout-17b-a16e", n_layers=48,
                       d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
                       vocab=202048, moe=True, n_experts=16, top_k=1,
                       rope_theta=500_000.0, dtype=jnp.bfloat16,
                       attn_chunk=128, gather_fsdp_in_body=True,
                       seq_shard_activations=True),
    shapes=lm_shapes(), seqs_per_micro=1,
    opt_state_dtype="bfloat16", serialize_opt_update=True,
    grad_accum_dtype="bfloat16",
    notes="EP: 16 experts == model axis -> 1 expert/rank; 40 heads not "
          "divisible by 16 -> attention replicated over model.")
