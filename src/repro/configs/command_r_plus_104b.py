"""command-r-plus-104b [dense]: 64L d12288 96H (GQA kv=8) ff33792
vocab 256000, no biases [hf:CohereForAI/c4ai-command-r-v01]."""
import jax.numpy as jnp
from ..models.transformer import LMConfig
from .api import ArchSpec, lm_shapes

SPEC = ArchSpec(
    arch_id="command-r-plus-104b", family="lm",
    model_cfg=LMConfig(name="command-r-plus-104b", n_layers=64,
                       d_model=12288, n_heads=96, n_kv_heads=8,
                       d_ff=33792, vocab=256000, rope_theta=75_000_000.0,
                       dtype=jnp.bfloat16, attn_chunk=1024,
                       gather_fsdp_in_body=True,
                       seq_shard_activations=True),
    shapes=lm_shapes(), seqs_per_micro=1,
    opt_state_dtype="bfloat16", serialize_opt_update=True,
    grad_accum_dtype="bfloat16",
    notes="104B dense: ZeRO-3 FSDP on data + TP on model is mandatory "
          "for 16 GB chips; 1 seq/device per microbatch.")
