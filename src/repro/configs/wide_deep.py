"""wide-deep [recsys]: 40 sparse fields x 1M rows x dim32 tables,
MLP 1024-512-256, concat interaction [arXiv:1606.07792]."""
from ..models.recsys import RecsysConfig
from .api import ArchSpec, recsys_shapes

SPEC = ArchSpec(
    arch_id="wide-deep", family="recsys",
    model_cfg=RecsysConfig(name="wide-deep", n_sparse=40, n_dense=13,
                           embed_dim=32, rows_per_field=1_000_000,
                           hots_per_field=2, mlp_dims=(1024, 512, 256),
                           interaction="concat"),
    shapes=recsys_shapes(),
    notes="embedding tables row-sharded on model axis; EmbeddingBag = "
          "take + segment_sum (no native op in JAX).")
