"""graphcast [gnn]: 16-layer encoder-processor-decoder mesh GNN,
d_hidden 512, sum aggregation, 227 vars [arXiv:2212.12794]."""
from ..models.gnn import GNNConfig
from .api import ArchSpec, gnn_shapes

SPEC = ArchSpec(
    arch_id="graphcast", family="gnn",
    model_cfg=GNNConfig(name="graphcast", arch="graphcast", n_layers=16,
                        d_hidden=512, d_feat=227, n_out=227,
                        aggregator="sum"),
    shapes=gnn_shapes(),
    notes="mesh_refinement=6 maps to the mesh graph the shape provides; "
          "n_vars=227 is the node-feature/output width.  Per-shape "
          "d_feat overrides n_vars where the shape pins it.")
