"""Fault tolerance: straggler detection, failure injection, elastic
restart (checkpoint -> smaller mesh -> resharded resume).

On real fleets node loss surfaces as a NCCL/ICI timeout; in this
single-process harness FailureInjector raises at a chosen step and
ElasticTrainer demonstrates the full recovery path the production
runbook needs: catch -> rebuild mesh without the lost slice -> restore
the latest checkpoint with the new shardings -> continue stepping.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager


class SimulatedNodeFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_step: Optional[int] = None
    failed: bool = False

    def check(self, step: int) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not self.failed):
            self.failed = True
            raise SimulatedNodeFailure(f"node lost at step {step}")


class StragglerMonitor:
    """Tracks per-step wall time; flags outliers > k x running median.

    On a real fleet the flagged ranks feed the backup-task policy
    (re-dispatch the step's shard elsewhere); here the monitor is the
    observability piece and is unit-tested on synthetic timings."""

    def __init__(self, factor: float = 3.0, window: int = 50):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        return self.observe(dt)

    def observe(self, dt: float) -> bool:
        """Record a step duration; True if it is a straggler step."""
        hist = self.times[-self.window:]
        is_straggler = (len(hist) >= 8
                        and dt > self.factor * float(np.median(hist)))
        self.times.append(dt)
        if is_straggler:
            self.flagged.append(len(self.times) - 1)
        return is_straggler

    def summary(self) -> dict:
        arr = np.array(self.times) if self.times else np.zeros(1)
        return {"steps": len(self.times), "median_s": float(np.median(arr)),
                "p99_s": float(np.percentile(arr, 99)),
                "stragglers": len(self.flagged)}


@dataclasses.dataclass
class ElasticTrainer:
    """Checkpoint/restart loop with elastic re-meshing.

    make_mesh(n_devices) -> mesh; make_step(mesh) -> (step_fn, state
    shardings); the trainer catches SimulatedNodeFailure, shrinks the
    device pool, rebuilds everything and restores the newest checkpoint.
    """
    ckpt: CheckpointManager
    make_mesh: Callable[[int], Any]
    make_step: Callable[[Any], tuple]
    init_state: Callable[[Any], Any]
    checkpoint_every: int = 10

    def run(self, n_steps: int, batches, *,
            injector: Optional[FailureInjector] = None,
            monitor: Optional[StragglerMonitor] = None) -> dict:
        n_dev = len(jax.devices())
        mesh = self.make_mesh(n_dev)
        step_fn, shardings = self.make_step(mesh)
        state = self.init_state(mesh)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            start, state = self.ckpt.restore(state, shardings=shardings)
        restarts = 0
        step = start
        while step < n_steps:
            batch = next(batches)
            try:
                if injector is not None:
                    injector.check(step)
                if monitor is not None:
                    monitor.start()
                state = step_fn(state, batch)
                if monitor is not None:
                    monitor.stop()
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save(step, state)
            except SimulatedNodeFailure:
                restarts += 1
                n_dev = max(1, n_dev // 2)     # lost a slice: shrink
                mesh = self.make_mesh(n_dev)
                step_fn, shardings = self.make_step(mesh)
                state = self.init_state(mesh)
                latest = self.ckpt.latest_step()
                if latest is not None:
                    step, state = self.ckpt.restore(state,
                                                    shardings=shardings)
                else:
                    step = 0
        self.ckpt.save(step, state)
        return {"final_step": step, "restarts": restarts,
                "devices": n_dev}
