from .fault import (ElasticTrainer, FailureInjector, StragglerMonitor)

__all__ = ["ElasticTrainer", "FailureInjector", "StragglerMonitor"]
