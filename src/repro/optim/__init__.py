from .adamw import (AdamWState, adamw_init, adamw_update, cosine_schedule,
                    global_norm_clip)
from .compress import (compressed_psum, dequantize_int8, quantize_int8)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm_clip", "quantize_int8", "dequantize_int8",
           "compressed_psum"]
