"""Gradient compression: int8 quantized all-reduce (wire-size 4x cut).

Used by the explicit-DDP training variant (shard_map grad sync): each
tensor is quantized to int8 with one fp32 absmax scale, psum'd in int32
(no overflow for <= 2^23 replicas), and dequantized with the psum'd
scale average.  Error is bounded by absmax/127 per element per step —
tests assert the end-to-end bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str | tuple) -> jax.Array:
    """Mean over ``axis_name`` replicas with int8 wire format.

    Every replica quantizes with its own scale; int32 accumulation uses
    the max scale (psum of per-replica scale maxima) so the dequant is
    conservative-correct.  Call inside shard_map."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    smax = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / smax), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * smax / n.astype(jnp.float32)
