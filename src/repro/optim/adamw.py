"""Sharded AdamW + schedules + global-norm clipping.

States (m, v) are fp32 regardless of param dtype and inherit the param
PartitionSpecs, so FSDP-sharded params get FSDP-sharded optimizer states
(ZeRO-style) for free through in_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AdamWState:
    m: Any
    v: Any
    step: jax.Array

    def tree_flatten(self):
        return (self.m, self.v, self.step), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    """``state_dtype=bfloat16`` halves optimizer memory — the moments are
    accumulated in fp32 inside the update and rounded on store (the
    standard 100B-scale trick)."""
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, state_dtype), params)
    return AdamWState(m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros),
                      step=jnp.zeros((), jnp.int32))


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01, max_norm: float = 1.0,
                 serialize: bool = False, grad_scale: float = 1.0):
    """-> (new_params, new_state, metrics). ``lr`` is a scalar or a
    schedule callable of the step.

    The clip scale is folded into the per-leaf update instead of
    materialising a scaled copy of the whole gradient tree (saves one
    full fp32 grad buffer on 100B-scale models).

    ``serialize=True`` chains the per-leaf updates through
    optimization_barrier so the scheduler cannot hold every leaf's fp32
    intermediates live at once — measured 8 GB/device on the 104B arch
    (EXPERIMENTS.md §Perf iteration M4)."""
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves)) * grad_scale
    clip = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9)) \
        * grad_scale
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        sdt = m.dtype
        g = g.astype(jnp.float32) * clip
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay \
            * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr_t * delta
        return newp.astype(p.dtype), m.astype(sdt), v.astype(sdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = []
    token = jnp.zeros((), jnp.float32)
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        if serialize:
            p, g, m, v, _ = jax.lax.optimization_barrier((p, g, m, v,
                                                          token))
        res = upd(p, g, m, v)
        if serialize:
            token = (res[1].ravel()[0].astype(jnp.float32)
                     + res[2].ravel()[0].astype(jnp.float32)) * 0.0
        out.append(res)
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, step), {"grad_norm": gnorm,
                                                   "lr": lr_t}


def cosine_schedule(peak_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * peak_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f
