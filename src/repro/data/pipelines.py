"""Synthetic data pipelines for every model family.

Deterministic, seedable, host-side numpy generators producing the exact
batch dicts the model forwards expect.  The neighbour sampler is a real
CSR fanout sampler (minibatch_lg is a *sampled-training* shape — the
sampler is part of the system, not a stub).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from ..core.graph import Graph


# ---------------------------------------------------------------------------
def lm_batches(batch: int, seq: int, vocab: int, seed: int = 0
               ) -> Iterator[np.ndarray]:
    """Zipf-ish token stream, [batch, seq] int32 per step."""
    rng = np.random.default_rng(seed)
    while True:
        z = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
        yield np.minimum(z - 1, vocab - 1).astype(np.int32)


def recsys_batches(batch: int, n_sparse: int, rows_per_field: int,
                   hots: int, n_dense: int = 13, seed: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        z = rng.zipf(1.2, size=(batch, n_sparse, hots))
        ids = np.minimum(z - 1, rows_per_field - 1).astype(np.int32)
        dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
        # labels correlate weakly with dense features (learnable signal)
        p = 1 / (1 + np.exp(-dense[:, :3].sum(-1)))
        labels = (rng.random(batch) < p).astype(np.int32)
        yield {"sparse_ids": ids, "dense": dense, "labels": labels}


# ---------------------------------------------------------------------------
def _edge_features(g: Graph) -> np.ndarray:
    """4-dim edge features: weight, log-weight, deg(u), deg(v)."""
    deg = g.degree().astype(np.float32)
    w = g.edge_w.astype(np.float32)
    return np.stack([w / (w.max() + 1e-9), np.log1p(w),
                     deg[g.edge_u] / (deg.max() + 1e-9),
                     deg[g.edge_v] / (deg.max() + 1e-9)], axis=1)


def _directed(g: Graph):
    src = np.concatenate([g.edge_u, g.edge_v]).astype(np.int32)
    dst = np.concatenate([g.edge_v, g.edge_u]).astype(np.int32)
    return src, dst


def gnn_full_batch(g: Graph, d_feat: int, n_classes: int, seed: int = 0,
                   n_out: int = 1) -> Dict[str, np.ndarray]:
    """Full-graph training batch with every key any GNN arch needs."""
    rng = np.random.default_rng(seed)
    src, dst = _directed(g)
    ef = np.concatenate([_edge_features(g)] * 2, axis=0)
    x = rng.normal(size=(g.n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, g.n).astype(np.int32)
    tri = _sample_triplets(g, src, dst, max_tri=2 * src.size, rng=rng)
    return {
        "node_feat": x,
        "edge_src": src, "edge_dst": dst, "edge_feat": ef,
        "edge_dist": np.concatenate([g.edge_w, g.edge_w]).astype(
            np.float32) / (g.edge_w.max() + 1e-9) * 3.0,
        "labels": labels,
        "loss_mask": np.ones(g.n, np.float32),
        "target": rng.normal(size=(g.n, n_out)).astype(np.float32),
        "graph_id": np.zeros(g.n, np.int32),
        "target_g": rng.normal(size=(1,)).astype(np.float32),
        **tri,
    }


def _sample_triplets(g: Graph, src, dst, max_tri: int, rng):
    """(k->j->i) edge pairs: for each edge (j,i) sample in-edges (k,j)."""
    e = src.size
    # build: for edge index a=(j->i), pick random edge b=(k->j)
    by_dst = np.argsort(dst, kind="stable")
    dst_sorted = dst[by_dst]
    starts = np.searchsorted(dst_sorted, np.arange(g.n))
    ends = np.searchsorted(dst_sorted, np.arange(g.n) + 1)
    tri_kj, tri_ji = [], []
    per_edge = max(1, max_tri // max(e, 1))
    for a in range(e):
        j = src[a]
        s_, e_ = starts[j], ends[j]
        if e_ <= s_:
            continue
        picks = rng.integers(s_, e_, size=min(per_edge, e_ - s_))
        for p in picks:
            b = by_dst[p]
            if b == a:
                continue
            tri_kj.append(b)
            tri_ji.append(a)
            if len(tri_kj) >= max_tri:
                break
        if len(tri_kj) >= max_tri:
            break
    t = max(len(tri_kj), 1)
    return {
        "tri_edge_kj": np.array(tri_kj or [0], np.int32),
        "tri_edge_ji": np.array(tri_ji or [0], np.int32),
        "tri_angle": rng.uniform(0, np.pi, t).astype(np.float32),
    }


def gnn_molecule_batch(n_graphs: int, n_nodes: int, n_edges: int,
                       d_feat: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Block-diagonal disjoint union of small random molecules."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for gi in range(n_graphs):
        off = gi * n_nodes
        u = rng.integers(0, n_nodes, n_edges // 2)
        v = (u + 1 + rng.integers(0, n_nodes - 1, n_edges // 2)) % n_nodes
        srcs += [u + off, v + off]
        dsts += [v + off, u + off]
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    n = n_graphs * n_nodes
    e = src.size
    gid = np.repeat(np.arange(n_graphs, dtype=np.int32), n_nodes)
    # triplets within molecules
    tri_n = 2 * e
    a = rng.integers(0, e, tri_n)
    # match: b must share src[a] as dst — approximate by rejection
    b = rng.integers(0, e, tri_n)
    ok = dst[b] == src[a]
    return {
        "node_feat": rng.normal(size=(n, d_feat)).astype(np.float32),
        "edge_src": src, "edge_dst": dst,
        "edge_feat": rng.normal(size=(e, 4)).astype(np.float32),
        "edge_dist": rng.uniform(0.5, 3.0, e).astype(np.float32),
        "labels": rng.integers(0, 8, n).astype(np.int32),
        "loss_mask": np.ones(n, np.float32),
        "target": rng.normal(size=(n, 1)).astype(np.float32),
        "graph_id": gid,
        "target_g": rng.normal(size=(n_graphs,)).astype(np.float32),
        "tri_edge_kj": np.where(ok, b, 0).astype(np.int32),
        "tri_edge_ji": a.astype(np.int32),
        "tri_angle": rng.uniform(0, np.pi, tri_n).astype(np.float32),
    }


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NeighborSampler:
    """Real fanout neighbour sampler over CSR (GraphSAGE-style).

    sample(seeds) returns a padded sampled subgraph in the unified
    edge-list format (seed nodes first, loss_mask marks them)."""
    g: Graph
    fanouts: tuple
    d_feat: int
    n_classes: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        rng = np.random.default_rng(self.seed + 1)
        # persistent synthetic features/labels for the big graph
        self._labels = rng.integers(0, self.n_classes,
                                    self.g.n).astype(np.int32)
        self._feat_seed = self.seed + 2

    def _features(self, nodes: np.ndarray) -> np.ndarray:
        """Deterministic per-node features without storing N x d."""
        out = np.empty((nodes.size, self.d_feat), np.float32)
        for i, v in enumerate(nodes):
            r = np.random.default_rng(self._feat_seed + int(v))
            out[i] = r.standard_normal(self.d_feat)
        return out

    def sample(self, seeds: np.ndarray) -> Dict[str, np.ndarray]:
        g = self.g
        frontier = seeds.astype(np.int64)
        nodes = [seeds.astype(np.int64)]
        edges_u, edges_v = [], []
        for fan in self.fanouts:
            nxt = []
            for u in frontier:
                s_, e_ = g.indptr[u], g.indptr[u + 1]
                deg = e_ - s_
                if deg == 0:
                    continue
                take = min(fan, deg)
                picks = self._rng.choice(deg, size=take, replace=False)
                nbrs = g.indices[s_ + picks]
                for v in nbrs:
                    edges_u.append(int(v))
                    edges_v.append(int(u))
                nxt.append(nbrs.astype(np.int64))
            frontier = (np.concatenate(nxt) if nxt
                        else np.empty(0, np.int64))
            nodes.append(frontier)
        all_nodes, inv = np.unique(np.concatenate(nodes),
                                   return_inverse=False), None
        remap = {int(v): i for i, v in enumerate(all_nodes)}
        src = np.array([remap[u] for u in edges_u], np.int32)
        dst = np.array([remap[v] for v in edges_v], np.int32)
        n = all_nodes.size
        mask = np.zeros(n, np.float32)
        for s_ in seeds:
            mask[remap[int(s_)]] = 1.0
        e = max(src.size, 1)
        rng = self._rng
        return {
            "node_feat": self._features(all_nodes),
            "edge_src": src if src.size else np.zeros(1, np.int32),
            "edge_dst": dst if dst.size else np.zeros(1, np.int32),
            "edge_feat": rng.normal(size=(e, 4)).astype(np.float32),
            "edge_dist": rng.uniform(0.5, 3.0, e).astype(np.float32),
            "labels": self._labels[all_nodes],
            "loss_mask": mask,
            "target": rng.normal(size=(n, 1)).astype(np.float32),
            "graph_id": np.zeros(n, np.int32),
            "target_g": rng.normal(size=(1,)).astype(np.float32),
            "tri_edge_kj": np.zeros(1, np.int32),
            "tri_edge_ji": np.zeros(1, np.int32),
            "tri_angle": np.zeros(1, np.float32),
        }
