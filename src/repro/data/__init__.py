from .pipelines import (lm_batches, recsys_batches, gnn_full_batch,
                        gnn_molecule_batch, NeighborSampler)
from .queries import grid_distance_queries

__all__ = ["lm_batches", "recsys_batches", "gnn_full_batch",
           "gnn_molecule_batch", "NeighborSampler",
           "grid_distance_queries"]
