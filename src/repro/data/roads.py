"""Named road-graph presets for the serve/benchmark drivers.

One registry for every graph size the drivers, benchmarks, CI smokes,
and BENCH records refer to by name, so "road64k" means the same
(nodes, seed, overlay hierarchy) everywhere.  ``road_like`` keeps the
largest connected component, so the realized node count lands slightly
under ``nodes`` — names are nominal, records carry the name.

The ``hierarchy`` field is the overlay-closure knob threaded into
``build_device_index`` (DESIGN.md §12): road4000 pins the dense
closure explicitly (its records must stay comparable with the whole
pre-hierarchy BENCH history — and "auto" picks dense at that size
anyway); road64k pins the measured sweet spot of three levels so the
CI smoke and BENCH records can't drift with the auto heuristics;
road250k rides "auto", which keeps adding grouping levels until the
top boundary fits under the dense threshold or stops shrinking
(DESIGN.md §13).
"""
from __future__ import annotations

import dataclasses

from ..core.graph import Graph, road_like


@dataclasses.dataclass(frozen=True)
class RoadPreset:
    name: str
    nodes: int
    seed: int = 0
    hierarchy: int | str = "auto"

    def make(self, seed: int | None = None) -> Graph:
        return road_like(self.nodes,
                         seed=self.seed if seed is None else seed)


ROAD_PRESETS = {
    p.name: p for p in (
        RoadPreset("road2000", nodes=2000, hierarchy=1),
        RoadPreset("road4000", nodes=4000, hierarchy=1),
        RoadPreset("road16k", nodes=16_000),
        RoadPreset("road64k", nodes=64_000, hierarchy=3),
        RoadPreset("road250k", nodes=250_000),
    )
}


def road_preset(name: str) -> RoadPreset:
    """Preset by name, with a helpful error listing what exists."""
    try:
        return ROAD_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown road preset {name!r}; have "
            f"{sorted(ROAD_PRESETS)}") from None
