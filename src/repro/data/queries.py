"""Distance-query generators.

``grid_distance_queries`` is the paper's evaluation workload (§VII-A,
after Wu et al. [34]): a 256 x 256 grid is imposed over the (synthetic)
road network's coordinates; query set Q_i holds node pairs whose grid
distance falls in [2^(i-1) * l, 2^i * l) — Q_1 is near pairs, Q_8 spans
the map.

The *serving* workloads (DESIGN.md §11) model live traffic instead of
benchmark buckets:

* ``zipf_pairs`` — a small pool of distinct OD pairs sampled with
  Zipf(a) frequencies, so a top sliver of pairs carries most of the
  query mass (what makes the epoch-tagged result cache pay);
* ``geo_local_pairs`` — destination within a Chebyshev ball of the
  source in lattice coordinates (commutes, deliveries), which lands
  queries disproportionately in the same-fragment planner bucket;
* ``workload_pairs`` — one dispatcher over mix names for the load
  harness and benchmarks.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core.graph import Graph


def _lattice_coords(g: Graph) -> tuple[np.ndarray, int]:
    """Default node positions for ``road_like`` graphs: node id ->
    (row, col) on the generator's square lattice."""
    side = int(np.ceil(np.sqrt(g.n)))
    ids = np.arange(g.n)
    return np.stack([ids // side, ids % side],
                    axis=1).astype(float), side


def _zipf_pool(g: Graph, pool: int,
               rng: np.random.Generator) -> np.ndarray:
    pool = min(pool, max(1, g.n * (g.n - 1)))
    # over-draw, then dedupe preserving draw order so the pool really
    # holds distinct pairs (duplicates would merge two Zipf ranks into
    # one observed pair and skew the analytic head mass)
    s = rng.integers(0, g.n, 2 * pool)
    t = rng.integers(0, g.n, 2 * pool)
    clash = s == t
    t[clash] = (t[clash] + 1 + rng.integers(0, g.n - 1,
                                            int(clash.sum()))) % g.n
    _, first = np.unique(s * np.int64(g.n) + t, return_index=True)
    keep = np.sort(first)[:pool]
    return np.stack([s[keep], t[keep]], axis=1).astype(np.int64)


def zipf_pool(g: Graph, *, pool: int = 2048,
              seed: int = 0) -> np.ndarray:
    """The distinct OD-pair pool behind ``zipf_pairs`` -> [pool, 2]
    int64, in Zipf rank order (row r is rank r+1, so a prefix of the
    rows is exactly the traffic head).

    Exposed separately so the hub-label hot tier (DESIGN.md §15) can
    pin its label set from the same deterministic pool the workload
    draws from — the pool draws lead the seeded RNG stream, so this
    returns bit-identical rows to the pool ``zipf_pairs(seed=seed)``
    samples from.
    """
    return _zipf_pool(g, pool, np.random.default_rng(seed))


def zipf_pairs(g: Graph, n_queries: int, *, a: float = 1.2,
               pool: int = 2048, seed: int = 0) -> np.ndarray:
    """Zipf-skewed repeated-pair workload -> [n_queries, 2] int64.

    A pool of ``pool`` distinct uniform-random (s, t) pairs
    (``zipf_pool``) is ranked 1..pool; query i draws pair r with
    probability proportional to r**-a.  ``top_pair_mass`` computes the
    resulting head mass analytically so tests (and capacity planning
    for the result cache) can assert the skew rather than eyeball it.
    """
    if n_queries <= 0:
        raise ValueError(f"n_queries must be positive: {n_queries}")
    rng = np.random.default_rng(seed)
    pairs = _zipf_pool(g, pool, rng)
    npool = len(pairs)
    p = np.arange(1, npool + 1, dtype=float) ** -a
    p /= p.sum()
    idx = rng.choice(npool, size=n_queries, p=p)
    return pairs[idx]


def top_pair_mass(frac: float, *, a: float = 1.2,
                  pool: int = 2048) -> float:
    """Analytic share of ``zipf_pairs`` queries carried by the top
    ``frac`` of the pool (e.g. 0.01 -> top-1% pairs)."""
    p = np.arange(1, pool + 1, dtype=float) ** -a
    k = max(1, int(np.floor(frac * pool)))
    return float(p[:k].sum() / p.sum())


def geo_local_pairs(g: Graph, n_queries: int, *, radius: int = 8,
                    coords: np.ndarray | None = None,
                    seed: int = 0) -> np.ndarray:
    """Geo-local workload -> [n_queries, 2]: s uniform, t within the
    Chebyshev ball of ``radius`` grid cells around s (t != s).

    coords: [n, 2] node positions; defaults to the ``road_like``
    lattice.  With explicit coords, t is found by rejection sampling
    against the ball (falling back to the nearest sampled candidate so
    pathological geometries still terminate).
    """
    if n_queries <= 0:
        raise ValueError(f"n_queries must be positive: {n_queries}")
    rng = np.random.default_rng(seed)
    if coords is None:
        co, side = _lattice_coords(g)
        s = rng.integers(0, g.n, n_queries)
        sr = co[s, 0].astype(int)
        sc = co[s, 1].astype(int)
        t = np.full(n_queries, -1, np.int64)
        todo = np.arange(n_queries)
        # draw offsets in the ball, clipped to the grid; re-draw
        # entries that landed on s or past the (partial) last lattice
        # row — ids are compacted, so row*side+col can exceed n-1
        for _ in range(32):
            if not todo.size:
                break
            di = rng.integers(-radius, radius + 1, todo.size)
            dj = rng.integers(-radius, radius + 1, todo.size)
            ri = np.clip(sr[todo] + di, 0, side - 1)
            cj = np.clip(sc[todo] + dj, 0, side - 1)
            cand = ri * side + cj
            ok = (cand < g.n) & (cand != s[todo])
            t[todo[ok]] = cand[ok]
            todo = todo[~ok]
        # fallback: a lattice neighbor (same row, else previous row
        # for the id-space edge s+1 == n) is always valid and in-ball
        if todo.size:
            fb = np.where(s[todo] % side > 0, s[todo] - 1, s[todo] + 1)
            fb = np.where(fb >= g.n, s[todo] - side, fb)
            t[todo] = fb
        return np.stack([s, t], axis=1).astype(np.int64)
    span = coords.max(0) - coords.min(0)
    cell = max(span.max() / 256, 1e-9)
    out = np.empty((n_queries, 2), np.int64)
    for i in range(n_queries):
        s = int(rng.integers(0, g.n))
        t, best, best_d = -1, -1, np.inf
        for _ in range(64):
            c = int(rng.integers(0, g.n))
            if c == s:
                continue
            d = np.abs(coords[c] - coords[s]).max() / cell
            if d <= radius:
                t = c
                break
            if d < best_d:
                best, best_d = c, d
        out[i] = (s, t if t >= 0 else best)
    return out


def workload_pairs(g: Graph, mix: str, n: int, *, seed: int = 0,
                   zipf_a: float = 1.2, pool: int = 2048,
                   radius: int = 8) -> np.ndarray:
    """Serving-workload dispatcher: mix in {uniform, zipf, geo}."""
    if mix == "uniform":
        rng = np.random.default_rng(seed)
        s = rng.integers(0, g.n, n)
        t = rng.integers(0, g.n, n)
        clash = s == t
        t[clash] = (t[clash] + 1) % g.n
        return np.stack([s, t], axis=1).astype(np.int64)
    if mix == "zipf":
        return zipf_pairs(g, n, a=zipf_a, pool=pool, seed=seed)
    if mix == "geo":
        return geo_local_pairs(g, n, radius=radius, seed=seed)
    raise ValueError(f"unknown workload mix: {mix!r} "
                     "(expected uniform | zipf | geo)")


def grid_distance_queries(g: Graph, coords: np.ndarray | None = None,
                          n_per_set: int = 1000, n_sets: int = 8,
                          grid: int = 256, seed: int = 0
                          ) -> Dict[int, np.ndarray]:
    """-> {i: [n, 2] node pairs}, i in 1..n_sets.

    coords: [n, 2] node positions; defaults to lattice positions for the
    road_like generator (node id -> (row, col))."""
    rng = np.random.default_rng(seed)
    if coords is None:
        side = int(np.ceil(np.sqrt(g.n)))
        ids = np.arange(g.n)
        coords = np.stack([ids // side, ids % side], axis=1).astype(float)
    span = coords.max(0) - coords.min(0)
    cell = max(span.max() / grid, 1e-9)
    out: Dict[int, List[Tuple[int, int]]] = {i: [] for i in
                                             range(1, n_sets + 1)}
    need = n_per_set * n_sets
    tries = 0
    while tries < 200 * need and any(len(v) < n_per_set
                                     for v in out.values()):
        tries += 1
        s, t = rng.integers(0, g.n, 2)
        if s == t:
            continue
        gd = np.abs(coords[s] // cell - coords[t] // cell).max()
        if gd < 1:
            continue
        i = int(np.floor(np.log2(max(gd, 1)))) + 1
        if 1 <= i <= n_sets and len(out[i]) < n_per_set:
            out[i].append((int(s), int(t)))
    return {i: np.array(v if v else [(0, 0)], np.int64)
            for i, v in out.items()}
