"""Distance-query generator (paper §VII-A, after Wu et al. [34]).

A 256 x 256 grid is imposed over the (synthetic) road network's
coordinates; query set Q_i holds node pairs whose grid distance falls in
[2^(i-1) * l, 2^i * l) — Q_1 is near pairs, Q_8 spans the map.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from ..core.graph import Graph


def grid_distance_queries(g: Graph, coords: np.ndarray | None = None,
                          n_per_set: int = 1000, n_sets: int = 8,
                          grid: int = 256, seed: int = 0
                          ) -> Dict[int, np.ndarray]:
    """-> {i: [n, 2] node pairs}, i in 1..n_sets.

    coords: [n, 2] node positions; defaults to lattice positions for the
    road_like generator (node id -> (row, col))."""
    rng = np.random.default_rng(seed)
    if coords is None:
        side = int(np.ceil(np.sqrt(g.n)))
        ids = np.arange(g.n)
        coords = np.stack([ids // side, ids % side], axis=1).astype(float)
    span = coords.max(0) - coords.min(0)
    cell = max(span.max() / grid, 1e-9)
    out: Dict[int, List[Tuple[int, int]]] = {i: [] for i in
                                             range(1, n_sets + 1)}
    need = n_per_set * n_sets
    tries = 0
    while tries < 200 * need and any(len(v) < n_per_set
                                     for v in out.values()):
        tries += 1
        s, t = rng.integers(0, g.n, 2)
        if s == t:
            continue
        gd = np.abs(coords[s] // cell - coords[t] // cell).max()
        if gd < 1:
            continue
        i = int(np.floor(np.log2(max(gd, 1)))) + 1
        if 1 <= i <= n_sets and len(out[i]) < n_per_set:
            out[i].append((int(s), int(t)))
    return {i: np.array(v if v else [(0, 0)], np.int64)
            for i, v in out.items()}
