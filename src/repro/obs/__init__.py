"""Observability layer (DESIGN.md §16): metrics, traces, exporters.

Three pieces, each independently usable:

* ``metrics`` — thread-safe ``MetricsRegistry`` of counters, gauges,
  labeled counters, array counters, and log-bucketed streaming
  ``Histogram``s (bounded memory, p50/p95/p99/max within a 5% bucket
  width, exact min/max).  The serving runtime owns one registry; the
  batcher, cache, and tier ladder all record into it under dotted
  ``layer.component.metric`` names.
* ``trace`` — spans with a near-zero-cost disabled path (a shared
  no-op singleton), a ``timed()`` helper that keeps the historical
  ``timings``-dict contract while emitting spans, and raw ``event()``
  emission for intervals measured elsewhere (per-request lifecycle).
  Build stages, ``refresh_index`` stages, hierarchy closures, and the
  serve flush all trace through the module-level default tracer.
* ``export`` — Chrome-trace JSONL writer/loader (opens in
  chrome://tracing), atomic periodic metrics snapshots + Prometheus
  text exposition (``serve.py --metrics-out/--metrics-port``), and
  the worst-N ``SlowQueryLog``.

The overhead contract: with tracing disabled (the default), call
sites cost one attribute read; with everything on, live road4000
serving stays within the measured <2% qps budget (``BENCH_serve.json``
section ``obs_overhead``, enforced by the A-B in ``tests/test_obs.py``).
"""
from .export import (MetricsExporter, MetricsServer, SlowQueryLog,
                     load_chrome_trace, write_chrome_trace,
                     write_snapshot)
from .metrics import (ArrayCounter, Counter, Gauge, Histogram,
                      HistogramSnapshot, LabeledCounter,
                      MetricsRegistry)
from .trace import Tracer, event, get_tracer, span, timed

__all__ = [
    "ArrayCounter", "Counter", "Gauge", "Histogram",
    "HistogramSnapshot", "LabeledCounter", "MetricsExporter",
    "MetricsRegistry", "MetricsServer", "SlowQueryLog", "Tracer",
    "event", "get_tracer", "load_chrome_trace", "span", "timed",
    "write_chrome_trace", "write_snapshot",
]
