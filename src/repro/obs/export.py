"""Exporters: Chrome-trace JSONL, periodic metrics snapshots,
Prometheus text over HTTP, and the slow-query log (DESIGN.md §16).

The trace file is the Chrome Trace Event Format's JSON-array form
written one event per line — chrome://tracing and Perfetto load it
directly (the format explicitly tolerates the trailing comma and a
missing ``]``), and line-oriented tools can stream it.  Metrics
snapshots are atomic (temp file + ``os.replace``), so a scraper never
reads a half-written JSON.  The ``SlowQueryLog`` keeps the worst-N
requests by latency with their span breakdown — bounded memory, O(log
N) per offer via a min-heap keyed on latency.
"""
from __future__ import annotations

import heapq
import json
import os
import tempfile
import threading
import time


# -- Chrome trace -----------------------------------------------------

def write_chrome_trace(path: str, events: list[dict]) -> None:
    """Write events as a Chrome-trace JSON array, one event per line
    (loadable by chrome://tracing AND greppable/streamable)."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(prefix=".trace.", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            f.write("[\n")
            for ev in events:
                f.write(json.dumps(ev, separators=(",", ":")) + ",\n")
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_chrome_trace(path: str) -> list[dict]:
    """Load a trace written by ``write_chrome_trace`` (or any Chrome
    trace array, complete or trailing-comma-truncated)."""
    with open(path) as f:
        text = f.read().strip()
    if not text.startswith("["):
        raise ValueError(f"{path}: not a Chrome trace array")
    body = text[1:].rstrip().rstrip(",").rstrip()
    if body.endswith("]"):
        body = body[:-1].rstrip().rstrip(",")
    if not body:
        return []
    return json.loads(f"[{body}]")


# -- metrics snapshots ------------------------------------------------

def _atomic_write_text(path: str, text: str) -> None:
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(prefix=".metrics.", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def write_snapshot(path: str, registry, extra: dict | None = None) \
        -> dict:
    """One atomic metrics-snapshot write: ``{path}`` gets the JSON
    dump (registry snapshot + ``extra``), ``{path_base}.prom`` the
    Prometheus text exposition.  Returns the snapshot dict."""
    snap = {"unix_time": time.time(), "metrics": registry.snapshot()}
    if extra:
        snap.update(extra)
    _atomic_write_text(path, json.dumps(snap, indent=1))
    base, _ext = os.path.splitext(path)
    _atomic_write_text(base + ".prom", registry.prometheus())
    return snap


class MetricsExporter:
    """Daemon thread writing a metrics snapshot every ``interval_s``;
    ``stop()`` writes one final snapshot so short runs always leave a
    complete file.  ``extra`` is an optional callable returning a dict
    merged into each snapshot (slow-query log, run metadata)."""

    def __init__(self, registry, path: str, *,
                 interval_s: float = 2.0, extra=None):
        self.registry = registry
        self.path = path
        self.interval_s = interval_s
        self._extra = extra
        self._stop = threading.Event()
        self.writes = 0
        self._thread = threading.Thread(target=self._run,
                                        name="metrics-exporter",
                                        daemon=True)

    def start(self) -> "MetricsExporter":
        self._thread.start()
        return self

    def _write(self) -> None:
        extra = self._extra() if self._extra is not None else None
        write_snapshot(self.path, self.registry, extra)
        self.writes += 1

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write()

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10.0)
        self._write()


class MetricsServer:
    """Minimal stdlib HTTP endpoint: ``GET /metrics`` serves the
    Prometheus text exposition, anything else the JSON snapshot.
    Binds 127.0.0.1:``port`` (port 0 picks a free one — read
    ``.port`` after start)."""

    def __init__(self, registry, port: int = 0):
        from http.server import (BaseHTTPRequestHandler,
                                 ThreadingHTTPServer)

        reg = registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):           # noqa: N802 (stdlib API name)
                if self.path.startswith("/metrics"):
                    body = reg.prometheus().encode()
                    ctype = "text/plain; version=0.0.4"
                else:
                    body = json.dumps(
                        {"metrics": reg.snapshot()}, indent=1).encode()
                    ctype = "application/json"
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):   # quiet
                pass

        self._httpd = ThreadingHTTPServer(("127.0.0.1", port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http",
            daemon=True)

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=10.0)


# -- slow-query log ---------------------------------------------------

class SlowQueryLog:
    """Worst-N requests by latency, with their serve breakdown.

    ``offer`` is called once per resolved request (flusher thread);
    a min-heap on latency keeps exactly the N worst in O(log N) per
    offer and O(N) memory.  ``records()`` returns them slowest-first,
    JSON-safe — surfaced in metrics snapshots and printed by
    ``serve.py --live``.
    """

    def __init__(self, n: int = 16):
        self.n = max(1, int(n))
        self._lock = threading.Lock()
        self._heap: list[tuple[float, int, dict]] = []
        self._seq = 0
        self.offered = 0

    def offer(self, latency_s: float, detail: dict) -> None:
        with self._lock:
            self.offered += 1
            self._seq += 1
            item = (float(latency_s), self._seq, detail)
            if len(self._heap) < self.n:
                heapq.heappush(self._heap, item)
            elif item[0] > self._heap[0][0]:
                heapq.heapreplace(self._heap, item)

    def records(self) -> list[dict]:
        with self._lock:
            worst = sorted(self._heap, reverse=True)
        return [{"latency_ms": round(lat * 1e3, 3), **detail}
                for lat, _seq, detail in worst]
