"""Tracing spans with a near-zero-cost disabled path (DESIGN.md §16).

One ``Tracer`` holds a bounded in-memory buffer of Chrome-trace
"complete" events (``ph: "X"``, microsecond timestamps).  The API is
built so that EVERY production call site stays hot-path-safe when
tracing is off:

* ``span(name, **tags)`` — context manager.  Disabled, it returns a
  shared no-op singleton whose ``__enter__``/``__exit__`` are empty
  methods: no allocation, no clock read, no tag dict materialized
  beyond the call itself.
* ``timed(name, out, key, **tags)`` — like ``span`` but ALWAYS times
  (one ``perf_counter`` pair) and writes the elapsed seconds into
  ``out[key]``.  This is the migration target for the hand-rolled
  ``timings["stage"] = time.perf_counter() - t0`` pattern in
  ``refresh_index``/``build_index``: the dict consumers keep their
  numbers, and the same measurement becomes a trace event when the
  tracer is on — one clock, two views.
* ``event(name, t0, t1, **tags)`` — post-hoc emission for intervals
  the caller already measured (per-request lifecycle events derived
  from ``Request.t_sched``/``t_done``).  Disabled, it's one attribute
  check.

Spans nest per-thread: each thread's open-span depth is tracked so
tests can assert nesting/ordering invariants, and events carry the
thread id so chrome://tracing lays concurrent flusher/refresh/export
activity out on separate rows.

A module-level default tracer (``get_tracer()``) is what the library
call sites use; ``serve.py --trace-out`` enables it and drains the
buffer into a Chrome-trace JSON at exit.
"""
from __future__ import annotations

import threading
import time


class _NullSpan:
    """Shared do-nothing context manager — the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records wall-clock bounds on exit and appends a
    Chrome "X" event to its tracer's buffer."""

    __slots__ = ("_tracer", "name", "tags", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, tags: dict):
        self._tracer = tracer
        self.name = name
        self.tags = tags

    def __enter__(self):
        self._depth = self._tracer._enter_depth()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._exit_depth()
        self._tracer._emit(self.name, self._t0, t1, self.tags,
                           self._depth)
        return False


class _Timed:
    """Always-on timer that doubles as a span: elapsed seconds land in
    ``out[key]`` unconditionally, and in the trace buffer when the
    tracer is enabled.  ``.elapsed`` is readable after exit."""

    __slots__ = ("_tracer", "name", "_out", "_key", "tags", "_t0",
                 "_depth", "elapsed")

    def __init__(self, tracer, name, out, key, tags):
        self._tracer = tracer
        self.name = name
        self._out = out
        self._key = key
        self.tags = tags
        self.elapsed = 0.0

    def __enter__(self):
        self._depth = self._tracer._enter_depth() \
            if self._tracer.enabled else 0
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self.elapsed = t1 - self._t0
        if self._out is not None:
            self._out[self._key] = self.elapsed
        if self._tracer.enabled:
            self._tracer._exit_depth()
            self._tracer._emit(self.name, self._t0, t1, self.tags,
                               self._depth)
        return False


class Tracer:
    """Bounded buffer of Chrome-trace events + the span/timed/event
    API.  Disabled by default; ``enable()`` flips one attribute read
    by every call site.  The buffer keeps at most ``max_events``
    (oldest dropped, drop count reported) so a long-lived server can
    leave tracing on without unbounded growth."""

    def __init__(self, *, enabled: bool = False,
                 max_events: int = 200_000):
        self.enabled = enabled
        self.max_events = max_events
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self.dropped = 0
        self._local = threading.local()
        # one fixed origin so every event's ts is a positive offset
        self._origin = time.perf_counter()

    # -- depth tracking (per-thread nesting, for tests/ordering) ------
    def _enter_depth(self) -> int:
        d = getattr(self._local, "depth", 0)
        self._local.depth = d + 1
        return d

    def _exit_depth(self) -> None:
        self._local.depth = getattr(self._local, "depth", 1) - 1

    @property
    def depth(self) -> int:
        """Current thread's open-span depth."""
        return getattr(self._local, "depth", 0)

    # -- emission -----------------------------------------------------
    def _emit(self, name, t0, t1, tags, depth) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0 - self._origin) * 1e6,
            "dur": max(0.0, (t1 - t0) * 1e6),
            "pid": 1,
            "tid": threading.get_ident() % 100_000,
            "args": dict(tags) if tags else {},
        }
        if depth:
            ev["args"]["depth"] = depth
        with self._lock:
            self._events.append(ev)
            if len(self._events) > self.max_events:
                drop = len(self._events) - self.max_events
                del self._events[:drop]
                self.dropped += drop

    # -- public API ---------------------------------------------------
    def enable(self, on: bool = True) -> "Tracer":
        self.enabled = on
        return self

    def span(self, name: str, **tags):
        """Context manager; the no-op singleton when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, tags)

    def timed(self, name: str, out: dict | None, key: str, **tags):
        """Context manager that always times into ``out[key]`` and
        additionally traces when enabled."""
        return _Timed(self, name, out, key, tags)

    def event(self, name: str, t0: float, t1: float, **tags) -> None:
        """Emit a completed interval measured by the caller (both
        bounds on the ``perf_counter`` clock)."""
        if not self.enabled:
            return
        self._emit(name, t0, t1, tags, 0)

    def events(self) -> list[dict]:
        """Copy of the buffered events (chronological emit order)."""
        with self._lock:
            return list(self._events)

    def drain(self) -> list[dict]:
        """Return and clear the buffer."""
        with self._lock:
            out = self._events
            self._events = []
            return out

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self.dropped = 0


# Module-level default: library call sites trace through this; it
# stays disabled (no-op spans, skipped events) unless a front end —
# serve.py --trace-out, a test — enables it.
_DEFAULT = Tracer()


def get_tracer() -> Tracer:
    return _DEFAULT


def span(name: str, **tags):
    """Span on the default tracer (the common call-site spelling)."""
    if not _DEFAULT.enabled:
        return _NULL_SPAN
    return _Span(_DEFAULT, name, tags)


def timed(name: str, out: dict | None, key: str, **tags):
    """Timed span on the default tracer (always populates ``out``)."""
    return _Timed(_DEFAULT, name, out, key, tags)


def event(name: str, t0: float, t1: float, **tags) -> None:
    if _DEFAULT.enabled:
        _DEFAULT._emit(name, t0, t1, tags, 0)
