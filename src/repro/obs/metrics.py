"""Thread-safe metrics primitives: counters, gauges, labeled
counters, array counters, and log-bucketed streaming histograms
(DESIGN.md §16).

Every primitive is independently lock-protected and O(1) per update,
so call sites can record from the flusher thread, the refresh thread,
and the exporter thread without coordinating.  The ``Histogram`` is
the load-bearing piece: geometric buckets (``growth`` ratio, default
5%) over a sparse dict give bounded memory no matter how many
observations stream through, while ``percentile()`` stays within one
bucket width of the exact nearest-rank answer — and ``min``/``max``
are tracked exactly, so the reported range is never an artifact of
bucketing.

The registry (``MetricsRegistry``) is a get-or-create namespace: a
call site asks for ``registry.counter("serve.tier.label.hits")`` and
shares the instance with every other site using that name.  Names are
dotted ``layer.component.metric`` paths (see DESIGN.md §16 for the
scheme); ``snapshot()`` renders everything JSON-safe and
``prometheus()`` renders the text exposition format.
"""
from __future__ import annotations

import math
import threading

import numpy as np


class Counter:
    """Monotonic (or at least add-only) scalar; ``inc`` accepts floats
    so the same primitive carries counts and accumulated seconds."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount: int | float = 1) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> int | float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar (queue depth, current epoch, ...)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: int | float) -> None:
        with self._lock:
            self._value = value

    @property
    def value(self) -> int | float:
        with self._lock:
            return self._value


class LabeledCounter:
    """Counter family keyed by one label value (flush reason, pow2
    occupancy bucket).  ``snapshot()`` returns ``{label: count}`` with
    string keys, sorted, which is exactly the perflog-record shape the
    batcher's ``occupancy_hist`` always had."""

    __slots__ = ("name", "_lock", "_counts")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._counts: dict[str, int | float] = {}

    def inc(self, label, amount: int | float = 1) -> None:
        key = str(label)
        with self._lock:
            self._counts[key] = self._counts.get(key, 0) + amount

    def get(self, label) -> int | float:
        with self._lock:
            return self._counts.get(str(label), 0)

    @property
    def total(self) -> int | float:
        with self._lock:
            return sum(self._counts.values())

    def snapshot(self) -> dict:
        with self._lock:
            return {k: self._counts[k] for k in sorted(self._counts)}


class ArrayCounter:
    """Fixed-size vector of int64 counters updated by bulk adds — the
    per-fragment traffic tallies the refresh pipeline prioritizes by.
    ``add`` takes a full-length count vector (np.bincount output);
    ``snapshot`` returns a copy."""

    __slots__ = ("name", "_lock", "_counts")

    def __init__(self, name: str, size: int):
        self.name = name
        self._lock = threading.Lock()
        self._counts = np.zeros(int(size), np.int64)

    def add(self, counts: np.ndarray) -> None:
        with self._lock:
            self._counts += counts

    def snapshot(self) -> np.ndarray:
        with self._lock:
            return self._counts.copy()

    @property
    def size(self) -> int:
        return len(self._counts)


class HistogramSnapshot:
    """Frozen view of a Histogram (or of the delta between two points
    in time): enough state to compute percentiles without holding the
    live histogram's lock."""

    __slots__ = ("counts", "count", "sum", "min", "max", "_lo",
                 "_log_growth")

    def __init__(self, counts, count, sum_, min_, max_, lo,
                 log_growth):
        self.counts = counts          # {bucket_idx: n}, sparse
        self.count = count
        self.sum = sum_
        self.min = min_               # exact; None when count == 0
        self.max = max_
        self._lo = lo
        self._log_growth = log_growth

    def _bucket_value(self, idx: int) -> float:
        # geometric midpoint of the bucket's (lo*g^(i-1), lo*g^i] span
        return self._lo * math.exp(self._log_growth * (idx - 0.5))

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (q in [0, 100]) to within one
        bucket width; clamped into the exact observed [min, max]."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen >= rank:
                v = self._bucket_value(idx)
                return min(max(v, self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self, scale: float = 1.0, digits: int = 3) -> dict:
        """p50/p95/p99/mean/max (+count), each scaled (e.g. 1e3 for
        seconds -> ms) and rounded — the perflog-record shape."""
        return {
            "count": self.count,
            "p50": round(self.percentile(50) * scale, digits),
            "p95": round(self.percentile(95) * scale, digits),
            "p99": round(self.percentile(99) * scale, digits),
            "mean": round(self.mean * scale, digits),
            "max": round((self.max or 0.0) * scale, digits),
        }


class Histogram:
    """Log-bucketed streaming histogram: bounded memory, O(1) insert,
    percentile extraction within ``growth`` relative error.

    Bucket ``i`` covers ``(lo * growth**(i-1), lo * growth**i]``;
    observations at or below ``lo`` land in bucket 0, and the index is
    clamped to ``max_buckets`` so pathological outliers cannot grow
    the table without bound (their mass lands in the top bucket, and
    the exact tracked ``max`` still reports them truthfully).

    Defaults suit latencies in seconds: lo=1µs, growth=1.05 resolves
    5% relative error over 1µs..{growth**max_buckets·lo} ≈ 28 minutes.
    """

    __slots__ = ("name", "lo", "growth", "max_buckets", "_log_growth",
                 "_lock", "_counts", "_count", "_sum", "_min", "_max")

    def __init__(self, name: str, *, lo: float = 1e-6,
                 growth: float = 1.05, max_buckets: int = 1536):
        if lo <= 0 or growth <= 1.0:
            raise ValueError(
                f"need lo > 0 and growth > 1: lo={lo} growth={growth}")
        self.name = name
        self.lo = lo
        self.growth = growth
        self.max_buckets = max_buckets
        self._log_growth = math.log(growth)
        self._lock = threading.Lock()
        self._counts: dict[int, int] = {}
        self._count = 0
        self._sum = 0.0
        self._min: float | None = None
        self._max: float | None = None

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        idx = math.ceil(math.log(x / self.lo) / self._log_growth)
        return min(idx, self.max_buckets)

    def observe(self, x: float) -> None:
        x = float(x)
        idx = self._bucket(x)
        with self._lock:
            self._counts[idx] = self._counts.get(idx, 0) + 1
            self._count += 1
            self._sum += x
            if self._min is None or x < self._min:
                self._min = x
            if self._max is None or x > self._max:
                self._max = x

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def freeze(self) -> HistogramSnapshot:
        """Consistent point-in-time copy."""
        with self._lock:
            return HistogramSnapshot(
                dict(self._counts), self._count, self._sum,
                self._min, self._max, self.lo, self._log_growth)

    def since(self, prev: HistogramSnapshot) -> HistogramSnapshot:
        """Snapshot of everything observed AFTER ``prev`` was frozen —
        how the load harness scopes percentiles to one phase of a
        shared runtime.  Bucket counts and count/sum subtract exactly;
        min/max fall back to the window's bucket bounds when the
        all-time extremum predates the window (bounded by the same
        ``growth`` relative error as any percentile)."""
        cur = self.freeze()
        counts = {i: n - prev.counts.get(i, 0)
                  for i, n in cur.counts.items()
                  if n - prev.counts.get(i, 0) > 0}
        count = cur.count - prev.count
        if count <= 0:
            return HistogramSnapshot({}, 0, 0.0, None, None, self.lo,
                                     self._log_growth)
        lo_idx, hi_idx = min(counts), max(counts)
        mn = cur.min if prev.count == 0 or cur.min != prev.min else \
            self.lo * math.exp(self._log_growth * (lo_idx - 1))
        mx = cur.max if prev.count == 0 or cur.max != prev.max else \
            self.lo * math.exp(self._log_growth * hi_idx)
        return HistogramSnapshot(counts, count, cur.sum - prev.sum,
                                 mn, mx, self.lo, self._log_growth)

    def percentile(self, q: float) -> float:
        return self.freeze().percentile(q)

    def summary(self, scale: float = 1.0, digits: int = 3) -> dict:
        return self.freeze().summary(scale, digits)


def _prom_name(name: str) -> str:
    """Prometheus metric names allow [a-zA-Z0-9_:]; our dotted paths
    map ``.`` and ``-`` to ``_``."""
    return "".join(c if c.isalnum() or c in "_:" else "_"
                   for c in name)


class MetricsRegistry:
    """Get-or-create namespace of metrics, shared across a runtime.

    Type-stable by name: asking for ``counter(n)`` after ``gauge(n)``
    was registered raises — two call sites silently aliasing one name
    to different primitives is always a bug.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get_or_create(self, name: str, cls, *args, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def labeled(self, name: str) -> LabeledCounter:
        return self._get_or_create(name, LabeledCounter)

    def array_counter(self, name: str, size: int) -> ArrayCounter:
        return self._get_or_create(name, ArrayCounter, size)

    def histogram(self, name: str, **kwargs) -> Histogram:
        return self._get_or_create(name, Histogram, **kwargs)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> dict:
        """JSON-safe dump of every metric: scalars as-is, labeled
        counters as dicts, array counters as nonzero totals, and
        histograms as their p50/p95/p99/mean/max summaries."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: dict[str, object] = {}
        for name, m in items:
            if isinstance(m, (Counter, Gauge)):
                out[name] = m.value
            elif isinstance(m, LabeledCounter):
                out[name] = m.snapshot()
            elif isinstance(m, ArrayCounter):
                c = m.snapshot()
                out[name] = {"size": int(c.size),
                             "total": int(c.sum()),
                             "nonzero": int((c > 0).sum()),
                             "max": int(c.max()) if c.size else 0}
            elif isinstance(m, Histogram):
                out[name] = m.summary()
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition (0.0.4): counters/gauges as
        bare samples, labeled counters with a ``label=...`` tag,
        histograms as summary quantiles + _count/_sum."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: list[str] = []
        for name, m in items:
            pn = _prom_name(name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pn} counter")
                lines.append(f"{pn} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pn} gauge")
                lines.append(f"{pn} {m.value}")
            elif isinstance(m, LabeledCounter):
                lines.append(f"# TYPE {pn} counter")
                for label, v in m.snapshot().items():
                    lines.append(f'{pn}{{label="{label}"}} {v}')
            elif isinstance(m, ArrayCounter):
                c = m.snapshot()
                lines.append(f"# TYPE {pn} gauge")
                lines.append(f'{pn}{{stat="total"}} {int(c.sum())}')
                lines.append(
                    f'{pn}{{stat="max"}} '
                    f'{int(c.max()) if c.size else 0}')
            elif isinstance(m, Histogram):
                snap = m.freeze()
                lines.append(f"# TYPE {pn} summary")
                for q in (0.5, 0.95, 0.99):
                    lines.append(
                        f'{pn}{{quantile="{q}"}} '
                        f'{snap.percentile(q * 100):.9g}')
                lines.append(f"{pn}_sum {snap.sum:.9g}")
                lines.append(f"{pn}_count {snap.count}")
        return "\n".join(lines) + "\n"
