"""Production meshes (system prompt MULTI-POD DRY-RUN step 1).

Functions, not module constants, so importing never touches jax device
state.  Axis semantics:
  pod    — gradient all-reduce across pods (pure DP)
  data   — batch sharding + FSDP (ZeRO-3) parameter/optimizer sharding
  model  — tensor/expert/sequence parallel axis
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_mesh(shape, axes)
