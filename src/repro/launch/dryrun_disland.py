import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Bonus dry-run cell: the paper's own workload — DISLAND batched
serving — AOT-lowered on the production meshes.

Index dimensions model a ~262k-node road graph (c=2): 256 fragments of
<=1024 nodes, 128 boundary slots, ~8k SUPER nodes, piece buckets per
device_engine.PIECE_BUCKETS.  Index replicated (it fits: ~1.6 GB),
query batch of 2^17 sharded over every mesh axis — the zero-collective
serving layout of DESIGN.md §5.

    PYTHONPATH=src python -m repro.launch.dryrun_disland
"""
import json       # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..core.device_engine import DeviceIndex, serve_step  # noqa: E402
from . import hloanalysis  # noqa: E402
from .dryrun import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

SDS = jax.ShapeDtypeStruct


def index_struct(n=262_144, k=256, maxf=1024, mb=128, s_super=8192,
                 pieces=(20_000, 2_000, 200, 16, 1)) -> DeviceIndex:
    f32, i32 = jnp.float32, jnp.int32
    caps = (8, 32, 128, 512, 2048)
    flat = sum(p * c * c for p, c in zip(pieces, caps))
    return DeviceIndex(
        agent_of=SDS((n,), i32), dist_to_agent=SDS((n,), f32),
        frag_of=SDS((n,), i32), pos_in_frag=SDS((n,), i32),
        piece_gid=SDS((n,), i32), pos_in_piece=SDS((n,), i32),
        piece_base=SDS((n,), i32), piece_stride=SDS((n,), i32),
        frag_apsp=SDS((k, maxf, maxf), f32),
        frag_next=SDS((k, maxf, maxf), i32),
        brow=SDS((k, maxf, mb), f32),
        bpos=SDS((k, mb), i32), bvalid=SDS((k, mb), jnp.bool_),
        bnd_super=SDS((k, mb), i32),
        d_super=SDS((s_super + 1, s_super + 1), f32),
        super_next=SDS((s_super + 1, s_super + 1), i32),
        piece_flat=SDS((flat,), f32),
        piece_next=SDS((flat,), i32),
    )


def main() -> None:
    out = {}
    for mesh_kind, multi in [("single", False), ("multipod", True)]:
        mesh = make_production_mesh(multi_pod=multi)
        axes = tuple(mesh.axis_names)
        dix = index_struct()
        rep = NamedSharding(mesh, P())
        qshard = NamedSharding(mesh, P(axes))
        dix_shard = jax.tree_util.tree_map(lambda _: rep, dix)
        q = SDS((131_072,), jnp.int32)
        t0 = time.perf_counter()
        with mesh:
            compiled = jax.jit(
                serve_step,
                in_shardings=(dix_shard, qshard, qshard)).lower(
                    dix, q, q).compile()
        dt = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        ana = hloanalysis.analyze(compiled.as_text())
        fit = (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9
        rec = {
            "mesh": mesh_kind, "n_chips": mesh.size,
            "lower_compile_s": round(dt, 1),
            "fit_gb": round(fit, 2),
            "flops_dev": ana.flops,
            "collective_bytes_dev": ana.collective_bytes,
            "roofline": {
                "compute_s": ana.flops / PEAK_FLOPS,
                # serve traffic per query: two boundary rows + two
                # scattered SUPER rows, plus D_super streamed once per
                # 128-query tile by the fused combine kernel
                "memory_s": (131_072 / mesh.size
                             * (128 * 4 * 2 + 8_193 * 4 * 2
                                + 8_193 ** 2 * 4 / 128)) / HBM_BW,
                "collective_s": ana.collective_bytes / LINK_BW,
            },
        }
        print(f"[OK] disland-serve x q131072 x {mesh_kind} "
              f"fit={fit:.2f}GB compile={dt:.1f}s "
              f"coll={ana.collective_bytes / 1e6:.1f}MB/dev")
        out[mesh_kind] = rec
    os.makedirs("experiments/dryrun", exist_ok=True)
    with open("experiments/dryrun/disland-serve__bonus.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
