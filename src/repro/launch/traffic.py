"""Analytic per-device HBM traffic model (roofline memory-term numerator).

The CPU-XLA backend's float normalisation (bf16 -> f32 converts of whole
cache/activation tensors) and layout transposes inflate the HLO-measured
bytes by up to ~50x on decode cells relative to what a bf16-native TPU
moves (EXPERIMENTS.md §Roofline methodology quantifies this on
granite-8b decode: 372 GB measured vs ~7 GB modelled).  The roofline
memory term therefore uses this explicit traffic model; HLO-measured
bytes and CPU copy bytes are reported alongside as diagnostics.

All numbers are per device per step.
"""
from __future__ import annotations

from ..configs.api import ArchSpec, ShapeCell
from ..models import gnn, recsys, transformer


def analytic_bytes(spec: ArchSpec, cell: ShapeCell, n_chips: int,
                   tp: int = 16, dp: int | None = None) -> float:
    if dp is None:
        dp = n_chips // tp
    if spec.family == "lm":
        return _lm(spec, cell, n_chips, tp, dp)
    if spec.family == "gnn":
        return _gnn(spec.model_cfg, cell, n_chips)
    return _recsys(spec.model_cfg, cell, n_chips, tp, dp)


def _lm(spec: ArchSpec, cell: ShapeCell, n_chips, tp, dp) -> float:
    cfg: transformer.LMConfig = spec.model_cfg
    d = cell.dims
    b, t = d["global_batch"], d["seq_len"]
    p_total = cfg.n_params() * 2                      # bf16
    p_gathered = p_total / tp                         # per-device working set
    kv_token = 2 * cfg.n_kv_heads * cfg.head_dim * 2  # k+v bytes/token/layer
    if cell.kind == "train":
        n_micro = max(1, (b // dp) // spec.seqs_per_micro)
        tokens_dev = b * t / dp / max(n_micro, 1)     # per micro
        # weights: fwd + bwd + remat refwd re-read the gathered shard
        w = 3.0 * n_micro * p_gathered
        # activations: ~12 intermediate tensors of [tokens, d] per layer
        act = (3.0 * n_micro * cfg.n_layers * tokens_dev
               * cfg.d_model * 2 * 12)
        # attention score tiles (f32, write+read in fwd, x3 with bwd)
        h_local = cfg.n_heads / (tp if cfg.n_heads % tp == 0 else 1)
        seqs_local = tokens_dev / t
        att = 3.0 * n_micro * cfg.n_layers * seqs_local * h_local \
            * t * t * 4 * 2
        # optimizer: grads f32 + m/v read+write + params read+write
        opt = (p_total / (dp * tp)) * (4 + 4 * 4 + 2 * 2)
        return w + act + att + opt
    if cell.kind == "prefill":
        tokens_dev = b * t / (dp if b % dp == 0 and b >= dp else 1)
        w = p_gathered
        act = cfg.n_layers * tokens_dev * cfg.d_model * 2 * 12
        cache_w = cfg.n_layers * tokens_dev * kv_token / tp
        return w + act + cache_w
    # decode: read the whole local cache slice + weights once
    shard_seq = bool(d.get("shard_seq", 0)) or not (b % dp == 0
                                                    and b >= dp)
    cache_total = cfg.n_layers * b * t * kv_token
    cache_dev = cache_total / n_chips if shard_seq \
        else cache_total / (dp * tp)
    w = p_gathered
    return w + cache_dev + b / dp * cfg.d_model * 2 * cfg.n_layers * 12


def _gnn(cfg: gnn.GNNConfig, cell: ShapeCell, n_chips) -> float:
    d = cell.dims
    n, e = d["n_nodes"], d["n_edges"]
    h = cfg.d_hidden
    dt = 2 if cfg.arch in ("graphcast", "dimenet") else 4
    if cfg.arch == "graphcast":
        # per layer: halo all_gather write+read of [N, h] + edge state
        # read/write + gathers [E/P, 3h] + node mlp, x3 for train bwd
        per_layer = (2 * n * h * dt + 4 * (e / n_chips) * h * dt
                     + 2 * (e / n_chips) * 3 * h * dt
                     + 4 * (n / n_chips) * h * dt)
        return 3.0 * cfg.n_layers * per_layer
    if cfg.arch == "dimenet":
        t3 = 2 * e
        per_layer = ((e / n_chips) * h * dt * 6
                     + (t3 / n_chips) * h * dt * 3)
        return 3.0 * cfg.n_layers * per_layer + 2 * n * cfg.d_feat * dt
    # graphsage / gat: replicated-node SPMD path
    per_layer = (2 * n * h * dt + 4 * (e / n_chips) * h * dt)
    return 3.0 * cfg.n_layers * per_layer


def _recsys(cfg: recsys.RecsysConfig, cell: ShapeCell, n_chips, tp,
            dp) -> float:
    d = cell.dims
    b = d["batch"]
    if cell.kind == "retrieval":
        return (d["n_candidates"] / n_chips * cfg.mlp_dims[-1] * 4
                + sum(a * 4 for a in cfg.mlp_dims))
    per_dev_rows = b * cfg.n_sparse * cfg.hots_per_field / \
        (dp if cell.kind == "train" else n_chips)
    lookup = per_dev_rows * cfg.embed_dim * 4 * 2     # gather + combine
    d_in = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    dims = (d_in,) + cfg.mlp_dims + (1,)
    w_bytes = sum(a * bb for a, bb in zip(dims[:-1], dims[1:])) * 4
    act = per_dev_rows / cfg.hots_per_field * d_in * 4
    mult = 3.0 if cell.kind == "train" else 1.0
    table_update = (cfg.n_sparse * cfg.rows_per_field * cfg.embed_dim
                    * 4 / tp) if cell.kind == "train" else 0.0
    # sparse AdamW touches only gathered rows; dense tables modelled as
    # row-sparse update traffic
    table_update = min(table_update, lookup * 6)
    return mult * (lookup + w_bytes + act) + table_update
