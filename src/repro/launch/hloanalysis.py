"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
64-layer scanned transformer reports ~1/64th of its real FLOPs (verified
empirically — EXPERIMENTS.md §Roofline methodology).  This module
re-derives per-device costs from the partitioned module text:

  * flops — dot FLOPs (2*out_elems*K), multiplied through while-loop
    trip counts (XLA annotates ``known_trip_count`` in backend_config;
    fallback: the loop condition's compare-with-constant) and through
    fusion/call boundaries.  Dot-only by design: the MXU term is the
    compute-roofline numerator; elementwise VPU work is not.
  * bytes — operand+result bytes of top-level instructions (fusion
    internals excluded — they never touch HBM), loop-aware as above.

Shapes of operands are resolved through a per-computation symbol table
(HLO instruction operands are untyped references).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# instruction: "%name = TYPE opcode(...)..." (ROOT prefix optional)
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota"}


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    text: str

    def operands(self) -> List[str]:
        # references inside the first call parens
        i = self.text.find(self.opcode + "(")
        rest = self.text[i + len(self.opcode) + 1:]
        # cut at the matching close: operands never contain parens except
        # via nested %refs, so cut at "), " attr boundary or final ")"
        depth = 1
        out_chars = []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            out_chars.append(ch)
        return re.findall(r"%([\w.\-]+)", "".join(out_chars))


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]          # value name -> type string


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s:
            continue
        if cur is None or (s.endswith("{") and "=" not in s.split("(")[0]):
            m = _COMP_RE.match(s)
            if m and s.endswith("{"):
                cur = Computation(name=m.group(1), instrs=[], symbols={})
                comps[cur.name] = cur
                # parameters from the signature
                for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|"
                                      r"(?:[a-z0-9]+\[[0-9,]*\]\S*))",
                                      m.group(2)):
                    cur.symbols[pm.group(1)] = pm.group(2)
                continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(s)
        if im:
            ins = Instr(name=im.group(1), type_str=im.group(2),
                        opcode=im.group(3), text=s)
            cur.instrs.append(ins)
            cur.symbols[ins.name] = ins.type_str
    return comps


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")


@dataclasses.dataclass
class Analysis:
    flops: float
    bytes: float
    unknown_trips: int
    copy_bytes: float = 0.0  # CPU-backend reshard/layout copies (absent
    #                          on TPU; excluded from ``bytes``)
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


def _fusion_result_bytes(ins: "Instr", comps: Dict[str, "Computation"]
                         ) -> int:
    """If the fused root is a dynamic-update-slice the result aliases
    the input and only the update window is written."""
    full = _shape_bytes(_shapes_in(ins.type_str))
    cm = re.search(r"calls=%?([\w.\-]+)", ins.text)
    if not cm or cm.group(1) not in comps:
        return full
    fused = comps[cm.group(1)]
    if fused.instrs and fused.instrs[-1].opcode == "dynamic-update-slice":
        root = fused.instrs[-1]
        ops_ = root.operands()
        upd = next((o for o in reversed(ops_)
                    if o in fused.symbols
                    and "s32[]" not in fused.symbols[o]
                    and fused.symbols[o] != root.type_str), None)
        if upd:
            return min(_shape_bytes(_shapes_in(fused.symbols[upd])), full)
    return full


def _fusion_operand_bytes(ins: "Instr", comp: "Computation",
                          comps: Dict[str, "Computation"]) -> int:
    """HBM reads of a fusion: per operand, the *consumed* window.

    If an operand's only consumers inside the fused computation are
    dynamic-slice/gather, charge the slice results (a loop-invariant
    stacked weight sliced per iteration reads one layer, not the stack);
    otherwise charge the full operand."""
    cm = re.search(r"calls=%?([\w.\-]+)", ins.text)
    ops_ = ins.operands()
    if not cm or cm.group(1) not in comps:
        return sum(_shape_bytes(_shapes_in(comp.symbols[o]))
                   for o in ops_ if o in comp.symbols)
    fused = comps[cm.group(1)]
    # map parameter index -> operand name
    params: Dict[str, int] = {}
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", fi.text)
            if pm:
                params[fi.name] = int(pm.group(1))
    def consumers_of(val: str, depth: int = 0) -> List[Instr]:
        """Consumers, looking through bitcast/reshape/copy wrappers."""
        out: List[Instr] = []
        for c in fused.instrs:
            if val not in c.operands():
                continue
            if c.opcode in ("bitcast", "reshape", "copy") and depth < 4:
                out += consumers_of(c.name, depth + 1)
            else:
                out.append(c)
        return out

    total = 0
    for fi_name, idx in params.items():
        if idx >= len(ops_):
            continue
        op_name = ops_[idx]
        full = (_shape_bytes(_shapes_in(comp.symbols[op_name]))
                if op_name in comp.symbols else 0)
        consumers = consumers_of(fi_name)
        windowed = ("dynamic-slice", "gather", "dynamic-update-slice")
        if consumers and all(c.opcode in windowed for c in consumers):
            sliced = 0
            for c in consumers:
                if c.opcode == "dynamic-update-slice":
                    continue  # aliased in place; update charged itself
                sliced += _shape_bytes(_shapes_in(c.type_str))
            total += min(sliced, full) if full else sliced
        else:
            total += full
    return total


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    res = _shapes_in(ins.type_str)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.text)
    ops = ins.operands()
    if m and ops and ops[0] in symbols:
        lhs = _shapes_in(symbols[ops[0]])
        if lhs:
            dims = lhs[0][1]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _while_trip(ins: Instr, comps: Dict[str, Computation]) -> Tuple[int, bool]:
    m = _TRIP_RE.search(ins.text)
    if m:
        return max(int(m.group(1)), 1), True
    cm = re.search(r"condition=%?([\w.\-]+)", ins.text)
    if cm and cm.group(1) in comps:
        consts = []
        for i2 in comps[cm.group(1)].instrs:
            c = re.match(r".*s32\[\]\s+constant\((\-?\d+)\)", i2.text)
            if c:
                consts.append(int(c.group(1)))
        if consts:
            return max(max(consts), 1), True
    return 1, False


def analyze(hlo: str, entry: Optional[str] = None) -> Analysis:
    comps = parse_computations(hlo)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))
    memo_f: Dict[str, float] = {}
    memo_b: Dict[str, float] = {}
    memo_c: Dict[str, float] = {}
    unknown = [0]

    def callees(ins: Instr) -> List[str]:
        out = []
        for key in ("calls", "to_apply", "body"):
            m = re.search(key + r"=%?([\w.\-]+)", ins.text)
            if m:
                out.append(m.group(1))
        m = re.search(r"branch_computations=\{([^}]*)\}", ins.text)
        if m:
            out += re.findall(r"%?([\w.\-]+)", m.group(1))
        return out

    def flops_of(name: str, stack=()) -> float:
        if name in memo_f:
            return memo_f[name]
        if name not in comps or name in stack:
            return 0.0
        comp = comps[name]
        total = 0.0
        for ins in comp.instrs:
            if ins.opcode in ("dot", "dot_general"):
                total += _dot_flops(ins, comp.symbols)
            elif ins.opcode == "while":
                trip, known = _while_trip(ins, comps)
                if not known:
                    unknown[0] += 1
                bm = re.search(r"body=%?([\w.\-]+)", ins.text)
                if bm:
                    total += trip * flops_of(bm.group(1), stack + (name,))
            else:
                for c in callees(ins):
                    total += flops_of(c, stack + (name,))
        memo_f[name] = total
        return total

    def bytes_of(name: str, stack=()) -> Tuple[float, float]:
        """-> (hbm_bytes, copy_bytes), both loop-aware."""
        if name in memo_b:
            return memo_b[name], memo_c[name]
        if name not in comps or name in stack:
            return 0.0, 0.0
        comp = comps[name]
        total = 0.0
        copies = 0.0
        for ins in comp.instrs:
            if ins.opcode == "while":
                trip, _ = _while_trip(ins, comps)
                bm = re.search(r"body=%?([\w.\-]+)", ins.text)
                if bm:
                    b_, c_ = bytes_of(bm.group(1), stack + (name,))
                    total += trip * b_
                    copies += trip * c_
                continue
            if ins.opcode in ("call", "conditional"):
                for c in callees(ins):
                    b_, c_ = bytes_of(c, stack + (name,))
                    total += b_
                    copies += c_
                continue
            if ins.opcode in _SKIP_BYTES_OPS:
                continue
            res_bytes = _shape_bytes(_shapes_in(ins.type_str))
            if ins.opcode == "copy":
                # CPU-backend reshard/layout copies: real traffic on this
                # compile, absent on TPU — tracked separately
                copies += 2 * res_bytes
                continue
            if ins.opcode in ("dynamic-slice", "gather"):
                # reads the sliced window, not the whole operand
                total += 2 * res_bytes
                continue
            if ins.opcode in ("dynamic-update-slice", "scatter"):
                # writes the update window (result aliases the operand);
                # update tensor is the last data operand
                ops_ = ins.operands()
                upd = next((o for o in reversed(ops_)
                            if o in comp.symbols
                            and "s32[]" not in comp.symbols[o]), None)
                upd_b = (_shape_bytes(_shapes_in(comp.symbols[upd]))
                         if upd else res_bytes)
                total += 2 * min(upd_b, res_bytes)
                continue
            if ins.opcode == "fusion":
                total += (_fusion_result_bytes(ins, comps)
                          + _fusion_operand_bytes(ins, comp, comps))
                continue
            total += res_bytes
            for op in ins.operands():
                if op in comp.symbols:
                    total += _shape_bytes(_shapes_in(comp.symbols[op]))
        memo_b[name] = total
        memo_c[name] = copies
        return total, copies

    coll: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    memo_coll: Dict[str, Dict[str, float]] = {}

    def coll_of(name: str, stack=()) -> Dict[str, float]:
        """Loop-aware per-kind collective result bytes."""
        if name in memo_coll:
            return memo_coll[name]
        if name not in comps or name in stack:
            return {}
        comp = comps[name]
        acc: Dict[str, float] = {}

        def add(d, mult=1.0):
            for k, v in d.items():
                acc[k] = acc.get(k, 0.0) + mult * v

        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES and not ins.opcode.endswith("-done"):
                acc[base] = acc.get(base, 0.0) + _shape_bytes(
                    _shapes_in(ins.type_str))
            elif ins.opcode == "while":
                trip, _ = _while_trip(ins, comps)
                bm = re.search(r"body=%?([\w.\-]+)", ins.text)
                if bm:
                    add(coll_of(bm.group(1), stack + (name,)), trip)
            else:
                for c in callees(ins):
                    add(coll_of(c, stack + (name,)))
        memo_coll[name] = acc
        return acc

    coll.update(coll_of(entry))
    hbm, copies = bytes_of(entry)
    return Analysis(flops=flops_of(entry), bytes=hbm,
                    unknown_trips=unknown[0], copy_bytes=copies,
                    collectives=coll)
