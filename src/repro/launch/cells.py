"""Cell assembly: (arch x shape x mesh) -> AOT-lowerable bundle.

A CellBundle carries the step callable, ShapeDtypeStruct args and
NamedShardings — everything launch/dryrun.py needs to ``jit(...,
in_shardings).lower(*args).compile()`` without allocating a byte.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..configs import get_arch
from ..configs.api import ArchSpec, ShapeCell
from ..models import gnn, recsys, transformer
from ..models.common import Shardings
from ..optim import adamw_init
from . import flops, steps

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellBundle:
    arch_id: str
    shape_name: str
    kind: str
    fn: Any
    args: Tuple
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    model_flops: float
    notes: str = ""


def _named(sh: Shardings, spec_tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(sh.mesh, p), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _params_struct(init_fn):
    return jax.eval_shape(init_fn, jax.random.PRNGKey(0))


def _replicated_like(sh: Shardings, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(sh.mesh, sh.spec()), tree)


def build_cell(arch_id: str, shape_name: str, mesh) -> CellBundle:
    spec = get_arch(arch_id)
    cell = spec.shape(shape_name)
    sh = Shardings(mesh=mesh)
    if spec.family == "lm":
        return _build_lm(spec, cell, sh)
    if spec.family == "gnn":
        return _build_gnn(spec, cell, sh)
    return _build_recsys(spec, cell, sh)


# ---------------------------------------------------------------------------
def _dp_size(sh: Shardings) -> int:
    out = 1
    for a in (sh.dp or ()):
        out *= sh.mesh.shape[a]
    return out


def _flat_axes(sh: Shardings):
    return tuple(sh.mesh.axis_names)


def _build_lm(spec: ArchSpec, cell: ShapeCell, sh: Shardings) -> CellBundle:
    cfg: transformer.LMConfig = spec.model_cfg
    d = cell.dims
    b, t = d["global_batch"], d["seq_len"]
    pstruct = _params_struct(lambda k: transformer.init_params(cfg, k))
    pshard = _named(sh, transformer.param_specs(cfg, sh))
    mf = flops.model_flops(spec, cell)
    dp = _dp_size(sh)
    batch_shardable = b % dp == 0 and b >= dp

    if cell.kind == "train":
        n_micro = max(1, (b // dp) // spec.seqs_per_micro)
        fn = steps.lm_train_step(
            cfg, sh, n_micro, serialize_update=spec.serialize_opt_update,
            accum_dtype=jnp.dtype(spec.grad_accum_dtype))
        sdt = jnp.dtype(spec.opt_state_dtype)
        ostruct = jax.eval_shape(lambda p: adamw_init(p, sdt), pstruct)
        # m/v shardings: FSDP-sharded even under ZeRO-1 (params may
        # replicate over data while opt state stays sharded); step repl.
        oshard_specs = _named(sh, transformer.param_specs(
            cfg, sh, for_opt_state=True))
        oshard = type(ostruct)(
            m=oshard_specs, v=oshard_specs,
            step=NamedSharding(sh.mesh, sh.spec()))
        tokens = SDS((b, t), jnp.int32)
        tshard = NamedSharding(sh.mesh, sh.spec(sh.dp, None))
        return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                          (pstruct, ostruct, tokens),
                          (pshard, oshard, tshard),
                          donate_argnums=(0, 1), model_flops=mf,
                          notes=f"n_micro={n_micro}")

    if cell.kind == "prefill":
        fn = steps.lm_prefill_step(cfg, sh)
        tokens = SDS((b, t), jnp.int32)
        tshard = NamedSharding(
            sh.mesh, sh.spec(sh.dp if batch_shardable else None, None))
        return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                          (pstruct, tokens), (pshard, tshard),
                          donate_argnums=(), model_flops=mf)

    # decode
    fn = steps.lm_decode_step(cfg, sh)
    shard_seq = bool(d.get("shard_seq", 0)) or not batch_shardable
    cspec = transformer.cache_specs(cfg, sh, b, t, shard_seq=shard_seq)
    cstruct = {k: v[0] for k, v in cspec.items()}
    cshard = {k: NamedSharding(sh.mesh, v[1]) for k, v in cspec.items()}
    token = SDS((b,), jnp.int32)
    tokshard = NamedSharding(
        sh.mesh, sh.spec(sh.dp if batch_shardable else None))
    return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                      (pstruct, cstruct, token),
                      (pshard, cshard, tokshard),
                      donate_argnums=(1,), model_flops=mf,
                      notes=f"shard_seq={shard_seq}")


# ---------------------------------------------------------------------------
_GNN_KEYS = {
    "graphcast": ("node_feat", "edge_src", "edge_dst", "edge_feat",
                  "target", "loss_mask"),
    "dimenet": ("node_feat", "edge_src", "edge_dst", "edge_dist",
                "tri_edge_kj", "tri_edge_ji", "tri_angle", "graph_id",
                "target_g"),
    "graphsage": ("node_feat", "edge_src", "edge_dst", "labels",
                  "loss_mask"),
    "gat": ("node_feat", "edge_src", "edge_dst", "labels", "loss_mask"),
}


def _build_gnn(spec: ArchSpec, cell: ShapeCell, sh: Shardings) -> CellBundle:
    import dataclasses as dc
    base: gnn.GNNConfig = spec.model_cfg
    d = cell.dims
    # graphcast/dimenet use the shard_map halo path on device meshes
    # (bf16 hidden state: the all_gather working set halves)
    sharded = base.arch in ("graphcast", "dimenet")
    cfg = dc.replace(base, d_feat=d["d_feat"], sharded=sharded,
                     dtype=jnp.bfloat16 if sharded else base.dtype)
    n, e, g_ = d["n_nodes"], d["n_edges"], d["n_graphs"]
    t3 = 2 * e
    flat = _flat_axes(sh)
    full = {
        "node_feat": (SDS((n, cfg.d_feat), jnp.float32), (flat, None)),
        "edge_src": (SDS((e,), jnp.int32), (flat,)),
        "edge_dst": (SDS((e,), jnp.int32), (flat,)),
        "edge_feat": (SDS((e, cfg.d_edge), jnp.float32), (flat, None)),
        "edge_dist": (SDS((e,), jnp.float32), (flat,)),
        "labels": (SDS((n,), jnp.int32), (flat,)),
        "loss_mask": (SDS((n,), jnp.float32), (flat,)),
        "target": (SDS((n, cfg.n_out), jnp.float32), (flat, None)),
        "graph_id": (SDS((n,), jnp.int32), (flat,)),
        "target_g": (SDS((g_,), jnp.float32), (None,)),
        "tri_edge_kj": (SDS((t3,), jnp.int32), (flat,)),
        "tri_edge_ji": (SDS((t3,), jnp.int32), (flat,)),
        "tri_angle": (SDS((t3,), jnp.float32), (flat,)),
    }
    keys = _GNN_KEYS[cfg.arch]
    bstruct = {k: full[k][0] for k in keys}
    bshard = {k: NamedSharding(sh.mesh, sh.spec(*full[k][1]))
              for k in keys}
    pstruct = _params_struct(lambda k: gnn.init_params(cfg, k))
    pshard = _replicated_like(sh, pstruct)
    ostruct = jax.eval_shape(adamw_init, pstruct)
    oshard = type(ostruct)(m=_replicated_like(sh, pstruct),
                           v=_replicated_like(sh, pstruct),
                           step=NamedSharding(sh.mesh, sh.spec()))
    fn = steps.gnn_train_step(cfg, sh)
    return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                      (pstruct, ostruct, bstruct),
                      (pshard, oshard, bshard),
                      donate_argnums=(0, 1),
                      model_flops=flops.model_flops(spec, cell),
                      notes=f"padded n={n} e={e}")


# ---------------------------------------------------------------------------
def _build_recsys(spec: ArchSpec, cell: ShapeCell,
                  sh: Shardings) -> CellBundle:
    cfg: recsys.RecsysConfig = spec.model_cfg
    d = cell.dims
    b = d["batch"]
    flat = _flat_axes(sh)
    pstruct = _params_struct(lambda k: recsys.init_params(cfg, k))
    pshard = _named(sh, recsys.param_specs(cfg, sh))
    mf = flops.model_flops(spec, cell)
    if cell.kind == "retrieval":
        fn = steps.recsys_retrieval_step(cfg, sh)
        bstruct = {
            "sparse_ids": SDS((1, cfg.n_sparse, cfg.hots_per_field),
                              jnp.int32),
            "dense": SDS((1, cfg.n_dense), jnp.float32),
            "candidates": SDS((d["n_candidates"], cfg.mlp_dims[-1]),
                              jnp.float32),
        }
        bshard = {
            "sparse_ids": NamedSharding(sh.mesh, sh.spec()),
            "dense": NamedSharding(sh.mesh, sh.spec()),
            "candidates": NamedSharding(sh.mesh, sh.spec(flat, None)),
        }
        return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                          (pstruct, bstruct), (pshard, bshard),
                          donate_argnums=(), model_flops=mf)
    batch_axes = sh.dp if cell.kind == "train" else flat
    bstruct = {
        "sparse_ids": SDS((b, cfg.n_sparse, cfg.hots_per_field),
                          jnp.int32),
        "dense": SDS((b, cfg.n_dense), jnp.float32),
    }
    bshard = {
        "sparse_ids": NamedSharding(sh.mesh, sh.spec(batch_axes, None,
                                                     None)),
        "dense": NamedSharding(sh.mesh, sh.spec(batch_axes, None)),
    }
    if cell.kind == "train":
        bstruct["labels"] = SDS((b,), jnp.int32)
        bshard["labels"] = NamedSharding(sh.mesh, sh.spec(batch_axes))
        ostruct = jax.eval_shape(adamw_init, pstruct)
        oshard = type(ostruct)(m=pshard, v=jax.tree_util.tree_map(
            lambda s: s, pshard),
            step=NamedSharding(sh.mesh, sh.spec()))
        fn = steps.recsys_train_step(cfg, sh)
        return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                          (pstruct, ostruct, bstruct),
                          (pshard, oshard, bshard), donate_argnums=(0, 1),
                          model_flops=mf)
    fn = steps.recsys_serve_step(cfg, sh)
    return CellBundle(spec.arch_id, cell.name, cell.kind, fn,
                      (pstruct, bstruct), (pshard, bshard),
                      donate_argnums=(), model_flops=mf)
