"""DISLAND serving driver (the paper's end-to-end application).

Builds the full index over a synthetic road graph, uploads the device
tensors, then serves batched shortest-distance queries — by default
through the case-bucketing QueryPlanner (each jitted sub-program does
only its bucket's work), or monolithically (--mode fused) or sharded
over a device mesh (--mode sharded) — and validates a sample against
host Dijkstra.  Each run appends a perf record to BENCH_serve.json so
the µs/query trajectory is tracked across PRs.

``--update-batches`` turns on the live-traffic loop (planner mode):
between serving batches, a localized weight-update batch is absorbed by
the incremental refresh path and published as a new index epoch
(DESIGN.md §9); refresh latency, the from-scratch rebuild baseline, and
an exact-match check against that rebuild are all recorded.

``--live`` replaces the offline batch loop with the online serving
runtime (DESIGN.md §11): an open-loop Poisson arrival stream with a
Zipf/geo/uniform query mix flows through the deadline-aware
micro-batcher and the epoch-tagged result cache, optionally while a
background thread absorbs ``--live-update-batches`` traffic rounds
concurrently; p50/p95/p99 latency, achieved qps, cache hit rate, and
the batch-occupancy histogram are recorded, and a response sample is
validated against the host oracle *of the epoch that served it*.

    PYTHONPATH=src python -m repro.launch.serve --nodes 4000 \
        --batches 5 --batch-size 1024 --validate 64 \
        --update-batches 3 --update-frac 0.02
    PYTHONPATH=src python -m repro.launch.serve --nodes 4000 --live \
        --rate 2000 --live-seconds 5 --mix zipf \
        --live-update-batches 3 --validate 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dijkstra
from ..core.device_engine import (build_device_index, index_fields_equal,
                                  serve_step)
from ..core.dist_engine import EpochedEngine, serve_sharded
from ..core.graph import road_like, traffic_updates
from ..core.paths import path_weight
from ..core.supergraph import (build_index, index_arrays_equal,
                               reweight_index)
from ..obs import trace
from ..perflog import append_records, latest
from ..runtime import StragglerMonitor
from .mesh import make_host_mesh

REFRESHED_FIELDS = ("frag_apsp", "frag_next", "brow", "d_super",
                    "super_next", "piece_flat", "piece_next",
                    "dist_to_agent",
                    # hierarchical overlay tables — per-level tuples,
                    # empty (or 1-sized dummies) at hierarchy_levels=1,
                    # so the parity check is free on dense epochs
                    "sf_closure", "sf_next", "l2row", "d2", "d2_next",
                    # resident pre-lifted rows (dummies when cold)
                    "res_rows", "res_of_frag",
                    # hub-label hot-tier tables (dummies when no hub
                    # set is pinned) — refresh must reproduce the
                    # scratch rebuild bit-for-bit (DESIGN.md §15)
                    "hub_rows", "hub_of_agent")


# ---------------------------------------------------------------------------
# shared helpers (engine setup / validation / record emission)
# ---------------------------------------------------------------------------
def _label(args) -> str:
    """Graph label for perf records; tolerant of hand-built arg
    namespaces (tests drive the loops without the CLI preamble)."""
    return getattr(args, "graph_label", None) or f"road{args.nodes}"


def _overlay_record(engine: EpochedEngine) -> dict:
    """Overlay-closure shape + memory fields for perf records: the
    measurement behind the exp10 sub-quadratic claim (DESIGN.md §12)."""
    plan = engine.plan
    if plan.hierarchy_levels >= 2:
        from ..core.hierarchy import hier_overlay_stats

        rec = hier_overlay_stats(plan.hier, plan.S)
        rec["resident_groups"] = max(
            0, int(engine.dix.res_rows.shape[0]) - 1)
        return rec
    dense = 2 * (plan.S + 1) * (plan.S + 1) * 4
    return {"hierarchy_levels": 1, "S": plan.S,
            "overlay_bytes": dense, "overlay_dense_bytes": dense}


def _hub_selection(g, args) -> np.ndarray | None:
    """Traffic-head hub set for the label hot tier (DESIGN.md §15):
    the endpoints of the top-ranked rows of the Zipf pool the live
    workload draws from (same seed => bit-identical pool), first seen
    in rank order, capped at ``--hub-budget`` nodes.  Returns None when
    the budget is 0 (tier off)."""
    budget = int(getattr(args, "hub_budget", 0) or 0)
    if not budget:
        return None
    from ..data.queries import zipf_pool

    pairs = zipf_pool(g, seed=args.seed + 4)
    flat = pairs.ravel()        # rank-interleaved (s1, t1, s2, t2, ...)
    _, first = np.unique(flat, return_index=True)
    return flat[np.sort(first)][:budget]


def _host_build_record(args, timings: dict) -> list:
    """``section: "host_build"`` perf record from the host index stage
    timings (DESIGN.md §17) — the measurement behind the staged-
    pipeline speedup claim and the bench-gate ``host_build`` section."""
    stages = {k: round(float(v), 4) for k, v in timings.items()
              if isinstance(v, (int, float)) and not isinstance(v, bool)}
    return [{
        "section": "host_build",
        "graph": _label(args),
        "backend": jax.default_backend(),
        "build_workers": int(getattr(args, "build_workers", 1) or 1),
        "wall_s": round(sum(stages.values()), 4),
        **{f"stage_{k}_s": v for k, v in stages.items()},
    }]


def _build_engine(args) -> tuple[EpochedEngine, float]:
    """Graph + host index + EpochedEngine with timing prints — the one
    setup path shared by the planner serving loops (offline batches,
    --paths, --update-batches, --live).  All stage wall-times flow
    through the span API (DESIGN.md §16): the console prints, the
    returned ``build_s``, and the build trace all read one
    measurement.

    The host index is built *inside* ``EpochedEngine`` via the staged
    streaming handoff (DESIGN.md §17): with ``--build-workers N`` the
    per-fragment covers run process-parallel and overlap the device
    build, so the ``device_engine`` span covers the whole index
    pipeline end to end."""
    workers = int(getattr(args, "build_workers", 1) or 1)
    bt: dict = {}
    with trace.timed("build.graph", bt, "graph", nodes=args.nodes):
        g = road_like(args.nodes, seed=args.seed)
    print(f"graph: n={g.n} m={g.m} ({bt['graph']:.1f}s)")
    # refresh-path warmup compiles the delta-FW programs — minutes of
    # wasted work at road64k scale when the run applies no updates
    warm = bool(args.update_batches
                or (args.live and args.live_update_batches))
    hub_nodes = _hub_selection(g, args)
    with trace.timed("build.device_engine", bt, "device_engine",
                     warm_refresh=warm, build_workers=workers):
        engine = EpochedEngine(g, paths=args.paths,
                               hierarchy_levels=args.hierarchy_levels,
                               resident_mb=args.resident_mb,
                               warm_refresh=warm, hub_nodes=hub_nodes,
                               build_workers=workers)
    build_s = bt["device_engine"]
    print(f"index: {engine.ix.timings} (workers={workers})")
    if getattr(args, "check_build_parity", False):
        with trace.timed("build.parity_check", bt, "parity"):
            eq = index_arrays_equal(engine.ix, build_index(g))
        bad = [k for k, v in eq.items() if not v]
        if bad:
            raise SystemExit(
                f"build parity FAILED: --build-workers {workers} "
                f"diverges from the serial build on {bad}")
        print(f"build parity: workers={workers} == serial on all "
              f"index tables ({bt['parity']:.1f}s)")
    _emit(args, _host_build_record(args, engine.ix.timings),
          "host_build",
          prev_filter={"section": "host_build", "graph": _label(args),
                       "build_workers": workers},
          prev_key="wall_s")
    dix = engine.dix
    ov = _overlay_record(engine)
    print(f"device index: frag_apsp={dix.frag_apsp.shape} "
          f"d_super={dix.d_super.shape} ({build_s:.1f}s)")
    if hub_nodes is not None:
        h, w = np.asarray(dix.hub_rows).shape
        print(f"hub labels: {h - 1} agents x {w} hubs from "
              f"{len(hub_nodes)}-node budget")
    if ov["hierarchy_levels"] >= 2:
        print(f"overlay hierarchy: {ov['hierarchy_levels']} levels, "
              f"S2 ladder {ov['levels_S2']} from S={ov['S']} "
              f"(nsf={ov['nsf']} m2={ov['m2']}); "
              f"{ov['resident_groups']} resident groups; "
              f"{ov['overlay_bytes'] / 1e6:.1f}MB vs dense "
              f"{ov['overlay_dense_bytes'] / 1e6:.1f}MB")
    if args.expect_hierarchy and \
            ov["hierarchy_levels"] != args.expect_hierarchy:
        raise SystemExit(
            f"expected hierarchy_levels={args.expect_hierarchy}, "
            f"built {ov['hierarchy_levels']} (S={ov['S']})")
    if args.max_s2_ratio and ov["hierarchy_levels"] >= 2:
        ratio = ov["S2"] / max(1, ov["S"])
        if ratio > args.max_s2_ratio:
            raise SystemExit(
                f"level-2 boundary too large: S2={ov['S2']} / "
                f"S={ov['S']} = {ratio:.3f} > --max-s2-ratio "
                f"{args.max_s2_ratio}")
        print(f"S2/S ratio {ratio:.3f} <= {args.max_s2_ratio} (ok)")
    return engine, build_s


def _validate_sample(g, s, t, got, n_check: int, *,
                     label: str = "validation") -> int:
    """Distance sample vs host Dijkstra on ``g``; returns (and prints)
    the mismatch count.  Callers assert it is zero."""
    bad = 0
    n_check = min(n_check, len(s))
    for i in range(n_check):
        want = dijkstra.pair(g, int(s[i]), int(t[i]))
        bad += dijkstra.mismatches_oracle(want, float(got[i]))
    print(f"{label}: {bad} mismatches of {n_check}")
    return bad


def _emit(args, records: list, label: str, *, prev_filter=None,
          prev_key: str | None = None) -> None:
    """Append perf records to --json (when enabled), printing the most
    recent committed record for the same config first so the cross-PR
    delta is visible in the run log."""
    if not args.json or not records:
        return
    if prev_filter:
        prev = latest(args.json, **prev_filter)
        if prev and prev_key:
            print(f"previous {label} record: {prev[prev_key]}")
    append_records(args.json, records)
    print(f"{len(records)} {label} record(s) appended to {args.json}")


# ---------------------------------------------------------------------------
# serving loops
# ---------------------------------------------------------------------------
def _update_loop(engine: EpochedEngine, args, build_s: float) -> list:
    """Absorb --update-batches rounds of localized traffic, serving and
    validating on each new epoch; returns perf records."""
    records = []
    rng = np.random.default_rng(args.seed + 2)
    for r in range(args.update_batches):
        u, v, w = traffic_updates(engine.g, args.update_frac,
                                  seed=args.seed + 10 + r)
        # one measurement per stage (span API): record fields, prints,
        # and the trace all read the same numbers
        tm: dict = {}
        with trace.timed("refresh.apply_updates", tm, "refresh",
                         round=r, n_updates=len(u)):
            stats = engine.apply_updates(u, v, w)
        refresh_s = tm["refresh"]
        s = rng.integers(0, engine.g.n, args.batch_size)
        t = rng.integers(0, engine.g.n, args.batch_size)
        with trace.timed("serve.epoch_batch", tm, "serve",
                         epoch=engine.epoch):
            out = engine.query(s, t)
        serve_s = tm["serve"]
        bad = _validate_sample(engine.g, s, t, out, args.validate,
                               label=f"epoch {engine.epoch} validation")
        # Two from-scratch baselines on the updated graph, re-measured
        # each round so refresh and baseline share contention
        # conditions:
        #  * full pipeline (build_index + device build) — what a weight
        #    change costs WITHOUT the delta path, since the hybrid
        #    covers are weight-dependent (DESIGN.md §9);
        #  * reweight + device rebuild (same structure) — itself only
        #    possible because overlay weights are derived; also the
        #    array-parity exactness reference (checked on round 0).
        with trace.timed("refresh.scratch_pipeline", tm, "pipeline"):
            build_device_index(
                build_index(engine.g),
                hierarchy_levels=engine.plan.hierarchy_levels)
        pipeline_s = tm["pipeline"]
        # same hub set as the live plan: the parity check covers the
        # hub tables too (REFRESHED_FIELDS), so the scratch oracle
        # must label the identical node set
        with trace.timed("refresh.scratch_reweight", tm, "reweight"):
            sdix = build_device_index(
                reweight_index(engine.ix, engine.g),
                hierarchy_levels=engine.plan.hierarchy_levels,
                hub_nodes=engine.plan.hub_nodes)
        reweight_s = tm["reweight"]
        scratch_match = all(index_fields_equal(
            engine.dix, sdix, REFRESHED_FIELDS).values())
        rec = {
            "section": "refresh",
            "graph": _label(args),
            "backend": jax.default_backend(),
            "epoch": engine.epoch,
            "update_frac": args.update_frac,
            "refresh_s": round(refresh_s, 4),
            "scratch_pipeline_s": round(pipeline_s, 4),
            "scratch_reweight_s": round(reweight_s, 4),
            "refresh_over_scratch": round(refresh_s / pipeline_s, 4),
            "refresh_over_reweight": round(refresh_s / reweight_s, 4),
            "initial_build_s": round(build_s, 4),
            "post_refresh_mismatches": bad,
            "scratch_match": scratch_match,
            "serve_batch_ms": round(serve_s * 1e3, 3),
            **stats.as_record(),
        }
        records.append(rec)
        print(f"epoch {engine.epoch}: refresh {refresh_s*1e3:.0f}ms "
              f"({stats.as_record()['dirty_frags']} frags, "
              f"{stats.as_record()['dirty_pieces']} pieces, "
              f"decrease_only={stats.decrease_only}) -> "
              f"{refresh_s / pipeline_s:.1%} of full pipeline "
              f"({pipeline_s:.2f}s), "
              f"{refresh_s / reweight_s:.1%} of reweight rebuild "
              f"({reweight_s:.2f}s), match={scratch_match}")
        assert bad == 0
    return records


def _paths_loop(engine: EpochedEngine, args) -> list:
    """Serve the path-unwinding workload (planner witness programs +
    host-side unwind) and validate a sample; returns perf records."""
    rng = np.random.default_rng(args.seed + 3)
    monitor = StragglerMonitor()
    total = 0
    last = None
    for _ in range(args.batches):
        s = rng.integers(0, engine.g.n, args.batch_size).astype(np.int32)
        t = rng.integers(0, engine.g.n, args.batch_size).astype(np.int32)
        monitor.start()
        dist, paths = engine.query_path(s, t)
        monitor.stop()
        total += args.batch_size
        last = (s, t, dist, paths)
    summ = monitor.summary()
    per_p = summ["median_s"] / args.batch_size
    pps = args.batch_size / summ["median_s"]
    hops = [len(p) - 1 for p in last[3] if p is not None]
    print(f"paths: {total} unwound; median batch "
          f"{summ['median_s'] * 1e3:.2f}ms -> {per_p * 1e6:.2f}us/path "
          f"({pps:,.0f} paths/s, mean {np.mean(hops):.1f} hops)")
    s, t, dist, paths = last
    bad = 0
    for i in range(min(args.validate, len(s))):
        want = dijkstra.pair(engine.g, int(s[i]), int(t[i]))
        if np.isinf(want):
            bad += paths[i] is not None
            continue
        w = path_weight(engine.g, paths[i])   # raises on a broken hop
        if not (w == float(dist[i]) == want):
            bad += 1
    print(f"path validation: {bad} mismatches of {args.validate} "
          "(edge-valid, weight == serve == Dijkstra, exact)")
    assert bad == 0
    return [{
        "section": "serve_paths",
        "graph": _label(args),
        "backend": jax.default_backend(),
        "batch_size": args.batch_size,
        "median_batch_ms": round(summ["median_s"] * 1e3, 3),
        "us_per_path": round(per_p * 1e6, 3),
        "paths_per_s": round(pps, 1),
        "mean_hops": round(float(np.mean(hops)), 1) if hops else 0.0,
    }]


def _start_obs(args, runtime) -> dict:
    """Wire the live runtime's registry to the exporters the CLI asked
    for (--metrics-out periodic snapshots + Prometheus text sidecar,
    --metrics-port HTTP endpoint).  Returns the handles to stop."""
    handles: dict = {}
    if getattr(args, "metrics_out", ""):
        from ..obs import MetricsExporter

        handles["exporter"] = MetricsExporter(
            runtime.registry, args.metrics_out,
            interval_s=getattr(args, "metrics_every", 2.0),
            extra=lambda: {
                "slow_queries": runtime.slow_log.records()}).start()
    port = getattr(args, "metrics_port", 0)
    if port:
        from ..obs import MetricsServer

        srv = MetricsServer(runtime.registry, port).start()
        handles["server"] = srv
        print(f"metrics: http://127.0.0.1:{srv.port}/metrics")
    return handles


def _stop_obs(args, handles: dict) -> None:
    exporter = handles.get("exporter")
    if exporter is not None:
        exporter.stop()
        print(f"metrics: {exporter.writes} snapshot(s) -> "
              f"{args.metrics_out} (+ .prom exposition)")
    server = handles.get("server")
    if server is not None:
        server.stop()


def _write_trace(args) -> None:
    """Drain the default tracer into a Chrome-trace file
    (--trace-out; load it in chrome://tracing or Perfetto)."""
    if not getattr(args, "trace_out", ""):
        return
    from ..obs.export import write_chrome_trace

    tr = trace.get_tracer()
    events = tr.events()
    write_chrome_trace(args.trace_out, events)
    dropped = f" ({tr.dropped} dropped)" if tr.dropped else ""
    print(f"trace: {len(events)} event(s) -> {args.trace_out}"
          f"{dropped}")


def _live_loop(engine: EpochedEngine, args) -> list:
    """Online serving runtime under open-loop load (DESIGN.md §11),
    optionally with concurrent background refresh (pipelined through
    the prioritized staged path by default, DESIGN.md §14); returns a
    ``section: "serve_live"`` perf record, plus a ``serve_refresh``
    record when refresh rounds ran."""
    from ..serving import (ServingRuntime, run_load_with_refresh,
                           validate_against_epochs, workload_pairs)

    runtime = ServingRuntime(engine, max_batch=args.live_batch,
                             deadline_s=args.deadline_ms * 1e-3,
                             cache_size=args.cache_size)
    tm: dict = {}
    with trace.timed("serve.warmup", tm, "warmup"):
        runtime.warmup()
    print(f"live: warmed {runtime.max_batch}-cap buckets in "
          f"{tm['warmup']:.1f}s; deadline "
          f"{args.deadline_ms}ms, cache "
          f"{args.cache_size or 'off'}, mix {args.mix}")
    n = max(1, int(round(args.rate * args.live_seconds)))
    pairs = workload_pairs(engine.g, args.mix, n, seed=args.seed + 4,
                           zipf_a=args.zipf_a)
    obs_handles = _start_obs(args, runtime)
    try:
        report, graphs, driver = run_load_with_refresh(
            runtime, pairs, rate_qps=args.rate, seed=args.seed + 5,
            refresh_rounds=args.live_update_batches,
            refresh_frac=args.update_frac,
            refresh_interval_s=args.live_update_every,
            refresh_seed=args.seed,
            refresh_pipelined=args.live_pipelined,
            wait_timeout_s=args.live_wait_timeout,
            join_timeout_s=args.live_join_timeout)
        runtime.close()
    finally:
        _stop_obs(args, obs_handles)
    epochs = sorted({r.epoch for r in report.requests})
    stats = runtime.stats()
    # per-tier resolution split (DESIGN.md §15): every response came
    # from exactly one of cache / label merge / planner dispatch
    label_rate = stats["label_hits"] / max(
        1, stats["label_hits"] + stats["planner_dispatches"])
    print(f"live: {report.n_requests} requests at "
          f"{report.offered_qps:.0f} qps offered / "
          f"{report.achieved_qps:.0f} achieved; latency p50 "
          f"{report.p50_ms}ms p95 {report.p95_ms}ms p99 "
          f"{report.p99_ms}ms "
          f"({report.latency_source}, n={report.latency_n}); "
          f"tiers: {stats['cache_hits']} cache / "
          f"{stats['label_hits']} label / "
          f"{stats['planner_dispatches']} planner "
          f"({stats.get('cache_hit_rate', 0.0):.1%} cache hit rate, "
          f"{stats.get('cache_stale', 0)} stale rejected; label tier "
          f"took {label_rate:.1%} of misses at "
          f"{stats['label_us_per_query']:.0f}us/q vs planner "
          f"{stats['planner_us_per_query']:.0f}us/q); "
          f"{stats['flushes']} flushes, mean occupancy "
          f"{stats['mean_occupancy']:.1%} "
          f"(full={stats['flush_full']} "
          f"deadline={stats['flush_deadline']}); epochs served "
          f"{epochs}")
    slow = runtime.slow_log.records()
    if slow:
        w0 = slow[0]
        print(f"slow queries: worst {w0['latency_ms']:.0f}ms "
              f"(tier {w0['tier']}, epoch {w0['epoch']}, waited "
              f"{w0['batch_wait_ms']:.0f}ms in a "
              f"{w0['batch_size']}-request batch); {len(slow)} logged "
              f"of {runtime.slow_log.offered}")
    if args.live_update_batches:
        print(f"live staleness: max serving gap "
              f"{report.max_serving_gap_ms:.0f}ms, "
              f"{report.stale_responses} responses from mid-pipeline "
              f"epochs, max lag {report.max_staleness_batches} "
              "batch(es)")
    evicted = driver.evicted_epochs if driver is not None else ()
    checked, bad = validate_against_epochs(
        report.requests, graphs, sample=args.validate, seed=args.seed,
        evicted=evicted)
    print(f"live validation: {bad} mismatches of {checked} vs the "
          "host oracle of each response's serving epoch")
    assert bad == 0
    if args.max_serving_gap and \
            report.max_serving_gap_ms > args.max_serving_gap * 1e3:
        raise SystemExit(
            f"serving stalled: max gap {report.max_serving_gap_ms:.0f}"
            f"ms > --max-serving-gap {args.max_serving_gap}s — the "
            "foreground paused longer than the allowed bound")
    hot_tier = getattr(args, "hot_tier", 0.0) or 0.0
    if hot_tier and label_rate < hot_tier:
        raise SystemExit(
            f"hot tier underused: label tier served {label_rate:.1%} "
            f"of cache misses < --hot-tier {hot_tier:.1%} — the hub "
            "selection no longer covers the workload head")
    rec = {
        "section": "serve_live",
        "graph": _label(args),
        "backend": jax.default_backend(),
        "mix": args.mix,
        "rate_qps": args.rate,
        "deadline_ms": args.deadline_ms,
        "max_batch": runtime.max_batch,
        "cache": "on" if args.cache_size else "off",
        "refresh": "on" if args.live_update_batches else "off",
        "hub_budget": int(getattr(args, "hub_budget", 0) or 0),
        "label_hit_rate": round(label_rate, 4),
        "epochs_served": len(epochs),
        "oracle_checked": checked,
        "oracle_bad": bad,
        **report.as_record(),
    }
    records = [rec]
    if driver is not None:
        rec.update(driver.as_record())
        records.append({
            "section": "serve_refresh",
            "graph": _label(args),
            "backend": jax.default_backend(),
            "mix": args.mix,
            "rate_qps": args.rate,
            "update_frac": args.update_frac,
            "pipelined": args.live_pipelined,
            "max_serving_gap_ms": report.max_serving_gap_ms,
            "stale_responses": report.stale_responses,
            "max_staleness_batches": report.max_staleness_batches,
            "epochs_served": len(epochs),
            **driver.as_record(),
        })
    return records


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--graph", default=None,
                    help="named road preset (data/roads.py, e.g. "
                         "road64k); overrides --nodes and labels the "
                         "perf records")
    ap.add_argument("--hierarchy-levels", default=None,
                    help="overlay closure: 1 (dense), N>=2 (N-level "
                         "tiled hierarchy) or auto (deepen until the "
                         "top closure is small); default: the "
                         "preset's setting, else auto")
    ap.add_argument("--expect-hierarchy", type=int, default=0,
                    help="fail unless the built index uses exactly "
                         "this many overlay levels (CI smoke sanity; "
                         "catches an auto build silently falling back "
                         "to a shallower hierarchy)")
    ap.add_argument("--max-s2-ratio", type=float, default=0.0,
                    help="fail if the level-2 boundary exceeds this "
                         "fraction of S (partitioner-quality gate; "
                         "0 disables)")
    ap.add_argument("--resident-mb", default="auto",
                    help="budget (MB) for the epoch-resident "
                         "pre-lifted row cache on hierarchical "
                         "indices; 0 disables, auto uses the "
                         "built-in default")
    ap.add_argument("--build-workers", type=int, default=1,
                    help="process-parallel per-fragment cover workers "
                         "for the host build (DESIGN.md §17); the "
                         "parallel build is array-equal to --build-"
                         "workers 1 by contract")
    ap.add_argument("--check-build-parity", action="store_true",
                    help="rebuild the host index serially and fail "
                         "unless the --build-workers build is array-"
                         "equal on every index table (CI smoke)")
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("planner", "fused", "sharded"),
                    default="planner")
    ap.add_argument("--sharded", action="store_true",
                    help="alias for --mode sharded")
    ap.add_argument("--paths", action="store_true",
                    help="also serve exact paths (witness mode + host "
                         "unwind, planner only) and report paths/sec")
    ap.add_argument("--update-batches", type=int, default=0,
                    help="live-traffic rounds after serving (planner)")
    ap.add_argument("--update-frac", type=float, default=0.02,
                    help="fraction of edges perturbed per round")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="perf-record file ('' disables)")
    live = ap.add_argument_group("live serving (--live)")
    live.add_argument("--live", action="store_true",
                      help="replace the offline batch loop with the "
                           "online serving runtime: open-loop arrivals "
                           "through micro-batching + result cache "
                           "(planner only)")
    live.add_argument("--rate", type=float, default=1500.0,
                      help="offered arrival rate, queries/sec")
    live.add_argument("--live-seconds", type=float, default=4.0,
                      help="load duration (requests = rate * seconds)")
    live.add_argument("--mix", choices=("uniform", "zipf", "geo"),
                      default="zipf", help="query mix")
    live.add_argument("--zipf-a", type=float, default=1.2,
                      help="Zipf exponent for --mix zipf")
    live.add_argument("--deadline-ms", type=float, default=2.0,
                      help="micro-batch flush deadline")
    live.add_argument("--live-batch", type=int, default=256,
                      help="micro-batch size cap (snapped to a planner "
                           "bucket size)")
    live.add_argument("--cache-size", type=int, default=65536,
                      help="result-cache capacity (0 disables)")
    live.add_argument("--hub-budget", type=int, default=0,
                      help="pin hub labels (DESIGN.md §15) for up to "
                           "this many traffic-head nodes (the Zipf "
                           "pool's top-ranked endpoints); 0 disables "
                           "the label hot tier")
    live.add_argument("--hot-tier", type=float, default=0.0,
                      help="fail unless the label tier served at "
                           "least this fraction of cache misses "
                           "(CI smoke gate; requires --hub-budget)")
    live.add_argument("--live-update-batches", type=int, default=0,
                      help="concurrent background refresh rounds "
                           "during the load run")
    live.add_argument("--live-pipelined",
                      action=argparse.BooleanOptionalAction,
                      default=True,
                      help="stage each refresh round through the "
                           "prioritized pipeline (one epoch per work "
                           "item, traffic-weighted order, staleness "
                           "tags); --no-live-pipelined restores the "
                           "monolithic one-epoch-per-round path")
    live.add_argument("--max-serving-gap", type=float, default=0.0,
                      help="fail if no response completes for longer "
                           "than this many seconds during the live "
                           "run (0 disables; the CI road64k smoke "
                           "sets a bound well under the refresh "
                           "wall time, so a stop-the-world re-close "
                           "fails it)")
    live.add_argument("--live-wait-timeout", type=float, default=60.0,
                      help="seconds to wait for every response after "
                           "the load phase (raise at road64k scale: "
                           "flushes contend with concurrent refresh "
                           "FW on CPU)")
    live.add_argument("--live-join-timeout", type=float, default=900.0,
                      help="seconds to wait for background refresh "
                           "rounds to finish after the load phase (a "
                           "road64k hierarchical re-close is minutes "
                           "on CPU)")
    live.add_argument("--live-update-every", type=float, default=0.25,
                      help="seconds between background refresh rounds")
    obs = ap.add_argument_group("observability (DESIGN.md §16)")
    obs.add_argument("--metrics-out", default="",
                     help="write periodic metrics snapshots (JSON + "
                          "Prometheus .prom sidecar) to this path "
                          "during --live ('' disables)")
    obs.add_argument("--metrics-every", type=float, default=2.0,
                     help="seconds between metrics snapshots")
    obs.add_argument("--metrics-port", type=int, default=0,
                     help="serve live Prometheus text at "
                          "127.0.0.1:PORT/metrics during --live "
                          "(0 disables)")
    obs.add_argument("--trace-out", default="",
                     help="enable tracing spans and write the Chrome-"
                          "trace JSON here at exit (build, refresh, "
                          "and per-request serve spans; load in "
                          "chrome://tracing)")
    args = ap.parse_args()
    preset = None
    if args.graph:
        from ..data.roads import road_preset

        preset = road_preset(args.graph)
        args.nodes = preset.nodes
    args.graph_label = preset.name if preset else f"road{args.nodes}"
    if args.hierarchy_levels is None:
        args.hierarchy_levels = preset.hierarchy if preset else "auto"
    elif args.hierarchy_levels != "auto":
        args.hierarchy_levels = int(args.hierarchy_levels)
    if args.resident_mb != "auto":
        args.resident_mb = float(args.resident_mb)
    mode = "sharded" if args.sharded else args.mode
    if args.expect_hierarchy and mode != "planner":
        # the guard lives in _build_engine (planner setup); accepting
        # it elsewhere would silently skip the check it exists for
        ap.error("--expect-hierarchy requires --mode planner")
    if args.update_batches and mode != "planner":
        ap.error("--update-batches requires --mode planner")
    if args.check_build_parity and mode != "planner":
        ap.error("--check-build-parity requires --mode planner "
                 "(the parity check lives in the planner setup path)")
    if args.paths and mode != "planner":
        ap.error("--paths requires --mode planner")
    if args.live and mode != "planner":
        ap.error("--live requires --mode planner")
    if args.live and args.paths:
        ap.error("--paths is not supported with --live (the live "
                 "runtime serves distances only)")
    if args.hub_budget and not args.live:
        ap.error("--hub-budget requires --live (the label hot tier "
                 "is a serving-runtime tier)")
    if args.hot_tier and not args.hub_budget:
        ap.error("--hot-tier requires --hub-budget (no labels, no "
                 "label hits to gate on)")
    if (args.metrics_out or args.metrics_port) and not args.live:
        ap.error("--metrics-out/--metrics-port require --live (the "
                 "metrics registry lives on the serving runtime)")
    if args.trace_out:
        # enable before the build so the build/refresh stage spans
        # land in the same trace as the serve lifecycle events
        trace.get_tracer().enable()

    if args.live:
        engine, _build_s = _build_engine(args)
        _emit(args, _live_loop(engine, args), "live",
              prev_filter={"section": "serve_live",
                           "graph": _label(args),
                           "mix": args.mix, "rate_qps": args.rate,
                           "cache": "on" if args.cache_size else "off",
                           "refresh": "on" if args.live_update_batches
                           else "off"},
              prev_key="p99_ms")
        if args.update_batches:
            _emit(args, _update_loop(engine, args, _build_s), "refresh")
        _write_trace(args)
        return

    engine = None
    if mode == "planner":
        engine, build_s = _build_engine(args)
        dix = engine.dix
    else:
        bt: dict = {}
        with trace.timed("build.graph", bt, "graph",
                         nodes=args.nodes):
            g = road_like(args.nodes, seed=args.seed)
        print(f"graph: n={g.n} m={g.m} ({bt['graph']:.1f}s)")
        with trace.timed("build.host_index", bt, "host_index",
                         build_workers=args.build_workers):
            ix = build_index(g, build_workers=args.build_workers)
        print(f"index: {ix.timings} ({bt['host_index']:.1f}s)")
        _emit(args, _host_build_record(args, ix.timings), "host_build",
              prev_filter={"section": "host_build",
                           "graph": _label(args),
                           "build_workers": args.build_workers},
              prev_key="wall_s")
        with trace.timed("build.device_index", bt, "device_index"):
            dix = build_device_index(
                ix, hierarchy_levels=args.hierarchy_levels)
        build_s = bt["device_index"]
        print(f"device index: frag_apsp={dix.frag_apsp.shape} "
              f"d_super={dix.d_super.shape} ({build_s:.1f}s)")
    g = engine.g if engine is not None else g

    rng = np.random.default_rng(args.seed + 1)
    monitor = StragglerMonitor()
    planner = None
    if mode == "sharded":
        mesh = make_host_mesh()
        fn = lambda s, t: serve_sharded(mesh, dix, s, t)  # noqa: E731
    elif mode == "planner":
        planner = engine.planner
        fn = planner
    else:
        jfn = jax.jit(lambda s, t: serve_step(dix, s, t))
        fn = jfn
    # warm-up before timing: the planner pre-compiles every sub-program
    # at every padded bucket size a batch can produce; the other modes
    # compile their one program on a throwaway batch
    if planner is not None:
        planner.warmup(args.batch_size)
    else:
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        jax.block_until_ready(jnp.asarray(fn(s, t)))
    total_q = 0
    last = None
    for i in range(args.batches):
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        monitor.start()
        out = jax.block_until_ready(jnp.asarray(fn(s, t)))
        monitor.stop()
        total_q += args.batch_size
        last = (np.asarray(s), np.asarray(t), np.asarray(out))
    summ = monitor.summary()
    per_q = summ["median_s"] / args.batch_size
    qps = args.batch_size / summ["median_s"]
    print(f"served {total_q} queries; median batch {summ['median_s']*1e3:.2f}ms "
          f"-> {per_q*1e6:.2f}us/query ({qps:,.0f} qps)")
    if planner is not None:
        print(f"planner buckets (last batch): {planner.last_counts}")
    _emit(args, [{
        "section": "serve",
        "graph": _label(args),
        "mode": mode,
        "backend": jax.default_backend(),
        "batch_size": args.batch_size,
        "median_batch_ms": round(summ["median_s"] * 1e3, 3),
        "us_per_query": round(per_q * 1e6, 3),
        "qps": round(qps, 1),
        **({} if engine is None else _overlay_record(engine)),
    }], mode, prev_filter={"section": "serve",
                           "graph": _label(args), "mode": mode},
        prev_key="us_per_query")
    if args.validate:
        s, t, got = last
        bad = _validate_sample(g, s, t, got, args.validate)
        assert bad == 0
    if args.paths:
        _emit(args, _paths_loop(engine, args), "paths",
              prev_filter={"section": "serve_paths",
                           "graph": _label(args)},
              prev_key="us_per_path")
    if args.update_batches:
        _emit(args, _update_loop(engine, args, build_s), "refresh")
    _write_trace(args)


if __name__ == "__main__":
    main()
