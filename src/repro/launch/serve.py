"""DISLAND serving driver (the paper's end-to-end application).

Builds the full index over a synthetic road graph, uploads the device
tensors, then serves batched shortest-distance queries — by default
through the case-bucketing QueryPlanner (each jitted sub-program does
only its bucket's work), or monolithically (--mode fused) or sharded
over a device mesh (--mode sharded) — and validates a sample against
host Dijkstra.  Each run appends a perf record to BENCH_serve.json so
the µs/query trajectory is tracked across PRs.

    PYTHONPATH=src python -m repro.launch.serve --nodes 4000 \
        --batches 5 --batch-size 1024 --validate 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dijkstra
from ..core.device_engine import build_device_index, serve_step
from ..core.dist_engine import QueryPlanner, serve_sharded
from ..core.graph import road_like
from ..core.supergraph import build_index
from ..perflog import append_records
from ..runtime import StragglerMonitor
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=("planner", "fused", "sharded"),
                    default="planner")
    ap.add_argument("--sharded", action="store_true",
                    help="alias for --mode sharded")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="perf-record file ('' disables)")
    args = ap.parse_args()
    mode = "sharded" if args.sharded else args.mode

    t0 = time.perf_counter()
    g = road_like(args.nodes, seed=args.seed)
    print(f"graph: n={g.n} m={g.m} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    ix = build_index(g)
    print(f"index: {ix.timings} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    dix = build_device_index(ix)
    print(f"device index: frag_apsp={dix.frag_apsp.shape} "
          f"d_super={dix.d_super.shape} ({time.perf_counter() - t0:.1f}s)")

    rng = np.random.default_rng(args.seed + 1)
    monitor = StragglerMonitor()
    planner = None
    if mode == "sharded":
        mesh = make_host_mesh()
        fn = lambda s, t: serve_sharded(mesh, dix, s, t)  # noqa: E731
    elif mode == "planner":
        planner = QueryPlanner(dix)
        fn = planner
    else:
        jfn = jax.jit(lambda s, t: serve_step(dix, s, t))
        fn = jfn
    # warm-up before timing: the planner pre-compiles every sub-program
    # at every padded bucket size a batch can produce; the other modes
    # compile their one program on a throwaway batch
    if planner is not None:
        planner.warmup(args.batch_size)
    else:
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        jax.block_until_ready(jnp.asarray(fn(s, t)))
    total_q = 0
    last = None
    for i in range(args.batches):
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        monitor.start()
        out = jax.block_until_ready(jnp.asarray(fn(s, t)))
        monitor.stop()
        total_q += args.batch_size
        last = (np.asarray(s), np.asarray(t), np.asarray(out))
    summ = monitor.summary()
    per_q = summ["median_s"] / args.batch_size
    qps = args.batch_size / summ["median_s"]
    print(f"served {total_q} queries; median batch {summ['median_s']*1e3:.2f}ms "
          f"-> {per_q*1e6:.2f}us/query ({qps:,.0f} qps)")
    if planner is not None:
        print(f"planner buckets (last batch): {planner.last_counts}")
    if args.json:
        append_records(args.json, [{
            "section": "serve",
            "graph": f"road{args.nodes}",
            "mode": mode,
            "backend": jax.default_backend(),
            "batch_size": args.batch_size,
            "median_batch_ms": round(summ["median_s"] * 1e3, 3),
            "us_per_query": round(per_q * 1e6, 3),
            "qps": round(qps, 1),
        }])
        print(f"perf record appended to {args.json}")
    if args.validate:
        s, t, got = last
        bad = 0
        for i in range(min(args.validate, len(s))):
            want = dijkstra.pair(g, int(s[i]), int(t[i]))
            if not (np.isinf(want) and np.isinf(got[i])) \
                    and abs(got[i] - want) > 1e-4 * max(want, 1):
                bad += 1
        print(f"validation: {bad} mismatches of {args.validate}")
        assert bad == 0


if __name__ == "__main__":
    main()
