"""DISLAND serving driver (the paper's end-to-end application).

Builds the full index over a synthetic road graph, uploads the device
tensors, then serves batched shortest-distance queries through the
jitted serve_step — optionally sharded over a device mesh — and
validates a sample against host Dijkstra.

    PYTHONPATH=src python -m repro.launch.serve --nodes 4000 \
        --batches 5 --batch-size 1024 --validate 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import dijkstra
from ..core.device_engine import build_device_index, serve_step
from ..core.dist_engine import serve_sharded
from ..core.graph import road_like
from ..core.supergraph import build_index
from ..runtime import StragglerMonitor
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4000)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=1024)
    ap.add_argument("--validate", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharded", action="store_true")
    args = ap.parse_args()

    t0 = time.perf_counter()
    g = road_like(args.nodes, seed=args.seed)
    print(f"graph: n={g.n} m={g.m} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    ix = build_index(g)
    print(f"index: {ix.timings} ({time.perf_counter() - t0:.1f}s)")
    t0 = time.perf_counter()
    dix = build_device_index(ix)
    print(f"device index: frag_apsp={dix.frag_apsp.shape} "
          f"d_super={dix.d_super.shape} ({time.perf_counter() - t0:.1f}s)")

    rng = np.random.default_rng(args.seed + 1)
    monitor = StragglerMonitor()
    if args.sharded:
        mesh = make_host_mesh()
        fn = lambda s, t: serve_sharded(mesh, dix, s, t)  # noqa: E731
    else:
        fn = jax.jit(lambda s, t: serve_step(dix, s, t))
    total_q = 0
    last = None
    for i in range(args.batches):
        s = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        t = jnp.asarray(rng.integers(0, g.n, args.batch_size), jnp.int32)
        monitor.start()
        out = jax.block_until_ready(fn(s, t))
        monitor.stop()
        total_q += args.batch_size
        last = (np.asarray(s), np.asarray(t), np.asarray(out))
    summ = monitor.summary()
    per_q = summ["median_s"] / args.batch_size
    print(f"served {total_q} queries; median batch {summ['median_s']*1e3:.2f}ms "
          f"-> {per_q*1e6:.2f}us/query")
    if args.validate:
        s, t, got = last
        bad = 0
        for i in range(min(args.validate, len(s))):
            want = dijkstra.pair(g, int(s[i]), int(t[i]))
            if not (np.isinf(want) and np.isinf(got[i])) \
                    and abs(got[i] - want) > 1e-4 * max(want, 1):
                bad += 1
        print(f"validation: {bad} mismatches of {args.validate}")
        assert bad == 0


if __name__ == "__main__":
    main()
